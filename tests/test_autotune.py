"""Kernel registry + autotune cache (DESIGN.md §13): winner persistence,
corrupt-cache fallback, env override, shape bucketing."""
import json

import jax.numpy as jnp
import pytest

from repro.kernels import registry
from repro.kernels.autotune import (AutotuneCache, CACHE_ENV, cache_key,
                                    cached_params, get_cache, reset_cache,
                                    tune)


@pytest.fixture(autouse=True)
def _isolated_cache(tmp_path, monkeypatch):
    """Every test gets its own cache file; the singleton is dropped on
    both sides so no state leaks between tests (or into the kernels'
    normal resolve path used elsewhere in the suite)."""
    monkeypatch.setenv(CACHE_ENV, str(tmp_path / "autotune.json"))
    reset_cache()
    yield
    reset_cache()


def test_registry_lists_all_kernels():
    assert registry.ops() == ["flash_attention", "paged_attention",
                              "rmsnorm", "sample_tokens", "sgd_momentum"]
    for name in registry.ops():
        spec = registry.get(name)
        assert set(spec.defaults) == set(spec.tunables)
        assert spec.bench_cases
        # defaults are the first candidate — the sweep always times the
        # untuned baseline, which is what makes speedup >= 1.0 exact
        assert spec.candidates()[0] == spec.defaults


def test_resolve_precedence():
    # no cache entry: defaults
    assert registry.resolve("rmsnorm", {"block_rows": None},
                            "rows=512,d=256,f32") == {"block_rows": 256}
    # cached winner beats defaults
    c = get_cache()
    c.put(cache_key("rmsnorm", "rows=512,d=256,f32"), {"block_rows": 1024},
          tuned_us=1.0, default_us=2.0)
    assert registry.resolve("rmsnorm", {"block_rows": None},
                            "rows=512,d=256,f32") == {"block_rows": 1024}
    # explicit kwarg beats the cached winner
    assert registry.resolve("rmsnorm", {"block_rows": 64},
                            "rows=512,d=256,f32") == {"block_rows": 64}


def test_winner_roundtrip(tmp_path):
    path = tmp_path / "rt.json"
    c = AutotuneCache(path)
    key = cache_key("rmsnorm", "rows=2048,d=512,f32", backend="cpu")
    c.put(key, {"block_rows": 1024}, tuned_us=10.0, default_us=25.0)
    c.save()
    re = AutotuneCache(path)
    assert re.get(key) == {"block_rows": 1024}
    assert re.entries[key]["default_us"] == 25.0
    # unknown key -> None, never a KeyError
    assert re.get("nope|cpu|x") is None


def test_corrupt_cache_warns_and_falls_back(tmp_path, monkeypatch):
    path = tmp_path / "corrupt.json"
    path.write_text("{ this is not json")
    monkeypatch.setenv(CACHE_ENV, str(path))
    reset_cache()
    with pytest.warns(UserWarning, match="falling back to default"):
        c = get_cache()
    assert c.entries == {}
    # resolve still answers with the registered defaults
    assert registry.resolve("rmsnorm", {"block_rows": None},
                            "rows=512,d=256,f32") == {"block_rows": 256}
    # wrong shape (valid json, no entries table) degrades the same way
    path.write_text(json.dumps([1, 2, 3]))
    reset_cache()
    with pytest.warns(UserWarning):
        assert get_cache().entries == {}


def test_env_override_moves_the_cache(tmp_path, monkeypatch):
    a, b = tmp_path / "a.json", tmp_path / "b.json"
    monkeypatch.setenv(CACHE_ENV, str(a))
    reset_cache()
    c = get_cache()
    c.put(cache_key("rmsnorm", "rows=512,d=256,f32"), {"block_rows": 64},
          tuned_us=1.0, default_us=2.0)
    c.save()
    assert a.exists() and not b.exists()
    monkeypatch.setenv(CACHE_ENV, str(b))
    reset_cache()
    assert cached_params("rmsnorm", "rows=512,d=256,f32") is None
    monkeypatch.setenv(CACHE_ENV, str(a))
    reset_cache()
    assert cached_params("rmsnorm",
                         "rows=512,d=256,f32") == {"block_rows": 64}


def test_shape_bucket_collision():
    """Two nearby shapes share one pow2 bucket (and therefore one tuned
    winner); a shape past the next power of two does not."""
    spec = registry.get("rmsnorm")
    w = jnp.zeros((256,))
    b_300 = spec.bucket_of(jnp.zeros((300, 256)), w)
    b_500 = spec.bucket_of(jnp.zeros((500, 256)), w)
    b_600 = spec.bucket_of(jnp.zeros((600, 256)), w)
    assert b_300 == b_500 == "rows=512,d=256,f32"
    assert b_600 == "rows=1024,d=256,f32"
    c = get_cache()
    c.put(cache_key("rmsnorm", b_300), {"block_rows": 1024},
          tuned_us=1.0, default_us=2.0)
    # the collision shape sees the winner, the out-of-bucket one doesn't
    assert registry.resolve("rmsnorm", {"block_rows": None},
                            b_500) == {"block_rows": 1024}
    assert registry.resolve("rmsnorm", {"block_rows": None},
                            b_600) == {"block_rows": 256}
    # last dim is NOT bucketed (it changes the kernel's inner tile), and
    # dtype partitions buckets too
    assert spec.bucket_of(jnp.zeros((300, 192)), w) != b_300
    assert spec.bucket_of(jnp.zeros((300, 256), jnp.bfloat16), w) != b_300


def test_tune_sweeps_and_persists():
    x = jnp.ones((128, 64)) * jnp.arange(64)
    w = jnp.ones((64,))
    rep = tune("rmsnorm", (x, w), repeats=1, warmup=1)
    assert set(rep["params"]) == {"block_rows"}
    assert rep["speedup"] >= 1.0     # defaults are in the sweep
    assert len(rep["sweep"]) == len(registry.get("rmsnorm").candidates())
    # the winner is on disk and consulted by resolve for the SAME bucket
    reset_cache()
    assert cached_params("rmsnorm", rep["bucket"]) == rep["params"]


def test_ops_wrappers_accept_explicit_tunables():
    """The public wrappers keep working with hand-passed schedule kwargs
    (explicit beats cache beats defaults) and produce oracle results."""
    from repro.kernels import ops, ref
    x = jnp.ones((96, 64)) * jnp.arange(64)
    w = jnp.ones((64,))
    want = ref.rmsnorm_ref(x, w)
    for br in (None, 64, 1024):
        got = ops.rmsnorm(x, w, block_rows=br)
        assert jnp.allclose(got, want, atol=1e-5)
