"""Unit tests for the dry-run/roofline tooling: the HLO collective parser
(replica-group accounting) and the probe-composition arithmetic."""
import pytest

from repro.launch.dryrun import _group_size, collective_bytes


HLO = """
  %ar = f32[16,512]{1,0} all-reduce(%x), replica_groups=[32,16]<=[512], to_apply=%sum
  %ag = bf16[4,1024]{1,0} all-gather(%y), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={1}
  %rs = f32[64]{0} reduce-scatter(%z), replica_groups=[2,8]<=[16], to_apply=%sum
  %a2a = bf16[8,8]{1,0} all-to-all(%w), replica_groups=[4,4]<=[16]
  %cp = f32[100]{0} collective-permute(%v), source_target_pairs={{0,1}}
  %other = f32[5]{0} add(%a, %b)
"""


def test_group_size_iota_and_list():
    assert _group_size("replica_groups=[32,16]<=[512]") == 16
    assert _group_size("replica_groups={{0,1,2,3},{4,5,6,7}}, dim") == 4
    assert _group_size("no groups here") == 1


def test_collective_bytes_accounting():
    out = collective_bytes(HLO)
    # all-reduce: result 16*512*4 = 32768 B, g=16 -> 2*S*(g-1)/g
    assert out["all-reduce"] == pytest.approx(2 * 32768 * 15 / 16)
    # all-gather: result 4*1024*2 = 8192 B, g=4 -> S*(g-1)/g
    assert out["all-gather"] == pytest.approx(8192 * 3 / 4)
    # reduce-scatter: result 64*4 = 256 B, g=8 -> S*(g-1)
    assert out["reduce-scatter"] == pytest.approx(256 * 7)
    # all-to-all: 8*8*2 = 128 B, g=4 -> S*(g-1)/g
    assert out["all-to-all"] == pytest.approx(128 * 3 / 4)
    # collective-permute: S
    assert out["collective-permute"] == pytest.approx(400)
    assert out["counts"]["all-reduce"] == 1
    assert out["total"] == pytest.approx(
        sum(out[k] for k in ("all-reduce", "all-gather", "reduce-scatter",
                             "all-to-all", "collective-permute")))


def test_collective_bytes_ignores_non_collectives():
    out = collective_bytes("%m = f32[128,128]{1,0} dot(%a, %b)")
    assert out["total"] == 0


def test_probe_composition():
    """total = base + n_super*per, per = (p4-p2)/2, base = p2-2*per."""
    from benchmarks.roofline import composed
    rec = {"probe2": {"flops": 110.0}, "probe4": {"flops": 210.0},
           "full": {"flops": 999.0}}
    val, src = composed(rec, ("flops",), ns=10)
    # per = 50, base = 10 -> 10 + 10*50 = 510
    assert val == pytest.approx(510.0)
    assert src == "probes"
    # fallback to full when probes missing
    val, src = composed({"full": {"flops": 999.0}}, ("flops",), ns=10)
    assert val == 999.0 and "full" in src


def test_roofline_terms_and_bottleneck():
    from benchmarks.roofline import analyze_record
    rec = {
        "status": "OK", "arch": "qwen1.5-0.5b", "shape": "train_4k",
        "n_layers": 24, "n_super": 24,
        "params": int(4.6e8), "params_active": int(4.6e8),
        "probe2": {"flops": 2e12, "bytes_accessed": 2e11,
                   "collectives": {"total": 2e10}},
        "probe4": {"flops": 4e12, "bytes_accessed": 4e11,
                   "collectives": {"total": 4e10}},
        "full": {"flops": 1, "bytes_accessed": 1,
                 "collectives": {"total": 1},
                 "memory": {"peak_per_device": 2**30}},
    }
    r = analyze_record(rec)
    # per-super: 1e12 flops -> total 24e12 -> compute = 24e12/197e12
    assert r["t_compute_s"] == pytest.approx(24e12 / 197e12)
    assert r["bottleneck"] in ("compute", "memory", "collective")
    assert r["peak_gib_per_dev"] == pytest.approx(1.0)
    assert 0 < r["useful_ratio"] < 10
