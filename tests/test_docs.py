"""Docs stay honest: every file README.md references must exist, and the
worked examples in the ``repro.dist`` docstrings must run (doctest).

CI runs this as a dedicated docs job; it is also part of tier-1 so a PR
cannot rename a module out from under the README.
"""
import doctest
import importlib
import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]

_MD_LINK = re.compile(r"\]\(([^)#]+)\)")
_CODE_PATH = re.compile(r"`([\w./-]+/[\w./-]+)`")

DIST_MODULES = ["repro.dist", "repro.dist.annotate", "repro.dist.bucketing",
                "repro.dist.collectives", "repro.dist.partition",
                "repro.dist.pipeline", "repro.dist.ring", "repro.dist.compat"]


def _referenced_paths():
    text = (ROOT / "README.md").read_text()
    refs = set()
    for m in _MD_LINK.finditer(text):
        target = m.group(1).strip()
        if "://" not in target:
            refs.add(target)
    for m in _CODE_PATH.finditer(text):
        p = m.group(1)
        # only things that look like repo paths (not shell flags / dotted
        # module names / spec fragments)
        if p.startswith(("src/", "tests/", "benchmarks/", "examples/",
                         "experiments/")) or p.endswith((".py", ".md")):
            refs.add(p.rstrip("/"))
    return sorted(refs)


def test_readme_exists_and_has_front_door_sections():
    text = (ROOT / "README.md").read_text()
    for required in ("Install", "Quickstart", "Concept map",
                     "pip install -e .", "python -m pytest -x -q"):
        assert required in text, f"README.md lost its '{required}' section"


@pytest.mark.parametrize("ref", _referenced_paths())
def test_readme_referenced_files_exist(ref):
    assert (ROOT / ref).exists(), f"README.md references missing path: {ref}"


@pytest.mark.parametrize("modname", DIST_MODULES)
def test_dist_doctests_pass(modname):
    mod = importlib.import_module(modname)
    result = doctest.testmod(mod, verbose=False)
    assert result.failed == 0, f"{modname}: {result.failed} doctest failures"


def test_dist_modules_are_documented():
    """The PR-1 subsystem shipped nearly undocumented; keep it documented:
    every dist module needs a real docstring and the public API a worked
    example somewhere in the package."""
    total_examples = 0
    for modname in DIST_MODULES:
        mod = importlib.import_module(modname)
        assert mod.__doc__ and len(mod.__doc__.strip()) > 80, modname
        total_examples += doctest.testmod(mod, verbose=False).attempted
    assert total_examples >= 10, "dist worked examples eroded"
