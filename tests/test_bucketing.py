"""Bucketed gradient sync (DESIGN.md §7): BucketPlan packing invariants,
pack/unpack round-trip, numeric equivalence of ``mode="bucketed"`` with
``mode="flat"`` on the 2x4x2 dry-run mesh, overlap taps, and the per-key
KVStore byte attribution the bucketed cross-validation relies on.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from mesh_subproc import run_sub
from repro.dist import gradient_sync
from repro.dist.bucketing import BucketPlan, leaf_nbytes, overlap_taps


def _structs(shapes, dtype="float32"):
    return [jax.ShapeDtypeStruct(tuple(s), dtype) for s in shapes]


# ---------------------------------------------------------------------------
# BucketPlan invariants

def _check_invariants(plan, leaves, cap, lead_dims=0):
    # every leaf exactly once
    seen = [i for b in plan.buckets for i in b.indices]
    assert sorted(seen) == list(range(len(leaves)))
    assert len(plan.assignment()) == len(leaves)
    for b, bucket in enumerate(plan.buckets):
        # dtype-pure buckets
        assert all(str(jnp.dtype(leaves[i].dtype)) == bucket.dtype
                   for i in bucket.indices)
        # byte cap respected except single oversized leaves
        if bucket.nbytes > cap:
            assert len(bucket.indices) == 1, (b, bucket)
        # recorded sizes consistent with the leaves
        elems = [math.prod(tuple(leaves[i].shape)[lead_dims:])
                 for i in bucket.indices]
        assert list(bucket.elems) == elems
        assert bucket.nbytes == sum(elems) * jnp.dtype(bucket.dtype).itemsize


def test_plan_basic_first_fit():
    leaves = _structs([(256, 256), (1024,), (512, 512)])  # 256K, 4K, 1M
    plan = BucketPlan.build(leaves, cap_bytes=300 * 1024)
    _check_invariants(plan, leaves, 300 * 1024)
    assert plan.n_buckets == 2
    assert plan.assignment() == (0, 0, 1)  # 4K first-fits beside 256K


def test_plan_oversized_leaf_is_isolated():
    leaves = _structs([(512, 512), (8,), (8,)])  # 1M then two tiny
    plan = BucketPlan.build(leaves, cap_bytes=1024)
    _check_invariants(plan, leaves, 1024)
    # the tiny leaves must NOT ride along in the oversized bucket
    assert plan.assignment()[0] != plan.assignment()[1]
    assert plan.assignment()[1] == plan.assignment()[2]


def test_plan_mixed_dtypes_never_share_buckets():
    leaves = (_structs([(16,)], "float32") + _structs([(16,)], "bfloat16")
              + _structs([(16,)], "float32"))
    plan = BucketPlan.build(leaves, cap_bytes=1 << 20)
    _check_invariants(plan, leaves, 1 << 20)
    a = plan.assignment()
    assert a[0] == a[2] != a[1]


def test_plan_rejects_bad_cap():
    with pytest.raises(ValueError):
        BucketPlan.build(_structs([(4,)]), cap_bytes=0)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=5000), min_size=1,
                max_size=40),
       st.integers(min_value=1, max_value=16 * 1024))
def test_plan_property_partition_and_cap(sizes, cap):
    """Property test: any leaf list is partitioned exactly once and every
    multi-leaf bucket respects the byte cap."""
    leaves = _structs([(n,) for n in sizes])
    plan = BucketPlan.build(leaves, cap_bytes=cap)
    _check_invariants(plan, leaves, cap)


def test_leaf_nbytes():
    assert leaf_nbytes(jax.ShapeDtypeStruct((3, 4), "float32")) == 48
    assert leaf_nbytes(jax.ShapeDtypeStruct((), "bfloat16")) == 2


# ---------------------------------------------------------------------------
# pack / unpack round-trip

def test_pack_unpack_roundtrip_lead_dim():
    rng = np.random.RandomState(0)
    leaves = [jnp.asarray(rng.randn(4, 3, 5), np.float32),
              jnp.asarray(rng.randn(4, 7), np.float32),
              jnp.asarray(rng.randn(4, 2, 2, 2), np.float32)]
    plan = BucketPlan.build(leaves, cap_bytes=10 * 4, lead_dims=1)
    buffers = plan.pack(leaves, lead_dims=1)
    assert all(b.shape[0] == 4 for b in buffers)
    back = plan.unpack(buffers, leaves, lead_dims=1)
    for a, b in zip(leaves, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_unpack_after_lead_reduction():
    """unpack() also restores shapes when the buffers lost the lead dim
    (the gradient_sync case: sync reduces over workers)."""
    leaves = [jnp.ones((4, 3)), jnp.ones((4, 5))]
    plan = BucketPlan.build(leaves, cap_bytes=1 << 20, lead_dims=1)
    buffers = [b.sum(0) for b in plan.pack(leaves, lead_dims=1)]
    back = plan.unpack(buffers, leaves, lead_dims=1)
    assert [tuple(b.shape) for b in back] == [(3,), (5,)]
    np.testing.assert_array_equal(np.asarray(back[0]), np.full((3,), 4.0))


# ---------------------------------------------------------------------------
# gradient_sync mode="bucketed" — numerics on the 2x4x2 dry-run mesh

@pytest.mark.mesh
def test_bucketed_sync_matches_flat_on_mesh():
    out = run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.dist import gradient_sync
    mesh = jax.make_mesh((2, 4, 2), ("pod", "data", "model"))
    W = 8
    rng = np.random.RandomState(0)
    grads = {"a": jnp.asarray(rng.randn(W, 3, 5), jnp.float32),
             "b": jnp.asarray(rng.randn(W, 7), jnp.float32),
             "c": jnp.asarray(rng.randn(W, 64), jnp.float32)}
    with jax.set_mesh(mesh):
        # tiny cap -> multiple buckets; must equal the flat reduction
        b = gradient_sync(mesh, grads, mode="bucketed", bucket_bytes=64)
        f = gradient_sync(mesh, grads, mode="flat")
    for k in grads:
        np.testing.assert_allclose(np.asarray(b[k]), np.asarray(f[k]),
                                   rtol=1e-5)
        assert b[k].shape == grads[k].shape[1:]
    print("BUCKETED_OK")
    """)
    assert "BUCKETED_OK" in out


def test_bucketed_sync_no_mesh_fallback():
    mesh = jax.make_mesh((1,), ("data",))
    grads = {"w": jnp.arange(12, dtype=jnp.float32).reshape(4, 3)}
    out = gradient_sync(mesh, grads, mode="bucketed")
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(grads["w"]).sum(0))


# ---------------------------------------------------------------------------
# overlap taps: identity forward, identity gradients

def test_overlap_taps_identity_and_grads():
    rng = np.random.RandomState(0)
    params = {"w1": jnp.asarray(rng.randn(8, 4), np.float32),
              "w2": jnp.asarray(rng.randn(4,), np.float32)}
    x = jnp.asarray(rng.randn(3, 8), np.float32)

    def loss(p, tap):
        q = overlap_taps(p, cap_bytes=16) if tap else p
        return jnp.sum((x @ q["w1"] + q["w2"]) ** 2)

    l0, g0 = jax.value_and_grad(lambda p: loss(p, False))(params)
    l1, g1 = jax.value_and_grad(lambda p: loss(p, True))(params)
    assert float(l0) == float(l1)
    for k in params:
        np.testing.assert_array_equal(np.asarray(g0[k]), np.asarray(g1[k]))


def test_trainer_overlap_step_matches_plain():
    """A Trainer step with overlap=True is numerically identical to the
    default step (the taps only restructure the collective schedule)."""
    from repro.configs import get_config
    from repro.models import reduced
    from repro.train import TrainConfig, Trainer

    cfg = reduced(get_config("qwen1.5-0.5b"), vocab=32, n_layers=2,
                  d_model=64, d_ff=128)
    data = {"tokens": jnp.asarray(
        np.random.RandomState(0).randint(0, 32, (4, 16)))}
    outs = []
    for overlap in (False, True):
        tcfg = TrainConfig(lr=1e-2, total_steps=1, overlap=overlap,
                           bucket_mb=0.001)
        tr = Trainer(cfg, tcfg)
        params, opt = tr.init_state(seed=0)
        step = tr._make_step()
        p2, _, metrics = step(params, opt, data)
        outs.append((p2, metrics))
    (pa, ma), (pb, mb) = outs
    assert float(ma["loss"]) == float(mb["loss"])
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.mesh
def test_trainer_overlap_step_on_mesh():
    """The overlap taps' replicated-pin branch under a real multi-device
    mesh: the step must run and match the plain step's loss."""
    out = run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models import reduced
    from repro.train import TrainConfig, Trainer
    cfg = reduced(get_config("qwen1.5-0.5b"), vocab=32, n_layers=2,
                  d_model=64, d_ff=128)
    data = {"tokens": jnp.asarray(
        np.random.RandomState(0).randint(0, 32, (8, 16)))}
    mesh = jax.make_mesh((4, 4), ("data", "model"))
    losses = []
    with jax.set_mesh(mesh):
        for overlap in (False, True):
            tr = Trainer(cfg, TrainConfig(overlap=overlap, bucket_mb=0.001))
            params, opt = tr.init_state(seed=0)
            _, _, metrics = tr._make_step()(params, opt, data)
            losses.append(float(metrics["loss"]))
    assert abs(losses[0] - losses[1]) < 1e-5, losses
    print("OVERLAP_MESH_OK")
    """)
    assert "OVERLAP_MESH_OK" in out


# ---------------------------------------------------------------------------
# per-key KVStore byte attribution (the analytic side of the bucketed
# cross-validation in benchmarks/bench_dist.py)

def test_kvstore_dist_per_key_attribution():
    from repro.core import KVStoreDist
    kv = KVStoreDist(n_machines=2, devices_per_machine=4,
                     consistency="sequential")
    sizes = {"bucket0": 1024, "bucket1": 512}
    for k, n in sizes.items():
        kv.init(k, np.zeros(n, np.float32))
    for w in range(8):
        for k, n in sizes.items():
            kv.push(k, worker=w, grad=np.ones(n, np.float32))
    assert sum(kv.bytes_l1_by_key.values()) == kv.bytes_l1
    assert sum(kv.bytes_l2_by_key.values()) == kv.bytes_l2
    for k, n in sizes.items():
        assert kv.bytes_l1_by_key[k] == 8 * n * 4
        assert kv.bytes_l2_by_key[k] == 2 * n * 4
        assert kv.bytes_l1_by_key[k] == 4 * kv.bytes_l2_by_key[k]


def test_kvstore_local_per_key_attribution():
    from repro.core import KVStoreLocal, NDArray, reset_default_engine
    eng = reset_default_engine()
    kv = KVStoreLocal(eng)
    kv.init("a", np.zeros(16, np.float32))
    kv.init("b", np.zeros(4, np.float32))
    kv.push("a", NDArray(np.ones(16, np.float32), engine=eng))
    kv.push("b", NDArray(np.ones(4, np.float32), engine=eng))
    kv.push("a", NDArray(np.ones(16, np.float32), engine=eng))
    assert kv.bytes_pushed_by_key["a"] == 2 * 16 * 4
    assert kv.bytes_pushed_by_key["b"] == 4 * 4
    assert sum(kv.bytes_pushed_by_key.values()) == kv.bytes_pushed
