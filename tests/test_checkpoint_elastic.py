"""Elastic restore (ISSUE 7 / DESIGN.md §12): sharded save -> restore
onto a DIFFERENT mesh is bit-exact — across dp/model/stage reshapes,
restore-to-single-device, every arch config in the partition rule table,
and a property suite over the resharding assembly math itself.  The
resume-parity gates check that training continued from a checkpoint on a
reshaped mesh tracks the uninterrupted run's losses to <= 1e-6."""
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from mesh_subproc import run_sub

# ---------------------------------------------------------------------------
# property suite: the resharding math (save grid -> target grid), pure host


def _chunk(arr, grid):
    """Shards of ``arr`` under a per-dim chunk grid — what the device
    shards of a NamedSharding layout look like on disk: (start, block)
    pairs covering the array exactly once."""
    assert len(grid) == arr.ndim
    def splits(dim, k):
        q = dim // k
        return [(i * q, q) for i in range(k)]
    out = [((), arr)] if arr.ndim == 0 else []
    if arr.ndim == 0:
        return out
    import itertools
    per_dim = [splits(d, k) for d, k in zip(arr.shape, grid)]
    for combo in itertools.product(*per_dim):
        start = tuple(s for s, _ in combo)
        ix = tuple(slice(s, s + n) for s, n in combo)
        out.append((start, np.ascontiguousarray(arr[ix])))
    return out


def _write_fake_ckpt(tmp_path, arr, grid):
    """A manifest leaf + shard files exactly as ``save_checkpoint`` lays
    them out, but with the chunk grid chosen by the test."""
    meta = {"path": [["k", "w"]], "shape": list(arr.shape),
            "dtype": str(arr.dtype), "shards": []}
    for j, (start, block) in enumerate(_chunk(arr, grid)):
        f = tmp_path / f"l0_s{j}.bin"
        f.write_bytes(block.tobytes())
        meta["shards"].append({"file": f.name, "start": list(start),
                               "shape": list(block.shape)})
    return meta


def _divisors(n):
    return [k for k in (1, 2, 3, 4) if n % k == 0]


@pytest.mark.parametrize("shape,save_grid,target_grid", [
    ((8, 6), (2, 3), (4, 1)),          # dp-major -> model-major
    ((8, 6), (4, 1), (1, 3)),          # model-only target
    ((12,), (4,), (3,)),               # non-nested split boundaries
    ((4, 4, 8), (2, 1, 4), (1, 4, 2)), # 3-D (stacked-blocks style)
    ((8, 6), (2, 2), (1, 1)),          # restore to single device
    ((8, 6), (1, 1), (4, 3)),          # replicated save -> sharded target
])
def test_reshard_assembly_exact(tmp_path, shape, save_grid, target_grid):
    from repro.train.checkpoint import _assemble
    rng = np.random.RandomState(0)
    arr = rng.randn(*shape).astype(np.float32)
    meta = _write_fake_ckpt(tmp_path, arr, save_grid)
    for start, block in _chunk(arr, target_grid):
        ix = tuple(slice(s, s + n) for s, n in zip(start, block.shape))
        got = _assemble(tmp_path, meta, ix)
        np.testing.assert_array_equal(got, block)


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_reshard_assembly_property(tmp_path_factory, data):
    """Any save grid -> any target grid reconstructs every target shard
    bit-exactly (the dp<->pp<->seq reshape space, abstractly)."""
    from repro.train.checkpoint import _assemble
    ndim = data.draw(st.integers(0, 3), label="ndim")
    shape = tuple(data.draw(st.sampled_from([1, 2, 3, 4, 6, 12]),
                            label=f"dim{i}") for i in range(ndim))
    save_grid = tuple(data.draw(st.sampled_from(_divisors(d)),
                                label=f"sg{i}") for i, d in enumerate(shape))
    tgt_grid = tuple(data.draw(st.sampled_from(_divisors(d)),
                               label=f"tg{i}") for i, d in enumerate(shape))
    tmp = tmp_path_factory.mktemp("reshard")
    arr = np.arange(int(np.prod(shape, dtype=np.int64)),
                    dtype=np.float32).reshape(shape)
    meta = _write_fake_ckpt(tmp, arr, save_grid)
    for start, block in _chunk(arr, tgt_grid):
        ix = tuple(slice(s, s + n) for s, n in zip(start, block.shape))
        np.testing.assert_array_equal(_assemble(tmp, meta, ix), block)


def test_rule_table_round_trips_through_json():
    """Every role in the partition rule table survives the manifest's
    spec serialization unchanged (the spec each leaf "was saved under")."""
    from jax.sharding import PartitionSpec as P
    from repro.dist.partition import _PARAM_RULES
    from repro.train.checkpoint import _spec_from_json, _spec_to_json
    for role, entries in _PARAM_RULES.items():
        spec = P(*entries)
        back = _spec_from_json(_spec_to_json(spec, len(entries)))
        assert tuple(back) == tuple(spec), role


# ---------------------------------------------------------------------------
# real meshes (subprocess; 4/8 forced host devices)


@pytest.mark.mesh
def test_elastic_roundtrip_all_archs():
    """Sharded save on a 2x2 (data, model) mesh -> restore onto 1x4 and
    onto a single device, bit-exact, for EVERY config in the registry
    (the rule table resolves per arch: dense GQA, MoE, SSM, hybrid,
    VLM-prefix, enc-dec)."""
    out = run_sub("""
    import tempfile, jax, numpy as np
    from repro.configs import ARCH_IDS, get_config
    from repro.models import get_model, reduced
    from repro.dist.partition import make_shardings, param_pspecs
    from repro.train import load_checkpoint, save_checkpoint

    mesh_a = jax.make_mesh((2, 2), ("data", "model"))
    mesh_b = jax.make_mesh((4,), ("model",))
    for arch in ARCH_IDS:
        cfg = reduced(get_config(arch))
        params = get_model(cfg).init(jax.random.PRNGKey(0))
        state = {"params": params}
        ref = [np.asarray(x) for x in jax.tree.leaves(jax.device_get(state))]
        sharded = jax.device_put(
            state, make_shardings(mesh_a, param_pspecs(None, state, mesh_a)))
        d = tempfile.mkdtemp()
        save_checkpoint(d, sharded, step=0)
        n_multi = sum(
            1 for leaf in jax.tree.leaves(sharded)
            if len({tuple(int(sl.start or 0) for sl in s.index)
                    for s in leaf.addressable_shards}) > 1)
        assert n_multi > 0, f"{arch}: nothing was actually sharded"
        for tag, tgt in (("1x4", mesh_b), ("single", None)):
            restored, _ = load_checkpoint(d, like=state, mesh=tgt)
            for a, b in zip(jax.tree.leaves(jax.device_get(restored)), ref):
                assert np.array_equal(np.asarray(a), b), (arch, tag)
        print(arch, "OK", n_multi, "sharded leaves")
    print("ALL_ARCHS_OK")
    """, devices=4)
    assert "ALL_ARCHS_OK" in out


@pytest.mark.mesh
def test_elastic_roundtrip_opt_state_and_stage_mesh():
    """Params + momentum opt-state saved under a PIPELINED (stage, data)
    mesh restore bit-exactly onto model-parallel, single-device, and
    back onto a different stage mesh (dp x pp 2x2 -> 1x4 and friends)."""
    out = run_sub("""
    import tempfile, jax, numpy as np
    from repro.configs import get_config
    from repro.models import get_model, reduced
    from repro.dist.partition import make_shardings, param_pspecs
    from repro.dist.pipeline import stage_pspecs
    from repro.optim import sgd_momentum
    from repro.train import load_checkpoint, save_checkpoint

    cfg = reduced(get_config("qwen1.5-0.5b"))
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": sgd_momentum().init(params)}
    ref = [np.asarray(x) for x in jax.tree.leaves(jax.device_get(state))]

    mesh_pp = jax.make_mesh((2, 2), ("stage", "data"))
    sharded = jax.device_put(
        state, make_shardings(mesh_pp, stage_pspecs(None, state, mesh_pp)))
    d = tempfile.mkdtemp()
    save_checkpoint(d, sharded, step=3)

    # pipelined 2x2 -> unpipelined 1x4
    mesh_b = jax.make_mesh((4,), ("model",))
    rb, step = load_checkpoint(d, like=state, mesh=mesh_b)
    assert step == 3
    for a, b in zip(jax.tree.leaves(jax.device_get(rb)), ref):
        assert np.array_equal(np.asarray(a), b)

    # pipelined 2x2 -> single device (template-free: the serve handoff)
    rs, _ = load_checkpoint(d)
    for a, b in zip(jax.tree.leaves(rs), ref):
        assert np.array_equal(np.asarray(a), b)

    # dp-style save -> restore INTO an ambient stage mesh (grow the run)
    mesh_dp = jax.make_mesh((4,), ("data",))
    d2 = tempfile.mkdtemp()
    save_checkpoint(d2, jax.device_put(
        state, make_shardings(mesh_dp, param_pspecs(None, state, mesh_dp))))
    with jax.set_mesh(mesh_pp):
        rp, _ = load_checkpoint(d2, like=state)
    blk = jax.tree.leaves(rp["params"]["blocks"])[0]
    assert "stage" in str(blk.sharding.spec), blk.sharding.spec
    for a, b in zip(jax.tree.leaves(jax.device_get(rp)), ref):
        assert np.array_equal(np.asarray(a), b)
    print("ELASTIC_OPT_STAGE_OK")
    """, devices=4)
    assert "ELASTIC_OPT_STAGE_OK" in out


@pytest.mark.mesh
def test_resume_parity_across_mesh_reshapes():
    """Acceptance gate: training resumed from a sharded checkpoint onto
    a DIFFERENT mesh matches the uninterrupted run's per-step losses to
    <= 1e-6 for 5 steps, on two distinct reshape pairs:
    (2x2 data x model -> 1x4 model) and (4x1 data -> single device)."""
    out = run_sub("""
    import tempfile, itertools, jax, numpy as np
    from repro.configs import get_config
    from repro.data import SyntheticLM
    from repro.models import reduced
    from repro.train import TrainConfig, Trainer, latest_checkpoint, \
        load_checkpoint

    cfg = reduced(get_config("qwen1.5-0.5b"))
    STEPS, CKPT_AT = 11, 6

    def data():
        return iter(SyntheticLM(cfg.vocab, 32, 4, n_batches=STEPS))

    def losses(tr):
        return {h["step"]: h["loss"] for h in tr.history}

    def uninterrupted(mesh_ctx):
        tcfg = TrainConfig(lr=1e-2, total_steps=STEPS, warmup_steps=2,
                           log_every=1, grad_clip=1.0)
        tr = Trainer(cfg, tcfg)
        with mesh_ctx():
            tr.fit(data())
        return losses(tr)

    def interrupted(mesh_a_ctx, mesh_b_ctx, root):
        # same schedule horizon as the uninterrupted run; the "crash" is
        # the data stream ending after the checkpointed step
        tcfg = TrainConfig(lr=1e-2, total_steps=STEPS, warmup_steps=2,
                           log_every=1, grad_clip=1.0,
                           checkpoint_every=CKPT_AT, checkpoint_dir=root)
        tr = Trainer(cfg, tcfg)
        with mesh_a_ctx():
            tr.fit(itertools.islice(data(), CKPT_AT + 1))
        # resume on mesh B from the committed step-6 checkpoint
        tcfg2 = TrainConfig(lr=1e-2, total_steps=STEPS, warmup_steps=2,
                            log_every=1, grad_clip=1.0)
        tr2 = Trainer(cfg, tcfg2)
        with mesh_b_ctx():
            restored, step = load_checkpoint(latest_checkpoint(root))
            assert step == CKPT_AT, step
            it = data()
            for _ in range(step + 1):
                next(it)
            tr2.fit(it, state=(restored["params"], restored["opt"]),
                    start_step=step + 1)
        return losses(tr2)

    import contextlib
    mesh22 = lambda: jax.set_mesh(jax.make_mesh((2, 2), ("data", "model")))
    mesh14 = lambda: jax.set_mesh(jax.make_mesh((4,), ("model",)))
    mesh41 = lambda: jax.set_mesh(jax.make_mesh((4,), ("data",)))
    single = contextlib.nullcontext

    for name, (ma, mb) in {"2x2->1x4": (mesh22, mesh14),
                           "4x1->single": (mesh41, single)}.items():
        base = uninterrupted(ma)
        res = interrupted(ma, mb, tempfile.mkdtemp())
        diffs = [abs(base[s] - res[s]) for s in range(CKPT_AT + 1, STEPS)]
        assert len(diffs) >= 4
        print(name, "max loss diff", max(diffs))
        assert max(diffs) <= 1e-6, (name, diffs)
    print("RESUME_PARITY_OK")
    """, devices=4)
    assert "RESUME_PARITY_OK" in out
