"""Model-layer correctness: SSD vs sequential oracle, decode vs full
forward, windowed attention, GQA vs explicit reference, MoE dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model, reduced
from repro.models.layers import decode_attention, gqa_attention
from repro.models.ssm import ssd_chunked, ssd_reference

KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# SSD: chunked == sequential recurrence

@pytest.mark.parametrize("T,chunk", [(32, 8), (64, 16), (128, 128)])
def test_ssd_chunked_matches_reference(T, chunk):
    B, H, P, N = 2, 3, 8, 16
    ks = jax.random.split(KEY, 5)
    xh = jax.random.normal(ks[0], (B, T, H, P))
    Bm = jax.random.normal(ks[1], (B, T, N)) * 0.5
    Cm = jax.random.normal(ks[2], (B, T, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, T, H)) - 1.0)
    A_log = jax.random.normal(ks[4], (H,)) * 0.3
    D = jnp.ones((H,))
    y_ref = ssd_reference(xh, Bm, Cm, dt, A_log, D)
    y, final = ssd_chunked(xh, Bm, Cm, dt, A_log, D, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_final_state_continues_decode():
    """Prefill state + decode steps == running the full sequence."""
    B, T, H, P, N = 1, 24, 2, 4, 8
    ks = jax.random.split(KEY, 5)
    xh = jax.random.normal(ks[0], (B, T + 4, H, P))
    Bm = jax.random.normal(ks[1], (B, T + 4, N)) * 0.5
    Cm = jax.random.normal(ks[2], (B, T + 4, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, T + 4, H)) - 1.0)
    A_log = jax.random.normal(ks[4], (H,)) * 0.3
    D = jnp.zeros((H,))

    y_all = ssd_reference(xh, Bm, Cm, dt, A_log, D)
    _, state = ssd_chunked(xh[:, :T], Bm[:, :T], Cm[:, :T], dt[:, :T],
                           A_log, D, chunk=8)
    A = -jnp.exp(A_log)
    for t in range(T, T + 4):
        dA = jnp.exp(dt[:, t] * A)
        state = state * dA[:, :, None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dt[:, t], Bm[:, t], xh[:, t])
        y_t = jnp.einsum("bn,bhpn->bhp", Cm[:, t], state)
        np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_all[:, t]),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# attention

def _ref_attention(q, k, v, causal=True, window=None, softcap=None,
                   q_offset=0):
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    kk = jnp.repeat(k, G, axis=2)
    vv = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bshd->bhqs", q, kk) / np.sqrt(hd)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m &= kpos[None] <= qpos[:, None]
    if window:
        m &= kpos[None] > qpos[:, None] - window
    s = jnp.where(m[None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqs,bshd->bqhd", p, vv)


@pytest.mark.parametrize("H,K", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("window,softcap", [(None, None), (6, None),
                                            (None, 30.0)])
def test_gqa_attention_vs_reference(H, K, window, softcap):
    B, S, hd = 2, 16, 8
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    out = gqa_attention(q, k, v, causal=True, window=window, softcap=softcap)
    ref = _ref_attention(q, k, v, causal=True, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_decode_attention_matches_last_row_of_full():
    B, S, H, K, hd = 2, 12, 4, 2, 8
    ks = jax.random.split(KEY, 3)
    q_all = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    full = _ref_attention(q_all, k, v, causal=True)
    out = decode_attention(q_all[:, -1:], k, v, cache_len=S)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, -1]),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# decode == teacher-forced forward (whole model, per family)

@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "gemma2-2b", "mamba2-130m",
                                  "dbrx-132b", "whisper-base",
                                  "internvl2-76b", "jamba-1.5-large-398b"])
def test_decode_consistent_with_prefill(arch):
    """prefill(t[0:n]) then decode(t[n]) must equal prefill(t[0:n+1])'s
    last-token logits (greedy serving correctness)."""
    m = get_model(reduced(get_config(arch)))
    cfg = m.cfg
    params = m.init(KEY)
    B, S = 2, 17
    batch = m.make_batch(jax.random.PRNGKey(5), "prefill", B, S)
    toks = batch["tokens"]

    b_short = dict(batch, tokens=toks[:, :-1])
    _, cache = jax.jit(lambda p, b: m.prefill(p, b, pad_to=S + 4))(
        params, b_short)
    logits_dec, _ = jax.jit(m.decode)(params, cache, {"tokens": toks[:, -1:]})

    logits_full, _ = jax.jit(lambda p, b: m.prefill(p, b))(params, batch)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full),
                               rtol=2e-3, atol=2e-3)


def test_windowed_ring_cache_decode():
    """With window W << S the ring cache must reproduce windowed attention."""
    arch = get_config("gemma2-2b", long_context=True)
    from repro.models.common import reduced as _red
    cfg = _red(arch)
    # shrink window so S > W exercises the ring
    from dataclasses import replace
    pat = tuple(replace(s, window=8) for s in cfg.pattern)
    cfg = replace(cfg, pattern=pat)
    m = get_model(cfg)
    params = m.init(KEY)
    B, S = 1, 21
    batch = m.make_batch(jax.random.PRNGKey(9), "prefill", B, S)
    toks = batch["tokens"]
    _, cache = jax.jit(m.prefill)(params, dict(batch, tokens=toks[:, :-1]))
    logits_dec, _ = jax.jit(m.decode)(params, cache, {"tokens": toks[:, -1:]})
    logits_full, _ = jax.jit(m.prefill)(params, batch)
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(logits_full),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# MoE

def test_moe_identity_when_experts_equal():
    """If all experts share weights, MoE == the single dense expert."""
    from repro.models.moe import moe_block
    from repro.models.layers import mlp_block
    cfg = reduced(get_config("dbrx-132b"))
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(KEY, 4)
    wg = jax.random.normal(ks[0], (D, F)) * 0.05
    wu = jax.random.normal(ks[1], (D, F)) * 0.05
    wd = jax.random.normal(ks[2], (F, D)) * 0.05
    p = {"router": jax.random.normal(ks[3], (D, E)),
         "wg": jnp.tile(wg, (E, 1, 1)), "wu": jnp.tile(wu, (E, 1, 1)),
         "wd": jnp.tile(wd, (E, 1, 1))}
    x = jax.random.normal(KEY, (2, 8, D)) * 0.5
    from dataclasses import replace
    cfg2 = replace(cfg, capacity_factor=8.0)  # no drops
    y, aux = moe_block(p, x, cfg2)
    y_dense = mlp_block({"wg": wg, "wu": wu, "wd": wd}, x, "swiglu")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_dense),
                               rtol=2e-3, atol=2e-3)
    assert float(aux["load_balance"]) >= 0.99  # >= 1 ideal balance


def test_moe_capacity_drops_tokens():
    from repro.models.moe import moe_block
    cfg = reduced(get_config("dbrx-132b"))
    from dataclasses import replace
    cfg = replace(cfg, capacity_factor=0.1)  # force overflow
    D, E = cfg.d_model, cfg.n_experts
    ks = jax.random.split(KEY, 5)
    p = {"router": jax.random.normal(ks[0], (D, E)),
         "wg": jax.random.normal(ks[1], (E, D, cfg.d_ff)) * 0.05,
         "wu": jax.random.normal(ks[2], (E, D, cfg.d_ff)) * 0.05,
         "wd": jax.random.normal(ks[3], (E, cfg.d_ff, D)) * 0.05}
    x = jax.random.normal(ks[4], (1, 64, D))
    y, _ = moe_block(p, x, cfg)
    # dropped tokens produce zero output rows — at least some survive
    norms = np.linalg.norm(np.asarray(y), axis=-1)
    assert (norms > 1e-6).any()
    assert np.all(np.isfinite(np.asarray(y)))
