"""load_checkpoint validation: clear errors on structure/shape/dtype
mismatch instead of silent mis-restores (ISSUE 2 satellite)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import load_checkpoint, save_checkpoint


def _state():
    return {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                       "b": jnp.ones(3, jnp.float32)},
            "step_scale": jnp.asarray(0.5, jnp.float32)}


def test_roundtrip_preserves_values(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path / "ck"), state, step=3)
    like = jax.tree.map(jnp.zeros_like, state)
    restored, step = load_checkpoint(str(tmp_path / "ck"), like)
    assert step == 3
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_missing_manifest_is_clear(tmp_path):
    with pytest.raises(FileNotFoundError, match="manifest"):
        load_checkpoint(str(tmp_path / "nope"), _state())


def test_leaf_count_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path / "ck"), _state())
    like = {"params": {"w": jnp.zeros((2, 3))}}
    with pytest.raises(ValueError, match="leaves"):
        load_checkpoint(str(tmp_path / "ck"), like)


def test_shape_mismatch_names_the_leaf(tmp_path):
    save_checkpoint(str(tmp_path / "ck"), _state())
    like = _state()
    like["params"]["w"] = jnp.zeros((4, 3), jnp.float32)  # wrong shape
    with pytest.raises(ValueError) as e:
        load_checkpoint(str(tmp_path / "ck"), like)
    msg = str(e.value)
    assert "'w'" in msg and "(2, 3)" in msg and "(4, 3)" in msg


def test_dtype_mismatch_refuses_silent_cast(tmp_path):
    save_checkpoint(str(tmp_path / "ck"), _state())
    like = _state()
    like["params"]["b"] = jnp.ones(3, jnp.bfloat16)  # wrong dtype
    with pytest.raises(ValueError, match="dtype"):
        load_checkpoint(str(tmp_path / "ck"), like)
