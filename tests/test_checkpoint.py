"""Sharded checkpoint subsystem (ISSUE 7 / DESIGN.md §12): manifest
validation (structural, shape, dtype — naming the first diverging leaf
path), two-phase commit + torn-checkpoint discovery, async finalization,
retention, the byte model, and the async-save obs track."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.memplan import checkpoint_bytes
from repro.obs import TraceRecorder, get_recorder, set_recorder
from repro.train import (AsyncCheckpointer, CheckpointError, FailingFS,
                         checkpoint_plan, find_checkpoints,
                         latest_checkpoint, load_checkpoint,
                         save_checkpoint, verify_checkpoint)


def _state():
    return {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                       "b": jnp.ones(3, jnp.float32)},
            "step_scale": jnp.asarray(0.5, jnp.float32)}


def _assert_state_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# roundtrip + validation (the PR-2 guarantees, kept)

def test_roundtrip_preserves_values(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path / "ck"), state, step=3)
    like = jax.tree.map(jnp.zeros_like, state)
    restored, step = load_checkpoint(str(tmp_path / "ck"), like)
    assert step == 3
    _assert_state_equal(restored, state)


def test_missing_manifest_is_clear(tmp_path):
    with pytest.raises(FileNotFoundError, match="manifest"):
        load_checkpoint(str(tmp_path / "nope"), _state())


def test_leaf_count_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path / "ck"), _state())
    like = {"params": {"w": jnp.zeros((2, 3))}}
    with pytest.raises(ValueError, match="leaves"):
        load_checkpoint(str(tmp_path / "ck"), like)


def test_shape_mismatch_names_the_leaf(tmp_path):
    save_checkpoint(str(tmp_path / "ck"), _state())
    like = _state()
    like["params"]["w"] = jnp.zeros((4, 3), jnp.float32)  # wrong shape
    with pytest.raises(ValueError) as e:
        load_checkpoint(str(tmp_path / "ck"), like)
    msg = str(e.value)
    assert "'w'" in msg and "(2, 3)" in msg and "(4, 3)" in msg


def test_dtype_mismatch_refuses_silent_cast(tmp_path):
    save_checkpoint(str(tmp_path / "ck"), _state())
    like = _state()
    like["params"]["b"] = jnp.ones(3, jnp.bfloat16)  # wrong dtype
    with pytest.raises(ValueError, match="dtype"):
        load_checkpoint(str(tmp_path / "ck"), like)


# ---------------------------------------------------------------------------
# structural validation by key path (satellite: no more str(treedef))

def test_structure_divergence_names_first_diverging_path(tmp_path):
    """Same leaf COUNT, different key names: the error points at the
    first diverging pytree path, saved vs target."""
    save_checkpoint(str(tmp_path / "ck"), _state())
    like = {"params": {"w": jnp.zeros((2, 3), jnp.float32),
                       "bias": jnp.ones(3, jnp.float32)},   # was "b"
            "step_scale": jnp.asarray(0.5, jnp.float32)}
    with pytest.raises(ValueError) as e:
        load_checkpoint(str(tmp_path / "ck"), like)
    msg = str(e.value)
    assert "diverge" in msg and "'b'" in msg and "'bias'" in msg


def test_nesting_divergence_detected(tmp_path):
    """A leaf moved to another subtree diverges structurally even though
    shapes/dtypes/count all match."""
    save_checkpoint(str(tmp_path / "ck"), _state())
    like = {"params": {"w": jnp.zeros((2, 3), jnp.float32)},
            "extra": {"b": jnp.ones(3, jnp.float32)},
            "step_scale": jnp.asarray(0.5, jnp.float32)}
    with pytest.raises(ValueError, match="diverge"):
        load_checkpoint(str(tmp_path / "ck"), like)


def test_template_free_restore_rebuilds_structure(tmp_path):
    """``load_checkpoint(path)`` with no template rebuilds the nested
    dict pytree from the manifest's key paths — what --init-from and the
    serve handoff use."""
    state = _state()
    save_checkpoint(str(tmp_path / "ck"), state, step=9)
    restored, step = load_checkpoint(str(tmp_path / "ck"))
    assert step == 9
    assert jax.tree.structure(restored) == jax.tree.structure(state)
    _assert_state_equal(restored, state)


# ---------------------------------------------------------------------------
# manifest format / two-phase commit

def test_manifest_records_paths_shapes_dtypes_specs(tmp_path):
    p = save_checkpoint(str(tmp_path / "ck"), _state(), step=1)
    man = json.loads((p / "manifest.json").read_text())
    assert man["format"] == "repro-sharded-ckpt"
    assert man["n_leaves"] == 3
    by = {lf["keystr"]: lf for lf in man["leaves"]}
    w = by["['params']['w']"]
    assert w["shape"] == [2, 3] and w["dtype"] == "float32"
    assert len(w["spec"]) == 2                 # one entry per dim
    for lf in man["leaves"]:                   # every shard fully described
        for s in lf["shards"]:
            assert (p / s["file"]).stat().st_size == s["nbytes"]
            assert set(s) >= {"file", "start", "shape", "nbytes", "crc32"}
    assert not (p / "manifest.json.tmp").exists()   # tmp was renamed away


def test_find_checkpoints_skips_torn_and_orders_by_step(tmp_path):
    root = tmp_path / "run"
    mgr = AsyncCheckpointer(root, keep=10, async_save=False)
    for s in (2, 10, 1):
        mgr.save(_state(), step=s)
    # torn: shard files but no committed manifest
    torn = root / "step_00000011"
    torn.mkdir()
    (torn / "l0_s0.bin").write_bytes(b"\x00" * 8)
    (torn / "manifest.json.tmp").write_text("{}")
    assert [s for s, _ in find_checkpoints(root)] == [1, 2, 10]
    assert latest_checkpoint(root).name == "step_00000010"


def test_truncated_shard_after_commit_is_detected(tmp_path):
    """Even a COMMITTED checkpoint whose shard file was later truncated
    (disk loss) is skipped by discovery and flagged by the deep check."""
    root = tmp_path / "run"
    mgr = AsyncCheckpointer(root, keep=10, async_save=False)
    p1 = mgr.save(_state(), step=1)
    p2 = mgr.save(_state(), step=2)
    victim = next(p2.glob("l0_*.bin"))
    victim.write_bytes(victim.read_bytes()[:-2])
    assert latest_checkpoint(root) == p1       # torn step 2 skipped
    ok, reason = verify_checkpoint(p2)
    assert not ok and "truncated" in reason
    ok, _ = verify_checkpoint(p1)
    assert ok


def test_bitflip_in_shard_caught_by_crc(tmp_path):
    p = save_checkpoint(str(tmp_path / "ck"), _state(), step=1)
    victim = next(p.glob("l0_*.bin"))
    raw = bytearray(victim.read_bytes())
    raw[0] ^= 0xFF
    victim.write_bytes(bytes(raw))
    ok, reason = verify_checkpoint(p)
    assert not ok and "crc" in reason


# ---------------------------------------------------------------------------
# FailingFS (the injectable fault)

def test_failing_fs_tears_save_and_previous_survives(tmp_path):
    root = tmp_path / "run"
    good = AsyncCheckpointer(root, keep=5, async_save=False)
    good.save(_state(), step=1)
    bad = AsyncCheckpointer(root, keep=5, async_save=False,
                            fs=FailingFS(fail_after_bytes=10))
    with pytest.raises(OSError, match="fault injected"):
        bad.save(_state(), step=2)
    # the torn dir exists (partial bytes DID land) but is never returned
    assert (root / "step_00000002").exists()
    assert [s for s, _ in find_checkpoints(root)] == [1]
    restored, step = load_checkpoint(latest_checkpoint(root))
    assert step == 1
    _assert_state_equal(restored, _state())
    # loading the torn dir directly fails fast, never half-loads
    with pytest.raises(FileNotFoundError, match="manifest"):
        load_checkpoint(root / "step_00000002")


def test_failing_fs_during_manifest_write_leaves_no_commit(tmp_path):
    """Fault after all shard bytes but inside the manifest write: still
    torn (phase 2 never renamed), still skipped."""
    state = _state()
    data_bytes = checkpoint_plan(state)["total_bytes"]
    root = tmp_path / "run"
    bad = AsyncCheckpointer(root, keep=5, async_save=False,
                            fs=FailingFS(fail_after_bytes=data_bytes + 5))
    with pytest.raises(OSError):
        bad.save(state, step=1)
    d = root / "step_00000001"
    assert not (d / "manifest.json").exists()
    assert find_checkpoints(root) == []


# ---------------------------------------------------------------------------
# async finalization

def test_async_save_commits_after_wait_and_roundtrips(tmp_path):
    state = _state()
    mgr = AsyncCheckpointer(tmp_path / "run", keep=3)
    mgr.save(state, step=5)
    mgr.wait_for_checkpoint()
    restored, step = load_checkpoint(latest_checkpoint(tmp_path / "run"))
    assert step == 5
    _assert_state_equal(restored, state)
    mgr.close()


def test_async_failure_surfaces_at_wait(tmp_path):
    mgr = AsyncCheckpointer(tmp_path / "run", keep=3,
                            fs=FailingFS(fail_after_bytes=8))
    mgr.save(_state(), step=1)
    with pytest.raises(CheckpointError, match="fault injected"):
        mgr.wait_for_checkpoint()
    assert find_checkpoints(tmp_path / "run") == []


def test_retention_prunes_oldest_committed(tmp_path):
    mgr = AsyncCheckpointer(tmp_path / "run", keep=2)
    for s in range(1, 6):
        mgr.save(_state(), step=s)
    mgr.wait_for_checkpoint()
    assert [s for s, _ in find_checkpoints(tmp_path / "run")] == [4, 5]
    mgr.close()


def test_async_spans_land_on_checkpoint_track(tmp_path):
    """The background serialize/commit spans ride their own "checkpoint"
    obs track; the caller thread only pays for the snapshot span."""
    old = get_recorder()
    rec = set_recorder(TraceRecorder(enabled=True))
    try:
        mgr = AsyncCheckpointer(tmp_path / "run", keep=2)
        mgr.save(_state(), step=1)
        mgr.wait_for_checkpoint()
        mgr.close()
        names = {e["name"] for e in rec.events() if e.get("ph") == "X"
                 or e.get("ph") == "B"}
        assert {"ckpt_snapshot", "ckpt_serialize",
                "ckpt_commit"} <= names
        doc = rec.export()
        tracks = {e["args"]["name"]: e["pid"] * 1e9 + e["tid"]
                  for e in doc["traceEvents"]
                  if e.get("ph") == "M" and e["name"] == "thread_name"}
        assert "checkpoint" in tracks
    finally:
        set_recorder(old)


# ---------------------------------------------------------------------------
# byte model (core.memplan.checkpoint_bytes) vs actual disk bytes

def test_checkpoint_plan_matches_disk_exactly(tmp_path):
    state = _state()
    plan = checkpoint_plan(state)
    p = save_checkpoint(str(tmp_path / "ck"), state)
    disk = sum(f.stat().st_size for f in Path(p).glob("*.bin"))
    assert plan["total_bytes"] == disk          # raw .bin: EXACT equality
    assert plan["n_shards"] == sum(1 for _ in Path(p).glob("*.bin"))


def test_checkpoint_bytes_model_sharded():
    """Analytic model: total bytes are layout-independent (each global
    array is written once); sharding divides the per-host work."""
    leaves = [((16, 8), "float32", (("data",), ("model",))),   # 4 shards
              ((8,), "float32", (None,)),                      # replicated
              ((), "int32", ())]
    out = checkpoint_bytes(leaves, {"data": 2, "model": 2}, n_hosts=2)
    assert out["total_bytes"] == 16 * 8 * 4 + 8 * 4 + 4
    assert out["n_shards"] == 4 + 1 + 1
    assert out["max_shard_bytes"] == 16 * 8 * 4 // 4
    assert out["bytes_per_host"] == -(-out["total_bytes"] // 2)


def test_save_preserves_bfloat16_bitexact(tmp_path):
    state = {"w": (jnp.arange(31, dtype=jnp.float32) * 0.37).astype(
        jnp.bfloat16)}
    save_checkpoint(str(tmp_path / "ck"), state)
    restored, _ = load_checkpoint(str(tmp_path / "ck"), state)
    assert restored["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
