"""Crash / fault-injection harness (ISSUE 7, DESIGN.md §12): a writer
process is SIGKILLed mid-save — during shard writes and during the
manifest write, on both the sync and async paths.  In every case the
previous committed checkpoint restores bit-exactly and the torn one is
detected and skipped by discovery (never loadable)."""
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")

# The writer: commits step 1 with a healthy filesystem, then attempts
# step 2 through a FailingFS that SIGKILLs the process after N bytes.
# sys.argv: root, mode (sync|async), fail_after_bytes.
CHILD = """
import sys
from repro.train import AsyncCheckpointer, FailingFS

import test_checkpoint_fault as tf

root, mode, after = sys.argv[1], sys.argv[2], int(sys.argv[3])
state = tf.reference_state()

ok = AsyncCheckpointer(root, async_save=(mode == "async"))
ok.save(state, step=1)
ok.wait_for_checkpoint()
print("COMMITTED_STEP_1", flush=True)

bad = AsyncCheckpointer(root, async_save=(mode == "async"),
                        fs=FailingFS(fail_after_bytes=after, kill=True))
bad.save(tf.reference_state(1), step=2)
bad.wait_for_checkpoint()
print("UNREACHABLE", flush=True)   # the SIGKILL must have fired by now
"""


def reference_state(seed: int = 0):
    rng = np.random.RandomState(seed)
    return {"w": rng.randn(8, 6).astype(np.float32),
            "blocks": {"p0": {"scale": rng.randn(12).astype(np.float32)}},
            "step": np.int32(7 + seed)}


def _crash_writer(root, mode, fail_after_bytes):
    env = dict(os.environ)
    env["PYTHONPATH"] = (SRC + os.pathsep + os.path.dirname(__file__)
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env.setdefault("JAX_PLATFORMS", "cpu")
    p = subprocess.run(
        [sys.executable, "-c", CHILD, str(root), mode,
         str(fail_after_bytes)],
        capture_output=True, text=True, env=env, timeout=120)
    assert "COMMITTED_STEP_1" in p.stdout, (p.stdout, p.stderr)
    assert "UNREACHABLE" not in p.stdout, "fault injection never fired"
    assert p.returncode == -9, (p.returncode, p.stderr)   # SIGKILLed
    return p


def _assert_survivor_intact(root):
    from repro.train import (find_checkpoints, latest_checkpoint,
                             load_checkpoint, verify_checkpoint)
    found = find_checkpoints(root)
    assert [s for s, _ in found] == [1], found     # torn step 2 skipped
    ck = latest_checkpoint(root)
    assert ck is not None and ck.name.endswith("00000001")
    ok, reason = verify_checkpoint(ck)
    assert ok, reason
    restored, step = load_checkpoint(ck, like=reference_state())
    assert step == 1
    ref = reference_state()
    np.testing.assert_array_equal(np.asarray(restored["w"]), ref["w"])
    np.testing.assert_array_equal(
        np.asarray(restored["blocks"]["p0"]["scale"]),
        ref["blocks"]["p0"]["scale"])
    # the torn attempt left a directory but no committed manifest
    torn = root / "step_00000002"
    if torn.exists():
        assert not (torn / "manifest.json").exists()
        ok, reason = verify_checkpoint(torn)
        assert not ok and "manifest" in reason


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_sigkill_during_shard_write(tmp_path, mode):
    """Killed 64 bytes into the first shard: step 1 survives bit-exact,
    the torn step-2 directory is skipped and unloadable."""
    _crash_writer(tmp_path, mode, fail_after_bytes=64)
    _assert_survivor_intact(tmp_path)


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_sigkill_during_manifest_write(tmp_path, mode):
    """Killed after every shard landed, mid-manifest: the .tmp never
    became manifest.json, so the two-phase commit never happened."""
    total = sum(np.asarray(x).nbytes
                for x in [reference_state()["w"],
                          reference_state()["blocks"]["p0"]["scale"],
                          reference_state()["step"]])
    _crash_writer(tmp_path, mode, fail_after_bytes=total + 16)
    torn = tmp_path / "step_00000002"
    assert torn.exists()
    shard_bytes = sum(f.stat().st_size for f in torn.glob("*.bin"))
    assert shard_bytes == total        # all shards fully written...
    assert not (torn / "manifest.json").exists()   # ...but no commit
    _assert_survivor_intact(tmp_path)
    # dead letter: the partial tmp may exist; discovery must ignore it
    from repro.train import CheckpointError, load_checkpoint
    with pytest.raises((CheckpointError, FileNotFoundError)):
        load_checkpoint(torn)


def test_injected_io_error_keeps_previous_restorable(tmp_path):
    """Non-fatal variant: FailingFS raises instead of killing; the error
    surfaces to the caller, the previous checkpoint stays valid."""
    from repro.train import (AsyncCheckpointer, CheckpointError, FailingFS,
                             find_checkpoints)
    ck = AsyncCheckpointer(tmp_path, async_save=False)
    ck.save(reference_state(), step=1)
    bad = AsyncCheckpointer(tmp_path, async_save=False,
                            fs=FailingFS(fail_after_bytes=32))
    with pytest.raises((CheckpointError, OSError)):
        bad.save(reference_state(1), step=2)
    assert [s for s, _ in find_checkpoints(tmp_path)] == [1]
    _assert_survivor_intact(tmp_path)
