"""KVStore (MXNet §2.3, §3.3): aggregation, consistency, two-level bytes."""
import numpy as np

from repro.core import (Engine, KVStoreDist, KVStoreLocal, NDArray,
                        sgd_updater)


def test_local_push_aggregates_devices():
    eng = Engine()
    kv = KVStoreLocal(eng)
    kv.init("w", np.zeros(4, np.float32))
    gs = [NDArray(np.full(4, float(i + 1), np.float32), engine=eng)
          for i in range(4)]
    kv.push("w", gs)           # level-1 aggregation: sum = 1+2+3+4
    out = kv.pull("w")
    np.testing.assert_allclose(out.asnumpy(), np.full(4, 10.0))


def test_local_custom_updater():
    eng = Engine()
    kv = KVStoreLocal(eng)
    kv.set_updater(sgd_updater(lr=0.1))
    kv.init("w", np.full(3, 1.0, np.float32))
    kv.push("w", NDArray(np.full(3, 10.0, np.float32), engine=eng))
    np.testing.assert_allclose(kv.pull("w").asnumpy(), np.zeros(3))


def test_dist_sequential_barrier():
    """No update until every worker of every machine pushed (sync SGD)."""
    kv = KVStoreDist(n_machines=2, devices_per_machine=2,
                     consistency="sequential")
    kv.set_updater(lambda k, s, g: s + g)
    kv.init("w", np.zeros(2, np.float32))
    kv.push("w", worker=0, grad=np.ones(2, np.float32))
    kv.push("w", worker=1, grad=np.ones(2, np.float32))
    kv.push("w", worker=2, grad=np.ones(2, np.float32))
    assert kv.version("w") == 0                       # barrier holds
    kv.push("w", worker=3, grad=np.ones(2, np.float32))
    assert kv.version("w") == 1
    np.testing.assert_allclose(np.asarray(kv.pull("w", 0)), np.full(2, 4.0))


def test_dist_eventual_applies_per_machine():
    kv = KVStoreDist(n_machines=2, devices_per_machine=1,
                     consistency="eventual", staleness=1)
    kv.init("w", np.zeros(2, np.float32))
    kv.push("w", worker=0, grad=np.ones(2, np.float32))
    assert kv.version("w") == 1                       # no barrier
    kv.push("w", worker=1, grad=np.ones(2, np.float32))
    assert kv.version("w") == 2


def test_dist_eventual_staleness_bounded():
    kv = KVStoreDist(n_machines=2, devices_per_machine=1,
                     consistency="eventual", staleness=1)
    kv.init("w", np.zeros(1, np.float32))
    for step in range(5):
        kv.push("w", worker=0, grad=np.ones(1, np.float32))
    fresh = np.asarray(kv.pull("w", worker=0)).item()
    stale = np.asarray(kv.pull("w", worker=1)).item()
    assert fresh == 5.0
    assert fresh - stale <= 1.0 + 1e-6               # bounded staleness


def test_two_level_bandwidth_reduction():
    """§3.3: level-1 aggregation => inter-machine bytes shrink by
    devices_per_machine."""
    n_m, dpm, steps = 4, 8, 3
    kv = KVStoreDist(n_machines=n_m, devices_per_machine=dpm,
                     consistency="sequential")
    kv.init("w", np.zeros(16, np.float32))
    for _ in range(steps):
        for w in range(n_m * dpm):
            kv.push("w", worker=w, grad=np.ones(16, np.float32))
    assert kv.bytes_l1 == steps * n_m * dpm * 16 * 4
    assert kv.bytes_l2 == steps * n_m * 16 * 4
    assert kv.bytes_l1 // kv.bytes_l2 == dpm


def test_dist_sequential_matches_single_worker_sgd():
    """K synchronous workers with grad/K == one worker on the full batch."""
    rng = np.random.RandomState(3)
    X = rng.randn(64, 8).astype(np.float32)
    w_true = rng.randn(8).astype(np.float32)
    y = X @ w_true

    def grad(w, Xb, yb):
        return 2 * Xb.T @ (Xb @ w - yb) / len(yb)

    # single worker
    w1 = np.zeros(8, np.float32)
    for _ in range(50):
        w1 -= 0.05 * grad(w1, X, y)

    # 4 synchronous workers through KVStoreDist
    kv = KVStoreDist(n_machines=4, devices_per_machine=1,
                     consistency="sequential")
    kv.set_updater(lambda k, s, g: s - 0.05 * np.asarray(g))
    kv.init("w", np.zeros(8, np.float32))
    shards = np.split(np.arange(64), 4)
    for _ in range(50):
        wcur = [np.asarray(kv.pull("w", i)) for i in range(4)]
        for i in range(4):
            gi = grad(wcur[i], X[shards[i]], y[shards[i]]) / 4.0
            kv.push("w", worker=i, grad=gi)
    w4 = np.asarray(kv.pull("w", 0))
    np.testing.assert_allclose(w4, w1, rtol=1e-4, atol=1e-5)
