"""Per-architecture smoke tests (deliverable f).

Each assigned arch instantiates a REDUCED same-family variant (<=2
super-blocks, d_model<=512, <=4 experts) and runs one forward + one
training step on CPU, asserting output shapes and absence of NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, LONG_CONTEXT_ARCHS, get_config
from repro.models import get_model, reduced

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def models():
    return {}


def _reduced_model(arch):
    cfg = reduced(get_config(arch))
    return get_model(cfg)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_loss_finite(arch):
    m = _reduced_model(arch)
    params = m.init(KEY)
    batch = m.make_batch(KEY, "train", 2, 32)
    loss, metrics = jax.jit(m.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert np.isfinite(float(metrics["ce"]))
    # fresh init => CE near ln(V)
    assert abs(float(metrics["ce"]) - np.log(m.cfg.vocab)) < 1.5


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_improves(arch):
    """One SGD step must run and produce finite, changed params."""
    m = _reduced_model(arch)
    params = m.init(KEY)
    batch = m.make_batch(KEY, "train", 2, 32)

    @jax.jit
    def step(p, b):
        (l, _), g = jax.value_and_grad(m.loss, has_aux=True)(p, b)
        p2 = jax.tree.map(lambda w, gw: w - 0.002 * gw.astype(w.dtype), p, g)
        return l, p2

    l0, params1 = step(params, batch)
    l1, _ = step(params1, batch)
    assert np.isfinite(float(l0)) and np.isfinite(float(l1))
    assert float(l1) < float(l0) + 1e-3, (arch, float(l0), float(l1))
    leaves0, leaves1 = jax.tree.leaves(params), jax.tree.leaves(params1)
    assert any(not np.allclose(a, b) for a, b in zip(leaves0, leaves1))
    for leaf in leaves1:
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_shapes(arch):
    m = _reduced_model(arch)
    cfg = m.cfg
    params = m.init(KEY)
    B, S = 2, 16
    batch = m.make_batch(KEY, "prefill", B, S)
    logits, cache = jax.jit(lambda p, b: m.prefill(p, b, pad_to=S + 8))(
        params, batch)
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))
    tok = jnp.zeros((B, 1), jnp.int32)
    logits2, cache2 = jax.jit(m.decode)(params, cache, {"tokens": tok})
    assert logits2.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits2)))
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", sorted(LONG_CONTEXT_ARCHS))
def test_long_context_variant_exists(arch):
    cfg = get_config(arch, long_context=True)
    for spec in cfg.pattern:
        if spec.kind == "attn":
            assert spec.window is not None  # sub-quadratic for long_500k


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_dims_match_assignment(arch):
    """The FULL configs carry the exact assigned dimensions."""
    expect = {
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "mamba2-130m": (24, 768, 1, 1, 0, 50280),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
    }[arch]
    c = get_config(arch)
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == expect


def test_param_counts_near_nameplate():
    """param_count() should land near each model's nameplate size."""
    expect = {"dbrx-132b": 132e9, "internvl2-76b": 70e9,
              "qwen1.5-0.5b": 0.46e9, "gemma2-2b": 2.6e9,
              "jamba-1.5-large-398b": 398e9, "whisper-base": 74e6,
              "llama4-scout-17b-a16e": 108e9, "starcoder2-15b": 15e9,
              "mamba2-130m": 130e6, "granite-20b": 20e9}
    for arch, target in expect.items():
        n = get_config(arch).param_count()
        assert 0.6 * target < n < 1.45 * target, (arch, n, target)


def test_moe_active_params_smaller():
    for arch in ("dbrx-132b", "llama4-scout-17b-a16e",
                 "jamba-1.5-large-398b"):
        c = get_config(arch)
        assert c.param_count(active_only=True) < 0.55 * c.param_count()
