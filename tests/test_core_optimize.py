"""Graph optimization (MXNet §3.1): pruning, pattern fusion, segment fusion."""
import numpy as np
import pytest

from repro.core import (Activation, FullyConnected, SoftmaxOutput, Variable,
                        reset_default_engine)
from repro.core.graph import Graph
from repro.core.optimize import fuse_elementwise, optimize_graph, pattern_fuse
from repro.core.symbol import Symbol


@pytest.fixture(autouse=True)
def fresh_engine():
    reset_default_engine()


def test_prune_drops_unused_branch():
    a = Variable("a")
    used = a * 2.0
    _unused = Symbol._from_op("exp", [a * 3.0])  # never an output
    g = Graph(used._outputs)
    ops = [n.op for n in g.nodes]
    assert "exp" not in ops and len(ops) == 2  # var + scale


def test_prediction_graph_smaller_than_training():
    """Binding only the forward output skips the backward subgraph."""
    data, label = Variable("data"), Variable("label")
    net = SoftmaxOutput(FullyConnected(data, 8, name="fc"), label)[0]
    args = {"data": np.zeros((4, 6), np.float32),
            "label": np.zeros(4, np.float32),
            "fc_weight": np.zeros((8, 6), np.float32),
            "fc_bias": np.zeros(8, np.float32)}
    ex_pred = net.bind(args)
    ex_train = net.bind(args, grad_wrt=["fc_weight", "fc_bias"])
    assert len(ex_pred.graph) < len(ex_train.graph)


def test_pattern_fuse_axb_plus_const():
    """Paper's example: a*b+1 becomes a single fused call."""
    a, b = Variable("a"), Variable("b")
    expr = a * b + 1.0
    g = pattern_fuse(Graph(expr._outputs))
    ops = [n.op for n in g.nodes if n.op != "var"]
    assert ops == ["fma_const"]
    # and it evaluates identically
    va = np.random.RandomState(0).randn(3, 3).astype(np.float32)
    vb = np.random.RandomState(1).randn(3, 3).astype(np.float32)
    out = expr.eval(a=va, b=vb)[0]
    np.testing.assert_allclose(np.asarray(out), va * vb + 1.0, rtol=1e-6)


def test_fused_segments_reduce_op_count():
    a, b = Variable("a"), Variable("b")
    x = a * b
    for _ in range(6):
        x = Symbol._from_op("tanh", [x + 1.0])
    loss = Symbol._from_op("reduce_sum", [x])
    g = optimize_graph(loss._outputs)
    segs, node2seg = fuse_elementwise(g)
    assert len(segs) >= 1
    biggest = max(len(s.nodes) for s in segs.values())
    assert biggest >= 6  # the chain fused into one jitted call


def test_optimized_equals_unoptimized():
    rng = np.random.RandomState(0)
    data, label = Variable("data"), Variable("label")
    h = Activation(FullyConnected(data, 32, name="fc1"), "tanh")
    net = SoftmaxOutput(FullyConnected(h, 5, name="fc2"), label)[0]
    args = {"data": rng.randn(16, 8).astype(np.float32),
            "label": rng.randint(0, 5, 16).astype(np.float32),
            "fc1_weight": rng.randn(32, 8).astype(np.float32) * 0.2,
            "fc1_bias": np.zeros(32, np.float32),
            "fc2_weight": rng.randn(5, 32).astype(np.float32) * 0.2,
            "fc2_bias": np.zeros(5, np.float32)}
    wrt = ["fc1_weight", "fc2_weight"]
    reset_default_engine()
    ex1 = net.bind(args, grad_wrt=wrt, optimize=True)
    o1, g1 = ex1.forward()[0], ex1.backward()
    reset_default_engine()
    ex2 = net.bind(args, grad_wrt=wrt, optimize=False)
    o2, g2 = ex2.forward()[0], ex2.backward()
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-6)
    for k in wrt:
        np.testing.assert_allclose(np.asarray(g1[k]), np.asarray(g2[k]),
                                   rtol=1e-5, atol=1e-6)


def test_compile_whole_matches_per_op():
    """Whole-graph jit (the Fig.6 fast path) must equal per-op execution."""
    rng = np.random.RandomState(1)
    data, label = Variable("data"), Variable("label")
    h = Activation(FullyConnected(data, 16, name="fc1"), "relu")
    net = SoftmaxOutput(FullyConnected(h, 4, name="fc2"), label)[0]
    args = {"data": rng.randn(8, 6).astype(np.float32),
            "label": rng.randint(0, 4, 8).astype(np.float32),
            "fc1_weight": rng.randn(16, 6).astype(np.float32) * 0.3,
            "fc1_bias": np.zeros(16, np.float32),
            "fc2_weight": rng.randn(4, 16).astype(np.float32) * 0.3,
            "fc2_bias": np.zeros(4, np.float32)}
    wrt = ["fc1_weight", "fc2_weight", "fc1_bias", "fc2_bias"]
    reset_default_engine()
    ex1 = net.bind(args, grad_wrt=wrt, compile_whole=True)
    o1 = ex1.forward()[0]
    g1 = ex1.backward()
    reset_default_engine()
    ex2 = net.bind(args, grad_wrt=wrt)
    o2 = ex2.forward()[0]
    g2 = ex2.backward()
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-6)
    for k in wrt:
        np.testing.assert_allclose(np.asarray(g1[k]), np.asarray(g2[k]),
                                   rtol=1e-5, atol=1e-7, err_msg=k)


def test_fused_segment_multi_output():
    """A fused node also consumed outside the segment is exported."""
    a = Variable("a")
    t = Symbol._from_op("tanh", [a * 2.0])
    u = t + 1.0
    v = t * 3.0          # t consumed twice -> stays a segment output
    loss = Symbol._from_op("reduce_sum", [u]) + Symbol._from_op("reduce_sum", [v])
    va = np.random.RandomState(0).randn(4).astype(np.float32)
    out = loss.eval(a=va)[0]
    ref = np.sum(np.tanh(va * 2) + 1) + np.sum(np.tanh(va * 2) * 3)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5)
