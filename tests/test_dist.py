"""Distribution layer: sharding rules, hierarchical collectives, dry-run.

Multi-device behaviour needs --xla_force_host_platform_device_count, which
must be set before jax initializes — these tests run their bodies in a
subprocess.
"""
import pytest

from mesh_subproc import run_sub


@pytest.mark.mesh
def test_hierarchical_allreduce_matches_flat():
    out = run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.dist import gradient_sync
    mesh = jax.make_mesh((2, 4, 2), ("pod", "data", "model"))
    W = 8  # pod*data workers
    rng = np.random.RandomState(0)
    grads = {"a": jnp.asarray(rng.randn(W, 3, 5), jnp.float32),
             "b": jnp.asarray(rng.randn(W, 7), jnp.float32)}
    with jax.set_mesh(mesh):
        h = gradient_sync(mesh, grads, mode="hierarchical")
        f = gradient_sync(mesh, grads, mode="flat")
    for k in grads:
        want = np.asarray(grads[k]).sum(0)
        np.testing.assert_allclose(np.asarray(h[k]), want, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(f[k]), want, rtol=1e-5)
    print("SYNC_OK")
    """)
    assert "SYNC_OK" in out


@pytest.mark.mesh
def test_hierarchical_reduces_interpod_bytes():
    """The two-level schedule must move fewer bytes across 'pod' than the
    flat all-reduce (the §3.3 claim, on-mesh)."""
    out = run_sub("""
    import jax, jax.numpy as jnp, re
    from repro.dist.collectives import gradient_sync
    mesh = jax.make_mesh((2, 4, 2), ("pod", "data", "model"))
    W = 8
    g = {"w": jnp.zeros((W, 4096), jnp.float32)}
    with jax.set_mesh(mesh):
        texts = {}
        for mode in ("hierarchical", "flat"):
            lowered = jax.jit(
                lambda x, mode=mode: gradient_sync(mesh, x, mode=mode)
            ).lower(g)
            texts[mode] = lowered.compile().as_text()
    def pod_coll_bytes(txt):
        # pod-axis collectives have replica groups spanning across pods:
        # count all-reduce result bytes where group contains stride >= 8
        total = 0
        for m in re.finditer(r"f32\\[(\\d+)\\][^\\n]*all-reduce", txt):
            total += int(m.group(1)) * 4
        return total
    h, f = pod_coll_bytes(texts["hierarchical"]), pod_coll_bytes(texts["flat"])
    print("H", h, "F", f)
    assert h < f, (h, f)
    print("BYTES_OK")
    """)
    assert "BYTES_OK" in out


@pytest.mark.mesh
def test_param_pspecs_cover_tree_and_divide():
    out = run_sub("""
    import jax
    from repro.configs import get_config
    from repro.dist import param_pspecs
    from repro.models import get_model
    mesh = jax.make_mesh((4, 4), ("data", "model"))
    for arch in ("dbrx-132b", "mamba2-130m", "gemma2-2b", "whisper-base"):
        cfg = get_config(arch)
        specs = param_pspecs(cfg, get_model(cfg).param_specs(), mesh)
        leaves, specl = (jax.tree.leaves(get_model(cfg).param_specs()),
                         jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, '_normalized_spec_for_aval')))
        import jax.sharding as shd
        specl = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, shd.PartitionSpec))
        assert len(leaves) == len(specl), (arch, len(leaves), len(specl))
        for leaf, spec in zip(leaves, specl):
            for i, s in enumerate(spec):
                if s is None: continue
                group = (s,) if isinstance(s, str) else s
                n = 1
                for a in group: n *= mesh.shape[a]
                assert leaf.shape[i] % n == 0, (arch, leaf.shape, spec)
    print("SPECS_OK")
    """)
    assert "SPECS_OK" in out


@pytest.mark.mesh
def test_dryrun_single_pair_tiny():
    """The dry-run path end-to-end on a reduced arch (16 fake devices)."""
    out = run_sub("""
    import jax
    from repro.launch.dryrun import collective_bytes, lower_and_compile
    from repro.configs import get_config
    from dataclasses import replace
    from repro.models import reduced
    cfg = reduced(get_config("qwen1.5-0.5b"))
    mesh = jax.make_mesh((4, 4), ("data", "model"))
    import repro.models.common as C
    import repro.launch.steps as S
    # shrink the input shape table for the test
    S.INPUT_SHAPES = dict(S.INPUT_SHAPES)
    S.INPUT_SHAPES["train_4k"] = C.InputShape("train_4k", 64, 8, "train")
    lowered, compiled, tl, tc = lower_and_compile(cfg, "train_4k", mesh)
    mem = compiled.memory_analysis()
    assert mem.temp_size_in_bytes > 0
    ca = compiled.cost_analysis()
    assert ca["flops"] > 0
    coll = collective_bytes(compiled.as_text())
    assert coll["total"] > 0, coll
    print("DRYRUN_OK")
    """)
    assert "DRYRUN_OK" in out


@pytest.mark.mesh
def test_decode_step_lowering_tiny():
    out = run_sub("""
    import jax
    from repro.launch.dryrun import lower_and_compile
    from repro.configs import get_config
    from repro.models import reduced
    import repro.models.common as C
    import repro.launch.steps as S
    cfg = reduced(get_config("gemma2-2b"))
    mesh = jax.make_mesh((4, 4), ("data", "model"))
    S.INPUT_SHAPES = dict(S.INPUT_SHAPES)
    S.INPUT_SHAPES["decode_32k"] = C.InputShape("decode_32k", 256, 8, "decode")
    lowered, compiled, tl, tc = lower_and_compile(cfg, "decode_32k", mesh)
    assert compiled.cost_analysis()["flops"] > 0
    print("DECODE_OK")
    """)
    assert "DECODE_OK" in out
