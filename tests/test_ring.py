"""Ring attention (dist/ring.py, DESIGN.md §8): numeric parity with the
unsharded reference on multi-shard meshes, forward and backward, for
full-causal and sliding-window layers — plus the seq-shard plumbing
(batch_pspecs kind="seq", PerfFlags, long-context config gating).

Multi-device behaviour needs --xla_force_host_platform_device_count set
before jax initializes, so mesh tests run their bodies in a subprocess
(the ISSUE-3 acceptance harness: >= 4 sequence shards).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from mesh_subproc import run_sub


# ---------------------------------------------------------------------------
# in-process: the no-mesh fallback is the oracle the mesh tests trust

def test_ring_no_mesh_matches_ref_fwd_bwd():
    from repro.dist.ring import ring_attention
    from repro.kernels import ref
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    B, S, H, K, hd = 2, 96, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    w = jax.random.normal(ks[3], (B, S, H, hd))
    for kw in (dict(causal=True), dict(causal=True, window=24),
               dict(causal=True, window=24, softcap=10.0)):
        out = ring_attention(q, k, v, **kw)
        want = ref.flash_attention_ref(q, k, v, **kw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        g = jax.grad(lambda *a: (ring_attention(*a, **kw) * w).sum(),
                     argnums=(0, 1, 2))(q, k, v)
        gw = jax.grad(lambda *a: (ref.flash_attention_ref(*a, **kw)
                                  * w).sum(), argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g, gw):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4)


def test_ring_rejects_cross_lengths():
    from repro.dist.ring import ring_attention
    q = jnp.zeros((1, 8, 2, 4))
    kv = jnp.zeros((1, 6, 2, 4))
    with pytest.raises(ValueError, match="self-attention"):
        ring_attention(q, kv, kv)


def test_contributing_steps_and_byte_model():
    from repro.dist.ring import contributing_steps, ring_permute_bytes
    # full causal: every forward step contributes, backward wraps
    assert contributing_steps(4, 32, causal=True, window=None) == [0, 1, 2, 3]
    assert contributing_steps(4, 32, causal=True, window=33) == [0, 1]
    assert contributing_steps(4, 32, causal=True, window=33,
                              direction="bwd") == [0, 3]
    m = ring_permute_bytes(1, 128, 2, 16, 4, itemsize=2, causal=True)
    # fwd: 3 rotations x 2 tensors x (1*32*2*16*2) bytes
    assert m["fwd_total"] == 3 * 2 * (32 * 2 * 16 * 2)
    # bwd: k/v for P-1 hops, f32 dk/dv for P hops
    assert m["bwd_total"] == 3 * 2 * (32 * 2 * 16 * 2) + 4 * 2 * (32 * 2 * 16 * 4)
    assert m["grad_total"] == m["fwd_total"] + m["bwd_total"]
    one = ring_permute_bytes(1, 128, 2, 16, 1)
    assert one["fwd_total"] == one["grad_total"] == 0


def test_long_context_config_gating():
    from repro.configs import get_config
    # sub-quadratic archs keep their native variant
    cfg = get_config("gemma2-2b", long_context=True)
    assert all(s.window is not None for s in cfg.pattern)
    # full-attention archs need the ring acknowledgement
    with pytest.raises(ValueError, match="ring"):
        get_config("qwen1.5-0.5b", long_context=True)
    cfg = get_config("qwen1.5-0.5b", long_context=True, seq_shard=True)
    assert any(s.window is None for s in cfg.pattern)  # attention stays full


def test_long_500k_prefill_shape_registered():
    from repro.models import INPUT_SHAPES
    shp = INPUT_SHAPES["long_500k_prefill"]
    assert (shp.seq_len, shp.global_batch, shp.kind) == (524_288, 1,
                                                         "prefill")


# ---------------------------------------------------------------------------
# mesh subprocess tests (>= 4 sequence shards)

@pytest.mark.mesh
def test_ring_matches_ref_4_shards_fwd_bwd():
    """ISSUE-3 acceptance: ring fwd+bwd == unsharded ref on a 4-shard
    mesh, full-causal and sliding-window (window crosses chunk bounds)."""
    out = run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.dist.ring import ring_attention
    from repro.kernels import ref
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    B, S, H, K, hd = 2, 256, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    w = jax.random.normal(ks[3], (B, S, H, hd))
    mesh = jax.make_mesh((4,), ("model",))
    for kw in (dict(causal=True), dict(causal=True, window=48),
               dict(causal=True, window=100, softcap=15.0)):
        want = ref.flash_attention_ref(q, k, v, **kw)
        gw = jax.grad(lambda *a: (ref.flash_attention_ref(*a, **kw)
                                  * w).sum(), argnums=(0, 1, 2))(q, k, v)
        with jax.set_mesh(mesh):
            out = jax.jit(lambda *a: ring_attention(*a, **kw))(q, k, v)
            g = jax.jit(jax.grad(
                lambda *a: (ring_attention(*a, **kw) * w).sum(),
                argnums=(0, 1, 2)))(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        for a, b in zip(g, gw):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4)
        print("OK", sorted(kw))
    # a sequence the 4-way ring axis does not divide must be refused
    # with a clear error, not an opaque shard_map failure
    bad = jax.random.normal(ks[0], (B, 250, H, hd))
    bkv = jax.random.normal(ks[1], (B, 250, K, hd))
    with jax.set_mesh(mesh):
        try:
            ring_attention(bad, bkv, bkv)
        except ValueError as e:
            assert "divisible" in str(e), e
            print("DIVISIBILITY_OK")
    print("RING_MESH_OK")
    """, devices=4)
    assert "RING_MESH_OK" in out
    assert "DIVISIBILITY_OK" in out


@pytest.mark.mesh
def test_ring_pallas_inner_4_shards():
    """The flash kernel (carry mode) as the per-ring-step inner kernel,
    interpret mode, under shard_map + custom_vjp."""
    out = run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.dist.ring import ring_attention
    from repro.kernels import ref
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    B, S, H, K, hd = 1, 128, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    w = jax.random.normal(ks[3], (B, S, H, hd))
    mesh = jax.make_mesh((4,), ("model",))
    for kw in (dict(causal=True), dict(causal=True, window=40)):
        want = ref.flash_attention_ref(q, k, v, **kw)
        with jax.set_mesh(mesh):
            out = jax.jit(lambda *a: ring_attention(
                *a, inner="pallas", block_q=32, block_k=32, **kw))(q, k, v)
            g = jax.jit(jax.grad(lambda *a: (ring_attention(
                *a, inner="pallas", block_q=32, block_k=32, **kw)
                * w).sum(), argnums=(0, 1, 2)))(q, k, v)
        gw = jax.grad(lambda *a: (ref.flash_attention_ref(*a, **kw)
                                  * w).sum(), argnums=(0, 1, 2))(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        for a, b in zip(g, gw):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4)
    print("RING_PALLAS_OK")
    """, devices=4)
    assert "RING_PALLAS_OK" in out


@pytest.mark.mesh
def test_seq_shard_model_loss_and_grads_match():
    """PerfFlags.seq_shard + attn_impl=auto: a reduced dense model's train
    loss and parameter gradients on a (1, 4) mesh equal the no-mesh
    baseline (the ring path is numerically transparent end to end)."""
    out = run_sub("""
    import jax, numpy as np
    from repro.configs import get_config
    from repro.models import get_model, reduced
    from repro.perf_flags import reset_flags, set_flags
    cfg = reduced(get_config("qwen1.5-0.5b"))
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = m.make_batch(jax.random.PRNGKey(1), "train", 2, 64)
    loss0, _ = m.loss(params, batch)
    g0 = jax.grad(lambda p: m.loss(p, batch)[0])(params)
    mesh = jax.make_mesh((1, 4), ("data", "model"))
    set_flags(seq_shard=True, attn_impl="auto")
    try:
        with jax.set_mesh(mesh):
            loss1, _ = jax.jit(m.loss)(params, batch)
            g1 = jax.jit(jax.grad(lambda p: m.loss(p, batch)[0]))(params)
    finally:
        reset_flags()
    np.testing.assert_allclose(float(loss0), float(loss1), rtol=1e-5)
    f0, f1 = jax.tree.leaves(g0), jax.tree.leaves(g1)
    assert len(f0) == len(f1)
    for a, b in zip(f0, f1):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4)
    print("SEQ_SHARD_MODEL_OK")
    """, devices=4)
    assert "SEQ_SHARD_MODEL_OK" in out


@pytest.mark.mesh
def test_ring_hlo_permute_bytes_match_analytic():
    """The analytic permute-byte model equals the compiled HLO exactly
    (fwd and grad), including the windowed early-stop."""
    out = run_sub("""
    import jax, jax.numpy as jnp
    from repro.dist.ring import ring_attention, ring_permute_bytes
    from repro.launch.dryrun import collective_bytes
    B, S, H, K, hd = 2, 256, 4, 2, 32
    q = jnp.zeros((B, S, H, hd), jnp.float32)
    k = jnp.zeros((B, S, K, hd), jnp.float32)
    v = jnp.zeros((B, S, K, hd), jnp.float32)
    mesh = jax.make_mesh((4,), ("model",))
    for window in (None, 48):
        model = ring_permute_bytes(B, S, K, hd, 4, itemsize=4,
                                   causal=True, window=window)
        with jax.set_mesh(mesh):
            f = jax.jit(lambda *a: ring_attention(
                *a, causal=True, window=window))
            g = jax.jit(jax.grad(lambda *a: ring_attention(
                *a, causal=True, window=window).sum(), argnums=(0, 1, 2)))
            cf = collective_bytes(f.lower(q, k, v).compile().as_text())
            cg = collective_bytes(g.lower(q, k, v).compile().as_text())
        assert cf["raw"]["collective-permute"] == model["fwd_total"], (
            window, cf["raw"], model)
        assert cg["raw"]["collective-permute"] == model["grad_total"], (
            window, cg["raw"], model)
    print("RING_BYTES_OK")
    """, devices=4)
    assert "RING_BYTES_OK" in out


@pytest.mark.mesh
def test_batch_pspecs_seq_kind():
    out = run_sub("""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.dist import batch_pspecs
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    batch = {"tokens": jax.ShapeDtypeStruct((8, 64), "int32"),
             "patches": jax.ShapeDtypeStruct((8, 64, 32), "float32"),
             "scalar": jax.ShapeDtypeStruct((), "int32")}
    specs = batch_pspecs(None, batch, mesh, kind="seq")
    assert specs["tokens"] == P("data", "model"), specs["tokens"]
    assert specs["patches"] == P("data", "model", None), specs["patches"]
    assert specs["scalar"] == P()
    # non-dividing model axis on dim 1 is dropped, not an error
    odd = {"tokens": jax.ShapeDtypeStruct((8, 63), "int32")}
    assert batch_pspecs(None, odd, mesh, kind="seq")["tokens"] == \
        P("data", None)
    # other kinds unchanged
    specs = batch_pspecs(None, batch, mesh, kind="train")
    assert specs["tokens"] == P("data", None)
    print("SEQ_PSPECS_OK")
    """, devices=8)
    assert "SEQ_PSPECS_OK" in out
