"""Memory planning (MXNet §3.1): plan validity + Fig.7-style reductions.

Property tests build random symbolic DAGs; the executor's strict
read-after-clobber checker (`check_plan=True`) validates every plan by
executing the graph with buffer ownership tracking, and the results must be
identical under every allocation strategy.
"""
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import (Activation, FullyConnected, SoftmaxOutput, Variable,
                        reset_default_engine)
from repro.core.graph import Graph, infer_shapes
from repro.core.memplan import plan_graph
from repro.core.symbol import Symbol


@pytest.fixture(autouse=True)
def fresh_engine():
    reset_default_engine()


def mlp_loss(depth=3, hidden=64):
    data, label = Variable("data"), Variable("label")
    x = data
    for i in range(depth):
        x = Activation(FullyConnected(x, hidden, name=f"fc{i}"), "relu")
    return SoftmaxOutput(FullyConnected(x, 10, name="head"), label)[0]


def mlp_args(depth=3, hidden=64, batch=32, din=32, rng=None):
    rng = rng or np.random.RandomState(0)
    args = {"data": rng.randn(batch, din).astype(np.float32),
            "label": rng.randint(0, 10, (batch,)).astype(np.float32)}
    d = din
    for i in range(depth):
        args[f"fc{i}_weight"] = (rng.randn(hidden, d) * 0.1).astype(np.float32)
        args[f"fc{i}_bias"] = np.zeros(hidden, np.float32)
        d = hidden
    args["head_weight"] = (rng.randn(10, d) * 0.1).astype(np.float32)
    args["head_bias"] = np.zeros(10, np.float32)
    return args


STRATEGIES = ("naive", "inplace", "coshare", "both")


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_plan_executes_correctly(strategy):
    sym = mlp_loss()
    args = mlp_args()
    wrt = [k for k in args if k not in ("data", "label")]
    ref = None
    ex = sym.bind(args, grad_wrt=wrt, memplan=strategy, check_plan=True)
    out = ex.forward()[0]
    grads = ex.backward()
    if ref is None:
        ref = (out, grads)
    # compare against naive
    ex0 = sym.bind(args, grad_wrt=wrt, memplan="naive", check_plan=True)
    out0 = ex0.forward()[0]
    grads0 = ex0.backward()
    np.testing.assert_allclose(np.asarray(out), np.asarray(out0), rtol=1e-6)
    for k in wrt:
        np.testing.assert_allclose(np.asarray(grads[k]), np.asarray(grads0[k]),
                                   rtol=1e-5, atol=1e-6, err_msg=k)


def test_reduction_ordering():
    """naive >= inplace, coshare >= both; both gives the paper's ~2x train."""
    sym = mlp_loss(depth=6, hidden=128)
    shapes = {k: v.shape for k, v in mlp_args(depth=6, hidden=128).items()}
    g = Graph(sym._outputs)
    sh, dt = infer_shapes(g, shapes)
    sizes = {s: plan_graph(g, sh, dt, strategy=s).internal_bytes()
             for s in STRATEGIES}
    assert sizes["naive"] >= sizes["inplace"] >= sizes["both"]
    assert sizes["naive"] >= sizes["coshare"] >= sizes["both"]
    assert sizes["naive"] / sizes["both"] >= 1.5  # forward-only already shares


def test_prediction_shares_more_than_training():
    """Fig. 7: prediction (forward-only) reuses much more than training."""
    sym = mlp_loss(depth=8, hidden=256)
    args = mlp_args(depth=8, hidden=256)
    wrt = [k for k in args if k not in ("data", "label")]
    ex_pred = sym.bind(args, memplan="both")
    ex_train = sym.bind(args, grad_wrt=wrt, memplan="both")
    red_pred = ex_pred.memory_stats()["reduction"]
    red_train = ex_train.memory_stats()["reduction"]
    assert red_pred > red_train >= 1.0
    assert red_pred >= 3.0  # paper: ~4x for prediction


# ---------------------------------------------------------------------------
# Property-based: random elementwise DAGs execute identically under all plans

@st.composite
def random_dag_program(draw):
    n_ops = draw(st.integers(3, 25))
    ops = draw(st.lists(st.sampled_from(["add", "mul", "sub", "tanh", "relu",
                                         "exp_s", "neg", "scale"]),
                        min_size=n_ops, max_size=n_ops))
    picks = draw(st.lists(st.tuples(st.integers(0, 10 ** 6),
                                    st.integers(0, 10 ** 6)),
                          min_size=n_ops, max_size=n_ops))
    return ops, picks


@given(random_dag_program())
@settings(max_examples=25, deadline=None)
def test_random_dag_all_strategies_agree(prog):
    ops_list, picks = prog
    a, b = Variable("a"), Variable("b")
    vals = [a, b]
    for op, (i, j) in zip(ops_list, picks):
        x = vals[i % len(vals)]
        y = vals[j % len(vals)]
        if op == "add":
            vals.append(x + y)
        elif op == "mul":
            vals.append(x * y)
        elif op == "sub":
            vals.append(x - y)
        elif op == "tanh":
            vals.append(Symbol._from_op("tanh", [x]))
        elif op == "relu":
            vals.append(Activation(x, "relu"))
        elif op == "exp_s":
            vals.append(Symbol._from_op("sigmoid", [x]))
        elif op == "neg":
            vals.append(-x)
        elif op == "scale":
            vals.append(x * 0.5 + 1.0)
    loss = Symbol._from_op("reduce_sum", [vals[-1]])
    rng = np.random.RandomState(0)
    args = {"a": rng.randn(3, 4).astype(np.float32),
            "b": rng.randn(3, 4).astype(np.float32)}
    results = {}
    for strat in STRATEGIES:
        reset_default_engine()
        ex = loss.bind(args, grad_wrt=["a", "b"], memplan=strat,
                       check_plan=True)
        out = np.asarray(ex.forward()[0])
        grads = {k: np.asarray(v) for k, v in ex.backward().items()}
        results[strat] = (out, grads)
    base = results["naive"]
    for strat in STRATEGIES[1:]:
        np.testing.assert_allclose(results[strat][0], base[0], rtol=1e-5,
                                   err_msg=strat)
        for k in ("a", "b"):
            np.testing.assert_allclose(results[strat][1][k], base[1][k],
                                       rtol=1e-4, atol=1e-5,
                                       err_msg=f"{strat}:{k}")


# ---------------------------------------------------------------------------
# nbytes dtype table (ISSUE 3 satellite: no silent 4-byte fallback)

def test_nbytes_known_dtypes_including_narrow():
    from repro.core.memplan import nbytes
    assert nbytes((4,), "float32") == 16
    assert nbytes((4,), "bfloat16") == 8
    assert nbytes((4,), "int16") == 8
    assert nbytes((4,), "uint32") == 16
    assert nbytes((4,), "float8_e4m3fn") == 4
    assert nbytes((4,), "float8_e5m2") == 4
    assert nbytes((2, 3), np.dtype("uint16")) == 12
    assert nbytes((), "float64") == 8


def test_nbytes_unknown_dtype_raises_naming_it():
    from repro.core.memplan import nbytes
    with pytest.raises(ValueError, match="complex64"):
        nbytes((2, 3), "complex64")
    with pytest.raises(ValueError, match="unknown dtype"):
        nbytes((1,), np.dtype("complex128"))
