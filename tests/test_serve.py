"""Serving engines: static vs paged parity, block-allocator invariants,
continuous-batching slot recycling (ISSUE 4 / DESIGN.md §9)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model, reduced
from repro.serve import (BlockAllocator, BlockTables, PagedServeEngine,
                         PagingError, ServeEngine, SINK_BLOCK)

KEY = jax.random.PRNGKey(0)


def _setup(arch):
    cfg = reduced(get_config(arch))
    params = get_model(cfg).init(KEY)
    return cfg, params


def _prompts(cfg, lengths, seed=1):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(1, cfg.vocab, L)) for L in lengths]


# ---------------------------------------------------------------------------
# allocator / block tables

def test_allocator_alloc_free_cycle():
    a = BlockAllocator(num_blocks=8, block_size=4)
    assert a.num_free == 7                     # block 0 is the sink
    blocks = a.alloc(3)
    assert SINK_BLOCK not in blocks
    assert a.in_use == 3 and a.peak_in_use == 3
    a.free(blocks)
    assert a.in_use == 0 and a.num_free == 7
    assert a.peak_in_use == 3                  # high-water mark sticks


def test_allocator_double_free_raises():
    a = BlockAllocator(num_blocks=8, block_size=4)
    (b,) = a.alloc(1)
    a.free([b])
    with pytest.raises(PagingError):
        a.free([b])
    with pytest.raises(PagingError):
        a.free([SINK_BLOCK])                   # the sink is never in use


def test_allocator_exhaustion_raises():
    a = BlockAllocator(num_blocks=4, block_size=4)
    a.alloc(3)
    with pytest.raises(PagingError):
        a.alloc(1)


def test_block_tables_ensure_release():
    a = BlockAllocator(num_blocks=16, block_size=4)
    t = BlockTables(a, max_batch=2, max_pages=5)
    t.ensure(0, 9)                             # 3 pages of 4
    assert t.n_pages(0) == 3 and a.in_use == 3
    t.ensure(0, 9)                             # idempotent
    assert a.in_use == 3
    t.ensure(0, 13)                            # grow by one page
    assert t.n_pages(0) == 4 and a.in_use == 4
    assert all(b != SINK_BLOCK for b in t.row(0)[:4])
    with pytest.raises(PagingError):
        t.ensure(1, 4 * 5 + 1)                 # beyond max_pages
    t.release(0)
    assert a.in_use == 0
    assert all(b == SINK_BLOCK for b in t.row(0))


# ---------------------------------------------------------------------------
# static vs paged: identical greedy tokens on mixed-length prompts

@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "gemma2-2b",
                                  "mamba2-130m"])
def test_static_paged_parity_mixed_lengths(arch):
    """Continuous batching is a scheduling + memory-layout change; the
    sampled tokens must be bit-identical to the static engine's.  Covers
    GQA (qwen), sliding-window + softcap (gemma2) and the SSM recurrent
    state (mamba2); prompt lengths straddle block boundaries."""
    cfg, params = _setup(arch)
    prompts = _prompts(cfg, (9, 16, 5, 12))    # 16 = exact block boundary
    static = ServeEngine(cfg, params, max_len=40)
    toks, _ = static.generate(prompts, max_new_tokens=6, warmup=False)
    paged = PagedServeEngine(cfg, params, block_size=4, max_batch=3,
                             max_len=40, prefill_chunk=8)
    outs, _ = paged.generate(prompts, max_new_tokens=6, warmup=False)
    for i in range(len(prompts)):
        assert [int(t) for t in toks[i]] == outs[i], f"request {i}"


def test_paged_uneven_budgets_and_slot_reuse():
    """More requests than lanes with uneven generation budgets: every
    request completes with its own budget, freed slots are recycled, and
    the allocator ends the run empty (no leaked blocks)."""
    cfg, params = _setup("qwen1.5-0.5b")
    prompts = _prompts(cfg, (7, 3, 11, 5, 9, 4))
    budgets = [2, 7, 3, 5, 1, 4]
    eng = PagedServeEngine(cfg, params, block_size=4, max_batch=2,
                           max_len=32, prefill_chunk=8)
    outs, stats = eng.generate(prompts, max_new_tokens=budgets,
                               warmup=False)
    assert [len(o) for o in outs] == budgets
    assert eng.alloc.in_use == 0               # everything released
    assert not eng.busy
    assert stats.peak_cache_blocks > 0
    # 2 lanes of <= 4 pages: the pool high-water mark can never exceed
    # the per-lane worst case
    assert stats.peak_cache_blocks <= 2 * eng.max_pages
    # slots were actually recycled: 6 requests through 2 lanes
    assert all(r is None for r in eng.slots)


def test_paged_matches_static_with_slot_reuse():
    """Token parity must survive slot recycling: a recycled lane's pool
    blocks and SSM state rows held a previous request's data."""
    cfg, params = _setup("qwen1.5-0.5b")
    prompts = _prompts(cfg, (6, 13, 4, 10, 7), seed=3)
    static = ServeEngine(cfg, params, max_len=32)
    toks, _ = static.generate(prompts, max_new_tokens=5, warmup=False)
    paged = PagedServeEngine(cfg, params, block_size=4, max_batch=2,
                             max_len=32, prefill_chunk=16)
    outs, _ = paged.generate(prompts, max_new_tokens=5, warmup=False)
    for i in range(len(prompts)):
        assert [int(t) for t in toks[i]] == outs[i], f"request {i}"


def test_paged_rejects_overlong_and_encdec():
    """Unservable requests get a TYPED rejection (DESIGN.md §14) — no
    exception escapes add_request for an overload/shape problem."""
    from repro.serve import Status
    from repro.serve.engine import REJECT_PROMPT_TOO_LONG

    cfg, params = _setup("qwen1.5-0.5b")
    eng = PagedServeEngine(cfg, params, block_size=4, max_batch=2,
                           max_len=16)
    t = eng.add_request([1] * 15, 8)           # prompt + budget > max_len
    assert not t.accepted and t.reason == REJECT_PROMPT_TOO_LONG
    assert eng.results[t.rid].status is Status.SHED
    assert not eng.busy                        # never enqueued
    tiny = PagedServeEngine(cfg, params, block_size=4, max_batch=2,
                            max_len=16, num_blocks=3)
    t = tiny.add_request([1] * 10, 4)          # needs 4 blocks of the 2:
    assert not t.accepted                      # could never be admitted
    assert t.reason == REJECT_PROMPT_TOO_LONG
    wcfg, wparams = _setup("whisper-base")
    with pytest.raises(ValueError):            # arch limitation, not load
        PagedServeEngine(wcfg, wparams)


def test_static_engine_compile_time_reported_separately():
    """Satellite: the first static call used to fold jit compile into
    prefill_s/decode_s; with warmup the timed phases exclude it."""
    cfg, params = _setup("qwen1.5-0.5b")
    eng = ServeEngine(cfg, params, max_len=24)
    prompts = _prompts(cfg, (5, 9))
    toks, stats = eng.generate(prompts, max_new_tokens=4)
    assert toks.shape == (2, 4)
    assert stats.compile_s > 0 and stats.decode_s > 0
    # both generates run fully warm (warmup compiled everything), so the
    # first decode_s must be the same order as a repeat run — if compile
    # had leaked into the timed phase it would be ~100x larger.  Robust
    # to persistent compilation caches, unlike asserting on compile_s.
    _, again = eng.generate(prompts, max_new_tokens=4, warmup=False)
    assert stats.decode_s < 20 * again.decode_s


def test_static_mixed_length_logits_ignore_padding():
    """Satellite: tail-padded prompts must produce the same greedy tokens
    as running each prompt alone (pad id 0 is a real vocab id — only the
    per-sequence length mask keeps it out)."""
    cfg, params = _setup("qwen1.5-0.5b")
    prompts = _prompts(cfg, (5, 12), seed=7)
    eng = ServeEngine(cfg, params, max_len=24)
    toks, _ = eng.generate(prompts, max_new_tokens=4, warmup=False)
    for i, p in enumerate(prompts):
        solo, _ = eng.generate([p], max_new_tokens=4, warmup=False)
        assert list(toks[i]) == list(solo[0]), f"prompt {i}"


# ---------------------------------------------------------------------------
# train -> serve handoff (ISSUE 7 / DESIGN.md §12)

@pytest.mark.mesh
def test_trained_checkpoint_serves_identically():
    """A checkpoint written by a Trainer on a 2x2 (data, model) mesh
    loads into PagedServeEngine on a single device and produces greedy
    tokens bit-identical to serving the in-memory trained params — the
    elastic train->serve handoff."""
    from mesh_subproc import run_sub
    out = run_sub("""
    import tempfile, jax, numpy as np
    from repro.configs import get_config
    from repro.data import SyntheticLM
    from repro.models import reduced
    from repro.serve import PagedServeEngine
    from repro.train import TrainConfig, Trainer, latest_checkpoint, \
        load_checkpoint

    cfg = reduced(get_config("qwen1.5-0.5b"))
    root = tempfile.mkdtemp()
    tcfg = TrainConfig(lr=1e-2, total_steps=4, warmup_steps=1, log_every=2,
                       checkpoint_every=3, checkpoint_dir=root)
    tr = Trainer(cfg, tcfg)
    with jax.set_mesh(jax.make_mesh((2, 2), ("data", "model"))):
        params, _ = tr.fit(iter(SyntheticLM(cfg.vocab, 32, 4, n_batches=4)))
    tr.wait_for_checkpoint()
    # NOTE: checkpoint lands at step 3 (the last update), so the saved
    # params ARE the in-memory ones fit() returned.
    restored, step = load_checkpoint(latest_checkpoint(root))
    assert step == 3

    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(1, cfg.vocab, L)) for L in (5, 9, 12)]
    def greedy(p):
        eng = PagedServeEngine(cfg, p, block_size=8, max_batch=3,
                               max_len=32)
        outs, _ = eng.generate(prompts, max_new_tokens=6)
        return [list(map(int, o)) for o in outs]

    mem = greedy(jax.device_get(params))
    ck = greedy(restored["params"])
    assert mem == ck, (mem, ck)
    print("HANDOFF_OK", mem[0][:4])
    """, devices=4)
    assert "HANDOFF_OK" in out


# ---------------------------------------------------------------------------
# quantized KV-cache serving + fused sampling (DESIGN.md §13)

def test_paged_int8_greedy_parity_and_bytes():
    """int8 paged engine: same greedy tokens as the fp engine on a short
    workload, at the byte-model-predicted fraction of the fp cache (the
    per-row f32 scales included, peak block count identical)."""
    from repro.core.memplan import kv_cache_bytes_paged
    cfg, params = _setup("qwen1.5-0.5b")
    prompts = _prompts(cfg, (16, 24, 32), seed=2)
    outs, stats = {}, {}
    for kd in (None, "int8"):
        eng = PagedServeEngine(cfg, params, block_size=8, max_batch=3,
                               max_len=48, prefill_chunk=16, kv_dtype=kd)
        outs[kd], stats[kd] = eng.generate(prompts, max_new_tokens=6,
                                           warmup=False)
    assert [list(map(int, o)) for o in outs["int8"]] == \
        [list(map(int, o)) for o in outs[None]]
    assert stats["int8"].peak_cache_blocks == stats[None].peak_cache_blocks
    # measured peak == model EXACTLY, and >= 1.8x below fp
    blocks = stats["int8"].peak_cache_blocks
    model = kv_cache_bytes_paged(cfg, [], 8, kv_dtype="int8")["block_bytes"]
    assert stats["int8"].peak_cache_bytes == blocks * model
    assert stats[None].peak_cache_bytes / stats["int8"].peak_cache_bytes \
        >= 1.8


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8_e4m3", "fp8_e5m2"])
def test_paged_cache_byte_model_is_exact(kv_dtype):
    """memplan's block_bytes == the real pool allocation (codes + scale
    tensors), leaf for leaf, for every supported storage dtype."""
    import numpy as np
    from repro.core.memplan import _DTYPE_BYTES, kv_cache_bytes_paged
    from repro.models import get_model
    cfg, _ = _setup("qwen1.5-0.5b")
    specs = get_model(cfg).paged_cache_specs(10, 8, 4, kv_dtype=kv_dtype)
    real = sum(int(np.prod(s.shape)) * _DTYPE_BYTES[str(s.dtype)]
               for s in jax.tree.leaves(specs))
    model = kv_cache_bytes_paged(cfg, [], 8, kv_dtype=kv_dtype)
    assert real == model["block_bytes"] * 10


def test_paged_engine_rejects_unknown_kv_dtype():
    cfg, params = _setup("qwen1.5-0.5b")
    with pytest.raises(ValueError, match="kv.dtype"):
        PagedServeEngine(cfg, params, max_len=32, kv_dtype="int4")


def test_paged_engine_fused_sampling_path():
    """top-k/top-p routes through the fused kernel: reproducible under a
    seed, different from greedy, tokens in-vocab."""
    cfg, params = _setup("qwen1.5-0.5b")
    prompts = _prompts(cfg, (9, 14), seed=4)

    def go(**kw):
        eng = PagedServeEngine(cfg, params, block_size=8, max_batch=2,
                               max_len=48, **kw)
        outs, _ = eng.generate(prompts, max_new_tokens=8, temperature=0.9,
                               seed=13, warmup=False)
        return [list(map(int, o)) for o in outs]

    a = go(top_k=25, top_p=0.9)
    assert a == go(top_k=25, top_p=0.9)            # seed-reproducible
    assert all(0 <= t < cfg.vocab for o in a for t in o)
    greedy_eng = PagedServeEngine(cfg, params, block_size=8, max_batch=2,
                                  max_len=48)
    g, _ = greedy_eng.generate(prompts, max_new_tokens=8, warmup=False)
    assert a != [list(map(int, o)) for o in g]


def test_static_engine_fused_sampling_path():
    cfg, params = _setup("qwen1.5-0.5b")
    prompts = _prompts(cfg, (7, 11), seed=5)
    eng = ServeEngine(cfg, params, max_len=32)
    a, _ = eng.generate(prompts, max_new_tokens=6, temperature=0.8,
                        top_k=30, top_p=0.95, seed=3, warmup=False)
    b, _ = eng.generate(prompts, max_new_tokens=6, temperature=0.8,
                        top_k=30, top_p=0.95, seed=3, warmup=False)
    assert (a == b).all()
    assert a.shape == (2, 6)
