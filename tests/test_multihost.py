"""Multi-host launch path (DESIGN.md §15): per-host data sharding,
PrefetchIterator lifecycle, and real multi-process jax.distributed
groups through the repro.launch.multihost driver."""
import json
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro.data import DataIterator, RecordReader, SyntheticLM, pack_records
from repro.data.pipeline import PrefetchIterator, global_batch_slice

SRC = str(Path(__file__).resolve().parents[1] / "src")


# ---------------------------------------------------------------------------
# per-host sharding (single-process unit tests)

def test_global_batch_slice_partitions_the_batch():
    for batch, procs in [(8, 1), (8, 2), (8, 4), (12, 3)]:
        slices = [global_batch_slice(batch, p, procs) for p in range(procs)]
        rows = [r for lo, hi in slices for r in range(lo, hi)]
        assert rows == list(range(batch))


def test_global_batch_slice_rejects_bad_args():
    with pytest.raises(ValueError, match="divisible"):
        global_batch_slice(10, 0, 4)
    with pytest.raises(ValueError, match="out of range"):
        global_batch_slice(8, 4, 4)


def test_synthetic_shards_concatenate_to_single_host_stream():
    full = list(SyntheticLM(32, 8, 8, seed=5, n_batches=3))
    shards = [list(SyntheticLM(32, 8, 8, seed=5, n_batches=3,
                               process_index=p, process_count=4))
              for p in range(4)]
    for t, batch in enumerate(full):
        got = np.concatenate([shards[p][t]["tokens"] for p in range(4)])
        np.testing.assert_array_equal(got, batch["tokens"])
        assert shards[0][t]["tokens"].shape[0] == 2


def test_data_iterator_shards_disjoint_and_cover(tmp_path):
    path = str(tmp_path / "r.rec")
    rng = np.random.default_rng(0)
    pack_records(path, [rng.integers(0, 99, 4, dtype=np.int32).tobytes()
                        for _ in range(50)])
    decode = lambda b: np.frombuffer(b, np.int32)
    full = list(DataIterator(RecordReader(path), batch=8, decode_fn=decode,
                             seed=2))
    all_idx = []
    for p in range(4):
        it = DataIterator(RecordReader(path), batch=8, decode_fn=decode,
                          seed=2, process_index=p, process_count=4)
        idx = it.record_indices()
        all_idx.extend(idx.tolist())
        lo, hi = global_batch_slice(8, p, 4)
        for t, mine in enumerate(it):
            np.testing.assert_array_equal(mine, full[t][lo:hi])
    # disjoint and covering: exactly the 6 full batches' records
    assert len(all_idx) == len(set(all_idx)) == 48


def test_data_iterator_multi_host_requires_drop_last(tmp_path):
    path = str(tmp_path / "r.rec")
    pack_records(path, [b"1234"] * 8)
    with pytest.raises(ValueError, match="drop_last"):
        DataIterator(RecordReader(path), batch=4, decode_fn=bytes,
                     drop_last=False, process_index=0, process_count=2)


# ---------------------------------------------------------------------------
# PrefetchIterator lifecycle

def test_prefetch_propagates_reader_exception():
    def bad():
        yield 1
        raise RuntimeError("disk on fire")
    it = iter(PrefetchIterator(bad(), depth=2))
    assert next(it) == 1
    with pytest.raises(RuntimeError, match="disk on fire"):
        while True:
            next(it)


def test_prefetch_threads_exit_on_early_abandonment():
    import threading
    before = threading.active_count()
    for _ in range(5):
        it = iter(PrefetchIterator(iter(range(10_000)), depth=2,
                                   num_threads=2))
        assert next(it) == 0
        it.close()                      # abandon mid-stream
    deadline = time.time() + 5
    while threading.active_count() > before and time.time() < deadline:
        time.sleep(0.02)
    assert threading.active_count() <= before, "prefetch workers leaked"


def test_prefetch_completes_normally_after_lifecycle_fix():
    out = list(PrefetchIterator(iter(range(100)), depth=3, num_threads=2))
    assert sorted(out) == list(range(100))


def test_prefetch_exception_before_first_item():
    def bad():
        raise ValueError("no data")
        yield  # pragma: no cover
    with pytest.raises(ValueError, match="no data"):
        list(PrefetchIterator(bad(), depth=2))


# ---------------------------------------------------------------------------
# real multi-process groups (subprocess driver; slow — own CI shard)

def _driver(task, tmp_path, *extra, procs=2):
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)          # workers force their own count
    out_dir = tmp_path / task
    cmd = [sys.executable, "-m", "repro.launch.multihost",
           "--local-procs", str(procs), "--task", task,
           "--metrics-dir", str(out_dir), "--steps", "2", *extra]
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout, out_dir


def _reports(out_dir, task):
    recs = []
    for p in sorted(Path(out_dir).glob("proc*.jsonl")):
        for line in p.read_text().splitlines():
            rec = json.loads(line)
            if rec.get("task") == task:
                recs.append(rec)
    return recs


@pytest.mark.multihost
def test_two_process_shards_disjoint_and_cover_epoch(tmp_path):
    """ISSUE gate: a real 2-process jax.distributed group where per-host
    record shards are disjoint and cover the epoch (the driver enforces
    it; re-assert from the per-process reports here)."""
    stdout, out_dir = _driver("shard_check", tmp_path, "--n-records", "48",
                              "--batch", "8")
    assert "shard_check OK" in stdout
    recs = _reports(out_dir, "shard_check")
    assert len(recs) == 2
    idx = [i for r in recs for i in r["record_indices"]]
    assert len(idx) == len(set(idx)) == 48
    assert all(r["n_local"] == 24 for r in recs)


@pytest.mark.multihost
def test_two_process_parity_eventual_vs_sequential(tmp_path):
    """Real 2-process launch: eventual at staleness 0 must match
    sequential bit-for-bit on every process, and the processes must agree
    with each other (params crc + losses)."""
    stdout, out_dir = _driver("parity", tmp_path)
    assert "parity OK" in stdout
    recs = _reports(out_dir, "parity")
    assert len(recs) == 2
    assert all(r["bit_exact"] for r in recs)
    assert len({r["params_crc"] for r in recs}) == 1
    assert len({tuple(r["losses"]) for r in recs}) == 1


@pytest.mark.multihost
def test_two_process_eventual_staleness_bounded(tmp_path):
    stdout, out_dir = _driver("smoke", tmp_path, "--sync-mode", "eventual",
                              "--max-staleness", "2", "--steps", "4")
    assert "smoke OK" in stdout
    recs = _reports(out_dir, "smoke")
    assert len(recs) == 2
    assert all(r["observed_staleness"] <= 2 for r in recs)
