"""Serve-path fault injection (ISSUE 9 / DESIGN.md §14): the engine must
isolate injected faults to the affected request — typed terminal ERROR,
resources reclaimed, every other lane bit-exact — and retry transient
device faults.  Runs under the ``chaos`` CI shard, which uploads the
engine metrics JSONL written by the session fixture below."""
import os

import jax
import numpy as np
import pytest

from repro import obs
from repro.configs import get_config
from repro.models import get_model, reduced
from repro.serve import ChaosHooks, PagedServeEngine, Status

pytestmark = pytest.mark.chaos

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="session", autouse=True)
def _dump_chaos_metrics():
    """CI artifact: engine counters/histograms accumulated across the
    chaos shard, written where the workflow's CHAOS_METRICS_PATH points."""
    yield
    path = os.environ.get("CHAOS_METRICS_PATH")
    if path:
        obs.get_metrics().dump_jsonl(path)


def _setup(arch="qwen1.5-0.5b"):
    cfg = reduced(get_config(arch))
    params = get_model(cfg).init(KEY)
    return cfg, params


def _prompts(cfg, lengths, seed=1):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(1, cfg.vocab, L)) for L in lengths]


def test_poisoned_request_is_isolated():
    """A request whose every device-path touch faults must end in a
    terminal ERROR with its blocks/slot reclaimed, while the other
    lanes' greedy tokens are bit-identical to a fault-free run."""
    cfg, params = _setup()
    prompts = _prompts(cfg, (9, 6, 11))
    clean = PagedServeEngine(cfg, params, block_size=4, max_batch=2,
                             max_len=40, prefill_chunk=8)
    want, _ = clean.generate(prompts, max_new_tokens=6, warmup=False)

    chaos = ChaosHooks(poison_rid=1)
    eng = PagedServeEngine(cfg, params, block_size=4, max_batch=2,
                           max_len=40, prefill_chunk=8, chaos=chaos)
    outs, stats = eng.generate(prompts, max_new_tokens=6, warmup=False)
    assert eng.results[1].status is Status.ERROR
    assert "poison" in eng.results[1].reason
    assert chaos.faults_fired >= 1
    assert outs[0] == want[0] and outs[2] == want[2]   # bystanders exact
    assert stats.errors == 1
    assert eng.alloc.in_use == 0 and not eng.busy      # nothing leaked


def test_alloc_fault_fails_request_not_process():
    """Once the injected allocator fault trips, growing requests end in
    typed ERROR — the engine keeps draining, frees stay consistent, and
    no exception escapes run()."""
    cfg, params = _setup()
    prompts = _prompts(cfg, (8, 8, 8))
    chaos = ChaosHooks(fail_alloc_after=8)
    eng = PagedServeEngine(cfg, params, block_size=4, max_batch=2,
                           max_len=48, prefill_chunk=8, chaos=chaos)
    outs, stats = eng.generate(prompts, max_new_tokens=12, warmup=False)
    statuses = [eng.results[rid].status for rid in range(3)]
    assert Status.ERROR in statuses                    # the fault landed
    assert all(s in (Status.OK, Status.ERROR) for s in statuses)
    for rid, s in enumerate(statuses):                 # typed, actionable
        if s is Status.ERROR:
            assert "alloc fault" in eng.results[rid].reason
    assert chaos.faults_fired >= 1
    assert eng.alloc.in_use == 0 and not eng.busy


def test_corrupted_swap_roundtrip_is_detected():
    """A swap payload corrupted in flight must be caught by the restore-
    time crc check: the request fails typed, it is never resumed from
    garbage KV, and the swap entry is released."""
    cfg, params = _setup()
    chaos = ChaosHooks(corrupt_swap_rid=0)
    eng = PagedServeEngine(cfg, params, block_size=4, max_batch=2,
                           max_len=40, prefill_chunk=8, swap_blocks=16,
                           chaos=chaos)
    t0 = eng.add_request(_prompts(cfg, (9,))[0], 10)
    t1 = eng.add_request(_prompts(cfg, (6,))[0], 6)
    for _ in range(50):
        eng.step()
        req0 = next((r for r in eng.slots if r and r.rid == t0.rid), None)
        if req0 is not None and len(req0.out) >= 2:
            break
    assert eng.preempt(t0.rid) and t0.rid in eng.swap
    assert chaos.corrupted == [t0.rid]
    eng.run()
    assert eng.results[t0.rid].status is Status.ERROR
    assert "corrupt" in eng.results[t0.rid].reason
    assert eng.results[t1.rid].status is Status.OK
    assert len(eng.swap) == 0 and eng.alloc.in_use == 0


def test_transient_decode_fault_is_retried():
    """A decode-step fault injected BEFORE dispatch mutates nothing, so
    the engine retries the identical step: every request still finishes
    OK with tokens bit-identical to a fault-free run."""
    cfg, params = _setup()
    prompts = _prompts(cfg, (9, 6))
    clean = PagedServeEngine(cfg, params, block_size=4, max_batch=2,
                             max_len=40, prefill_chunk=8)
    want, _ = clean.generate(prompts, max_new_tokens=6, warmup=False)

    chaos = ChaosHooks(fail_decode_at_step=3)
    eng = PagedServeEngine(cfg, params, block_size=4, max_batch=2,
                           max_len=40, prefill_chunk=8, chaos=chaos)
    outs, _ = eng.generate(prompts, max_new_tokens=6, warmup=False)
    assert chaos.faults_fired == 1
    assert outs == want
    assert all(r.status is Status.OK for r in eng.results.values())


def test_admission_delay_expires_tight_deadlines():
    """A slow admission path (injected delay) pushes queued requests past
    their deadlines: they end TIMEOUT via the sweep, never crash."""
    cfg, params = _setup()
    chaos = ChaosHooks(admission_delay_s=0.02)
    eng = PagedServeEngine(cfg, params, block_size=4, max_batch=1,
                           max_len=32, chaos=chaos)
    p = _prompts(cfg, (6,))[0]
    t_doomed = eng.add_request(p, 4, deadline_ms=5)
    t_fine = eng.add_request(p, 4)
    stats = eng.run()
    assert eng.results[t_doomed.rid].status is Status.TIMEOUT
    assert eng.results[t_fine.rid].status is Status.OK
    assert stats.timeouts == 1
    assert eng.alloc.in_use == 0 and not eng.busy


def test_warmup_is_immune_to_chaos():
    """The warmup request is not traffic: even with every hook armed,
    warmup compiles cleanly and the seam re-arms afterwards."""
    cfg, params = _setup()
    chaos = ChaosHooks(fail_alloc_after=0, admission_delay_s=0.0)
    eng = PagedServeEngine(cfg, params, block_size=4, max_batch=1,
                           max_len=32, chaos=chaos)
    compile_s = eng.warmup()
    assert compile_s > 0
    assert eng.chaos is chaos and eng.alloc.chaos is chaos   # re-armed
    t = eng.add_request(_prompts(cfg, (6,))[0], 3)
    eng.run()
    assert eng.results[t.rid].status is Status.ERROR   # fault now live
