"""Import hypothesis if available; otherwise expose stand-ins that turn
``@given`` property tests into skips (the container may lack hypothesis,
and tier-1 must not pip install)."""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:
    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        return lambda f: f

    class _NoStrategies:
        """Absorbs any strategy construction (st.lists, @st.composite...)."""

        def __call__(self, *_a, **_k):
            return self

        def __getattr__(self, _name):
            return self

    st = _NoStrategies()
