"""Dependency engine (MXNet §3.2): mutation ordering, laziness, RNG serialization."""
import numpy as np

from repro.core import Engine, NDArray, RNG


def test_lazy_then_flush():
    eng = Engine()
    a = NDArray(np.ones((2, 2), np.float32), engine=eng)
    b = a + 1.0
    c = b * 3.0
    assert c._value is None          # nothing ran yet (lazy, §2.2)
    np.testing.assert_allclose(c.asnumpy(), np.full((2, 2), 6.0))


def test_wait_is_fine_grained():
    """wait(tag) flushes only the tag's ancestor closure — an independent
    pending op must NOT be executed (§3.2 per-resource waits)."""
    eng = Engine()
    a = NDArray(np.ones(4, np.float32), engine=eng)
    b = (a + 1.0) * 2.0                    # dependent chain: 2 ops
    c = NDArray(np.ones(4, np.float32), engine=eng)
    d = c + 5.0                            # independent pending op
    np.testing.assert_allclose(b.asnumpy(), np.full(4, 4.0))
    assert d._value is None                # untouched by b's flush
    assert eng.stats()["ops"] == 2
    np.testing.assert_allclose(d.asnumpy(), np.full(4, 6.0))
    assert eng.stats()["ops"] == 3


def test_wait_flushes_war_predecessors():
    """A pre-mutation reader is an ancestor of the mutator: waiting on the
    mutated tag must run the reader first (WAR edge preserved)."""
    eng = Engine()
    w = NDArray(np.zeros(3, np.float32), engine=eng)
    r = w + 1.0                            # reads pre-mutation value
    w += 7.0
    np.testing.assert_allclose(w.asnumpy(), np.full(3, 7.0))
    assert r._value is not None            # reader ran as part of the closure
    np.testing.assert_allclose(np.asarray(r._value), np.full(3, 1.0))


def test_mutation_war_ordering():
    """A reader pushed before a mutation must see the pre-mutation value."""
    eng = Engine()
    w = NDArray(np.zeros(4, np.float32), engine=eng)
    r1 = w + 0.0        # read (before)
    w += 5.0            # mutate
    r2 = w + 0.0        # read (after)
    np.testing.assert_allclose(r1.asnumpy(), np.zeros(4))
    np.testing.assert_allclose(r2.asnumpy(), np.full(4, 5.0))


def test_mutation_waw_ordering():
    eng = Engine()
    w = NDArray(np.zeros(4, np.float32), engine=eng)
    w += 1.0
    w *= 3.0
    w -= 2.0
    np.testing.assert_allclose(w.asnumpy(), np.full(4, 1.0))


def test_parameter_update_pattern():
    """w -= eta * g: the §2.2 gradient-descent snippet."""
    eng = Engine()
    w = NDArray(np.full(3, 10.0, np.float32), engine=eng)
    g = NDArray(np.full(3, 2.0, np.float32), engine=eng)
    for _ in range(5):
        w -= 0.5 * g
    np.testing.assert_allclose(w.asnumpy(), np.full(3, 5.0))


def test_rng_same_seed_serialized_reproducible():
    """§3.2: two generators with the same seed write the seed resource, so
    they cannot run in parallel and draws are reproducible."""
    def draws(order):
        eng = Engine()
        rng = RNG(seed=7, engine=eng)
        outs = [rng.normal((4,)) for _ in range(3)]
        if order == "reverse":
            # force different *flush* order; engine order must not change
            _ = outs[2].asnumpy()
        return [o.asnumpy() for o in outs]

    a = draws("forward")
    b = draws("reverse")
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_wave_parallelism_detected():
    """Independent ops land in one wave; dependent chains serialize."""
    eng = Engine()
    xs = [NDArray(np.ones(2, np.float32), engine=eng) for _ in range(8)]
    ys = [x + 1.0 for x in xs]       # 8 independent ops
    eng.wait_all()
    assert eng.stats()["max_wave"] >= 8

    eng2 = Engine()
    a = NDArray(np.ones(2, np.float32), engine=eng2)
    for _ in range(10):
        a = a + 1.0                  # serial chain
    eng2.wait_all()
    assert eng2.stats()["max_wave"] == 1


def test_joint_scheduling_compute_and_comm():
    """KVStore ops and compute flow through one queue (§2.3 claim)."""
    from repro.core import KVStoreLocal, sgd_updater
    eng = Engine()
    kv = KVStoreLocal(eng)
    kv.set_updater(sgd_updater(1.0))
    kv.init("w", np.full(2, 4.0, np.float32))
    w = NDArray(np.zeros(2, np.float32), engine=eng)
    kv.pull("w", out=w)
    g = w * 0.25            # compute depends on pull
    kv.push("w", g)         # push depends on compute
    out = NDArray(np.zeros(2, np.float32), engine=eng)
    kv.pull("w", out=out)
    np.testing.assert_allclose(out.asnumpy(), np.full(2, 3.0))


def test_no_deadlock_large_random_dag():
    rs = np.random.RandomState(0)
    eng = Engine()
    pool = [NDArray(np.ones(2, np.float32), engine=eng) for _ in range(4)]
    for i in range(200):
        k = rs.randint(0, 4)
        if rs.rand() < 0.3:
            pool[k] += 1.0
        else:
            j = rs.randint(0, 4)
            pool[k] = pool[k] + pool[j]
    eng.wait_all()
    assert eng.stats()["ops"] == 200
