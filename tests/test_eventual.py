"""Eventual-consistency gradient sync (DESIGN.md §15): the bounded-
staleness schedule, its analytic byte/state models, and the on-mesh
staleness-0 bit-exactness gate."""
import sys
from pathlib import Path

import jax
import pytest

sys.path.insert(0, str(Path(__file__).parent))
from hypothesis_compat import given, settings, st  # noqa: E402
from mesh_subproc import run_sub  # noqa: E402

from repro.dist.bucketing import BucketPlan
from repro.dist.collectives import (EventualSync, eventual_crosspod_bytes,
                                    eventual_state_bytes,
                                    eventual_sync_buckets)


def _plan(n_leaves=6, elems=1000, cap=4096):
    leaves = [jax.ShapeDtypeStruct((elems,), "float32")
              for _ in range(n_leaves)]
    return BucketPlan.build(leaves, cap_bytes=cap)


# ---------------------------------------------------------------------------
# schedule

def test_schedule_round_robin():
    assert eventual_sync_buckets(4, 0, 0) == (0, 1, 2, 3)
    assert eventual_sync_buckets(4, 1, 0) == (0, 2)
    assert eventual_sync_buckets(4, 1, 1) == (1, 3)
    assert eventual_sync_buckets(4, 3, 2) == (2,)
    assert eventual_sync_buckets(4, 3, 1, warm=True) == (0, 1, 2, 3)


def test_schedule_covers_every_bucket_once_per_period():
    for n, ms in [(1, 0), (3, 1), (4, 2), (7, 5), (5, 9)]:
        period = ms + 1
        seen = []
        for p in range(period):
            seen.extend(eventual_sync_buckets(n, ms, p))
        assert sorted(seen) == list(range(n)), (n, ms, seen)


@settings(max_examples=200, deadline=None)
@given(st.integers(1, 12), st.integers(0, 8), st.integers(0, 60))
def test_staleness_never_exceeds_bound(n_buckets, max_staleness, n_steps):
    """Property: running the host-side schedule for any number of steps,
    every bucket's observed staleness (steps since its last scheduled
    sync) stays <= max_staleness."""
    versions = [None] * n_buckets
    for step in range(n_steps):
        warm = step == 0
        synced = set(eventual_sync_buckets(n_buckets, max_staleness,
                                           step % (max_staleness + 1),
                                           warm=warm))
        for b in range(n_buckets):
            if b in synced or versions[b] is None:
                versions[b] = step
            else:
                assert step - versions[b] <= max_staleness, \
                    (b, step, versions[b])


def test_record_step_tracks_observed_staleness():
    # EventualSync on a 1-device host degenerates (no pod axis), so the
    # host-side bookkeeping is exercised through the schedule directly
    # (run_sub covers the on-mesh variant); here: versions math only.
    versions = [None] * 4
    max_obs = 0
    for step in range(9):
        synced = set(eventual_sync_buckets(4, 2, step % 3, warm=step == 0))
        for b in range(4):
            if b in synced or versions[b] is None:
                versions[b] = step
            else:
                max_obs = max(max_obs, step - versions[b])
    assert max_obs == 2


# ---------------------------------------------------------------------------
# analytic models (pure, no mesh)

def test_crosspod_bytes_sum_over_phases_equals_full_sync():
    plan = _plan()
    for n_data in (1, 2, 4):
        for ms in (0, 1, 2, 5):
            total = sum(eventual_crosspod_bytes(plan, n_data,
                                                max_staleness=ms, phase=p)
                        for p in range(ms + 1))
            full = eventual_crosspod_bytes(plan, n_data, max_staleness=ms,
                                           phase=0, warm=True)
            assert total == full, (n_data, ms)
            # warm == the staleness-0 every-step (sequential) total
            assert full == eventual_crosspod_bytes(plan, n_data,
                                                   max_staleness=0, phase=0)


def test_state_bytes_is_one_shard_per_bucket_per_worker():
    plan = _plan(n_leaves=3, elems=1001, cap=1 << 20)
    out = eventual_state_bytes(plan, n_data=4, n_workers=8)
    shard = -(-3 * 1001 // 4) * 4           # padded 1/n_data shard, f32
    assert out["per_worker"] == shard
    assert out["total"] == shard * 8
    assert out["n_buckets"] == 1


def test_memplan_model_matches_collectives_model():
    from repro.core.memplan import eventual_sync_bytes
    leaves = [((1000,), "float32")] * 6
    out = eventual_sync_bytes(leaves, n_data=4, n_workers=8,
                              max_staleness=2, bucket_bytes=4096)
    plan = _plan()
    assert out["per_worker"] == eventual_state_bytes(
        plan, 4, 8)["per_worker"]
    assert out["crosspod_reduction"] == pytest.approx(3.0)


def test_eventual_sync_validates_args():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="max_staleness"):
        EventualSync(mesh, {"w": jax.ShapeDtypeStruct((1, 8), "float32")},
                     max_staleness=-1)


def test_degenerate_on_single_worker_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ev = EventualSync(mesh, {"w": jax.ShapeDtypeStruct((1, 8), "float32")},
                      max_staleness=3)
    assert ev.degenerate
    assert ev.init_state() == {}
    assert ev.crosspod_allreduce_bytes(0) == 0
    assert ev.state_bytes()["total"] == 0
    # degenerate schedule: every bucket "syncs" every step
    assert ev.sync_buckets(2) == tuple(range(ev.n_buckets))


# ---------------------------------------------------------------------------
# on-mesh (subprocess, 16 devices: 2 pods x 4 data x 2 model)

@pytest.mark.mesh
def test_staleness0_bit_exact_and_hlo_bytes_on_mesh():
    """Eventual at staleness 0 == bucketed bit-for-bit (warm AND steady
    state), and each phase's compiled cross-pod all-reduce bytes equal
    the analytic model exactly."""
    out = run_sub("""
    import functools
    import jax, jax.numpy as jnp, numpy as np
    from repro.dist.collectives import EventualSync, gradient_sync
    from repro.launch.dryrun import collective_bytes

    mesh = jax.make_mesh((2, 4, 2), ("pod", "data", "model"))
    W = 8
    rng = np.random.default_rng(0)
    g = {f"w{i}": jnp.asarray(rng.normal(size=(W, 700 + 100 * i)),
                              jnp.float32) for i in range(4)}

    ev0 = EventualSync(mesh, g, max_staleness=0, bucket_bytes=4096)
    s = ev0.init_state()
    ref = gradient_sync(mesh, g, mode="bucketed", plan=ev0.plan)
    for warm in (True, False):
        out, s = ev0.apply(g, s, phase=0, warm=warm)
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
            assert (np.asarray(a) == np.asarray(b)).all(), "not bit-exact"
    print("BIT_EXACT_OK")

    ev = EventualSync(mesh, g, max_staleness=2, bucket_bytes=4096)
    state = ev.init_state()
    for phase in range(ev.period):
        f = jax.jit(functools.partial(
            lambda p, x, s: ev.apply(x, s, phase=p), phase))
        coll = collective_bytes(f.lower(g, state).compile().as_text())
        want = ev.crosspod_allreduce_bytes(phase)
        assert coll['raw']['all-reduce'] == want, (phase, coll, want)
    print("HLO_BYTES_OK")
    """)
    assert "BIT_EXACT_OK" in out and "HLO_BYTES_OK" in out


@pytest.mark.mesh
def test_trainer_eventual_staleness0_matches_sequential():
    """Through the Trainer: sync_mode='eventual' at staleness 0 produces
    bit-identical params to sync_mode='sequential' on a (2,4,1) mesh."""
    out = run_sub("""
    import jax, numpy as np
    from repro.configs import get_config
    from repro.models import reduced
    from repro.train import TrainConfig, Trainer
    from repro.data import SyntheticLM

    cfg = reduced(get_config("qwen1.5-0.5b"), vocab=32, n_layers=2,
                  d_model=64, d_ff=128)
    mesh = jax.make_mesh((2, 4, 1), ("pod", "data", "model"))

    def run(mode, ms=0):
        data = SyntheticLM(32, 16, 8, seed=1, n_batches=3)
        tcfg = TrainConfig(lr=1e-2, total_steps=3, log_every=10,
                           sync_mode=mode, max_staleness=ms,
                           bucket_mb=0.001)
        with jax.set_mesh(mesh):
            tr = Trainer(cfg, tcfg)
            params, _ = tr.fit(data, seed=0)
        return tr, params

    _, p_seq = run("sequential")
    tr_ev, p_ev = run("eventual")
    for a, b in zip(jax.tree.leaves(p_seq), jax.tree.leaves(p_ev)):
        assert (np.asarray(a) == np.asarray(b)).all()
    assert tr_ev._ev.max_observed_staleness == 0
    tr2, p2 = run("eventual", ms=2)
    assert tr2._ev.max_observed_staleness <= 2
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(p2))
    print("TRAINER_EVENTUAL_OK")
    """, devices=8)
    assert "TRAINER_EVENTUAL_OK" in out
