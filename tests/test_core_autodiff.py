"""Symbolic autodiff (MXNet §2.1 'backward') vs the jax.grad oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Activation, FullyConnected, LayerNorm, SoftmaxOutput,
                        Variable, reset_default_engine)

RNG = np.random.RandomState(42)


@pytest.fixture(autouse=True)
def fresh_engine():
    reset_default_engine()


def check_grads(sym_builder, ref_fn, arg_shapes, wrt=None, atol=1e-4):
    """Build symbol, bind, backward; compare with jax.grad of ref_fn."""
    args = {k: RNG.randn(*s).astype(np.float32) for k, s in arg_shapes.items()}
    sym = sym_builder()
    wrt = wrt or list(arg_shapes)
    ex = sym.bind(args, grad_wrt=wrt)
    outs = ex.forward()
    grads = ex.backward()

    jargs = {k: jnp.asarray(v) for k, v in args.items()}
    ref_out = ref_fn(jargs)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(ref_out),
                               atol=atol, rtol=1e-4)
    ref_grads = jax.grad(lambda p: ref_fn({**jargs, **p}))(
        {k: jargs[k] for k in wrt})
    for k in wrt:
        np.testing.assert_allclose(np.asarray(grads[k]),
                                   np.asarray(ref_grads[k]),
                                   atol=atol, rtol=1e-3, err_msg=k)


def test_grad_elementwise_chain():
    def build():
        a, b = Variable("a"), Variable("b")
        from repro.core.symbol import Symbol
        e = Symbol._from_op("exp", [a * b])
        t = Symbol._from_op("tanh", [e + a])
        return Symbol._from_op("reduce_sum", [t * 0.5 - b])
    check_grads(build,
                lambda p: jnp.sum(jnp.tanh(jnp.exp(p["a"] * p["b"]) + p["a"]) * 0.5
                                  - p["b"]),
                {"a": (4, 5), "b": (4, 5)})


def test_grad_broadcast():
    def build():
        a, b = Variable("a"), Variable("b")
        from repro.core.symbol import Symbol
        return Symbol._from_op("reduce_sum", [a * b])
    check_grads(build, lambda p: jnp.sum(p["a"] * p["b"]),
                {"a": (4, 5), "b": (5,)})


def test_grad_div_maximum():
    def build():
        a, b = Variable("a"), Variable("b")
        from repro.core.symbol import Symbol
        m = Symbol._from_op("maximum", [a, b])
        return Symbol._from_op("reduce_sum", [m / (b * b + 2.0)])
    check_grads(build,
                lambda p: jnp.sum(jnp.maximum(p["a"], p["b"])
                                  / (p["b"] * p["b"] + 2.0)),
                {"a": (3, 7), "b": (3, 7)})


def test_grad_matmul_transpose():
    def build():
        a, b = Variable("a"), Variable("b")
        from repro.core.symbol import Symbol
        t = Symbol._from_op("transpose", [a @ b])
        return Symbol._from_op("reduce_sum", [Symbol._from_op("tanh", [t])])
    check_grads(build, lambda p: jnp.sum(jnp.tanh((p["a"] @ p["b"]).T)),
                {"a": (3, 4), "b": (4, 5)})


def test_grad_reductions():
    def build():
        a = Variable("a")
        from repro.core.symbol import Symbol
        m = Symbol._from_op("reduce_mean", [a], {"axis": 1, "keepdims": True})
        return Symbol._from_op("reduce_sum", [a * m])
    check_grads(build,
                lambda p: jnp.sum(p["a"] * jnp.mean(p["a"], 1, keepdims=True)),
                {"a": (4, 6)})


def test_grad_softmax():
    def build():
        a, w = Variable("a"), Variable("w")
        from repro.core.symbol import Symbol
        s = Symbol._from_op("softmax", [a @ w])
        return Symbol._from_op("reduce_sum", [s * s])
    check_grads(build,
                lambda p: jnp.sum(jax.nn.softmax(p["a"] @ p["w"], -1) ** 2),
                {"a": (4, 3), "w": (3, 5)})


def test_grad_layernorm():
    def build():
        x, g, b = Variable("x"), Variable("g"), Variable("b")
        ln = LayerNorm(x, g, b)
        from repro.core.symbol import Symbol
        return Symbol._from_op("reduce_sum", [ln * ln])

    def ref(p):
        x, g, b = p["x"], p["g"], p["b"]
        mu = x.mean(-1, keepdims=True)
        var = ((x - mu) ** 2).mean(-1, keepdims=True)
        y = (x - mu) / jnp.sqrt(var + 1e-5) * g + b
        return jnp.sum(y * y)
    check_grads(build, ref, {"x": (6, 8), "g": (8,), "b": (8,)}, atol=3e-4)


def test_grad_mlp_full():
    def build():
        data, label = Variable("data"), Variable("label")
        h = Activation(FullyConnected(data, 16, name="fc1"), "tanh")
        out = SoftmaxOutput(FullyConnected(h, 5, name="fc2"), label)
        return out[0]

    label = RNG.randint(0, 5, (8,)).astype(np.float32)

    def ref(p):
        h = jnp.tanh(p["data"] @ p["fc1_weight"].T + p["fc1_bias"])
        logits = h @ p["fc2_weight"].T + p["fc2_bias"]
        lp = jax.nn.log_softmax(logits, -1)
        lab = jnp.asarray(label).astype(jnp.int32)
        return -jnp.mean(jnp.take_along_axis(lp, lab[:, None], -1))

    args = {"data": RNG.randn(8, 12).astype(np.float32),
            "fc1_weight": RNG.randn(16, 12).astype(np.float32) * 0.3,
            "fc1_bias": np.zeros(16, np.float32),
            "fc2_weight": RNG.randn(5, 16).astype(np.float32) * 0.3,
            "fc2_bias": np.zeros(5, np.float32)}
    wrt = [k for k in args if k != "data"] + ["data"]
    sym = build()
    ex = sym.bind({**args, "label": label}, grad_wrt=wrt)
    ex.forward()
    grads = ex.backward()
    jargs = {k: jnp.asarray(v) for k, v in args.items()}
    ref_grads = jax.grad(ref)(jargs)
    for k in wrt:
        np.testing.assert_allclose(np.asarray(grads[k]),
                                   np.asarray(ref_grads[k]), atol=1e-4,
                                   err_msg=k)


def test_second_use_accumulates():
    # y = a*a + a  -> dy/da = 2a + 1 (add_n accumulation path)
    def build():
        a = Variable("a")
        from repro.core.symbol import Symbol
        return Symbol._from_op("reduce_sum", [a * a + a])
    check_grads(build, lambda p: jnp.sum(p["a"] * p["a"] + p["a"]),
                {"a": (5,)})


def test_grad_unused_variable_is_zero():
    a, b = Variable("a"), Variable("b")
    from repro.core.symbol import Symbol
    sg = Symbol._from_op("stop_gradient", [b])
    loss = Symbol._from_op("reduce_sum", [a * 2.0 + sg])
    va = RNG.randn(3).astype(np.float32)
    vb = RNG.randn(3).astype(np.float32)
    # no grad path to b: grad must be zeros (MXNet returns zeros for
    # unreached args)
    g = loss.grad(["b"], a=(3,), b=(3,))
    ex = g.bind({"a": va, "b": vb})
    out = ex.forward()[0]
    np.testing.assert_allclose(np.asarray(out), np.zeros(3), atol=0)
