"""Cross-validate the §3.3 byte model: the analytic two-level KVStore
counters (``KVStoreDist.bytes_l1/bytes_l2``) against ``collective_bytes()``
parsed from the compiled ``gradient_sync`` HLO.

Both layers model the same claim — level-1 (intra-machine / intra-pod)
aggregation shrinks inter-machine traffic by the devices-per-machine
factor — so the analytic ratio and the HLO all-reduce ratio must agree.

Multi-device lowering needs --xla_force_host_platform_device_count set
before jax initializes, hence the subprocess.
"""
import numpy as np

import pytest

from mesh_subproc import run_sub
from repro.core import KVStoreDist

# topology shared by both layers: 2 machines/pods x 4 devices, 4096-float
# gradient
N_MACHINES, DEVS_PER_MACHINE, N_PARAM = 2, 4, 4096


def test_analytic_two_level_ratio():
    """bytes_l1 / bytes_l2 == devices_per_machine for one sync round."""
    kv = KVStoreDist(n_machines=N_MACHINES,
                     devices_per_machine=DEVS_PER_MACHINE,
                     consistency="sequential")
    kv.init("w", np.zeros(N_PARAM, np.float32))
    for w in range(N_MACHINES * DEVS_PER_MACHINE):
        kv.push("w", worker=w, grad=np.ones(N_PARAM, np.float32))
    assert kv.bytes_l1 == N_MACHINES * DEVS_PER_MACHINE * N_PARAM * 4
    assert kv.bytes_l2 == N_MACHINES * N_PARAM * 4
    assert kv.bytes_l1 // kv.bytes_l2 == DEVS_PER_MACHINE


@pytest.mark.mesh
def test_hlo_matches_analytic_ratio():
    """The compiled hierarchical schedule's cross-pod all-reduce carries
    1/devices_per_machine of the flat schedule's bytes — the same factor
    the analytic counters predict."""
    out = run_sub(f"""
    import jax, jax.numpy as jnp
    from repro.dist.collectives import gradient_sync
    from repro.launch.dryrun import collective_bytes
    mesh = jax.make_mesh(({N_MACHINES}, {DEVS_PER_MACHINE}, 2),
                         ("pod", "data", "model"))
    W = {N_MACHINES * DEVS_PER_MACHINE}
    g = {{"w": jnp.zeros((W, {N_PARAM}), jnp.float32)}}
    with jax.set_mesh(mesh):
        coll = {{}}
        for mode in ("flat", "hierarchical"):
            txt = jax.jit(
                lambda x, mode=mode: gradient_sync(mesh, x, mode=mode)
            ).lower(g).compile().as_text()
            coll[mode] = collective_bytes(txt)
    flat_ar = coll["flat"]["raw"]["all-reduce"]
    hier_ar = coll["hierarchical"]["raw"]["all-reduce"]
    assert flat_ar == {N_PARAM} * 4, coll["flat"]
    assert hier_ar == {N_PARAM} * 4 // {DEVS_PER_MACHINE}, coll["hierarchical"]
    # the level-1 reduction traffic moved off the pod boundary onto
    # intra-pod collectives, which must therefore be present
    assert coll["hierarchical"]["counts"]["all-to-all"] >= 1
    assert coll["hierarchical"]["counts"]["all-gather"] >= 1
    print("RATIO", flat_ar // hier_ar)
    """)
    # HLO factor == analytic factor == devices-per-machine
    assert f"RATIO {DEVS_PER_MACHINE}" in out
