"""Pipeline parallelism (dist/pipeline.py, DESIGN.md §10): the 1F1B
schedule's bubble/byte models, stage-boundary permute correctness under
multi-stage meshes (fwd + bwd), pp x dp composition parity against pure
data parallelism, and the TrainConfig/flag validation surface.

Multi-device behaviour needs --xla_force_host_platform_device_count set
before jax initializes, so mesh tests run their bodies in a subprocess
(the ISSUE-5 acceptance harness: 4 stages, fwd+bwd).
"""
import jax
import pytest

from mesh_subproc import run_sub


# ---------------------------------------------------------------------------
# in-process: schedule math and validation (no devices needed)

def simulate_schedule(n_stages: int, microbatches: int):
    """Tick-by-tick fill–drain simulation: stage s runs microbatch t - s.

    Returns (active stage-ticks, total stage-ticks) — the oracle for
    ``pipeline_bubble_fraction``."""
    ticks = microbatches + n_stages - 1
    active = total = 0
    for t in range(ticks):
        for s in range(n_stages):
            total += 1
            if 0 <= t - s < microbatches:
                active += 1
    return active, total


def test_bubble_fraction_matches_simulated_schedule():
    from repro.dist.pipeline import pipeline_bubble_fraction
    for pp in (1, 2, 4, 8):
        for M in (1, 2, 4, 12, 32):
            active, total = simulate_schedule(pp, M)
            assert active == pp * M
            frac = pipeline_bubble_fraction(pp, M)
            assert abs(frac - (1 - active / total)) < 1e-12, (pp, M)


def test_permute_byte_model():
    from repro.dist.pipeline import pipeline_permute_bytes
    m = pipeline_permute_bytes(2, 64, 128, n_stages=4, microbatches=8,
                               itemsize=2)
    # fwd: M + pp - 2 = 10 hops of one (b, S, D) microbatch activation
    assert m["fwd_permutes"] == 10
    assert m["fwd_total"] == 10 * 2 * 64 * 128 * 2
    # reverse schedule permutes the activation cotangent the same count
    assert m["grad_total"] == 2 * m["fwd_total"]
    one = pipeline_permute_bytes(2, 64, 128, n_stages=1, microbatches=8)
    assert one["fwd_total"] == one["grad_total"] == 0


def test_trainconfig_validation_errors():
    """Indivisible layer / microbatch counts and seq_shard composition are
    refused with clear errors at Trainer construction."""
    from repro.configs import get_config
    from repro.models import reduced
    from repro.perf_flags import reset_flags, set_flags
    from repro.train import TrainConfig, Trainer
    cfg = reduced(get_config("qwen1.5-0.5b"))     # n_super == 2
    try:
        with pytest.raises(ValueError, match="stage groups"):
            Trainer(cfg, TrainConfig(pp_stages=3, microbatches=4))
        with pytest.raises(ValueError, match="microbatches"):
            Trainer(cfg, TrainConfig(pp_stages=2, microbatches=0))
        with pytest.raises(ValueError, match="pp_stages"):
            Trainer(cfg, TrainConfig(pp_stages=0, microbatches=2))
        set_flags(seq_shard=True)
        with pytest.raises(ValueError, match="seq_shard"):
            Trainer(cfg, TrainConfig(pp_stages=2, microbatches=4))
    finally:
        reset_flags()
    # a valid config installs the flags for the model path
    from repro.perf_flags import FLAGS
    try:
        Trainer(cfg, TrainConfig(pp_stages=2, microbatches=4))
        assert (FLAGS.pp_stages, FLAGS.microbatches) == (2, 4)
    finally:
        reset_flags()


def test_batch_divisibility_refused():
    from repro.dist.pipeline import pipeline_stack, validate_pipeline
    import jax.numpy as jnp
    w = jnp.zeros((2, 4, 4))
    x = jnp.zeros((6, 3, 4))
    with pytest.raises(ValueError, match="not divisible"):
        pipeline_stack(lambda p, h: (h, {}), w, x, microbatches=4)
    # a per-microbatch batch the data axes do not divide must be refused:
    # inside the fully-manual stage region a dropped data axis would
    # silently scale block grads by n_data (DESIGN.md §10)
    with pytest.raises(ValueError, match="data-axis"):
        validate_pipeline(n_stages=2, microbatches=8, batch=32, n_data=8)
    validate_pipeline(n_stages=2, microbatches=4, batch=32, n_data=8)


def test_stage_pspecs_and_worker_axes():
    """Blocks leaves get the stage axis on their scan dim; gradient-sync
    worker axes never include "stage" (buckets reduce over data/pod only
    — DESIGN.md §10)."""
    from jax.sharding import PartitionSpec as P
    from repro.dist import worker_axes
    from repro.dist.pipeline import stage_pspecs
    mesh = jax.make_mesh((1, 1), ("stage", "data"))
    params = {"embed": jax.ShapeDtypeStruct((512, 64), "float32"),
              "blocks": {"p0": {"wq": jax.ShapeDtypeStruct((4, 64, 8, 16),
                                                           "float32")}}}
    specs = stage_pspecs(None, params, mesh)
    assert specs["blocks"]["p0"]["wq"][0] == "stage"
    assert specs["embed"] == P(None, "data")      # vocab % 1 == 0 -> kept
    assert worker_axes(mesh) == ("data",)


def test_trainer_overlap_composes_with_pipeline_fallback():
    """overlap=True under pp taps only the non-block params (block grads
    are stage-sharded; DESIGN.md §10) — one fit step must run and train
    on the sequential no-mesh fallback."""
    from repro.configs import get_config
    from repro.data import SyntheticLM
    from repro.models import reduced
    from repro.perf_flags import reset_flags
    from repro.train import TrainConfig, Trainer
    cfg = reduced(get_config("qwen1.5-0.5b"), vocab=64, d_model=64,
                  d_ff=128, n_heads=2, head_dim=32)
    try:
        tr = Trainer(cfg, TrainConfig(total_steps=2, overlap=True,
                                      pp_stages=2, microbatches=2,
                                      log_every=1))
        data = SyntheticLM(cfg.vocab, 16, 4, n_batches=2)
        tr.fit(iter(data))
        assert len(tr.history) == 2
        assert all(m["loss"] == m["loss"] for m in tr.history)  # no NaN
    finally:
        reset_flags()


def test_pipeline_rejects_enc_dec():
    from repro.configs import get_config
    from repro.models import get_model, reduced
    from repro.perf_flags import reset_flags, set_flags
    cfg = reduced(get_config("whisper-base"))
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = m.make_batch(jax.random.PRNGKey(1), "train", 2, 32)
    set_flags(pp_stages=2, microbatches=2)
    try:
        with pytest.raises(ValueError, match="enc-dec"):
            m.loss(params, batch)
    finally:
        reset_flags()


# ---------------------------------------------------------------------------
# mesh subprocess tests (>= 4 stages; ISSUE-5 acceptance harness)

@pytest.mark.mesh
def test_pipeline_stack_4_stages_fwd_bwd():
    """Stage-boundary permute correctness: a 4-stage pipeline of a toy
    stacked layer matches the sequential no-mesh oracle, forward and
    backward (params, input grads, aux)."""
    out = run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.dist.pipeline import pipeline_stack

    def stage_fn(w, x):
        def body(carry, wi):
            x, lb = carry
            return (jnp.tanh(x @ wi),
                    lb + jnp.sum(wi ** 2).astype(jnp.float32)), None
        (x, lb), _ = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), w)
        return x, {"lb": lb}

    B, S, D, NS, M = 8, 16, 32, 4, 4
    w = jax.random.normal(jax.random.PRNGKey(0), (NS, D, D)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D))
    dyw = jax.random.normal(jax.random.PRNGKey(2), (B, S, D))

    y0, aux0 = pipeline_stack(stage_fn, w, x, microbatches=M)
    def loss(w, x):
        y, aux = pipeline_stack(stage_fn, w, x, microbatches=M)
        return (y * dyw).sum() + 0.5 * aux["lb"]
    g0w, g0x = jax.grad(loss, argnums=(0, 1))(w, x)

    mesh = jax.make_mesh((4,), ("stage",))
    with jax.set_mesh(mesh):
        y1, aux1 = jax.jit(
            lambda w, x: pipeline_stack(stage_fn, w, x, microbatches=M)
        )(w, x)
        g1w, g1x = jax.jit(jax.grad(loss, argnums=(0, 1)))(w, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(aux1["lb"]), float(aux0["lb"]),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(g1w), np.asarray(g0w),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(g1x), np.asarray(g0x),
                               rtol=1e-4, atol=1e-5)
    # a stack the 4-way stage axis does not divide must be refused
    bad = jax.random.normal(jax.random.PRNGKey(3), (6, D, D))
    with jax.set_mesh(mesh):
        try:
            pipeline_stack(stage_fn, bad, x, microbatches=M)
        except ValueError as e:
            assert "stage groups" in str(e), e
            print("DIVISIBILITY_OK")
    print("PIPE_MESH_OK")
    """, devices=4)
    assert "PIPE_MESH_OK" in out
    assert "DIVISIBILITY_OK" in out


@pytest.mark.mesh
def test_pipeline_moe_arch_runs_on_stage_mesh():
    """MoE under pp: the grouped-dispatch shard_map must degrade to its
    local body inside the fully-manual stage region (the batch axes are
    already per-device there) — loss and grads run and stay finite.
    Exact MoE parity is not expected: capacity is per microbatch."""
    out = run_sub("""
    import jax, numpy as np
    from repro.configs import get_config
    from repro.models import get_model, reduced
    from repro.perf_flags import reset_flags, set_flags

    cfg = reduced(get_config("dbrx-132b"))        # MoE, n_super == 2
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = m.make_batch(jax.random.PRNGKey(1), "train", 4, 32)
    loss_fn = lambda p: m.loss(p, batch)[0]
    loss0 = float(loss_fn(params))

    mesh = jax.make_mesh((2, 2), ("stage", "data"))
    set_flags(pp_stages=2, microbatches=2)
    try:
        with jax.set_mesh(mesh):
            loss1 = float(jax.jit(loss_fn)(params))
            g1 = jax.jit(jax.grad(loss_fn))(params)
    finally:
        reset_flags()
    assert np.isfinite(loss1), loss1
    # CE dominates and is batch-separable; only the aux terms may drift
    assert abs(loss1 - loss0) < 0.1, (loss0, loss1)
    assert all(np.isfinite(np.asarray(g)).all()
               for g in jax.tree.leaves(g1))
    print("PP_MOE_OK")
    """, devices=4)
    assert "PP_MOE_OK" in out


@pytest.mark.mesh
def test_pipeline_model_pp_x_dp_matches_data_parallel():
    """Composition (ISSUE-5): a reduced dense model trained on a
    (2, 2) stage x data mesh (pp=2, M=2) produces the same loss and
    parameter grads as pure 1x4 data parallelism and as the no-mesh
    baseline."""
    out = run_sub("""
    import jax, numpy as np
    from repro.configs import get_config
    from repro.models import get_model, reduced
    from repro.perf_flags import reset_flags, set_flags

    cfg = reduced(get_config("qwen1.5-0.5b"))     # n_super == 2
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = m.make_batch(jax.random.PRNGKey(1), "train", 4, 32)
    loss_fn = lambda p: m.loss(p, batch)[0]
    loss0 = float(loss_fn(params))
    g0 = jax.tree.leaves(jax.grad(loss_fn)(params))

    # pure data parallelism (1 x 4)
    mesh_dp = jax.make_mesh((4,), ("data",))
    with jax.set_mesh(mesh_dp):
        loss_dp = float(jax.jit(loss_fn)(params))
        g_dp = jax.tree.leaves(jax.jit(jax.grad(loss_fn))(params))

    # pipeline x data (2 x 2)
    mesh_pp = jax.make_mesh((2, 2), ("stage", "data"))
    set_flags(pp_stages=2, microbatches=2)
    try:
        with jax.set_mesh(mesh_pp):
            loss_pp = float(jax.jit(loss_fn)(params))
            g_pp = jax.tree.leaves(jax.jit(jax.grad(loss_fn))(params))
    finally:
        reset_flags()

    for name, l, g in (("dp", loss_dp, g_dp), ("pp", loss_pp, g_pp)):
        assert abs(l - loss0) < 1e-5, (name, l, loss0)
        mx = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
                 for a, b in zip(g0, g))
        assert mx < 1e-5, (name, mx)
        print(name, "maxdiff", mx)
    mx = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
             for a, b in zip(g_dp, g_pp))
    assert mx < 1e-5, mx
    print("PP_X_DP_OK")
    """, devices=4)
    assert "PP_X_DP_OK" in out
