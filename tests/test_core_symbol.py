"""Symbol API (MXNet §2.1): composition, shape inference, save/load, eval."""
import numpy as np
import pytest

from repro.core import (Activation, FullyConnected, SoftmaxOutput, Symbol,
                        Variable, chain, reset_default_engine)


@pytest.fixture(autouse=True)
def fresh_engine():
    reset_default_engine()


def make_mlp():
    data, label = Variable("data"), Variable("label")
    return chain(data,
                 lambda x: FullyConnected(x, 64, name="fc1"),
                 lambda x: Activation(x, "relu"),
                 lambda x: FullyConnected(x, 10, name="fc2"),
                 lambda x: SoftmaxOutput(x, label))


def test_list_arguments_order():
    mlp = make_mlp()
    args = mlp.list_arguments()
    assert args == ["data", "fc1_weight", "fc1_bias", "fc2_weight", "fc2_bias",
                    "label"]


def test_infer_shape():
    mlp = make_mlp()
    shapes = mlp.infer_shape(data=(8, 32), label=(8,), fc1_weight=(64, 32),
                             fc1_bias=(64,), fc2_weight=(10, 64), fc2_bias=(10,))
    assert shapes == [(), (8, 10)]  # loss scalar + probs


def test_multi_output_select():
    mlp = make_mlp()
    assert len(mlp) == 2
    probs = mlp[1]
    assert probs.infer_shape(data=(4, 32), label=(4,), fc1_weight=(64, 32),
                             fc1_bias=(64,), fc2_weight=(10, 64),
                             fc2_bias=(10,)) == [(4, 10)]


def test_save_load_roundtrip(tmp_path):
    mlp = make_mlp()
    p = tmp_path / "mlp.json"
    mlp.save(str(p))
    again = Symbol.load(str(p))
    assert again.list_arguments() == mlp.list_arguments()
    kw = dict(data=(8, 32), label=(8,), fc1_weight=(64, 32), fc1_bias=(64,),
              fc2_weight=(10, 64), fc2_bias=(10,))
    assert again.infer_shape(**kw) == mlp.infer_shape(**kw)


def test_operator_sugar_eval():
    a, b = Variable("a"), Variable("b")
    expr = (a * b + 1.0) / 2.0 - a
    va = np.arange(6, dtype=np.float32).reshape(2, 3)
    vb = np.ones((2, 3), np.float32) * 3
    out = expr.eval(a=va, b=vb)[0]
    np.testing.assert_allclose(np.asarray(out), (va * vb + 1) / 2 - va, rtol=1e-6)


def test_matmul_sugar():
    a, b = Variable("a"), Variable("b")
    va = np.random.RandomState(0).randn(3, 4).astype(np.float32)
    vb = np.random.RandomState(1).randn(4, 5).astype(np.float32)
    out = (a @ b).eval(a=va, b=vb)[0]
    np.testing.assert_allclose(np.asarray(out), va @ vb, rtol=1e-5)


def test_memory_estimate_smaller_for_prediction():
    mlp = make_mlp()
    kw = dict(data=(64, 32), label=(64,), fc1_weight=(64, 32), fc1_bias=(64,),
              fc2_weight=(10, 64), fc2_bias=(10,))
    est_both = mlp[0].memory_estimate(strategy="both", **kw)
    est_naive = mlp[0].memory_estimate(strategy="naive", **kw)
    assert est_both["internal_bytes"] <= est_naive["internal_bytes"]


def test_missing_shape_raises():
    mlp = make_mlp()
    with pytest.raises(ValueError, match="missing shape"):
        mlp.infer_shape(data=(8, 32))
