"""Perf-variant flags must preserve numerics (the §Perf hillclimb
optimizations are only admissible if bit-compatible within tolerance)."""
import jax
import numpy as np
import pytest

from repro import perf_flags
from repro.configs import get_config
from repro.models import get_model, reduced
from repro.models.layers import gqa_attention

KEY = jax.random.PRNGKey(11)


@pytest.fixture(autouse=True)
def clean_flags():
    perf_flags.reset_flags()
    yield
    perf_flags.reset_flags()


def test_window_slice_matches_baseline():
    B, S, H, K, hd, W = 1, 4096, 4, 2, 32, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    base = gqa_attention(q, k, v, causal=True, window=W)
    perf_flags.set_flags(window_slice=True)
    fast = gqa_attention(q, k, v, causal=True, window=W)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(base),
                               rtol=1e-5, atol=1e-6)


def test_ce_chunks_invariant():
    cfg = reduced(get_config("qwen1.5-0.5b"), vocab=128)
    m = get_model(cfg)
    params = m.init(KEY)
    batch = m.make_batch(KEY, "train", 2, 33)
    l16, _ = m.loss(params, batch)
    perf_flags.set_flags(ce_chunks=4)
    l4, _ = m.loss(params, batch)
    perf_flags.set_flags(ce_chunks=1)
    l1, _ = m.loss(params, batch)
    np.testing.assert_allclose(float(l16), float(l4), rtol=1e-5)
    np.testing.assert_allclose(float(l16), float(l1), rtol=1e-5)


def test_attn_q_chunk_invariant():
    B, S, H, K, hd = 1, 2048, 2, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    perf_flags.set_flags(attn_q_chunk=4096)   # single block
    one = gqa_attention(q, k, v, causal=True)
    perf_flags.set_flags(attn_q_chunk=256)    # 8 chunks
    many = gqa_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(many), np.asarray(one),
                               rtol=1e-5, atol=1e-6)
