"""Substrate tests: recordio, prefetch pipeline, optimizers, trainer,
checkpointing, serving engine."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.data import (DataIterator, PrefetchIterator, RecordReader,
                        SyntheticLM, pack_records)
from repro.models import get_model, reduced
from repro.optim import adam, sgd, sgd_momentum
from repro.train import TrainConfig, Trainer, load_checkpoint, save_checkpoint
from repro.serve import ServeEngine


# ---------------------------------------------------------------------------
# recordio

def test_recordio_roundtrip_sequential_and_random(tmp_path):
    path = str(tmp_path / "data.rec")
    payloads = [bytes([i]) * (i + 1) for i in range(50)]
    assert pack_records(path, payloads) == 50
    r = RecordReader(path)
    assert len(r) == 50
    assert list(r) == payloads                       # sequential
    for i in (0, 17, 49, 3):                         # random seek
        assert r.read(i) == payloads[i]


def test_recordio_crc_detects_corruption(tmp_path):
    path = str(tmp_path / "data.rec")
    pack_records(path, [b"hello world"])
    raw = bytearray(open(path, "rb").read())
    raw[-1] ^= 0xFF
    open(path, "wb").write(bytes(raw))
    r = RecordReader(path)
    with pytest.raises(IOError, match="crc"):
        r.read(0)


def test_data_iterator_batches_and_shuffles(tmp_path):
    path = str(tmp_path / "d.rec")
    pack_records(path, [np.int32(i).tobytes() for i in range(32)])
    r = RecordReader(path)
    it = DataIterator(r, batch=8,
                      decode_fn=lambda b: np.frombuffer(b, np.int32),
                      shuffle=True, seed=1)
    batches = list(it)
    assert len(batches) == 4 and batches[0].shape == (8, 1)
    seen = sorted(int(x) for b in batches for x in b.ravel())
    assert seen == list(range(32))


def test_prefetch_iterator_preserves_items():
    src = [{"x": np.full((2,), i)} for i in range(20)]
    out = list(PrefetchIterator(src, depth=3, num_threads=2))
    got = sorted(int(d["x"][0]) for d in out)
    assert got == list(range(20))


# ---------------------------------------------------------------------------
# optimizers

def _quad_problem():
    w = jnp.asarray([3.0, -2.0])

    def loss(p):
        return jnp.sum((p - w) ** 2)
    return w, loss


@pytest.mark.parametrize("opt", [sgd(lr=0.1), sgd_momentum(lr=0.05),
                                 adam(lr=0.3)])
def test_optimizers_converge_quadratic(opt):
    w, loss = _quad_problem()
    p = jnp.zeros(2)
    state = opt.init(p)
    for _ in range(100):
        g = jax.grad(loss)(p)
        p, state = opt.update(g, state, p)
    assert float(loss(p)) < 1e-3


def test_sgd_momentum_pallas_matches_plain():
    p = jnp.ones((37,)) * 2
    g = jnp.linspace(-1, 1, 37)
    plain = sgd_momentum(lr=0.1, use_pallas=False)
    fused = sgd_momentum(lr=0.1, use_pallas=True)
    sp, sf = plain.init(p), fused.init(p)
    pp, pf = p, p
    for _ in range(3):
        pp, sp = plain.update(g, sp, pp)
        pf, sf = fused.update(g, sf, pf)
    np.testing.assert_allclose(np.asarray(pp), np.asarray(pf), rtol=1e-5)


# ---------------------------------------------------------------------------
# trainer end-to-end (tiny model, synthetic structured data)

def test_trainer_loss_decreases():
    cfg = reduced(get_config("qwen1.5-0.5b"), vocab=64, n_layers=2,
                  d_model=128, d_ff=256)
    tcfg = TrainConfig(lr=2e-2, total_steps=60, log_every=100,
                       warmup_steps=5, grad_clip=5.0)
    tr = Trainer(cfg, tcfg)
    data = SyntheticLM(vocab=64, seq_len=64, batch=8, seed=0)
    tr.fit(iter(data))
    first, last = tr.history[0]["loss"], tr.history[-1]["loss"]
    assert last < first - 0.3, (first, last)


def test_trainer_kvstore_matches_singleworker_direction():
    from repro.core import KVStoreDist
    cfg = reduced(get_config("qwen1.5-0.5b"), vocab=32, n_layers=2,
                  d_model=64, d_ff=128)
    tcfg = TrainConfig(lr=5e-3, total_steps=10, log_every=100)
    tr = Trainer(cfg, tcfg)
    data = list(SyntheticLM(vocab=32, seq_len=32, batch=8, seed=0,
                            n_batches=10))
    kv = KVStoreDist(n_machines=2, devices_per_machine=2,
                     consistency="sequential")
    losses = tr.fit_kvstore(iter(data), kv, n_workers=4)
    assert losses[-1] < losses[0], losses
    assert kv.bytes_l2 * 2 == kv.bytes_l1  # two-level aggregation held


# ---------------------------------------------------------------------------
# checkpoint

def test_checkpoint_roundtrip(tmp_path):
    cfg = reduced(get_config("mamba2-130m"), n_layers=2)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path / "ck"), {"params": params}, step=7)
    zeros = jax.tree.map(lambda x: jnp.zeros_like(x), {"params": params})
    restored, step = load_checkpoint(str(tmp_path / "ck"), zeros)
    assert step == 7
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(
            {"params": params})):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# serving

def test_serve_engine_greedy_batch():
    cfg = reduced(get_config("qwen1.5-0.5b"), vocab=64, n_layers=2,
                  d_model=128, d_ff=256)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=64)
    toks, stats = eng.generate([[1, 2, 3], [4, 5, 6, 7]], max_new_tokens=8)
    assert toks.shape == (2, 8)
    assert toks.dtype in (np.int32, np.int64)
    # first tokens are prefill-derived, so TIMED decode produced B*(N-1)
    # (the paged-engine accounting ServeStats documents)
    assert stats.tokens_out == 2 * 7
    # greedy decode must be deterministic
    toks2, _ = eng.generate([[1, 2, 3], [4, 5, 6, 7]], max_new_tokens=8)
    np.testing.assert_array_equal(toks, toks2)
