"""Overload-robust serving lifecycle (ISSUE 9 / DESIGN.md §14):
preempt -> swap -> restore token parity, cancellation/timeout resource
reclamation, shedding admission, optimistic-admission progress, typed
engine errors, and the swap-pool byte model."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.memplan import swap_pool_bytes
from repro.models import get_model, reduced
from repro.serve import PagedServeEngine, ServeEngine, ServeError, Status
from repro.serve.engine import (REJECT_EVICTED, REJECT_PROMPT_TOO_LONG,
                                REJECT_QUEUE_FULL)

from hypothesis_compat import given, settings, st

KEY = jax.random.PRNGKey(0)


def _setup(arch):
    cfg = reduced(get_config(arch))
    params = get_model(cfg).init(KEY)
    return cfg, params


def _prompts(cfg, lengths, seed=1):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(1, cfg.vocab, L)) for L in lengths]


def _drain(eng, stats=None, max_steps=5000):
    return eng.run(stats, max_steps=max_steps)


# ---------------------------------------------------------------------------
# preempt -> swap -> restore parity


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "gemma2-2b",
                                  "mamba2-130m"])
def test_preempt_swap_restore_token_parity(arch):
    """A request preempted mid-decode (KV blocks + SSM slot state swapped
    to host) and later restored must emit bit-identical greedy tokens to
    an uninterrupted run — the acceptance bar for swap being a true
    bit-exact round-trip.  Covers GQA (qwen), sliding-window + softcap
    (gemma2) and the SSM recurrent state (mamba2)."""
    cfg, params = _setup(arch)
    prompts = _prompts(cfg, (9, 6))
    ref = PagedServeEngine(cfg, params, block_size=4, max_batch=2,
                           max_len=40, prefill_chunk=8)
    want, _ = ref.generate(prompts, max_new_tokens=8, warmup=False)

    eng = PagedServeEngine(cfg, params, block_size=4, max_batch=2,
                           max_len=40, prefill_chunk=8, swap_blocks=16)
    t0 = eng.add_request(prompts[0], 8)
    t1 = eng.add_request(prompts[1], 8)
    # decode a few tokens, then forcibly evict request 0 mid-stream
    for _ in range(50):
        eng.step()
        req0 = next((r for r in eng.slots if r and r.rid == t0.rid), None)
        if req0 is not None and len(req0.out) >= 3:
            break
    assert eng.preempt(t0.rid)
    assert t0.rid in eng.swap                  # swap path, not recompute
    _drain(eng)
    assert eng.results[t0.rid].status is Status.OK
    assert eng.results[t0.rid].preemptions >= 1
    assert eng.results[t1.rid].status is Status.OK
    assert eng.completed[t0.rid] == want[0]
    assert eng.completed[t1.rid] == want[1]
    assert eng.alloc.in_use == 0 and len(eng.swap) == 0


def test_preempt_recompute_restore_token_parity():
    """With no swap pool the engine falls back to recompute-preemption
    (drop the blocks, re-prefill prompt + emitted tokens on restore);
    greedy tokens must still match the uninterrupted run."""
    cfg, params = _setup("qwen1.5-0.5b")
    prompts = _prompts(cfg, (9, 6))
    ref = PagedServeEngine(cfg, params, block_size=4, max_batch=2,
                           max_len=40, prefill_chunk=8)
    want, _ = ref.generate(prompts, max_new_tokens=8, warmup=False)

    eng = PagedServeEngine(cfg, params, block_size=4, max_batch=2,
                           max_len=40, prefill_chunk=8, swap_blocks=0)
    t0 = eng.add_request(prompts[0], 8)
    t1 = eng.add_request(prompts[1], 8)
    for _ in range(50):
        eng.step()
        req0 = next((r for r in eng.slots if r and r.rid == t0.rid), None)
        if req0 is not None and len(req0.out) >= 3:
            break
    assert eng.preempt(t0.rid)
    assert t0.rid not in eng.swap              # recompute path
    _drain(eng)
    assert eng.results[t0.rid].status is Status.OK
    assert eng.completed[t0.rid] == want[0]
    assert eng.completed[t1.rid] == want[1]
    assert eng.alloc.in_use == 0


def test_optimistic_admission_preempts_under_pressure():
    """An undersized pool under optimistic admission: worst-case demand
    exceeds the blocks, so lanes preempt each other — but every request
    still finishes OK with correct greedy tokens, and the pool drains."""
    cfg, params = _setup("qwen1.5-0.5b")
    prompts = _prompts(cfg, (8, 8, 8))
    ref = PagedServeEngine(cfg, params, block_size=4, max_batch=3,
                           max_len=32, prefill_chunk=8)
    want, _ = ref.generate(prompts, max_new_tokens=10, warmup=False)

    # 3 requests x ceil(18/4)=5 worst-case pages = 15 > 8 usable blocks
    eng = PagedServeEngine(cfg, params, block_size=4, max_batch=3,
                           max_len=32, prefill_chunk=8, num_blocks=9,
                           admission="optimistic", swap_blocks=16)
    outs, stats = eng.generate(prompts, max_new_tokens=10, warmup=False)
    assert stats.preempted > 0 and stats.restored > 0
    for i, t in enumerate(want):
        assert outs[i] == t, f"request {i}"
    assert all(r.status is Status.OK for r in eng.results.values())
    assert eng.alloc.in_use == 0 and len(eng.swap) == 0


# ---------------------------------------------------------------------------
# cancellation / deadlines reclaim resources


def test_cancel_frees_blocks_and_slot():
    cfg, params = _setup("qwen1.5-0.5b")
    eng = PagedServeEngine(cfg, params, block_size=4, max_batch=2,
                           max_len=32, prefill_chunk=8)
    t_run = eng.add_request(_prompts(cfg, (8,))[0], 20)
    t_queued = eng.add_request(_prompts(cfg, (6,))[0], 20)
    t_queued2 = eng.add_request(_prompts(cfg, (6,))[0], 4)
    eng.step()                                 # t_run admitted + prefilling
    assert eng.alloc.in_use > 0
    assert eng.cancel(t_run.rid)               # cancel while running
    assert eng.results[t_run.rid].status is Status.CANCELLED
    assert eng.cancel(t_queued.rid)            # cancel in queue
    assert eng.results[t_queued.rid].status is Status.CANCELLED
    assert not eng.cancel(t_queued.rid)        # already terminal
    assert not eng.cancel(10_000)              # unknown rid
    _drain(eng)
    assert eng.results[t_queued2.rid].status is Status.OK
    assert eng.alloc.in_use == 0               # no leaked blocks
    assert all(r is None for r in eng.slots)


def test_cancel_while_preempted_drops_swap_entry():
    cfg, params = _setup("qwen1.5-0.5b")
    eng = PagedServeEngine(cfg, params, block_size=4, max_batch=1,
                           max_len=32, prefill_chunk=8, swap_blocks=8)
    t = eng.add_request(_prompts(cfg, (8,))[0], 10)
    for _ in range(5):
        eng.step()
    assert eng.preempt(t.rid) and t.rid in eng.swap
    assert eng.cancel(t.rid)
    assert t.rid not in eng.swap and len(eng.swap) == 0
    assert eng.results[t.rid].status is Status.CANCELLED
    assert eng.alloc.in_use == 0 and not eng.busy


def test_deadline_timeout_reclaims_and_records_miss():
    cfg, params = _setup("qwen1.5-0.5b")
    eng = PagedServeEngine(cfg, params, block_size=4, max_batch=2,
                           max_len=64, prefill_chunk=8)
    # a deadline that cannot be met: expires while running or queued
    t_doomed = eng.add_request(_prompts(cfg, (8,))[0], 40, deadline_ms=0.01)
    t_fine = eng.add_request(_prompts(cfg, (6,))[0], 4)
    stats = _drain(eng)
    res = eng.results[t_doomed.rid]
    assert res.status is Status.TIMEOUT
    assert res.deadline_miss_s is not None and res.deadline_miss_s > 0
    assert stats.timeouts == 1
    assert eng.results[t_fine.rid].status is Status.OK
    assert eng.alloc.in_use == 0 and not eng.busy


# ---------------------------------------------------------------------------
# shedding admission


def test_queue_full_rejects_newest_with_retry_hint():
    cfg, params = _setup("qwen1.5-0.5b")
    eng = PagedServeEngine(cfg, params, block_size=4, max_batch=1,
                           max_len=32, max_queue=2)
    p = _prompts(cfg, (4,))[0]
    assert eng.add_request(p, 2).accepted
    assert eng.add_request(p, 2).accepted
    t = eng.add_request(p, 2)
    assert not t.accepted and t.reason == REJECT_QUEUE_FULL
    assert t.retry_after_s is not None and t.retry_after_s > 0
    assert eng.results[t.rid].status is Status.SHED
    _drain(eng)                                # survivors still complete
    assert len([r for r in eng.results.values()
                if r.status is Status.OK]) == 2


def test_queue_full_evict_lowest_respects_priority():
    cfg, params = _setup("qwen1.5-0.5b")
    eng = PagedServeEngine(cfg, params, block_size=4, max_batch=1,
                           max_len=32, max_queue=2,
                           shed_policy="evict_lowest")
    p = _prompts(cfg, (4,))[0]
    t_low = eng.add_request(p, 2, priority=0)
    eng.add_request(p, 2, priority=5)
    t_high = eng.add_request(p, 2, priority=9)     # evicts t_low
    assert t_high.accepted
    assert eng.results[t_low.rid].status is Status.SHED
    assert eng.results[t_low.rid].reason == REJECT_EVICTED
    t_lower = eng.add_request(p, 2, priority=-1)   # nothing below it
    assert not t_lower.accepted and t_lower.reason == REJECT_QUEUE_FULL


def test_add_request_never_raises_on_overload():
    """The admission loop survives any mix of unservable requests."""
    cfg, params = _setup("qwen1.5-0.5b")
    eng = PagedServeEngine(cfg, params, block_size=4, max_batch=1,
                           max_len=16, max_queue=1)
    tickets = [eng.add_request([1] * n, b)
               for n, b in [(30, 1), (4, 40), (4, 2), (4, 2), (4, 2)]]
    assert [t.accepted for t in tickets] == [False, False, True, False,
                                             False]
    assert tickets[0].reason == REJECT_PROMPT_TOO_LONG
    assert tickets[3].reason == REJECT_QUEUE_FULL
    _drain(eng)
    assert {r.status for r in eng.results.values()} == {Status.OK,
                                                        Status.SHED}


# ---------------------------------------------------------------------------
# progress / typed engine errors


def _random_overload_run(seed: int):
    cfg, params = _setup("qwen1.5-0.5b")
    rng = np.random.RandomState(seed)
    eng = PagedServeEngine(cfg, params, block_size=4, max_batch=3,
                           max_len=32, prefill_chunk=8, num_blocks=10,
                           admission="optimistic",
                           swap_blocks=int(rng.randint(0, 12)),
                           victim_policy=["lowest_priority", "most_blocks",
                                          "lifo"][seed % 3],
                           max_queue=6, shed_policy="reject_newest")
    tickets = []
    for _ in range(int(rng.randint(4, 9))):
        prompt = list(rng.randint(1, cfg.vocab, rng.randint(2, 14)))
        tickets.append(eng.add_request(
            prompt, int(rng.randint(1, 12)),
            priority=int(rng.randint(0, 3))))
        if rng.rand() < 0.2 and tickets[-1].accepted:
            eng.cancel(tickets[-1].rid)
        eng.step()
    eng.run(max_steps=2000)                    # ServeError if ever stuck
    assert not eng.busy
    assert eng.alloc.in_use == 0 and len(eng.swap) == 0
    for t in tickets:                          # every request is terminal
        assert t.rid in eng.results
        assert eng.results[t.rid].status in (Status.OK, Status.SHED,
                                             Status.CANCELLED)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_optimistic_admission_never_deadlocks(seed):
    """Randomized overload workloads (mixed priorities, cancels, tiny
    pool, all victim policies) always drain: the strict precedence order
    guarantees the highest-precedence live request can always grow."""
    _random_overload_run(seed)


@given(st.integers(min_value=0, max_value=10_000))
@settings(max_examples=10, deadline=None)
def test_optimistic_admission_never_deadlocks_property(seed):
    """Property form of the drain guarantee (skips if hypothesis is not
    installed; the seeded test above always runs)."""
    _random_overload_run(int(seed) % 1000)


def test_serve_error_names_stuck_requests():
    cfg, params = _setup("qwen1.5-0.5b")
    eng = PagedServeEngine(cfg, params, block_size=4, max_batch=1,
                           max_len=64)
    t = eng.add_request(_prompts(cfg, (8,))[0], 40)
    with pytest.raises(ServeError) as ei:
        eng.run(max_steps=1)                   # cannot finish in one step
    assert t.rid in ei.value.stuck_rids
    assert ei.value.blocks_in_use > 0
    assert str(t.rid) in str(ei.value)         # actionable message


# ---------------------------------------------------------------------------
# swap-pool byte model is exact


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mamba2-130m"])
def test_swap_payload_matches_byte_model(arch):
    """The host bytes of a real swapped-out payload equal the
    ``memplan.swap_pool_bytes`` model exactly: KV rows priced at the
    device ``block_bytes`` unit plus the fixed SSM slot state."""
    cfg, params = _setup(arch)
    eng = PagedServeEngine(cfg, params, block_size=4, max_batch=1,
                           max_len=32, prefill_chunk=8, swap_blocks=16)
    t = eng.add_request(_prompts(cfg, (9,))[0], 8)
    for _ in range(4):
        eng.step()
    slot = next(s for s, r in enumerate(eng.slots) if r is not None)
    n = eng.tables.n_pages(slot)
    blocks = [int(b) for b in eng.tables.row(slot)[:n]]
    payload = eng.model.paged_swap_out(eng.cache, slot, blocks)
    got = sum(a.nbytes for a in payload.values())
    model = swap_pool_bytes(cfg, n, eng.block_size,
                            max_swapped_requests=1)
    assert got == model["total_bytes"]
    assert t.rid not in eng.swap               # peek did not mutate state
    _drain(eng)
    assert eng.results[t.rid].status is Status.OK


def test_static_engine_untouched_by_lifecycle_api():
    """The static engine keeps its simple contract (regression guard for
    the lifecycle refactor)."""
    cfg, params = _setup("qwen1.5-0.5b")
    eng = ServeEngine(cfg, params, max_len=24)
    toks, _ = eng.generate(_prompts(cfg, (5, 9)), max_new_tokens=4,
                           warmup=False)
    assert toks.shape == (2, 4)
