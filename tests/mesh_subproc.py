"""Shared harness: run a jax test body in a subprocess with a forced host
device count (--xla_force_host_platform_device_count must be set before
jax initializes, so multi-device tests cannot run in the pytest process).
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_sub(body: str, devices: int = 16) -> str:
    code = ("import os\n"
            f"os.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={devices}'\n"
            + textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)  # a stray outer value would defeat `devices`
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout
