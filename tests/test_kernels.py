"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Shape/dtype sweeps for each kernel plus hypothesis property tests for the
fused-update (the KVStore updater big-op).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_update import sgd_momentum
from repro.kernels.rmsnorm import rmsnorm

KEY = jax.random.PRNGKey(3)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention

ATTN_SHAPES = [
    # B, Sq, Sk, H, K, hd
    (2, 128, 128, 4, 2, 64),
    (1, 256, 256, 8, 8, 64),     # MHA
    (2, 64, 64, 4, 1, 128),      # MQA
    (1, 200, 200, 4, 2, 64),     # non-multiple of block
    (2, 8, 8, 2, 2, 32),         # tiny
    (1, 384, 384, 2, 2, 256),    # gemma head_dim
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(shape, dtype):
    B, Sq, Sk, H, K, hd = shape
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Sk, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, Sk, K, hd), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_window(window):
    B, S, H, K, hd = 1, 128, 4, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    out = flash_attention(q, k, v, causal=True, window=window, block_q=32,
                          block_k=32)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_softcap():
    B, S, H, K, hd = 2, 96, 4, 4, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd)) * 3
    k = jax.random.normal(ks[1], (B, S, K, hd)) * 3
    v = jax.random.normal(ks[2], (B, S, K, hd))
    out = flash_attention(q, k, v, causal=True, softcap=30.0, block_q=32,
                          block_k=32)
    want = ref.flash_attention_ref(q, k, v, causal=True, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_flash_attention_decode_offset():
    """Sq=1 with a long kv and q_offset (serving path)."""
    B, Sk, H, K, hd = 2, 300, 8, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    k = jax.random.normal(ks[1], (B, Sk, K, hd))
    v = jax.random.normal(ks[2], (B, Sk, K, hd))
    out = flash_attention(q, k, v, causal=True, q_offset=Sk - 1,
                          block_k=128)
    want = ref.flash_attention_ref(q, k, v, causal=True, q_offset=Sk - 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("window", [None, 32])
@pytest.mark.parametrize("softcap", [None, 20.0])
@pytest.mark.parametrize("group", [1, 2, 4])
def test_flash_attention_matrix(causal, window, softcap, group):
    """Full causal × sliding-window × softcap × GQA-group matrix vs the
    jnp oracle (interpret mode) — ISSUE-3 satellite coverage."""
    if window is not None and not causal:
        pytest.skip("windowed layers are causal in every config")
    B, S, K, hd = 1, 128, 2, 32
    H = K * group
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd)) * 2
    k = jax.random.normal(ks[1], (B, S, K, hd)) * 2
    v = jax.random.normal(ks[2], (B, S, K, hd))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, block_q=32, block_k=32)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# carry mode: the per-ring-step contract (DESIGN.md §8)

def test_flash_attention_carry_chain_matches_full():
    """Chaining per-chunk passes through (m, l, acc) + kv_offset equals
    one full pass — the invariant dist/ring.py is built on."""
    from repro.kernels.flash_attention import flash_carry_finalize
    B, S, H, K, hd = 2, 192, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    for kw in (dict(causal=True), dict(causal=True, window=80),
               dict(causal=True, softcap=25.0), dict(causal=False)):
        want = ref.flash_attention_ref(q, k, v, **kw)
        st = None
        for c0 in range(0, S, 64):
            st = flash_attention(q, k[:, c0:c0 + 64], v[:, c0:c0 + 64],
                                 carry=st, kv_offset=c0, return_carry=True,
                                 block_q=32, block_k=32, **kw)
        out, lse = flash_carry_finalize(st, q.dtype)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=3e-5, atol=3e-5)
        assert lse.shape == (B, S, H)
        assert np.isfinite(np.asarray(lse)).all()


def test_flash_attention_neutral_carry_is_identity():
    """Seeding with the neutral (−inf, 0, 0) state changes nothing."""
    from repro.kernels.flash_attention import (flash_carry_finalize,
                                               flash_carry_init)
    B, S, H, K, hd = 1, 64, 2, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    base = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    st = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                         carry=flash_carry_init(B, S, H, hd),
                         return_carry=True)
    out, _ = flash_carry_finalize(st, q.dtype)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               rtol=2e-6, atol=2e-6)


def test_flash_carry_lse_matches_logsumexp():
    from repro.kernels.flash_attention import flash_carry_finalize
    B, S, H, K, hd = 1, 96, 2, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    st = flash_attention(q, k, v, causal=True, return_carry=True,
                         block_q=32, block_k=32)
    _, lse = flash_carry_finalize(st)
    s = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                   jnp.repeat(k, 1, 2).astype(jnp.float32)) / np.sqrt(hd)
    s = jnp.where(jnp.tril(jnp.ones((S, S), bool))[None, None], s, -1e30)
    want = jax.scipy.special.logsumexp(s, axis=-1).transpose(0, 2, 1)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_flash_attention_kv_len_masking():
    """Padded cache: keys beyond kv_len are invisible."""
    B, S, H, K, hd = 1, 64, 2, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    out = flash_attention(q, k, v, causal=False, kv_len=40, block_q=32,
                          block_k=32)
    want = ref.flash_attention_ref(q[:, :, :, :], k[:, :40], v[:, :40],
                                   causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# paged attention (ISSUE 4, DESIGN.md §9)

def _paged_case(B, H, K, hd, bs, NB, P, lengths, seed=5):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (NB, bs, K, hd))
    vp = jax.random.normal(ks[2], (NB, bs, K, hd))
    # distinct physical blocks per (seq, page), none using the sink 0
    tables = (1 + jnp.arange(B * P, dtype=jnp.int32) % (NB - 1)).reshape(B, P)
    return q, kp, vp, tables, jnp.asarray(lengths, jnp.int32)


@pytest.mark.parametrize("H,K", [(4, 4), (4, 2), (8, 1)])  # MHA, GQA, MQA
def test_paged_attention_gqa_vs_ref(H, K):
    from repro.kernels.paged_attention import paged_attention
    q, kp, vp, tables, lengths = _paged_case(
        B=3, H=H, K=K, hd=32, bs=8, NB=16, P=4, lengths=[19, 8, 1])
    out = paged_attention(q, kp, vp, tables, lengths)
    want = ref.paged_attention_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("lengths", [[8, 16, 24, 32],    # exact boundaries
                                     [7, 9, 17, 31],     # straddling
                                     [1, 2, 33, 40]])    # edges + full
def test_paged_attention_block_boundaries(lengths):
    from repro.kernels.paged_attention import paged_attention
    q, kp, vp, tables, lengths = _paged_case(
        B=4, H=4, K=2, hd=64, bs=8, NB=24, P=5, lengths=lengths)
    out = paged_attention(q, kp, vp, tables, lengths)
    want = ref.paged_attention_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window,softcap", [(6, None), (None, 20.0),
                                            (16, 30.0)])
def test_paged_attention_window_softcap(window, softcap):
    from repro.kernels.paged_attention import paged_attention
    q, kp, vp, tables, lengths = _paged_case(
        B=2, H=4, K=2, hd=32, bs=8, NB=12, P=3, lengths=[21, 13])
    q = q * 3                                   # exercise the softcap
    out = paged_attention(q, kp, vp, tables, lengths, window=window,
                          softcap=softcap)
    want = ref.paged_attention_ref(q, kp, vp, tables, lengths,
                                   window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_paged_attention_matches_contiguous_flash():
    """A paged sequence must attend identically to the same K/V laid out
    contiguously (flash decode with q_offset) — table indirection is
    layout only."""
    B, H, K, hd, bs, P = 1, 4, 2, 32, 8, 4
    S = 27                                      # straddles 4 pages
    ks = jax.random.split(KEY, 3)
    q1 = jax.random.normal(ks[0], (B, 1, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    want = ref.flash_attention_ref(q1, k, v, causal=True, q_offset=S - 1)
    # scatter the contiguous rows into shuffled physical blocks
    order = np.asarray([3, 1, 4, 2])            # physical block per page
    kp = np.zeros((6, bs, K, hd), np.float32)
    vp = np.zeros((6, bs, K, hd), np.float32)
    for page in range(P):
        rows = np.asarray(k[0, page * bs:(page + 1) * bs])
        kp[order[page], :rows.shape[0]] = rows
        rows = np.asarray(v[0, page * bs:(page + 1) * bs])
        vp[order[page], :rows.shape[0]] = rows
    from repro.kernels.paged_attention import paged_attention
    out = paged_attention(q1[:, 0], jnp.asarray(kp), jnp.asarray(vp),
                          jnp.asarray(order[None], jnp.int32),
                          jnp.asarray([S], jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want[:, 0]),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_zero_length_lane_is_zero():
    from repro.kernels.paged_attention import paged_attention
    q, kp, vp, tables, _ = _paged_case(
        B=2, H=4, K=2, hd=32, bs=8, NB=12, P=3, lengths=[5, 0])
    out = paged_attention(q, kp, vp, tables,
                          jnp.asarray([5, 0], jnp.int32))
    assert np.abs(np.asarray(out[1])).max() == 0.0
    assert np.isfinite(np.asarray(out)).all()


# ---------------------------------------------------------------------------
# paged attention schedule tunables (DESIGN.md §13): pages_per_step /
# head_tile never change results, only the grid

@pytest.mark.parametrize("pps", [1, 2, 4, 5])
@pytest.mark.parametrize("ht", [1, 2])
def test_paged_attention_schedule_tunables(pps, ht):
    from repro.kernels.paged_attention import paged_attention
    q, kp, vp, tables, lengths = _paged_case(
        B=3, H=4, K=2, hd=32, bs=8, NB=17, P=5, lengths=[19, 33, 40])
    out = paged_attention(q, kp, vp, tables, lengths,
                          pages_per_step=pps, head_tile=ht)
    want = ref.paged_attention_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# quantized paged KV-cache (int8 / fp8, DESIGN.md §13)

def _quantize_case(kv_dtype, **kw):
    from repro.kernels.quant import kv_quantize_rows
    q, kp, vp, tables, lengths = _paged_case(**kw)
    kq, ks = kv_quantize_rows(kp, kv_dtype)
    vq, vs = kv_quantize_rows(vp, kv_dtype)
    return q, (kp, vp), (kq, vq, ks, vs), tables, lengths


@pytest.mark.parametrize("kv_dtype,fp_tol", [
    ("int8", 2.5e-2), ("fp8_e4m3", 1e-1), ("fp8_e5m2", 2e-1)])
def test_paged_attention_quantized(kv_dtype, fp_tol):
    """Kernel with quantized pools: (a) must equal the quantized ORACLE
    tightly — the fused dequant is the same math; (b) must stay within
    the quantization error budget of full-precision attention."""
    from repro.kernels.paged_attention import paged_attention
    from repro.kernels.quant import resolve_kv_dtype
    q, (kp, vp), (kq, vq, ks, vs), tables, lengths = _quantize_case(
        resolve_kv_dtype(kv_dtype),
        B=3, H=4, K=2, hd=64, bs=8, NB=16, P=4, lengths=[19, 8, 31])
    out = paged_attention(q, kq, vq, tables, lengths,
                          k_scale=ks, v_scale=vs)
    qref = ref.paged_attention_ref(q, kq, vq, tables, lengths,
                                   k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(qref),
                               rtol=2e-5, atol=2e-5)
    fpref = ref.paged_attention_ref(q, kp, vp, tables, lengths)
    assert np.abs(np.asarray(out) - np.asarray(fpref)).max() < fp_tol


def test_paged_attention_quantized_with_schedule_and_window():
    from repro.kernels.paged_attention import paged_attention
    q, _, (kq, vq, ks, vs), tables, lengths = _quantize_case(
        jnp.int8, B=2, H=4, K=2, hd=32, bs=8, NB=12, P=3, lengths=[21, 13])
    want = ref.paged_attention_ref(q, kq, vq, tables, lengths,
                                   k_scale=ks, v_scale=vs, window=6)
    out = paged_attention(q, kq, vq, tables, lengths, k_scale=ks,
                          v_scale=vs, window=6, pages_per_step=2,
                          head_tile=2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_kv_quantize_roundtrip():
    from repro.kernels.quant import (kv_dequantize, kv_quantize_rows,
                                     resolve_kv_dtype)
    x = jax.random.normal(KEY, (6, 8, 2, 64)) * 3
    for name, tol_ in (("int8", 2e-2), ("fp8_e4m3", 2e-1)):
        qx, s = kv_quantize_rows(x, resolve_kv_dtype(name))
        assert s.shape == x.shape[:-1]
        back = kv_dequantize(qx, s)
        assert np.abs(np.asarray(back - x)).max() < tol_ * 3
    # all-zero rows survive (scale 0 -> dequant to exact 0, no NaN)
    qz, sz = kv_quantize_rows(jnp.zeros((2, 4, 1, 8)),
                              resolve_kv_dtype("int8"))
    assert np.abs(np.asarray(kv_dequantize(qz, sz))).max() == 0.0
    with pytest.raises(ValueError):
        resolve_kv_dtype("int4")


# ---------------------------------------------------------------------------
# fused top-k/top-p sampling kernel vs the ref oracle (DESIGN.md §13)

SAMPLE_CONFIGS = [
    {"temperature": 0.0},                               # greedy
    {"temperature": 1.0},                               # plain categorical
    {"temperature": 1.0, "top_k": 1},                   # degenerate argmax
    {"temperature": 0.7, "top_k": 8},
    {"temperature": 0.7, "top_p": 0.8},
    {"temperature": 0.9, "top_p": 0.999},               # keeps ~everything
    {"temperature": 0.8, "top_k": 50, "top_p": 0.9},    # both filters
]


@pytest.mark.parametrize("kw", SAMPLE_CONFIGS)
def test_sampling_kernel_matches_ref(kw):
    from repro.kernels.sampling import sample_tokens
    kk = jax.random.split(jax.random.PRNGKey(17), 2)
    logits = jax.random.normal(kk[0], (7, 257)) * 3.0   # odd B and V
    u = jax.random.uniform(kk[1], (7,))
    got = np.asarray(sample_tokens(logits, u, **kw))
    want = np.asarray(ref.sample_ref(logits, u, **kw))
    np.testing.assert_array_equal(got, want)


def test_sampling_top_k_support():
    """Every draw over many uniforms lies in the true top-k set."""
    from repro.kernels.sampling import sample_tokens
    logits = jax.random.normal(jax.random.PRNGKey(5), (1, 101)) * 2
    topk = set(np.asarray(jax.lax.top_k(logits, 8)[1])[0].tolist())
    us = jnp.linspace(0.001, 0.999, 41)
    for u in us:
        t = int(sample_tokens(logits, u[None], temperature=1.0, top_k=8)[0])
        assert t in topk


def test_sampling_top_p_support():
    """Draws live in the smallest nucleus with mass >= p (ties included)."""
    from repro.kernels.sampling import sample_tokens
    logits = jax.random.normal(jax.random.PRNGKey(6), (1, 64)) * 3
    p = jax.nn.softmax(logits, -1)[0]
    order = np.argsort(-np.asarray(p))
    cum = np.cumsum(np.asarray(p)[order])
    n_keep = int(np.searchsorted(cum, 0.8)) + 1
    nucleus = set(order[:n_keep].tolist())
    for u in jnp.linspace(0.01, 0.99, 23):
        t = int(sample_tokens(logits, u[None], temperature=1.0,
                              top_p=0.8)[0])
        assert t in nucleus


def test_sampling_rows_per_step_is_schedule_only():
    from repro.kernels.sampling import sample_tokens
    kk = jax.random.split(jax.random.PRNGKey(8), 2)
    logits = jax.random.normal(kk[0], (6, 130)) * 2
    u = jax.random.uniform(kk[1], (6,))
    base = np.asarray(sample_tokens(logits, u, temperature=0.8, top_k=10,
                                    top_p=0.95, rows_per_step=4))
    for rps in (1, 3, 8):
        got = np.asarray(sample_tokens(logits, u, temperature=0.8,
                                       top_k=10, top_p=0.95,
                                       rows_per_step=rps))
        np.testing.assert_array_equal(got, base)


# ---------------------------------------------------------------------------
# rmsnorm

@pytest.mark.parametrize("shape", [(4, 64), (3, 5, 128), (1, 2048),
                                   (17, 300), (128, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_shapes_dtypes(shape, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], shape, dtype)
    w = (jax.random.normal(ks[1], shape[-1:]) * 0.1).astype(dtype)
    out = rmsnorm(x, w, block_rows=8)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


# ---------------------------------------------------------------------------
# fused SGD-momentum update (the KVStore updater)

@pytest.mark.parametrize("shape", [(100,), (33, 7), (2, 3, 5, 8), (4096,)])
@pytest.mark.parametrize("pdtype", [jnp.float32, jnp.bfloat16])
def test_fused_update_shapes(shape, pdtype):
    ks = jax.random.split(KEY, 3)
    p = jax.random.normal(ks[0], shape, pdtype)
    g = jax.random.normal(ks[1], shape, pdtype)
    m = jax.random.normal(ks[2], shape, jnp.float32)
    new_p, new_m = sgd_momentum(p, g, m, lr=0.1, mu=0.9, weight_decay=0.01,
                                block=64)
    want_p, want_m = ref.sgd_momentum_ref(p, g, m, lr=0.1, mu=0.9,
                                          weight_decay=0.01)
    np.testing.assert_allclose(np.asarray(new_m), np.asarray(want_m),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(new_p, np.float32),
                               np.asarray(want_p, np.float32), **tol(pdtype))


@given(st.integers(1, 500), st.floats(1e-4, 0.5), st.floats(0.0, 0.99),
       st.floats(0.0, 0.1))
@settings(max_examples=20, deadline=None)
def test_fused_update_property(n, lr, mu, wd):
    """Hypothesis sweep over sizes and hyperparameters."""
    ks = jax.random.split(jax.random.PRNGKey(n), 3)
    p = jax.random.normal(ks[0], (n,))
    g = jax.random.normal(ks[1], (n,))
    m = jax.random.normal(ks[2], (n,))
    new_p, new_m = sgd_momentum(p, g, m, lr=lr, mu=mu, weight_decay=wd,
                                block=128)
    want_p, want_m = ref.sgd_momentum_ref(p, g, m, lr=lr, mu=mu,
                                          weight_decay=wd)
    np.testing.assert_allclose(np.asarray(new_p), np.asarray(want_p),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(new_m), np.asarray(want_m),
                               rtol=1e-4, atol=1e-5)


def test_update_is_idempotent_free_and_stateful():
    """Repeated updates track the reference trajectory (momentum state)."""
    p = jnp.ones((64,), jnp.float32)
    g = jnp.full((64,), 0.5)
    m = jnp.zeros((64,), jnp.float32)
    pr, mr = p, m
    for _ in range(5):
        p, m = sgd_momentum(p, g, m, lr=0.1, mu=0.9, weight_decay=0.0,
                            block=64)
        pr, mr = ref.sgd_momentum_ref(pr, g, mr, lr=0.1, mu=0.9,
                                      weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(p), np.asarray(pr), rtol=1e-6)


# ---------------------------------------------------------------------------
# model integration: Pallas attention == jnp attention inside a real model

def test_model_with_pallas_attention_matches():
    from repro.configs import get_config
    from repro.models import get_model, reduced
    from repro.models import layers as L
    m = get_model(reduced(get_config("qwen1.5-0.5b")))
    params = m.init(KEY)
    batch = m.make_batch(KEY, "train", 1, 64)
    loss0, _ = m.loss(params, batch)
    L.set_use_pallas(True)
    try:
        loss1, _ = m.loss(params, batch)
    finally:
        L.set_use_pallas(False)
    np.testing.assert_allclose(float(loss0), float(loss1), rtol=1e-4)
