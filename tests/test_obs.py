"""Observability layer (ISSUE 6 / DESIGN.md §11): trace recorder
semantics, metrics quantiles, Perfetto export validity, the serving
engine's per-request lifecycle spans, and engine-stats reset coherence."""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.engine import Tag, reset_default_engine
from repro.models import get_model, reduced
from repro.obs import (Metrics, TraceRecorder, get_metrics, get_recorder,
                       set_recorder)
from repro.serve import PagedServeEngine

KEY = jax.random.PRNGKey(0)


@pytest.fixture
def recorder():
    """Fresh enabled recorder installed as the process default."""
    old = get_recorder()
    rec = set_recorder(TraceRecorder(enabled=True))
    yield rec
    set_recorder(old)


# ---------------------------------------------------------------------------
# trace recorder

def test_span_nesting_and_ordering(recorder):
    with recorder.span("outer", cat="t"):
        with recorder.span("inner", cat="t"):
            recorder.instant("mark", cat="t")
    names = [e["name"] for e in recorder.events()]
    assert names == ["mark", "inner", "outer"]      # inner closes first
    by = {e["name"]: e for e in recorder.events()}
    # the outer interval contains the inner one
    assert by["outer"]["ts"] <= by["inner"]["ts"]
    assert (by["inner"]["ts"] + by["inner"]["dur"]
            <= by["outer"]["ts"] + by["outer"]["dur"] + 1e-6)
    assert by["mark"]["ph"] == "i"


def test_disabled_recorder_records_nothing():
    rec = TraceRecorder(enabled=False)
    with rec.span("a"):
        rec.instant("b")
        rec.counter("c", 1)
    rec.complete("d", 0.0, 1.0)
    assert rec.events() == []
    # the disabled span path allocates nothing: one shared nullcontext
    assert rec.span("x") is rec.span("y")


def test_tracks_map_to_stable_tids(recorder):
    with recorder.span("a", track="engine"):
        pass
    with recorder.span("b", track="serve"):
        pass
    with recorder.span("c", track="engine"):
        pass
    by = {e["name"]: e["tid"] for e in recorder.events()}
    assert by["a"] == by["c"] != by["b"]


def test_cross_frame_complete_event(recorder):
    import time
    t0 = time.perf_counter()
    t1 = time.perf_counter()
    recorder.complete("queued", recorder.to_us(t0), recorder.to_us(t1),
                      cat="serve", slot=3)
    (e,) = recorder.events()
    assert e["ph"] == "X" and e["dur"] >= 0 and e["args"]["slot"] == 3


def test_perfetto_export_schema(recorder, tmp_path):
    with recorder.span("op", cat="engine", track="engine", seq=0):
        recorder.instant("tick", cat="engine", track="engine")
    recorder.counter("pool", 5, track="engine")
    path = tmp_path / "trace.json"
    recorder.export(str(path))
    doc = json.loads(path.read_text())          # valid JSON
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    evs = doc["traceEvents"]
    # metadata first: process_name + one thread_name per track
    assert evs[0] == {"name": "process_name", "ph": "M", "pid": 1,
                      "args": {"name": "repro"}}
    tracks = [e["args"]["name"] for e in evs if e["name"] == "thread_name"]
    assert "engine" in tracks
    for e in evs:
        assert {"name", "ph", "pid"} <= set(e)
        if e["ph"] == "X":
            assert {"ts", "dur"} <= set(e) and e["dur"] >= 0
        if e["ph"] == "C":
            assert "value" in e["args"]


def test_enable_starts_fresh_timeline():
    from repro import obs
    old = get_recorder()
    try:
        rec = obs.enable()
        with rec.span("x"):
            pass
        assert len(rec.events()) == 1
        obs.enable(False)
        rec2 = obs.enable()                     # off -> on: fresh buffer
        assert rec2.events() == []
    finally:
        set_recorder(old)


# ---------------------------------------------------------------------------
# metrics

def test_histogram_quantiles_known_values():
    m = Metrics()
    h = m.histogram("lat")
    for v in range(1, 11):                      # 1..10
        h.observe(v)
    assert h.quantile(0.0) == 1.0
    assert h.quantile(0.5) == 5.5               # numpy linear interpolation
    assert h.quantile(0.9) == pytest.approx(9.1)
    assert h.quantile(1.0) == 10.0
    assert h.quantile(0.5, values=[3.0]) == 3.0
    assert h.quantile(0.5, values=[]) == 0.0
    s = h.summary()
    assert s["count"] == 10 and s["sum"] == 55.0


def test_metrics_registry_types_and_dump(tmp_path):
    m = Metrics()
    m.counter("bytes").inc(100)
    m.gauge("pool").set(3)
    m.gauge("pool").set(1)                      # max is a high-water mark
    with pytest.raises(TypeError):
        m.histogram("bytes")
    assert m.snapshot()["pool"] == {"type": "gauge", "value": 1, "max": 3}
    path = tmp_path / "m.jsonl"
    assert m.dump_jsonl(str(path)) == 2
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert {ln["name"] for ln in lines} == {"bytes", "pool"}
    assert all(ln["kind"] == "metric" for ln in lines)


# ---------------------------------------------------------------------------
# engine stats coherence (the reset-staleness fix)

def test_engine_stats_fresh_after_reset():
    eng = reset_default_engine()
    a = Tag("a")
    for _ in range(3):
        eng.push(lambda: None, writes=(a,), name="w")
    eng.wait_all()
    eng.publish_stats()
    m = get_metrics()
    assert m.gauge("engine.ops_executed").value == 3
    assert m.histogram("engine.wave_size").count == 3
    # a fresh engine must publish fresh numbers, not accumulate onto the
    # dead instance's record
    eng2 = reset_default_engine()
    assert "engine.ops_executed" not in m.names()
    eng2.push(lambda: None, writes=(a,), name="w")
    eng2.wait_all()
    eng2.publish_stats()
    assert m.gauge("engine.ops_executed").value == 1
    assert m.histogram("engine.wave_size").count == 1


def test_engine_op_spans(recorder):
    eng = reset_default_engine()
    a, b = Tag("a"), Tag("b")
    eng.push(lambda: None, writes=(a,), name="init")
    eng.push(lambda: None, reads=(a,), writes=(b,), name="consume")
    eng.wait_all()
    spans = [e for e in recorder.events() if e["cat"] == "engine"]
    assert [s["name"] for s in spans] == ["init", "consume"]
    assert spans[1]["args"]["reads"] == ["a"]
    assert spans[1]["args"]["writes"] == ["b"]
    assert all("wave" in s["args"] for s in spans)
    reset_default_engine()


# ---------------------------------------------------------------------------
# serving lifecycle spans

def test_paged_serve_request_lifecycle(recorder):
    cfg = reduced(get_config("qwen1.5-0.5b"))
    params = get_model(cfg).init(KEY)
    rng = np.random.RandomState(3)
    prompts = [list(rng.randint(1, cfg.vocab, L)) for L in (5, 11, 19)]
    eng = PagedServeEngine(cfg, params, block_size=8, max_batch=2,
                           max_len=64, prefill_chunk=8)
    outs, stats = eng.generate(prompts, max_new_tokens=[3, 4, 6])
    assert [len(o) for o in outs] == [3, 4, 6]

    evs = recorder.events()
    doc = recorder.export()
    req_tracks = sorted(e["args"]["name"] for e in doc["traceEvents"]
                        if e.get("name") == "thread_name"
                        and e["args"]["name"].startswith("req"))
    # exactly the 3 admitted requests have tracks: the warmup throwaway
    # request (rid 0) is not observed
    assert req_tracks == ["req1", "req2", "req3"]
    for track in req_tracks:
        tids = _tids_for(recorder, track)
        mine = [e for e in evs if e["cat"] == "serve" and e["tid"] in tids]
        names = [e["name"] for e in mine]
        # complete chain: enqueued -> queued -> prefill -> first token ->
        # decode -> evicted, in timeline order
        for n in ("enqueued", "queued", "prefill_chunk", "first_token",
                  "decode", "evicted"):
            assert n in names, f"{track} missing {n}: {names}"
        by = {e["name"]: e for e in mine}
        assert by["queued"]["ts"] <= by["first_token"]["ts"]
        assert by["first_token"]["ts"] <= by["evicted"]["ts"]

    # per-run latency percentiles populated (seconds, small but positive)
    assert stats.ttft_p99 >= stats.ttft_p50 > 0
    assert stats.tpot_p99 >= stats.tpot_p50 > 0
    assert stats.queue_wait_p99 >= stats.queue_wait_p50 >= 0
    h = get_metrics().histogram("serve.ttft_s")
    assert h.count >= 3


def _tids_for(rec, track):
    doc = rec.export()
    return {e["tid"] for e in doc["traceEvents"]
            if e.get("name") == "thread_name"
            and e["args"]["name"] == track}


def test_warmup_is_not_observed(recorder):
    cfg = reduced(get_config("qwen1.5-0.5b"))
    params = get_model(cfg).init(KEY)
    eng = PagedServeEngine(cfg, params, block_size=8, max_batch=2,
                           max_len=64, prefill_chunk=8)
    before = get_metrics().histogram("serve.ttft_s").count
    eng.warmup()
    assert get_metrics().histogram("serve.ttft_s").count == before
    assert eng._observe is True                 # restored after warmup
