"""Quickstart — the paper's own API tour (Figs. 2 & 3, §2).

1. Declare an MLP with the Symbol API (Fig. 2).
2. Imperative NDArray math with lazy engine execution (Fig. 3).
3. Mix both: the §2.2 training loop  ``while(1){net.forward_backward();
   net.w -= eta*net.g}``  and the §2.3 KVStore variant.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (Activation, FullyConnected, KVStoreLocal, NDArray,
                        SoftmaxOutput, Variable, chain, reset_default_engine,
                        sgd_updater)

# --- 1. declarative Symbol (Fig. 2) ---------------------------------------
data, label = Variable("data"), Variable("label")
mlp = chain(data,
            lambda x: FullyConnected(x, 64, name="fc1"),
            lambda x: Activation(x, "relu"),
            lambda x: FullyConnected(x, 10, name="fc2"),
            lambda x: SoftmaxOutput(x, label))
print("arguments:", mlp.list_arguments())
print("output shapes:", mlp.infer_shape(
    data=(32, 100), label=(32,), fc1_weight=(64, 100), fc1_bias=(64,),
    fc2_weight=(10, 64), fc2_bias=(10,)))
print("memory estimate (both heuristics):",
      mlp[0].memory_estimate(data=(32, 100), label=(32,),
                             fc1_weight=(64, 100), fc1_bias=(64,),
                             fc2_weight=(10, 64), fc2_bias=(10,)))

# --- 2. imperative NDArray (Fig. 3) ----------------------------------------
eng = reset_default_engine()
a = NDArray(np.ones((2, 3), np.float32), engine=eng)
b = a * 2  # lazy: nothing has executed yet
print("\n(a * 2).asnumpy():\n", b.asnumpy())  # forces the engine

# --- 3. mixed training loop (§2.2 + §2.3) ---------------------------------
rng = np.random.RandomState(0)
X = rng.randn(256, 100).astype(np.float32)
W = rng.randn(10, 100).astype(np.float32)
Y = np.argmax(X @ W.T, 1).astype(np.float32)

eng = reset_default_engine()
args = {"data": X, "label": Y,
        "fc1_weight": (rng.randn(64, 100) * 0.1).astype(np.float32),
        "fc1_bias": np.zeros(64, np.float32),
        "fc2_weight": (rng.randn(10, 64) * 0.1).astype(np.float32),
        "fc2_bias": np.zeros(10, np.float32)}
wrt = ["fc1_weight", "fc1_bias", "fc2_weight", "fc2_bias"]

kv = KVStoreLocal(eng)
kv.set_updater(sgd_updater(lr=0.5))
weights = {}
for k in wrt:
    kv.init(k, args[k])
    weights[k] = NDArray(args[k], engine=eng, name=k)

ex = mlp[0].bind({**args, **weights}, grad_wrt=wrt)
print("\ntraining (kv.pull -> forward_backward -> kv.push), all lazy:")
for step in range(101):
    for k in wrt:
        kv.pull(k, out=weights[k])
    outs, grads = ex.forward_backward(lazy=True)
    for k in wrt:
        kv.push(k, grads[k])
    if step % 25 == 0:
        print(f"  step {step:3d} loss {float(outs[0].copy().value):.4f}")

print("engine stats:", eng.stats())
