"""Batched multimodal serving: the internvl2 family (reduced) serving
image+text requests — stub patch embeddings -> projector -> LM prefill ->
batched greedy decode with KV cache.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import get_model, reduced
from repro.serve import ServeEngine

cfg = reduced(get_config("internvl2-76b"))
model = get_model(cfg)
params = model.init(jax.random.PRNGKey(0))
engine = ServeEngine(cfg, params, max_len=96)

rng = np.random.RandomState(0)
BATCH, PROMPT, NEW = 4, 24, 24
prompts = [list(rng.randint(1, cfg.vocab, PROMPT)) for _ in range(BATCH)]
patches = np.asarray(rng.randn(BATCH, cfg.frontend_tokens, cfg.frontend_dim),
                     np.float32)  # stub ViT output (DESIGN.md carve-out)

toks, stats = engine.generate(prompts, max_new_tokens=NEW,
                              extra_inputs={"patches": patches})
print(f"served {BATCH} multimodal requests "
      f"({cfg.frontend_tokens} patch tokens + {PROMPT} text tokens each)")
print(f"prefill {stats.prefill_s*1e3:.0f} ms; decode {NEW} steps in "
      f"{stats.decode_s*1e3:.0f} ms -> {stats.tok_per_s:.1f} tok/s")
print("first request tokens:", toks[0][:12], "...")

# determinism check (greedy)
toks2, _ = engine.generate(prompts, max_new_tokens=NEW,
                           extra_inputs={"patches": patches})
assert (toks == toks2).all(), "greedy decode must be deterministic"
print("greedy decode deterministic: OK")
