"""Distributed data-parallel training through the two-level KVStore
(§2.3/§3.3): 8 workers on 2 simulated machines, sequential vs eventual
consistency, with the byte accounting that motivates the two-level design.

Run:  PYTHONPATH=src python examples/distributed_kvstore.py
"""
import numpy as np

from repro.configs import get_config
from repro.core import KVStoreDist
from repro.data import SyntheticLM
from repro.models import reduced
from repro.train import TrainConfig, Trainer

cfg = reduced(get_config("qwen1.5-0.5b"), vocab=64, n_layers=2,
              d_model=128, d_ff=256)
tcfg = TrainConfig(lr=5e-3, total_steps=15, log_every=100)

for consistency in ("sequential", "eventual"):
    kv = KVStoreDist(n_machines=2, devices_per_machine=4,
                     consistency=consistency, staleness=1)
    tr = Trainer(cfg, tcfg)
    data = SyntheticLM(vocab=64, seq_len=32, batch=16, seed=0, n_batches=15)
    losses = tr.fit_kvstore(iter(data), kv, n_workers=8)
    print(f"{consistency:10s}: loss {losses[0]:.3f} -> {losses[-1]:.3f} | "
          f"intra-machine bytes {kv.bytes_l1/1e6:.1f}MB, "
          f"inter-machine bytes {kv.bytes_l2/1e6:.1f}MB "
          f"(two-level saves {kv.bytes_l1/max(kv.bytes_l2,1):.0f}x on the "
          f"slow links)")
