"""End-to-end training driver: a ~100M-parameter qwen-family LM trained
for a few hundred steps on the synthetic LM stream, with prefetching data
pipeline, LR schedule, grad clipping and checkpointing.

Run:  PYTHONPATH=src python examples/train_e2e.py [--steps 300]
(CPU: ~2-4 s/step at the default micro-batch.)
"""
import argparse
from dataclasses import replace

import jax

from repro.configs import get_config
from repro.data import PrefetchIterator, SyntheticLM
from repro.models import get_model
from repro.train import TrainConfig, Trainer


def build_cfg():
    # qwen1.5 family scaled to ~100M params; 32k vocab keeps the CE matmul
    # tractable on this 1-core container (full-vocab variant: --full-vocab)
    base = get_config("qwen1.5-0.5b")
    cfg = replace(base, n_layers=16, d_model=640, n_heads=10, n_kv_heads=10,
                  d_ff=1792, head_dim=64, vocab=32768, dtype="float32")
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg = build_cfg()
    n = cfg.param_count()
    print(f"arch={cfg.name} params={n/1e6:.1f}M layers={cfg.n_layers} "
          f"d_model={cfg.d_model}")

    tcfg = TrainConfig(lr=args.lr, total_steps=args.steps,
                       warmup_steps=args.steps // 10, log_every=10,
                       checkpoint_every=max(args.steps // 2, 1),
                       checkpoint_dir="checkpoints/e2e", grad_clip=10.0)
    data = PrefetchIterator(
        SyntheticLM(cfg.vocab, args.seq, args.batch,
                    n_batches=args.steps + 1, fixed_pattern=True), depth=4)
    tr = Trainer(cfg, tcfg)
    tr.fit(iter(data))
    first, last = tr.history[0]["loss"], tr.history[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'OK: decreased' if last < first else 'WARN: no decrease'})")


if __name__ == "__main__":
    main()
