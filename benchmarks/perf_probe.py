import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""One §Perf hillclimb iteration: recompile an (arch × shape) pair under a
set of perf flags and print the roofline terms.

  python -m benchmarks.perf_probe --arch dbrx-132b --shape train_4k \
      [--flags window_slice=1 ce_chunks=8 ...] [--probes]

Reports both the full-model compile (memory proof) and the probe-composed
totals (exact FLOPs/bytes/collectives), plus deltas vs the stored baseline
JSON when available.
"""
import argparse
import json
from pathlib import Path

from repro.configs import ARCH_IDS, LONG_CONTEXT_ARCHS, get_config
from repro.models import INPUT_SHAPES
from repro import perf_flags

PEAK_FLOPS, HBM_BW, ICI_BW = 197e12, 819e9, 50e9


def parse_flags(items):
    out = {}
    for it in items or []:
        k, v = it.split("=")
        cur = getattr(perf_flags.FLAGS, k)
        if isinstance(cur, bool):
            out[k] = v not in ("0", "false", "False")
        elif isinstance(cur, int):
            out[k] = int(v)
        else:
            out[k] = v
    return out


def terms(block, ns=None, block4=None):
    if block4 is not None and ns is not None:
        per = {k: (block4[k] - block[k]) / 2.0
               for k in ("flops", "bytes_accessed")}
        coll_per = (block4["collectives"]["total"]
                    - block["collectives"]["total"]) / 2.0
        flops = block["flops"] - 2 * per["flops"] + ns * per["flops"]
        byts = (block["bytes_accessed"] - 2 * per["bytes_accessed"]
                + ns * per["bytes_accessed"])
        coll = (block["collectives"]["total"] - 2 * coll_per + ns * coll_per)
    else:
        flops = block["flops"]
        byts = block["bytes_accessed"]
        coll = block["collectives"]["total"]
    return {"compute_s": flops / PEAK_FLOPS, "memory_s": byts / HBM_BW,
            "collective_s": coll / ICI_BW, "flops": flops, "bytes": byts,
            "coll_bytes": coll}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES), required=True)
    ap.add_argument("--flags", nargs="*", default=[])
    ap.add_argument("--probes", action="store_true",
                    help="also compile 2/4-superblock probes for exact totals")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    fl = parse_flags(args.flags)
    perf_flags.set_flags(**fl)
    print("flags:", {k: getattr(perf_flags.FLAGS, k)
                     for k in vars(perf_flags.FLAGS)})

    from repro.launch.dryrun import analyze, lower_and_compile, probe_cfg
    from repro.launch.mesh import make_production_mesh

    long_ctx = (args.shape.startswith("long_500k")
                and (args.arch in LONG_CONTEXT_ARCHS
                     or perf_flags.FLAGS.seq_shard))
    cfg = get_config(args.arch, long_context=long_ctx,
                     seq_shard=perf_flags.FLAGS.seq_shard)
    mesh = make_production_mesh(multi_pod=args.multi_pod)

    _, compiled, tl, tc = lower_and_compile(cfg, args.shape, mesh)
    full = analyze(compiled)
    peak = full["memory"]["peak_per_device"] / 2**30
    print(f"compile {tc:.1f}s  peak/device {peak:.2f} GiB")
    t = terms(full)
    print(f"full(scan-once): compute {t['compute_s']:.4f}s "
          f"memory {t['memory_s']:.4f}s collective {t['collective_s']:.4f}s")

    if args.probes:
        blocks = {}
        for n in (2, 4):
            if cfg.n_super < n:
                continue
            _, c2, _, _ = lower_and_compile(probe_cfg(cfg, n), args.shape,
                                            mesh)
            blocks[n] = analyze(c2)
        if 2 in blocks and 4 in blocks:
            t = terms(blocks[2], cfg.n_super, blocks[4])
            print(f"composed: compute {t['compute_s']:.4f}s "
                  f"memory {t['memory_s']:.4f}s "
                  f"collective {t['collective_s']:.4f}s "
                  f"(flops {t['flops']:.3e}, bytes {t['bytes']:.3e}, "
                  f"coll {t['coll_bytes']:.3e})")

    # baseline comparison
    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    base = (Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
            / f"{args.arch}__{args.shape}__{mesh_name}.json")
    if base.exists():
        rec = json.loads(base.read_text())
        if rec.get("status") == "OK":
            bpeak = rec["full"]["memory"]["peak_per_device"] / 2**30
            print(f"baseline peak {bpeak:.2f} GiB -> delta "
                  f"{peak - bpeak:+.2f} GiB ({(peak/bpeak - 1) * 100:+.1f}%)")


if __name__ == "__main__":
    main()
