"""Serving under mixed-length traffic: static padded batches vs paged
continuous batching (ISSUE 4, DESIGN.md §9).

Workload: requests with prompt lengths drawn from {32..512} (skewed
short, like real traffic) and uneven generation budgets.  The static
engine processes them in arrival-order lockstep batches — every batch
pads to the global max prompt length, allocates dense ``(B, max_len)``
caches, and decodes until its SLOWEST request finishes.  The paged
engine streams the same requests through ``max_batch`` decode lanes over
a block pool: finished lanes are refilled immediately, prompts prefill
in chunks, cache blocks are recycled.

Reported (CSV name,value,derived):

* greedy-token parity between the engines (they must implement the same
  math — continuous batching is a *scheduling* change);
* decode tokens/s: useful tokens (each request's own budget) over decode
  wall time, per engine — the headline claim: paged > static;
* peak KV-cache bytes: dense ``B x max_len`` model vs the allocator's
  block high-water mark — the claim: >= 4x smaller paged;
* paged-attention kernel vs oracle max |err| (GQA + block-boundary
  lengths), interpret mode.

Usage:  PYTHONPATH=src python benchmarks/bench_serving.py
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

_ROOT = Path(__file__).resolve().parents[1]
for p in (str(_ROOT), str(_ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

import numpy as np

N_REQUESTS = 24
MAX_BATCH = 8
BLOCK_SIZE = 16
PREFILL_CHUNK = 128
PROMPT_LENS = [32, 48, 64, 96, 128, 192, 256, 384, 512]
# chat-like traffic: heavy short mass, thin long tail (the regime where
# dense max_len padding wastes the most cache)
PROMPT_P = [0.30, 0.22, 0.16, 0.12, 0.08, 0.05, 0.04, 0.02, 0.01]
BUDGETS = [4, 8, 16, 32, 48]
KERNEL_TOL = 5e-3
# int8 greedy-parity sub-workload (short-skewed, like the main one but
# sized so the full fp-vs-int8 token comparison runs in seconds).  The
# rng seed is part of the benchmark definition: greedy decoding is
# deterministic, so parity verified once holds run to run.
INT8_N = 8
INT8_LENS = [16, 24, 32]
INT8_BUDGETS = [4, 6, 8]
INT8_SEED = 2
INT8_RATIO_FLOOR = 1.8
# overload section (ISSUE 9 / DESIGN.md §14): arrival rate > capacity on
# an undersized pool, optimistic admission + preemption/swap, mixed
# priorities and a slice of unmeetable deadlines.  Arrivals are
# step-driven (2 per engine step), so the pressure pattern — and hence
# the shed/preempt structure — does not depend on wall clock.
OV_N = 16
OV_ARRIVALS_PER_STEP = 2
OV_MAX_QUEUE = 4
OV_SEED = 5


def _workload(vocab: int, seed: int = 2):
    rng = np.random.RandomState(seed)
    lens = rng.choice(PROMPT_LENS, N_REQUESTS, p=PROMPT_P)
    budgets = [int(b) for b in rng.choice(BUDGETS, N_REQUESTS)]
    prompts = [list(rng.randint(1, vocab, int(L))) for L in lens]
    return prompts, budgets


def _short_workload(vocab: int, seed: int = INT8_SEED):
    rng = np.random.RandomState(seed)
    lens = rng.choice(INT8_LENS, INT8_N)
    budgets = [int(b) for b in rng.choice(INT8_BUDGETS, INT8_N)]
    prompts = [list(rng.randint(1, vocab, int(L))) for L in lens]
    return prompts, budgets


def _kernel_parity():
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref
    from repro.kernels.paged_attention import paged_attention

    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    B, H, K, hd, bs, NB, P = 4, 8, 2, 64, 16, 12, 4
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (NB, bs, K, hd))
    vp = jax.random.normal(ks[2], (NB, bs, K, hd))
    tables = jnp.arange(1, 1 + B * P, dtype=jnp.int32).reshape(B, P) % NB
    # mid-block, exact boundary, one token, full table
    lengths = jnp.asarray([37, 32, 1, 64], jnp.int32)
    out = paged_attention(q, kp, vp, tables, lengths)
    want = ref.paged_attention_ref(q, kp, vp, tables, lengths)
    return float(jnp.abs(out - want).max())


def run(csv: bool = True, kv_dtype: str = "int8"):
    import jax
    from repro.configs import get_config
    from repro.core.memplan import kv_cache_bytes_dense
    from repro.models import get_model, reduced
    from repro.serve import PagedServeEngine, ServeEngine

    cfg = reduced(get_config("qwen1.5-0.5b"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts, budgets = _workload(cfg.vocab)
    max_len = max(PROMPT_LENS) + max(BUDGETS) + 8
    # decode-produced tokens only: each request's FIRST token comes from
    # prefill logits on both engines, so it belongs to neither decode timer
    useful = sum(b - 1 for b in budgets)

    rows = []

    def emit(name, value, derived=""):
        rows.append((name, value, derived))
        if csv:
            print(f"{name},{value},{derived}")

    # -- static lockstep batches (arrival order) ---------------------------
    eng = ServeEngine(cfg, params, max_len=max_len)
    static_out = []
    static_decode_s = static_prefill_s = compile_s = 0.0
    for i in range(0, N_REQUESTS, MAX_BATCH):
        bp = prompts[i:i + MAX_BATCH]
        bb = budgets[i:i + MAX_BATCH]
        toks, st = eng.generate(bp, max_new_tokens=max(bb),
                                pad_prompts_to=max(PROMPT_LENS),
                                warmup=(i == 0))
        compile_s += st.compile_s
        static_decode_s += st.decode_s
        static_prefill_s += st.prefill_s
        static_out += [list(map(int, toks[j, :bb[j]])) for j in range(len(bp))]
    static_tok_s = useful / static_decode_s
    emit("serving_static_decode_tok_per_s", round(static_tok_s, 1),
         f"{useful} useful decode tokens / {static_decode_s:.3f}s "
         f"(compile {compile_s:.1f}s separate)")

    # -- paged continuous batching ----------------------------------------
    peng = PagedServeEngine(cfg, params, block_size=BLOCK_SIZE,
                            max_batch=MAX_BATCH, max_len=max_len,
                            prefill_chunk=PREFILL_CHUNK)
    t0 = time.time()
    paged_out, pst = peng.generate(prompts, max_new_tokens=budgets)
    wall = time.time() - t0
    paged_tok_s = pst.tokens_out / pst.decode_s
    emit("serving_paged_decode_tok_per_s", round(paged_tok_s, 1),
         f"{pst.tokens_out} decode tokens / {pst.decode_s:.3f}s in "
         f"{pst.steps} steps (compile {pst.compile_s:.1f}s separate)")
    emit("serving_paged_wall_s", round(wall - pst.compile_s, 3),
         f"prefill {pst.prefill_s:.3f}s")
    emit("serving_speedup", round(paged_tok_s / static_tok_s, 2),
         "paged/static decode tok/s")

    # -- per-request latency (informational, never gated: wall-clock
    #    percentiles swing with machine load like every timing here) -------
    emit("serving_ttft_p50_ms", round(pst.ttft_p50 * 1e3, 2),
         "enqueue -> first token (paged engine)")
    emit("serving_ttft_p99_ms", round(pst.ttft_p99 * 1e3, 2), "")
    emit("serving_tpot_p50_ms", round(pst.tpot_p50 * 1e3, 3),
         "per-token decode time after the first")
    emit("serving_tpot_p99_ms", round(pst.tpot_p99 * 1e3, 3), "")
    emit("serving_queue_wait_p50_ms", round(pst.queue_wait_p50 * 1e3, 2),
         "enqueue -> admission to a decode lane")
    emit("serving_queue_wait_p99_ms", round(pst.queue_wait_p99 * 1e3, 2), "")

    # -- parity ------------------------------------------------------------
    mismatches = sum(a != b for a, b in zip(static_out, paged_out))
    emit("serving_token_mismatches", mismatches,
         f"{N_REQUESTS} mixed-length greedy requests")

    # -- cache bytes -------------------------------------------------------
    dense = kv_cache_bytes_dense(cfg, MAX_BATCH, max_len)
    emit("serving_dense_cache_bytes", dense,
         f"{MAX_BATCH} x max_len={max_len} padded")
    emit("serving_paged_peak_cache_bytes", pst.peak_cache_bytes,
         f"{pst.peak_cache_blocks} blocks (block_size {BLOCK_SIZE})")
    emit("serving_cache_ratio",
         round(dense / max(pst.peak_cache_bytes, 1), 2),
         "dense / paged peak")

    # -- int8 paged KV-cache (DESIGN.md §13) -------------------------------
    # same full workload through a quantized-cache engine: block schedule
    # depends only on lengths/budgets, so fp and int8 peaks count the SAME
    # blocks — the byte ratio is purely bytes-per-block (codes + scales
    # vs native rows) and is allocator-deterministic
    qeng = PagedServeEngine(cfg, params, block_size=BLOCK_SIZE,
                            max_batch=MAX_BATCH, max_len=max_len,
                            prefill_chunk=PREFILL_CHUNK, kv_dtype=kv_dtype)
    q_out, qst = qeng.generate(prompts, max_new_tokens=budgets)
    emit("serving_int8_decode_tok_per_s",
         round(qst.tokens_out / qst.decode_s, 1),
         f"{kv_dtype}; informational: interpret-mode wall, not the TPU "
         f"story")
    emit("serving_int8_peak_cache_bytes", qst.peak_cache_bytes,
         f"{qst.peak_cache_blocks} blocks incl. per-row f32 scales "
         f"({kv_dtype})")
    emit("serving_int8_vs_fp_cache_ratio",
         round(pst.peak_cache_bytes / max(qst.peak_cache_bytes, 1), 2),
         f"fp paged peak / int8 paged peak (floor {INT8_RATIO_FLOOR})")

    # greedy-token parity fp vs int8 on the short-skewed sub-workload:
    # 1-byte codes perturb logits by ~1e-2, so near-tie argmaxes can flip
    # on long decodes; short generations with healthy top-1 margins must
    # agree EXACTLY, and greedy determinism makes this stable run to run
    sp, sb = _short_workload(cfg.vocab)
    s_len = max(INT8_LENS) + max(INT8_BUDGETS) + 8
    parity_out = {}
    for kd in (None, kv_dtype):
        e = PagedServeEngine(cfg, params, block_size=BLOCK_SIZE,
                             max_batch=MAX_BATCH, max_len=s_len,
                             prefill_chunk=32, kv_dtype=kd)
        parity_out[kd], _ = e.generate(sp, max_new_tokens=sb, warmup=False)
    q_mism = sum(int(a != b)
                 for ta, tb in zip(parity_out[None], parity_out[kv_dtype])
                 for a, b in zip(ta, tb))
    emit("serving_int8_token_mismatches", q_mism,
         f"{sum(sb)} greedy tokens, {INT8_N} short-skewed requests")

    # -- overload: traffic > capacity (ISSUE 9, DESIGN.md §14) -------------
    # undersized pool + bounded queue + tight deadlines: the engine must
    # degrade (preempt / shed / time out), never crash, and leave every
    # request in a typed terminal status
    from repro.serve import ServeStats, Status

    rng = np.random.RandomState(OV_SEED)
    ov_lens = rng.randint(16, 65, OV_N)
    ov_budgets = [int(b) for b in rng.randint(8, 25, OV_N)]
    ov_prios = [int(p) for p in rng.randint(0, 3, OV_N)]
    # every 5th request gets a deadline it cannot meet (1ms): exercises
    # the timeout sweep + deadline-miss accounting
    ov_deadlines = [1.0 if i % 5 == 3 else None for i in range(OV_N)]
    ov_prompts = [list(rng.randint(1, cfg.vocab, int(L))) for L in ov_lens]
    oeng = PagedServeEngine(
        cfg, params, block_size=16, max_batch=4, max_len=96,
        prefill_chunk=32, num_blocks=13,        # 12 usable << 4 lanes x 6
        admission="optimistic", swap_blocks=18,
        victim_policy="lowest_priority",
        max_queue=OV_MAX_QUEUE, shed_policy="reject_newest")
    ost = ServeStats()
    ost.compile_s = oeng.warmup()
    tickets, crashes, i = [], 0, 0
    try:
        while i < OV_N or oeng.busy:
            for _ in range(OV_ARRIVALS_PER_STEP):
                if i < OV_N:
                    tickets.append(oeng.add_request(
                        ov_prompts[i], ov_budgets[i],
                        priority=ov_prios[i],
                        deadline_ms=ov_deadlines[i]))
                    i += 1
            oeng.step(ost)
        oeng.run(ost)          # drained: fills the lifecycle counters
    except Exception as e:     # the gate: overload must never raise
        crashes = 1
        print(f"# overload section crashed: {type(e).__name__}: {e}",
              file=sys.stderr)
    accepted = sum(t.accepted for t in tickets)
    terminal = sum(1 for t in tickets
                   if t.rid in oeng.results
                   and isinstance(oeng.results[t.rid].status, Status))
    misses = sorted(r.deadline_miss_s for r in oeng.results.values()
                    if r.deadline_miss_s is not None)
    emit("serving_overload_crashes", crashes,
         f"{OV_N} requests at {OV_ARRIVALS_PER_STEP}/step, queue "
         f"{OV_MAX_QUEUE}, 12-block pool")
    emit("serving_overload_terminal_coverage",
         round(terminal / OV_N, 3),
         "fraction of requests with a typed terminal status (gate: 1.0)")
    emit("serving_overload_preempt_rate",
         round(ost.preempted / max(accepted, 1), 3),
         f"{ost.preempted} preemptions / {accepted} accepted "
         f"({ost.restored} restored, swap peak {ost.swap_peak_blocks} "
         f"blocks)")
    emit("serving_overload_shed_rate", round(ost.shed / OV_N, 3),
         f"{ost.shed} shed of {OV_N} submitted (bounded queue)")
    emit("serving_overload_timeouts", ost.timeouts,
         f"{sum(d is not None for d in ov_deadlines)} requests carried "
         f"unmeetable 1ms deadlines")
    emit("serving_overload_deadline_miss_p99_ms",
         round(float(np.percentile(misses, 99)) * 1e3, 2) if misses else 0,
         "informational: wall-clock dependent")
    emit("serving_overload_goodput_tok_per_s",
         round(ost.goodput_tok_per_s, 1),
         f"{ost.goodput_tokens} decode tokens of OK requests / "
         f"{ost.decode_s:.3f}s decode")

    # -- kernel ------------------------------------------------------------
    emit("serving_paged_kernel_max_err", _kernel_parity(),
         "pallas interpret vs oracle, GQA + block boundary")
    return rows


def validate(rows) -> list[str]:
    """Acceptance (ISSUE 4 + 9): identical greedy tokens, paged beats
    static decode tok/s, >= 4x smaller peak cache, kernel matches the
    oracle; the overload run crashes zero times, leaves every request in
    a typed terminal status, and actually exercises preemption+shedding."""
    d = {name: value for name, value, _ in rows}
    failures = []
    if d.get("serving_overload_crashes", 1) != 0:
        failures.append("overload section raised instead of degrading")
    if d.get("serving_overload_terminal_coverage", 0) != 1.0:
        failures.append(
            f"overload terminal coverage "
            f"{d.get('serving_overload_terminal_coverage')} != 1.0")
    if not d.get("serving_overload_preempt_rate", 0) > 0:
        failures.append("overload run never preempted (pool not stressed)")
    if not d.get("serving_overload_shed_rate", 0) > 0:
        failures.append("overload run never shed (queue bound not hit)")
    if not d.get("serving_overload_timeouts", 0) > 0:
        failures.append("overload run never timed out a doomed deadline")
    if d.get("serving_token_mismatches", 1) != 0:
        failures.append(
            f"static and paged engines disagree on "
            f"{d.get('serving_token_mismatches')} requests")
    if not d.get("serving_paged_decode_tok_per_s", 0) > \
            d.get("serving_static_decode_tok_per_s", float("inf")):
        failures.append(
            f"paged decode tok/s {d.get('serving_paged_decode_tok_per_s')} "
            f"<= static {d.get('serving_static_decode_tok_per_s')}")
    ratio = d.get("serving_cache_ratio", 0)
    if ratio < 4.0:
        failures.append(f"dense/paged peak cache ratio {ratio} < 4.0")
    qratio = d.get("serving_int8_vs_fp_cache_ratio", 0)
    if qratio < INT8_RATIO_FLOOR:
        failures.append(f"int8 cache ratio {qratio} < {INT8_RATIO_FLOOR}")
    if d.get("serving_int8_token_mismatches", 1) != 0:
        failures.append(
            f"int8 engine disagrees with fp greedy tokens on "
            f"{d.get('serving_int8_token_mismatches')} draws "
            f"(short-skewed parity workload)")
    err = d.get("serving_paged_kernel_max_err", 1.0)
    if err > KERNEL_TOL:
        failures.append(f"paged kernel max err {err} > {KERNEL_TOL}")
    return failures


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--kv-dtype", default="int8",
                    choices=["int8", "fp8_e4m3", "fp8_e5m2"],
                    help="storage dtype for the quantized-cache section "
                         "(the gates are calibrated for int8)")
    rows = run(kv_dtype=ap.parse_args().kv_dtype)
    bad = validate(rows)
    print("PASS" if not bad else bad)
    sys.exit(1 if bad else 0)
