import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Beyond-paper OPTIMIZED dry-run sweep (§Perf outcome).

Re-lowers the pairs where hillclimbing found wins, with the per-pair flag
policy below, writing records to experiments/dryrun_opt/ in the same
format as the baseline so `roofline.py` can diff them.
"""
import json
from pathlib import Path

from repro import perf_flags
from repro.configs import get_config
from repro.launch.dryrun import analyze, lower_and_compile, probe_cfg
from repro.launch.mesh import make_production_mesh

OUT = Path(__file__).resolve().parents[1] / "experiments" / "dryrun_opt"

# per-(arch, shape) winning flags from the §Perf hillclimb
OPT_POLICY = {
    ("dbrx-132b", "train_4k"): dict(moe_gather_once=True,
                                    attn_gather_once=True),
    ("dbrx-132b", "prefill_32k"): dict(attn_probs_seq_shard=True,
                                       moe_gather_once=True,
                                       attn_gather_once=True),
    ("internvl2-76b", "prefill_32k"): dict(attn_probs_seq_shard=True,
                                           probs_bf16=True),
    ("jamba-1.5-large-398b", "prefill_32k"): dict(attn_probs_seq_shard=True),
    ("starcoder2-15b", "prefill_32k"): dict(attn_probs_seq_shard=True),
    # granite-20b prefill: rejected (−2% peak for +30% collective; its
    # G=48 heads shard cleanly so it never hit the involuntary-remat)
    ("llama4-scout-17b-a16e", "train_4k"): dict(attn_probs_seq_shard=True,
                                                moe_gather_once=True,
                                                attn_gather_once=True),
    ("qwen1.5-0.5b", "decode_32k"): dict(decode_cache_shard="heads"),
}


def main():
    OUT.mkdir(parents=True, exist_ok=True)
    mesh = make_production_mesh()
    for (arch, shape), flags in OPT_POLICY.items():
        out = OUT / f"{arch}__{shape}__16x16.json"
        if out.exists():
            continue
        print(f"== OPT {arch} × {shape} flags={flags}")
        perf_flags.reset_flags()
        perf_flags.set_flags(**flags)
        cfg = get_config(arch)
        rec = {"arch": arch, "shape": shape, "mesh": "16x16",
               "n_layers": cfg.n_layers, "n_super": cfg.n_super,
               "params": cfg.param_count(),
               "params_active": cfg.param_count(active_only=True),
               "flags": flags, "status": "OK"}
        try:
            _, compiled, tl, tc = lower_and_compile(cfg, shape, mesh)
            rec["full"] = analyze(compiled)
            for n in (2, 4):
                if cfg.n_super < n:
                    continue
                _, c2, _, _ = lower_and_compile(probe_cfg(cfg, n), shape,
                                                mesh)
                rec[f"probe{n}"] = analyze(c2)
            m = rec["full"]["memory"]
            print(f"   peak {m['peak_per_device']/2**30:.1f} GiB  "
                  f"coll {rec['full']['collectives']['total']/2**30:.1f} GiB")
        except Exception as e:  # noqa: BLE001
            rec["status"] = "FAIL"
            rec["error"] = str(e)[:1500]
            print("   FAIL", str(e)[:150])
        out.write_text(json.dumps(rec, indent=1))
    perf_flags.reset_flags()


if __name__ == "__main__":
    main()
