"""Pipeline parallelism over the super-block stack (DESIGN.md §10): the
1F1B stage schedule's analytic collective-permute byte model
cross-validated against the compiled HLO — the same HLO-vs-model
discipline ``bench_dist.py``/``bench_ring.py`` established — plus the
ISSUE-5 acceptance parity gate: pipelined loss and parameter gradients
must match the single-stage baseline to 1e-5.

For each stage count pp in {1, 2, 4} (one mesh axis, "stage"; reduced
dense config with n_super = 4, M = 4 microbatches):

* lower + compile the model loss (fwd) and its parameter grad on the
  stage mesh with ``PerfFlags.pp_stages/microbatches`` set;
* parse collective-permute bytes out of the compiled HLO and require
  them to equal ``pipeline_permute_bytes`` *exactly* — forward
  ``(M + pp - 2)`` hops of one ``(b, S, D)`` microbatch activation, the
  reverse schedule the same count of activation-cotangent hops (pp = 1
  takes the plain unpipelined stack: zero permutes);
* run the pipelined loss/grad numerically and compare against the
  unpipelined no-mesh baseline (max abs diff, gated at 1e-5).

Multi-device lowering needs --xla_force_host_platform_device_count
before jax initializes, so measurement runs in a subprocess (CSV rows
out).

Usage:  PYTHONPATH=src python benchmarks/bench_pipeline.py

CSV: name,value,derived
"""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")

B, S, NS, M = 8, 32, 4, 4      # global batch, seq, super-blocks, microbatches
STAGES = (1, 2, 4)
ITEMSIZE = 4                   # reduced configs run f32 on CPU
TOL = 1e-5                     # ISSUE-5 acceptance: loss/grad parity bound

_BODY = f"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import jax, numpy as np
from dataclasses import replace
from repro.configs import get_config
from repro.models import get_model, reduced
from repro.perf_flags import reset_flags, set_flags
from repro.launch.dryrun import collective_bytes

B, S, NS, M = {B}, {S}, {NS}, {M}
cfg = replace(reduced(get_config("qwen1.5-0.5b")), n_layers=NS)
m = get_model(cfg)
params = m.init(jax.random.PRNGKey(0))
batch = m.make_batch(jax.random.PRNGKey(1), "train", B, S)

loss_fn = lambda p: m.loss(p, batch)[0]
loss0 = float(loss_fn(params))
g0 = jax.grad(loss_fn)(params)

for P in {STAGES}:
    mesh = jax.make_mesh((P,), ("stage",))
    set_flags(pp_stages=P, microbatches=M)
    try:
        with jax.set_mesh(mesh):
            jf = jax.jit(loss_fn)
            jg = jax.jit(jax.grad(loss_fn))
            cf = jf.lower(params).compile()
            cg = jg.lower(params).compile()
            loss1 = float(jf(params))
            g1 = jg(params)
    finally:
        reset_flags()
    for name, comp in (("fwd", cf), ("grad", cg)):
        coll = collective_bytes(comp.as_text())
        print(f"RESULT,P{{P}},{{name}}_permute_bytes,"
              f"{{int(coll['raw']['collective-permute'])}}")
        print(f"RESULT,P{{P}},{{name}}_permute_count,"
              f"{{coll['counts']['collective-permute']}}")
    gerr = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
               for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)))
    print(f"RESULT,P{{P}},loss_maxerr,{{abs(loss1 - loss0)}}")
    print(f"RESULT,P{{P}},grad_maxerr,{{gerr}}")
"""


def _measure() -> dict:
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _BODY], capture_output=True,
                       text=True, env=env, timeout=560)
    if r.returncode != 0:
        raise RuntimeError(
            f"bench_pipeline subprocess failed:\n{r.stderr[-2000:]}")
    out = {}
    for line in r.stdout.splitlines():
        if line.startswith("RESULT,"):
            _, tag, metric, value = line.split(",")
            out[(tag, metric)] = float(value)
    return out


def _analytic(P: int) -> dict:
    from repro.dist.pipeline import pipeline_permute_bytes

    # payload = the residual-stream microbatch (b, S, d_model); the bench
    # mesh has no data axis, so b = B / M.  d_model matches reduced()
    return pipeline_permute_bytes(B // M, S, 256, n_stages=P,
                                  microbatches=M, itemsize=ITEMSIZE)


def run(csv: bool = True):
    from repro.dist.pipeline import pipeline_bubble_fraction
    vals = _measure()
    rows = []

    def emit(name, value, derived=""):
        rows.append((name, value, derived))
        if csv:
            print(f"{name},{value},{derived}")

    for P in STAGES:
        model = _analytic(P)
        tag = f"P{P}"
        derived = {
            "fwd": f"{model['fwd_permutes']} hops x "
                   f"{model['payload_bytes']}B",
            "grad": f"fwd + {model['bwd_permutes']} reverse hops",
        }
        for d, key in (("fwd", "fwd_total"), ("grad", "grad_total")):
            emit(f"pipeline_{tag}_{d}_permute_bytes_hlo",
                 vals[(tag, f"{d}_permute_bytes")],
                 f"{int(vals[(tag, f'{d}_permute_count')])} permutes")
            emit(f"pipeline_{tag}_{d}_permute_bytes_analytic", model[key],
                 derived[d])
        emit(f"pipeline_{tag}_loss_maxerr", vals[(tag, "loss_maxerr")],
             f"vs single-stage baseline (tol {TOL})")
        emit(f"pipeline_{tag}_grad_maxerr", vals[(tag, "grad_maxerr")],
             f"vs single-stage baseline (tol {TOL})")
        emit(f"pipeline_{tag}_bubble_fraction",
             pipeline_bubble_fraction(P, M),
             f"(pp-1)/(pp-1+M), M={M}")
    return rows


def validate(rows) -> list[str]:
    """Acceptance (ISSUE 5): analytic permute bytes == compiled-HLO bytes
    exactly for pp in {1, 2, 4}, and pipelined loss/grads match the
    single-stage baseline within 1e-5."""
    d = {name: value for name, value, _ in rows}
    failures = []
    for P in STAGES:
        tag = f"P{P}"
        for direction in ("fwd", "grad"):
            hlo = d.get(f"pipeline_{tag}_{direction}_permute_bytes_hlo")
            ana = d.get(f"pipeline_{tag}_{direction}_permute_bytes_analytic")
            if hlo is None or ana is None:
                failures.append(
                    f"missing pipeline measurement {tag}/{direction}")
            elif hlo != ana:
                failures.append(
                    f"{tag} {direction}: HLO permute bytes {hlo} != "
                    f"analytic {ana}")
        for metric in ("loss_maxerr", "grad_maxerr"):
            err = d.get(f"pipeline_{tag}_{metric}")
            if err is None:
                failures.append(f"missing pipeline {tag} {metric}")
            elif err > TOL:
                failures.append(
                    f"{tag}: {metric} {err} exceeds {TOL} vs the "
                    f"single-stage baseline")
    multi = [P for P in STAGES if P > 1]
    if not any(d.get(f"pipeline_P{P}_fwd_permute_bytes_hlo", 0)
               for P in multi):
        failures.append("no collective-permutes found on any multi-stage "
                        "mesh — the pipeline schedule did not run")
    return failures


if __name__ == "__main__":
    rows = run()
    bad = validate(rows)
    print("PASS" if not bad else bad)
    sys.exit(1 if bad else 0)
