"""Pallas kernel benchmarks: oracle parity + the autotune sweep.

On this CPU container the kernels run in interpret mode, so absolute
wall-clock is NOT the kernel's merit (TPU is the target).  What IS
machine-portable here:

* allclose vs the jnp oracle at benchmark shapes (maxerr rows);
* the registry autotune sweep (DESIGN.md §13): every registered op's
  tunable space timed on its canned bench cases, reporting tuned-vs-
  default speedup.  Defaults are always in the sweep, so speedup >= 1.0
  by construction; the geomean over all cases is the gated primary (a
  same-run timing *ratio*, which survives machine changes);
* int8 paged-KV accuracy: kernel vs the quantized oracle (tight) and the
  quantized oracle vs full-precision attention (the information actually
  lost to 1-byte codes, gated loosely);
* fused sampling kernel vs the ``ref.py`` oracle under fixed keys —
  exact token match required.

CSV: name,value,derived
"""
from __future__ import annotations

import math
import sys
import tempfile
import time
from pathlib import Path

_ROOT = Path(__file__).resolve().parents[1]
for p in (str(_ROOT), str(_ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.autotune import AutotuneCache, tune
from repro.kernels import registry
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention
from repro.kernels.quant import kv_quantize_rows
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.sampling import sample_tokens
from repro.kernels.fused_update import sgd_momentum

TUNE_REPEATS = 3
INT8_VS_FP_TOL = 5e-2      # information lost to 1-byte codes, not a bug


def time_fn(fn, n=10, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / n * 1e6


def _paged_setup(kv_dtype=None):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    B, H, K, hd, bs, NB, P = 4, 8, 2, 64, 16, 12, 4
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (NB, bs, K, hd))
    vp = jax.random.normal(ks[2], (NB, bs, K, hd))
    tables = jnp.arange(1, 1 + B * P, dtype=jnp.int32).reshape(B, P) % NB
    lengths = jnp.asarray([37, 32, 1, 64], jnp.int32)
    kw = {}
    if kv_dtype is not None:
        kp, kw["k_scale"] = kv_quantize_rows(kp, kv_dtype)
        vp, kw["v_scale"] = kv_quantize_rows(vp, kv_dtype)
    return (q, kp, vp, tables, lengths), kw


def run(csv=True):
    rows = []
    key = jax.random.PRNGKey(0)

    # -- oracle parity + oracle wall (the TPU kernel's bar) ----------------
    B, S, H, K, hd = 1, 512, 8, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    err = float(np.abs(np.asarray(out) - np.asarray(want)).max())
    rows.append(("kernel_flash_attn_maxerr", err, "interpret vs oracle"))
    oracle = jax.jit(lambda: ref.flash_attention_ref(q, k, v, causal=True))
    rows.append(("kernel_flash_attn_oracle_us", round(time_fn(oracle), 1),
                 "jnp oracle wall (TPU kernel must beat)"))

    x = jax.random.normal(ks[0], (4096, 1024), jnp.float32)
    w = jax.random.normal(ks[1], (1024,)) * 0.1
    err = float(np.abs(np.asarray(rmsnorm(x, w))
                       - np.asarray(ref.rmsnorm_ref(x, w))).max())
    rows.append(("kernel_rmsnorm_maxerr", err, ""))
    oracle = jax.jit(lambda: ref.rmsnorm_ref(x, w))
    rows.append(("kernel_rmsnorm_oracle_us", round(time_fn(oracle), 1), ""))

    p = jax.random.normal(ks[0], (1 << 20,))
    g = jax.random.normal(ks[1], (1 << 20,))
    m = jnp.zeros((1 << 20,))
    new_p, new_m = sgd_momentum(p, g, m, lr=0.1, mu=0.9, weight_decay=1e-4)
    wp, wm = ref.sgd_momentum_ref(p, g, m, lr=0.1, mu=0.9, weight_decay=1e-4)
    err = float(np.abs(np.asarray(new_p) - np.asarray(wp)).max())
    rows.append(("kernel_fused_update_maxerr", err, "1M params"))
    oracle = jax.jit(lambda: ref.sgd_momentum_ref(p, g, m, lr=0.1, mu=0.9,
                                                  weight_decay=1e-4))
    rows.append(("kernel_fused_update_oracle_us", round(time_fn(oracle), 1),
                 ""))

    # paged attention: fp oracle parity at GQA + block-boundary lengths
    args, _ = _paged_setup()
    err = float(jnp.abs(paged_attention(*args)
                        - ref.paged_attention_ref(*args)).max())
    rows.append(("kernel_paged_attn_maxerr", err,
                 "interpret vs oracle, GQA + block boundary"))

    # -- int8 paged KV-cache accuracy (DESIGN.md §13) ----------------------
    qargs, qkw = _paged_setup(kv_dtype=jnp.int8)
    got = paged_attention(*qargs, **qkw)
    qref = ref.paged_attention_ref(*qargs, **qkw)
    rows.append(("kernel_paged_int8_vs_qref_maxerr",
                 float(jnp.abs(got - qref).max()),
                 "kernel vs quantized oracle (same math, tight)"))
    fpref = ref.paged_attention_ref(*args)
    rows.append(("kernel_paged_int8_vs_fp_err",
                 float(jnp.abs(got - fpref).max()),
                 f"quantization loss, tol {INT8_VS_FP_TOL}"))

    # -- fused sampling vs ref oracle (exact token parity) ------------------
    mism = 0
    n_toks = 0
    for i, kwargs in enumerate([
            {"temperature": 0.0},
            {"temperature": 1.0, "top_k": 5},
            {"temperature": 0.7, "top_p": 0.8},
            {"temperature": 0.8, "top_k": 50, "top_p": 0.9}]):
        kk = jax.random.split(jax.random.PRNGKey(20 + i), 2)
        logits = jax.random.normal(kk[0], (8, 512)) * 3.0
        u = jax.random.uniform(kk[1], (8,))
        a = np.asarray(sample_tokens(logits, u, **kwargs))
        b = np.asarray(ref.sample_ref(logits, u, **kwargs))
        mism += int((a != b).sum())
        n_toks += a.size
    rows.append(("kernel_sampling_token_mismatches", mism,
                 f"{n_toks} draws: greedy/top-k/top-p/both vs ref oracle"))
    logits = jax.random.normal(jax.random.PRNGKey(30), (8, 2048)) * 3.0
    u = jax.random.uniform(jax.random.PRNGKey(31), (8,))
    oracle = jax.jit(lambda: ref.sample_ref(logits, u, temperature=0.8,
                                            top_k=50, top_p=0.9))
    rows.append(("kernel_sampling_oracle_us", round(time_fn(oracle), 1),
                 "host-style filtered sampling, B8 V2048"))

    # -- the autotune sweep (tuned vs default, every registered op) ---------
    cache = AutotuneCache(Path(tempfile.mkdtemp()) / "autotune.json")
    speedups = []
    for op in registry.ops():
        spec = registry.get(op)
        for label, make in spec.bench_cases:
            a, kw = make()
            rep = tune(op, a, kw, cache=cache, repeats=TUNE_REPEATS,
                       save=False)
            win = " ".join(f"{k}={v}" for k, v in sorted(rep["params"].items()))
            rows.append((f"kernel_tune_{op}_{label}_speedup",
                         round(rep["speedup"], 3),
                         f"winner {win}: {rep['tuned_us']:.0f}us vs default "
                         f"{rep['default_us']:.0f}us"))
            speedups.append((op, label, rep["speedup"],
                             rep["params"] != spec.defaults))
    geo = math.exp(sum(math.log(s) for _, _, s, _ in speedups)
                   / len(speedups))
    rows.append(("kernels_tuned_speedup_geomean", round(geo, 3),
                 f"{len(speedups)} (op, shape) cases; defaults always in "
                 f"the sweep so each case >= 1.0"))

    if csv:
        print("name,value,derived")
        for r in rows:
            print(",".join(str(x) for x in r))
    return rows


def validate(rows):
    fails = []
    d = {name: val for name, val, _ in rows}
    for name, val in d.items():
        if name.endswith("maxerr") and val > 1e-4:
            fails.append(f"{name}: {val}")
    if d.get("kernel_paged_int8_vs_fp_err", 1.0) > INT8_VS_FP_TOL:
        fails.append(f"int8 quantization loss "
                     f"{d.get('kernel_paged_int8_vs_fp_err')} > "
                     f"{INT8_VS_FP_TOL}")
    if d.get("kernel_sampling_token_mismatches", 1) != 0:
        fails.append(f"sampling kernel disagrees with ref oracle on "
                     f"{d.get('kernel_sampling_token_mismatches')} draws")
    tuned = {n: v for n, v in d.items()
             if n.startswith("kernel_tune_") and n.endswith("_speedup")}
    if not tuned:
        fails.append("no autotune sweep rows")
    for name, s in tuned.items():
        if s < 0.99:    # >= 1.0 by construction; 1% float/timing guard
            fails.append(f"{name}: tuned slower than default ({s})")
    if tuned and max(tuned.values()) <= 1.05:
        fails.append("no op shows a strict tuned-vs-default win "
                     f"(max speedup {max(tuned.values())})")
    return fails


if __name__ == "__main__":
    rows = run()
    print("VALIDATION:", validate(rows) or "PASS")
    sys.exit(1 if validate(rows) else 0)
