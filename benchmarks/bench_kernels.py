"""Pallas kernel sanity benchmarks.

On this CPU container the kernels run in interpret mode, so wall-clock is
NOT the kernel's merit (TPU is the target); what we benchmark here is
(a) allclose vs the jnp oracle at benchmark shapes, and (b) the oracle's
jnp wall time as the baseline the TPU kernel must beat (recorded for
the EXPERIMENTS.md §Perf bookkeeping).

CSV: name,us_per_call,derived
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.fused_update import sgd_momentum


def time_fn(fn, n=10, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / n * 1e6


def run(csv=True):
    rows = []
    key = jax.random.PRNGKey(0)

    # flash attention @ a serving-ish shape
    B, S, H, K, hd = 1, 512, 8, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    err = float(np.abs(np.asarray(out) - np.asarray(want)).max())
    rows.append(("kernel_flash_attn_maxerr", err, "interpret vs oracle"))
    oracle = jax.jit(lambda: ref.flash_attention_ref(q, k, v, causal=True))
    rows.append(("kernel_flash_attn_oracle_us", round(time_fn(oracle), 1),
                 "jnp oracle wall (TPU kernel must beat)"))

    # rmsnorm
    x = jax.random.normal(ks[0], (4096, 1024), jnp.float32)
    w = jax.random.normal(ks[1], (1024,)) * 0.1
    err = float(np.abs(np.asarray(rmsnorm(x, w))
                       - np.asarray(ref.rmsnorm_ref(x, w))).max())
    rows.append(("kernel_rmsnorm_maxerr", err, ""))
    oracle = jax.jit(lambda: ref.rmsnorm_ref(x, w))
    rows.append(("kernel_rmsnorm_oracle_us", round(time_fn(oracle), 1), ""))

    # fused update
    p = jax.random.normal(ks[0], (1 << 20,))
    g = jax.random.normal(ks[1], (1 << 20,))
    m = jnp.zeros((1 << 20,))
    new_p, new_m = sgd_momentum(p, g, m, lr=0.1, mu=0.9, weight_decay=1e-4)
    wp, wm = ref.sgd_momentum_ref(p, g, m, lr=0.1, mu=0.9, weight_decay=1e-4)
    err = float(np.abs(np.asarray(new_p) - np.asarray(wp)).max())
    rows.append(("kernel_fused_update_maxerr", err, "1M params"))
    oracle = jax.jit(lambda: ref.sgd_momentum_ref(p, g, m, lr=0.1, mu=0.9,
                                                  weight_decay=1e-4))
    rows.append(("kernel_fused_update_oracle_us", round(time_fn(oracle), 1),
                 ""))
    if csv:
        print("name,us_per_call,derived")
        for r in rows:
            print(",".join(str(x) for x in r))
    return rows


def validate(rows):
    fails = []
    for name, val, _ in rows:
        if name.endswith("maxerr") and val > 1e-4:
            fails.append(f"{name}: {val}")
    return fails


if __name__ == "__main__":
    rows = run()
    print("VALIDATION:", validate(rows) or "PASS")
