"""Fig. 7 reproduction: internal memory usage under allocation strategies.

Paper claim: inplace+co-share give ~2x reduction for training
(forward+backward) and ~4x for prediction (forward only), across
alexnet/vgg-class nets.  We measure exact planned bytes on MLP stacks of
paper-era scale (fc layers dominate memory behaviour the same way).

CSV: name,mode,strategy,bytes,reduction_vs_naive
"""
from __future__ import annotations


from repro.core.graph import Graph, infer_shapes
from repro.core.memplan import naive_bytes, plan_graph
from repro.configs.mxnet_mlp import symbol

NETS = {
    # (hidden sizes, batch, d_in) — alexnet-fc / vgg-fc scale
    "mlp-small": ((256, 256, 256), 64, 784),
    "alexnet-fc": ((4096, 4096), 64, 9216),
    "vgg-fc": ((4096, 4096, 4096, 4096), 64, 25088),
    "deep-mlp": (tuple([1024] * 12), 64, 1024),
}

STRATEGIES = ("naive", "inplace", "coshare", "both")


def measure(hidden, batch, d_in, training: bool):
    sym = symbol(num_hidden=hidden)
    loss = sym[0]
    shapes = {"data": (batch, d_in), "label": (batch,)}
    d = d_in
    for i, h in enumerate(hidden):
        shapes[f"fc{i}_weight"] = (h, d)
        shapes[f"fc{i}_bias"] = (h,)
        d = h
    shapes["head_weight"] = (10, d)
    shapes["head_bias"] = (10,)

    if training:
        wrt = [k for k in shapes if k.endswith(("weight", "bias"))]
        from repro.core.autodiff import gradient_with_shapes
        gsym = gradient_with_shapes(loss, wrt, shapes)
        heads = loss._outputs + gsym._outputs
    else:
        heads = loss._outputs
    g = Graph(heads)
    sh, dt = infer_shapes(g, shapes)
    out = {}
    for strat in STRATEGIES:
        out[strat] = plan_graph(g, sh, dt, strategy=strat).internal_bytes()
    out["naive_check"] = naive_bytes(g, sh, dt)
    return out


def run(csv=True):
    rows = []
    for name, (hidden, batch, d_in) in NETS.items():
        for mode in ("predict", "train"):
            res = measure(hidden, batch, d_in, training=(mode == "train"))
            base = res["naive"]
            for strat in STRATEGIES:
                rows.append((f"fig7_{name}", mode, strat, res[strat],
                             round(base / max(res[strat], 1), 2)))
    if csv:
        print("name,mode,strategy,bytes,reduction_vs_naive")
        for r in rows:
            print(",".join(str(x) for x in r))
    return rows


def validate(rows) -> list[str]:
    """Check the paper's headline claims.

    The 2x(train)/4x(predict) figures hold for deep nets (vgg/googlenet
    have dozens of layers); shallow fc stacks cannot exceed their internal
    buffer count, so they are held to >=2x only (finding recorded in
    EXPERIMENTS.md).
    """
    failures = []
    by = {(r[0], r[1], r[2]): r[4] for r in rows}
    deep = {name for name, (h, _, _) in NETS.items() if len(h) >= 4}
    for name in NETS:
        train_red = by[(f"fig7_{name}", "train", "both")]
        pred_red = by[(f"fig7_{name}", "predict", "both")]
        if train_red < (2.0 if name in deep else 1.8):
            failures.append(f"{name}: train reduction {train_red}")
        if pred_red < (3.5 if name in deep else 2.0):
            failures.append(f"{name}: predict reduction {pred_red}")
        if pred_red < train_red:
            failures.append(f"{name}: predict should reuse >= train")
    return failures


if __name__ == "__main__":
    rows = run()
    fails = validate(rows)
    print("VALIDATION:", "PASS" if not fails else fails)
