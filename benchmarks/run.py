"""Benchmark driver: one benchmark per paper table/figure + framework
microbenches + the roofline table from the dry-run artifacts.

Prints ``name,us_per_call,derived`` style CSV sections, then a validation
summary checking the paper's claims (exit 1 on any validation failure).
"""
from __future__ import annotations

import sys


def main() -> None:
    failures = {}

    from benchmarks import (bench_dist, bench_engine, bench_kernels,
                            bench_memory, bench_raw_perf, bench_scalability)

    print("## Fig.6 raw performance (executor vs hand-jit vs eager)")
    rows = bench_raw_perf.run()
    failures["fig6"] = bench_raw_perf.validate(rows)

    print("\n## Fig.7 memory allocation strategies")
    rows = bench_memory.run()
    failures["fig7"] = bench_memory.validate(rows)

    print("\n## Fig.8 distributed scalability (two-level KVStore)")
    rows, curves = bench_scalability.run()
    failures["fig8"] = bench_scalability.validate(rows, curves)

    print("\n## §3.3 on-mesh gradient sync (flat vs hierarchical, 2x4x2)")
    rows = bench_dist.run()
    failures["dist"] = bench_dist.validate(rows)

    print("\n## Dependency engine")
    rows = bench_engine.run()
    failures["engine"] = bench_engine.validate(rows)

    print("\n## Pallas kernels (interpret-mode correctness + oracle walls)")
    rows = bench_kernels.run()
    failures["kernels"] = bench_kernels.validate(rows)

    print("\n## Roofline (from experiments/dryrun)")
    try:
        from benchmarks import roofline
        roofline.run(csv=True)
    except Exception as e:  # dry-run artifacts may not exist yet
        print(f"roofline skipped: {e}")

    print("\n## VALIDATION SUMMARY")
    bad = False
    for k, v in failures.items():
        print(f"{k}: {'PASS' if not v else v}")
        bad = bad or bool(v)
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
