"""Benchmark driver: one benchmark per paper table/figure + framework
microbenches + the roofline table from the dry-run artifacts.

Prints ``name,us_per_call,derived`` style CSV sections, then a validation
summary checking the paper's claims (exit 1 on any validation failure).
A crashing benchmark is recorded as a failure in ``BENCH_summary.json``
and the remaining benchmarks still run — one bad bench no longer loses
the whole trajectory record.

``--json PATH`` additionally writes machine-readable records — one
``BENCH_<name>.json`` per benchmark plus ``BENCH_summary.json`` — into
the ``PATH`` directory (the perf trajectory artifact CI uploads).  Every
record carries a ``primary`` metric (the one number that summarizes the
bench, with its improvement direction).

``--compare DIR`` gates the perf trajectory: after running, each bench's
primary metric is compared against the committed baseline record in
``DIR`` (normally ``benchmarks/baselines/``) and the driver exits 1 when
any metric regresses more than ``--tolerance`` (default 20%).  Structural
metrics (byte-model-vs-HLO cross-validation, token parity) are exact
gates inside each bench's ``validate`` and are not subject to tolerance.

``--write-baselines`` refreshes ``benchmarks/baselines/`` from this run
(the workflow is documented in README "Perf-regression gate").
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

# make `python benchmarks/run.py` work from anywhere: the repo root (for
# the `benchmarks` package) and src/ (for `repro`) join sys.path
_ROOT = Path(__file__).resolve().parents[1]
for p in (str(_ROOT), str(_ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

BASELINE_DIR = _ROOT / "benchmarks" / "baselines"

# every bench the driver runs (the registry the baseline-drift guard
# checks): each name must have a committed baselines/BENCH_<name>.json
# and every committed record must correspond to a registered bench
BENCH_NAMES = ("fig6", "fig7", "fig8", "dist", "ring", "pipeline",
               "serving", "checkpoint", "engine", "kernels")


def check_baselines(baseline_dir: Path = BASELINE_DIR) -> list[str]:
    """Baseline-drift guard: a bench registered here with no committed
    baseline record silently escapes the perf gate, and a stale record
    with no bench behind it gates nothing — both fail CI."""
    problems = []
    committed = {p.stem.removeprefix("BENCH_")
                 for p in baseline_dir.glob("BENCH_*.json")} - {"summary"}
    for name in BENCH_NAMES:
        if name not in committed:
            problems.append(
                f"bench '{name}' is registered in run.py but has no "
                f"committed {baseline_dir}/BENCH_{name}.json "
                f"(run.py --write-baselines, then commit)")
    for name in sorted(committed - set(BENCH_NAMES)):
        problems.append(
            f"{baseline_dir}/BENCH_{name}.json has no registered bench "
            f"named '{name}' in run.py (stale record? delete it or "
            f"register the bench)")
    return problems


def _jsonable(v):
    try:
        json.dumps(v)
        return v
    except TypeError:
        return float(v) if hasattr(v, "__float__") else str(v)


def _rowmap(rows) -> dict:
    """``name -> value`` for the standard (name, value, derived) rows."""
    return {r[0]: r[1] for r in rows if len(r) >= 2}


# one number that summarizes each bench — compared against the committed
# baseline by --compare.  Only machine-portable values qualify: compiler
# byte counts, analytic ratios, and throughput ratios of two timings from
# the SAME run.  Absolute wall times never do, which is why fig6 (a pure
# timing bench whose executor/eager ratio swings ~40% with machine load)
# carries no primary — its regressions are caught by its own validate().
def _p_fig7(rows):
    for r in rows:
        if r[0] == "fig7_deep-mlp" and r[1] == "train" and r[2] == "both":
            return r[4]
    raise KeyError("fig7_deep-mlp/train/both row missing")


def _p_dist(rows):
    d = _rowmap(rows)
    return (d["gradient_sync_flat_crosspod_allreduce_bytes"]
            / d["gradient_sync_hierarchical_crosspod_allreduce_bytes"])


_PRIMARY = {
    # name: (metric label, extractor(rows) -> value, better direction)
    "fig7": ("deep_mlp_train_bytes_reduction", _p_fig7, "higher"),
    "fig8": ("fig8_speedup", lambda r: _rowmap(r)["fig8_speedup"], "higher"),
    "dist": ("crosspod_bytes_reduction", _p_dist, "higher"),
    "ring": ("ring_P8_fwd_peak_temp_bytes",
             lambda r: _rowmap(r)["ring_P8_fwd_peak_temp_bytes"], "lower"),
    "pipeline": ("pipeline_P4_grad_permute_bytes_hlo",
                 lambda r: _rowmap(r)["pipeline_P4_grad_permute_bytes_hlo"],
                 "lower"),
    # NOT serving_speedup: the paged/static tok/s ratio swings ~25% with
    # machine load; the peak-cache byte ratio is allocator-deterministic
    # (validate() still gates paged > static throughput structurally)
    "serving": ("serving_cache_ratio",
                lambda r: _rowmap(r)["serving_cache_ratio"], "higher"),
    "engine": ("engine_mean_wave_width",
               lambda r: _rowmap(r)["engine_mean_wave_width"], "higher"),
    # NOT checkpoint_stall_ratio: at ~0.001 the ratio is all scheduler
    # noise, where a +/-20% relative gate is meaningless; validate()
    # gates it at the absolute 25% acceptance bound instead.  The
    # per-host write-volume byte model is analytic and deterministic.
    "checkpoint": ("checkpoint_bytes_per_host_8",
                   lambda r: _rowmap(r)["checkpoint_bytes_per_host_8"],
                   "lower"),
    # kernels' correctness rows (maxerr) sit at the fp noise floor where a
    # +/-20% relative gate is meaningless; the gated primary is the
    # autotune sweep's tuned-vs-default speedup geomean — a same-run
    # timing RATIO, which survives machine/XLA changes (and is >= 1.0 by
    # construction since the defaults are always in the sweep)
    "kernels": ("kernels_tuned_speedup_geomean",
                lambda r: _rowmap(r)["kernels_tuned_speedup_geomean"],
                "higher"),
}


def _primary_record(name, rows):
    entry = _PRIMARY.get(name)
    if entry is None:
        return None
    label, extract, better = entry
    try:
        return {"metric": label, "value": float(extract(rows)),
                "better": better}
    except Exception as e:  # noqa: BLE001 — a crashed bench has no rows
        return {"metric": label, "value": None, "better": better,
                "error": f"{type(e).__name__}: {e}"}


def compare_primaries(records: dict, baseline_dir: Path,
                      tolerance: float) -> list[str]:
    """Primary-metric regressions vs the committed baseline records."""
    failures = []
    print(f"\n## PERF vs baselines ({baseline_dir}, tolerance "
          f"{tolerance:.0%})")
    for name, rec in records.items():
        pr = rec.get("primary")
        path = baseline_dir / f"BENCH_{name}.json"
        if pr is None:
            continue
        if not path.exists():
            print(f"{name}: no baseline record — skipped "
                  f"(run.py --write-baselines to add one)")
            continue
        base = json.loads(path.read_text()).get("primary") or {}
        if base.get("metric") != pr["metric"] or base.get("value") is None:
            print(f"{name}: baseline lacks comparable primary — skipped")
            continue
        if pr.get("value") is None:
            failures.append(f"{name}: no primary value this run "
                            f"({pr.get('error', 'bench crashed')})")
            continue
        bv, nv = float(base["value"]), float(pr["value"])
        if pr["better"] == "higher":
            bad = nv < bv * (1 - tolerance)
        else:
            bad = nv > bv * (1 + tolerance)
        verdict = "REGRESSED" if bad else "ok"
        # absolute delta alongside the percentage: near-zero baselines
        # make relative numbers unreadable in CI logs
        delta = nv - bv
        rel = delta / bv if bv else float("inf")
        print(f"{name}: {pr['metric']} {nv:.6g} vs baseline {bv:.6g} "
              f"(delta {delta:+.6g}, {rel:+.2%}; {pr['better']} is better) "
              f"-> {verdict}")
        if bad:
            failures.append(
                f"{name}: {pr['metric']} regressed beyond {tolerance:.0%}: "
                f"{nv:.6g} vs baseline {bv:.6g} (delta {delta:+.6g}, "
                f"{rel:+.2%}; {pr['better']} is better)")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="directory for BENCH_*.json records (created)")
    ap.add_argument("--compare", metavar="DIR", default=None,
                    help="gate primary metrics against the baseline "
                         "records in DIR (exit 1 on >tolerance regression)")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed relative regression for --compare")
    ap.add_argument("--write-baselines", action="store_true",
                    help=f"refresh {BASELINE_DIR} from this run")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="record host spans across the benches and write a "
                         "Perfetto / chrome://tracing JSON.  NOTE: tracing "
                         "perturbs fig6's executor/eager timing ratios — "
                         "don't combine with --compare gating")
    ap.add_argument("--metrics", metavar="PATH", default=None,
                    help="write the final metrics-registry snapshot "
                         "(serving latency histograms, engine gauges) "
                         "as JSONL")
    ap.add_argument("--check-baselines", action="store_true",
                    help="baseline-drift guard only (no benches run): "
                         "every registered bench must have a committed "
                         "baseline record and vice versa; exit 1 on drift")
    args = ap.parse_args()

    if args.check_baselines:
        problems = check_baselines()
        for p in problems:
            print(f"BASELINE DRIFT: {p}")
        if not problems:
            print(f"baseline records in sync with run.py registry "
                  f"({len(BENCH_NAMES)} benches)")
        sys.exit(1 if problems else 0)

    from repro import obs
    if args.trace:
        obs.enable()

    failures = {}
    records = {}

    def record(name, rows, fails):
        failures[name] = fails
        records[name] = {
            "bench": name,
            "rows": [[_jsonable(x) for x in row] for row in rows],
            "failures": list(fails) if fails else [],
            "primary": _primary_record(name, rows),
        }

    def run_bench(name, title, fn):
        """One bench, crash-isolated: a raising bench becomes a recorded
        failure instead of killing the driver (and every later record)."""
        print(title)
        try:
            rows, fails = fn()
        except Exception as e:  # noqa: BLE001 — record and continue
            traceback.print_exc()
            rows, fails = [], [f"crashed: {type(e).__name__}: {e}"]
        record(name, rows, fails)

    from benchmarks import (bench_checkpoint, bench_dist, bench_engine,
                            bench_kernels, bench_memory, bench_pipeline,
                            bench_raw_perf, bench_ring, bench_scalability,
                            bench_serving)

    def _std(mod):
        """run() then validate(rows) — the shape every bench shares."""
        def fn():
            rows = mod.run()
            return rows, mod.validate(rows)
        return fn

    def _scalability():
        rows, curves = bench_scalability.run()
        return rows, bench_scalability.validate(rows, curves)

    benches = [
        ("fig6", "## Fig.6 raw performance (executor vs hand-jit vs eager)",
         _std(bench_raw_perf)),
        ("fig7", "\n## Fig.7 memory allocation strategies",
         _std(bench_memory)),
        ("fig8", "\n## Fig.8 distributed scalability (two-level KVStore)",
         _scalability),
        ("dist", "\n## §3.3 on-mesh gradient sync (flat vs hier, 2x4x2)",
         _std(bench_dist)),
        ("ring", "\n## §8 ring attention (sequence-sharded long context)",
         _std(bench_ring)),
        ("pipeline", "\n## §10 pipeline parallelism (1F1B stage schedule)",
         _std(bench_pipeline)),
        ("serving", "\n## §9 serving: paged KV-cache + continuous batching",
         _std(bench_serving)),
        ("checkpoint",
         "\n## §12 sharded async checkpointing (save stall + byte model)",
         _std(bench_checkpoint)),
        ("engine", "\n## Dependency engine", _std(bench_engine)),
        ("kernels", "\n## Pallas kernels (interpret-mode + oracle walls)",
         _std(bench_kernels)),
    ]
    assert tuple(n for n, _, _ in benches) == BENCH_NAMES, \
        "bench list drifted from the BENCH_NAMES registry"
    for name, title, fn in benches:
        run_bench(name, title, fn)

    print("\n## Roofline (from experiments/dryrun)")
    try:
        from benchmarks import roofline
        roofline.run(csv=True)
    except Exception as e:  # dry-run artifacts may not exist yet
        print(f"roofline skipped: {e}")

    print("\n## VALIDATION SUMMARY")
    bad = False
    for k, v in failures.items():
        print(f"{k}: {'PASS' if not v else v}")
        bad = bad or bool(v)

    compare_failures = []
    if args.compare:
        compare_failures = compare_primaries(records, Path(args.compare),
                                             args.tolerance)
        for f in compare_failures:
            print(f"PERF REGRESSION: {f}")
        bad = bad or bool(compare_failures)

    out_dirs = [Path(args.json)] if args.json else []
    if args.write_baselines:
        out_dirs.append(BASELINE_DIR)
    for outdir in out_dirs:
        import jax
        outdir.mkdir(parents=True, exist_ok=True)
        meta = {"timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                           time.gmtime()),
                "backend": jax.default_backend(),
                "jax_version": jax.__version__}
        for name, rec in records.items():
            path = outdir / f"BENCH_{name}.json"
            path.write_text(json.dumps({**meta, **rec}, indent=1))
        summary = {**meta,
                   "benches": {k: ("PASS" if not v else list(v))
                               for k, v in failures.items()},
                   "perf_regressions": compare_failures}
        (outdir / "BENCH_summary.json").write_text(
            json.dumps(summary, indent=1))
        print(f"wrote {len(records) + 1} BENCH_*.json records to {outdir}")

    if args.metrics:
        obs.get_metrics().dump_jsonl(args.metrics)
        print(f"metrics: {args.metrics}")
    if args.trace:
        obs.export(args.trace)
        print(f"trace: {args.trace}")

    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
