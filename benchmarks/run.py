"""Benchmark driver: one benchmark per paper table/figure + framework
microbenches + the roofline table from the dry-run artifacts.

Prints ``name,us_per_call,derived`` style CSV sections, then a validation
summary checking the paper's claims (exit 1 on any validation failure).

``--json PATH`` additionally writes machine-readable records — one
``BENCH_<name>.json`` per benchmark plus ``BENCH_summary.json`` — into
the ``PATH`` directory (the perf trajectory artifact CI uploads).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

# make `python benchmarks/run.py` work from anywhere: the repo root (for
# the `benchmarks` package) and src/ (for `repro`) join sys.path
_ROOT = Path(__file__).resolve().parents[1]
for p in (str(_ROOT), str(_ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)


def _jsonable(v):
    try:
        json.dumps(v)
        return v
    except TypeError:
        return float(v) if hasattr(v, "__float__") else str(v)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="directory for BENCH_*.json records (created)")
    args = ap.parse_args()

    failures = {}
    records = {}

    def record(name, rows, fails):
        failures[name] = fails
        records[name] = {
            "bench": name,
            "rows": [[_jsonable(x) for x in row] for row in rows],
            "failures": list(fails) if fails else [],
        }

    from benchmarks import (bench_dist, bench_engine, bench_kernels,
                            bench_memory, bench_raw_perf, bench_ring,
                            bench_scalability, bench_serving)

    print("## Fig.6 raw performance (executor vs hand-jit vs eager)")
    rows = bench_raw_perf.run()
    record("fig6", rows, bench_raw_perf.validate(rows))

    print("\n## Fig.7 memory allocation strategies")
    rows = bench_memory.run()
    record("fig7", rows, bench_memory.validate(rows))

    print("\n## Fig.8 distributed scalability (two-level KVStore)")
    rows, curves = bench_scalability.run()
    record("fig8", rows, bench_scalability.validate(rows, curves))

    print("\n## §3.3 on-mesh gradient sync (flat vs hierarchical, 2x4x2)")
    rows = bench_dist.run()
    record("dist", rows, bench_dist.validate(rows))

    print("\n## §8 ring attention (sequence-sharded long context)")
    rows = bench_ring.run()
    record("ring", rows, bench_ring.validate(rows))

    print("\n## §9 serving: paged KV-cache + continuous batching vs static")
    rows = bench_serving.run()
    record("serving", rows, bench_serving.validate(rows))

    print("\n## Dependency engine")
    rows = bench_engine.run()
    record("engine", rows, bench_engine.validate(rows))

    print("\n## Pallas kernels (interpret-mode correctness + oracle walls)")
    rows = bench_kernels.run()
    record("kernels", rows, bench_kernels.validate(rows))

    print("\n## Roofline (from experiments/dryrun)")
    try:
        from benchmarks import roofline
        roofline.run(csv=True)
    except Exception as e:  # dry-run artifacts may not exist yet
        print(f"roofline skipped: {e}")

    print("\n## VALIDATION SUMMARY")
    bad = False
    for k, v in failures.items():
        print(f"{k}: {'PASS' if not v else v}")
        bad = bad or bool(v)

    if args.json:
        import jax
        outdir = Path(args.json)
        outdir.mkdir(parents=True, exist_ok=True)
        meta = {"timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                           time.gmtime()),
                "backend": jax.default_backend(),
                "jax_version": jax.__version__}
        for name, rec in records.items():
            path = outdir / f"BENCH_{name}.json"
            path.write_text(json.dumps({**meta, **rec}, indent=1))
        summary = {**meta,
                   "benches": {k: ("PASS" if not v else list(v))
                               for k, v in failures.items()}}
        (outdir / "BENCH_summary.json").write_text(
            json.dumps(summary, indent=1))
        print(f"wrote {len(records) + 1} BENCH_*.json records to {outdir}")

    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
