"""Ring attention on a sequence-sharded mesh (DESIGN.md §8): per-device
peak attention activation bytes vs the number of sequence shards, and the
analytic collective-permute byte model cross-validated against the
compiled HLO — the same HLO-vs-model discipline ``bench_dist.py``
established for the all-reduce schedules.

For each shard count P in {1, 2, 4, 8} (one mesh axis, "model"):

* lower + compile ``ring_attention`` forward and grad on a fixed
  (B=1, S=4096, H=8, K=4, hd=64) f32 problem;
* read ``memory_analysis().temp_size_in_bytes`` — the per-device peak of
  the attention activations (the jitted function *is* the attention call,
  so temps are scores/probs/carry state only).  The claim under test: it
  shrinks at least ~linearly in P (the score block alone shrinks
  quadratically: (S/P)² per step instead of S²);
* parse collective-permute bytes out of the compiled HLO and require them
  to equal ``ring_permute_bytes`` *exactly* — forward
  ``max(contributing_steps)·2·chunk``, grad adds the reverse ring's
  ``(P-1)·2·chunk + P·2·chunk_f32`` (dk/dv are f32 accumulators);
* repeat at P=8 with a sliding window that masks all but one ring hop,
  checking the windowed early-stop byte model.

Multi-device lowering needs --xla_force_host_platform_device_count before
jax initializes, so measurement runs in a subprocess (CSV rows out).

Usage:  PYTHONPATH=src python benchmarks/bench_ring.py

CSV: name,value,derived
"""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")

B, S, H, K, HD = 1, 4096, 8, 4, 64
SHARDS = (1, 2, 4, 8)
WINDOW = 512          # at P=8 (chunk 512): ring steps 0..1 contribute
ITEMSIZE = 4          # f32 on the CPU bench

_BODY = f"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import jax, jax.numpy as jnp
from repro.dist.ring import ring_attention
from repro.launch.dryrun import collective_bytes

B, S, H, K, HD = {B}, {S}, {H}, {K}, {HD}
q = jnp.zeros((B, S, H, HD), jnp.float32)
k = jnp.zeros((B, S, K, HD), jnp.float32)
v = jnp.zeros((B, S, K, HD), jnp.float32)

def measure(P, window):
    mesh = jax.make_mesh((P,), ("model",))
    def attn(q, k, v):
        return ring_attention(q, k, v, causal=True, window=window)
    def loss(q, k, v):
        return ring_attention(q, k, v, causal=True,
                              window=window).astype(jnp.float32).sum()
    with jax.set_mesh(mesh):
        cf = jax.jit(attn).lower(q, k, v).compile()
        cg = jax.jit(jax.grad(loss, argnums=(0, 1, 2))).lower(
            q, k, v).compile()
    tag = f"P{{P}}" + ("" if window is None else f"_w{{window}}")
    for name, comp in (("fwd", cf), ("grad", cg)):
        coll = collective_bytes(comp.as_text())
        mem = comp.memory_analysis()
        print(f"RESULT,{{tag}},{{name}}_permute_bytes,"
              f"{{int(coll['raw']['collective-permute'])}}")
        print(f"RESULT,{{tag}},{{name}}_permute_count,"
              f"{{coll['counts']['collective-permute']}}")
        print(f"RESULT,{{tag}},{{name}}_peak_temp_bytes,"
              f"{{mem.temp_size_in_bytes}}")

for P in {SHARDS}:
    measure(P, None)
measure(8, {WINDOW})
"""


def _measure() -> dict:
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _BODY], capture_output=True,
                       text=True, env=env, timeout=560)
    if r.returncode != 0:
        raise RuntimeError(f"bench_ring subprocess failed:\n{r.stderr[-2000:]}")
    out = {}
    for line in r.stdout.splitlines():
        if line.startswith("RESULT,"):
            _, tag, metric, value = line.split(",")
            out[(tag, metric)] = float(value)
    return out


def _analytic(P: int, window=None) -> dict:
    from repro.dist.ring import ring_permute_bytes
    return ring_permute_bytes(B, S, K, HD, P, itemsize=ITEMSIZE,
                              causal=True, window=window)


def run(csv: bool = True):
    vals = _measure()
    rows = []

    def emit(name, value, derived=""):
        rows.append((name, value, derived))
        if csv:
            print(f"{name},{value},{derived}")

    for P in SHARDS:
        model = _analytic(P)
        tag = f"P{P}"
        derived = {
            "fwd": f"{model['fwd_rotations']} rot x {model['per_step_fwd']}B",
            "grad": f"fwd + {model['bwd_rotations']} bwd rot",
        }
        for d, key in (("fwd", "fwd_total"), ("grad", "grad_total")):
            emit(f"ring_{tag}_{d}_permute_bytes_hlo",
                 vals[(tag, f"{d}_permute_bytes")],
                 f"{int(vals[(tag, f'{d}_permute_count')])} permutes")
            emit(f"ring_{tag}_{d}_permute_bytes_analytic", model[key],
                 derived[d])
        emit(f"ring_{tag}_fwd_peak_temp_bytes",
             vals[(tag, "fwd_peak_temp_bytes")],
             f"S/P={S // P}")
    # windowed early-stop model at P=8
    model = _analytic(8, window=WINDOW)
    tag = f"P8_w{WINDOW}"
    emit(f"ring_{tag}_fwd_permute_bytes_hlo",
         vals[(tag, "fwd_permute_bytes")])
    emit(f"ring_{tag}_fwd_permute_bytes_analytic", model["fwd_total"],
         f"{model['fwd_rotations']} of 7 rotations (window early-stop)")
    emit(f"ring_{tag}_grad_permute_bytes_hlo",
         vals[(tag, "grad_permute_bytes")])
    emit(f"ring_{tag}_grad_permute_bytes_analytic", model["grad_total"])
    return rows


def validate(rows) -> list[str]:
    """Acceptance (ISSUE 3): analytic permute bytes == compiled-HLO bytes
    exactly, and per-device peak attention bytes shrink ~linearly in P."""
    d = {name: value for name, value, _ in rows}
    failures = []
    tags = [f"P{P}" for P in SHARDS] + [f"P8_w{WINDOW}"]
    for tag in tags:
        for direction in ("fwd", "grad"):
            hlo = d.get(f"ring_{tag}_{direction}_permute_bytes_hlo")
            ana = d.get(f"ring_{tag}_{direction}_permute_bytes_analytic")
            if hlo is None or ana is None:
                failures.append(f"missing ring measurement {tag}/{direction}")
            elif hlo != ana:
                failures.append(
                    f"{tag} {direction}: HLO permute bytes {hlo} != "
                    f"analytic {ana}")
    multi = [P for P in SHARDS if P > 1]
    if not any(d.get(f"ring_P{P}_fwd_permute_bytes_hlo", 0) for P in multi):
        failures.append("no collective-permutes found on any multi-shard "
                        "mesh — the ring schedule did not run")
    peaks = {P: d.get(f"ring_P{P}_fwd_peak_temp_bytes", 0) for P in SHARDS}
    if not all(peaks.values()):
        failures.append(f"missing/zero peak temp bytes: {peaks}")
    else:
        for prev, P in zip(SHARDS, SHARDS[1:]):
            if peaks[P] > peaks[prev] / 1.5:
                failures.append(
                    f"peak attention bytes did not shrink ~linearly: "
                    f"P={prev}: {peaks[prev]:.0f} -> P={P}: {peaks[P]:.0f} "
                    f"(ratio {peaks[prev] / peaks[P]:.2f} < 1.5)")
    return failures


if __name__ == "__main__":
    rows = run()
    bad = validate(rows)
    print("PASS" if not bad else bad)
    sys.exit(1 if bad else 0)
