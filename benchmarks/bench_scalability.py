"""Fig. 8 reproduction: distributed training scalability/convergence.

Paper setup: googlenet on ILSVRC12, 1 vs 10 machines (4 GPUs each),
batch-per-GPU fixed => 10x aggregate batch on the cluster; distributed
converges slower for the first passes then overtakes; time-per-pass
14K s -> 1.4K s (super-linear, a caching artifact).

Scaled-down analogue: an MLP classifier on a synthetic task through the
two-level KVStoreDist, 1 worker vs 10 machines x 4 devices, batch-per-
device fixed.  We measure (a) loss vs data passes for both settings and
both consistency models, (b) a time-per-pass cost model from the measured
two-level byte counters (compute/worker + comm over 10G Ethernet like the
paper's cluster).

CSV: name,value,derived
"""
from __future__ import annotations

import numpy as np

from repro.core import KVStoreDist

# synthetic classification task
D_IN, N_CLS, N_TRAIN = 64, 10, 4096
BATCH_PER_DEV = 32
PASSES = 8


def make_task(seed=0):
    rng = np.random.RandomState(seed)
    W = rng.randn(N_CLS, D_IN).astype(np.float32)
    X = rng.randn(N_TRAIN, D_IN).astype(np.float32)
    y = np.argmax(X @ W.T + 0.5 * rng.randn(N_TRAIN, N_CLS), axis=1)
    return X, y


def loss_grad(w, X, y):
    logits = X @ w.T                          # w: (C, D)
    logits -= logits.max(1, keepdims=True)
    p = np.exp(logits)
    p /= p.sum(1, keepdims=True)
    n = len(y)
    loss = -np.mean(np.log(p[np.arange(n), y] + 1e-12))
    dlog = p
    dlog[np.arange(n), y] -= 1
    return loss, (dlog.T @ X) / n


def train(n_machines, devs_per_machine, consistency, lr=0.2, seed=0):
    X, y = make_task(seed)
    n_workers = n_machines * devs_per_machine
    kv = KVStoreDist(n_machines, devs_per_machine, consistency=consistency,
                     staleness=1)
    kv.set_updater(lambda k, s, g: s - lr * np.asarray(g))
    kv.init("w", np.zeros((N_CLS, D_IN), np.float32))
    rng = np.random.RandomState(seed)
    losses = []
    steps_per_pass = N_TRAIN // (BATCH_PER_DEV * n_workers)
    for p in range(PASSES):
        order = rng.permutation(N_TRAIN)
        pass_loss = []
        for s in range(steps_per_pass):
            base = s * BATCH_PER_DEV * n_workers
            for wk in range(n_workers):
                idx = order[base + wk * BATCH_PER_DEV:
                            base + (wk + 1) * BATCH_PER_DEV]
                w = np.asarray(kv.pull("w", wk))
                l, g = loss_grad(w, X[idx], y[idx])
                kv.push("w", wk, g / n_workers)
                pass_loss.append(l)
        losses.append(float(np.mean(pass_loss)))
    return losses, kv


def cost_model(kv, n_machines, devs_per_machine):
    """Seconds per data pass: compute scales 1/workers; comm from the
    two-level byte counters over the paper's 10G Ethernet + PCIe."""
    n_workers = n_machines * devs_per_machine
    compute_s = 100.0 / n_workers           # normalized single-worker = 100s
    pcie_bw, eth_bw = 8e9, 1.25e9           # bytes/s
    comm_s = (kv.bytes_l1 / PASSES / pcie_bw / max(devs_per_machine, 1)
              + kv.bytes_l2 / PASSES / eth_bw / max(n_machines - 1, 1))
    return compute_s + comm_s


def run(csv=True):
    rows = []
    single, _ = train(1, 1, "sequential")
    dist_seq, kv_seq = train(10, 4, "sequential")
    dist_ev, kv_ev = train(10, 4, "eventual")
    for name, ls in [("fig8_single_worker", single),
                     ("fig8_dist40_sequential", dist_seq),
                     ("fig8_dist40_eventual", dist_ev)]:
        rows.append((f"{name}_first_pass_loss", round(ls[0], 4), ""))
        rows.append((f"{name}_final_loss", round(ls[-1], 4), ""))
    t1 = cost_model(kv_seq, 1, 1) + 100.0 - 100.0  # single: no comm
    t10 = cost_model(kv_seq, 10, 4)
    rows.append(("fig8_time_per_pass_single_s", 100.0, ""))
    rows.append(("fig8_time_per_pass_dist_s", round(t10, 2), ""))
    rows.append(("fig8_speedup", round(100.0 / t10, 2),
                 "paper: 10x (super-linear, cache artifact)"))
    two_level_saving = kv_seq.bytes_l1 / max(kv_seq.bytes_l2, 1)
    rows.append(("fig8_l2_bytes_reduction_from_two_level",
                 round(two_level_saving, 2), "== devices per machine"))
    if csv:
        print("name,value,derived")
        for r in rows:
            print(",".join(str(x) for x in r))
    return rows, (single, dist_seq, dist_ev)


def validate(rows, curves) -> list[str]:
    single, dist_seq, dist_ev = curves
    failures = []
    # paper: distributed converges slower at the beginning...
    if not dist_seq[0] >= single[0] - 0.05:
        failures.append("distributed should start no faster than single")
    # ...but still converges (we check it reaches a low loss)
    if not dist_seq[-1] < 0.75 * dist_seq[0]:
        failures.append(f"dist sequential did not converge: {dist_seq}")
    if not dist_ev[-1] < 0.75 * dist_ev[0]:
        failures.append(f"dist eventual did not converge: {dist_ev}")
    by = dict((r[0], r[1]) for r in rows)
    if by["fig8_l2_bytes_reduction_from_two_level"] != 4.0:
        failures.append("two-level aggregation should cut inter-machine "
                        "bytes by devices-per-machine (4)")
    if by["fig8_speedup"] < 5.0:
        failures.append(f"speedup {by['fig8_speedup']} < 5x")
    return failures


if __name__ == "__main__":
    rows, curves = run()
    print("VALIDATION:", validate(rows, curves) or "PASS")
