"""Roofline analysis (deliverable g) from the dry-run artifacts.

Per (arch × shape) on the single-pod 16×16 mesh:
  compute term    = HLO_FLOPs_per_device / 197 TFLOP/s
  memory term     = HLO_bytes_per_device / 819 GB/s
  collective term = link_bytes_per_device / 50 GB/s

cost_analysis counts lax.scan bodies once, so totals are composed from the
unrolled 1- and 2-superblock probes:
    per_super = probe2 - probe1;  base = probe1 - per_super
    total     = base + n_super * per_super
(The full-model compile is still the existence/memory proof; its aggregate
numbers are recorded as `full_*` with the scan caveat.)

MODEL_FLOPS = 6·N·T (training; fwd 2NT + bwd 4NT) or 2·N·T (prefill) or
2·N_active·B (decode), per device (÷256 chips), with N_active for MoE.
The ratio MODEL/HLO exposes remat/redundancy waste (training with block
remat recomputes the forward: ideal ratio ≈ 6/8 = 0.75).

Usage: python -m benchmarks.roofline [--csv|--md] [--out FILE]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = 256

SHAPE_TOKENS = {  # (tokens per step, flops factor: train 6, fwd-only 2)
    "train_4k": (256 * 4096, 6),
    "prefill_32k": (32 * 32768, 2),
    "decode_32k": (128 * 1, 2),
    "long_500k": (1 * 1, 2),
    "long_500k_prefill": (1 * 524288, 2),
}


def _n_super(rec) -> int:
    from repro.configs import LONG_CONTEXT_ARCHS, get_config
    seq_shard = bool(rec.get("seq_shard"))
    long_ctx = (rec["shape"].startswith("long_500k")
                and (rec["arch"] in LONG_CONTEXT_ARCHS or seq_shard))
    return get_config(rec["arch"], long_context=long_ctx,
                      seq_shard=seq_shard).n_super


def composed(rec, field_path, ns):
    """base + n_super * per_super from the {2,4}-superblock probes;
    falls back to full (scan caveat noted)."""
    def get(block):
        cur = rec.get(block)
        if cur is None:
            return None
        for k in field_path:
            cur = cur[k]
        return cur
    p2, p4 = get("probe2"), get("probe4")
    full = get("full")
    if p2 is None or p4 is None:
        return full, "full(scan-caveat)"
    per = (p4 - p2) / 2.0
    base = p2 - 2.0 * per
    return base + ns * per, "probes"


def analyze_record(rec):
    if rec.get("status") != "OK":
        return None
    ns = rec.get("n_super") or _n_super(rec)
    flops, src = composed(rec, ("flops",), ns)
    mem_bytes, _ = composed(rec, ("bytes_accessed",), ns)
    coll, _ = composed(rec, ("collectives", "total"), ns)
    t_comp = flops / PEAK_FLOPS
    t_mem = mem_bytes / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)

    tokens, factor = SHAPE_TOKENS[rec["shape"]]
    n_active = rec.get("params_active", rec["params"])
    model_flops_dev = factor * n_active * tokens / CHIPS
    ratio = model_flops_dev / flops if flops else 0.0
    step_t = max(terms.values())
    mfu = model_flops_dev / PEAK_FLOPS / step_t if step_t else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "flops_per_dev": flops, "bytes_per_dev": mem_bytes,
        "coll_bytes_per_dev": coll,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "bottleneck": dom,
        "model_flops_per_dev": model_flops_dev,
        "useful_ratio": ratio,
        "roofline_mfu": mfu,
        "peak_gib_per_dev": rec["full"]["memory"]["peak_per_device"] / 2**30,
        "source": src,
    }


def load_all(mesh="16x16"):
    out = []
    # plain records plus the __ring-suffixed seq-shard records the
    # dry-run's --seq-shard mode writes (same shape names, ring schedule)
    files = sorted(DRYRUN_DIR.glob(f"*__{mesh}.json")) + \
        sorted(DRYRUN_DIR.glob(f"*__{mesh}__ring.json"))
    for f in files:
        rec = json.loads(f.read_text())
        r = analyze_record(rec)
        if r:
            if rec.get("seq_shard"):
                r["shape"] += "+ring"
            out.append(r)
    skips = []
    for f in sorted(DRYRUN_DIR.glob("*__skip.json")):
        rec = json.loads(f.read_text())
        skips.append((rec["arch"], rec["shape"], rec.get("reason", "")))
    return out, skips


def fmt_md(rows, skips):
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "bottleneck | useful(MODEL/HLO) | roofline MFU | peak GiB/dev |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | "
            f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
            f"**{r['bottleneck']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_mfu']:.1%} | {r['peak_gib_per_dev']:.1f} |")
    if skips:
        lines.append("\nSkipped (documented in DESIGN.md §5):")
        for a, s, why in skips:
            lines.append(f"- {a} × {s}: {why}")
    return "\n".join(lines)


def fmt_csv(rows):
    cols = ["arch", "shape", "t_compute_s", "t_memory_s", "t_collective_s",
            "bottleneck", "useful_ratio", "roofline_mfu", "peak_gib_per_dev"]
    lines = [",".join(cols)]
    for r in rows:
        lines.append(",".join(
            f"{r[c]:.6g}" if isinstance(r[c], float) else str(r[c])
            for c in cols))
    return "\n".join(lines)


def run(csv=True):
    rows, skips = load_all()
    print(fmt_csv(rows) if csv else fmt_md(rows, skips))
    return rows


def fmt_opt_diff():
    """Baseline vs optimized (dryrun_opt) comparison table."""
    opt_dir = DRYRUN_DIR.parent / "dryrun_opt"
    lines = ["| pair | term | baseline | optimized | Δ |", "|---|---|---|---|---|"]
    for f in sorted(opt_dir.glob("*__16x16.json")):
        opt = json.loads(f.read_text())
        base_f = DRYRUN_DIR / f.name
        if opt.get("status") != "OK" or not base_f.exists():
            continue
        base = json.loads(base_f.read_text())
        ro, rb = analyze_record(opt), analyze_record(base)
        pair = f"{opt['arch']} × {opt['shape']}"
        for term, key in [("peak GiB/dev", "peak_gib_per_dev"),
                          ("collective s", "t_collective_s"),
                          ("memory s", "t_memory_s")]:
            b, o = rb[key], ro[key]
            if b <= 0:
                continue
            lines.append(f"| {pair} | {term} | {b:.3f} | {o:.3f} | "
                         f"{(o / b - 1) * 100:+.0f}% |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="baseline vs optimized diff table")
    ap.add_argument("--out")
    args = ap.parse_args()
    if args.opt:
        text = fmt_opt_diff()
    else:
        rows, skips = load_all()
        text = fmt_md(rows, skips) if args.md else fmt_csv(rows)
    if args.out:
        Path(args.out).write_text(text + "\n")
    print(text)


if __name__ == "__main__":
    main()
