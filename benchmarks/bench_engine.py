"""Dependency-engine microbenchmarks (§3.2): scheduling overhead per op,
discovered parallelism (wave widths) for mixed imperative/symbolic loads,
and the mutation-serialization guarantee cost.

CSV: name,us_per_call,derived
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import Engine, NDArray


def time_fn(fn, n=5, warmup=1):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def bench_push_overhead(n_ops=2000):
    def run():
        eng = Engine(record_waves=False)
        a = NDArray(np.ones(4, np.float32), engine=eng)
        for _ in range(n_ops):
            a = a + 1.0
        eng.wait_all()
    return time_fn(run) / n_ops


def bench_parallelism_width(width=64, depth=10):
    eng = Engine()
    arrs = [NDArray(np.ones(8, np.float32), engine=eng)
            for _ in range(width)]
    for _ in range(depth):
        arrs = [a * 1.001 for a in arrs]
    eng.wait_all()
    s = eng.stats()
    return s["max_wave"], s["mean_wave"]


def bench_mixed_load():
    """Symbolic executor + imperative updates + kvstore in one queue."""
    from repro.core import KVStoreLocal, Variable, FullyConnected, \
        SoftmaxOutput, sgd_updater, reset_default_engine
    rng = np.random.RandomState(0)
    eng = reset_default_engine()
    data, label = Variable("data"), Variable("label")
    net = SoftmaxOutput(FullyConnected(data, 32, name="fc"), label)[0]
    args = {"data": rng.randn(64, 16).astype(np.float32),
            "label": rng.randint(0, 10, 64).astype(np.float32),
            "fc_weight": rng.randn(32, 16).astype(np.float32) * .1,
            "fc_bias": np.zeros(32, np.float32)}
    kv = KVStoreLocal(eng)
    kv.set_updater(sgd_updater(0.1))
    kv.init("w", args["fc_weight"])
    w = NDArray(args["fc_weight"], engine=eng)
    ex = net.bind({**args, "fc_weight": w}, grad_wrt=["fc_weight"],
                  check_plan=False)

    def run():
        for _ in range(10):
            kv.pull("w", out=w)
            _, grads = ex.forward_backward(lazy=True)
            kv.push("w", grads["fc_weight"])
        eng.wait_all()
    us = time_fn(run) / 10
    return us, eng.stats()


def run(csv=True):
    rows = []
    rows.append(("engine_push_overhead_per_op", round(bench_push_overhead(), 2),
                 "python-side schedule+exec cost"))
    mw, meanw = bench_parallelism_width()
    rows.append(("engine_max_wave_width", mw, "64 independent chains"))
    rows.append(("engine_mean_wave_width", round(meanw, 1), ""))
    us, stats = bench_mixed_load()
    rows.append(("engine_mixed_train_step_us", round(us, 1),
                 "kv.pull+fwd_bwd+kv.push, jointly scheduled"))
    if csv:
        print("name,us_per_call,derived")
        for r in rows:
            print(",".join(str(x) for x in r))
    return rows


def validate(rows):
    by = {r[0]: r[1] for r in rows}
    fails = []
    if by["engine_max_wave_width"] < 64:
        fails.append("engine failed to discover independent parallelism")
    if by["engine_push_overhead_per_op"] > 2000:
        fails.append("per-op overhead excessive")
    return fails


if __name__ == "__main__":
    rows = run()
    print("VALIDATION:", validate(rows) or "PASS")
