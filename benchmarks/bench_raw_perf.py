"""Fig. 6 reproduction: raw forward-backward performance.

Paper claim: MXNet matches Torch7/Caffe because the compute kernels
dominate and the framework adds no per-op overhead; TensorFlow was 2x
slower (older cudnn).  The CPU/XLA analogue: our Symbol executor (graph-
optimized, fused segments, engine-scheduled) should match a hand-written
jax.jit step; an op-by-op EAGER interpreter (no fusion, no jit) plays the
role of the slow framework.

CSV: name,us_per_call,derived
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.mxnet_mlp import init_args, symbol
from repro.core import reset_default_engine

NETS = {
    "alexnet-fc": ((4096, 4096), 64, 9216),
    "mlp-deep": (tuple([1024] * 8), 64, 1024),
}


def time_fn(fn, n=20, warmup=3):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6  # us


def bench_net(name, hidden, batch, d_in):
    rng = np.random.RandomState(0)
    args = init_args(rng, batch, d_in, num_hidden=hidden)
    wrt = [k for k in args if k.endswith(("weight", "bias"))]
    rows = []

    # 1) our executor: optimized graph compiled whole, engine-scheduled
    reset_default_engine()
    sym = symbol(num_hidden=hidden)[0]
    ex = sym.bind(args, grad_wrt=wrt, optimize=True, check_plan=False,
                  compile_whole=True)

    def run_executor():
        outs, grads = ex.forward_backward(lazy=True)
        ex.engine.wait_all()
        jax.block_until_ready(grads[wrt[0]]._value)
    rows.append((f"fig6_{name}_executor", time_fn(run_executor)))

    # 1b) executor with per-op engine scheduling (fused segments only)
    reset_default_engine()
    ex1b = sym.bind(args, grad_wrt=wrt, optimize=True, check_plan=False)

    def run_executor_perop():
        outs, grads = ex1b.forward_backward(lazy=True)
        ex1b.engine.wait_all()
        jax.block_until_ready(grads[wrt[0]]._value)
    rows.append((f"fig6_{name}_executor_per_op",
                 time_fn(run_executor_perop, n=5)))

    # 2) op-by-op eager interpreter (no fusion, segments unjitted)
    reset_default_engine()
    ex2 = sym.bind(args, grad_wrt=wrt, optimize=False, check_plan=False,
                   jit_segments=False)

    def run_eager():
        outs, grads = ex2.forward_backward(lazy=True)
        ex2.engine.wait_all()
        jax.block_until_ready(grads[wrt[0]]._value)
    rows.append((f"fig6_{name}_eager_per_op", time_fn(run_eager, n=5)))

    # 3) hand-written jax.jit (the "raw kernels" reference)
    jargs = {k: jnp.asarray(v) for k, v in args.items()}

    def ref_loss(params, data, label):
        x = data
        for i in range(len(hidden)):
            x = jnp.maximum(x @ params[f"fc{i}_weight"].T
                            + params[f"fc{i}_bias"], 0)
        logits = x @ params["head_weight"].T + params["head_bias"]
        lp = jax.nn.log_softmax(logits, -1)
        return -jnp.mean(jnp.take_along_axis(
            lp, label[:, None].astype(jnp.int32), -1))

    params = {k: v for k, v in jargs.items() if k not in ("data", "label")}
    grad_fn = jax.jit(jax.value_and_grad(ref_loss))

    def run_jit():
        l, g = grad_fn(params, jargs["data"], jargs["label"])
        jax.block_until_ready(l)
    rows.append((f"fig6_{name}_hand_jax_jit", time_fn(run_jit)))
    return rows


def run(csv=True):
    rows = []
    for name, (hidden, batch, d_in) in NETS.items():
        rows.extend(bench_net(name, hidden, batch, d_in))
    out = []
    for name, us in rows:
        out.append((name, round(us, 1), ""))
    if csv:
        print("name,us_per_call,derived")
        for r in out:
            print(",".join(str(x) for x in r))
    return rows


def validate(rows) -> list[str]:
    by = {r[0]: r[1] for r in rows}
    failures = []
    for name in NETS:
        ours = by[f"fig6_{name}_executor"]
        ref = by[f"fig6_{name}_hand_jax_jit"]
        eager = by[f"fig6_{name}_eager_per_op"]
        # paper claim: the framework path ~= raw kernels (1.3x slack for
        # the python engine + boundary copies)
        if ours > 1.3 * ref:
            failures.append(f"{name}: executor {ours}us vs jit {ref}us")
        if eager < ours:
            failures.append(f"{name}: eager should be slower than executor")
    return failures


if __name__ == "__main__":
    rows = run()
    print("VALIDATION:", validate(rows) or "PASS")
