"""Sharded async checkpointing (DESIGN.md §12): the save-stall benchmark
plus the byte-model and torn-checkpoint structural gates.

Three claims, one per gate:

* **Async overlap** — ``AsyncCheckpointer.save()`` on the async path
  only snapshots device shards to host and enqueues; serialization,
  fsync and the two-phase commit run on the background writer.  The
  caller-visible stall must be <= 25% of a fully synchronous
  gather-serialize-commit save of the same state (the ISSUE 7
  acceptance bound; both numbers from the same run, so the ratio is
  machine-portable in the ``fig7``/``dist`` sense).
* **Byte model** — ``checkpoint_plan()``'s analytic ``total_bytes``
  must equal the bytes actually on disk *exactly* (raw shard files
  carry no container overhead, so the memplan §6 cross-validation
  discipline applies byte-for-byte).
* **Torn checkpoints are never loadable** — a save that dies mid-write
  (FailingFS) must leave a directory that ``find_checkpoints`` skips
  and ``load_checkpoint`` refuses.

Usage:  PYTHONPATH=src python benchmarks/bench_checkpoint.py

CSV: name,value,derived
"""
from __future__ import annotations

import shutil
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

N_LEAVES = 8
LEAF_SHAPE = (1024, 1024)      # 8 x 4 MiB f32 = 32 MiB state
REPS = 3
STALL_RATIO_MAX = 0.25         # ISSUE 7 acceptance bound


def _make_state():
    # device-resident leaves: the async-path stall then includes the
    # device->host shard snapshot, exactly as Trainer.fit pays it
    import jax
    rng = np.random.RandomState(0)
    host = {"blocks": {f"p{i}": {"w": rng.randn(*LEAF_SHAPE)
                                 .astype(np.float32)}
                       for i in range(N_LEAVES - 1)},
            "head": rng.randn(*LEAF_SHAPE).astype(np.float32)}
    return jax.device_put(host)


def _time_saves(state, async_save: bool) -> float:
    """Min caller-visible ``save()`` wall time over REPS reps (fresh
    checkpointer, keep=0 so pruning never pollutes the timing)."""
    from repro.train import AsyncCheckpointer
    root = Path(tempfile.mkdtemp(prefix="bench_ckpt_"))
    try:
        ck = AsyncCheckpointer(root, keep=0, async_save=async_save)
        ck.save(state, step=0)          # warmup: thread spin-up, allocs
        ck.wait_for_checkpoint()
        best = float("inf")
        for i in range(REPS):
            t0 = time.perf_counter()
            ck.save(state, step=i + 1)
            best = min(best, time.perf_counter() - t0)
            ck.wait_for_checkpoint()    # drain before the next rep
        ck.close()
        return best
    finally:
        shutil.rmtree(root, ignore_errors=True)


def _disk_bytes(state) -> tuple[int, int, float]:
    """(shard bytes on disk, shard file count, restore seconds)."""
    from repro.train import load_checkpoint, save_checkpoint
    root = Path(tempfile.mkdtemp(prefix="bench_ckpt_"))
    try:
        d = root / "step_00000000"
        save_checkpoint(d, state, step=0)
        files = sorted(d.glob("*.bin"))
        nbytes = sum(f.stat().st_size for f in files)
        t0 = time.perf_counter()
        restored, _ = load_checkpoint(d, like=state)
        dt = time.perf_counter() - t0
        np.testing.assert_array_equal(restored["head"], state["head"])
        return nbytes, len(files), dt
    finally:
        shutil.rmtree(root, ignore_errors=True)


def _torn_loadable() -> int:
    """1 if a torn (mid-write-failed) checkpoint is discoverable or
    loadable — must be 0."""
    from repro.train import (AsyncCheckpointer, CheckpointError, FailingFS,
                             find_checkpoints, load_checkpoint)
    root = Path(tempfile.mkdtemp(prefix="bench_ckpt_"))
    try:
        state = {"w": np.arange(4096, dtype=np.float32)}
        bad = AsyncCheckpointer(root, async_save=False,
                                fs=FailingFS(fail_after_bytes=256))
        try:
            bad.save(state, step=1)
            return 1                    # the fault never fired
        except (CheckpointError, OSError):
            pass
        if find_checkpoints(root):
            return 1                    # discovery offered the torn dir
        try:
            load_checkpoint(root / "step_00000001")
            return 1                    # ...and it loaded?!
        except (CheckpointError, FileNotFoundError):
            return 0
    finally:
        shutil.rmtree(root, ignore_errors=True)


def run(csv: bool = True):
    from repro.train import checkpoint_plan

    state = _make_state()
    rows = []

    def emit(name, value, derived=""):
        rows.append((name, value, derived))
        if csv:
            print(f"{name},{value},{derived}")

    plan = checkpoint_plan(state)
    plan8 = checkpoint_plan(state, n_hosts=8)
    disk, n_files, restore_s = _disk_bytes(state)
    sync_s = _time_saves(state, async_save=False)
    stall_s = _time_saves(state, async_save=True)

    emit("checkpoint_state_mib", round(plan["total_bytes"] / 2**20, 3),
         f"{plan['n_shards']} leaves/shards")
    emit("checkpoint_bytes_model", plan["total_bytes"],
         "checkpoint_plan() analytic total")
    emit("checkpoint_bytes_disk", disk,
         f"{n_files} raw shard files (gate: == model exactly)")
    emit("checkpoint_bytes_per_host_8", plan8["bytes_per_host"],
         "analytic per-host write volume, 8 hosts")
    emit("checkpoint_sync_save_ms", round(sync_s * 1e3, 2),
         "gather+serialize+fsync+commit on the caller (absolute; "
         "not gated)")
    emit("checkpoint_async_stall_ms", round(stall_s * 1e3, 2),
         "caller-visible save() stall, async path (absolute; not gated)")
    emit("checkpoint_stall_ratio", round(stall_s / sync_s, 4),
         f"async stall / sync save (gate: <= {STALL_RATIO_MAX})")
    emit("checkpoint_restore_ms", round(restore_s * 1e3, 2),
         "single-device elastic restore (absolute; not gated)")
    emit("checkpoint_torn_loadable", _torn_loadable(),
         "torn save discoverable or loadable (gate: 0)")
    return rows


def validate(rows) -> list[str]:
    """Acceptance (ISSUE 7): async stall <= 25% of the sync save, the
    analytic byte model matches disk exactly, and no torn checkpoint is
    ever loadable."""
    d = {name: value for name, value, _ in rows}
    failures = []
    ratio = d.get("checkpoint_stall_ratio")
    if ratio is None:
        failures.append("missing checkpoint_stall_ratio")
    elif ratio > STALL_RATIO_MAX:
        failures.append(
            f"async save stall is {ratio:.0%} of the sync save "
            f"(bound {STALL_RATIO_MAX:.0%}) — serialization is back "
            f"on the step critical path")
    if d.get("checkpoint_bytes_model") != d.get("checkpoint_bytes_disk"):
        failures.append(
            f"byte model {d.get('checkpoint_bytes_model')} != disk "
            f"{d.get('checkpoint_bytes_disk')} — the memplan checkpoint "
            f"model no longer matches the on-disk format")
    if d.get("checkpoint_torn_loadable") != 0:
        failures.append("a torn checkpoint was discoverable or loadable")
    total = d.get("checkpoint_bytes_model", 0)
    per_host = d.get("checkpoint_bytes_per_host_8", 0)
    if not total or per_host != -(-total // 8):
        failures.append(
            f"per-host byte model {per_host} != ceil(total/8)")
    return failures


if __name__ == "__main__":
    rows = run()
    bad = validate(rows)
    print("PASS" if not bad else bad)
    sys.exit(1 if bad else 0)
