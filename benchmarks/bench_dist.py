"""Flat vs hierarchical vs bucketed ``gradient_sync`` on a 2x4x2 host mesh
(§3.3 on-mesh + DESIGN.md §7): wall time per sync plus cross-pod all-reduce
bytes from the compiled HLO.

Bucketed mode additionally reports *per-bucket* cross-pod bytes (each
bucket lowered through the hierarchical schedule on its own) and
cross-validates them two ways:

* their sum must equal the monolithic ``hierarchical`` cross-pod total
  (no bytes appear or vanish when the sync is split for overlap);
* the analytic two-level KVStore counters, with one key per bucket, must
  attribute the same per-bucket traffic shares (``bytes_l2_by_key``) and
  keep the §3.3 level-1/level-2 ratio per key.

Eventual mode (DESIGN.md §15) cross-validates the bounded-staleness
schedule: each of the ``max_staleness + 1`` phase variants is lowered
separately and its compiled cross-pod all-reduce bytes must equal the
analytic ``eventual_crosspod_bytes`` model EXACTLY; the phases must sum
to the monolithic hierarchical total (every bucket still crosses the pod
boundary once per period), and the steady-state per-step mean must show
the ``period``× reduction.

Multi-device lowering needs --xla_force_host_platform_device_count set
before jax initializes, so the measurement runs in a subprocess and
reports one CSV row per (mode, metric).

Usage:  PYTHONPATH=src python benchmarks/bench_dist.py [--mode MODE]
        MODE in {flat, hier, bucketed, eventual, all} (default all)

CSV: name,value,derived
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")

# 8 workers x 1 MiB gradient (8 leaves x 128 KiB) on a 2 pods x 4 data x
# 2 model mesh; 256 KiB buckets -> 4 buckets of 2 leaves each
N_LEAVES = 8
LEAF_ELEMS = 32_768
N_ELEMS = N_LEAVES * LEAF_ELEMS          # 262144 floats = 1 MiB
BUCKET_BYTES = 256 * 1024
STEPS = 20
N_MACHINES, DEVS_PER_MACHINE = 2, 4      # = mesh (pod, data)
MAX_STALENESS = 2                        # eventual: 3-phase round robin

_BODY = f"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=16'
import time
import jax, jax.numpy as jnp, numpy as np
from repro.dist.bucketing import BucketPlan
from repro.dist.collectives import gradient_sync
from repro.launch.dryrun import collective_bytes

MODES = os.environ['BENCH_DIST_MODES'].split(',')
mesh = jax.make_mesh((2, 4, 2), ("pod", "data", "model"))
W = 8
rng = np.random.RandomState(0)
g = {{f"w{{i}}": jnp.asarray(rng.randn(W, {LEAF_ELEMS}), jnp.float32)
     for i in range({N_LEAVES})}}

with jax.set_mesh(mesh):
    for mode in [m for m in MODES if m != "eventual"]:
        f = jax.jit(lambda x, mode=mode: gradient_sync(
            mesh, x, mode=mode, bucket_bytes={BUCKET_BYTES}))
        coll = collective_bytes(f.lower(g).compile().as_text())
        out = f(g)                      # compile + warm
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range({STEPS}):
            out = f(g)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / {STEPS} * 1e6
        print(f"RESULT,{{mode}},us_per_sync,{{us:.1f}}")
        print(f"RESULT,{{mode}},crosspod_allreduce_bytes,"
              f"{{coll['raw']['all-reduce']}}")
        print(f"RESULT,{{mode}},total_collective_bytes,"
              f"{{coll['raw_total']}}")
    if "bucketed" in MODES:
        # per-bucket attribution: lower each bucket's buffer through the
        # hierarchical schedule on its own and read its cross-pod bytes
        leaves, _ = jax.tree.flatten(g)
        plan = BucketPlan.build(leaves, cap_bytes={BUCKET_BYTES},
                                lead_dims=1)
        buffers = plan.pack(leaves, lead_dims=1)
        print(f"RESULT,bucketed,n_buckets,{{plan.n_buckets}}")
        for i, (bucket, buf) in enumerate(zip(plan.buckets, buffers)):
            txt = jax.jit(lambda x: gradient_sync(
                mesh, [x], mode="hierarchical")).lower(buf).compile().as_text()
            coll = collective_bytes(txt)
            print(f"RESULT,bucketed,bucket{{i}}_crosspod_bytes,"
                  f"{{coll['raw']['all-reduce']}}")
            print(f"RESULT,bucketed,bucket{{i}}_payload_bytes,"
                  f"{{bucket.nbytes}}")
    if "eventual" in MODES:
        # bounded-staleness schedule: lower every phase variant and read
        # its cross-pod all-reduce bytes off the compiled HLO
        from repro.dist.collectives import EventualSync
        ev = EventualSync(mesh, g, max_staleness={MAX_STALENESS},
                          bucket_bytes={BUCKET_BYTES})
        state = ev.init_state()
        print(f"RESULT,eventual,n_buckets,{{ev.n_buckets}}")
        print(f"RESULT,eventual,period,{{ev.period}}")
        print(f"RESULT,eventual,state_bytes_per_worker,"
              f"{{ev.state_bytes()['per_worker']}}")
        variants = [(p, False) for p in range(ev.period)] + [(0, True)]
        total_us = 0.0
        for phase, warm in variants:
            f = jax.jit(lambda x, s, phase=phase, warm=warm:
                        ev.apply(x, s, phase=phase, warm=warm))
            coll = collective_bytes(f.lower(g, state).compile().as_text())
            tag = "warm" if warm else f"phase{{phase}}"
            print(f"RESULT,eventual,{{tag}}_crosspod_bytes,"
                  f"{{coll['raw']['all-reduce']}}")
            print(f"RESULT,eventual,{{tag}}_crosspod_bytes_analytic,"
                  f"{{ev.crosspod_allreduce_bytes(phase, warm=warm)}}")
            out, st = f(g, state)       # compile + warm
            jax.block_until_ready((out, st))
            if not warm:
                t0 = time.perf_counter()
                for _ in range({STEPS}):
                    out, st = f(g, st)
                jax.block_until_ready((out, st))
                total_us += (time.perf_counter() - t0) / {STEPS} * 1e6
        print(f"RESULT,eventual,us_per_sync,{{total_us / ev.period:.1f}}")
        steady = sum(ev.crosspod_allreduce_bytes(p) for p in
                     range(ev.period)) / ev.period
        print(f"RESULT,eventual,crosspod_allreduce_bytes,{{steady:.1f}}")
"""

_MODE_SETS = {
    "flat": ["flat"],
    "hier": ["hierarchical"],
    # bucketed/eventual need the monolithic hierarchical total as their
    # reference
    "bucketed": ["hierarchical", "bucketed"],
    "eventual": ["hierarchical", "eventual"],
    "all": ["flat", "hierarchical", "bucketed", "eventual"],
}


def _measure(mode: str = "all") -> dict:
    env = dict(os.environ, PYTHONPATH=SRC,
               BENCH_DIST_MODES=",".join(_MODE_SETS[mode]))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _BODY], capture_output=True,
                       text=True, env=env, timeout=560)
    if r.returncode != 0:
        raise RuntimeError(f"bench_dist subprocess failed:\n{r.stderr[-2000:]}")
    out = {}
    for line in r.stdout.splitlines():
        if line.startswith("RESULT,"):
            _, m, metric, value = line.split(",")
            out[(m, metric)] = float(value)
    return out


def _analytic_bucket_shares(vals) -> tuple[dict[int, float], dict[int, float]]:
    """Per-bucket ``(l2_shares, l1_over_l2_ratios)`` from the two-level
    KVStore byte counters (one key per bucket) — the analytic side of the
    bucketed cross-validation."""
    from repro.core import KVStoreDist
    import numpy as np
    kv = KVStoreDist(n_machines=N_MACHINES,
                     devices_per_machine=DEVS_PER_MACHINE,
                     consistency="sequential")
    n = int(vals[("bucketed", "n_buckets")])
    for i in range(n):
        elems = int(vals[("bucketed", f"bucket{i}_payload_bytes")]) // 4
        kv.init(f"bucket{i}", np.zeros(elems, np.float32))
    for w in range(N_MACHINES * DEVS_PER_MACHINE):
        for i in range(n):
            elems = int(vals[("bucketed", f"bucket{i}_payload_bytes")]) // 4
            kv.push(f"bucket{i}", worker=w,
                    grad=np.ones(elems, np.float32))
    total = sum(kv.bytes_l2_by_key.values())
    shares = {i: kv.bytes_l2_by_key[f"bucket{i}"] / total for i in range(n)}
    # §3.3 two-level ratio per bucket, reported as rows so validate() can
    # fail it structurally rather than crashing mid-benchmark
    ratios = {i: kv.bytes_l1_by_key[f"bucket{i}"]
              / max(kv.bytes_l2_by_key[f"bucket{i}"], 1) for i in range(n)}
    return shares, ratios


def run(csv: bool = True, mode: str = "all"):
    vals = _measure(mode)
    rows = []
    for (m, metric), value in sorted(vals.items()):
        derived = ""
        if metric == "crosspod_allreduce_bytes" and m == "hierarchical":
            flat = vals.get(("flat", metric))
            if flat:
                derived = f"{flat / max(value, 1):.1f}x fewer than flat"
        if metric == "crosspod_allreduce_bytes" and m == "eventual":
            hier = vals.get(("hierarchical", metric))
            if hier:
                derived = (f"{hier / max(value, 1):.1f}x fewer than "
                           f"sequential (steady state)")
        rows.append((f"gradient_sync_{m}_{metric}", value, derived))
        if csv:
            print(f"{rows[-1][0]},{value},{derived}")
    if ("bucketed", "n_buckets") in vals:
        shares, ratios = _analytic_bucket_shares(vals)
        for i, share in shares.items():
            rows.append((f"gradient_sync_bucketed_bucket{i}_l2_share_analytic",
                         share, "KVStore bytes_l2_by_key"))
            if csv:
                print(f"{rows[-1][0]},{share},{rows[-1][2]}")
        for i, ratio in ratios.items():
            rows.append((f"gradient_sync_bucketed_bucket{i}_l1_over_l2",
                         ratio, "analytic two-level ratio"))
            if csv:
                print(f"{rows[-1][0]},{ratio},{rows[-1][2]}")
    return rows


def validate(rows, mode: str = "all") -> list[str]:
    """§3.3 on-mesh: hierarchical moves fewer cross-pod bytes than flat;
    DESIGN.md §7: the per-bucket bytes sum back to the monolithic
    hierarchical total and match the analytic KVStore attribution.

    ``mode`` declares which measurements are *required*: every sync mode
    the run was supposed to measure must report nonzero cross-pod bytes
    (a parser that silently reads 0 is a failure, not a pass)."""
    d = {name: value for name, value, _ in rows}
    failures = []
    flat = d.get("gradient_sync_flat_crosspod_allreduce_bytes", 0)
    hier = d.get("gradient_sync_hierarchical_crosspod_allreduce_bytes", 0)
    for required in _MODE_SETS[mode]:
        if not d.get(f"gradient_sync_{required}_crosspod_allreduce_bytes", 0):
            failures.append(
                f"missing/zero {required} gradient_sync byte measurement")
    if flat and hier:
        if hier >= flat:
            failures.append(
                f"hierarchical all-reduce bytes {hier} >= flat {flat}")
        elif flat / hier < 2.0:
            failures.append(
                f"hierarchical reduction factor {flat / hier:.2f} < 2.0")

    n = int(d.get("gradient_sync_bucketed_n_buckets", 0))
    if n:
        if n < 2:
            failures.append(f"expected a multi-bucket plan, got {n} buckets")
        per_bucket = [d[f"gradient_sync_bucketed_bucket{i}_crosspod_bytes"]
                      for i in range(n)]
        if sum(per_bucket) != hier:
            failures.append(
                f"per-bucket cross-pod bytes {per_bucket} sum to "
                f"{sum(per_bucket)}, monolithic hierarchical moved {hier}")
        hlo_total = sum(per_bucket)
        for i in range(n):
            analytic = d[f"gradient_sync_bucketed_bucket{i}_l2_share_analytic"]
            hlo_share = per_bucket[i] / hlo_total
            if abs(analytic - hlo_share) > 1e-9:
                failures.append(
                    f"bucket {i}: analytic l2 share {analytic} != HLO share "
                    f"{hlo_share}")
            ratio = d.get(f"gradient_sync_bucketed_bucket{i}_l1_over_l2", 0)
            if ratio != DEVS_PER_MACHINE:
                failures.append(
                    f"bucket {i}: analytic l1/l2 ratio {ratio} != "
                    f"devices-per-machine {DEVS_PER_MACHINE}")

    period = int(d.get("gradient_sync_eventual_period", 0))
    if period:
        # the eventual gate (DESIGN.md §15): per-phase compiled bytes ==
        # the analytic staleness model EXACTLY, phases sum to the
        # sequential (hierarchical) total, warm == full sync, steady-state
        # mean shows the period-x reduction
        if period != MAX_STALENESS + 1:
            failures.append(f"eventual period {period} != "
                            f"max_staleness+1 = {MAX_STALENESS + 1}")
        phase_bytes = []
        for p in range(period):
            hlo = d.get(f"gradient_sync_eventual_phase{p}_crosspod_bytes")
            analytic = d.get(
                f"gradient_sync_eventual_phase{p}_crosspod_bytes_analytic")
            if hlo is None or hlo != analytic:
                failures.append(
                    f"eventual phase {p}: HLO cross-pod bytes {hlo} != "
                    f"analytic model {analytic}")
            phase_bytes.append(hlo or 0)
        warm = d.get("gradient_sync_eventual_warm_crosspod_bytes", 0)
        warm_an = d.get("gradient_sync_eventual_warm_crosspod_bytes_analytic")
        if warm != warm_an:
            failures.append(f"eventual warm: HLO bytes {warm} != "
                            f"analytic {warm_an}")
        if hier and sum(phase_bytes) != hier:
            failures.append(
                f"eventual phases {phase_bytes} sum to {sum(phase_bytes)}, "
                f"sequential hierarchical moved {hier}")
        if hier and warm != hier:
            failures.append(f"eventual warm sync {warm} != hierarchical "
                            f"full sync {hier}")
        steady = d.get("gradient_sync_eventual_crosspod_allreduce_bytes", 0)
        if hier and abs(steady - hier / period) > 1:
            failures.append(
                f"eventual steady-state mean {steady} != hierarchical/"
                f"period = {hier / period:.1f}")
    return failures


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=sorted(_MODE_SETS), default="all")
    args = ap.parse_args()
    rows = run(mode=args.mode)
    bad = validate(rows, mode=args.mode)
    print("PASS" if not bad else bad)
    sys.exit(1 if bad else 0)
