"""Flat vs hierarchical ``gradient_sync`` on a 2x4x2 host mesh (§3.3
on-mesh): wall time per sync and cross-pod all-reduce bytes from the
compiled HLO.

Multi-device lowering needs --xla_force_host_platform_device_count set
before jax initializes, so the measurement runs in a subprocess and
reports one CSV row per (mode, metric).

CSV: name,value,derived
"""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")

# 8 workers x 1 MiB gradient on a 2 pods x 4 data x 2 model mesh
N_ELEMS = 262_144
STEPS = 20

_BODY = f"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=16'
import time
import jax, jax.numpy as jnp, numpy as np
from repro.dist.collectives import gradient_sync
from repro.launch.dryrun import collective_bytes

mesh = jax.make_mesh((2, 4, 2), ("pod", "data", "model"))
W = 8
rng = np.random.RandomState(0)
g = {{"w": jnp.asarray(rng.randn(W, {N_ELEMS}), jnp.float32)}}

with jax.set_mesh(mesh):
    for mode in ("flat", "hierarchical"):
        f = jax.jit(lambda x, mode=mode: gradient_sync(mesh, x, mode=mode))
        coll = collective_bytes(f.lower(g).compile().as_text())
        out = f(g)                      # compile + warm
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range({STEPS}):
            out = f(g)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / {STEPS} * 1e6
        print(f"RESULT,{{mode}},us_per_sync,{{us:.1f}}")
        print(f"RESULT,{{mode}},crosspod_allreduce_bytes,"
              f"{{coll['raw']['all-reduce']}}")
        print(f"RESULT,{{mode}},total_collective_bytes,"
              f"{{coll['raw_total']}}")
"""


def _measure() -> dict:
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _BODY], capture_output=True,
                       text=True, env=env, timeout=560)
    if r.returncode != 0:
        raise RuntimeError(f"bench_dist subprocess failed:\n{r.stderr[-2000:]}")
    out = {}
    for line in r.stdout.splitlines():
        if line.startswith("RESULT,"):
            _, mode, metric, value = line.split(",")
            out[(mode, metric)] = float(value)
    return out


def run(csv: bool = True):
    vals = _measure()
    rows = []
    for (mode, metric), value in sorted(vals.items()):
        derived = ""
        if metric == "crosspod_allreduce_bytes" and mode == "hierarchical":
            flat = vals[("flat", metric)]
            derived = f"{flat / max(value, 1):.1f}x fewer than flat"
        rows.append((f"gradient_sync_{mode}_{metric}", value, derived))
        if csv:
            print(f"{rows[-1][0]},{value},{derived}")
    return rows


def validate(rows) -> list[str]:
    """The §3.3 claim on-mesh: the hierarchical schedule's cross-pod
    all-reduce moves fewer bytes than flat (factor = |data| = 4)."""
    d = {name: value for name, value, _ in rows}
    failures = []
    flat = d.get("gradient_sync_flat_crosspod_allreduce_bytes", 0)
    hier = d.get("gradient_sync_hierarchical_crosspod_allreduce_bytes", 0)
    if not flat or not hier:
        failures.append("missing gradient_sync byte measurements")
    elif hier >= flat:
        failures.append(
            f"hierarchical all-reduce bytes {hier} >= flat {flat}")
    elif flat / hier < 2.0:
        failures.append(
            f"hierarchical reduction factor {flat / hier:.2f} < 2.0")
    return failures


if __name__ == "__main__":
    rows = run()
    bad = validate(rows)
    print("PASS" if not bad else bad)
    sys.exit(1 if bad else 0)
