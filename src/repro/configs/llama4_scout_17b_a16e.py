"""llama4-scout-17b-a16e [moe] — 16 routed experts top-1 + shared expert,
early-fusion multimodal (text path here; fusion enters as token stream).
[hf:meta-llama/Llama-4-Scout-17B-16E]

48L d_model=5120 40H (GQA kv=8) d_ff=8192 (per expert) vocab=202048.
"""
from repro.models.common import ArchConfig, LayerSpec

ARCH_ID = "llama4-scout-17b-a16e"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab=202048,
        head_dim=128,
        n_experts=16,
        top_k=1,
        shared_expert=True,
        rope_theta=500_000.0,
        pattern=(LayerSpec(kind="attn", attn="causal", mlp="moe"),),
    )
