"""qwen1.5-0.5b [dense] — QKV bias, tied embeddings.
[hf:Qwen/Qwen1.5-0.5B]

24L d_model=1024 16H (GQA kv=16) d_ff=2816 vocab=151936.
"""
from repro.models.common import ArchConfig, LayerSpec

ARCH_ID = "qwen1.5-0.5b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=2816,
        vocab=151936,
        head_dim=64,
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1_000_000.0,
        pattern=(LayerSpec(kind="attn", attn="causal", mlp="swiglu"),),
    )
