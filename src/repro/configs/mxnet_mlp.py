"""The paper's own example model (MXNet Fig. 2): an MLP built with the
Symbol API — used by the quickstart example and the Fig. 6/7 benchmarks."""
from repro.core import (Activation, FullyConnected, SoftmaxOutput,
                        Variable)

ARCH_ID = "mxnet-mlp"


def symbol(num_hidden=(64,), num_classes=10):
    data, label = Variable("data"), Variable("label")
    x = data
    for i, h in enumerate(num_hidden):
        x = Activation(FullyConnected(x, h, name=f"fc{i}"), "relu")
    return SoftmaxOutput(FullyConnected(x, num_classes, name="head"), label)


def init_args(rng, batch, d_in, num_hidden=(64,), num_classes=10):
    import numpy as np
    args = {"data": rng.randn(batch, d_in).astype(np.float32),
            "label": rng.randint(0, num_classes, (batch,)).astype(np.float32)}
    d = d_in
    for i, h in enumerate(num_hidden):
        args[f"fc{i}_weight"] = (rng.randn(h, d) / np.sqrt(d)).astype(np.float32)
        args[f"fc{i}_bias"] = np.zeros(h, np.float32)
        d = h
    args["head_weight"] = (rng.randn(num_classes, d) / np.sqrt(d)).astype(np.float32)
    args["head_bias"] = np.zeros(num_classes, np.float32)
    return args
