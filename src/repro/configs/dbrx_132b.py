"""dbrx-132b [moe] — 16 experts top-4, fine-grained MoE.
[hf:databricks/dbrx-base]

40L d_model=6144 48H (GQA kv=8) d_ff=10752 (per expert) vocab=100352.
"""
from repro.models.common import ArchConfig, LayerSpec

ARCH_ID = "dbrx-132b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=10752,
        vocab=100352,
        head_dim=128,
        n_experts=16,
        top_k=4,
        rope_theta=500_000.0,
        pattern=(LayerSpec(kind="attn", attn="causal", mlp="moe"),),
    )
