"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060]

24L d_model=768, ssm_state=128, expand=2 (d_inner=1536, head P=64 ->
24 SSD heads), vocab=50280, tied embeddings, no MLP.
"""
from repro.models.common import ArchConfig, LayerSpec

ARCH_ID = "mamba2-130m"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="ssm",
        n_layers=24,
        d_model=768,
        n_heads=1,            # unused (attention-free)
        n_kv_heads=1,
        d_ff=0,
        vocab=50280,
        ssm_state=128,
        ssm_heads=24,         # d_inner 1536 / P 64
        ssm_expand=2,
        tie_embeddings=True,
        pattern=(LayerSpec(kind="mamba", mlp="none"),),
    )
