"""gemma2-2b [dense] — local+global alternating attention, logit
soft-capping, sandwich norms, GeGLU, tied embeddings. [arXiv:2408.00118]

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000, head_dim=256,
sliding window 4096 on local layers.

``long_context=True`` returns the serving variant where the global layers
also fall back to a 4096 sliding window — the dense-arch sub-quadratic
carve-out required to run the ``long_500k`` shape (see DESIGN.md §5).
"""
from repro.models.common import ArchConfig, LayerSpec

ARCH_ID = "gemma2-2b"
WINDOW = 4096


def config(long_context: bool = False) -> ArchConfig:
    local = LayerSpec(kind="attn", attn="window", window=WINDOW, mlp="geglu")
    glob = (LayerSpec(kind="attn", attn="window", window=WINDOW, mlp="geglu")
            if long_context else
            LayerSpec(kind="attn", attn="causal", mlp="geglu"))
    return ArchConfig(
        name=ARCH_ID + ("-long" if long_context else ""),
        family="dense",
        n_layers=26,
        d_model=2304,
        n_heads=8,
        n_kv_heads=4,
        d_ff=9216,
        vocab=256000,
        head_dim=256,
        attn_softcap=50.0,
        final_softcap=30.0,
        sandwich_norm=True,
        tie_embeddings=True,
        pattern=(local, glob),
    )
