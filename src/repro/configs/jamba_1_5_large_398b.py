"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE
every other layer (16e top-2). [arXiv:2403.19887]

72L d_model=8192 64H (GQA kv=8) d_ff=24576 (per expert) vocab=65536,
ssm_state=128.  Super-block of 8 layers: attention at position 4, mamba
elsewhere; MoE on odd positions.

NOTE (DESIGN.md §4): Jamba uses Mamba-1 blocks; we implement the Mamba-2
SSD block as the TPU-native stand-in (chunked-scan formulation), same
state size. ``long_context=True`` adds a 4096 sliding window to the
attention layers for the ``long_500k`` decode shape.
"""
from repro.models.common import ArchConfig, LayerSpec

ARCH_ID = "jamba-1.5-large-398b"


def config(long_context: bool = False) -> ArchConfig:
    pattern = []
    for i in range(8):
        kind = "attn" if i == 4 else "mamba"
        mlp = "moe" if i % 2 == 1 else "swiglu"
        window = 4096 if (kind == "attn" and long_context) else None
        pattern.append(LayerSpec(kind=kind,
                                 attn="window" if window else "causal",
                                 window=window, mlp=mlp))
    return ArchConfig(
        name=ARCH_ID + ("-long" if long_context else ""),
        family="hybrid",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=24576,
        vocab=65536,
        head_dim=128,
        n_experts=16,
        top_k=2,
        ssm_state=128,
        ssm_heads=128,       # d_inner 16384 / P 128
        ssm_expand=2,
        pattern=tuple(pattern),
    )
