"""whisper-base [audio] — encoder-decoder; conv/mel frontend STUBBED per
spec (input_specs provides 1500 precomputed frame embeddings).
[arXiv:2212.04356]

6L (decoder; +6L encoder) d_model=512 8H (kv=8) d_ff=2048 vocab=51865.
NOTE (DESIGN.md): whisper uses learned absolute positions; we use RoPE
(framework-uniform). decode_32k exceeds whisper's trained 448 positions —
lowered structurally per the dry-run contract.
"""
from repro.models.common import ArchConfig, LayerSpec

ARCH_ID = "whisper-base"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="audio",
        n_layers=6,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_ff=2048,
        vocab=51865,
        head_dim=64,
        encoder_layers=6,
        frontend_tokens=1500,
        frontend_dim=512,
        pattern=(LayerSpec(kind="attn", attn="causal", mlp="gelu",
                           cross_attn=True),),
    )
