"""Config registry: ``get_config("<arch-id>")`` -> ArchConfig.

One module per assigned architecture (exact dims from the assignment,
source cited in each module docstring) plus the paper's own MLP example.
"""
from __future__ import annotations

from repro.models.common import ArchConfig

from . import (dbrx_132b, gemma2_2b, granite_20b, internvl2_76b,
               jamba_1_5_large_398b, llama4_scout_17b_a16e, mamba2_130m,
               qwen1_5_0_5b, starcoder2_15b, whisper_base)

_MODULES = {
    m.ARCH_ID: m
    for m in (dbrx_132b, internvl2_76b, qwen1_5_0_5b, gemma2_2b,
              jamba_1_5_large_398b, whisper_base, llama4_scout_17b_a16e,
              starcoder2_15b, mamba2_130m, granite_20b)
}

ARCH_IDS = list(_MODULES)

# archs whose attention is sub-quadratic-capable (run long_500k natively);
# others need the sequence-sharded ring path (DESIGN.md §5, §8)
LONG_CONTEXT_ARCHS = {"mamba2-130m", "jamba-1.5-large-398b", "gemma2-2b"}


def get_config(arch_id: str, *, long_context: bool = False,
               seq_shard: bool = False) -> ArchConfig:
    """``long_context=True`` returns the arch's long-context serving
    variant.  Sub-quadratic archs (``LONG_CONTEXT_ARCHS``) have a native
    one (windowed/SSM).  Full-attention archs are only viable with the
    sequence-sharded ring attention path — pass ``seq_shard=True``
    (mirroring ``PerfFlags.seq_shard``) to acknowledge that, and the base
    config is returned unchanged: attention stays full, and the O(S·S/P)
    per-device footprint comes from ``dist/ring.py`` (DESIGN.md §8)."""
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")
    mod = _MODULES[arch_id]
    if long_context:
        if arch_id not in LONG_CONTEXT_ARCHS:
            if not seq_shard:
                raise ValueError(
                    f"{arch_id} has no sub-quadratic long-context variant; "
                    f"full-attention archs run long_500k only on the "
                    f"sequence-sharded ring path (seq_shard=True, "
                    f"DESIGN.md §8)")
            return mod.config()
        import inspect
        if "long_context" in inspect.signature(mod.config).parameters:
            return mod.config(long_context=True)
    return mod.config()
