"""starcoder2-15b [dense] — GQA, RoPE, GELU MLP, QKV bias.
[arXiv:2402.19173]

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.
"""
from repro.models.common import ArchConfig, LayerSpec

ARCH_ID = "starcoder2-15b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=4,
        d_ff=24576,
        vocab=49152,
        head_dim=128,
        qkv_bias=True,
        rope_theta=100_000.0,
        pattern=(LayerSpec(kind="attn", attn="causal", mlp="gelu"),),
    )
