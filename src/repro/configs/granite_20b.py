"""granite-20b [dense] — code model, MQA (kv=1), GELU MLP
(gpt_bigcode-style FFN matches the 20B param count). [arXiv:2405.04324]

52L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152.
"""
from repro.models.common import ArchConfig, LayerSpec

ARCH_ID = "granite-20b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=52,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,
        d_ff=24576,
        vocab=49152,
        head_dim=128,
        pattern=(LayerSpec(kind="attn", attn="causal", mlp="gelu"),),
    )
