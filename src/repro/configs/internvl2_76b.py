"""internvl2-76b [vlm] — InternViT (STUB frontend) + llama3-70b-style
language backbone. [arXiv:2404.16821]

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
The ViT is stubbed per spec: ``input_specs`` provides 256 pre-computed
patch embeddings (InternViT-6B hidden=3200, pixel-shuffled 448px/14 grid),
projected into d_model by a learned projector.
"""
from repro.models.common import ArchConfig, LayerSpec

ARCH_ID = "internvl2-76b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=28672,
        vocab=128256,
        head_dim=128,
        rope_theta=500_000.0,
        frontend_tokens=256,
        frontend_dim=3200,
        pattern=(LayerSpec(kind="attn", attn="causal", mlp="swiglu"),),
    )
