"""Sharded checkpointing with async finalization and elastic restore
(DESIGN.md §12; MXNet §2.1 save/load at fleet scale).

The flat gather-everything-to-host ``.npy`` writer is gone.  A checkpoint
is now a *directory of shard files plus a JSON manifest*:

* **shard-by-shard save** — every leaf of the state pytree is written as
  its device shards (one raw little-endian ``.bin`` per distinct shard;
  replicas deduplicated by shard index, so each global array hits disk
  exactly once).  The manifest records, per leaf: the pytree key path,
  the global shape/dtype, the ``PartitionSpec`` the leaf was saved
  under, and each shard's file / start offsets / shape / byte length /
  crc32.  Raw ``.bin`` (no npy header) keeps on-disk data bytes exactly
  equal to the analytic byte model (``core.memplan.checkpoint_bytes``).
* **two-phase atomic commit** — all shard files are written (and
  fsynced) first, then the manifest lands as ``manifest.json.tmp`` and
  is ``os.replace``d to ``manifest.json``.  A crash anywhere mid-save
  leaves a directory *without* a committed manifest, which
  ``find_checkpoints`` skips — the previous checkpoint is never
  corrupted and a torn one is never half-loaded.
* **async finalization** (``AsyncCheckpointer``) — the step critical
  path only snapshots device shards to host; serialization + commit run
  on a background thread (spans ``ckpt_serialize`` / ``ckpt_commit`` on
  the "checkpoint" obs track).  ``wait_for_checkpoint()`` drains the
  queue and re-raises any background failure.
* **elastic restore** — ``load_checkpoint`` reconstructs each global
  array under the *target* mesh's PartitionSpec rule table
  (``dist.partition.spec_for_path``): every target device shard is
  assembled from exactly the saved shard regions that overlap its index
  (``jax.make_array_from_callback`` + memory-mapped shard files), so a
  dp×pp=2×2 checkpoint restores onto 1×4, a pipelined checkpoint loads
  into an unpipelined mesh, and a trained checkpoint loads straight
  into a serving engine on a single device.

All checkpoint bytes flow through an injectable filesystem seam
(``LocalFS``); ``FailingFS`` errors — or SIGKILLs the process — after N
bytes, which is how the crash/fault-injection suite tears saves
deterministically mid-write.
"""
from __future__ import annotations

import io
import json
import os
import queue
import shutil
import signal
import threading
import zlib
from pathlib import Path

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.tree_util import (DictKey, FlattenedIndexKey, GetAttrKey,
                           SequenceKey)

from repro import obs

MANIFEST = "manifest.json"
FORMAT = "repro-sharded-ckpt"
VERSION = 2


class CheckpointError(RuntimeError):
    """A checkpoint could not be written or restored."""


# ---------------------------------------------------------------------------
# filesystem seam (fault injection)


class LocalFS:
    """Filesystem layer every checkpoint byte flows through.

    The indirection exists so tests (and the fault-injection bench gate)
    can tear a save mid-write deterministically — see ``FailingFS``.
    """

    def mkdir(self, path):
        Path(path).mkdir(parents=True, exist_ok=True)

    def write_bytes(self, path, data: bytes):
        with open(path, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())

    def replace(self, tmp, dst):
        os.replace(tmp, dst)


class FailingFS(LocalFS):
    """Injectable fault: fail after ``fail_after_bytes`` total bytes.

    The partial write up to the budget DOES land on disk (and is
    fsynced) before the fault fires, so the torn state is exactly what a
    crashed writer leaves behind.  ``kill=True`` SIGKILLs the process
    instead of raising — the subprocess crash harness's deterministic
    "writer died mid-save" trigger.
    """

    def __init__(self, fail_after_bytes: int, kill: bool = False):
        self.fail_after_bytes = int(fail_after_bytes)
        self.kill = kill
        self.written = 0

    def write_bytes(self, path, data: bytes):
        room = self.fail_after_bytes - self.written
        if room >= len(data):
            super().write_bytes(path, data)
            self.written += len(data)
            return
        if room > 0:
            super().write_bytes(path, data[:room])
        self.written = self.fail_after_bytes
        if self.kill:
            os.kill(os.getpid(), signal.SIGKILL)
        raise OSError(f"FailingFS: fault injected after "
                      f"{self.fail_after_bytes} bytes (writing {path})")


# ---------------------------------------------------------------------------
# pytree key paths <-> JSON


def _path_entries(path) -> list:
    """JSON-serializable form of a jax key path: ``["k", key]`` for dict
    keys, ``["i", idx]`` for sequence entries, ``["a", name]`` for
    attributes (NamedTuples / dataclasses)."""
    out = []
    for k in path:
        if isinstance(k, DictKey):
            out.append(["k", k.key])
        elif isinstance(k, SequenceKey):
            out.append(["i", k.idx])
        elif isinstance(k, GetAttrKey):
            out.append(["a", k.name])
        elif isinstance(k, FlattenedIndexKey):
            out.append(["i", k.key])
        else:  # unknown key kind: repr is enough for comparison/errors
            out.append(["r", repr(k)])
    return out


def _entries_str(entries) -> str:
    """Human-readable ``['params']['blocks']['wq']`` form."""
    if not entries:
        return "<root>"
    parts = []
    for kind, v in entries:
        parts.append(f".{v}" if kind == "a" else f"[{v!r}]")
    return "".join(parts)


def _leaf_name(path) -> str:
    return jax.tree_util.keystr(path) or "<root>"


def _key_names(entries) -> list:
    """Dict-key strings along a manifest path (partition-rule lookup)."""
    return [v for kind, v in entries if kind == "k"]


def _unflatten_from_entries(paths, leaves):
    """Rebuild a nested dict/list pytree from manifest key paths — the
    template-free restore (``load_checkpoint(path)`` with no ``like``).
    Only dict and sequence keys are supported; tuples come back as
    lists."""
    if not paths or not paths[0]:
        return leaves[0] if leaves else {}
    root = {} if paths[0][0][0] == "k" else []

    def _set(container, entries, value):
        kind, key = entries[0]
        if kind not in ("k", "i"):
            raise CheckpointError(
                f"cannot rebuild a pytree containing {_entries_str(entries)} "
                f"without a template — pass `like=`")
        last = len(entries) == 1
        if isinstance(container, list):
            while len(container) <= key:
                container.append(None)
        if last:
            container[key] = value
            return
        nxt_kind = entries[1][0]
        if isinstance(container, list):
            if container[key] is None:
                container[key] = {} if nxt_kind == "k" else []
            _set(container[key], entries[1:], value)
        else:
            if key not in container:
                container[key] = {} if nxt_kind == "k" else []
            _set(container[key], entries[1:], value)

    for entries, leaf in zip(paths, leaves):
        _set(root, entries, leaf)
    return root


# ---------------------------------------------------------------------------
# PartitionSpec <-> JSON


def _spec_to_json(spec: P, ndim: int) -> list:
    entries = list(spec) + [None] * (ndim - len(spec))
    out = []
    for e in entries[:ndim]:
        if e is None:
            out.append(None)
        elif isinstance(e, str):
            out.append([e])
        else:
            out.append(list(e))
    return out


def _spec_from_json(entries) -> P:
    return P(*[None if e is None else (e[0] if len(e) == 1 else tuple(e))
               for e in entries])


def _leaf_spec(x) -> P:
    sh = getattr(x, "sharding", None)
    if isinstance(sh, NamedSharding):
        return sh.spec
    return P()


def _leaf_axis_sizes(x) -> dict:
    sh = getattr(x, "sharding", None)
    if isinstance(sh, NamedSharding):
        return dict(sh.mesh.shape)
    return {}


# ---------------------------------------------------------------------------
# snapshot (the only step on the save critical path)


def _unique_shards(x):
    """``[(start_offsets, host_ndarray)]`` covering the global array
    exactly once: addressable shards deduplicated by index (replicas of
    a replicated/partially-replicated leaf share their index tuple)."""
    if not hasattr(x, "addressable_shards"):
        arr = np.asarray(x)
        return [((0,) * arr.ndim, arr)]
    seen, out = set(), []
    for s in x.addressable_shards:
        start = tuple(int(sl.start or 0) for sl in s.index)
        if start in seen:
            continue
        seen.add(start)
        out.append((start, np.asarray(s.data)))
    return out


def snapshot_state(state) -> list[dict]:
    """Host-side snapshot of every leaf's shards + metadata.

    This is the ONLY work ``AsyncCheckpointer.save`` does on the caller
    thread: device->host copies of the addressable shards (jax buffers
    are immutable, so on CPU backends the "copy" is typically a view).
    Everything downstream (serialization, commit) runs off-thread.
    """
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    snap = []
    for kpath, leaf in flat:
        shards = _unique_shards(leaf)
        gshape = tuple(int(d) for d in getattr(leaf, "shape",
                                               shards[0][1].shape))
        dtype = str(np.dtype(getattr(leaf, "dtype", shards[0][1].dtype)))
        snap.append({"path": _path_entries(kpath),
                     "keystr": _leaf_name(kpath),
                     "shape": list(gshape), "dtype": dtype,
                     "spec": _spec_to_json(_leaf_spec(leaf), len(gshape)),
                     "axis_sizes": _leaf_axis_sizes(leaf),
                     "shards": shards})
    return snap


# ---------------------------------------------------------------------------
# serialize + two-phase commit (background-thread side)


def _write_shards(p: Path, snap: list[dict], fs: LocalFS) -> list[dict]:
    """Phase 1: every shard as raw C-order little-endian bytes, fsynced."""
    fs.mkdir(p)
    leaves_meta = []
    for i, leaf in enumerate(snap):
        shard_meta = []
        for j, (start, arr) in enumerate(leaf["shards"]):
            fname = f"l{i}_s{j}.bin"
            data = np.ascontiguousarray(arr).tobytes()
            fs.write_bytes(p / fname, data)
            shard_meta.append({"file": fname, "start": list(start),
                               "shape": list(arr.shape),
                               "nbytes": len(data),
                               "crc32": zlib.crc32(data)})
        leaves_meta.append({k: leaf[k] for k in
                            ("path", "keystr", "shape", "dtype", "spec",
                             "axis_sizes")} | {"shards": shard_meta})
    return leaves_meta


def _commit(p: Path, leaves_meta: list[dict], step, fs: LocalFS):
    """Phase 2: the atomic rename that makes the checkpoint exist."""
    manifest = {"format": FORMAT, "version": VERSION,
                "step": int(step) if step is not None else None,
                "n_leaves": len(leaves_meta), "leaves": leaves_meta}
    fs.write_bytes(p / (MANIFEST + ".tmp"),
                   json.dumps(manifest).encode())
    fs.replace(p / (MANIFEST + ".tmp"), p / MANIFEST)


def save_checkpoint(path: str, state: dict, step: int | None = None,
                    fs: LocalFS | None = None) -> Path:
    """Synchronous sharded save into ``path`` (a single checkpoint dir).

    Shard files first, manifest rename last — interrupting this call at
    any point leaves either the old committed checkpoint or a torn
    (manifest-less) directory that loaders skip, never a half-written
    one that parses.
    """
    fs = fs or LocalFS()
    p = Path(path)
    snap = snapshot_state(state)
    _commit(p, _write_shards(p, snap, fs), step, fs)
    return p


# ---------------------------------------------------------------------------
# discovery / integrity


def _read_manifest(p: Path) -> dict:
    mf = p / MANIFEST
    if not mf.exists():
        raise FileNotFoundError(f"no checkpoint manifest at {mf} — torn "
                                f"or missing checkpoint")
    m = json.loads(mf.read_text())
    if m.get("format") != FORMAT:
        raise CheckpointError(f"{mf}: not a {FORMAT} manifest "
                              f"(format={m.get('format')!r})")
    return m


def verify_checkpoint(path) -> tuple[bool, str]:
    """Deep integrity check: committed manifest + every shard file
    present with the recorded byte length and crc32."""
    p = Path(path)
    try:
        m = _read_manifest(p)
    except (FileNotFoundError, CheckpointError, ValueError) as e:
        return False, str(e)
    for lf in m["leaves"]:
        for s in lf["shards"]:
            f = p / s["file"]
            if not f.exists():
                return False, f"missing shard file {f}"
            data = f.read_bytes()
            if len(data) != s["nbytes"]:
                return False, (f"truncated shard {f}: {len(data)} bytes "
                               f"!= recorded {s['nbytes']}")
            if zlib.crc32(data) != s["crc32"]:
                return False, f"crc mismatch in shard {f}"
    return True, "ok"


def find_checkpoints(root) -> list[tuple[int, Path]]:
    """Committed ``step_*`` checkpoints under ``root`` as ascending
    ``(step, path)``.  Torn directories — no committed manifest, or
    shard files missing / with the wrong length — are skipped, never
    returned."""
    root = Path(root)
    out = []
    if not root.is_dir():
        return out
    for d in root.glob("step_*"):
        try:
            m = _read_manifest(d)
        except (FileNotFoundError, CheckpointError, ValueError):
            continue
        ok = all((d / s["file"]).is_file()
                 and (d / s["file"]).stat().st_size == s["nbytes"]
                 for lf in m["leaves"] for s in lf["shards"])
        if not ok:
            continue
        step = m.get("step")
        if step is None:
            try:
                step = int(d.name.split("_", 1)[1])
            except ValueError:
                continue
        out.append((int(step), d))
    return sorted(out)


def latest_checkpoint(root) -> Path | None:
    """Newest committed checkpoint directory under ``root`` (or None)."""
    found = find_checkpoints(root)
    return found[-1][1] if found else None


# ---------------------------------------------------------------------------
# elastic restore


def _resolve_dtype(name: str) -> np.dtype:
    # ml_dtypes (imported by jax) registers bfloat16/fp8 names with numpy
    return np.dtype(name)


def _assemble(p: Path, meta: dict, index) -> np.ndarray:
    """The resharding core: materialize the global-array region ``index``
    (a tuple of slices, one per dim) by pasting every saved shard's
    overlap with it.  Shard files are memory-mapped, so only the
    overlapping bytes are read — restoring a 1/N target shard touches
    ~1/N of the checkpoint regardless of the save-time layout."""
    gshape = tuple(meta["shape"])
    dtype = _resolve_dtype(meta["dtype"])
    t_lo = [int(sl.start or 0) for sl in index]
    t_hi = [int(sl.stop) if sl.stop is not None else gshape[d]
            for d, sl in enumerate(index)]
    tshape = tuple(h - l for l, h in zip(t_lo, t_hi))
    out = np.empty(tshape, dtype)
    filled = 0
    for s in meta["shards"]:
        s_lo = [int(x) for x in s["start"]]
        s_hi = [lo + int(n) for lo, n in zip(s_lo, s["shape"])]
        lo = [max(a, b) for a, b in zip(t_lo, s_lo)]
        hi = [min(a, b) for a, b in zip(t_hi, s_hi)]
        if any(l >= h for l, h in zip(lo, hi)):
            continue
        src = np.memmap(p / s["file"], dtype=dtype, mode="r",
                        shape=tuple(s["shape"]))
        dst_ix = tuple(slice(l - tl, h - tl)
                       for l, h, tl in zip(lo, hi, t_lo))
        src_ix = tuple(slice(l - sl, h - sl)
                       for l, h, sl in zip(lo, hi, s_lo))
        out[dst_ix] = src[src_ix]
        n = 1
        for l, h in zip(lo, hi):
            n *= h - l
        filled += n
    want = 1
    for d in tshape:
        want *= d
    if filled != want:
        raise CheckpointError(
            f"saved shards cover {filled}/{want} elements of "
            f"{_entries_str(meta['path'])}{list(index)} — overlapping or "
            f"missing shard regions in the manifest")
    return out


def _full_index(shape):
    return tuple(slice(0, d) for d in shape)


def _target_sharding(meta: dict, mesh) -> NamedSharding | None:
    """Target layout for one leaf under ``mesh`` via the partition rule
    table, looked up by the leaf's pytree key path (the *saved* spec is
    deliberately ignored — restore is elastic onto the target mesh)."""
    if mesh is None or mesh.size == 1:
        return None
    from repro.dist.partition import spec_for_path
    stage = "stage" if "stage" in mesh.axis_names else None
    spec = spec_for_path(_key_names(meta["path"]), tuple(meta["shape"]),
                         mesh, stage_axis=stage)
    return NamedSharding(mesh, spec)


def _restore_leaf(p: Path, meta: dict, sharding: NamedSharding | None):
    import jax.numpy as jnp
    gshape = tuple(meta["shape"])
    if sharding is None:
        return jnp.asarray(_assemble(p, meta, _full_index(gshape)))
    return jax.make_array_from_callback(
        gshape, sharding, lambda idx: _assemble(p, meta, idx))


def _validate_like(p: Path, leaves_meta: list[dict], like):
    """Structural + shape/dtype validation against a template pytree,
    erroring with the FIRST diverging pytree path (never a blind
    ``str(treedef)`` string compare)."""
    flat = jax.tree_util.tree_flatten_with_path(like)[0]
    if len(leaves_meta) != len(flat):
        raise ValueError(
            f"checkpoint at {p} has {len(leaves_meta)} leaves but the "
            f"target structure has {len(flat)} — wrong checkpoint for "
            f"this model/optimizer state?")
    for i, ((kpath, ref), meta) in enumerate(zip(flat, leaves_meta)):
        if _path_entries(kpath) != [list(e) for e in meta["path"]]:
            raise ValueError(
                f"checkpoint/target tree structures diverge at leaf {i}: "
                f"saved {_entries_str(meta['path'])} != target "
                f"{_leaf_name(kpath)}")
        if tuple(meta["shape"]) != tuple(ref.shape):
            raise ValueError(
                f"checkpoint leaf {i} ({_leaf_name(kpath)}): saved shape "
                f"{tuple(meta['shape'])} != expected {tuple(ref.shape)} — "
                f"the checkpoint was written for a different configuration")
        if _resolve_dtype(meta["dtype"]) != np.dtype(ref.dtype):
            raise ValueError(
                f"checkpoint leaf {i} ({_leaf_name(kpath)}): saved dtype "
                f"{meta['dtype']} != expected {np.dtype(ref.dtype)} — "
                f"refusing to cast silently; convert explicitly if this "
                f"is intended")


def load_checkpoint(path: str, like=None, *, mesh=None, specs=None):
    """Elastic restore.  Returns ``(state, step)``.

    * ``like`` (optional): template pytree — structure, shapes and
      dtypes are validated leaf-by-leaf with the first diverging pytree
      path named in the error.  Without it, the pytree is rebuilt from
      the manifest's key paths (nested dicts/lists).
    * target layout: ``specs`` (a PartitionSpec pytree) if given; else
      the ambient-or-passed ``mesh``'s partition rule table by leaf path
      (``dist.partition.spec_for_path``); else unsharded host arrays.
      The mesh the checkpoint was SAVED under never constrains the
      restore — that is the elasticity.
    """
    p = Path(path)
    manifest = _read_manifest(p)
    leaves_meta = manifest["leaves"]
    if like is not None:
        _validate_like(p, leaves_meta, like)
    if mesh is None:
        from repro.dist.compat import current_mesh
        mesh = current_mesh()
    flat_specs = None
    if specs is not None:
        flat_specs = jax.tree.flatten(
            specs, is_leaf=lambda s: isinstance(s, P))[0]
        if len(flat_specs) != len(leaves_meta):
            raise ValueError(f"specs pytree has {len(flat_specs)} leaves, "
                             f"checkpoint has {len(leaves_meta)}")
    leaves = []
    for i, meta in enumerate(leaves_meta):
        if flat_specs is not None and mesh is not None:
            sharding = NamedSharding(mesh, flat_specs[i])
        else:
            sharding = _target_sharding(meta, mesh)
        leaves.append(_restore_leaf(p, meta, sharding))
    if like is not None:
        treedef = jax.tree.flatten(like)[1]
        state = jax.tree.unflatten(treedef, leaves)
    else:
        state = _unflatten_from_entries(
            [meta["path"] for meta in leaves_meta], leaves)
    return state, manifest.get("step")


# ---------------------------------------------------------------------------
# byte model hook (core.memplan.checkpoint_bytes cross-validation)


def checkpoint_plan(state, n_hosts: int = 1) -> dict:
    """Analytic bytes-per-host model of saving ``state`` — the
    ``core.memplan.checkpoint_bytes`` inputs derived from the live
    arrays' shardings.  ``total_bytes`` equals the on-disk sum of shard
    files EXACTLY (raw .bin shards carry no headers)."""
    from repro.core.memplan import checkpoint_bytes
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    leaves, axis_sizes = [], {}
    for _, leaf in flat:
        arr = np.asarray(leaf) if not hasattr(leaf, "shape") else leaf
        spec = _leaf_spec(leaf)
        entries = _spec_to_json(spec, len(arr.shape))
        leaves.append((tuple(arr.shape), str(np.dtype(arr.dtype)),
                       tuple(None if e is None else tuple(e)
                             for e in entries)))
        axis_sizes.update(_leaf_axis_sizes(leaf))
    return checkpoint_bytes(leaves, axis_sizes, n_hosts=n_hosts)


# ---------------------------------------------------------------------------
# async finalization


class AsyncCheckpointer:
    """Checkpoint manager over a run directory: ``root/step_<n>/``.

    ``save(state, step)`` snapshots device shards on the caller thread
    (the ONLY stall the training step sees) and hands serialization +
    two-phase commit to a daemon worker; retention prunes committed
    checkpoints beyond ``keep``.  ``async_save=False`` degrades to the
    synchronous writer (the bench baseline).  A failed background save
    is re-raised — wrapped in ``CheckpointError`` — by the next
    ``save()`` / ``wait_for_checkpoint()``.
    """

    def __init__(self, root, *, keep: int = 3, async_save: bool = True,
                 fs: LocalFS | None = None):
        self.root = Path(root)
        self.keep = keep
        self.async_save = async_save
        self.fs = fs or LocalFS()
        self._q: queue.Queue = queue.Queue()
        self._err: BaseException | None = None
        self._thread: threading.Thread | None = None

    def step_dir(self, step: int) -> Path:
        return self.root / f"step_{step:08d}"

    # -- caller side --------------------------------------------------------
    def save(self, state, step: int) -> Path:
        self._raise_pending()
        rec = obs.get_recorder()
        with rec.span("ckpt_snapshot", cat="ckpt", track="checkpoint",
                      step=step):
            snap = snapshot_state(state)
        path = self.step_dir(step)
        if not self.async_save:
            with rec.span("ckpt_serialize", cat="ckpt", track="checkpoint",
                          step=step):
                meta = _write_shards(path, snap, self.fs)
            with rec.span("ckpt_commit", cat="ckpt", track="checkpoint",
                          step=step):
                _commit(path, meta, step, self.fs)
            self._prune()
            return path
        self._ensure_thread()
        self._q.put((snap, path, step))
        return path

    def wait_for_checkpoint(self):
        """Block until every enqueued save is committed (or failed)."""
        self._q.join()
        self._raise_pending()

    def close(self):
        self.wait_for_checkpoint()
        if self._thread is not None:
            self._q.put(None)
            self._thread.join(timeout=60)
            self._thread = None

    # -- worker side --------------------------------------------------------
    def _ensure_thread(self):
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(target=self._worker,
                                            name="ckpt-writer", daemon=True)
            self._thread.start()

    def _worker(self):
        rec = obs.get_recorder()
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            snap, path, step = item
            try:
                with rec.span("ckpt_serialize", cat="ckpt",
                              track="checkpoint", step=step):
                    meta = _write_shards(path, snap, self.fs)
                with rec.span("ckpt_commit", cat="ckpt", track="checkpoint",
                              step=step):
                    _commit(path, meta, step, self.fs)
                self._prune()
            except BaseException as e:  # noqa: BLE001 — surfaced at wait()
                self._err = e
            finally:
                self._q.task_done()

    def _raise_pending(self):
        if self._err is not None:
            err, self._err = self._err, None
            raise CheckpointError(
                f"async checkpoint save failed: {err}") from err

    def _prune(self):
        found = find_checkpoints(self.root)
        for _, d in found[:-self.keep] if self.keep else []:
            shutil.rmtree(d, ignore_errors=True)
