"""Checkpointing: params/opt-state/step to a directory of .npy shards with
a JSON manifest (pytree structure + dtypes), like MXNet's save/load (§2.1).
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(path: str, state: dict, step: int | None = None):
    p = Path(path)
    p.mkdir(parents=True, exist_ok=True)
    leaves, treedef = _flatten(state)
    manifest = {"treedef": str(treedef), "n_leaves": len(leaves),
                "step": int(step) if step is not None else None,
                "dtypes": [str(np.asarray(l).dtype) for l in leaves],
                "shapes": [list(np.asarray(l).shape) for l in leaves]}
    for i, leaf in enumerate(leaves):
        np.save(p / f"leaf_{i}.npy", np.asarray(leaf))
    (p / "manifest.json").write_text(json.dumps(manifest))
    return p


def _leaf_name(path) -> str:
    """Human-readable pytree path for error messages."""
    return jax.tree_util.keystr(path) or "<root>"


def load_checkpoint(path: str, like: dict):
    """Restore into the structure of ``like``.

    Every leaf is validated against ``like`` — shape and dtype — and a
    ``ValueError`` naming the offending leaf path is raised on mismatch,
    instead of silently mis-restoring into the wrong structure (e.g.
    loading a reduced-config checkpoint into a full-size model, or fp32
    momentum into bf16 params).
    """
    p = Path(path)
    manifest_file = p / "manifest.json"
    if not manifest_file.exists():
        raise FileNotFoundError(f"no checkpoint manifest at {manifest_file}")
    manifest = json.loads(manifest_file.read_text())
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    if manifest["n_leaves"] != len(flat):
        raise ValueError(
            f"checkpoint at {p} has {manifest['n_leaves']} leaves but the "
            f"target structure has {len(flat)} — wrong checkpoint for this "
            f"model/optimizer state?")
    loaded = []
    for i, (kpath, ref) in enumerate(flat):
        arr = np.load(p / f"leaf_{i}.npy")
        # shape/dtype come straight off the leaf — no host materialization
        # of (possibly sharded, multi-GB) target state just to compare
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"checkpoint leaf {i} ({_leaf_name(kpath)}): saved shape "
                f"{tuple(arr.shape)} != expected {tuple(ref.shape)} — the "
                f"checkpoint was written for a different configuration")
        if arr.dtype != np.dtype(ref.dtype):
            raise ValueError(
                f"checkpoint leaf {i} ({_leaf_name(kpath)}): saved dtype "
                f"{arr.dtype} != expected {ref.dtype} — refusing to cast "
                f"silently; convert explicitly if this is intended")
        loaded.append(arr)
    state = jax.tree.unflatten(treedef, loaded)
    return state, manifest.get("step")
