"""Checkpointing: params/opt-state/step to a directory of .npy shards with
a JSON manifest (pytree structure + dtypes), like MXNet's save/load (§2.1).
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(path: str, state: dict, step: int | None = None):
    p = Path(path)
    p.mkdir(parents=True, exist_ok=True)
    leaves, treedef = _flatten(state)
    manifest = {"treedef": str(treedef), "n_leaves": len(leaves),
                "step": int(step) if step is not None else None,
                "dtypes": [str(np.asarray(l).dtype) for l in leaves],
                "shapes": [list(np.asarray(l).shape) for l in leaves]}
    for i, leaf in enumerate(leaves):
        np.save(p / f"leaf_{i}.npy", np.asarray(leaf))
    (p / "manifest.json").write_text(json.dumps(manifest))
    return p


def load_checkpoint(path: str, like: dict):
    """Restore into the structure of ``like`` (shape/dtype-checked)."""
    p = Path(path)
    manifest = json.loads((p / "manifest.json").read_text())
    leaves, treedef = jax.tree.flatten(like)
    assert manifest["n_leaves"] == len(leaves), "structure mismatch"
    loaded = []
    for i, ref in enumerate(leaves):
        arr = np.load(p / f"leaf_{i}.npy")
        assert list(arr.shape) == list(np.asarray(ref).shape), \
            (i, arr.shape, np.asarray(ref).shape)
        loaded.append(arr.astype(np.asarray(ref).dtype))
    state = jax.tree.unflatten(treedef, loaded)
    return state, manifest.get("step")
