from .trainer import Trainer, TrainConfig
from .checkpoint import save_checkpoint, load_checkpoint

__all__ = ["Trainer", "TrainConfig", "save_checkpoint", "load_checkpoint"]
