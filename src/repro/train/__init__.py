from .trainer import Trainer, TrainConfig
from .checkpoint import (AsyncCheckpointer, CheckpointError, FailingFS,
                         LocalFS, checkpoint_plan, find_checkpoints,
                         latest_checkpoint, load_checkpoint,
                         save_checkpoint, verify_checkpoint)

__all__ = ["Trainer", "TrainConfig", "save_checkpoint", "load_checkpoint",
           "AsyncCheckpointer", "CheckpointError", "FailingFS", "LocalFS",
           "checkpoint_plan", "find_checkpoints", "latest_checkpoint",
           "verify_checkpoint"]
