"""Training module (MXNet §2.4): trains a model given a symbolic module
and data iterators, "optionally distributedly if an additional KVStore is
provided" — the paper's loop verbatim:

    while(1) { kv.pull(net.w); net.forward_backward(); kv.push(net.g); }

Two backends:
  * ``jit``   — single-process pjit path (CPU smoke / TPU production);
    gradient sync is implicit (GSPMD) or via dist.collectives.
  * ``kvstore`` — the engine-scheduled path: gradients flow through a
    KVStore (local or the multi-worker simulation with sequential/eventual
    consistency), exercising C3/C4/C7 end-to-end.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import ArchConfig, get_model
from repro.obs import MetricsLogger
from repro.optim import sgd_momentum, warmup_cosine
from repro.optim.optimizers import Optimizer

from .checkpoint import AsyncCheckpointer


@dataclass
class TrainConfig:
    lr: float = 3e-4
    mu: float = 0.9
    weight_decay: float = 1e-4
    warmup_steps: int = 20
    total_steps: int = 200
    log_every: int = 10
    checkpoint_every: int = 0
    checkpoint_dir: str = "checkpoints"
    # sharded checkpointing (DESIGN.md §12): async finalization keeps
    # only the device->host shard snapshot on the step critical path;
    # serialization + two-phase commit run on a background thread.
    # checkpoint_keep prunes committed step_* dirs beyond the newest N.
    checkpoint_async: bool = True
    checkpoint_keep: int = 3
    grad_clip: float = 1.0
    # bucketed gradient sync emitted inside backward (DESIGN.md §7):
    # the §4 lazy-push analogue on the jit path. Numerically identical to
    # overlap=False; only the collective schedule changes.
    overlap: bool = False
    bucket_mb: float = 4.0
    # pipeline parallelism over the super-block stack (DESIGN.md §10):
    # number of "stage" mesh-axis groups (1 = off) and micro-batches
    # streamed through the 1F1B schedule.  Selects PerfFlags.pp_stages /
    # .microbatches; validated against the arch in Trainer.__init__.
    pp_stages: int = 1
    microbatches: int = 1
    # cross-worker gradient sync (DESIGN.md §15): "auto" leaves the
    # reduction to GSPMD (implicit, the default); "sequential" computes
    # per-worker grads explicitly and reduces them with the two-level
    # bucketed schedule every step; "eventual" additionally bounds each
    # bucket's cross-pod exchange to every max_staleness+1 steps
    # (EventualSync — the paper's §2.3 eventual-consistency KVStore).
    # Explicit modes degrade to "auto" when the ambient mesh has <= 1
    # gradient worker.
    sync_mode: str = "auto"
    max_staleness: int = 0


class Trainer:
    def __init__(self, cfg: ArchConfig, tcfg: TrainConfig,
                 optimizer: Optimizer | None = None,
                 logger: MetricsLogger | None = None):
        self.cfg = cfg
        self.tcfg = tcfg
        # stdout sink by default — a bare run logs exactly like before;
        # launch --metrics swaps in/adds the JSONL sink (DESIGN.md §11)
        self.logger = logger if logger is not None else MetricsLogger()
        if tcfg.pp_stages > 1 or tcfg.microbatches > 1:
            from repro.dist.pipeline import validate_pipeline
            from repro.perf_flags import FLAGS, set_flags
            validate_pipeline(n_stages=tcfg.pp_stages,
                              microbatches=tcfg.microbatches,
                              n_super=cfg.n_super,
                              seq_shard=FLAGS.seq_shard)
            set_flags(pp_stages=tcfg.pp_stages,
                      microbatches=tcfg.microbatches)
        if tcfg.sync_mode not in ("auto", "sequential", "eventual"):
            raise ValueError(f"sync_mode must be auto|sequential|eventual, "
                             f"got {tcfg.sync_mode!r}")
        if tcfg.sync_mode != "auto" and (tcfg.pp_stages > 1 or tcfg.overlap):
            raise ValueError("explicit sync_mode is incompatible with "
                             "pipeline parallelism and overlap taps")
        # eventual-sync runtime state (built lazily in fit, when the
        # params template and ambient mesh are known)
        self._ev = None
        self._ev_steps: dict = {}
        self.model = get_model(cfg)
        self.optimizer = optimizer or sgd_momentum(
            lr=tcfg.lr, mu=tcfg.mu, weight_decay=tcfg.weight_decay)
        self.schedule = warmup_cosine(tcfg.warmup_steps, tcfg.total_steps)
        self.history: list[dict] = []
        # sharded checkpoint manager (DESIGN.md §12), created only when
        # checkpointing is on — fit() enqueues, exit waits for the commit
        self.checkpointer = (AsyncCheckpointer(
            tcfg.checkpoint_dir, keep=tcfg.checkpoint_keep,
            async_save=tcfg.checkpoint_async)
            if tcfg.checkpoint_every else None)

    # ------------------------------------------------------------------
    def init_state(self, seed: int = 0):
        params = self.model.init(jax.random.PRNGKey(seed))
        opt = self.optimizer.init(params)
        return params, opt

    def _make_step(self):
        model, optimizer, schedule = self.model, self.optimizer, self.schedule
        clip = self.tcfg.grad_clip
        overlap = self.tcfg.overlap
        bucket_bytes = max(int(self.tcfg.bucket_mb * 2**20), 1)

        pp = self.tcfg.pp_stages > 1

        def loss_fn(params, batch):
            if overlap:
                # route params through per-bucket custom_vjp taps so each
                # bucket's gradient reduction is emitted inside backward.
                # Under pipeline parallelism the block stack is excluded:
                # its grads are stage-sharded and already reduced over the
                # data axes inside the pipeline backward — a replicated
                # bucket pin would all-gather them over "stage"
                # (DESIGN.md §10); taps cover the replicated params only.
                from repro.dist import overlap_taps
                if pp:
                    rest = {k: v for k, v in params.items() if k != "blocks"}
                    params = {**overlap_taps(rest, cap_bytes=bucket_bytes),
                              "blocks": params["blocks"]}
                else:
                    params = overlap_taps(params, cap_bytes=bucket_bytes)
            return model.loss(params, batch)

        @jax.jit
        def step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            if clip:
                gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                                  for g in jax.tree.leaves(grads)))
                scale = jnp.minimum(1.0, clip / (gn + 1e-9))
                grads = jax.tree.map(lambda g: g * scale.astype(g.dtype),
                                     grads)
            else:
                gn = jnp.zeros(())
            lr_scale = schedule(opt_state["step"])
            params, opt_state = optimizer.update(grads, opt_state, params,
                                                 lr_scale=lr_scale)
            return params, opt_state, {"loss": loss, "grad_norm": gn,
                                       **metrics}
        return step

    # -- explicit cross-worker sync (DESIGN.md §15) --------------------
    def _sync_setup(self):
        """``(mesh, waxes, n_workers)`` for the explicit sync path, or
        ``None`` when the ambient mesh cannot support it (no mesh, or a
        single gradient worker) — the caller degrades to the auto path."""
        from repro.dist import worker_axes
        from repro.dist import compat as dist_compat
        mesh = dist_compat.current_mesh()
        if mesh is None:
            return None
        waxes = worker_axes(mesh)
        sizes = dict(mesh.shape)
        n = 1
        for a in waxes:
            n *= sizes[a]
        if n <= 1:
            return None
        if sizes.get("model", 1) > 1:
            raise ValueError(
                "explicit sync_mode holds params replicated inside the "
                "per-worker region; a multi-way model axis is not supported")
        return mesh, waxes, n

    def _make_grad_fn(self, mesh, waxes):
        """Per-worker loss/grads as global ``(W, ...)`` arrays: params
        replicated into a fully-manual shard_map, batch split on dim 0
        over the worker axes, annotations suppressed (the pipeline-stage
        precedent — model code must not re-annotate inside manual)."""
        from jax.sharding import PartitionSpec as P
        from repro.dist import annotate as dist_annotate
        from repro.dist import compat as dist_compat
        model = self.model

        def per_worker(params, batch):
            with dist_annotate.suppressed():
                (loss, metrics), grads = jax.value_and_grad(
                    model.loss, has_aux=True)(params, batch)
            lead = lambda x: jnp.asarray(x)[None]
            return (lead(loss), jax.tree.map(lead, metrics),
                    jax.tree.map(lead, grads))

        return dist_compat.shard_map(
            per_worker, mesh,
            in_specs=(P(), P(waxes)),
            out_specs=(P(waxes), P(waxes), P(waxes)))

    def _finish_step(self, loss_w, metrics_w, grads, opt_state, params):
        """Shared tail of the explicit step: clip, schedule, update."""
        clip = self.tcfg.grad_clip
        if clip:
            gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                              for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, clip / (gn + 1e-9))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
        else:
            gn = jnp.zeros(())
        lr_scale = self.schedule(opt_state["step"])
        params, opt_state = self.optimizer.update(grads, opt_state, params,
                                                  lr_scale=lr_scale)
        metrics = {"loss": loss_w.mean(), "grad_norm": gn,
                   **jax.tree.map(lambda x: x.mean(axis=0), metrics_w)}
        return params, opt_state, metrics

    def _make_sequential_step(self, mesh, waxes, n_workers):
        from repro.dist import gradient_sync
        grad_fn = self._make_grad_fn(mesh, waxes)
        bucket_bytes = max(int(self.tcfg.bucket_mb * 2**20), 1)

        @jax.jit
        def step(params, opt_state, batch):
            loss_w, metrics_w, grads_w = grad_fn(params, batch)
            synced = gradient_sync(mesh, grads_w, mode="bucketed",
                                   bucket_bytes=bucket_bytes)
            grads = jax.tree.map(lambda g: g / n_workers, synced)
            return self._finish_step(loss_w, metrics_w, grads,
                                     opt_state, params)
        return step

    def _setup_eventual(self, mesh, waxes, n_workers, params):
        from repro.dist.collectives import EventualSync
        template = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct((n_workers,) + p.shape, p.dtype),
            params)
        self._ev = EventualSync(
            mesh, template, max_staleness=self.tcfg.max_staleness,
            bucket_bytes=max(int(self.tcfg.bucket_mb * 2**20), 1))
        self._ev_grad_fn = self._make_grad_fn(mesh, waxes)
        self._ev_n_workers = n_workers
        self._ev_steps = {}
        return self._ev.init_state()

    def _eventual_step(self, phase: int, warm: bool):
        """jit variant for one (phase, warm) — the schedule is static, so
        each variant lowers exactly the scheduled buckets' cross-pod
        collectives (what makes the HLO byte model exact)."""
        key = (phase, warm)
        if key not in self._ev_steps:
            ev, grad_fn = self._ev, self._ev_grad_fn
            n_workers = self._ev_n_workers

            @jax.jit
            def step(params, opt_state, batch, sync_state):
                loss_w, metrics_w, grads_w = grad_fn(params, batch)
                synced, new_state = ev.apply(grads_w, sync_state,
                                             phase=phase, warm=warm)
                grads = jax.tree.map(lambda g: g / n_workers, synced)
                out = self._finish_step(loss_w, metrics_w, grads,
                                        opt_state, params)
                return (*out, new_state)
            self._ev_steps[key] = step
        return self._ev_steps[key]

    def _make_globalize(self):
        """Batch host->device transfer.  Single-process: plain asarray.
        Multi-process (DESIGN.md §15): each host holds its contiguous
        row-slice of the global batch (``data.pipeline.global_batch_slice``
        order), which lines up with process-major device order on the
        ``(pod, data)`` mesh — ``make_array_from_process_local_data``
        assembles the global array with no cross-host shuffle."""
        if jax.process_count() == 1:
            return lambda b: {k: jnp.asarray(v) for k, v in b.items()}
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P
        from repro.dist import worker_axes
        from repro.dist import compat as dist_compat
        mesh = dist_compat.current_mesh()
        if mesh is None:
            raise ValueError("multi-process fit needs an ambient mesh "
                             "(jax.set_mesh) to place the global batch")
        sharding = NamedSharding(mesh, P(worker_axes(mesh)))
        nproc = jax.process_count()

        def to_global(v):
            v = np.asarray(v)
            gshape = (v.shape[0] * nproc,) + v.shape[1:]
            return jax.make_array_from_process_local_data(sharding, v,
                                                          gshape)
        return lambda b: {k: to_global(v) for k, v in b.items()}

    # ------------------------------------------------------------------
    def fit(self, data: Iterator, seed: int = 0, state=None,
            start_step: int = 0):
        """jit path.

        Per-step obs (DESIGN.md §11): ``data_wait`` / ``step`` /
        ``metrics_fetch`` / ``checkpoint`` spans on the "trainer" track.
        Metrics reach the host via ONE ``jax.device_get`` of the whole
        dict, only on log steps — per-item ``float(v)`` inside the loop
        forced a device sync per metric on every logged step, blocking
        dispatch of the next step's work.

        Checkpointing (DESIGN.md §12) is an *enqueue*: the span covers
        only the device->host shard snapshot; the write + atomic commit
        happen on the checkpointer's background thread and are flushed
        by ``wait_for_checkpoint()`` before fit returns.

        ``start_step`` resumes a run: pass the restored ``state`` and
        the step after the checkpoint's; the caller fast-forwards
        ``data`` to the same position.
        """
        params, opt_state = state or self.init_state(seed)
        mode = self.tcfg.sync_mode
        setup = self._sync_setup() if mode != "auto" else None
        sync_state = None
        if setup is None:
            # auto path — or explicit mode on a 1-worker mesh, where the
            # explicit reduction is the identity and GSPMD already agrees
            step_fn = self._make_step()
        elif mode == "sequential":
            step_fn = self._make_sequential_step(*setup)
        else:  # eventual
            sync_state = self._setup_eventual(*setup, params)
            step_fn = None
        rec = obs.get_recorder()
        globalize = self._make_globalize()
        t0 = time.time()
        t_log, i_log = t0, start_step    # steps_per_s window since last log
        data = iter(data)
        i = start_step
        while i < self.tcfg.total_steps:
            with rec.span("data_wait", cat="train", track="trainer", step=i):
                batch = next(data, None)
            if batch is None:
                break
            batch = globalize(batch)
            with rec.span("step", cat="train", track="trainer", step=i), \
                    obs.annotation("train_step"):
                if step_fn is not None:
                    params, opt_state, metrics = step_fn(params, opt_state,
                                                         batch)
                else:
                    phase, warm = self._ev.phase_for(i)
                    params, opt_state, metrics, sync_state = \
                        self._eventual_step(phase, warm)(
                            params, opt_state, batch, sync_state)
                    self._ev.record_step(i)
            if i % self.tcfg.log_every == 0 or i == self.tcfg.total_steps - 1:
                with rec.span("metrics_fetch", cat="train", track="trainer",
                              step=i):
                    m = {k: float(v)
                         for k, v in jax.device_get(metrics).items()}
                now = time.time()
                m.update(step=i, wall_s=round(now - t0, 2),
                         steps_per_s=round((i - i_log + 1)
                                           / max(now - t_log, 1e-9), 3))
                t_log, i_log = now, i + 1
                self.history.append(m)
                self.logger.log(m)
                obs.get_metrics().gauge("train.steps_per_s").set(
                    m["steps_per_s"])
            if (self.tcfg.checkpoint_every
                    and i and i % self.tcfg.checkpoint_every == 0):
                with rec.span("checkpoint", cat="train", track="trainer",
                              step=i):
                    self.checkpointer.save(
                        {"params": params, "opt": opt_state}, step=i)
            i += 1
        if self.checkpointer is not None:
            with rec.span("checkpoint_wait", cat="train", track="trainer"):
                self.checkpointer.wait_for_checkpoint()
        return params, opt_state

    def wait_for_checkpoint(self):
        """Flush pending async checkpoint saves (re-raises failures)."""
        if self.checkpointer is not None:
            self.checkpointer.wait_for_checkpoint()

    # ------------------------------------------------------------------
    def fit_kvstore(self, data: Iterator, kv, n_workers: int = 1,
                    seed: int = 0):
        """The paper's KVStore loop: grads pushed, weights pulled.

        ``kv``: KVStoreDist (simulation). Each step splits the batch over
        n_workers; every worker pulls its (possibly stale) weights, computes
        grads, pushes. Returns the loss history.
        """
        params0, _ = self.init_state(seed)
        flat, treedef = jax.tree.flatten(params0)
        keys = [f"w{i}" for i in range(len(flat))]
        for k, v in zip(keys, flat):
            kv.init(k, np.asarray(v, np.float32))
        model = self.model

        @jax.jit
        def grad_fn(params, batch):
            (loss, _), grads = jax.value_and_grad(model.loss,
                                                  has_aux=True)(params, batch)
            return loss, grads

        losses = []
        lr = self.tcfg.lr
        kv.set_updater(lambda key, stored, g: stored - lr * np.asarray(g))
        for i, batch in enumerate(data):
            if i >= self.tcfg.total_steps:
                break
            tokens = np.asarray(batch["tokens"])
            shards = np.array_split(tokens, n_workers)
            step_losses = []
            for w in range(n_workers):
                pulled = [jnp.asarray(kv.pull(k, w)).astype(l.dtype)
                          for k, l in zip(keys, flat)]
                params = jax.tree.unflatten(treedef, pulled)
                loss, grads = grad_fn(params, {"tokens":
                                               jnp.asarray(shards[w])})
                gleaves = jax.tree.leaves(grads)
                for k, g in zip(keys, gleaves):
                    kv.push(k, w, np.asarray(g, np.float32) / n_workers)
                step_losses.append(float(loss))
            losses.append(float(np.mean(step_losses)))
        # per-key push/pull byte attribution -> process metrics registry
        kv.publish_metrics()
        return losses
