"""Training module (MXNet §2.4): trains a model given a symbolic module
and data iterators, "optionally distributedly if an additional KVStore is
provided" — the paper's loop verbatim:

    while(1) { kv.pull(net.w); net.forward_backward(); kv.push(net.g); }

Two backends:
  * ``jit``   — single-process pjit path (CPU smoke / TPU production);
    gradient sync is implicit (GSPMD) or via dist.collectives.
  * ``kvstore`` — the engine-scheduled path: gradients flow through a
    KVStore (local or the multi-worker simulation with sequential/eventual
    consistency), exercising C3/C4/C7 end-to-end.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import ArchConfig, get_model
from repro.obs import MetricsLogger
from repro.optim import sgd_momentum, warmup_cosine
from repro.optim.optimizers import Optimizer

from .checkpoint import AsyncCheckpointer


@dataclass
class TrainConfig:
    lr: float = 3e-4
    mu: float = 0.9
    weight_decay: float = 1e-4
    warmup_steps: int = 20
    total_steps: int = 200
    log_every: int = 10
    checkpoint_every: int = 0
    checkpoint_dir: str = "checkpoints"
    # sharded checkpointing (DESIGN.md §12): async finalization keeps
    # only the device->host shard snapshot on the step critical path;
    # serialization + two-phase commit run on a background thread.
    # checkpoint_keep prunes committed step_* dirs beyond the newest N.
    checkpoint_async: bool = True
    checkpoint_keep: int = 3
    grad_clip: float = 1.0
    # bucketed gradient sync emitted inside backward (DESIGN.md §7):
    # the §4 lazy-push analogue on the jit path. Numerically identical to
    # overlap=False; only the collective schedule changes.
    overlap: bool = False
    bucket_mb: float = 4.0
    # pipeline parallelism over the super-block stack (DESIGN.md §10):
    # number of "stage" mesh-axis groups (1 = off) and micro-batches
    # streamed through the 1F1B schedule.  Selects PerfFlags.pp_stages /
    # .microbatches; validated against the arch in Trainer.__init__.
    pp_stages: int = 1
    microbatches: int = 1


class Trainer:
    def __init__(self, cfg: ArchConfig, tcfg: TrainConfig,
                 optimizer: Optimizer | None = None,
                 logger: MetricsLogger | None = None):
        self.cfg = cfg
        self.tcfg = tcfg
        # stdout sink by default — a bare run logs exactly like before;
        # launch --metrics swaps in/adds the JSONL sink (DESIGN.md §11)
        self.logger = logger if logger is not None else MetricsLogger()
        if tcfg.pp_stages > 1 or tcfg.microbatches > 1:
            from repro.dist.pipeline import validate_pipeline
            from repro.perf_flags import FLAGS, set_flags
            validate_pipeline(n_stages=tcfg.pp_stages,
                              microbatches=tcfg.microbatches,
                              n_super=cfg.n_super,
                              seq_shard=FLAGS.seq_shard)
            set_flags(pp_stages=tcfg.pp_stages,
                      microbatches=tcfg.microbatches)
        self.model = get_model(cfg)
        self.optimizer = optimizer or sgd_momentum(
            lr=tcfg.lr, mu=tcfg.mu, weight_decay=tcfg.weight_decay)
        self.schedule = warmup_cosine(tcfg.warmup_steps, tcfg.total_steps)
        self.history: list[dict] = []
        # sharded checkpoint manager (DESIGN.md §12), created only when
        # checkpointing is on — fit() enqueues, exit waits for the commit
        self.checkpointer = (AsyncCheckpointer(
            tcfg.checkpoint_dir, keep=tcfg.checkpoint_keep,
            async_save=tcfg.checkpoint_async)
            if tcfg.checkpoint_every else None)

    # ------------------------------------------------------------------
    def init_state(self, seed: int = 0):
        params = self.model.init(jax.random.PRNGKey(seed))
        opt = self.optimizer.init(params)
        return params, opt

    def _make_step(self):
        model, optimizer, schedule = self.model, self.optimizer, self.schedule
        clip = self.tcfg.grad_clip
        overlap = self.tcfg.overlap
        bucket_bytes = max(int(self.tcfg.bucket_mb * 2**20), 1)

        pp = self.tcfg.pp_stages > 1

        def loss_fn(params, batch):
            if overlap:
                # route params through per-bucket custom_vjp taps so each
                # bucket's gradient reduction is emitted inside backward.
                # Under pipeline parallelism the block stack is excluded:
                # its grads are stage-sharded and already reduced over the
                # data axes inside the pipeline backward — a replicated
                # bucket pin would all-gather them over "stage"
                # (DESIGN.md §10); taps cover the replicated params only.
                from repro.dist import overlap_taps
                if pp:
                    rest = {k: v for k, v in params.items() if k != "blocks"}
                    params = {**overlap_taps(rest, cap_bytes=bucket_bytes),
                              "blocks": params["blocks"]}
                else:
                    params = overlap_taps(params, cap_bytes=bucket_bytes)
            return model.loss(params, batch)

        @jax.jit
        def step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            if clip:
                gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                                  for g in jax.tree.leaves(grads)))
                scale = jnp.minimum(1.0, clip / (gn + 1e-9))
                grads = jax.tree.map(lambda g: g * scale.astype(g.dtype),
                                     grads)
            else:
                gn = jnp.zeros(())
            lr_scale = schedule(opt_state["step"])
            params, opt_state = optimizer.update(grads, opt_state, params,
                                                 lr_scale=lr_scale)
            return params, opt_state, {"loss": loss, "grad_norm": gn,
                                       **metrics}
        return step

    # ------------------------------------------------------------------
    def fit(self, data: Iterator, seed: int = 0, state=None,
            start_step: int = 0):
        """jit path.

        Per-step obs (DESIGN.md §11): ``data_wait`` / ``step`` /
        ``metrics_fetch`` / ``checkpoint`` spans on the "trainer" track.
        Metrics reach the host via ONE ``jax.device_get`` of the whole
        dict, only on log steps — per-item ``float(v)`` inside the loop
        forced a device sync per metric on every logged step, blocking
        dispatch of the next step's work.

        Checkpointing (DESIGN.md §12) is an *enqueue*: the span covers
        only the device->host shard snapshot; the write + atomic commit
        happen on the checkpointer's background thread and are flushed
        by ``wait_for_checkpoint()`` before fit returns.

        ``start_step`` resumes a run: pass the restored ``state`` and
        the step after the checkpoint's; the caller fast-forwards
        ``data`` to the same position.
        """
        params, opt_state = state or self.init_state(seed)
        step_fn = self._make_step()
        rec = obs.get_recorder()
        t0 = time.time()
        t_log, i_log = t0, start_step    # steps_per_s window since last log
        data = iter(data)
        i = start_step
        while i < self.tcfg.total_steps:
            with rec.span("data_wait", cat="train", track="trainer", step=i):
                batch = next(data, None)
            if batch is None:
                break
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            with rec.span("step", cat="train", track="trainer", step=i), \
                    obs.annotation("train_step"):
                params, opt_state, metrics = step_fn(params, opt_state,
                                                     batch)
            if i % self.tcfg.log_every == 0 or i == self.tcfg.total_steps - 1:
                with rec.span("metrics_fetch", cat="train", track="trainer",
                              step=i):
                    m = {k: float(v)
                         for k, v in jax.device_get(metrics).items()}
                now = time.time()
                m.update(step=i, wall_s=round(now - t0, 2),
                         steps_per_s=round((i - i_log + 1)
                                           / max(now - t_log, 1e-9), 3))
                t_log, i_log = now, i + 1
                self.history.append(m)
                self.logger.log(m)
                obs.get_metrics().gauge("train.steps_per_s").set(
                    m["steps_per_s"])
            if (self.tcfg.checkpoint_every
                    and i and i % self.tcfg.checkpoint_every == 0):
                with rec.span("checkpoint", cat="train", track="trainer",
                              step=i):
                    self.checkpointer.save(
                        {"params": params, "opt": opt_state}, step=i)
            i += 1
        if self.checkpointer is not None:
            with rec.span("checkpoint_wait", cat="train", track="trainer"):
                self.checkpointer.wait_for_checkpoint()
        return params, opt_state

    def wait_for_checkpoint(self):
        """Flush pending async checkpoint saves (re-raises failures)."""
        if self.checkpointer is not None:
            self.checkpointer.wait_for_checkpoint()

    # ------------------------------------------------------------------
    def fit_kvstore(self, data: Iterator, kv, n_workers: int = 1,
                    seed: int = 0):
        """The paper's KVStore loop: grads pushed, weights pulled.

        ``kv``: KVStoreDist (simulation). Each step splits the batch over
        n_workers; every worker pulls its (possibly stale) weights, computes
        grads, pushes. Returns the loss history.
        """
        params0, _ = self.init_state(seed)
        flat, treedef = jax.tree.flatten(params0)
        keys = [f"w{i}" for i in range(len(flat))]
        for k, v in zip(keys, flat):
            kv.init(k, np.asarray(v, np.float32))
        model = self.model

        @jax.jit
        def grad_fn(params, batch):
            (loss, _), grads = jax.value_and_grad(model.loss,
                                                  has_aux=True)(params, batch)
            return loss, grads

        losses = []
        lr = self.tcfg.lr
        kv.set_updater(lambda key, stored, g: stored - lr * np.asarray(g))
        for i, batch in enumerate(data):
            if i >= self.tcfg.total_steps:
                break
            tokens = np.asarray(batch["tokens"])
            shards = np.array_split(tokens, n_workers)
            step_losses = []
            for w in range(n_workers):
                pulled = [jnp.asarray(kv.pull(k, w)).astype(l.dtype)
                          for k, l in zip(keys, flat)]
                params = jax.tree.unflatten(treedef, pulled)
                loss, grads = grad_fn(params, {"tokens":
                                               jnp.asarray(shards[w])})
                gleaves = jax.tree.leaves(grads)
                for k, g in zip(keys, gleaves):
                    kv.push(k, w, np.asarray(g, np.float32) / n_workers)
                step_losses.append(float(loss))
            losses.append(float(np.mean(step_losses)))
        # per-key push/pull byte attribution -> process metrics registry
        kv.publish_metrics()
        return losses
