"""Fused top-k / top-p token sampling as a Pallas kernel.

Per decode step the serve engines need one token per lane from the
``(B, V)`` logits.  The host path is a sort (top-k), a cumsum (top-p) and
a categorical draw — three full-vocab passes with HBM round-trips between
them.  This kernel fuses filter + softmax + inverse-CDF draw into one
VMEM-resident pass per row tile; the only inputs besides logits are B
uniform floats (drawn with ``jax.random`` outside — the kernel itself is
RNG-free and deterministic).

Sorting is not available on the VPU, so both cutoffs are found by a
32-step binary search over the *bit space* of the score values: an IEEE
f32 compares like its sign-adjusted uint32 image, so "the k-th largest
score" and "the smallest score whose strictly-greater probability mass is
< top_p * Z" are both exact lattice points reachable by monotone
predicate bisection (no float epsilon anywhere — ties share one key and
are kept or dropped together, matching ``ref.sample_ref``).

Semantics (shared with the oracle):

* temperature == 0: plain argmax (first index on ties);
* top-k keeps every score >= the k-th largest (ties widen the set);
* top-p keeps score x iff the probability mass STRICTLY ABOVE x is
  < top_p * Z, computed over the top-k-filtered distribution;
* the draw inverts the CDF in vocab-index order: the sampled index is
  the first i with cumsum(p)[i] > u * total_mass.

Tunable: ``rows_per_step`` — logits rows per grid step (registry op
``sample_tokens``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 spells it TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def _order_keys(x):
    """f32 -> uint32 image with the same total order (sign-flip trick)."""
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    sign = (bits >> jnp.uint32(31)).astype(bool)
    return jnp.where(sign, ~bits, bits | jnp.uint32(0x80000000))


def _kth_largest_key(keys, k):
    """Exact k-th largest uint32 key per row (keys: (R, V) -> (R, 1)).

    Greedy MSB-first bisection for the largest lattice value t with
    ``count(keys >= t) >= k``; since every key is a lattice point, t IS
    the k-th largest key.
    """
    t = jnp.zeros((keys.shape[0], 1), jnp.uint32)
    for b in range(31, -1, -1):
        cand = t | jnp.uint32(2 ** b)
        cnt = jnp.sum((keys >= cand).astype(jnp.int32), axis=1,
                      keepdims=True)
        t = jnp.where(cnt >= k, cand, t)
    return t


def _nucleus_keep(keys, p, budget):
    """Top-p keep mask: keep key x iff ``sum(p[keys > x]) < budget``.

    Bisection for the largest lattice t with mass-strictly-above >=
    budget; the kept set is then ``keys > t`` (or everything, when even
    the full strictly-above-minimum mass is under budget).
    """
    R = keys.shape[0]

    def strict_mass(t):
        return jnp.sum(jnp.where(keys > t, p, 0.0), axis=1, keepdims=True)

    t = jnp.zeros((R, 1), jnp.uint32)
    for b in range(31, -1, -1):
        cand = t | jnp.uint32(2 ** b)
        t = jnp.where(strict_mass(cand) >= budget, cand, t)
    all_kept = strict_mass(jnp.zeros((R, 1), jnp.uint32)) < budget
    return jnp.where(all_kept, True, keys > t)


def _sampling_kernel(logits_ref, u_ref, o_ref, *, temperature, top_k,
                     top_p, vocab):
    l = logits_ref[...].astype(jnp.float32)            # (R, V)
    if temperature == 0.0:
        o_ref[...] = jnp.argmax(l, axis=1, keepdims=True).astype(jnp.int32)
        return
    x = l / temperature
    keys = _order_keys(x)
    keep = jnp.ones_like(x, bool)
    if top_k is not None and 0 < top_k < vocab:
        keep &= keys >= _kth_largest_key(keys, top_k)
    m = jnp.max(x, axis=1, keepdims=True)              # argmax always kept
    p = jnp.where(keep, jnp.exp(x - m), 0.0)
    if top_p is not None and top_p < 1.0:
        budget = top_p * jnp.sum(p, axis=1, keepdims=True)
        p = jnp.where(_nucleus_keep(keys, p, budget), p, 0.0)
    c = jnp.cumsum(p, axis=1)
    target = u_ref[...] * c[:, -1:]                    # u in [0,1) -> < total
    o_ref[...] = jnp.argmax(c > target, axis=1,
                            keepdims=True).astype(jnp.int32)


def sample_tokens(logits, u, *, temperature=1.0, top_k=None, top_p=None,
                  rows_per_step=4, interpret=None):
    """Sample one token per row.  logits: (B, V); u: (B,) uniforms in
    [0, 1).  Returns (B,) int32.  ``temperature == 0`` is greedy argmax
    (u is ignored); ``top_k=None``/``top_p=None`` disable the cutoffs.
    """
    B, V = logits.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    rb = max(1, min(int(rows_per_step), B))
    pad = (-B) % rb
    if pad:
        logits = jnp.pad(logits, [(0, pad), (0, 0)])
        u = jnp.pad(u, [(0, pad)])
    n_tiles = (B + pad) // rb

    kernel = functools.partial(
        _sampling_kernel, temperature=float(temperature),
        top_k=None if top_k is None else int(top_k),
        top_p=None if top_p is None else float(top_p), vocab=V)
    out = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((rb, V), lambda i: (i, 0)),
                  pl.BlockSpec((rb, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rb, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B + pad, 1), jnp.int32),
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(logits, u.astype(jnp.float32)[:, None])
    return out[:B, 0]
