"""Shape-keyed autotuner for the registered Pallas kernels (DESIGN.md §13).

``tune`` sweeps an op's tunable space (``registry.OpSpec.candidates``,
defaults always included) with timed compiled runs on the caller's real
arrays, and persists the winner under the key
``op|backend|shape-bucket`` in a JSON cache.  ``kernels/ops.py`` consults
the cache on every call (``registry.resolve``), so call sites get tuned
parameters with no signature change — tuning is an explicit offline step
(this module's CLI, or ``bench_kernels.py``'s sweep), never implicit at
inference time.

Cache location: ``~/.cache/repro/autotune.json``, overridable with the
``REPRO_AUTOTUNE_CACHE`` environment variable.  A corrupt or unreadable
cache file degrades to the defaults with a warning — it never crashes a
serving process.

CLI::

    python -m repro.kernels.autotune --op paged_attention   # one op
    python -m repro.kernels.autotune --all                  # every op
    python -m repro.kernels.autotune --all --cache /tmp/at.json --json

>>> import tempfile, os
>>> path = os.path.join(tempfile.mkdtemp(), "autotune.json")
>>> c = AutotuneCache(path)
>>> key = cache_key("rmsnorm", "rows=512,d=256,f32", backend="cpu")
>>> c.put(key, {"block_rows": 1024}, tuned_us=10.0, default_us=30.0)
>>> c.save()
>>> AutotuneCache(path).get(key)
{'block_rows': 1024}
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import time
import warnings
from pathlib import Path

import jax

from . import registry

CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
DEFAULT_CACHE = "~/.cache/repro/autotune.json"
_SCHEMA = 1


def cache_path() -> Path:
    return Path(os.environ.get(CACHE_ENV) or DEFAULT_CACHE).expanduser()


def cache_key(op: str, bucket: str, backend: str | None = None) -> str:
    backend = backend or jax.default_backend()
    return f"{op}|{backend}|{bucket}"


class AutotuneCache:
    """The persisted winner table: ``key -> {params, tuned_us, ...}``."""

    def __init__(self, path: str | Path | None = None):
        self.path = Path(path) if path is not None else cache_path()
        self.entries: dict[str, dict] = {}
        self._load()

    def _load(self):
        if not self.path.exists():
            return
        try:
            data = json.loads(self.path.read_text())
            if (not isinstance(data, dict)
                    or not isinstance(data.get("entries"), dict)):
                raise ValueError("missing 'entries' table")
            self.entries = data["entries"]
        except (ValueError, OSError) as e:
            warnings.warn(
                f"autotune cache {self.path} is unreadable ({e}); "
                f"falling back to default kernel parameters", stacklevel=2)
            self.entries = {}

    def get(self, key: str) -> dict | None:
        e = self.entries.get(key)
        return dict(e["params"]) if e else None

    def put(self, key: str, params: dict, *, tuned_us: float,
            default_us: float):
        self.entries[key] = {
            "params": dict(params),
            "tuned_us": round(float(tuned_us), 3),
            "default_us": round(float(default_us), 3)}

    def save(self):
        """Atomic write (tmp + rename) so a crashed tuner never leaves a
        truncated cache behind."""
        self.path.parent.mkdir(parents=True, exist_ok=True)
        tmp = self.path.with_suffix(".tmp")
        tmp.write_text(json.dumps(
            {"schema": _SCHEMA, "entries": self.entries}, indent=1,
            sort_keys=True))
        os.replace(tmp, self.path)


# process-wide singleton consulted by registry.resolve on every op call;
# loaded lazily once (re-reading JSON per decode step would be absurd)
_CACHE: AutotuneCache | None = None


def get_cache() -> AutotuneCache:
    global _CACHE
    if _CACHE is None:
        _CACHE = AutotuneCache()
    return _CACHE


def reset_cache():
    """Drop the singleton (tests flip ``REPRO_AUTOTUNE_CACHE``)."""
    global _CACHE
    _CACHE = None


def cached_params(op: str, bucket: str) -> dict | None:
    return get_cache().get(cache_key(op, bucket))


# ---------------------------------------------------------------------------
# the sweep


def _time_us(fn, args, *, repeats: int, warmup: int) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def tune(op: str, args, kwargs=None, *, cache: AutotuneCache | None = None,
         repeats: int = 3, warmup: int = 1, save: bool = True) -> dict:
    """Sweep ``op``'s tunable space on one concrete workload.

    ``args``/``kwargs`` are the op's real call arguments (tunables
    excluded).  Every candidate is jit-compiled and timed; the winner is
    stored under the workload's shape bucket.  Returns a report dict
    (params / tuned_us / default_us / speedup / bucket / key / sweep).
    Since the defaults are always in the candidate set, ``speedup`` is
    >= 1.0 by construction.
    """
    kwargs = dict(kwargs or {})
    spec = registry.get(op)
    bucket = spec.bucket_of(*args, **kwargs)
    key = cache_key(op, bucket)
    sweep = []
    best = None
    default_us = None
    for cand in spec.candidates():
        fn = jax.jit(functools.partial(spec.impl, **kwargs, **cand))
        try:
            us = _time_us(fn, args, repeats=repeats, warmup=warmup)
        except Exception as e:  # noqa: BLE001 — candidate may be invalid
            sweep.append({**cand, "us": None, "error": f"{type(e).__name__}"})
            continue
        sweep.append({**cand, "us": round(us, 3)})
        if default_us is None:          # candidates() yields defaults first
            default_us = us
        if best is None or us < best[1]:
            best = (cand, us)
    if best is None:
        raise RuntimeError(f"every candidate failed for {op} ({bucket})")
    params, tuned_us = best
    cache = cache or get_cache()
    cache.put(key, params, tuned_us=tuned_us, default_us=default_us)
    if save:
        cache.save()
    return {"op": op, "bucket": bucket, "key": key, "params": params,
            "tuned_us": tuned_us, "default_us": default_us,
            "speedup": default_us / tuned_us, "sweep": sweep}


def tune_op_bench_cases(op: str, **kw) -> list[dict]:
    """Tune every canned bench case of one op (the CLI unit of work)."""
    spec = registry.get(op)
    out = []
    for label, make in spec.bench_cases:
        args, kwargs = make()
        rep = tune(op, args, kwargs, **kw)
        rep["case"] = label
        out.append(rep)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Sweep kernel tunables and persist winners "
                    "(see DESIGN.md §13)")
    ap.add_argument("--op", action="append", default=[],
                    help="op to tune (repeatable); see --list")
    ap.add_argument("--all", action="store_true", help="tune every op")
    ap.add_argument("--list", action="store_true",
                    help="list registered ops and exit")
    ap.add_argument("--cache", default=None,
                    help=f"cache file (default: ${CACHE_ENV} or "
                         f"{DEFAULT_CACHE})")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--json", action="store_true",
                    help="emit the report as JSON instead of a table")
    args = ap.parse_args(argv)

    if args.list:
        for name in registry.ops():
            spec = registry.get(name)
            print(f"{name}: tunables={dict(spec.tunables)} "
                  f"defaults={spec.defaults}")
        return 0

    names = registry.ops() if args.all else args.op
    if not names:
        ap.error("pass --op NAME (repeatable), --all, or --list")
    cache = AutotuneCache(args.cache) if args.cache else get_cache()

    reports = []
    for name in names:
        reports.extend(tune_op_bench_cases(name, cache=cache,
                                           repeats=args.repeats))
    if args.json:
        print(json.dumps(reports, indent=1))
    else:
        print(f"# autotune -> {cache.path}")
        print("op,case,bucket,winner,tuned_us,default_us,speedup")
        for r in reports:
            win = " ".join(f"{k}={v}" for k, v in sorted(r["params"].items()))
            print(f"{r['op']},{r['case']},{r['bucket']},{win},"
                  f"{r['tuned_us']:.1f},{r['default_us']:.1f},"
                  f"{r['speedup']:.2f}x")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
