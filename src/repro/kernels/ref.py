"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                        q_offset=0, kv_len=None):
    """q: (B, Sq, H, hd); k/v: (B, Sk, K, hd), H % K == 0. f32 math."""
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    kk = jnp.repeat(k, G, axis=2).astype(jnp.float32)
    vv = jnp.repeat(v, G, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32), kk) / np.sqrt(hd)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m &= kpos[None] <= qpos[:, None]
    if window is not None:
        m &= kpos[None] > qpos[:, None] - window
    if kv_len is not None:
        m &= (kpos < kv_len)[None]
    s = jnp.where(m[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", p, vv).astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths, *,
                        window=None, softcap=None):
    """q: (B, H, hd); pools: (NB, bs, K, hd); block_tables: (B, P) int32;
    lengths: (B,) live tokens incl. the current one.  Gathers the logical
    KV through the table, then masked dense attention in f32.  This is
    also the CPU fast path the serving engine uses (interpret-mode Pallas
    is per-grid-step Python)."""
    B, H, hd = q.shape
    NB, bs, K, _ = k_pages.shape
    G = H // K
    P = block_tables.shape[1]
    # (B, P, bs, K, hd) -> (B, P*bs, K, hd): logical position order
    k = k_pages[block_tables].reshape(B, P * bs, K, hd)
    v = v_pages[block_tables].reshape(B, P * bs, K, hd)
    qg = q.reshape(B, K, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(hd)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    kpos = jnp.arange(P * bs)
    mask = kpos[None] < lengths[:, None]                  # (B, S)
    if window is not None:
        mask &= kpos[None] > (lengths[:, None] - 1) - window
    s = jnp.where(mask[:, None, None], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m) * (mask[:, None, None])
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.where(l == 0.0, 1.0, l)                   # empty lane -> 0
    out = jnp.einsum("bkgs,bskh->bkgh", p, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


def rmsnorm_ref(x, weight, eps=1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, -1, keepdims=True) + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(dt)


def sgd_momentum_ref(param, grad, mom, *, lr, mu, weight_decay):
    """The KVStore updater as a fused mutating op (fp32 momentum master)."""
    g32 = grad.astype(jnp.float32) + weight_decay * param.astype(jnp.float32)
    mom_new = mu * mom + g32
    p_new = (param.astype(jnp.float32) - lr * mom_new).astype(param.dtype)
    return p_new, mom_new
