"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                        q_offset=0, kv_len=None):
    """q: (B, Sq, H, hd); k/v: (B, Sk, K, hd), H % K == 0. f32 math."""
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    kk = jnp.repeat(k, G, axis=2).astype(jnp.float32)
    vv = jnp.repeat(v, G, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32), kk) / np.sqrt(hd)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m &= kpos[None] <= qpos[:, None]
    if window is not None:
        m &= kpos[None] > qpos[:, None] - window
    if kv_len is not None:
        m &= (kpos < kv_len)[None]
    s = jnp.where(m[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", p, vv).astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths, *,
                        k_scale=None, v_scale=None, window=None,
                        softcap=None):
    """q: (B, H, hd); pools: (NB, bs, K, hd); block_tables: (B, P) int32;
    lengths: (B,) live tokens incl. the current one.  Gathers the logical
    KV through the table, then masked dense attention in f32.  This is
    also the CPU fast path the serving engine uses (interpret-mode Pallas
    is per-grid-step Python).

    ``k_scale``/``v_scale``: (NB, bs, K) f32 per-(token, kv-head) scales
    for quantized pools (DESIGN.md §13) — rows dequantize as
    ``row.astype(f32) * scale`` before attention."""
    B, H, hd = q.shape
    NB, bs, K, _ = k_pages.shape
    G = H // K
    P = block_tables.shape[1]
    # (B, P, bs, K, hd) -> (B, P*bs, K, hd): logical position order
    k = k_pages[block_tables].reshape(B, P * bs, K, hd)
    v = v_pages[block_tables].reshape(B, P * bs, K, hd)
    if k_scale is not None:
        from .quant import kv_dequantize
        k = kv_dequantize(k, k_scale[block_tables].reshape(B, P * bs, K))
        v = kv_dequantize(v, v_scale[block_tables].reshape(B, P * bs, K))
    qg = q.reshape(B, K, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(hd)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    kpos = jnp.arange(P * bs)
    mask = kpos[None] < lengths[:, None]                  # (B, S)
    if window is not None:
        mask &= kpos[None] > (lengths[:, None] - 1) - window
    s = jnp.where(mask[:, None, None], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m) * (mask[:, None, None])
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.where(l == 0.0, 1.0, l)                   # empty lane -> 0
    out = jnp.einsum("bkgs,bskh->bkgh", p, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


def rmsnorm_ref(x, weight, eps=1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, -1, keepdims=True) + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(dt)


def sample_ref(logits, u, *, temperature=1.0, top_k=None, top_p=None):
    """Oracle for ``kernels.sampling.sample_tokens``: top-k / top-p /
    inverse-CDF sampling in dense jnp with the kernel's exact tie rules.

    logits: (B, V); u: (B,) uniforms in [0, 1).  Returns (B,) int32.
    Top-p uses the per-token strict-mass predicate (keep x iff the mass
    strictly above x is < top_p * Z) via an O(V^2) pairwise sum — tie
    classes are kept or dropped whole, unlike the usual sorted-cumsum
    formulation that splits them arbitrarily.  Fine for oracle-sized V.
    """
    B, V = logits.shape
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    x = logits.astype(jnp.float32) / temperature
    keep = jnp.ones_like(x, bool)
    if top_k is not None and 0 < top_k < V:
        kth = jax.lax.top_k(x, top_k)[0][:, -1:]
        keep &= x >= kth
    m = jnp.max(x, axis=-1, keepdims=True)
    p = jnp.where(keep, jnp.exp(x - m), 0.0)
    if top_p is not None and top_p < 1.0:
        budget = top_p * jnp.sum(p, axis=-1, keepdims=True)
        strictly_above = x[:, None, :] > x[:, :, None]        # (B, V, V)
        mass_above = jnp.sum(strictly_above * p[:, None, :], axis=-1)
        p = jnp.where(mass_above < budget, p, 0.0)
    c = jnp.cumsum(p, axis=-1)
    target = u.astype(jnp.float32)[:, None] * c[:, -1:]
    return jnp.argmax(c > target, axis=-1).astype(jnp.int32)


def sgd_momentum_ref(param, grad, mom, *, lr, mu, weight_decay):
    """The KVStore updater as a fused mutating op (fp32 momentum master)."""
    g32 = grad.astype(jnp.float32) + weight_decay * param.astype(jnp.float32)
    mom_new = mu * mom + g32
    p_new = (param.astype(jnp.float32) - lr * mom_new).astype(param.dtype)
    return p_new, mom_new
