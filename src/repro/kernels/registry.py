"""Per-op kernel registry: reference + Pallas implementations and their
tunable-parameter spaces (DESIGN.md §13).

The paper's efficiency layer picks the best implementation per device and
shape (MXNet §5's mshadow kernel templates; TensorFlow's per-device op
registries make the same move).  Here every Pallas kernel registers:

* ``impl`` — the Pallas entry point (what ``kernels/ops.py`` wraps),
* ``reference`` — the pure-jnp oracle (``kernels/ref.py``),
* ``tunables`` — schedule knobs and their candidate values (block sizes,
  pages-per-step, ...).  Knobs never change results, only the schedule,
* ``defaults`` — the hand-picked values call sites get with no tuning,
* ``bucket_of`` — the shape-bucketing function: real call shapes map to
  a coarse bucket string (dims rounded up to powers of two) so one tuned
  entry covers a band of nearby shapes instead of one exact shape,
* ``bench_cases`` — canned representative workloads the autotuner CLI
  and ``bench_kernels.py`` sweep.

``resolve`` is the single lookup path: explicit caller kwargs beat the
autotune cache, which beats the defaults — so every existing call site
gets tuned parameters with no signature change, and a hand-passed
``block_q=...`` still wins.

>>> pow2_bucket(300)
512
>>> sorted(ops())[:3]
['flash_attention', 'paged_attention', 'rmsnorm']
>>> resolve("rmsnorm", {"block_rows": None}, "rows=512,d=256,f32")
{'block_rows': 256}
>>> resolve("rmsnorm", {"block_rows": 64}, "rows=512,d=256,f32")
{'block_rows': 64}
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Any, Callable

import jax.numpy as jnp


def pow2_bucket(n: int) -> int:
    """Smallest power of two >= n (bucket edge for a shape dim)."""
    n = max(1, int(n))
    return 1 << (n - 1).bit_length()


def _dt(dtype) -> str:
    """Short dtype tag for bucket strings (f32, bf16, i8, f8e4, ...)."""
    name = jnp.dtype(dtype).name
    return {"float32": "f32", "bfloat16": "bf16", "float16": "f16",
            "int8": "i8", "float8_e4m3fn": "f8e4",
            "float8_e5m2": "f8e5"}.get(name, name)


@dataclass(frozen=True)
class OpSpec:
    """One registered op (see module docstring for field semantics)."""
    name: str
    impl: Callable
    reference: Callable
    tunables: dict[str, tuple]
    defaults: dict[str, Any]
    bucket_of: Callable[..., str]
    bench_cases: tuple = ()     # ((label, make() -> (args, kwargs)), ...)

    def candidates(self) -> list[dict]:
        """Tunable cartesian product, defaults first (so a sweep always
        measures the untuned baseline)."""
        names = sorted(self.tunables)
        out = [dict(self.defaults)]
        for vals in itertools.product(*(self.tunables[n] for n in names)):
            c = dict(zip(names, vals))
            if c not in out:
                out.append(c)
        return out


_REGISTRY: dict[str, OpSpec] = {}


def register(spec: OpSpec) -> OpSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"op {spec.name!r} already registered")
    assert set(spec.defaults) == set(spec.tunables), spec.name
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> OpSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown op {name!r}; registered: {sorted(_REGISTRY)}"
                       ) from None


def ops() -> list[str]:
    return sorted(_REGISTRY)


def resolve(name: str, explicit: dict, bucket: str) -> dict:
    """Final tunable values for one call: defaults <- cached winner <-
    explicit non-None kwargs.  Returns a full params dict."""
    spec = get(name)
    params = dict(spec.defaults)
    from .autotune import cached_params       # lazy: autotune imports us
    won = cached_params(name, bucket)
    if won:
        params.update({k: v for k, v in won.items() if k in spec.tunables})
    params.update({k: v for k, v in explicit.items() if v is not None})
    return params


# ---------------------------------------------------------------------------
# registrations — one per Pallas kernel.  bench_cases build their arrays
# lazily (import-time stays allocation-free).

def _rand(key, shape, dtype=jnp.float32):
    import jax
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


def _register_all():
    from . import ref
    from .flash_attention import flash_attention
    from .fused_update import sgd_momentum
    from .paged_attention import paged_attention
    from .rmsnorm import rmsnorm
    from .sampling import sample_tokens

    def flash_bucket(q, k, v, **kw):
        B, Sq, H, hd = q.shape
        Sk, K = k.shape[1], k.shape[2]
        return (f"B={pow2_bucket(B)},Sq={pow2_bucket(Sq)},"
                f"Sk={pow2_bucket(Sk)},H={H},K={K},hd={hd},{_dt(q.dtype)}")

    def flash_case(B, S, H, K, hd):
        def make():
            return ((_rand(0, (B, S, H, hd)), _rand(1, (B, S, K, hd)),
                     _rand(2, (B, S, K, hd))), {"causal": True})
        return make

    register(OpSpec(
        name="flash_attention", impl=flash_attention,
        reference=ref.flash_attention_ref,
        tunables={"block_q": (64, 128, 256), "block_k": (64, 128, 256)},
        defaults={"block_q": 128, "block_k": 128},
        bucket_of=flash_bucket,
        bench_cases=(("S256_gqa", flash_case(1, 256, 4, 2, 64)),
                     ("S512_gqa", flash_case(1, 512, 8, 2, 64)))))

    def paged_bucket(q, k_pages, v_pages, block_tables, lengths, **kw):
        B, H, hd = q.shape
        bs, K = k_pages.shape[1], k_pages.shape[2]
        P = block_tables.shape[1]
        quant = "q" if kw.get("k_scale") is not None else ""
        return (f"B={pow2_bucket(B)},P={pow2_bucket(P)},bs={bs},H={H},"
                f"K={K},hd={hd},{_dt(k_pages.dtype)}{quant}")

    def paged_case(B, P, NB, bs, H, K, hd, kv_dtype=None):
        def make():
            import jax
            import numpy as np
            kp = _rand(1, (NB, bs, K, hd))
            vp = _rand(2, (NB, bs, K, hd))
            kw = {}
            if kv_dtype is not None:
                from .quant import kv_quantize_rows
                kp, kw["k_scale"] = kv_quantize_rows(kp, kv_dtype)
                vp, kw["v_scale"] = kv_quantize_rows(vp, kv_dtype)
            tables = jax.random.permutation(
                jax.random.PRNGKey(3),
                np.arange(1, NB))[:B * P].reshape(B, P).astype(jnp.int32)
            lengths = jnp.full((B,), P * bs - bs // 2, jnp.int32)
            return ((_rand(0, (B, H, hd)), kp, vp, tables, lengths), kw)
        return make

    register(OpSpec(
        name="paged_attention", impl=paged_attention,
        reference=ref.paged_attention_ref,
        tunables={"pages_per_step": (1, 2, 4), "head_tile": (1, 2)},
        defaults={"pages_per_step": 1, "head_tile": 1},
        bucket_of=paged_bucket,
        bench_cases=(
            ("decode_B4", paged_case(4, 8, 40, 16, 8, 2, 64)),
            ("decode_B4_int8", paged_case(4, 8, 40, 16, 8, 2, 64,
                                          kv_dtype=jnp.int8)))))

    def rmsnorm_bucket(x, weight, **kw):
        rows = 1
        for d in x.shape[:-1]:
            rows *= d
        return f"rows={pow2_bucket(rows)},d={x.shape[-1]},{_dt(x.dtype)}"

    def rmsnorm_case(rows, d):
        def make():
            return ((_rand(0, (rows, d)), _rand(1, (d,))), {})
        return make

    register(OpSpec(
        name="rmsnorm", impl=rmsnorm,
        reference=ref.rmsnorm_ref,
        tunables={"block_rows": (64, 256, 1024)},
        defaults={"block_rows": 256},
        bucket_of=rmsnorm_bucket,
        bench_cases=(("2048x512", rmsnorm_case(2048, 512)),
                     ("8192x512", rmsnorm_case(8192, 512)))))

    def sgd_bucket(param, grad, mom, **kw):
        return f"n={pow2_bucket(param.size)},{_dt(param.dtype)}"

    def sgd_case(n):
        def make():
            return ((_rand(0, (n,)), _rand(1, (n,)),
                     _rand(2, (n,))), {})
        return make

    register(OpSpec(
        name="sgd_momentum", impl=sgd_momentum,
        reference=ref.sgd_momentum_ref,
        tunables={"block": (16384, 65536, 262144)},
        defaults={"block": 65536},
        bucket_of=sgd_bucket,
        bench_cases=(("256k", sgd_case(1 << 18)),
                     ("1M", sgd_case(1 << 20)))))

    def sample_bucket(logits, u, **kw):
        B, V = logits.shape
        return f"B={pow2_bucket(B)},V={pow2_bucket(V)},{_dt(logits.dtype)}"

    def sample_case(B, V):
        def make():
            import jax
            u = jax.random.uniform(jax.random.PRNGKey(9), (B,))
            return ((_rand(0, (B, V)) * 3.0, u),
                    {"temperature": 0.8, "top_k": 50, "top_p": 0.9})
        return make

    register(OpSpec(
        name="sample_tokens", impl=sample_tokens,
        reference=ref.sample_ref,
        tunables={"rows_per_step": (1, 4, 8)},
        defaults={"rows_per_step": 4},
        bucket_of=sample_bucket,
        bench_cases=(("B8_V512", sample_case(8, 512)),
                     ("B16_V2048", sample_case(16, 2048)))))


_register_all()
