"""Quantized KV-cache helpers (int8 / fp8 paged pools, DESIGN.md §13).

The paged pool stores each cached token row quantized per (token, kv-head)
with a single f32 scale: ``row_q = clip(round(row / scale))`` where
``scale = max|row| / QMAX``.  Scales live in pool-shaped side tensors
``(num_blocks, block_size, K)`` so the paged kernel's block-table
indirection fetches the scale tile with the same index map as the KV tile
and dequantizes inside the score block — a full-precision copy of the
cache never materializes.

Symmetric scaling (no zero-point): attention K/V rows are zero-centered
post-RoPE, and a zero-point would add an MXU-unfriendly integer bias term
to the score matmul.
"""
from __future__ import annotations

import jax.numpy as jnp

# canonical CLI/engine names -> jnp storage dtype.  "native" / None keep
# the activation dtype (no quantization, no scale tensors).
KV_DTYPES = {
    "int8": jnp.int8,
    "fp8": jnp.float8_e4m3fn,           # alias for the e4m3 default
    "fp8_e4m3": jnp.float8_e4m3fn,
    "fp8_e5m2": jnp.float8_e5m2,
}

# largest finite magnitude representable per storage dtype
_QMAX = {
    jnp.dtype(jnp.int8): 127.0,
    jnp.dtype(jnp.float8_e4m3fn): 448.0,
    jnp.dtype(jnp.float8_e5m2): 57344.0,
}


def resolve_kv_dtype(name):
    """CLI name -> jnp dtype, or None for the native (unquantized) path.

    Accepts None, "native", a name from ``KV_DTYPES``, or a jnp dtype
    already in the table.
    """
    if name is None or name == "native":
        return None
    if not isinstance(name, str):
        if jnp.dtype(name) in _QMAX:
            return jnp.dtype(name)
        raise ValueError(f"unsupported kv dtype {name!r}")
    try:
        return jnp.dtype(KV_DTYPES[name])
    except KeyError:
        raise ValueError(
            f"unknown --kv-dtype {name!r}; choose from "
            f"{['native', *sorted(KV_DTYPES)]}") from None


def kv_qmax(dtype) -> float:
    return _QMAX[jnp.dtype(dtype)]


def kv_quantize_rows(x, dtype):
    """Quantize rows over the last axis: ``x (..., hd)`` -> ``(q, scale)``
    with ``q (..., hd)`` in ``dtype`` and ``scale (...,)`` f32.

    ``scale = max|row| / QMAX`` (0 for all-zero rows, which dequantize
    back to exact zeros — freshly zeroed pool blocks stay zero).
    """
    dtype = jnp.dtype(dtype)
    qmax = kv_qmax(dtype)
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32), axis=-1)
    scale = amax / qmax
    y = x32 / jnp.where(scale == 0.0, 1.0, scale)[..., None]
    if dtype == jnp.dtype(jnp.int8):
        q = jnp.clip(jnp.rint(y), -qmax, qmax).astype(dtype)
    else:
        q = jnp.clip(y, -qmax, qmax).astype(dtype)
    return q, scale


def kv_dequantize(q, scale):
    """Inverse of ``kv_quantize_rows``: ``(..., hd)`` x ``(...,)`` -> f32."""
    return q.astype(jnp.float32) * scale[..., None]
