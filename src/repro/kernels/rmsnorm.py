"""Fused RMSNorm as a Pallas TPU kernel.

One VMEM-staged pass: f32 mean-square, rsqrt, scale by (1 + w) — the
unfused jnp version reads x twice and materializes the f32 upcast in HBM.
Rows are tiled (block_rows, D); the weight block is broadcast (index_map
pins it to block 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)            # (rows, D)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    w = w_ref[...].astype(jnp.float32)
    o_ref[...] = (y * (1.0 + w)).astype(o_ref.dtype)


def rmsnorm(x, weight, eps=1e-6, block_rows=256, interpret=None):
    """x: (..., D); weight: (D,)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    orig_shape = x.shape
    D = x.shape[-1]
    xr = x.reshape(-1, D)
    n = xr.shape[0]
    block_rows = min(block_rows, n)
    pad = (-n) % block_rows
    if pad:
        xr = jnp.pad(xr, [(0, pad), (0, 0)])
    grid = (xr.shape[0] // block_rows,)

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xr.shape, x.dtype),
        interpret=interpret,
    )(xr, weight.reshape(1, D))
    if pad:
        out = out[:n]
    return out.reshape(orig_shape)
