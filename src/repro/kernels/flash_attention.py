"""Flash attention as a Pallas TPU kernel (the paper's §3.1 "manually
implemented well-optimized big operation", adapted to the MXU/VMEM).

Design (TPU-native, not a CUDA port):
  * grid = (B, H, nQ, nK); the nK axis is "arbitrary" (sequential) so the
    online-softmax state (m, l, acc) lives in VMEM scratch across k-blocks;
  * q/k/v blocks are staged HBM->VMEM by BlockSpecs; block shapes default
    to (128, head_dim) — MXU-aligned (multiples of 128 on the matmul dims);
  * GQA: the k/v BlockSpec index_map folds the query head onto its kv head
    (h // group), so no repeated-KV materialization;
  * causal/sliding-window masking and gemma-style logit soft-capping are
    fused into the score block;
  * accumulation in f32, outputs cast back to the input dtype.

Ring-attention reuse (DESIGN.md §8): the online-softmax state can cross
kernel invocations.  ``carry=(m, l, acc)`` seeds the scratch instead of
the (-inf, 0, 0) init, ``return_carry=True`` returns the *unnormalized*
state instead of the normalized output, and ``kv_offset`` shifts the key
positions seen by the causal/window mask (the keys of a rotated ring
chunk live at a different absolute offset than their local indices).
A full pass equals a chain of per-chunk passes::

    st = flash_attention(q, k0, v0, return_carry=True)
    st = flash_attention(q, k1, v1, carry=st, kv_offset=S0,
                         return_carry=True)
    out, lse = flash_carry_finalize(st, q.dtype)

which is exactly the per-ring-step contract ``dist/ring.py`` relies on —
the kernel body is unchanged between the two modes.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 spells it TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")

NEG_INF = -1e30


def _flash_kernel(*refs, scale, causal, window, softcap, q_offset, kv_offset,
                  kv_len, block_q, block_k, n_k, has_carry, return_carry):
    """One (b, h, qi, ki) grid step.

    ``refs`` layout depends on the mode:
      inputs:  q, k, v [, m_in, l_in, acc_in when has_carry]
      outputs: o                  (return_carry=False)
               m_out, l_out, acc_out   (return_carry=True)
      scratch: m_scr, l_scr, acc_scr
    """
    q_ref, k_ref, v_ref = refs[0], refs[1], refs[2]
    pos = 3
    carry_refs = None
    if has_carry:
        carry_refs = refs[pos:pos + 3]
        pos += 3
    out_refs = refs[pos:-3]
    m_scr, l_scr, acc_scr = refs[-3:]

    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        if has_carry:
            m_in, l_in, acc_in = carry_refs
            m_scr[...] = m_in[0, :, 0, :]
            l_scr[...] = l_in[0, :, 0, :]
            acc_scr[...] = acc_in[0, :, 0, :]
        else:
            m_scr[...] = jnp.full_like(m_scr, NEG_INF)
            l_scr[...] = jnp.zeros_like(l_scr)
            acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap

    qi = pl.program_id(2)
    qpos = (qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)
            + q_offset)
    # local key index (masks chunk padding via kv_len) vs global key
    # position (masks causality/window; a ring chunk's keys sit kv_offset
    # tokens into the global sequence)
    kidx = (ki * block_k
            + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1))
    kpos = kidx + kv_offset
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    if kv_len is not None:
        mask &= kidx < kv_len
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                 # (bq, 1)
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                              # (bq, bk)
    # a fully-masked block with a still -inf running max would exp(0)=1:
    # re-zero the masked lanes explicitly (cheap, and carry-safe)
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)                      # (bq, 1)
    l_new = corr * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_k - 1)
    def _done():
        if return_carry:
            m_out, l_out, acc_out = out_refs
            m_out[0, :, 0, :] = m_scr[...]
            l_out[0, :, 0, :] = l_scr[...]
            acc_out[0, :, 0, :] = acc_scr[...]
        else:
            (o_ref,) = out_refs
            l = l_scr[...]
            l = jnp.where(l == 0.0, 1.0, l)             # fully-masked rows
            o_ref[0, :, 0, :] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_carry_init(B, Sq, H, hd):
    """Neutral online-softmax state: (m, l, acc) = (-inf, 0, 0), f32.

    Shapes: m, l (B, Sq, H, 1); acc (B, Sq, H, hd) — the q-block layout the
    kernel's carry BlockSpecs expect."""
    return (jnp.full((B, Sq, H, 1), NEG_INF, jnp.float32),
            jnp.zeros((B, Sq, H, 1), jnp.float32),
            jnp.zeros((B, Sq, H, hd), jnp.float32))


def flash_carry_finalize(carry, dtype=None):
    """Normalize an accumulated carry: returns (out, lse).

    ``out = acc / l`` cast to ``dtype`` (default: keep f32); ``lse = m +
    log l`` is the log-sum-exp the flash backward recomputes probs from.
    Fully-masked rows produce out = 0, lse = NEG_INF."""
    m, l, acc = carry
    safe = jnp.where(l == 0.0, 1.0, l)
    out = acc / safe
    if dtype is not None:
        out = out.astype(dtype)
    lse = jnp.where(l[..., 0] == 0.0, NEG_INF, m[..., 0] + jnp.log(safe[..., 0]))
    return out, lse


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    q_offset=0, kv_offset=0, kv_len=None, carry=None,
                    return_carry=False, block_q=128, block_k=128,
                    interpret=None):
    """q: (B, Sq, H, hd); k/v: (B, Sk, K, hd). Returns (B, Sq, H, hd) —
    or, with ``return_carry=True``, the unnormalized ``(m, l, acc)`` state
    (finalize with :func:`flash_carry_finalize`).  ``carry`` seeds the
    state from a previous chunk's output; ``kv_offset`` is the absolute
    position of k[:, 0] (ring chunks)."""
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    assert H % K == 0
    G = H // K
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Sk, 8))
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    if pk and kv_len is None:
        kv_len = Sk                       # mask the padded keys
    if pq:
        q = jnp.pad(q, [(0, 0), (0, pq), (0, 0), (0, 0)])
    if pk:
        k = jnp.pad(k, [(0, 0), (0, pk), (0, 0), (0, 0)])
        v = jnp.pad(v, [(0, 0), (0, pk), (0, 0), (0, 0)])
    Sq_p, Sk_p = Sq + pq, Sk + pk
    n_q, n_k = Sq_p // block_q, Sk_p // block_k

    has_carry = carry is not None
    if has_carry:
        m0, l0, acc0 = carry
        assert m0.shape == (B, Sq, H, 1) and acc0.shape == (B, Sq, H, hd), \
            (m0.shape, acc0.shape)
        if pq:  # padded q rows carry the neutral state
            m0 = jnp.pad(m0, [(0, 0), (0, pq), (0, 0), (0, 0)],
                         constant_values=NEG_INF)
            l0 = jnp.pad(l0, [(0, 0), (0, pq), (0, 0), (0, 0)])
            acc0 = jnp.pad(acc0, [(0, 0), (0, pq), (0, 0), (0, 0)])

    kernel = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(hd), causal=causal,
        window=window, softcap=softcap, q_offset=q_offset,
        kv_offset=kv_offset, kv_len=kv_len, block_q=block_q, block_k=block_k,
        n_k=n_k, has_carry=has_carry, return_carry=return_carry)

    q_spec = pl.BlockSpec((1, block_q, 1, hd),
                          lambda b, h, qi, ki: (b, qi, h, 0))
    scalar_spec = pl.BlockSpec((1, block_q, 1, 1),
                               lambda b, h, qi, ki: (b, qi, h, 0))
    in_specs = [
        q_spec,
        pl.BlockSpec((1, block_k, 1, hd),
                     lambda b, h, qi, ki, G=G: (b, ki, h // G, 0)),
        pl.BlockSpec((1, block_k, 1, hd),
                     lambda b, h, qi, ki, G=G: (b, ki, h // G, 0)),
    ]
    inputs = [q, k, v]
    if has_carry:
        in_specs += [scalar_spec, scalar_spec, q_spec]
        inputs += [m0, l0, acc0]

    if return_carry:
        out_specs = [scalar_spec, scalar_spec, q_spec]
        out_shape = [jax.ShapeDtypeStruct((B, Sq_p, H, 1), jnp.float32),
                     jax.ShapeDtypeStruct((B, Sq_p, H, 1), jnp.float32),
                     jax.ShapeDtypeStruct((B, Sq_p, H, hd), jnp.float32)]
    else:
        out_specs = [q_spec]
        out_shape = [jax.ShapeDtypeStruct((B, Sq_p, H, hd), q.dtype)]

    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum l
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(*inputs)

    if return_carry:
        m, l, acc = out
        if pq:
            m, l, acc = m[:, :Sq], l[:, :Sq], acc[:, :Sq]
        return m, l, acc
    (o,) = out
    if pq:
        o = o[:, :Sq]
    return o
