"""Flash attention as a Pallas TPU kernel (the paper's §3.1 "manually
implemented well-optimized big operation", adapted to the MXU/VMEM).

Design (TPU-native, not a CUDA port):
  * grid = (B, H, nQ, nK); the nK axis is "arbitrary" (sequential) so the
    online-softmax state (m, l, acc) lives in VMEM scratch across k-blocks;
  * q/k/v blocks are staged HBM->VMEM by BlockSpecs; block shapes default
    to (128, head_dim) — MXU-aligned (multiples of 128 on the matmul dims);
  * GQA: the k/v BlockSpec index_map folds the query head onto its kv head
    (h // group), so no repeated-KV materialization;
  * causal/sliding-window masking and gemma-style logit soft-capping are
    fused into the score block;
  * accumulation in f32, outputs cast back to the input dtype.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 spells it TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, causal, window, softcap, q_offset, kv_len,
                  block_q, block_k, n_k):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap

    qi = pl.program_id(2)
    qpos = (qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)
            + q_offset)
    kpos = (ki * block_k
            + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1))
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    if kv_len is not None:
        mask &= kpos < kv_len
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                 # (bq, 1)
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                              # (bq, bk)
    corr = jnp.exp(m_prev - m_new)                      # (bq, 1)
    l_new = corr * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_k - 1)
    def _done():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)                 # fully-masked rows
        o_ref[0, :, 0, :] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    q_offset=0, kv_len=None, block_q=128, block_k=128,
                    interpret=None):
    """q: (B, Sq, H, hd); k/v: (B, Sk, K, hd). Returns (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    assert H % K == 0
    G = H // K
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Sk, 8))
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    if pk and kv_len is None:
        kv_len = Sk                       # mask the padded keys
    if pq:
        q = jnp.pad(q, [(0, 0), (0, pq), (0, 0), (0, 0)])
    if pk:
        k = jnp.pad(k, [(0, 0), (0, pk), (0, 0), (0, 0)])
        v = jnp.pad(v, [(0, 0), (0, pk), (0, 0), (0, 0)])
    Sq_p, Sk_p = Sq + pq, Sk + pk
    n_q, n_k = Sq_p // block_q, Sk_p // block_k

    kernel = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(hd), causal=causal,
        window=window, softcap=softcap, q_offset=q_offset, kv_len=kv_len,
        block_q=block_q, block_k=block_k, n_k=n_k)

    grid = (B, H, n_q, n_k)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, qi, ki, G=G: (b, ki, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, qi, ki, G=G: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq_p, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum l
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    if pq:
        out = out[:, :Sq]
    return out
