"""Jit'd public wrappers for the Pallas kernels, registry-resolved.

On CPU containers the kernels execute with ``interpret=True`` (the kernel
body runs in Python per grid step) — correctness validation only; TPU is
the performance target.

Every wrapper resolves its schedule tunables through the kernel registry
(DESIGN.md §13) before entering jit: explicit caller kwargs win, then the
autotune cache's winner for this shape bucket, then the registered
defaults.  The tunables ride the inner ``jax.jit`` as static argnames, so
a new winner simply traces a new specialization.
"""
from __future__ import annotations


import jax

from . import registry
from .flash_attention import flash_attention as _flash
from .fused_update import sgd_momentum as _sgd
from .paged_attention import paged_attention as _paged
from .rmsnorm import rmsnorm as _rmsnorm
from .sampling import sample_tokens as _sample

_flash_jit = jax.jit(_flash, static_argnames=(
    "causal", "window", "softcap", "q_offset", "kv_offset", "kv_len",
    "return_carry", "block_q", "block_k", "interpret"))

_paged_jit = jax.jit(_paged, static_argnames=(
    "window", "softcap", "pages_per_step", "head_tile", "interpret"))

_rmsnorm_jit = jax.jit(_rmsnorm, static_argnames=("eps", "block_rows",
                                                  "interpret"))

_sgd_jit = jax.jit(_sgd, static_argnames=("lr", "mu", "weight_decay",
                                          "block", "interpret"))

_sample_jit = jax.jit(_sample, static_argnames=(
    "temperature", "top_k", "top_p", "rows_per_step", "interpret"))


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    q_offset=0, kv_offset=0, kv_len=None, carry=None,
                    return_carry=False, block_q=None, block_k=None,
                    interpret=None):
    p = registry.resolve(
        "flash_attention", {"block_q": block_q, "block_k": block_k},
        registry.get("flash_attention").bucket_of(q, k, v))
    return _flash_jit(q, k, v, causal=causal, window=window,
                      softcap=softcap, q_offset=q_offset,
                      kv_offset=kv_offset, kv_len=kv_len, carry=carry,
                      return_carry=return_carry, interpret=interpret, **p)


def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    k_scale=None, v_scale=None, window=None, softcap=None,
                    pages_per_step=None, head_tile=None, interpret=None):
    p = registry.resolve(
        "paged_attention",
        {"pages_per_step": pages_per_step, "head_tile": head_tile},
        registry.get("paged_attention").bucket_of(
            q, k_pages, v_pages, block_tables, lengths, k_scale=k_scale))
    return _paged_jit(q, k_pages, v_pages, block_tables, lengths,
                      k_scale=k_scale, v_scale=v_scale, window=window,
                      softcap=softcap, interpret=interpret, **p)


def rmsnorm(x, weight, eps=1e-6, block_rows=None, interpret=None):
    p = registry.resolve("rmsnorm", {"block_rows": block_rows},
                         registry.get("rmsnorm").bucket_of(x, weight))
    return _rmsnorm_jit(x, weight, eps=eps, interpret=interpret, **p)


def sgd_momentum(param, grad, mom, *, lr=1e-3, mu=0.9, weight_decay=1e-4,
                 block=None, interpret=None):
    p = registry.resolve("sgd_momentum", {"block": block},
                         registry.get("sgd_momentum").bucket_of(param, grad,
                                                                mom))
    return _sgd_jit(param, grad, mom, lr=lr, mu=mu,
                    weight_decay=weight_decay, interpret=interpret, **p)


def sample_tokens(logits, u, *, temperature=1.0, top_k=None, top_p=None,
                  rows_per_step=None, interpret=None):
    p = registry.resolve("sample_tokens", {"rows_per_step": rows_per_step},
                         registry.get("sample_tokens").bucket_of(logits, u))
    return _sample_jit(logits, u, temperature=float(temperature),
                      top_k=top_k, top_p=top_p, interpret=interpret, **p)
