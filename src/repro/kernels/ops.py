"""Jit'd public wrappers for the Pallas kernels.

On CPU containers the kernels execute with ``interpret=True`` (the kernel
body runs in Python per grid step) — correctness validation only; TPU is
the performance target.
"""
from __future__ import annotations


import jax

from .flash_attention import flash_attention as _flash
from .fused_update import sgd_momentum as _sgd
from .paged_attention import paged_attention as _paged
from .rmsnorm import rmsnorm as _rmsnorm

flash_attention = jax.jit(_flash, static_argnames=(
    "causal", "window", "softcap", "q_offset", "kv_offset", "kv_len",
    "return_carry", "block_q", "block_k", "interpret"))

paged_attention = jax.jit(_paged, static_argnames=(
    "window", "softcap", "interpret"))

rmsnorm = jax.jit(_rmsnorm, static_argnames=("eps", "block_rows",
                                             "interpret"))

sgd_momentum = jax.jit(_sgd, static_argnames=("lr", "mu", "weight_decay",
                                              "block", "interpret"))
