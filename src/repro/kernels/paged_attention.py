"""Paged decode attention as a Pallas TPU kernel.

One query row per sequence against a block-table-indirected KV pool
(continuous-batching decode, DESIGN.md §9).  Where the flash kernel
streams *contiguous* k-blocks, this kernel streams *logical pages*: the
grid's last axis walks a sequence's block table and the k/v BlockSpec
``index_map`` reads the physical block id out of a scalar-prefetched
table — the DMA engine gathers through the indirection, the MXU only
ever sees dense (block_size, head_dim) tiles.

Design notes (TPU-native, mirrors ``flash_attention.py``):

* grid = (B, K/head_tile, n_pages/pages_per_step); the page axis is
  "arbitrary" (sequential) so the online-softmax carry (m, l, acc) lives
  in VMEM scratch across pages;
* scalar prefetch: ``block_tables (B, n_pages)`` and ``lengths (B,)``
  ride ahead of the grid so index_maps can compute DMA source blocks
  (``pltpu.PrefetchScalarGridSpec``);
* GQA: each grid step processes ``head_tile`` KV heads with all their G
  query heads as the q tile (ht, G, hd) — no repeated-KV
  materialization;
* tunables (registry op ``paged_attention``): ``pages_per_step`` fetches
  several table entries per grid step (each page is its own BlockSpec
  input, so the DMA engine issues the gathers in parallel and the MXU
  sees one (ht, pps*bs, hd) tile); ``head_tile`` batches KV heads per
  step.  Both shrink grid-overhead-bound decode steps;
* quantized pools (DESIGN.md §13): when ``k_scale``/``v_scale``
  (num_blocks, block_size, K) f32 ride along, k/v tiles are stored
  int8/fp8 and dequantized *inside the score block* right after the DMA
  lands (``tile.astype(f32) * scale``) — no fp16 copy of the cache ever
  materializes;
* pages past a sequence's live length are skipped (``pl.when``), so a
  short sequence in a long-table batch costs only its own pages of MXU
  work (the DMA for the skipped block still lands — sink pages make it
  harmless);
* sliding-window layers mask ``kpos > qpos - window`` with qpos =
  length-1 (the paged pool is position-ordered, no ring buffer);
* accumulation in f32, output cast to the query dtype.

The online-softmax recurrence is shared with ``flash_attention.py``
(PR 3's carry form); only the page indirection differs.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 spells it TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")

NEG_INF = -1e30


def _paged_kernel(tables_ref, lens_ref, q_ref, *refs, scale, block_size,
                  n_steps, pps, quant, window, softcap):
    """One (b, kv-head-tile, page-group) grid step."""
    k_refs = refs[:pps]
    v_refs = refs[pps:2 * pps]
    if quant:
        ks_refs = refs[2 * pps:3 * pps]
        vs_refs = refs[3 * pps:4 * pps]
        o_ref, m_scr, l_scr, acc_scr = refs[4 * pps:]
    else:
        o_ref, m_scr, l_scr, acc_scr = refs[2 * pps:]

    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lens_ref[b]                       # live tokens incl. current

    def tile(j, kv_ref, s_ref):
        """(1, bs, ht, hd) page -> dequantized f32 (ht, bs, hd)."""
        t = jnp.swapaxes(kv_ref[0], 0, 1).astype(jnp.float32)
        if quant:
            t = t * jnp.swapaxes(s_ref[0], 0, 1).astype(jnp.float32)[..., None]
        return t

    @pl.when(pi * pps * block_size < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # (ht, G, hd)
        k = jnp.concatenate(
            [tile(j, k_refs[j], ks_refs[j] if quant else None)
             for j in range(pps)], axis=1)               # (ht, pps*bs, hd)
        v = jnp.concatenate(
            [tile(j, v_refs[j], vs_refs[j] if quant else None)
             for j in range(pps)], axis=1)

        s = jax.lax.dot_general(q, k, (((2,), (2,)), ((0,), (0,))),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:                          # (ht, G, pps*bs)
            s = jnp.tanh(s / softcap) * softcap

        kpos = (pi * pps * block_size
                + jax.lax.broadcasted_iota(jnp.int32, (1, 1, pps * block_size),
                                           2))
        mask = kpos < length
        if window is not None:
            # the single query row sits at absolute position length-1
            mask &= kpos > (length - 1) - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                              # (ht, G, 1)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=2, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)         # fully-masked block: exp(0)=1
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = corr * l_prev + jnp.sum(p, axis=2, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(pi == n_steps - 1)
    def _done():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)                  # inactive lanes
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    k_scale=None, v_scale=None, window=None, softcap=None,
                    pages_per_step=1, head_tile=1, interpret=None):
    """Single-token attention through a paged KV pool.

    q: (B, H, hd) — the current token's query rows;
    k_pages/v_pages: (num_blocks, block_size, K, hd) physical pools;
    block_tables: (B, n_pages) int32, logical page -> physical block
    (sink-filled past each sequence's pages);
    lengths: (B,) int32 — live tokens per sequence INCLUDING the current
    one (the row at position lengths-1 must already be written);
    k_scale/v_scale: (num_blocks, block_size, K) f32 per-row scales when
    the pools are quantized (both or neither);
    pages_per_step / head_tile: grid tunables (see module docstring) —
    pure schedule knobs, the output is bitwise independent of them up to
    f32 summation order.

    Returns (B, H, hd).  Lanes with length 0 return zeros.
    """
    B, H, hd = q.shape
    NB, bs, K, _ = k_pages.shape
    assert H % K == 0, (H, K)
    assert (k_scale is None) == (v_scale is None)
    quant = k_scale is not None
    G = H // K
    n_pages = block_tables.shape[1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    ht = int(head_tile) if head_tile and K % int(head_tile) == 0 else 1
    pps = max(1, min(int(pages_per_step), n_pages))
    pad = (-n_pages) % pps
    tables = block_tables.astype(jnp.int32)
    if pad:
        # pad the table to a pps multiple with sink pages (block 0); the
        # pad pages sit past every live length, so they are masked out
        tables = jnp.pad(tables, [(0, 0), (0, pad)])
    n_steps = (n_pages + pad) // pps

    qg = q.reshape(B, K, G, hd)
    kernel = functools.partial(
        _paged_kernel, scale=1.0 / math.sqrt(hd), block_size=bs,
        n_steps=n_steps, pps=pps, quant=quant, window=window,
        softcap=softcap)

    q_spec = pl.BlockSpec((1, ht, G, hd), lambda b, kh, pi, *_: (b, kh, 0, 0))

    def kv_spec(j):
        return pl.BlockSpec(
            (1, bs, ht, hd),
            lambda b, kh, pi, tables, lens: (tables[b, pi * pps + j], 0,
                                             kh, 0))

    def scale_spec(j):
        return pl.BlockSpec(
            (1, bs, ht),
            lambda b, kh, pi, tables, lens: (tables[b, pi * pps + j], 0, kh))

    in_specs = ([q_spec]
                + [kv_spec(j) for j in range(pps)]
                + [kv_spec(j) for j in range(pps)])
    inputs = [qg] + [k_pages] * pps + [v_pages] * pps
    if quant:
        in_specs += ([scale_spec(j) for j in range(pps)]
                     + [scale_spec(j) for j in range(pps)])
        inputs += [k_scale] * pps + [v_scale] * pps

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K // ht, n_steps),
        in_specs=in_specs,
        out_specs=q_spec,
        scratch_shapes=[
            pltpu.VMEM((ht, G, 1), jnp.float32),     # running max m
            pltpu.VMEM((ht, G, 1), jnp.float32),     # running sum l
            pltpu.VMEM((ht, G, hd), jnp.float32),    # output accumulator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(tables, lengths.astype(jnp.int32), *inputs)
    return out.reshape(B, H, hd)
