"""Paged decode attention as a Pallas TPU kernel.

One query row per sequence against a block-table-indirected KV pool
(continuous-batching decode, DESIGN.md §9).  Where the flash kernel
streams *contiguous* k-blocks, this kernel streams *logical pages*: the
grid's last axis walks a sequence's block table and the k/v BlockSpec
``index_map`` reads the physical block id out of a scalar-prefetched
table — the DMA engine gathers through the indirection, the MXU only
ever sees dense (block_size, head_dim) tiles.

Design notes (TPU-native, mirrors ``flash_attention.py``):

* grid = (B, K, n_pages); n_pages is "arbitrary" (sequential) so the
  online-softmax carry (m, l, acc) lives in VMEM scratch across pages;
* scalar prefetch: ``block_tables (B, n_pages)`` and ``lengths (B,)``
  ride ahead of the grid so index_maps can compute DMA source blocks
  (``pltpu.PrefetchScalarGridSpec``);
* GQA: the kernel processes one KV head per grid step with all its G
  query heads as the q tile (G, hd) — no repeated-KV materialization;
* pages past a sequence's live length are skipped (``pl.when``), so a
  short sequence in a long-table batch costs only its own pages of MXU
  work (the DMA for the skipped block still lands — sink pages make it
  harmless);
* sliding-window layers mask ``kpos > qpos - window`` with qpos =
  length-1 (the paged pool is position-ordered, no ring buffer);
* accumulation in f32, output cast to the query dtype.

The online-softmax recurrence is shared with ``flash_attention.py``
(PR 3's carry form); only the page indirection differs.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 spells it TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")

NEG_INF = -1e30


def _paged_kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale, block_size, n_pages,
                  window, softcap):
    """One (b, kv_head, page) grid step."""
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lens_ref[b]                       # live tokens incl. current

    @pl.when(pi * block_size < length)
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32)        # (G, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)        # (bs, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap

        kpos = (pi * block_size
                + jax.lax.broadcasted_iota(jnp.int32, (1, block_size), 1))
        mask = kpos < length
        if window is not None:
            # the single query row sits at absolute position length-1
            mask &= kpos > (length - 1) - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                               # (G, 1)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)         # fully-masked block: exp(0)=1
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = corr * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(pi == n_pages - 1)
    def _done():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)                  # inactive lanes
        o_ref[0, 0, :, :] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    window=None, softcap=None, interpret=None):
    """Single-token attention through a paged KV pool.

    q: (B, H, hd) — the current token's query rows;
    k_pages/v_pages: (num_blocks, block_size, K, hd) physical pools;
    block_tables: (B, n_pages) int32, logical page -> physical block
    (sink-filled past each sequence's pages);
    lengths: (B,) int32 — live tokens per sequence INCLUDING the current
    one (the row at position lengths-1 must already be written).

    Returns (B, H, hd).  Lanes with length 0 return zeros.
    """
    B, H, hd = q.shape
    NB, bs, K, _ = k_pages.shape
    assert H % K == 0, (H, K)
    G = H // K
    n_pages = block_tables.shape[1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    qg = q.reshape(B, K, G, hd)
    kernel = functools.partial(
        _paged_kernel, scale=1.0 / math.sqrt(hd), block_size=bs,
        n_pages=n_pages, window=window, softcap=softcap)

    q_spec = pl.BlockSpec((1, 1, G, hd), lambda b, kh, pi, *_: (b, kh, 0, 0))
    kv_spec = pl.BlockSpec(
        (1, bs, 1, hd),
        lambda b, kh, pi, tables, lens: (tables[b, pi], 0, kh, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, n_pages),
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=q_spec,
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),     # running max m
            pltpu.VMEM((G, 1), jnp.float32),     # running sum l
            pltpu.VMEM((G, hd), jnp.float32),    # output accumulator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      qg, k_pages, v_pages)
    return out.reshape(B, H, hd)
