"""Fused SGD-momentum parameter update as a Pallas TPU kernel — the
KVStore *updater* (MXNet §2.3) as a mutating big-op.

MXNet's engine schedules parameter updates as mutations of the parameter
array (§3.2); the JAX analogue is input/output buffer aliasing
(``input_output_aliases``): param and momentum are updated in place, one
fused VMEM pass instead of 5 HBM-roundtrip elementwise ops
(decay-add, scale, momentum-mul, add, subtract).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _update_kernel(p_ref, g_ref, m_ref, po_ref, mo_ref, *, lr, mu, wd):
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32) + wd * p
    m = mu * m_ref[...] + g
    po_ref[...] = (p - lr * m).astype(po_ref.dtype)
    mo_ref[...] = m


def sgd_momentum(param, grad, mom, *, lr=1e-3, mu=0.9, weight_decay=1e-4,
                 block=65536, interpret=None):
    """param: any shape (bf16/f32); grad: same shape; mom: f32 master.

    Returns (new_param, new_mom); buffers are aliased (donated) so the
    update is in place, like the engine's write-tag mutation.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    shape = param.shape
    p = param.reshape(-1)
    g = grad.reshape(-1)
    m = mom.reshape(-1)
    n = p.size
    block = min(block, n)
    pad = (-n) % block
    if pad:
        p = jnp.pad(p, (0, pad))
        g = jnp.pad(g, (0, pad))
        m = jnp.pad(m, (0, pad))
    rows = p.size // block
    p2, g2, m2 = (a.reshape(rows, block) for a in (p, g, m))

    new_p, new_m = pl.pallas_call(
        functools.partial(_update_kernel, lr=lr, mu=mu, wd=weight_decay),
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))] * 3,
        out_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct(p2.shape, param.dtype),
                   jax.ShapeDtypeStruct(m2.shape, jnp.float32)],
        input_output_aliases={0: 0, 2: 1},
        interpret=interpret,
    )(p2, g2, m2)
    new_p = new_p.reshape(-1)[:n].reshape(shape)
    new_m = new_m.reshape(-1)[:n].reshape(shape)
    return new_p, new_m
