# Pallas kernel layer (TPU-targeted, interpret-mode on CPU).
#
# Impl modules (flash_attention, paged_attention, rmsnorm, fused_update,
# sampling) pair with ``ref.py`` oracles.  ``registry.py`` names every op's
# impl, reference, and tunable-parameter space; ``autotune.py`` sweeps the
# space per (op, shape-bucket, dtype, backend) and persists winners;
# ``ops.py`` is the public entry — call sites get tuned schedules with no
# signature changes (DESIGN.md §13).  ``quant.py`` holds the int8/fp8
# KV-cache quantization helpers.
