"""Performance-variant flags for §Perf hillclimbing.

Each flag toggles one optimization hypothesis; the baseline (paper-faithful
reproduction) is all-defaults.  ``benchmarks/perf_probe.py`` recompiles a
given (arch × shape) pair under a set of flags and reports the roofline
terms, so every hillclimb iteration is one CLI call.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class PerfFlags:
    # slice k/v to the sliding window per query chunk (windowed layers):
    # attention work drops from O(S^2) to O(S·W)
    window_slice: bool = False
    # decode cache sharding strategy: "seq" shards the cache S dim over
    # "model" (distributed softmax); "heads" prefers KV heads over "model"
    # (no dynamic-update-slice over a sharded dim)
    decode_cache_shard: str = "seq"
    # number of unrolled CE loss chunks
    ce_chunks: int = 16
    # dtype for the residual-stream scan carry (remat save size)
    # "keep" = whatever the model computes (bf16 already)
    carry_dtype: str = "keep"
    # MoE dispatch index width (int32 default; int16 halves cumsum traffic)
    moe_small_idx: bool = False
    # attention q-chunk size for the unrolled flash-style loop
    attn_q_chunk: int = 1024
    # gather the sequence-parallel residual once (compact, bf16) before the
    # MoE S*k-expanded dispatch / the three qkv einsums
    moe_gather_once: bool = False
    attn_gather_once: bool = False
    # compute router logits without materializing an f32 copy of x
    router_no_f32_copy: bool = False
    # dispatch/combine as a loop over the k routing choices: compact
    # (B,S,D) scatters/gathers, never materializing (B, S*k, D)
    moe_k_loop: bool = False
    # cast softmax probabilities to the activation dtype before the PV
    # matmul (halves the dominant prefill buffers; softmax stays f32)
    probs_bf16: bool = False
    # vectorized chunk-parallel attention: the q-chunk dim is sharded over
    # "model" (GQA's (K,G) head split defeats head-sharding when K,G < 16;
    # chunk-parallelism sidesteps it and lands S-block-sharded outputs that
    # compose with the sequence-parallel residual)
    attn_chunk_parallel: bool = False
    # pin scores/probs to S-sharding through softmax and let the PV matmul
    # do a small partial-sum all-reduce — avoids the partitioner's
    # "involuntary full rematerialization" (replicating per-chunk probs)
    # when GQA's (K,G) split defeats head sharding
    attn_probs_seq_shard: bool = False
    # sequence sharding (DESIGN.md §8): keep q/k/v S-sharded over "model"
    # through the attention block instead of gathering S / sharding heads —
    # the long-context layout whose attention runs on the ring schedule.
    # Batches enter S-sharded via batch_pspecs(kind="seq").
    seq_shard: bool = False
    # attention implementation: "auto" rings causal/window layers when
    # seq_shard is on and the mesh's "model" axis divides S; "ring" forces
    # the ring schedule (dist/ring.py); "dense" never rings
    attn_impl: str = "auto"
    # pipeline parallelism (DESIGN.md §10): number of "stage" mesh-axis
    # groups the super-block stack splits into (1 = off) and the number of
    # micro-batches streamed through the 1F1B schedule.  Selected by
    # TrainConfig(pp_stages, microbatches) / launch --pp-stages.
    pp_stages: int = 1
    microbatches: int = 1


FLAGS = PerfFlags()


def set_flags(**kw):
    for k, v in kw.items():
        assert hasattr(FLAGS, k), k
        setattr(FLAGS, k, v)
    return FLAGS


def reset_flags():
    global FLAGS
    defaults = PerfFlags()
    for k in vars(defaults):
        setattr(FLAGS, k, getattr(defaults, k))
    return FLAGS
