"""Symbolic auto-differentiation (MXNet §2.1 "backward").

Builds an explicit backward *graph* from the forward graph using the
per-operator gradient registrations — the gradients are themselves Symbols,
so the same optimizer/memory-planner/executor machinery applies to them
(exactly how MXNet's Fig. 4 shows a joint forward+backward graph).
"""
from __future__ import annotations

from .graph import Graph, Node, NodeRef, infer_shapes
from . import ops as _ops
from .symbol import Symbol


def gradient(sym: Symbol, wrt: list[str], out_grads: list | None = None) -> Symbol:
    """Return a Symbol whose outputs are d(sum of sym outputs)/d(wrt).

    ``out_grads``: optional NodeRefs seeding the head gradients; defaults to
    ones_like for every head (scalar losses get grad 1.0).
    """
    g = Graph(sym._outputs)
    consumers = g.consumers()

    # accumulate grad contributions per (node uid, output index)
    grads: dict[tuple[int, int], list[NodeRef]] = {}

    def add_grad(ref: NodeRef, contrib: NodeRef | None):
        if contrib is None:
            return
        grads.setdefault((ref.node.uid, ref.index), []).append(contrib)

    for i, head in enumerate(sym._outputs):
        if out_grads is not None and out_grads[i] is not None:
            add_grad(head, out_grads[i])
        else:
            add_grad(head, _ops.GB.ones_like(head))

    # Shape-dependent grad rules (broadcast unreduction etc.) receive None
    # shapes here; rules that need them raise, directing users to
    # gradient_with_shapes (the executor always uses that path).
    return _build(sym, g, consumers, grads, wrt, shapes=None)


def gradient_with_shapes(sym: Symbol, wrt: list[str],
                         var_shapes: dict[str, tuple],
                         out_grads: list | None = None) -> Symbol:
    g = Graph(sym._outputs)
    shapes, _ = infer_shapes(g, var_shapes)
    consumers = g.consumers()
    grads: dict[tuple[int, int], list[NodeRef]] = {}

    def add_grad(ref: NodeRef, contrib):
        if contrib is not None:
            grads.setdefault((ref.node.uid, ref.index), []).append(contrib)

    for i, head in enumerate(sym._outputs):
        seed = out_grads[i] if out_grads else None
        add_grad(head, seed if seed is not None else _ops.GB.ones_like(head))

    return _build(sym, g, consumers, grads, wrt, shapes)


def _build(sym: Symbol, g: Graph, consumers, grads, wrt, shapes) -> Symbol:
    # reverse topological order
    for node in reversed(g.nodes):
        if node.op == "var":
            continue
        opdef = _ops.get(node.op)
        # gather output grads (None where no contribution)
        n_out = opdef.num_outputs
        ogs = []
        any_grad = False
        for j in range(n_out):
            lst = grads.get((node.uid, j))
            if lst:
                ogs.append(_ops.add_n(lst))
                any_grad = True
            else:
                ogs.append(None)
        if not any_grad:
            continue
        if opdef.grad is None:
            raise NotImplementedError(f"no gradient registered for op {node.op}")
        in_shapes = ([shapes[r.node.uid][r.index] for r in node.inputs]
                     if shapes is not None else
                     [None] * len(node.inputs))
        in_grads = opdef.grad(_ops.GB, node, in_shapes, ogs)
        assert len(in_grads) <= len(node.inputs)
        for ref, ig in zip(node.inputs, in_grads):
            if ig is not None:
                grads.setdefault((ref.node.uid, ref.index), []).append(ig)

    # collect per-variable grads
    var_nodes = {n.name: n for n in g.variables}
    outs = []
    for name in wrt:
        if name not in var_nodes:
            # var pruned from (or never in) the graph: zero gradient, like
            # MXNet's executor for unreached arguments
            from .graph import Node
            var_nodes[name] = Node("var", [], {}, name)
        node = var_nodes[name]
        lst = grads.get((node.uid, 0))
        if not lst:
            outs.append(_ops.GB.zeros_like(NodeRef(node, 0)))
        else:
            outs.append(_ops.add_n(lst))
    return Symbol(outs)
