"""Graph optimization passes (MXNet §3.1).

1. ``prune``        — only the subgraph needed for the requested outputs is
                      kept (prediction drops the backward half; feature
                      extraction drops the head).
2. ``pattern_fuse`` — operator grouping: e.g. ``a * b + c`` (c constant)
                      becomes one ``fma_const`` call, ``matmul + add(bias)``
                      becomes one ``fully_connected`` ("single BLAS call").
3. ``fuse_elementwise`` — maximal single-consumer trees of elementwise ops
                      are grouped into one ``fused`` segment that the
                      executor compiles as a single jitted call ("big op").
"""
from __future__ import annotations

from .graph import Graph, Node, NodeRef
from . import ops as _ops


# ---------------------------------------------------------------------------
# 1. Pruning: Graph() construction already keeps only ancestors of outputs —
# expose it as an explicit pass for clarity + stats.


def prune(graph: Graph, keep: list[NodeRef] | None = None) -> Graph:
    return Graph(keep if keep is not None else graph.outputs)


# ---------------------------------------------------------------------------
# 2. Pattern fusion (operator grouping)

def pattern_fuse(graph: Graph) -> Graph:
    """Rewrite mul+scale(beta) -> fma_const and matmul+add -> fully_connected.

    Single backward pass with a replacement map; consumers are rebuilt.
    """
    repl: dict[int, NodeRef] = {}  # old uid -> new ref

    def res(ref: NodeRef) -> NodeRef:
        while ref.node.uid in repl and repl[ref.node.uid].node.uid != ref.node.uid:
            nref = repl[ref.node.uid]
            ref = NodeRef(nref.node, nref.index if ref.index == 0 else ref.index)
        return ref

    consumers = graph.consumers()
    new_nodes: dict[int, Node] = {}

    for node in graph.nodes:
        ins = [res(r) for r in node.inputs]
        # pattern: scale(mul(a,b), alpha=1, beta=c) -> fma_const(a,b,beta=c)
        if (node.op == "scale" and node.attrs.get("alpha", 1.0) == 1.0
                and ins and ins[0].node.op == "mul"
                and len(consumers[node.inputs[0].node.uid]) == 1):
            m = ins[0].node
            fused = Node("fma_const", list(m.inputs),
                         {"beta": node.attrs.get("beta", 0.0)},
                         name=node.name + "_fma")
            fused.inputs = [res(r) for r in m.inputs]
            repl[node.uid] = NodeRef(fused, 0)
            new_nodes[node.uid] = fused
            continue
        # pattern: add(matmul(x, wT), b) -> fully_connected — only when the
        # matmul feeds just this add. (Layout: our matmul-based MLPs use
        # x @ w.T; we fuse the generic matmul+broadcast-add shape.)
        if (node.op == "add" and ins[0].node.op == "matmul"
                and len(consumers[node.inputs[0].node.uid]) == 1
                and ins[1].node.op == "var"):
            mm = ins[0].node
            x, w = [res(r) for r in mm.inputs]
            if w.node.op == "transpose":  # x @ w.T + b == fully_connected
                fused = Node("fully_connected", [x, res(w.node.inputs[0]), ins[1]],
                             {}, name=node.name + "_fc")
                repl[node.uid] = NodeRef(fused, 0)
                new_nodes[node.uid] = fused
                continue
        if ins != node.inputs:
            nn = Node(node.op, ins, node.attrs, node.name)
            repl[node.uid] = NodeRef(nn, 0)
            new_nodes[node.uid] = nn

    outs = []
    for r in graph.outputs:
        rr = res(r)
        if rr.node.uid in {n.uid for n in new_nodes.values()} or rr.node.uid not in repl:
            outs.append(NodeRef(rr.node, r.index))
        else:
            outs.append(rr)
    return Graph(outs)


# ---------------------------------------------------------------------------
# 3. Elementwise segment fusion

class FusedSegment:
    """A connected set of elementwise nodes executed as one jitted call."""

    def __init__(self, nodes: list[Node], graph: Graph):
        self.nodes = nodes  # topo order
        node_ids = {n.uid for n in nodes}
        consumers = graph.consumers()
        # external inputs (order-stable)
        self.ext_inputs: list[NodeRef] = []
        seen = set()
        for n in nodes:
            for r in n.inputs:
                if r.node.uid not in node_ids and (r.node.uid, r.index) not in seen:
                    seen.add((r.node.uid, r.index))
                    self.ext_inputs.append(r)
        # outputs needed outside the segment (or graph outputs)
        out_ids = {(r.node.uid, r.index) for r in graph.outputs}
        self.ext_outputs: list[NodeRef] = []
        for n in nodes:
            needed = any(c.uid not in node_ids for c, _ in consumers[n.uid])
            n_out = _ops.get(n.op).num_outputs
            for j in range(n_out):
                if needed or (n.uid, j) in out_ids:
                    self.ext_outputs.append(NodeRef(n, j))

    def make_callable(self):
        nodes, ext_inputs, ext_outputs = self.nodes, self.ext_inputs, self.ext_outputs

        def run(*arrays):
            env = {}
            for ref, a in zip(ext_inputs, arrays):
                env[(ref.node.uid, ref.index)] = a
            for n in nodes:
                ins = [env[(r.node.uid, r.index)] for r in n.inputs]
                outs = _ops.get(n.op).compute(ins, n.attrs)
                for j, o in enumerate(outs):
                    env[(n.uid, j)] = o
            return tuple(env[(r.node.uid, r.index)] for r in ext_outputs)

        return run


def fuse_elementwise(graph: Graph, min_size: int = 2):
    """Group elementwise nodes into segments.

    Legality rule (cycle-free by construction): a node joins its producer's
    segment iff the producer is elementwise and feeds ONLY this node.  This
    grows trees of single-consumer chains — the common case in backward
    graphs (Fig. 4) — without an expensive reachability check.

    Returns (segments, node2seg): segments maps seg_id -> FusedSegment for
    all segments with >= min_size nodes; node2seg maps uid -> seg_id.
    """
    consumers = graph.consumers()
    seg_of: dict[int, int] = {}
    members: dict[int, list[Node]] = {}
    next_seg = [0]

    def new_seg(node):
        sid = next_seg[0]
        next_seg[0] += 1
        seg_of[node.uid] = sid
        members[sid] = [node]
        return sid

    out_ids = {(r.node.uid, r.index) for r in graph.outputs}
    for node in graph.nodes:
        if node.op == "var" or not _ops.get(node.op).elementwise:
            continue
        sid = new_seg(node)
        # merge each producer's segment when the producer feeds only us
        for r in node.inputs:
            p = r.node
            if (p.uid in seg_of and len(consumers[p.uid]) == 1
                    and (p.uid, 0) not in out_ids
                    and seg_of[p.uid] != sid):
                old = seg_of[p.uid]
                for m in members[old]:
                    seg_of[m.uid] = sid
                members[sid] = members.pop(old) + members[sid]

    segments = {}
    node2seg = {}
    for sid, nodes in members.items():
        if len(nodes) >= min_size:
            # keep topo order within segment
            order = {n.uid: i for i, n in enumerate(graph.nodes)}
            nodes.sort(key=lambda n: order[n.uid])
            segments[sid] = FusedSegment(nodes, graph)
            for n in nodes:
                node2seg[n.uid] = sid
    return segments, node2seg


def optimize_graph(sym_outputs: list[NodeRef], enable_pattern: bool = True):
    g = Graph(sym_outputs)  # prune happens here
    if enable_pattern:
        g = pattern_fuse(g)
    return g
