"""KVStore — data synchronization over devices (MXNet §2.3, §3.3).

Primitives: ``push(key, grad)`` and ``pull(key) -> value`` with a
user-registered *updater* that merges pushed values into the stored one.
Consistency between workers is controlled by a consistency model:

* ``sequential`` — a push is an atomic barrier-ed reduction: all workers'
  step-*t* gradients are aggregated before any worker's step-*t+1* pull
  returns (synchronous data parallelism);
* ``eventual``  — pushes apply asynchronously; pulls may return values up to
  ``staleness`` versions old (asynchronous SGD).

Two-level topology (§3.3): a level-1 server aggregates gradients *within* a
machine (sum over local devices — one outbound message per machine), a
level-2 server aggregates *across* machines.  This reduces inter-machine
bytes by a factor of devices-per-machine; ``bytes_l1``/``bytes_l2`` account
for it and are validated by tests and the Fig. 8 benchmark.

All store traffic is scheduled through the dependency engine, so pushes and
pulls interleave correctly with computation (the paper's
``while(1){kv.pull; net.forward_backward(); kv.push}`` loop is lazy
end-to-end).

The *production on-mesh mapping* of this two-level structure (hierarchical
reduce-scatter/all-reduce/all-gather over a (pod, data, model) TPU mesh)
lives in ``repro.dist.collectives``.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.obs import get_metrics

from .engine import Engine, default_engine
from .ndarray import NDArray


def sgd_updater(lr: float) -> Callable:
    def update(key, stored, pushed):
        return stored - lr * pushed
    return update


def sum_updater():
    def update(key, stored, pushed):
        return stored + pushed
    return update


class KVStoreLocal:
    """Single-process store: aggregates pushes from local devices (level-1)."""

    def __init__(self, engine: Engine | None = None):
        self.engine = engine or default_engine()
        self._store: dict[str, NDArray] = {}
        self._updater: Callable = lambda key, stored, pushed: stored + pushed
        self.bytes_pushed = 0
        # per-key attribution (sums to bytes_pushed); keys may be gradient
        # buckets, so per-bucket traffic rolls up for cross-validation
        self.bytes_pushed_by_key: dict[str, int] = defaultdict(int)

    def set_updater(self, fn: Callable):
        self._updater = fn

    def init(self, key: str, value):
        arr = value if isinstance(value, NDArray) else NDArray(value,
                                                               engine=self.engine,
                                                               name=f"kv_{key}")
        self._store[key] = arr

    def keys(self):
        return list(self._store)

    def push(self, key: str, values):
        """values: NDArray or list of NDArrays (one per local device)."""
        if not isinstance(values, (list, tuple)):
            values = [values]
        stored = self._store[key]
        read_tags = [v.tag for v in values]
        nb = sum(int(np.prod(v.shape)) * 4 for v in values)
        self.bytes_pushed += nb
        self.bytes_pushed_by_key[key] += nb

        def fn(stored=stored, values=values, key=key):
            agg = values[0]._value
            for v in values[1:]:
                agg = agg + v._value  # level-1 aggregation
            stored._set(self._updater(key, stored._value, agg))
        self.engine.push(fn, reads=read_tags, writes=(stored.tag,),
                         name=f"kv_push_{key}")

    def pull(self, key: str, out: NDArray | None = None) -> NDArray:
        stored = self._store[key]
        out = out or NDArray(engine=self.engine, name=f"kv_pull_{key}")
        out.shape, out.dtype = stored.shape, stored.dtype
        self.engine.push(lambda: out._set(stored._value),
                         reads=(stored.tag,), writes=(out.tag,),
                         name=f"kv_pull_{key}")
        return out

    def publish_metrics(self, metrics=None) -> None:
        """Publish byte attribution into a metrics registry (default: the
        process-wide one): ``kvstore.bytes_pushed`` plus one
        ``kvstore.bytes_pushed.<key>`` counter per key.  Gauge-free set:
        counters are assigned, not incremented, so repeated publishes
        stay idempotent."""
        m = metrics if metrics is not None else get_metrics()
        m.counter("kvstore.bytes_pushed").value = self.bytes_pushed
        for k, nb in self.bytes_pushed_by_key.items():
            m.counter(f"kvstore.bytes_pushed.{k}").value = nb


class KVStoreDist:
    """Multi-worker simulation of the two-level distributed store.

    ``n_machines`` level-1 servers × ``devices_per_machine`` devices each.
    Worker w = (machine m, device d).  Byte counters model the paper's
    claim that level-1 aggregation reduces inter-machine bandwidth.
    """

    def __init__(self, n_machines: int, devices_per_machine: int = 1,
                 consistency: str = "sequential", staleness: int = 1,
                 engine: Engine | None = None):
        assert consistency in ("sequential", "eventual")
        self.engine = engine or default_engine()
        self.n_machines = n_machines
        self.devices_per_machine = devices_per_machine
        self.n_workers = n_machines * devices_per_machine
        self.consistency = consistency
        self.staleness = staleness
        self._updater = lambda key, stored, pushed: stored + pushed
        self._value: dict[str, jnp.ndarray] = {}          # level-2 (global)
        self._version: dict[str, int] = {}
        self._history: dict[str, list] = defaultdict(list)  # for staleness
        self._pending: dict[str, dict[int, list]] = defaultdict(dict)
        self.bytes_l1 = 0  # device -> level-1 server (intra-machine)
        self.bytes_l2 = 0  # level-1 -> level-2 (inter-machine)
        # per-key attribution (each sums to its total): when keys are
        # gradient buckets this is the per-bucket traffic the bucketed
        # gradient_sync cross-validates against the compiled HLO
        # (benchmarks/bench_dist.py --mode bucketed)
        self.bytes_l1_by_key: dict[str, int] = defaultdict(int)
        self.bytes_l2_by_key: dict[str, int] = defaultdict(int)

    def set_updater(self, fn: Callable):
        self._updater = fn

    def init(self, key: str, value):
        v = jnp.asarray(value)
        self._value[key] = v
        self._version[key] = 0
        self._history[key] = [v]

    def keys(self):
        return list(self._value)

    # -- worker API ---------------------------------------------------------
    def push(self, key: str, worker: int, grad):
        """Queue worker's gradient; applies when the machine set completes
        (sequential) or immediately per-machine (eventual)."""
        g = grad._value if isinstance(grad, NDArray) else jnp.asarray(grad)
        m = worker // self.devices_per_machine
        nb = int(np.prod(g.shape)) * 4
        self.bytes_l1 += nb
        self.bytes_l1_by_key[key] += nb
        pend = self._pending[key]
        pend.setdefault(m, [])
        pend[m].append(g)

        if self.consistency == "eventual":
            # machine-complete? flush that machine's level-1 aggregate up
            if len(pend[m]) == self.devices_per_machine:
                agg = pend.pop(m)
                total = agg[0]
                for x in agg[1:]:
                    total = total + x
                self.bytes_l2 += nb
                self.bytes_l2_by_key[key] += nb
                self._apply(key, total)
        else:
            # sequential: wait for ALL machines' full sets, then one update
            if all(len(pend.get(mm, [])) >= self.devices_per_machine
                   for mm in range(self.n_machines)):
                total = None
                for mm in range(self.n_machines):
                    gs = pend[mm][:self.devices_per_machine]
                    pend[mm] = pend[mm][self.devices_per_machine:]
                    l1 = gs[0]
                    for x in gs[1:]:
                        l1 = l1 + x          # level-1 aggregate
                    self.bytes_l2 += nb      # one message per machine
                    self.bytes_l2_by_key[key] += nb
                    total = l1 if total is None else total + l1
                self._apply(key, total)
                self._pending[key] = {mm: v for mm, v in pend.items() if v}

    def _apply(self, key, agg):
        self._value[key] = self._updater(key, self._value[key], agg)
        self._version[key] += 1
        h = self._history[key]
        h.append(self._value[key])
        if len(h) > self.staleness + 2:
            del h[: len(h) - (self.staleness + 2)]

    def pull(self, key: str, worker: int = 0):
        if self.consistency == "eventual" and self.staleness > 0:
            h = self._history[key]
            # deterministic bounded staleness: workers on machine 0 see fresh
            # values, later machines see progressively staler ones
            m = worker // self.devices_per_machine
            lag = min(m % (self.staleness + 1), len(h) - 1)
            return h[-1 - lag]
        return self._value[key]

    def version(self, key: str) -> int:
        return self._version[key]

    def publish_metrics(self, metrics=None) -> None:
        """Publish the two-level byte attribution (§3.3) into a metrics
        registry: ``kvstore.bytes_l1`` / ``kvstore.bytes_l2`` totals plus
        per-key counters — the numbers ``bench_dist`` cross-validates
        against compiled HLO, now visible outside the bench."""
        m = metrics if metrics is not None else get_metrics()
        m.counter("kvstore.bytes_l1").value = self.bytes_l1
        m.counter("kvstore.bytes_l2").value = self.bytes_l2
        for k, nb in self.bytes_l1_by_key.items():
            m.counter(f"kvstore.bytes_l1.{k}").value = nb
        for k, nb in self.bytes_l2_by_key.items():
            m.counter(f"kvstore.bytes_l2.{k}").value = nb
