"""Memory allocation for computation graphs (MXNet §3.1 "Memory Allocation").

Each internal variable's lifetime is known statically from the graph, so
buffers can be shared between variables whose lifetimes do not intersect.
The optimal assignment is quadratic; the paper proposes two linear-time
heuristics which we implement faithfully:

* ``inplace``  — simulate graph traversal keeping a reference count of
  consumers not yet executed; when an op's input refcount drops to zero at
  the op itself AND the op is registered inplace-capable for that input,
  the output is written into the input's buffer.
* ``co-share`` — two nodes may share a buffer iff they cannot run in
  parallel.  We recycle buffers through a free pool keyed by size when the
  refcount reaches zero; every reuse adds a serialization constraint
  (recorded in ``plan.constraints`` and honoured by the dependency engine
  via write-tags on buffers).

Strategies: ``naive`` (no sharing), ``inplace``, ``coshare``, ``both``.
``benchmarks/bench_memory.py`` reproduces Fig. 7 with these.
"""
from __future__ import annotations

from dataclasses import dataclass, field


from .graph import Graph
from . import ops as _ops

_DTYPE_BYTES = {"float64": 8, "float32": 4, "float16": 2, "bfloat16": 2,
                "float8_e4m3fn": 1, "float8_e5m2": 1,
                "int64": 8, "uint64": 8, "int32": 4, "uint32": 4,
                "int16": 2, "uint16": 2, "int8": 1, "uint8": 1, "bool": 1}


def nbytes(shape, dtype) -> int:
    """Bytes of one ``(shape, dtype)`` buffer.

    Unknown dtypes are an error, not a silent 4-byte guess — a planner
    that under- or over-counts buffer sizes corrupts the co-share free
    pool (buffers are recycled by exact size)."""
    key = str(dtype)
    if key not in _DTYPE_BYTES:
        raise ValueError(
            f"memplan.nbytes: unknown dtype {key!r}; add its width to "
            f"memplan._DTYPE_BYTES (known: {sorted(_DTYPE_BYTES)})")
    n = 1
    for d in shape:
        n *= int(d)
    return n * _DTYPE_BYTES[key]


@dataclass
class Buffer:
    bid: int
    size: int


@dataclass
class MemPlan:
    # (uid, out_idx) -> buffer id;  external (vars, outputs) get bid = -uid-1
    assignment: dict[tuple[int, int], int]
    buffers: dict[int, Buffer]
    external: set[tuple[int, int]]
    constraints: list[tuple[int, int]] = field(default_factory=list)  # (uid_before, uid_after)
    inplace_pairs: list[tuple[int, int]] = field(default_factory=list)

    def internal_bytes(self) -> int:
        return sum(b.size for b in self.buffers.values())

    def stats(self) -> dict:
        return {
            "internal_bytes": self.internal_bytes(),
            "n_buffers": len(self.buffers),
            "n_inplace": len(self.inplace_pairs),
            "n_constraints": len(self.constraints),
        }


@dataclass
class Unit:
    """One schedulable execution unit: a plain node or a fused segment.

    ``in_keys``/``out_keys`` are (uid, out_idx) value identifiers;
    ``out_sizes`` parallel bytes; ``inplace`` = (input_pos, output_pos)
    candidate pairs whose buffers may be unified when the input dies here.
    """
    uid: int
    in_keys: list
    out_keys: list
    out_sizes: list
    inplace: tuple = ()


def plan_schedule(units: list[Unit], external: set,
                  strategy: str = "both") -> MemPlan:
    """Linear-time buffer assignment over an execution schedule (§3.1).

    The schedule — not the raw graph — is planned, so deferred fused
    segments see buffers kept alive until they actually run.
    """
    assert strategy in ("naive", "inplace", "coshare", "both")
    use_inplace = strategy in ("inplace", "both")
    use_coshare = strategy in ("coshare", "both")

    refcount: dict[tuple[int, int], int] = {}
    for u in units:
        for k in u.in_keys:
            refcount[k] = refcount.get(k, 0) + 1

    assignment: dict[tuple[int, int], int] = {}
    buffers: dict[int, Buffer] = {}
    free_pool: dict[int, list[int]] = {}
    last_user: dict[int, int] = {}
    constraints: list[tuple[int, int]] = []
    inplace_pairs: list[tuple[int, int]] = []
    next_bid = [0]
    next_ext = [-1]

    def fresh(size: int) -> int:
        bid = next_bid[0]
        next_bid[0] += 1
        buffers[bid] = Buffer(bid, size)
        return bid

    for u in units:
        dying = []
        for k in u.in_keys:
            refcount[k] -= 1
            if refcount[k] == 0 and k not in external:
                dying.append(k)

        used_inplace: set[tuple[int, int]] = set()
        for j, (key, size) in enumerate(zip(u.out_keys, u.out_sizes)):
            if key in external:
                assignment[key] = next_ext[0]
                next_ext[0] -= 1
                continue
            bid = None
            if use_inplace:
                for (ii, oo) in u.inplace:
                    if oo != j or ii >= len(u.in_keys):
                        continue
                    k = u.in_keys[ii]
                    if k in dying and k in assignment and k not in used_inplace:
                        cand = assignment[k]
                        if cand >= 0 and buffers[cand].size == size:
                            bid = cand
                            used_inplace.add(k)
                            inplace_pairs.append((k[0], u.uid))
                            break
            if bid is None and use_coshare:
                pool = free_pool.get(size)
                if pool:
                    bid = pool.pop()
                    constraints.append((last_user[bid], u.uid))
            if bid is None:
                bid = fresh(size)
            assignment[key] = bid
            last_user[bid] = u.uid

        for k in dying:
            if k in used_inplace or k not in assignment:
                continue
            bid = assignment[k]
            if bid >= 0:
                free_pool.setdefault(buffers[bid].size, []).append(bid)
                last_user[bid] = u.uid
        for key, size in zip(u.out_keys, u.out_sizes):
            if key in external or refcount.get(key, 0) > 0:
                continue
            bid = assignment[key]
            if bid >= 0:
                free_pool.setdefault(buffers[bid].size, []).append(bid)

    return MemPlan(assignment, buffers, external, constraints, inplace_pairs)


def units_from_graph(graph: Graph, shapes, dtypes) -> tuple[list[Unit], set]:
    """Per-node units in topo order (the no-fusion schedule)."""
    external = {(n.uid, 0) for n in graph.variables}
    external |= {(r.node.uid, r.index) for r in graph.outputs}
    units = []
    for node in graph.nodes:
        if node.op == "var":
            continue
        opdef = _ops.get(node.op)
        in_keys = [(r.node.uid, r.index) for r in node.inputs]
        out_keys = [(node.uid, j) for j in range(opdef.num_outputs)]
        out_sizes = [nbytes(sh, dt) for sh, dt in
                     zip(shapes[node.uid], dtypes[node.uid])]
        units.append(Unit(node.uid, in_keys, out_keys, out_sizes,
                          inplace=opdef.inplace))
    return units, external


def plan_graph(graph: Graph, shapes: dict, dtypes: dict,
               strategy: str = "both",
               external: set[tuple[int, int]] | None = None) -> MemPlan:
    """Assign buffers to every internal node output (per-node schedule).

    ``external``: (uid, idx) pairs that own storage outside the plan
    (free variables always; graph outputs by default — they are returned to
    the user, mirroring Fig. 7's "internal variables except the outputs").
    """
    units, ext = units_from_graph(graph, shapes, dtypes)
    if external:
        ext |= set(external)
    return plan_schedule(units, ext, strategy=strategy)


# ---------------------------------------------------------------------------
# KV/SSM decode-cache byte models (serving).  The §3.1 lifetime argument
# applied to the serving cache: a dense engine allocates every sequence its
# worst-case ``max_len`` rectangle; a paged cache only keeps blocks whose
# lifetime has actually started (positions < the sequence's live length).


def _kv_tok_bytes(cfg, kv_dtype=None) -> int:
    """Bytes per cached (token, kv-head) K or V row for ONE layer's head.

    Native caches store ``hd`` activations at the model itemsize.  A
    quantized cache (``kv_dtype`` = "int8" / "fp8_e4m3" / "fp8_e5m2")
    stores ``hd`` one-byte codes PLUS one f32 scale per (token, kv-head)
    row — the per-block scale tensors allocated alongside the pools by
    ``make_paged_cache`` (DESIGN.md §13)."""
    act = 2 if cfg.dtype == "bfloat16" else 4
    if kv_dtype in (None, "native"):
        return cfg.hd * act
    from repro.kernels.quant import resolve_kv_dtype
    qdt = resolve_kv_dtype(kv_dtype)    # validates the name
    return cfg.hd * _DTYPE_BYTES[str(qdt)] + 4


def _cache_row_bytes(cfg, kv_dtype=None) -> tuple[int, int]:
    """(bytes per cached token across all attn layers, fixed per-seq SSM
    state bytes).  ``cfg`` is an ``ArchConfig`` duck-type: only pattern /
    n_super / head dims / ssm dims / dtype are read."""
    act = 2 if cfg.dtype == "bfloat16" else 4
    tok = _kv_tok_bytes(cfg, kv_dtype)
    per_tok = 0
    fixed = 0
    for spec in cfg.pattern:
        if spec.kind == "attn":
            per_tok += cfg.n_super * 2 * cfg.n_kv_heads * tok
        else:
            ch = cfg.d_inner + 2 * cfg.ssm_state
            fixed += cfg.n_super * ((cfg.conv_width - 1) * ch * act
                                    + cfg.ssm_heads * cfg.ssm_p
                                    * cfg.ssm_state * 4)
    return per_tok, fixed


def kv_cache_bytes_dense(cfg, batch: int, max_len: int,
                         kv_dtype=None) -> int:
    """Dense engine footprint: every sequence padded to ``max_len``
    (windowed layers ring-buffered to ``min(window, max_len)``)."""
    act = 2 if cfg.dtype == "bfloat16" else 4
    tok = _kv_tok_bytes(cfg, kv_dtype)
    total = 0
    for spec in cfg.pattern:
        if spec.kind == "attn":
            S = max_len if spec.window is None else min(spec.window, max_len)
            total += cfg.n_super * batch * S * 2 * cfg.n_kv_heads * tok
        else:
            ch = cfg.d_inner + 2 * cfg.ssm_state
            total += cfg.n_super * batch * (
                (cfg.conv_width - 1) * ch * act
                + cfg.ssm_heads * cfg.ssm_p * cfg.ssm_state * 4)
    return total


def kv_cache_bytes_paged(cfg, lengths, block_size: int,
                         kv_dtype=None) -> dict:
    """Paged footprint for live per-sequence ``lengths`` (an iterable of
    token counts): blocks actually backed, block-granularity rounding
    included, plus the per-slot SSM state.  Returns ``{"bytes", "blocks",
    "block_bytes"}`` — ``block_bytes`` is the size of ONE block across all
    attention layers (the unit the allocator's ``peak_in_use`` counts).
    With ``kv_dtype`` set, the per-row f32 scale tensors are included so
    the model equals the real pool allocation exactly."""
    per_tok, fixed = _cache_row_bytes(cfg, kv_dtype)
    lengths = [int(L) for L in lengths]
    block_bytes = per_tok * block_size
    blocks = sum(-(-L // block_size) for L in lengths if L > 0)
    return {"bytes": blocks * block_bytes + len(lengths) * fixed,
            "blocks": blocks,
            "block_bytes": block_bytes}


def swap_pool_bytes(cfg, swap_blocks: int, block_size: int, *,
                    kv_dtype=None, max_swapped_requests: int = 0) -> dict:
    """Host-side swap pool footprint (preemption target, DESIGN.md §14).

    A swapped-out request carries its KV block rows — priced at the SAME
    ``block_bytes`` unit as the device pool, so device + swap capacity
    add in one currency — plus its fixed per-request SSM slot state (the
    ``fixed`` term of ``_cache_row_bytes``; zero for pure-attention
    archs).  ``max_swapped_requests`` bounds the SSM term: the pool
    holds at most that many entries at once (0 = attn-only accounting).
    The payload is a bit-exact host copy, so the byte model is exact —
    ``tests/test_serve_lifecycle.py`` audits it against real payloads.
    """
    per_tok, fixed = _cache_row_bytes(cfg, kv_dtype)
    block_bytes = per_tok * block_size
    return {"block_bytes": block_bytes,
            "kv_bytes": swap_blocks * block_bytes,
            "ssm_bytes_per_request": fixed,
            "total_bytes": (swap_blocks * block_bytes
                            + max_swapped_requests * fixed)}


def pipeline_stage_bytes(cfg, *, n_stages: int, microbatches: int,
                         global_batch: int, seq_len: int,
                         n_data: int = 1) -> dict:
    """Per-stage byte model of the 1F1B pipeline (DESIGN.md §10).

    ``stage_param_bytes``: the layer-contiguous super-block slice each
    stage owns (replicated params — embed/head/norms — are counted
    separately).  ``stage_activation_bytes``: the saved stage *inputs*
    (one (b, S, D) activation per in-flight microbatch — the backward
    residuals; block internals are rematerialized).  ``permute`` is the
    activation hand-off model (``dist.pipeline.pipeline_permute_bytes``).
    """
    from dataclasses import replace
    from repro.dist.pipeline import (pipeline_bubble_fraction,
                                     pipeline_permute_bytes,
                                     validate_pipeline)
    validate_pipeline(n_stages=n_stages, microbatches=microbatches,
                      n_super=cfg.n_super, batch=global_batch,
                      n_data=n_data)
    act = 2 if cfg.dtype == "bfloat16" else 4
    total = cfg.param_count()
    rest = replace(cfg, n_layers=0).param_count()   # embed/head/frontend
    b = global_batch // microbatches // n_data
    permute = pipeline_permute_bytes(b, seq_len, cfg.d_model,
                                     n_stages=n_stages,
                                     microbatches=microbatches,
                                     itemsize=act)
    return {
        "n_stages": n_stages,
        "microbatches": microbatches,
        "stage_param_bytes": (total - rest) * act // n_stages,
        "replicated_param_bytes": rest * act,
        "stage_activation_bytes": microbatches * b * seq_len
                                  * cfg.d_model * act,
        "bubble_fraction": pipeline_bubble_fraction(n_stages, microbatches),
        "permute": permute,
    }


def checkpoint_bytes(leaves, axis_sizes=None, n_hosts: int = 1) -> dict:
    """Bytes-per-host model of a sharded checkpoint save (DESIGN.md §12).

    ``leaves``: iterable of ``(shape, dtype, spec)`` where ``spec`` has
    one entry per dim — ``None`` or a tuple of mesh axis names (the
    resolved PartitionSpec the leaf is laid out under).  ``axis_sizes``
    maps axis name -> mesh size.

    Each global array is written exactly once (replicas are
    deduplicated at save time), so ``total_bytes`` is mesh-independent
    and equals the on-disk sum of shard files EXACTLY (raw ``.bin``
    shards carry no headers).  Sharding only divides the *work*: with
    shards spread over ``n_hosts`` writers, each host serializes
    ``bytes_per_host`` ~= total/n_hosts, which is the term that replaces
    the old gather-to-host model (one host writing everything) in the
    save-stall budget.
    """
    axis_sizes = dict(axis_sizes or {})
    total = n_shards = max_shard = 0
    for shape, dtype, spec in leaves:
        b = nbytes(shape, dtype)
        total += b
        k = 1
        for e in (spec or ()):
            if e is None:
                continue
            axes = (e,) if isinstance(e, str) else tuple(e)
            for a in axes:
                k *= axis_sizes.get(a, 1)
        n_shards += k
        max_shard = max(max_shard, b // k)
    n_hosts = max(int(n_hosts), 1)
    return {"total_bytes": total, "n_shards": n_shards,
            "max_shard_bytes": max_shard, "n_hosts": n_hosts,
            "bytes_per_host": -(-total // n_hosts)}


def eventual_sync_bytes(leaves, *, n_data: int, n_workers: int,
                        max_staleness: int = 0,
                        bucket_bytes: int | None = None) -> dict:
    """Device-byte model of the eventual-consistency sync state
    (DESIGN.md §15): each worker holds one stale remote-pod 1/``n_data``
    shard per gradient bucket, so the footprint is the full gradient
    payload divided by the intra-pod reduce-scatter factor — the price of
    bounding staleness instead of synchronizing every step.

    ``leaves``: iterable of ``(shape, dtype)`` per-worker gradient leaves
    (no worker dim).  Delegates to the SAME :class:`~repro.dist.bucketing.
    BucketPlan` + ``eventual_state_bytes`` the runtime uses, so the model
    is exact, and adds the steady-state cross-pod traffic summary.
    """
    import jax
    from repro.dist.bucketing import DEFAULT_BUCKET_BYTES, BucketPlan
    from repro.dist.collectives import (eventual_crosspod_bytes,
                                        eventual_state_bytes)
    structs = [jax.ShapeDtypeStruct(tuple(s), d) for s, d in leaves]
    plan = BucketPlan.build(structs, cap_bytes=bucket_bytes
                            or DEFAULT_BUCKET_BYTES)
    state = eventual_state_bytes(plan, n_data, n_workers)
    period = max_staleness + 1
    full = eventual_crosspod_bytes(plan, n_data, max_staleness=0, phase=0)
    steady = sum(eventual_crosspod_bytes(plan, n_data,
                                         max_staleness=max_staleness,
                                         phase=p) for p in range(period))
    return {**state, "period": period,
            "crosspod_bytes_full_sync": full,
            "crosspod_bytes_per_step_steady": steady / period,
            "crosspod_reduction": full / max(steady / period, 1)}


def naive_bytes(graph: Graph, shapes, dtypes) -> int:
    """Sum of all internal node outputs with no sharing (the Fig. 7 baseline)."""
    ext = {(n.uid, 0) for n in graph.variables}
    ext |= {(r.node.uid, r.index) for r in graph.outputs}
    total = 0
    for n in graph.nodes:
        if n.op == "var":
            continue
        for j, (sh, dt) in enumerate(zip(shapes[n.uid], dtypes[n.uid])):
            if (n.uid, j) not in ext:
                total += nbytes(sh, dt)
    return total
