"""Symbol — declarative symbolic expressions (MXNet §2.1).

A Symbol wraps one or more graph-node output references.  Symbols are
composited from operators (simple matrix ops like ``+`` or whole neural-net
layers like :func:`FullyConnected`), may be multi-output, and support shape
inference, save/load, memory estimation, autodiff (:meth:`Symbol.grad`) and
binding (:meth:`Symbol.bind`) to an executor.
"""
from __future__ import annotations

import json
from typing import Sequence

from .graph import Graph, Node, NodeRef, infer_shapes
from . import ops as _ops


class Symbol:
    def __init__(self, outputs: Sequence[NodeRef]):
        self._outputs = list(outputs)

    # -- composition --------------------------------------------------------
    @staticmethod
    def _from_op(op: str, inputs: Sequence["Symbol"], attrs=None, name=None) -> "Symbol":
        refs = []
        for s in inputs:
            if len(s._outputs) != 1:
                raise ValueError("operator inputs must be single-output symbols; "
                                 "select with sym[i]")
            refs.append(s._outputs[0])
        node = Node(op, refs, attrs, name)
        n_out = _ops.get(op).num_outputs
        return Symbol([NodeRef(node, i) for i in range(n_out)])

    def __getitem__(self, i: int) -> "Symbol":
        return Symbol([self._outputs[i]])

    def __len__(self):
        return len(self._outputs)

    # -- operator sugar ------------------------------------------------------
    def _binop(self, op, other, reverse=False):
        if not isinstance(other, Symbol):
            if op in ("add", "sub"):
                alpha, beta = (1.0, float(other)) if not reverse else (-1.0, float(other))
                if op == "sub" and not reverse:
                    beta = -float(other)
                return Symbol._from_op("scale", [self],
                                       {"alpha": alpha, "beta": beta})
            if op in ("mul", "div"):
                alpha = float(other) if op == "mul" else 1.0 / float(other)
                return Symbol._from_op("scale", [self], {"alpha": alpha})
            raise TypeError(other)
        a, b = (other, self) if reverse else (self, other)
        return Symbol._from_op(op, [a, b])

    __add__ = lambda s, o: s._binop("add", o)
    __radd__ = lambda s, o: s._binop("add", o, True)
    __sub__ = lambda s, o: s._binop("sub", o)
    __rsub__ = lambda s, o: s._binop("sub", o, True)
    __mul__ = lambda s, o: s._binop("mul", o)
    __rmul__ = lambda s, o: s._binop("mul", o, True)
    __truediv__ = lambda s, o: s._binop("div", o)
    __neg__ = lambda s: Symbol._from_op("neg", [s])
    __matmul__ = lambda s, o: Symbol._from_op("matmul", [s, o])

    # -- introspection -------------------------------------------------------
    def graph(self) -> Graph:
        return Graph(self._outputs)

    def list_arguments(self) -> list[str]:
        return [n.name for n in self.graph().variables]

    def infer_shape(self, **var_shapes):
        g = self.graph()
        shapes, _ = infer_shapes(g, var_shapes)
        return [shapes[r.node.uid][r.index] for r in self._outputs]

    def memory_estimate(self, strategy: str = "both", **var_shapes) -> dict:
        """Bytes needed for internal variables under a memplan strategy."""
        from .memplan import plan_graph
        g = self.graph()
        shapes, dtypes = infer_shapes(g, var_shapes)
        return plan_graph(g, shapes, dtypes, strategy=strategy).stats()

    # -- autodiff (§2.1 "backward") ------------------------------------------
    def grad(self, wrt: Sequence[str], **var_shapes) -> "Symbol":
        from .autodiff import gradient, gradient_with_shapes
        if var_shapes:
            return gradient_with_shapes(self, wrt, var_shapes)
        return gradient(self, wrt)

    # -- save / load -----------------------------------------------------------
    def tojson(self) -> str:
        g = self.graph()
        idx = {n.uid: i for i, n in enumerate(g.nodes)}
        nodes = [{
            "op": n.op, "name": n.name, "attrs": _jsonable(n.attrs),
            "inputs": [[idx[r.node.uid], r.index] for r in n.inputs],
        } for n in g.nodes]
        heads = [[idx[r.node.uid], r.index] for r in self._outputs]
        return json.dumps({"nodes": nodes, "heads": heads})

    @staticmethod
    def fromjson(s: str) -> "Symbol":
        d = json.loads(s)
        built: list[Node] = []
        for nd in d["nodes"]:
            ins = [NodeRef(built[i], j) for i, j in nd["inputs"]]
            attrs = {k: (tuple(v) if isinstance(v, list) else v)
                     for k, v in nd["attrs"].items()}
            built.append(Node(nd["op"], ins, attrs, nd["name"]))
        return Symbol([NodeRef(built[i], j) for i, j in d["heads"]])

    def save(self, path: str):
        with open(path, "w") as f:
            f.write(self.tojson())

    @staticmethod
    def load(path: str) -> "Symbol":
        with open(path) as f:
            return Symbol.fromjson(f.read())

    # -- binding / evaluation ---------------------------------------------------
    def bind(self, args: dict, grad_wrt: Sequence[str] = (), optimize: bool = True,
             memplan: str = "both", **kw):
        from .executor import Executor
        return Executor(self, args, grad_wrt=grad_wrt, optimize=optimize,
                        memplan=memplan, **kw)

    def eval(self, **args):
        ex = self.bind(args, optimize=True)
        return ex.forward()

    def __repr__(self):
        return f"<Symbol {[r.node.name for r in self._outputs]}>"


def _jsonable(attrs: dict) -> dict:
    out = {}
    for k, v in attrs.items():
        out[k] = list(v) if isinstance(v, tuple) else v
    return out


# ---------------------------------------------------------------------------
# Layer-level operator API (Fig. 2 style)


def Variable(name: str) -> Symbol:
    return Symbol([NodeRef(Node("var", [], {}, name))])


def FullyConnected(data: Symbol, num_hidden: int, name: str | None = None,
                   no_bias: bool = False) -> Symbol:
    prefix = name or f"fc{data._outputs[0].node.uid}"
    w = Variable(prefix + "_weight")
    ins = [data, w] if no_bias else [data, w, Variable(prefix + "_bias")]
    return Symbol._from_op("fully_connected", ins,
                           {"num_hidden": int(num_hidden)}, name=prefix)


def Activation(data: Symbol, act_type: str = "relu", name=None) -> Symbol:
    assert act_type in ("relu", "tanh", "sigmoid")
    return Symbol._from_op(act_type, [data], name=name)


def SoftmaxOutput(data: Symbol, label: Symbol, name=None) -> Symbol:
    """Outputs: [0] mean cross-entropy loss, [1] softmax probabilities."""
    return Symbol._from_op("softmax_xent", [data, label], name=name)


def Softmax(data: Symbol, name=None) -> Symbol:
    return Symbol._from_op("softmax", [data], name=name)


def LayerNorm(data: Symbol, gamma: Symbol, beta: Symbol, eps: float = 1e-5,
              name=None) -> Symbol:
    return Symbol._from_op("layernorm", [data, gamma, beta], {"eps": eps}, name=name)


def chain(*stages):
    """``chain(Variable("data"), lambda x: FullyConnected(x, 64), ...)`` —
    the Julia ``@mx.chain`` macro from Fig. 2, in Python."""
    sym = stages[0]
    for fn in stages[1:]:
        sym = fn(sym)
    return sym
