"""NDArray — imperative, lazily-evaluated tensors (MXNet §2.2).

Operations on NDArrays are pushed to the dependency engine instead of being
executed eagerly; ``asnumpy()`` (or any read of ``.value``) flushes.  This
lets imperative statements like ``w -= lr * g`` interleave with symbolic
executor calls *and* KVStore communication under one scheduler, which is the
paper's central flexibility claim.
"""
from __future__ import annotations


import jax.numpy as jnp
import numpy as np

from .engine import Engine, Tag, default_engine


class NDArray:
    def __init__(self, value=None, engine: Engine | None = None, name: str = "",
                 shape=None, dtype=None):
        self.engine = engine or default_engine()
        self.tag = Tag(name or "ndarray")
        self._value = None
        self.shape = tuple(shape) if shape is not None else None
        self.dtype = dtype
        if value is not None:
            arr = jnp.asarray(value)
            self._value = arr
            self.shape, self.dtype = arr.shape, arr.dtype

    # -- engine plumbing --------------------------------------------------------
    def _set(self, v):
        self._value = v

    @property
    def value(self):
        self.engine.wait(self.tag)
        return self._value

    def asnumpy(self) -> np.ndarray:
        return np.asarray(self.value)

    # -- functional ops (lazy) -----------------------------------------------
    def _binary(self, other, fn, name):
        out = NDArray(engine=self.engine, name=name)
        if isinstance(other, NDArray):
            a, b = self, other
            out.shape = tuple(jnp.broadcast_shapes(a.shape, b.shape))
            out.dtype = a.dtype
            self.engine.push(lambda: out._set(fn(a._value, b._value)),
                             reads=(a.tag, b.tag), writes=(out.tag,), name=name)
        else:
            a, c = self, other
            out.shape, out.dtype = a.shape, a.dtype
            self.engine.push(lambda: out._set(fn(a._value, c)),
                             reads=(a.tag,), writes=(out.tag,), name=name)
        return out

    __add__ = lambda s, o: s._binary(o, lambda a, b: a + b, "add")
    __radd__ = lambda s, o: s._binary(o, lambda a, b: b + a, "radd")
    __sub__ = lambda s, o: s._binary(o, lambda a, b: a - b, "sub")
    __rsub__ = lambda s, o: s._binary(o, lambda a, b: b - a, "rsub")
    __mul__ = lambda s, o: s._binary(o, lambda a, b: a * b, "mul")
    __rmul__ = lambda s, o: s._binary(o, lambda a, b: b * a, "rmul")
    __truediv__ = lambda s, o: s._binary(o, lambda a, b: a / b, "div")
    __matmul__ = lambda s, o: s._binary(o, lambda a, b: a @ b, "matmul")

    def __neg__(self):
        out = NDArray(engine=self.engine, name="neg")
        out.shape, out.dtype = self.shape, self.dtype
        self.engine.push(lambda: out._set(-self._value),
                         reads=(self.tag,), writes=(out.tag,), name="neg")
        return out

    # -- mutating ops (write-tags; §3.2) ------------------------------------
    def _inplace(self, other, fn, name):
        if isinstance(other, NDArray):
            self.engine.push(lambda: self._set(fn(self._value, other._value)),
                             reads=(other.tag,), writes=(self.tag,), name=name)
        else:
            self.engine.push(lambda: self._set(fn(self._value, other)),
                             reads=(), writes=(self.tag,), name=name)
        return self

    __iadd__ = lambda s, o: s._inplace(o, lambda a, b: a + b, "iadd")
    __isub__ = lambda s, o: s._inplace(o, lambda a, b: a - b, "isub")
    __imul__ = lambda s, o: s._inplace(o, lambda a, b: a * b, "imul")

    def assign(self, other):
        if isinstance(other, NDArray):
            self.engine.push(lambda: self._set(other._value),
                             reads=(other.tag,), writes=(self.tag,), name="assign")
        else:
            arr = jnp.asarray(other)
            self.engine.push(lambda: self._set(arr),
                             reads=(), writes=(self.tag,), name="assign")
        return self

    def copy(self) -> "NDArray":
        out = NDArray(engine=self.engine, name="copy")
        out.shape, out.dtype = self.shape, self.dtype
        self.engine.push(lambda: out._set(self._value),
                         reads=(self.tag,), writes=(out.tag,), name="copy")
        return out

    def __repr__(self):
        return f"<NDArray {self.shape} {self.dtype} tag={self.tag.name}>"


# -- constructors -----------------------------------------------------------

def array(v, engine=None, name="") -> NDArray:
    return NDArray(v, engine=engine, name=name)


def zeros(shape, dtype=jnp.float32, engine=None, name="zeros") -> NDArray:
    return NDArray(jnp.zeros(shape, dtype), engine=engine, name=name)


def ones(shape, dtype=jnp.float32, engine=None, name="ones") -> NDArray:
    return NDArray(jnp.ones(shape, dtype), engine=engine, name=name)


class RNG:
    """Seeded random source registered as an engine resource (§3.2: two
    generators with the same seed must not run in parallel — the seed is a
    write-tag)."""

    def __init__(self, seed: int, engine: Engine | None = None):
        self.engine = engine or default_engine()
        self.tag = Tag(f"rng{seed}")
        self._state = np.random.RandomState(seed)

    def normal(self, shape, scale=1.0, name="randn") -> NDArray:
        out = NDArray(engine=self.engine, name=name)
        out.shape, out.dtype = tuple(shape), jnp.float32

        def fn():
            out._set(jnp.asarray(
                self._state.standard_normal(shape).astype(np.float32) * scale))
        # the RNG state is WRITTEN: serializes draws for reproducibility
        self.engine.push(fn, reads=(), writes=(self.tag, out.tag), name=name)
        return out

    def uniform(self, shape, low=0.0, high=1.0, name="rand") -> NDArray:
        out = NDArray(engine=self.engine, name=name)
        out.shape, out.dtype = tuple(shape), jnp.float32

        def fn():
            out._set(jnp.asarray(
                self._state.uniform(low, high, shape).astype(np.float32)))
        self.engine.push(fn, reads=(), writes=(self.tag, out.tag), name=name)
        return out
