"""Operator registry (MXNet §2.1 "operators").

Each operator declares:
  * ``infer``      — output shapes from input shapes + attrs,
  * ``compute``    — the jnp implementation (jit-able; fused segments jit it),
  * ``grad``       — builds the *symbolic backward graph* (MXNet-style
                     auto-differentiation: gradients are graph nodes, not a
                     tape),
  * ``elementwise``— eligibility for operator grouping/fusion (§3.1),
  * ``inplace``    — (input_idx, output_idx) pairs whose buffers may be
                     shared by the *inplace* memory-plan heuristic (§3.1).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable

import jax.numpy as jnp
import jax

from .graph import Node, NodeRef

_REGISTRY: dict[str, "OpDef"] = {}


@dataclass
class OpDef:
    name: str
    infer: Callable
    compute: Callable  # (list_of_arrays, attrs) -> tuple of arrays
    grad: Callable | None = None  # (B, node, inputs, out_grads) -> list grads
    infer_dtype: Callable | None = None
    elementwise: bool = False
    inplace: tuple = ()
    num_outputs: int = 1
    flops: Callable | None = None  # (in_shapes, out_shapes, attrs) -> float


def register(**kw):
    op = OpDef(**kw)
    _REGISTRY[op.name] = op
    return op


def get(name: str) -> OpDef:
    if name not in _REGISTRY:
        raise KeyError(f"unknown operator {name!r}")
    return _REGISTRY[name]


def all_ops():
    return dict(_REGISTRY)


# ---------------------------------------------------------------------------
# Graph-builder helper used by gradient functions


class B:
    """Tiny builder: ``B.mul(x, y)`` appends a node and returns its NodeRef."""

    @staticmethod
    def _mk(op, ins, attrs=None, name=None, index=0):
        return NodeRef(Node(op, list(ins), attrs or {}, name), index)

    def __getattr__(self, op):
        def make(*ins, **attrs):
            name = attrs.pop("name", None)
            return self._mk(op, ins, attrs, name)
        return make


GB = B()


def add_n(refs):
    """Sum a list of gradient contributions (skipping None)."""
    refs = [r for r in refs if r is not None]
    if not refs:
        return None
    if len(refs) == 1:
        return refs[0]
    return GB.add_n(*refs)


# ---------------------------------------------------------------------------
# Shape helpers


def _same(in_shapes, attrs):
    return [in_shapes[0]]


def _broadcast_shape(a, b):
    return tuple(jnp.broadcast_shapes(tuple(a), tuple(b)))


def _binary_infer(in_shapes, attrs):
    return [_broadcast_shape(in_shapes[0], in_shapes[1])]


def _unbroadcast(B_, g, target_shape, src_shape):
    """Sum-reduce g (shape src) back to target_shape (reverse of broadcast)."""
    if tuple(target_shape) == tuple(src_shape):
        return g
    return GB.reduce_to(g, shape=tuple(target_shape))


# ---------------------------------------------------------------------------
# Elementwise binary ops

def _bin(name, fn, grad_fn):
    def compute(ins, attrs):
        return (fn(ins[0], ins[1]),)
    register(name=name, infer=_binary_infer, compute=compute, grad=grad_fn,
             elementwise=True, inplace=((0, 0), (1, 0)))


def _grad_add(Bx, node, in_shapes, og):
    g = og[0]
    return [_unbroadcast(Bx, g, in_shapes[0], _broadcast_shape(*in_shapes[:2])),
            _unbroadcast(Bx, g, in_shapes[1], _broadcast_shape(*in_shapes[:2]))]


def _grad_sub(Bx, node, in_shapes, og):
    g = og[0]
    bs = _broadcast_shape(*in_shapes[:2])
    return [_unbroadcast(Bx, g, in_shapes[0], bs),
            _unbroadcast(Bx, GB.neg(g), in_shapes[1], bs)]


def _grad_mul(Bx, node, in_shapes, og):
    g = og[0]
    x, y = node.inputs
    bs = _broadcast_shape(*in_shapes[:2])
    return [_unbroadcast(Bx, GB.mul(g, y), in_shapes[0], bs),
            _unbroadcast(Bx, GB.mul(g, x), in_shapes[1], bs)]


def _grad_div(Bx, node, in_shapes, og):
    g = og[0]
    x, y = node.inputs
    bs = _broadcast_shape(*in_shapes[:2])
    gx = GB.div(g, y)
    gy = GB.neg(GB.div(GB.mul(g, x), GB.mul(y, y)))
    return [_unbroadcast(Bx, gx, in_shapes[0], bs),
            _unbroadcast(Bx, gy, in_shapes[1], bs)]


_bin("add", lambda a, b: a + b, _grad_add)
_bin("sub", lambda a, b: a - b, _grad_sub)
_bin("mul", lambda a, b: a * b, _grad_mul)
_bin("div", lambda a, b: a / b, _grad_div)
_bin("maximum", lambda a, b: jnp.maximum(a, b),
     lambda Bx, node, in_shapes, og: [
         GB.mul(og[0], GB.greater_equal(node.inputs[0], node.inputs[1])),
         GB.mul(og[0], GB.greater_equal(node.inputs[1], node.inputs[0]))])

register(name="greater_equal", infer=_binary_infer,
         compute=lambda ins, attrs: ((ins[0] >= ins[1]).astype(ins[0].dtype),),
         grad=lambda Bx, node, in_shapes, og: [None, None], elementwise=True)


# ---------------------------------------------------------------------------
# Elementwise unary ops

def _un(name, fn, grad_fn, inplace=((0, 0),)):
    register(name=name, infer=_same,
             compute=lambda ins, attrs, fn=fn: (fn(ins[0]),),
             grad=grad_fn, elementwise=True, inplace=inplace)


_un("neg", lambda a: -a, lambda Bx, n, s, og: [GB.neg(og[0])])
_un("exp", jnp.exp, lambda Bx, n, s, og: [GB.mul(og[0], GB.exp(n.inputs[0]))])
_un("log", jnp.log, lambda Bx, n, s, og: [GB.div(og[0], n.inputs[0])])
_un("sqrt", jnp.sqrt,
    lambda Bx, n, s, og: [GB.div(og[0], GB.scale(GB.sqrt(n.inputs[0]), alpha=2.0))])
_un("tanh", jnp.tanh,
    lambda Bx, n, s, og: [GB.mul(og[0], GB.sub(GB.ones_like(n.inputs[0]),
                                               GB.mul(GB.tanh(n.inputs[0]),
                                                      GB.tanh(n.inputs[0]))))])
_un("relu", lambda a: jnp.maximum(a, 0),
    lambda Bx, n, s, og: [GB.mul(og[0], GB.greater_equal(
        n.inputs[0], GB.zeros_like(n.inputs[0])))])
_un("sigmoid", jax.nn.sigmoid,
    lambda Bx, n, s, og: [GB.mul(og[0], GB.mul(GB.sigmoid(n.inputs[0]),
                                               GB.sub(GB.ones_like(n.inputs[0]),
                                                      GB.sigmoid(n.inputs[0]))))])
_un("ones_like", jnp.ones_like, lambda Bx, n, s, og: [None])
_un("zeros_like", jnp.zeros_like, lambda Bx, n, s, og: [None])
_un("copy", lambda a: a, lambda Bx, n, s, og: [og[0]])
_un("stop_gradient", jax.lax.stop_gradient, lambda Bx, n, s, og: [None])


def _scale_compute(ins, attrs):
    return (ins[0] * attrs.get("alpha", 1.0) + attrs.get("beta", 0.0),)


register(name="scale", infer=_same, compute=_scale_compute,
         grad=lambda Bx, n, s, og: [GB.scale(og[0], alpha=n.attrs.get("alpha", 1.0))],
         elementwise=True, inplace=((0, 0),))

# Fused a*b+beta — the paper's "a × b + 1 is replaced by a single call" example.
register(name="fma_const", infer=_binary_infer,
         compute=lambda ins, attrs: (ins[0] * ins[1] + attrs.get("beta", 0.0),),
         grad=_grad_mul, elementwise=True, inplace=((0, 0), (1, 0)))


# ---------------------------------------------------------------------------
# add_n (gradient accumulation)

register(
    name="add_n",
    infer=lambda in_shapes, attrs: [in_shapes[0]],
    compute=lambda ins, attrs: (sum(ins[1:], start=ins[0]),),
    grad=lambda Bx, n, s, og: [og[0]] * len(n.inputs),
    elementwise=True, inplace=((0, 0),),
)


# ---------------------------------------------------------------------------
# Structural ops

def _reshape_infer(in_shapes, attrs):
    shape = list(attrs["shape"])
    n = math.prod(in_shapes[0])
    if -1 in shape:
        i = shape.index(-1)
        rest = math.prod(s for s in shape if s != -1)
        shape[i] = n // rest
    assert math.prod(shape) == n, (in_shapes, shape)
    return [tuple(shape)]


register(name="reshape", infer=_reshape_infer,
         compute=lambda ins, attrs: (jnp.reshape(ins[0], attrs["shape"]),),
         grad=lambda Bx, n, s, og: [GB.reshape(og[0], shape=tuple(s[0]))],
         inplace=((0, 0),))

def _grad_transpose(Bx, n, s, og):
    axes = n.attrs.get("axes")
    if axes is None:
        return [GB.transpose(og[0])]
    inv = [0] * len(axes)
    for i, a in enumerate(axes):
        inv[a] = i
    return [GB.transpose(og[0], axes=tuple(inv))]


register(name="transpose",
         infer=lambda in_shapes, attrs: [tuple(in_shapes[0][i]
                                               for i in (attrs.get("axes") or
                                               range(len(in_shapes[0]) - 1, -1, -1)))],
         compute=lambda ins, attrs: (jnp.transpose(ins[0], attrs.get("axes")),),
         grad=_grad_transpose)


def _bcast_infer(in_shapes, attrs):
    return [tuple(attrs["shape"])]


register(name="broadcast_to", infer=_bcast_infer,
         compute=lambda ins, attrs: (jnp.broadcast_to(ins[0], attrs["shape"]),),
         grad=lambda Bx, n, s, og: [GB.reduce_to(og[0], shape=tuple(s[0]))])


def _reduce_to_compute(ins, attrs):
    x = ins[0]
    target = tuple(attrs["shape"])
    # sum-reduce broadcasted dims back
    while x.ndim > len(target):
        x = x.sum(axis=0)
    for ax, (t, s) in enumerate(zip(target, x.shape)):
        if t != s:
            x = x.sum(axis=ax, keepdims=True)
    return (jnp.reshape(x, target),)


register(name="reduce_to", infer=_bcast_infer, compute=_reduce_to_compute,
         grad=lambda Bx, n, s, og: [GB.broadcast_to(og[0], shape=tuple(s[0]))])


def _reduce_infer(in_shapes, attrs):
    axes = attrs.get("axis")
    sh = list(in_shapes[0])
    if axes is None:
        return [()] if not attrs.get("keepdims") else [tuple(1 for _ in sh)]
    axes = (axes,) if isinstance(axes, int) else tuple(axes)
    if attrs.get("keepdims"):
        return [tuple(1 if i in axes else d for i, d in enumerate(sh))]
    return [tuple(d for i, d in enumerate(sh) if i not in axes)]


def _grad_reduce_sum(Bx, node, in_shapes, og):
    return [GB.broadcast_like_sum(og[0], shape=tuple(in_shapes[0]),
                                  axis=node.attrs.get("axis"),
                                  keepdims=node.attrs.get("keepdims", False))]


register(name="reduce_sum", infer=_reduce_infer,
         compute=lambda ins, attrs: (jnp.sum(ins[0], axis=attrs.get("axis"),
                                             keepdims=attrs.get("keepdims", False)),),
         grad=_grad_reduce_sum)


def _blsum_compute(ins, attrs):
    g = ins[0]
    shape = tuple(attrs["shape"])
    axis, keepdims = attrs.get("axis"), attrs.get("keepdims", False)
    if axis is not None and not keepdims:
        axes = (axis,) if isinstance(axis, int) else tuple(axis)
        for ax in sorted(axes):
            g = jnp.expand_dims(g, ax)
    elif axis is None and not keepdims:
        g = jnp.reshape(g, (1,) * len(shape))
    return (jnp.broadcast_to(g, shape),)


register(name="broadcast_like_sum", infer=_bcast_infer, compute=_blsum_compute,
         grad=lambda Bx, n, s, og: [GB.reduce_sum(og[0], axis=n.attrs.get("axis"),
                                                  keepdims=n.attrs.get("keepdims", False))])


def _grad_reduce_mean(Bx, node, in_shapes, og):
    axes = node.attrs.get("axis")
    sh = in_shapes[0]
    if axes is None:
        cnt = math.prod(sh)
    else:
        axes = (axes,) if isinstance(axes, int) else tuple(axes)
        cnt = math.prod(sh[i] for i in axes)
    g = GB.scale(og[0], alpha=1.0 / cnt)
    return [GB.broadcast_like_sum(g, shape=tuple(sh), axis=node.attrs.get("axis"),
                                  keepdims=node.attrs.get("keepdims", False))]


register(name="reduce_mean", infer=_reduce_infer,
         compute=lambda ins, attrs: (jnp.mean(ins[0], axis=attrs.get("axis"),
                                              keepdims=attrs.get("keepdims", False)),),
         grad=_grad_reduce_mean)


# ---------------------------------------------------------------------------
# Linear algebra ("big" BLAS ops)

def _matmul_infer(in_shapes, attrs):
    a, b = in_shapes
    assert len(a) == 2 and len(b) == 2 and a[1] == b[0], (a, b)
    return [(a[0], b[1])]


def _grad_matmul(Bx, node, in_shapes, og):
    a, b = node.inputs
    g = og[0]
    return [GB.matmul(g, GB.transpose(b)), GB.matmul(GB.transpose(a), g)]


register(name="matmul", infer=_matmul_infer,
         compute=lambda ins, attrs: (ins[0] @ ins[1],),
         grad=_grad_matmul,
         flops=lambda i, o, a: 2.0 * i[0][0] * i[0][1] * i[1][1])


def _fc_infer(in_shapes, attrs):
    x, w = in_shapes[0], in_shapes[1]
    assert w[1] == x[-1], (x, w)
    return [tuple(x[:-1]) + (w[0],)]


def _fc_compute(ins, attrs):
    x, w = ins[0], ins[1]
    y = x @ w.T
    if len(ins) > 2:
        y = y + ins[2]
    return (y,)


def _grad_fc(Bx, node, in_shapes, og):
    x, w = node.inputs[0], node.inputs[1]
    g = og[0]
    gx = GB.matmul(g, w)
    gw = GB.matmul(GB.transpose(g), x)
    grads = [gx, gw]
    if len(node.inputs) > 2:
        grads.append(GB.reduce_sum(g, axis=0))
    return grads


register(name="fully_connected", infer=_fc_infer, compute=_fc_compute,
         grad=_grad_fc,
         flops=lambda i, o, a: 2.0 * math.prod(i[0][:-1]) * i[0][-1] * i[1][0])


# ---------------------------------------------------------------------------
# Softmax family

def _softmax_compute(ins, attrs):
    return (jax.nn.softmax(ins[0], axis=-1),)


def _grad_softmax(Bx, node, in_shapes, og):
    # dx = p * (g - sum(g * p, -1, keepdims))
    p = GB.softmax(node.inputs[0])
    gp = GB.mul(og[0], p)
    s = GB.reduce_sum(gp, axis=-1 % len(in_shapes[0]), keepdims=True)
    return [GB.mul(p, GB.sub(og[0], GB.broadcast_to(s, shape=tuple(in_shapes[0]))))]


register(name="softmax", infer=_same, compute=_softmax_compute, grad=_grad_softmax)

register(name="log_softmax", infer=_same,
         compute=lambda ins, attrs: (jax.nn.log_softmax(ins[0], axis=-1),),
         grad=lambda Bx, n, s, og: [GB.sub(og[0], GB.mul(
             GB.softmax(n.inputs[0]),
             GB.broadcast_to(GB.reduce_sum(og[0], axis=len(s[0]) - 1, keepdims=True),
                             shape=tuple(s[0]))))])


def _sxent_infer(in_shapes, attrs):
    logits, labels = in_shapes
    assert len(logits) == 2 and labels == (logits[0],), (logits, labels)
    return [(), logits]  # (mean loss, softmax probs)


def _sxent_compute(ins, attrs):
    logits, labels = ins
    lp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(lp, labels[:, None].astype(jnp.int32), axis=-1)
    return (jnp.mean(nll), jax.nn.softmax(logits, axis=-1))


def _grad_sxent(Bx, node, in_shapes, og):
    # MXNet SoftmaxOutput semantics: the loss layer defines its own gradient
    # (p - onehot)/B, scaled by the incoming loss grad.
    logits, labels = node.inputs
    B_ = in_shapes[0][0]
    g = GB.softmax_xent_backward(logits, labels, name=None)
    g = GB.scale(g, alpha=1.0 / B_)
    if og[0] is not None:
        g = GB.mul(g, GB.broadcast_to(
            GB.reshape(og[0], shape=(1, 1)), shape=tuple(in_shapes[0])))
    return [g, None]


register(name="softmax_xent", infer=_sxent_infer, compute=_sxent_compute,
         grad=_grad_sxent, num_outputs=2)

register(name="softmax_xent_backward",
         infer=lambda in_shapes, attrs: [in_shapes[0]],
         compute=lambda ins, attrs: (
             jax.nn.softmax(ins[0], -1)
             - jax.nn.one_hot(ins[1].astype(jnp.int32), ins[0].shape[-1],
                              dtype=ins[0].dtype),),
         grad=None)


# ---------------------------------------------------------------------------
# Norm layers (as "big ops", §3.1 "manually implemented well-optimized ops")

def _layernorm_compute(ins, attrs):
    x, gamma, beta = ins
    eps = attrs.get("eps", 1e-5)
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return ((x - mu) / jnp.sqrt(var + eps) * gamma + beta,)


def _grad_layernorm(Bx, node, in_shapes, og):
    # Fallback: express via primitive graph ops (numerically matches compute).
    x, gamma, beta = node.inputs
    sh = tuple(in_shapes[0])
    d = sh[-1]
    eps = node.attrs.get("eps", 1e-5)
    mu = GB.reduce_mean(x, axis=len(sh) - 1, keepdims=True)
    mu_b = GB.broadcast_to(mu, shape=sh)
    xc = GB.sub(x, mu_b)
    var = GB.reduce_mean(GB.mul(xc, xc), axis=len(sh) - 1, keepdims=True)
    rstd = GB.div(GB.ones_like(var), GB.sqrt(GB.scale(var, beta=eps)))
    rstd_b = GB.broadcast_to(rstd, shape=sh)
    xhat = GB.mul(xc, rstd_b)
    g = og[0]
    gamma_b = GB.broadcast_to(GB.reshape(gamma, shape=(1,) * (len(sh) - 1) + (d,)),
                              shape=sh)
    gxhat = GB.mul(g, gamma_b)
    m1 = GB.broadcast_to(GB.reduce_mean(gxhat, axis=len(sh) - 1, keepdims=True),
                         shape=sh)
    m2 = GB.broadcast_to(GB.reduce_mean(GB.mul(gxhat, xhat), axis=len(sh) - 1,
                                        keepdims=True), shape=sh)
    gx = GB.mul(rstd_b, GB.sub(GB.sub(gxhat, m1), GB.mul(xhat, m2)))
    red_axes = tuple(range(len(sh) - 1))
    ggamma = GB.reduce_sum(GB.mul(g, xhat), axis=red_axes)
    gbeta = GB.reduce_sum(g, axis=red_axes)
    return [gx, ggamma, gbeta]


register(name="layernorm",
         infer=lambda in_shapes, attrs: [in_shapes[0]],
         compute=_layernorm_compute, grad=_grad_layernorm)
