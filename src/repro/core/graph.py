"""Computation-graph IR — the substrate under Symbol (MXNet §3.1).

A :class:`Node` applies a registered operator to the outputs of other nodes.
Shape/dtype inference is deferred (as in MXNet) until bind time, when the
free variables' shapes are known.
"""
from __future__ import annotations

import itertools
from typing import Any, Iterable, NamedTuple

_node_counter = itertools.count()


class NodeRef(NamedTuple):
    """Reference to one output of a node (operators can be multi-output)."""

    node: "Node"
    index: int = 0


class Node:
    __slots__ = ("uid", "op", "name", "inputs", "attrs")

    def __init__(self, op: str, inputs: list[NodeRef], attrs: dict | None = None,
                 name: str | None = None):
        self.uid = next(_node_counter)
        self.op = op
        self.inputs = list(inputs)
        self.attrs = dict(attrs or {})
        self.name = name or f"{op}{self.uid}"

    def __repr__(self):
        ins = ",".join(f"{r.node.name}[{r.index}]" for r in self.inputs)
        return f"<Node {self.name}:{self.op}({ins})>"


def topo_sort(outputs: Iterable[NodeRef]) -> list[Node]:
    """Deterministic post-order topological sort of the ancestor set."""
    order: list[Node] = []
    state: dict[int, int] = {}  # uid -> 0 visiting, 1 done
    stack: list[tuple[Node, bool]] = [(r.node, False) for r in outputs][::-1]
    seen_push = set()
    while stack:
        node, processed = stack.pop()
        if processed:
            if state.get(node.uid) != 1:
                state[node.uid] = 1
                order.append(node)
            continue
        if node.uid in state:
            continue
        if node.uid in seen_push:
            # children done
            state[node.uid] = 1
            order.append(node)
            continue
        seen_push.add(node.uid)
        stack.append((node, True))
        for ref in reversed(node.inputs):
            if ref.node.uid not in state:
                stack.append((ref.node, False))
    return order


class Graph:
    """A bound set of outputs plus the topologically-sorted ancestor closure."""

    def __init__(self, outputs: list[NodeRef]):
        self.outputs = list(outputs)
        self.nodes = topo_sort(self.outputs)

    @property
    def variables(self) -> list[Node]:
        return [n for n in self.nodes if n.op == "var"]

    def consumers(self) -> dict[int, list[tuple[Node, int]]]:
        """uid -> list of (consumer node, which input slot)."""
        out: dict[int, list[tuple[Node, int]]] = {n.uid: [] for n in self.nodes}
        for n in self.nodes:
            for slot, ref in enumerate(n.inputs):
                out[ref.node.uid].append((n, slot))
        return out

    def __len__(self):
        return len(self.nodes)


# ---------------------------------------------------------------------------
# Shape & dtype inference


def infer_shapes(graph: Graph, var_shapes: dict[str, tuple[int, ...]],
                 var_dtypes: dict[str, Any] | None = None):
    """Propagate shapes/dtypes through the graph.

    Returns (shapes, dtypes): dict uid -> tuple-of-shapes / tuple-of-dtypes,
    one entry per node output.
    """
    from . import ops as _ops  # late import: registry

    var_dtypes = var_dtypes or {}
    shapes: dict[int, tuple] = {}
    dtypes: dict[int, tuple] = {}
    for node in graph.nodes:
        if node.op == "var":
            if node.name not in var_shapes:
                raise ValueError(f"missing shape for free variable {node.name!r}")
            shapes[node.uid] = (tuple(var_shapes[node.name]),)
            dtypes[node.uid] = (var_dtypes.get(node.name, "float32"),)
            continue
        opdef = _ops.get(node.op)
        in_shapes = [shapes[r.node.uid][r.index] for r in node.inputs]
        in_dtypes = [dtypes[r.node.uid][r.index] for r in node.inputs]
        out_sh = opdef.infer(in_shapes, node.attrs)
        shapes[node.uid] = tuple(tuple(s) for s in out_sh)
        if opdef.infer_dtype is not None:
            dtypes[node.uid] = tuple(opdef.infer_dtype(in_dtypes, node.attrs))
        else:
            dtypes[node.uid] = tuple(in_dtypes[0] if in_dtypes else "float32"
                                     for _ in out_sh)
    return shapes, dtypes
