"""Executor — binds a Symbol and evaluates it through the engine (MXNet §3.1).

Bind-time pipeline (mirrors the paper):
  1. prune to the requested outputs (prediction skips backward, etc.);
  2. pattern fusion (operator grouping) + elementwise segment fusion, each
     fused segment compiled as ONE jitted call (the "big op" path);
  3. shape inference;
  4. memory planning (inplace / co-share) — buffer ids map to engine Tags so
     buffer reuse is serialized by write-dependencies exactly as §3.2
     describes ("easier memory reuse ... by representing updates as
     mutations");
  5. forward()/backward() push the scheduled ops into the dependency engine
     lazily; results are NDArrays that force on read.

A strict "poison" check validates the memory plan at runtime: every read
asserts the buffer still holds the value planned for it.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax

from . import ops as _ops
from .autodiff import gradient_with_shapes
from .engine import Engine, Tag, default_engine
from .graph import NodeRef, infer_shapes
from .memplan import Unit, naive_bytes, nbytes, plan_schedule
from .ndarray import NDArray
from .optimize import optimize_graph, fuse_elementwise
from .symbol import Symbol


class Executor:
    def __init__(self, sym: Symbol, args: dict, grad_wrt: Sequence[str] = (),
                 optimize: bool = True, memplan: str = "both",
                 engine: Engine | None = None, jit_segments: bool = True,
                 check_plan: bool = True, compile_whole: bool = False):
        self.engine = engine or default_engine()
        self.sym = sym
        self.grad_wrt = list(grad_wrt)
        self.jit_segments = jit_segments
        self.check_plan = check_plan and not compile_whole
        # compile_whole: the planned forward (and backward) schedules each
        # become ONE jitted XLA program — the CPU/XLA analogue of executing
        # MXNet's planned graph with compiled kernels.  The engine still
        # schedules the two composites + imperative ops jointly.
        self.compile_whole = compile_whole

        # normalize args to NDArray
        self.args: dict[str, NDArray] = {}
        for k, v in args.items():
            self.args[k] = v if isinstance(v, NDArray) else NDArray(v, engine=self.engine,
                                                                    name=k)
        var_shapes = {k: tuple(v.shape) for k, v in self.args.items()}
        var_dtypes = {k: str(v.dtype) for k, v in self.args.items()}

        # ---- joint forward(+backward) graph
        self.n_fwd_outputs = len(sym._outputs)
        heads = list(sym._outputs)
        if self.grad_wrt:
            gsym = gradient_with_shapes(sym, self.grad_wrt, var_shapes)
            heads = heads + list(gsym._outputs)

        g = optimize_graph(heads, enable_pattern=optimize)
        self.graph = g
        self.shapes, self.dtypes = infer_shapes(g, var_shapes, var_dtypes)

        # ---- fusion
        if optimize:
            self.segments, self.node2seg = fuse_elementwise(g)
        else:
            self.segments, self.node2seg = {}, {}

        # ---- split schedule into forward / backward portions (before
        # planning: memory is planned over the ACTUAL unit schedule, so
        # deferred fused segments keep their inputs alive)
        fwd_needed = set()
        stack = [r.node for r in g.outputs[:self.n_fwd_outputs]]
        while stack:
            n = stack.pop()
            if n.uid in fwd_needed:
                continue
            fwd_needed.add(n.uid)
            stack.extend(r.node for r in n.inputs)
        self._fwd_sched, self._bwd_sched = self._build_schedule(fwd_needed)

        # ---- memory plan (buffer accounting + reuse constraints)
        units, ext = self._schedule_units(g)
        self.plan = plan_schedule(units, ext, strategy=memplan)
        self.naive_bytes = naive_bytes(g, self.shapes, self.dtypes)

        # engine tags: one per buffer (internal) / per arg or output
        self._buffer_tags: dict[int, Tag] = {}
        self._key_tag: dict[tuple[int, int], Tag] = {}
        out_keys = [(r.node.uid, r.index) for r in g.outputs]
        self._out_keys = out_keys
        var_nodes = {n.name: n for n in g.variables}
        self.var_nodes = var_nodes

        for key, bid in self.plan.assignment.items():
            if bid >= 0:
                self._buffer_tags.setdefault(bid, Tag(f"buf{bid}"))
                self._key_tag[key] = self._buffer_tags[bid]
            else:
                self._key_tag[key] = Tag(f"ext{key[0]}_{key[1]}")
        for name, n in var_nodes.items():
            if name in self.args:
                self._key_tag[(n.uid, 0)] = self.args[name].tag

        # ---- runtime value env + plan validation state
        self._env: dict[tuple[int, int], Any] = {}
        self._buffer_owner: dict[int, tuple[int, int]] = {}

        # output handles
        self.outputs: list[NDArray] = []
        for i, r in enumerate(g.outputs[:self.n_fwd_outputs]):
            h = NDArray(engine=self.engine, name=f"out{i}")
            h.shape = self.shapes[r.node.uid][r.index]
            h.dtype = self.dtypes[r.node.uid][r.index]
            self.outputs.append(h)
        self.grad_arrays: dict[str, NDArray] = {}
        for name, r in zip(self.grad_wrt, g.outputs[self.n_fwd_outputs:]):
            h = NDArray(engine=self.engine, name=f"grad_{name}")
            h.shape = self.shapes[r.node.uid][r.index]
            h.dtype = self.dtypes[r.node.uid][r.index]
            self.grad_arrays[name] = h

        self._jit_cache: dict[int, Any] = {}

    # ------------------------------------------------------------------
    def _schedule_units(self, g):
        """Execution units (in actual run order) for memory planning."""
        external = {(n.uid, 0) for n in g.variables}
        external |= {(r.node.uid, r.index) for r in g.outputs}
        units = []
        for kind, payload in list(self._fwd_sched) + list(self._bwd_sched):
            if kind == "node":
                node = payload
                opdef = _ops.get(node.op)
                in_keys = [(r.node.uid, r.index) for r in node.inputs]
                out_keys = [(node.uid, j) for j in range(opdef.num_outputs)]
                out_sizes = [nbytes(sh, dt) for sh, dt in
                             zip(self.shapes[node.uid], self.dtypes[node.uid])]
                units.append(Unit(node.uid, in_keys, out_keys, out_sizes,
                                  inplace=opdef.inplace))
            else:
                seg = self.segments[payload]
                in_keys = [(r.node.uid, r.index) for r in seg.ext_inputs]
                out_keys = [(r.node.uid, r.index) for r in seg.ext_outputs]
                out_sizes = [nbytes(self.shapes[r.node.uid][r.index],
                                    self.dtypes[r.node.uid][r.index])
                             for r in seg.ext_outputs]
                # elementwise segments: any dying input may host any
                # size-matching output (atomic unit => safe)
                inplace = tuple((i, j) for j in range(len(out_keys))
                                for i in range(len(in_keys)))
                units.append(Unit(seg.nodes[-1].uid, in_keys, out_keys,
                                  out_sizes, inplace=inplace))
        return units, external

    # ------------------------------------------------------------------
    def _build_schedule(self, fwd_needed: set[int]):
        """Units = fused segments (emitted at last member) or single nodes."""
        fwd, bwd = [], []
        emitted_segs = set()
        seg_last = {}
        for n in self.graph.nodes:
            sid = self.node2seg.get(n.uid)
            if sid is not None:
                seg_last[sid] = n.uid
        for n in self.graph.nodes:
            if n.op == "var":
                continue
            sid = self.node2seg.get(n.uid)
            if sid is not None:
                if seg_last[sid] != n.uid or sid in emitted_segs:
                    continue
                emitted_segs.add(sid)
                unit = ("seg", sid)
                is_fwd = all(m.uid in fwd_needed for m in self.segments[sid].nodes)
            else:
                unit = ("node", n)
                is_fwd = n.uid in fwd_needed
            (fwd if is_fwd else bwd).append(unit)
        return fwd, bwd

    # ------------------------------------------------------------------
    def _read(self, key):
        if self.check_plan:
            bid = self.plan.assignment.get(key)
            if bid is not None and bid >= 0:
                owner = self._buffer_owner.get(bid)
                assert owner == key, (
                    f"memory-plan violation: buffer {bid} holds {owner}, "
                    f"read wanted {key}")
        return self._env[key]

    def _write(self, key, value):
        self._env[key] = value
        if self.check_plan:
            bid = self.plan.assignment.get(key)
            if bid is not None and bid >= 0:
                self._buffer_owner[bid] = key

    def _push_unit(self, unit):
        kind, payload = unit
        if kind == "node":
            node = payload
            opdef = _ops.get(node.op)
            in_keys = [(r.node.uid, r.index) for r in node.inputs]
            out_keys = [(node.uid, j) for j in range(opdef.num_outputs)]
            read_tags = [self._tag_for_input(r) for r in node.inputs]
            write_tags = [self._key_tag[k] for k in out_keys]

            def fn(node=node, opdef=opdef, in_keys=in_keys, out_keys=out_keys):
                ins = [self._fetch(r, k) for r, k in zip(node.inputs, in_keys)]
                outs = opdef.compute(ins, node.attrs)
                for k, v in zip(out_keys, outs):
                    self._write(k, v)
            self.engine.push(fn, reads=read_tags, writes=write_tags, name=node.op)
        else:
            seg = self.segments[payload]
            run = self._jit_for(payload, seg)
            in_refs = seg.ext_inputs
            in_keys = [(r.node.uid, r.index) for r in in_refs]
            out_keys = [(r.node.uid, r.index) for r in seg.ext_outputs]
            read_tags = [self._tag_for_input(r) for r in in_refs]
            write_tags = [self._key_tag[k] for k in out_keys]

            def fn(run=run, in_refs=in_refs, in_keys=in_keys, out_keys=out_keys):
                ins = [self._fetch(r, k) for r, k in zip(in_refs, in_keys)]
                outs = run(*ins)
                for k, v in zip(out_keys, outs):
                    self._write(k, v)
            self.engine.push(fn, reads=read_tags, writes=write_tags,
                             name=f"fused{payload}x{len(seg.nodes)}")

    def _tag_for_input(self, ref: NodeRef) -> Tag:
        key = (ref.node.uid, ref.index)
        return self._key_tag[key]

    def _fetch(self, ref: NodeRef, key):
        node = ref.node
        if node.op == "var":
            return self.args[node.name]._value
        return self._read(key)

    def _jit_for(self, sid, seg):
        if sid not in self._jit_cache:
            fn = seg.make_callable()
            self._jit_cache[sid] = jax.jit(fn) if self.jit_segments else fn
        return self._jit_cache[sid]

    # ------------------------------------------------------------------
    # whole-graph compilation

    def _unit_apply(self, unit, env, var_vals):
        """Execute one schedule unit on a (traced) value dict."""
        kind, payload = unit
        if kind == "node":
            node = payload
            opdef = _ops.get(node.op)
            ins = [var_vals[r.node.name] if r.node.op == "var"
                   else env[(r.node.uid, r.index)] for r in node.inputs]
            outs = opdef.compute(ins, node.attrs)
            for j, v in enumerate(outs):
                env[(node.uid, j)] = v
        else:
            seg = self.segments[payload]
            run = seg.make_callable()
            ins = [var_vals[r.node.name] if r.node.op == "var"
                   else env[(r.node.uid, r.index)] for r in seg.ext_inputs]
            outs = run(*ins)
            for r, v in zip(seg.ext_outputs, outs):
                env[(r.node.uid, r.index)] = v

    def _whole_fns(self):
        if hasattr(self, "_whole_cache"):
            return self._whole_cache
        # boundary: fwd-produced keys read by the backward schedule or
        # published as outputs
        bwd_reads = set()
        for kind, payload in self._bwd_sched:
            refs = (payload.inputs if kind == "node"
                    else self.segments[payload].ext_inputs)
            for r in refs:
                if r.node.op != "var":
                    bwd_reads.add((r.node.uid, r.index))
        fwd_writes = set()
        for kind, payload in self._fwd_sched:
            if kind == "node":
                n_out = _ops.get(payload.op).num_outputs
                fwd_writes |= {(payload.uid, j) for j in range(n_out)}
            else:
                fwd_writes |= {(r.node.uid, r.index)
                               for r in self.segments[payload].ext_outputs}
        out_keys = list(self._out_keys[:self.n_fwd_outputs])
        exports = sorted((bwd_reads & fwd_writes)
                         | {k for k in out_keys if k in fwd_writes})

        fwd_sched, bwd_sched = self._fwd_sched, self._bwd_sched
        node_map = {n.uid: n for n in self.graph.nodes}

        def fwd_fn(var_vals):
            env = {}
            for unit in fwd_sched:
                self._unit_apply(unit, env, var_vals)
            outs = []
            for key in out_keys:
                n = node_map[key[0]]
                outs.append(var_vals[n.name] if n.op == "var" else env[key])
            return tuple(outs), {f"{k[0]}_{k[1]}": env[k] for k in exports}

        grad_keys = list(self._out_keys[self.n_fwd_outputs:])

        def bwd_fn(var_vals, saved):
            env = {(int(s.split("_")[0]), int(s.split("_")[1])): v
                   for s, v in saved.items()}
            for unit in bwd_sched:
                self._unit_apply(unit, env, var_vals)
            return tuple(env[k] if k[0] in node_map
                         and node_map[k[0]].op != "var"
                         else var_vals[node_map[k[0]].name]
                         for k in grad_keys)

        self._whole_cache = (jax.jit(fwd_fn), jax.jit(bwd_fn))
        return self._whole_cache

    def _forward_whole(self, lazy):
        fwd_fn, _ = self._whole_fns()

        def run():
            var_vals = {k: a._value for k, a in self.args.items()}
            outs, saved = fwd_fn(var_vals)
            self._saved = saved
            for h, v in zip(self.outputs, outs):
                h._set(v)
        self.engine.push(
            run, reads=[a.tag for a in self.args.values()],
            writes=[h.tag for h in self.outputs], name="fwd_graph")
        if lazy:
            return self.outputs
        return [o.value for o in self.outputs]

    def _backward_whole(self, lazy):
        _, bwd_fn = self._whole_fns()

        def run():
            var_vals = {k: a._value for k, a in self.args.items()}
            grads = bwd_fn(var_vals, self._saved)
            for name, g in zip(self.grad_wrt, grads):
                self.grad_arrays[name]._set(g)
        self.engine.push(
            run, reads=[a.tag for a in self.args.values()],
            writes=[self.grad_arrays[n].tag for n in self.grad_wrt],
            name="bwd_graph")
        if lazy:
            return self.grad_arrays
        return {k: v.value for k, v in self.grad_arrays.items()}

    # ------------------------------------------------------------------
    def forward(self, lazy: bool = False, **new_args):
        for k, v in new_args.items():
            self.args[k].assign(v)
        if self.compile_whole:
            return self._forward_whole(lazy)
        for unit in self._fwd_sched:
            self._push_unit(unit)
        # publish outputs as NDArray handles
        for h, key in zip(self.outputs, self._out_keys[:self.n_fwd_outputs]):
            self.engine.push(lambda h=h, key=key: h._set(self._read_pub(key)),
                             reads=(self._key_tag[key],), writes=(h.tag,),
                             name="publish")
        if lazy:
            return self.outputs
        return [o.value for o in self.outputs]

    def _read_pub(self, key):
        node_map = {n.uid: n for n in self.graph.nodes}
        n = node_map[key[0]]
        if n.op == "var":
            return self.args[n.name]._value
        return self._read(key)

    def backward(self, lazy: bool = False):
        assert self.grad_wrt, "bind with grad_wrt to use backward()"
        if self.compile_whole:
            return self._backward_whole(lazy)
        for unit in self._bwd_sched:
            self._push_unit(unit)
        for name, key in zip(self.grad_wrt,
                             self._out_keys[self.n_fwd_outputs:]):
            h = self.grad_arrays[name]
            self.engine.push(lambda h=h, key=key: h._set(self._read_pub(key)),
                             reads=(self._key_tag[key],), writes=(h.tag,),
                             name=f"publish_grad")
        if lazy:
            return self.grad_arrays
        return {k: v.value for k, v in self.grad_arrays.items()}

    def forward_backward(self, lazy: bool = True, **new_args):
        outs = self.forward(lazy=True, **new_args)
        grads = self.backward(lazy=True)
        if lazy:
            return outs, grads
        return [o.value for o in outs], {k: v.value for k, v in grads.items()}

    # ------------------------------------------------------------------
    def memory_stats(self) -> dict:
        s = self.plan.stats()
        s["naive_bytes"] = self.naive_bytes
        s["reduction"] = (self.naive_bytes / s["internal_bytes"]
                          if s["internal_bytes"] else float("inf"))
        return s
