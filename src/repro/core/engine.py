"""Dependency engine (MXNet §3.2).

Every *source unit* — an NDArray buffer, a random number generator, a
temporal workspace — is registered with a unique :class:`Tag`.  Operations
(compute, communication, parameter updates) are pushed with the tags they
*read* and the tags they *write* (mutate).  The engine resolves the implied
DAG and schedules operations whose dependencies are satisfied.

Differences from classic dataflow engines, reproduced here:
  * mutation is first-class — write-tags serialize writers against both the
    previous writer (WAW) and all readers since (WAR), enabling numpy-style
    array mutation, in-place parameter updates and seeded-RNG reproducibility;
  * computation, KVStore communication and imperative NDArray ops all flow
    through the same queue, so they are *jointly* scheduled (§2.3's claim
    that the mixed program matches a single declarative program).

On a single-process CPU container the "multiple threads" of the paper
become *waves*: each scheduling round executes every ready op; ops within a
wave are independent by construction (the measured wave widths are the
engine's discovered parallelism — reported by ``bench_engine``).  Execution
is lazy: pushes return immediately; ``wait``/``wait_all`` flush.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.obs import get_metrics, get_recorder


class Tag:
    """A schedulable resource (array buffer, RNG, workspace)."""

    _ids = itertools.count()

    def __init__(self, name: str = ""):
        self.tid = next(self._ids)
        self.name = name or f"tag{self.tid}"

    def __repr__(self):
        return f"<Tag {self.name}>"


def _DONE():  # pragma: no cover - replaced fn of an executed op
    raise AssertionError("op already executed")


@dataclass
class _Op:
    seq: int
    fn: Callable[[], Any]
    reads: tuple
    writes: tuple
    name: str
    n_deps: int = 0
    dependents: list = field(default_factory=list)
    deps: list = field(default_factory=list)   # predecessor ops (for wait)
    done: bool = False
    claimed: bool = False                      # taken by an executor/waiter


class Engine:
    """Tag-based dependency scheduler with wave execution."""

    _ids = itertools.count()

    def __init__(self, record_waves: bool = True):
        self.eid = next(Engine._ids)
        self._track = "engine"           # trace track for this engine's ops
        self._seq = itertools.count()
        self._pending: dict[int, _Op] = {}
        self._ready: deque[_Op] = deque()
        # per-tag state: last writer op (or None), readers since last write
        self._last_writer: dict[int, _Op | None] = defaultdict(lambda: None)
        self._readers_since: dict[int, list[_Op]] = defaultdict(list)
        self.wave_sizes: list[int] = []
        self.record_waves = record_waves
        self.ops_executed = 0
        self._lock = threading.RLock()

    # -- push ---------------------------------------------------------------
    def push(self, fn: Callable[[], Any], reads=(), writes=(), name="op"):
        """Push an operation; returns immediately (lazy, §2.2)."""
        with self._lock:
            op = _Op(next(self._seq), fn, tuple(reads), tuple(writes), name)
            deps: set[int] = set()

            for t in op.reads:
                w = self._last_writer[t.tid]
                if w is not None and not w.done:
                    deps.add(w.seq)
            for t in op.writes:
                w = self._last_writer[t.tid]
                if w is not None and not w.done:
                    deps.add(w.seq)  # WAW
                for r in self._readers_since[t.tid]:
                    if not r.done and r.seq != op.seq:
                        deps.add(r.seq)  # WAR

            for d in deps:
                dep_op = self._pending.get(d)
                if dep_op is not None and not dep_op.done:
                    dep_op.dependents.append(op)
                    op.deps.append(dep_op)
                    op.n_deps += 1

            # update tag state
            for t in op.reads:
                self._readers_since[t.tid].append(op)
            for t in op.writes:
                self._last_writer[t.tid] = op
                self._readers_since[t.tid] = []

            self._pending[op.seq] = op
            if op.n_deps == 0:
                self._ready.append(op)
            return op

    # -- execution ------------------------------------------------------------
    def _finish(self, op: _Op):
        with self._lock:
            op.done = True
            self.ops_executed += 1
            self._pending.pop(op.seq, None)
            for dep in op.dependents:
                dep.n_deps -= 1
                if dep.n_deps == 0:
                    self._ready.append(dep)
            # drop the graph edges (and the closure) so a long-flushed
            # chain does not stay reachable through _last_writer
            op.deps.clear()
            op.dependents.clear()
            op.fn = _DONE

    def _exec(self, op: _Op, wave: int | None = None):
        """Run one claimed op, spanning it on the default trace recorder
        (op name, read/write tags, wave index — the paper's dependency-
        engine execution as a Perfetto timeline)."""
        rec = get_recorder()
        if rec.enabled:
            args = {"reads": [t.name for t in op.reads],
                    "writes": [t.name for t in op.writes],
                    "seq": op.seq}
            if wave is not None:
                args["wave"] = wave
            with rec.span(op.name, cat="engine", track=self._track, **args):
                op.fn()
        else:
            op.fn()
        self._finish(op)

    def _run_wave(self) -> int:
        with self._lock:
            # ops executed out-of-wave by a fine-grained wait() may still
            # sit in the ready queue; drop them (and ops another executor
            # has already claimed)
            wave = [op for op in self._ready if not op.done and not op.claimed]
            for op in wave:
                op.claimed = True
            self._ready.clear()
        if not wave:
            return 0
        wave_idx = len(self.wave_sizes)
        if self.record_waves:
            self.wave_sizes.append(len(wave))
        for op in wave:  # independent by construction
            self._exec(op, wave=wave_idx)
        return len(wave)

    def wait_all(self):
        while True:
            if self._run_wave():
                continue
            with self._lock:
                if not self._pending:
                    return
                busy = any(op.claimed and not op.done
                           for op in self._pending.values())
                assert busy, \
                    f"deadlock: {list(self._pending.values())[:5]}"
            time.sleep(0)  # an op is mid-execution on another thread

    def wait(self, tag: Tag):
        """Flush exactly the ops `tag`'s final value depends on.

        The closure of the tag's last writer over dependency edges (RAW,
        WAW and WAR — a pre-mutation reader is a real predecessor of the
        mutator, so ordering is preserved).  Independent pending ops are
        left untouched (§3.2: waits are per-resource, not global barriers).
        """
        with self._lock:
            writer = self._last_writer[tag.tid]
            if writer is None or writer.done:
                return
            closure = []
            foreign = []
            stack = [writer]
            seen = set()
            while stack:
                op = stack.pop()
                if op.seq in seen or op.done:
                    continue
                seen.add(op.seq)
                if op.claimed:          # mid-execution on another thread
                    foreign.append(op)
                    continue
                op.claimed = True
                closure.append(op)
                stack.extend(op.deps)
        if foreign:
            # an ancestor is mid-execution on another thread: release our
            # claims, let it (and any ready work) finish, then re-resolve —
            # the closure may have shrunk or completed in the meantime
            with self._lock:
                for op in closure:
                    op.claimed = False
            while any(not op.done for op in foreign):
                if not self._run_wave():
                    time.sleep(0)
            return self.wait(tag)
        # push order is a topological order (deps always have smaller seq)
        for op in sorted(closure, key=lambda o: o.seq):
            self._exec(op)

    # -- introspection ----------------------------------------------------------
    def stats(self) -> dict:
        ws = self.wave_sizes
        return {
            "ops": self.ops_executed,
            "waves": len(ws),
            "max_wave": max(ws, default=0),
            "mean_wave": (sum(ws) / len(ws)) if ws else 0.0,
        }

    def reset_stats(self) -> None:
        """Zero this engine's execution record (pending ops unaffected)."""
        self.wave_sizes.clear()
        self.ops_executed = 0

    def publish_stats(self, metrics=None) -> dict:
        """Fold :meth:`stats` into a metrics registry (default: the
        process-wide one) under ``engine.*``.  Gauges, not counters: each
        publish reflects THIS engine's current record, so a fresh engine
        (``reset_default_engine``) publishes fresh numbers instead of
        accumulating onto a dead instance's."""
        m = metrics if metrics is not None else get_metrics()
        s = self.stats()
        m.gauge("engine.ops_executed").set(s["ops"])
        m.gauge("engine.waves").set(s["waves"])
        m.gauge("engine.max_wave").set(s["max_wave"])
        m.gauge("engine.mean_wave").set(s["mean_wave"])
        wh = m.histogram("engine.wave_size")
        for w in self.wave_sizes[wh.count:]:   # only waves not yet observed
            wh.observe(w)
        return s


_default: Engine | None = None


def default_engine() -> Engine:
    global _default
    if _default is None:
        _default = Engine()
    return _default


def reset_default_engine() -> Engine:
    """Install a fresh default engine.

    Also drops every ``engine.*`` metric from the process-wide registry:
    published stats and wave-size samples belong to the engine instance
    that recorded them, and letting a dead engine's numbers linger is
    exactly the cross-test staleness this reset exists to prevent.
    """
    global _default
    get_metrics().remove_prefix("engine.")
    _default = Engine()
    return _default
