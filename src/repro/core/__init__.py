"""repro.core — the MXNet paper's contribution as composable JAX modules.

Public API (mirrors the paper's interface, §2):
  Symbol layer:  Variable, FullyConnected, Activation, SoftmaxOutput, chain
  NDArray layer: NDArray, array, zeros, ones, RNG
  Engine:        Engine, Tag, default_engine
  KVStore:       KVStoreLocal, KVStoreDist, sgd_updater
"""
from .symbol import (Symbol, Variable, FullyConnected, Activation,
                     SoftmaxOutput, Softmax, LayerNorm, chain)
from .ndarray import NDArray, array, zeros, ones, RNG
from .engine import Engine, Tag, default_engine, reset_default_engine
from .executor import Executor
from .kvstore import KVStoreLocal, KVStoreDist, sgd_updater, sum_updater
from .autodiff import gradient, gradient_with_shapes
from .graph import Graph, Node, NodeRef, infer_shapes
from . import ops
from .memplan import plan_graph, naive_bytes

__all__ = [
    "Symbol", "Variable", "FullyConnected", "Activation", "SoftmaxOutput",
    "Softmax", "LayerNorm", "chain", "NDArray", "array", "zeros", "ones",
    "RNG", "Engine", "Tag", "default_engine", "reset_default_engine",
    "Executor", "KVStoreLocal", "KVStoreDist", "sgd_updater", "sum_updater",
    "gradient", "gradient_with_shapes", "Graph", "Node", "NodeRef",
    "infer_shapes", "ops", "plan_graph", "naive_bytes",
]
