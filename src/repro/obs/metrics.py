"""Metrics registry: counters, gauges, histograms with quantile summaries.

The numeric half of the obs layer (DESIGN.md §11): where ``trace.py``
answers *when did it happen*, this answers *how much / how often* —
engine wave widths, KVStore bytes by key, serving TTFT/TPOT
distributions, block-pool occupancy.  Always on: recording a sample is a
dict lookup plus a float append, cheap enough that the serving engine
can observe every request without a flag.

Export is JSONL — one self-describing line per metric — so CI can grep a
single metric out of an artifact without parsing a document.

Worked example (pure — runs anywhere)::

    >>> m = Metrics()
    >>> m.counter("kv.bytes").inc(512)
    >>> m.gauge("pool.blocks").set(7)
    >>> h = m.histogram("ttft_s")
    >>> for v in [1, 2, 3, 4, 5, 6, 7, 8, 9, 10]:
    ...     h.observe(v)
    >>> h.quantile(0.5), h.quantile(0.99)
    (5.5, 9.91)
    >>> snap = m.snapshot()
    >>> snap["kv.bytes"]["value"], snap["pool.blocks"]["max"]
    (512, 7)
"""
from __future__ import annotations

import json
import threading
from dataclasses import dataclass, field


@dataclass
class Counter:
    """Monotonic accumulator (bytes moved, ops executed, tokens emitted)."""
    name: str
    value: float = 0

    def inc(self, v: float = 1) -> None:
        self.value += v

    def summary(self) -> dict:
        return {"type": "counter", "value": self.value}


@dataclass
class Gauge:
    """Last-value metric with a high-water mark (pool occupancy)."""
    name: str
    value: float = 0.0
    max: float = float("-inf")

    def set(self, v: float) -> None:
        self.value = v
        if v > self.max:
            self.max = v

    def summary(self) -> dict:
        return {"type": "gauge", "value": self.value,
                "max": self.max if self.max != float("-inf") else self.value}


@dataclass
class Histogram:
    """Raw-sample histogram with linear-interpolated quantiles.

    Samples are kept (bounded by ``cap``, oldest dropped) so p50/p90/p99
    are exact over the retained window — serving runs observe hundreds of
    requests, not millions, and exactness is worth more than a sketch.
    """
    name: str
    cap: int = 1 << 16
    values: list[float] = field(default_factory=list)
    count: int = 0
    total: float = 0.0

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        self.values.append(float(v))
        if len(self.values) > self.cap:
            del self.values[: len(self.values) - self.cap]

    def quantile(self, q: float, values: list[float] | None = None) -> float:
        """Linear interpolation between closest ranks (numpy's default),
        over ``values`` (default: all retained samples)."""
        vs = sorted(self.values if values is None else values)
        if not vs:
            return 0.0
        if len(vs) == 1:
            return vs[0]
        pos = q * (len(vs) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(vs) - 1)
        return vs[lo] + (vs[hi] - vs[lo]) * (pos - lo)

    def summary(self) -> dict:
        vs = self.values
        return {"type": "histogram", "count": self.count, "sum": self.total,
                "min": min(vs) if vs else 0.0, "max": max(vs) if vs else 0.0,
                "p50": self.quantile(0.50), "p90": self.quantile(0.90),
                "p99": self.quantile(0.99)}


class Metrics:
    """Get-or-create registry of named metrics; thread-safe creation."""

    def __init__(self):
        self._metrics: dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.setdefault(name, cls(name))
        if not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def remove_prefix(self, prefix: str) -> int:
        """Drop every metric whose name starts with ``prefix`` (a layer
        re-initializing — e.g. ``reset_default_engine`` — must not leave
        a dead instance's numbers in the registry)."""
        with self._lock:
            dead = [n for n in self._metrics if n.startswith(prefix)]
            for n in dead:
                del self._metrics[n]
        return len(dead)

    def snapshot(self) -> dict:
        """``{name: summary dict}`` for every registered metric."""
        return {n: m.summary() for n, m in sorted(self._metrics.items())}

    def dump_jsonl(self, path: str, mode: str = "a",
                   extra: dict | None = None) -> int:
        """Append one ``{"kind": "metric", "name": ..., ...}`` JSON line
        per metric; returns the number of lines written."""
        snap = self.snapshot()
        with open(path, mode) as f:
            for name, summary in snap.items():
                line = {"kind": "metric", "name": name, **summary,
                        **(extra or {})}
                # numpy scalars (KVStore byte counters) must not corrupt
                # the artifact mid-write
                f.write(json.dumps(line, default=float) + "\n")
        return len(snap)


# ---------------------------------------------------------------------------
# module-level default registry

_METRICS = Metrics()


def get_metrics() -> Metrics:
    return _METRICS


def reset_metrics() -> Metrics:
    global _METRICS
    _METRICS = Metrics()
    return _METRICS
