"""Host-side tracing: nestable spans exported as Chrome trace-event JSON.

The runtime lens the MXNet paper's systems story needs (and the
TensorFlow whitepaper ships as EEG): the dependency engine's waves, the
trainer's data-wait/step/checkpoint cadence and the serving engine's
per-request lifecycle all record onto one timeline that Perfetto /
``chrome://tracing`` opens directly (DESIGN.md §11).

Design constraints:

* **~zero overhead when disabled** — the common case.  ``span()`` on a
  disabled recorder returns a shared ``nullcontext`` (no allocation, one
  attribute check); ``instant``/``counter`` return immediately.  The
  acceptance gate: bench_serving decode tok/s within 2% of no-obs.
* **thread-safe** — the engine executes ops from waiter threads and the
  data pipeline prefetches on background threads; events append under a
  lock, and each thread's events land on its own track by default.
* **dependency-free** — stdlib only; jax is imported lazily and only for
  the optional device-profile alignment wrappers.

Event model (Chrome trace-event format, the subset Perfetto renders):

* ``ph: "X"`` complete events — spans with ``ts``/``dur`` in µs;
* ``ph: "i"`` instant events — points in time (request milestones);
* ``ph: "C"`` counter events — numeric tracks (block-pool occupancy);
* ``ph: "M"`` metadata — human-readable track names, emitted at export.

Tracks are logical names ("engine", "trainer", "serve", "req3"), mapped
to stable ``tid`` ints at first use; ``pid`` is always 1 (one host
process — device timelines come from ``jax.profiler`` alignment, not
from this recorder).

Worked example (pure host tracing — runs anywhere)::

    >>> rec = TraceRecorder(enabled=True)
    >>> with rec.span("outer", cat="demo"):
    ...     with rec.span("inner", cat="demo"):
    ...         rec.instant("tick", cat="demo")
    >>> [e["name"] for e in rec.events()]       # inner closes first
    ['tick', 'inner', 'outer']
    >>> doc = rec.export()
    >>> sorted(doc) == ['displayTimeUnit', 'traceEvents']
    True
"""
from __future__ import annotations

import contextlib
import json
import threading
import time
from contextlib import nullcontext

_NULL = nullcontext()


def _coerce(o):
    """JSON fallback for span-arg payloads: numpy/jax scalars carry
    ``__int__``/``__float__``; anything else degrades to its repr rather
    than corrupting the export mid-write."""
    for cast in (int, float):
        try:
            return cast(o)
        except (TypeError, ValueError):
            continue
    return str(o)


class TraceRecorder:
    """Thread-safe span/instant/counter recorder with Perfetto export."""

    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self._events: list[dict] = []
        self._lock = threading.Lock()
        self._t0 = time.perf_counter()
        self._tracks: dict[str, int] = {}
        self._tls = threading.local()

    # -- time / track bookkeeping ------------------------------------------
    def now_us(self) -> float:
        """Microseconds since this recorder's epoch."""
        return (time.perf_counter() - self._t0) * 1e6

    def to_us(self, t_perf: float) -> float:
        """Convert a raw ``time.perf_counter()`` stamp to recorder µs —
        for lifecycle events whose begin was stamped before the event is
        recorded (e.g. a request's enqueue time)."""
        return (t_perf - self._t0) * 1e6

    def _tid(self, track: str | None) -> int:
        if track is None:
            track = getattr(self._tls, "name", None)
            if track is None:
                track = threading.current_thread().name
        tid = self._tracks.get(track)
        if tid is None:
            tid = self._tracks[track] = len(self._tracks) + 1
        return tid

    def set_thread_track(self, name: str) -> None:
        """Default track for events recorded from the calling thread."""
        self._tls.name = name

    # -- recording ---------------------------------------------------------
    @contextlib.contextmanager
    def _span(self, name, cat, track, args):
        t0 = self.now_us()
        try:
            yield
        finally:
            t1 = self.now_us()
            ev = {"name": name, "cat": cat, "ph": "X", "ts": t0,
                  "dur": t1 - t0, "pid": 1}
            if args:
                ev["args"] = args
            with self._lock:
                ev["tid"] = self._tid(track)
                self._events.append(ev)

    def span(self, name: str, cat: str = "host", track: str | None = None,
             **args):
        """Context manager recording one complete event around its body.

        Disabled recorders return a shared ``nullcontext`` — the hot-path
        cost of an un-traced span is one attribute check.
        """
        if not self.enabled:
            return _NULL
        return self._span(name, cat, track, args)

    def complete(self, name: str, start_us: float, end_us: float,
                 cat: str = "host", track: str | None = None, **args):
        """Record a span whose begin/end happened in different call frames
        (e.g. a request's queued->admitted interval)."""
        if not self.enabled:
            return
        ev = {"name": name, "cat": cat, "ph": "X", "ts": start_us,
              "dur": max(end_us - start_us, 0.0), "pid": 1}
        if args:
            ev["args"] = args
        with self._lock:
            ev["tid"] = self._tid(track)
            self._events.append(ev)

    def instant(self, name: str, cat: str = "host",
                track: str | None = None, **args):
        if not self.enabled:
            return
        ev = {"name": name, "cat": cat, "ph": "i", "ts": self.now_us(),
              "s": "t", "pid": 1}
        if args:
            ev["args"] = args
        with self._lock:
            ev["tid"] = self._tid(track)
            self._events.append(ev)

    def counter(self, name: str, value, track: str | None = None,
                cat: str = "host"):
        """Counter-track sample (rendered as a filled line in Perfetto)."""
        if not self.enabled:
            return
        ev = {"name": name, "cat": cat, "ph": "C", "ts": self.now_us(),
              "pid": 1, "args": {"value": value}}
        with self._lock:
            ev["tid"] = self._tid(track)
            self._events.append(ev)

    # -- export ------------------------------------------------------------
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()

    def export(self, path: str | None = None) -> dict:
        """Chrome trace-event / Perfetto JSON document; writes ``path``
        when given.  Track-name metadata events come first so Perfetto
        labels every row."""
        with self._lock:
            events = list(self._events)
            tracks = dict(self._tracks)
        meta = [{"name": "process_name", "ph": "M", "pid": 1,
                 "args": {"name": "repro"}}]
        for name, tid in sorted(tracks.items(), key=lambda kv: kv[1]):
            meta.append({"name": "thread_name", "ph": "M", "pid": 1,
                         "tid": tid, "args": {"name": name}})
        doc = {"traceEvents": meta + events, "displayTimeUnit": "ms"}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f, default=_coerce)
        return doc


# ---------------------------------------------------------------------------
# module-level default recorder (what the instrumented layers talk to)

_RECORDER = TraceRecorder(enabled=False)


def get_recorder() -> TraceRecorder:
    return _RECORDER


def set_recorder(rec: TraceRecorder) -> TraceRecorder:
    global _RECORDER
    _RECORDER = rec
    return _RECORDER


def enable(enabled: bool = True) -> TraceRecorder:
    """Turn the default recorder on/off (fresh event buffer when enabling
    from off, so a CLI's --trace starts a clean timeline)."""
    global _RECORDER
    if enabled and not _RECORDER.enabled:
        _RECORDER = TraceRecorder(enabled=True)
    else:
        _RECORDER.enabled = enabled
    return _RECORDER


def tracing() -> bool:
    return _RECORDER.enabled


def span(name: str, cat: str = "host", track: str | None = None, **args):
    return _RECORDER.span(name, cat=cat, track=track, **args)


def instant(name: str, cat: str = "host", track: str | None = None, **args):
    return _RECORDER.instant(name, cat=cat, track=track, **args)


def export(path: str | None = None) -> dict:
    return _RECORDER.export(path)


# ---------------------------------------------------------------------------
# device-profile alignment (jax.profiler / HLO metadata)

def named_scope(name: str):
    """Name the ops traced inside the body (HLO op-metadata scope), so a
    device profile (``jax.profiler.trace``) shows the same ring-step /
    pipeline-tick / bucket-chain names as the host timeline.  Also records
    a host span on the default recorder when tracing is enabled — jit
    tracing happens once, so these spans show the *trace-time* structure
    (which scheduled region was being staged), not per-execution timing.
    """
    try:
        import jax
        scope = jax.named_scope(name)
    except Exception:   # jax absent/ancient: host-side span only
        scope = _NULL
    if not _RECORDER.enabled:
        return scope
    stack = contextlib.ExitStack()
    stack.enter_context(_RECORDER.span(name, cat="jit-trace",
                                       track="jit-trace"))
    stack.enter_context(scope)
    return stack


def annotation(name: str, **kwargs):
    """Host-side ``jax.profiler.TraceAnnotation`` (shows up on the device
    profile's host rows) combined with a span on the default recorder —
    the glue that lines our timeline up with ``jax.profiler.trace``."""
    try:
        from jax.profiler import TraceAnnotation
        ann = TraceAnnotation(name, **kwargs)
    except Exception:
        ann = _NULL
    if not _RECORDER.enabled:
        return ann
    stack = contextlib.ExitStack()
    stack.enter_context(_RECORDER.span(name, cat="dispatch"))
    stack.enter_context(ann)
    return stack
