"""repro.obs — unified tracing + metrics layer (DESIGN.md §11).

Dependency-free observability used by every layer of the stack:

* :mod:`repro.obs.trace` — ``TraceRecorder``: nestable spans, instants
  and counter tracks exported as Chrome trace-event / Perfetto JSON,
  plus ``named_scope``/``annotation`` wrappers that line host spans up
  with ``jax.profiler`` device profiles.  ~Zero overhead when disabled.
* :mod:`repro.obs.metrics` — ``Metrics`` registry: counters, gauges and
  histograms with p50/p90/p99 summaries, JSONL snapshot export.
* :mod:`repro.obs.logger` — ``MetricsLogger`` sinks (stdout / JSONL)
  replacing the trainer's raw ``print``.

Instrumented layers: ``core/engine.py`` (per-op wave spans),
``train/trainer.py`` (data-wait/step/checkpoint spans),
``serve/engine.py`` (per-request queued→admitted→prefill→decode→evicted
lifecycle, TTFT/TPOT/queue-wait histograms), ``dist/`` (named scopes on
ring steps, pipeline ticks, bucketed sync chains; KVStore byte counters).
CLI wiring: ``--trace PATH`` / ``--metrics PATH`` on ``launch.train``,
``launch.serve`` and ``benchmarks/run.py``.
"""
from .logger import JsonlSink, MetricsLogger, StdoutSink
from .metrics import (Counter, Gauge, Histogram, Metrics, get_metrics,
                      reset_metrics)
from .trace import (TraceRecorder, annotation, enable, export, get_recorder,
                    instant, named_scope, set_recorder, span, tracing)

__all__ = [
    "TraceRecorder", "get_recorder", "set_recorder", "enable", "tracing",
    "span", "instant", "export", "named_scope", "annotation",
    "Metrics", "Counter", "Gauge", "Histogram", "get_metrics",
    "reset_metrics",
    "MetricsLogger", "StdoutSink", "JsonlSink",
]
