"""Structured training-log sinks: the replacement for raw ``print``.

``Trainer.fit`` hands each log-step payload (loss, grad_norm, wall_s,
steps_per_s, ...) to a :class:`MetricsLogger`, which fans it out to
sinks: ``StdoutSink`` keeps the familiar one-line format (the default —
a bare ``python -m repro.launch.train`` looks exactly like before),
``JsonlSink`` appends machine-readable lines for CI artifacts
(``--metrics PATH``).

Worked example::

    >>> log = MetricsLogger([])                # no sinks: history only
    >>> log.log({"step": 0, "loss": 2.5})
    >>> log.history[0]["loss"]
    2.5
"""
from __future__ import annotations

import json


class StdoutSink:
    """The trainer's classic one-liner, plus throughput."""

    def log(self, payload: dict) -> None:
        loss = payload.get("loss", float("nan"))
        parts = [f"step {payload.get('step', 0):5d} loss {loss:.4f}",
                 f"ce {payload.get('ce', loss):.4f}",
                 f"gnorm {payload.get('grad_norm', 0.0):.2f}",
                 f"t {payload.get('wall_s', 0.0)}s"]
        if "steps_per_s" in payload:
            parts.append(f"{payload['steps_per_s']:.2f} steps/s")
        print(" ".join(parts))


class JsonlSink:
    """One ``{"kind": "step", ...}`` JSON line per log event."""

    def __init__(self, path: str, mode: str = "a"):
        self.path = path
        self._f = open(path, mode)

    def log(self, payload: dict) -> None:
        self._f.write(json.dumps({"kind": "step", **payload}) + "\n")
        self._f.flush()

    def close(self) -> None:
        self._f.close()


class MetricsLogger:
    """Fan a log-step payload out to sinks; keeps an in-process history
    (what ``Trainer.history`` reads)."""

    def __init__(self, sinks: list | None = None):
        self.sinks = [StdoutSink()] if sinks is None else list(sinks)
        self.history: list[dict] = []

    def log(self, payload: dict) -> None:
        self.history.append(dict(payload))
        for sink in self.sinks:
            sink.log(payload)

    def close(self) -> None:
        for sink in self.sinks:
            close = getattr(sink, "close", None)
            if close:
                close()
