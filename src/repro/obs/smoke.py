"""Observability smoke: exercise every instrumented layer on CPU, export
the trace + metrics artifacts, and assert the trace is a valid Chrome
trace-event document carrying >= 1 span from each layer.

CI runs this (instead of tracing the full bench suite — tracing would
perturb fig6's executor/eager timing-ratio gates) to produce the
``--trace``/``--metrics`` artifacts and gate the instrumentation:

  PYTHONPATH=src python -m repro.obs.smoke \
      --trace /tmp/trace.json --metrics /tmp/metrics.jsonl

Exercised layers -> expected spans:

* dependency engine (``core/engine.py``)  -> cat ``engine`` op spans;
* trainer (``train/trainer.py``)          -> cat ``train`` step spans;
* serving (``serve/engine.py``)           -> cat ``serve`` lifecycle
  spans (queued / prefill_chunk / decode per admitted request);
* dist (``dist/ring.py``, ``dist/pipeline.py``, ``dist/collectives.py``)
  -> cat ``jit-trace`` named-scope spans (``ring_fwd_*``, ``pp_fwd_*``,
  ``grad_sync_*``) recorded while the schedules stage.

Exit 1 with a per-layer report when any expectation fails.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# the pipeline schedule needs a real multi-device "stage" axis; must be
# set before jax initializes (same trick as benchmarks/bench_dist.py)
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=2")


def _engine_layer():
    """A tiny RAW/WAR/WAW chain through a fresh default engine."""
    from repro.core.engine import Tag, reset_default_engine
    eng = reset_default_engine()
    a, b = Tag("a"), Tag("b")
    eng.push(lambda: None, writes=(a,), name="init_a")
    eng.push(lambda: None, reads=(a,), writes=(b,), name="b_from_a")
    eng.push(lambda: None, reads=(a,), writes=(a,), name="update_a")
    eng.wait_all()
    eng.publish_stats()


def _train_layer(cfg):
    from repro.data import SyntheticLM
    from repro.train import TrainConfig, Trainer
    tcfg = TrainConfig(total_steps=2, warmup_steps=1)
    data = SyntheticLM(cfg.vocab, 16, 2, n_batches=2)
    Trainer(cfg, tcfg).fit(iter(data))


def _serve_layer(cfg, params):
    import numpy as np
    from repro.serve import PagedServeEngine
    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(1, cfg.vocab, L)) for L in (5, 9, 17)]
    eng = PagedServeEngine(cfg, params, block_size=8, max_batch=2,
                           max_len=48, prefill_chunk=8)
    eng.generate(prompts, max_new_tokens=[3, 4, 5])


def _dist_layer():
    import jax
    import jax.numpy as jnp
    from repro.dist.collectives import gradient_sync
    from repro.dist.pipeline import pipeline_stack
    from repro.dist.ring import ring_attention

    # ring: the 1-shard fallback still walks the _ring_fwd schedule
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (1, 8, 2, 4))
    kv = jax.random.normal(k, (1, 8, 1, 4))
    ring_attention(q, kv, kv)

    n_dev = len(jax.devices())
    if n_dev < 2:   # single-device jax: ring scopes alone cover the layer
        return

    # pipeline: 2 stages x 2 microbatches over a forced host-device mesh
    mesh = jax.make_mesh((2,), ("stage",))
    params = {"w": jnp.eye(4)[None].repeat(2, 0)}

    def stage_fn(p, x):
        def body(h, w):
            return jnp.tanh(h @ w), 0.0
        h, _ = jax.lax.scan(body, x, p["w"])
        return h, {"aux": jnp.zeros((), jnp.float32)}

    x = jax.random.normal(k, (2, 4, 4))
    with jax.set_mesh(mesh):
        pipeline_stack(stage_fn, params, x, microbatches=2, mesh=mesh)

    # bucketed gradient sync: per-bucket collective chains
    dmesh = jax.make_mesh((2,), ("data",))
    gradient_sync(dmesh, {"w": jnp.ones((2, 5))}, mode="bucketed")


LAYERS = {
    "engine": lambda spans: any(e["cat"] == "engine" for e in spans),
    "train": lambda spans: any(e["cat"] == "train" for e in spans),
    "serve-lifecycle": lambda spans: all(
        any(e["cat"] == "serve" and e["name"] == n for e in spans)
        for n in ("queued", "prefill_chunk", "decode")),
    "dist-named-scopes": lambda spans: any(
        e["cat"] == "jit-trace" and e["name"].startswith(
            ("ring_fwd_", "pp_fwd_", "grad_sync_"))
        for e in spans),
}


def check_trace(path: str) -> list[str]:
    """Validate the exported document; returns failure strings."""
    failures = []
    with open(path) as f:
        doc = json.load(f)          # malformed JSON raises -> crash is fine
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return [f"{path}: no traceEvents"]
    for e in events:
        missing = {"name", "ph", "pid"} - set(e)
        if missing:
            failures.append(f"event {e} lacks {sorted(missing)}")
        if e.get("ph") == "X" and not {"ts", "dur"} <= set(e):
            failures.append(f"complete event {e['name']} lacks ts/dur")
    spans = [e for e in events if e.get("ph") == "X"]
    for layer, ok in LAYERS.items():
        n = "yes" if ok(spans) else "MISSING"
        print(f"  layer {layer}: {n}")
        if n == "MISSING":
            failures.append(f"no span for instrumented layer {layer!r}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", metavar="PATH", required=True)
    ap.add_argument("--metrics", metavar="PATH", default=None)
    args = ap.parse_args()

    from repro import obs
    from repro.configs import get_config
    from repro.models import get_model, reduced
    obs.enable()

    cfg = reduced(get_config("qwen1.5-0.5b"))
    print("== engine layer")
    _engine_layer()
    print("== train layer")
    _train_layer(cfg)
    print("== serve layer")
    import jax
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    _serve_layer(cfg, params)
    print("== dist layer")
    _dist_layer()

    if args.metrics:
        n = obs.get_metrics().dump_jsonl(args.metrics)
        print(f"metrics: {args.metrics} ({n} metrics)")
    obs.export(args.trace)
    print(f"trace: {args.trace}")

    failures = check_trace(args.trace)
    # the serving histograms must have real samples, not just names
    snap = obs.get_metrics().snapshot()
    for name in ("serve.ttft_s", "serve.tpot_s", "serve.queue_wait_s"):
        if snap.get(name, {}).get("count", 0) < 1:
            failures.append(f"metric {name} recorded no samples")
    if failures:
        for f in failures:
            print(f"FAIL: {f}")
        sys.exit(1)
    print("PASS")


if __name__ == "__main__":
    main()
