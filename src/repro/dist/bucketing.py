"""Gradient bucketing: the engine's lazy-push analogue on the jit path.

MXNet's §4 dependency engine overlaps communication with computation by
pushing each layer's gradient to the KVStore as soon as its backward op
completes, instead of waiting for the whole backward pass.  Under jit
there is no runtime scheduler to push to — the equivalent is *structural*:
pack gradient leaves into ~N-MB buckets and emit one collective per
bucket inside the backward graph, so XLA's latency-hiding scheduler can
run bucket *k*'s all-reduce while the FLOPs that produce bucket *k+1*
are still executing (DESIGN.md §7).

Two pieces:

* :class:`BucketPlan` — greedy first-fit packing of flattened leaves into
  byte-capped, dtype-pure buckets (the same first-applicable-candidate
  discipline as ``annotate.ann_first_fit``, applied to sizes instead of
  specs).  Pure shape metadata: it can be built from arrays or
  ``ShapeDtypeStruct``s and is hashable trace-time state.
* :func:`overlap_taps` — the ``custom_vjp`` emission trick: an identity
  on the *params* whose backward rule packs each bucket's cotangents into
  one fused buffer and pins its layout, forcing the partitioner to
  materialise that bucket's gradient reduction at that point of the
  backward computation rather than sinking every all-reduce to the end.

Worked example (pure packing — runs anywhere)::

    >>> import jax
    >>> leaves = [jax.ShapeDtypeStruct((256, 256), 'float32'),   # 256 KiB
    ...           jax.ShapeDtypeStruct((1024,), 'float32'),      #   4 KiB
    ...           jax.ShapeDtypeStruct((512, 512), 'float32')]   #   1 MiB
    >>> plan = BucketPlan.build(leaves, cap_bytes=300 * 1024)
    >>> plan.n_buckets          # leaf 1 first-fits into leaf 0's bucket;
    2
    >>> plan.assignment()       # the 1 MiB leaf is oversized -> own bucket
    (0, 0, 1)
    >>> [b.nbytes for b in plan.buckets]
    [266240, 1048576]
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

DEFAULT_BUCKET_BYTES = 4 << 20  # 4 MiB — see DESIGN.md §7 tradeoff model


def leaf_nbytes(leaf) -> int:
    """Payload bytes of one array-like (shape/dtype duck-typed)."""
    return math.prod(leaf.shape) * jnp.dtype(leaf.dtype).itemsize


@dataclass(frozen=True)
class Bucket:
    """One bucket: leaf indices (into the flattened tree), shared dtype,
    per-leaf element counts, and total payload bytes."""
    indices: tuple[int, ...]
    dtype: str
    elems: tuple[int, ...]
    nbytes: int

    @property
    def n_elems(self) -> int:
        return sum(self.elems)


@dataclass(frozen=True)
class BucketPlan:
    """First-fit packing of a leaf list into byte-capped buckets.

    Invariants (property-tested by ``tests/test_bucketing.py``):

    * every leaf index appears in exactly one bucket;
    * every bucket's payload is <= ``cap_bytes`` unless it holds a single
      oversized leaf (a leaf larger than the cap gets a bucket to itself);
    * all leaves in a bucket share a dtype (buckets are concatenated into
      one flat buffer, so mixed dtypes never pack together).
    """
    buckets: tuple[Bucket, ...]
    cap_bytes: int

    @classmethod
    def build(cls, leaves, cap_bytes: int = DEFAULT_BUCKET_BYTES,
              lead_dims: int = 0) -> "BucketPlan":
        """Pack ``leaves`` (arrays or ShapeDtypeStructs) greedily: each
        leaf, in order, goes into the first open same-dtype bucket with
        room, else opens a new bucket.  ``lead_dims`` leading dims are
        excluded from the size accounting (e.g. the per-worker stacking
        dim of ``gradient_sync`` inputs — packing is about the *synced*
        payload, which is per-worker)."""
        if cap_bytes <= 0:
            raise ValueError(f"cap_bytes must be positive, got {cap_bytes}")
        open_: list[list[int]] = []   # per bucket: leaf indices
        used: list[int] = []          # per bucket: payload bytes
        dtypes: list[str] = []
        for i, leaf in enumerate(leaves):
            shape = tuple(leaf.shape)[lead_dims:]
            nb = math.prod(shape) * jnp.dtype(leaf.dtype).itemsize
            dt = str(jnp.dtype(leaf.dtype))
            for b in range(len(open_)):
                # a bucket already at/over cap is closed (oversized leaves
                # must stay alone; normal buckets stop accepting at cap)
                if (dtypes[b] == dt and used[b] < cap_bytes
                        and used[b] + nb <= cap_bytes):
                    open_[b].append(i)
                    used[b] += nb
                    break
            else:
                open_.append([i])
                used.append(nb)
                dtypes.append(dt)
        buckets = []
        for b, idx in enumerate(open_):
            elems = tuple(math.prod(tuple(leaves[i].shape)[lead_dims:])
                          for i in idx)
            buckets.append(Bucket(indices=tuple(idx), dtype=dtypes[b],
                                  elems=elems, nbytes=used[b]))
        return cls(buckets=tuple(buckets), cap_bytes=cap_bytes)

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    def assignment(self) -> tuple[int, ...]:
        """``assignment()[leaf_index] -> bucket index``."""
        out: dict[int, int] = {}
        for b, bucket in enumerate(self.buckets):
            for i in bucket.indices:
                out[i] = b
        return tuple(out[i] for i in range(len(out)))

    # ------------------------------------------------------------------
    # pack / unpack
    def pack(self, leaves, lead_dims: int = 0) -> list:
        """Concatenate each bucket's leaves (flattened past ``lead_dims``)
        into one buffer per bucket: shape ``lead + (bucket elems,)``."""
        out = []
        for bucket in self.buckets:
            parts = []
            for i in bucket.indices:
                leaf = leaves[i]
                lead = leaf.shape[:lead_dims]
                parts.append(jnp.reshape(leaf, lead + (-1,)))
            out.append(parts[0] if len(parts) == 1
                       else jnp.concatenate(parts, axis=lead_dims))
        return out

    def unpack(self, buffers, like_leaves, lead_dims: int = 0) -> list:
        """Inverse of :meth:`pack`: split each bucket buffer back into the
        original leaf shapes (minus any reduced lead dims: shapes are taken
        from ``like_leaves`` past ``lead_dims``)."""
        out: list = [None] * sum(len(b.indices) for b in self.buckets)
        for bucket, buf in zip(self.buckets, buffers):
            offset = 0
            for i, n in zip(bucket.indices, bucket.elems):
                shape = tuple(like_leaves[i].shape)[lead_dims:]
                lead = buf.shape[:-1]
                piece = jax.lax.slice_in_dim(buf, offset, offset + n,
                                             axis=buf.ndim - 1)
                out[i] = jnp.reshape(piece, lead + shape)
                offset += n
        return out


# ---------------------------------------------------------------------------
# the custom_vjp emission trick (DESIGN.md §7)

def _pin_replicated(buf):
    """Force ``buf`` (a fully-reduced bucket buffer) to materialise as one
    replicated array at this point of the graph; identity without a mesh."""
    from .compat import current_mesh
    m = current_mesh()
    if m is None or m.size == 1:
        return buf
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.lax.with_sharding_constraint(buf, NamedSharding(m, P()))


def overlap_taps(params, cap_bytes: int = DEFAULT_BUCKET_BYTES,
                 sync=None):
    """Identity on ``params`` whose VJP emits one fused per-bucket gradient
    buffer *inside* the backward computation.

    Forward: returns ``params`` unchanged (bitwise — a ``custom_vjp``
    identity).  Backward: cotangents are grouped by a :class:`BucketPlan`
    over the param leaves; each bucket's cotangents are concatenated into
    one flat buffer, passed through ``sync`` (default: a replicated layout
    pin, which under GSPMD forces the partitioner to materialise that
    bucket's gradient all-reduce at this point instead of sinking all of
    them past the end of backward), and split back.  Gradient *values* are
    unchanged, so a step with taps is numerically identical to one
    without — only the collective schedule differs.
    """
    sync = sync or _pin_replicated
    leaves, treedef = jax.tree.flatten(params)
    plan = BucketPlan.build(leaves, cap_bytes=cap_bytes)

    @jax.custom_vjp
    def tap(*xs):
        return xs

    def tap_fwd(*xs):
        return xs, None

    def tap_bwd(_, gs):
        buffers = [sync(buf) for buf in plan.pack(gs)]
        return tuple(plan.unpack(buffers, gs))

    tap.defvjp(tap_fwd, tap_bwd)
    return treedef.unflatten(tap(*leaves))
