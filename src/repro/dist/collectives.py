"""Hierarchical on-mesh gradient synchronization (MXNet §3.3).

The paper's two-level KVStore aggregates gradients *within* a machine
first (level-1), then *across* machines (level-2), shrinking inter-machine
traffic by the devices-per-machine factor.  ``core/kvstore.py`` models
this analytically; this module is the on-mesh counterpart over a
``(pod, data, model)`` TPU mesh, where "machine" = pod and
"device-per-machine" = the ``data`` axis:

* ``mode="flat"`` — one all-reduce over the combined worker axes: every
  worker's full gradient crosses the pod boundary;
* ``mode="hierarchical"`` — reduce-scatter within each pod's ``data``
  axis (level-1: after it each worker holds a 1/|data| summed shard),
  an all-reduce of only that shard across ``pod`` (level-2), and an
  all-gather within ``data`` to restore the full replica.

* ``mode="bucketed"`` — the overlap-friendly schedule (DESIGN.md §7):
  leaves are packed into byte-capped buckets by a ``BucketPlan`` and each
  bucket is reduced with the hierarchical schedule (flat where the two
  coincide) as its own independent collective chain, so a scheduler can
  overlap bucket *k*'s sync with whatever produces bucket *k+1*.

All modes produce identical sums; the hierarchical HLO's cross-pod
all-reduce moves 1/|data| of the bytes — the §3.3 claim, checked from the
compiled HLO by ``tests/test_dist.py`` and benchmarked by
``benchmarks/bench_dist.py`` (which also checks that the per-bucket
cross-pod bytes sum back to the monolithic hierarchical total).

Worked example (1-device fallback — runs anywhere)::

    >>> import jax, jax.numpy as jnp
    >>> mesh = jax.make_mesh((1,), ("data",))
    >>> grads = {"w": jnp.ones((4, 3))}      # 4 workers, one 3-vector each
    >>> out = gradient_sync(mesh, grads, mode="bucketed")
    >>> out["w"].tolist()                    # leading-dim sum, same tree
    [4.0, 4.0, 4.0]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import obs

from . import compat
from .annotate import DATA_AXES
from .bucketing import DEFAULT_BUCKET_BYTES, BucketPlan

MODES = ("flat", "hierarchical", "bucketed")


def worker_axes(mesh):
    """The mesh axes whose product is the gradient-worker count."""
    return tuple(a for a in DATA_AXES if a in mesh.axis_names)


def _flat_body(waxes):
    def sync(g):
        return jax.lax.psum(jnp.squeeze(g, 0), waxes)
    return sync


def _hier_body(n_data):
    def sync(g):
        g = jnp.squeeze(g, 0)
        shape, size = g.shape, g.size
        flat = g.reshape(-1)
        pad = (-size) % n_data
        if pad:
            flat = jnp.pad(flat, (0, pad))
        # level-1 reduce-scatter within the pod, spelled as all-to-all +
        # local sum (XLA backends without native reduce-scatter decompose
        # psum_scatter into a FULL-size all-reduce, which would defeat the
        # schedule); after this each data rank holds a 1/|data| summed shard
        with obs.named_scope("l1_reduce_scatter"):
            chunks = flat.reshape(n_data, -1)
            received = jax.lax.all_to_all(chunks, "data", split_axis=0,
                                          concat_axis=0, tiled=False)
            shard = received.sum(0)
        # level-2: only the 1/|data| shard crosses the pod boundary
        with obs.named_scope("l2_cross_pod"):
            shard = jax.lax.psum(shard, "pod")
        with obs.named_scope("l1_all_gather"):
            gathered = jax.lax.all_gather(shard, "data",
                                          axis=0)  # (n_data, c)
        full = gathered.reshape(-1)
        if pad:
            full = full[:size]
        return full.reshape(shape)
    return sync


def gradient_sync(mesh, grads, mode: str = "flat", *,
                  bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                  plan: BucketPlan | None = None):
    """Sum a pytree of per-worker gradients over their leading worker dim.

    Every leaf of ``grads`` has shape ``(W, ...)`` with ``W`` the product
    of the mesh's worker axes (``pod`` × ``data``); the result is the
    leading-dim sum, replicated over the mesh.  ``mode="hierarchical"``
    falls back to flat when the mesh has no ``pod`` axis or no multi-way
    ``data`` axis (the two schedules coincide there).

    ``mode="bucketed"`` packs the leaves into ``bucket_bytes``-capped
    buckets (``plan`` overrides the packing; its byte accounting is
    per-worker, i.e. excludes the leading ``W`` dim) and reduces each
    bucket with the hierarchical schedule as an independent collective
    chain.  Numerically identical to the other modes.
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    if mode == "bucketed":
        leaves, treedef = jax.tree.flatten(grads)
        plan = plan or BucketPlan.build(leaves, cap_bytes=bucket_bytes,
                                        lead_dims=1)
        buffers = plan.pack(leaves, lead_dims=1)
        synced = gradient_sync(mesh, buffers, mode="hierarchical")
        return treedef.unflatten(plan.unpack(synced, leaves, lead_dims=1))
    waxes = worker_axes(mesh)
    sizes = dict(mesh.shape)
    n_workers = 1
    for a in waxes:
        n_workers *= sizes[a]
    if not waxes or n_workers == 1 or mesh.size == 1:
        return jax.tree.map(lambda g: g.sum(0), grads)
    for g in jax.tree.leaves(grads):
        if g.shape[0] != n_workers:
            raise ValueError(
                f"gradient leaf has leading dim {g.shape[0]}, expected the "
                f"worker count {n_workers} (= product of mesh axes {waxes})")
    if (mode == "hierarchical" and "pod" in mesh.axis_names
            and sizes.get("data", 1) > 1):
        body = _hier_body(sizes["data"])
    else:
        # single-pod or no intra-pod data axis: the two schedules coincide
        body = _flat_body(waxes)
    def tree_sync(t):
        # one named scope per leaf: under mode="bucketed" the leaves ARE
        # the packed buckets, so a device profile shows each bucket's
        # collective chain (grad_sync_b0, grad_sync_b1, ...) as the
        # independent region a scheduler may overlap with compute
        leaves, treedef = jax.tree.flatten(t)
        out = []
        for k, g in enumerate(leaves):
            with obs.named_scope(f"grad_sync_b{k}"):
                out.append(body(g))
        return treedef.unflatten(out)

    # all axes manual (inputs have no "model" dim; full-manual also works
    # eagerly, where partial-auto does not on older jax)
    sync = compat.shard_map(tree_sync, mesh,
                            in_specs=(P(waxes),), out_specs=P())
    return sync(grads)
