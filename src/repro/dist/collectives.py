"""Hierarchical on-mesh gradient synchronization (MXNet §3.3).

The paper's two-level KVStore aggregates gradients *within* a machine
first (level-1), then *across* machines (level-2), shrinking inter-machine
traffic by the devices-per-machine factor.  ``core/kvstore.py`` models
this analytically; this module is the on-mesh counterpart over a
``(pod, data, model)`` TPU mesh, where "machine" = pod and
"device-per-machine" = the ``data`` axis:

* ``mode="flat"`` — one all-reduce over the combined worker axes: every
  worker's full gradient crosses the pod boundary;
* ``mode="hierarchical"`` — reduce-scatter within each pod's ``data``
  axis (level-1: after it each worker holds a 1/|data| summed shard),
  an all-reduce of only that shard across ``pod`` (level-2), and an
  all-gather within ``data`` to restore the full replica.

* ``mode="bucketed"`` — the overlap-friendly schedule (DESIGN.md §7):
  leaves are packed into byte-capped buckets by a ``BucketPlan`` and each
  bucket is reduced with the hierarchical schedule (flat where the two
  coincide) as its own independent collective chain, so a scheduler can
  overlap bucket *k*'s sync with whatever produces bucket *k+1*.

* ``mode="eventual"`` — the paper's *eventual consistency* model
  (§2.3), on-mesh: the level-1 (intra-pod) reduction still runs every
  step, but each bucket's level-2 cross-pod exchange runs only on its
  scheduled step — a round-robin over ``max_staleness + 1`` phases —
  and off-schedule steps reuse the *stale* remote-pod contribution held
  in per-bucket versioned state (:class:`EventualSync`; DESIGN.md §15).
  Steady-state cross-pod bytes shrink by ``max_staleness + 1``×, and at
  ``max_staleness=0`` the schedule degenerates to the sequential
  (hierarchical) chain bit-for-bit.

All modes produce identical sums (eventual: identical at staleness 0,
bounded-staleness otherwise); the hierarchical HLO's cross-pod
all-reduce moves 1/|data| of the bytes — the §3.3 claim, checked from the
compiled HLO by ``tests/test_dist.py`` and benchmarked by
``benchmarks/bench_dist.py`` (which also checks that the per-bucket
cross-pod bytes sum back to the monolithic hierarchical total, and that
the per-phase eventual bytes match the analytic staleness model exactly).

Worked example (1-device fallback — runs anywhere)::

    >>> import jax, jax.numpy as jnp
    >>> mesh = jax.make_mesh((1,), ("data",))
    >>> grads = {"w": jnp.ones((4, 3))}      # 4 workers, one 3-vector each
    >>> out = gradient_sync(mesh, grads, mode="bucketed")
    >>> out["w"].tolist()                    # leading-dim sum, same tree
    [4.0, 4.0, 4.0]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import obs

from . import compat
from .annotate import DATA_AXES
from .bucketing import DEFAULT_BUCKET_BYTES, BucketPlan

MODES = ("flat", "hierarchical", "bucketed", "eventual")


def worker_axes(mesh):
    """The mesh axes whose product is the gradient-worker count."""
    return tuple(a for a in DATA_AXES if a in mesh.axis_names)


def _flat_body(waxes):
    def sync(g):
        return jax.lax.psum(jnp.squeeze(g, 0), waxes)
    return sync


def _hier_body(n_data):
    def sync(g):
        g = jnp.squeeze(g, 0)
        shape, size = g.shape, g.size
        flat = g.reshape(-1)
        pad = (-size) % n_data
        if pad:
            flat = jnp.pad(flat, (0, pad))
        # level-1 reduce-scatter within the pod, spelled as all-to-all +
        # local sum (XLA backends without native reduce-scatter decompose
        # psum_scatter into a FULL-size all-reduce, which would defeat the
        # schedule); after this each data rank holds a 1/|data| summed shard
        with obs.named_scope("l1_reduce_scatter"):
            chunks = flat.reshape(n_data, -1)
            received = jax.lax.all_to_all(chunks, "data", split_axis=0,
                                          concat_axis=0, tiled=False)
            shard = received.sum(0)
        # level-2: only the 1/|data| shard crosses the pod boundary
        with obs.named_scope("l2_cross_pod"):
            shard = jax.lax.psum(shard, "pod")
        with obs.named_scope("l1_all_gather"):
            gathered = jax.lax.all_gather(shard, "data",
                                          axis=0)  # (n_data, c)
        full = gathered.reshape(-1)
        if pad:
            full = full[:size]
        return full.reshape(shape)
    return sync


def gradient_sync(mesh, grads, mode: str = "flat", *,
                  bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                  plan: BucketPlan | None = None):
    """Sum a pytree of per-worker gradients over their leading worker dim.

    Every leaf of ``grads`` has shape ``(W, ...)`` with ``W`` the product
    of the mesh's worker axes (``pod`` × ``data``); the result is the
    leading-dim sum, replicated over the mesh.  ``mode="hierarchical"``
    falls back to flat when the mesh has no ``pod`` axis or no multi-way
    ``data`` axis (the two schedules coincide there).

    ``mode="bucketed"`` packs the leaves into ``bucket_bytes``-capped
    buckets (``plan`` overrides the packing; its byte accounting is
    per-worker, i.e. excludes the leading ``W`` dim) and reduces each
    bucket with the hierarchical schedule as an independent collective
    chain.  Numerically identical to the other modes.

    ``mode="eventual"`` is the *stateless* entry to the bounded-staleness
    schedule: a single isolated sync always starts warm (every bucket's
    cross-pod exchange is fresh), so it coincides with ``bucketed``
    bit-for-bit.  Steady-state staleness lives across steps — hold an
    :class:`EventualSync` and thread its state for that.
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    if mode in ("bucketed", "eventual"):
        leaves, treedef = jax.tree.flatten(grads)
        plan = plan or BucketPlan.build(leaves, cap_bytes=bucket_bytes,
                                        lead_dims=1)
        buffers = plan.pack(leaves, lead_dims=1)
        synced = gradient_sync(mesh, buffers, mode="hierarchical")
        return treedef.unflatten(plan.unpack(synced, leaves, lead_dims=1))
    waxes = worker_axes(mesh)
    sizes = dict(mesh.shape)
    n_workers = 1
    for a in waxes:
        n_workers *= sizes[a]
    if not waxes or n_workers == 1 or mesh.size == 1:
        return jax.tree.map(lambda g: g.sum(0), grads)
    for g in jax.tree.leaves(grads):
        if g.shape[0] != n_workers:
            raise ValueError(
                f"gradient leaf has leading dim {g.shape[0]}, expected the "
                f"worker count {n_workers} (= product of mesh axes {waxes})")
    if (mode == "hierarchical" and "pod" in mesh.axis_names
            and sizes.get("data", 1) > 1):
        body = _hier_body(sizes["data"])
    else:
        # single-pod or no intra-pod data axis: the two schedules coincide
        body = _flat_body(waxes)
    def tree_sync(t):
        # one named scope per leaf: under mode="bucketed" the leaves ARE
        # the packed buckets, so a device profile shows each bucket's
        # collective chain (grad_sync_b0, grad_sync_b1, ...) as the
        # independent region a scheduler may overlap with compute
        leaves, treedef = jax.tree.flatten(t)
        out = []
        for k, g in enumerate(leaves):
            with obs.named_scope(f"grad_sync_b{k}"):
                out.append(body(g))
        return treedef.unflatten(out)

    # all axes manual (inputs have no "model" dim; full-manual also works
    # eagerly, where partial-auto does not on older jax)
    sync = compat.shard_map(tree_sync, mesh,
                            in_specs=(P(waxes),), out_specs=P())
    return sync(grads)


# ---------------------------------------------------------------------------
# eventual consistency: bounded-staleness cross-pod sync (DESIGN.md §15)

def eventual_sync_buckets(n_buckets: int, max_staleness: int,
                          phase: int, warm: bool = False) -> tuple[int, ...]:
    """Bucket indices whose cross-pod exchange runs at ``phase``.

    The schedule is a static round-robin over ``max_staleness + 1``
    phases: bucket *b* syncs when ``b % period == phase``.  A ``warm``
    step (the first step of a run) syncs every bucket, so no bucket ever
    serves an uninitialized remote contribution.

    >>> eventual_sync_buckets(4, 1, 0)
    (0, 2)
    >>> eventual_sync_buckets(4, 3, 2)
    (2,)
    >>> eventual_sync_buckets(4, 3, 1, warm=True)
    (0, 1, 2, 3)
    """
    period = max_staleness + 1
    if warm:
        return tuple(range(n_buckets))
    return tuple(b for b in range(n_buckets) if b % period == phase % period)


def _bucket_shard_elems(bucket, n_data: int) -> int:
    """Per-device level-2 shard length of one bucket: the per-worker
    payload padded up to a multiple of the intra-pod ``data`` axis."""
    return -(-bucket.n_elems // max(n_data, 1))


def eventual_crosspod_bytes(plan: BucketPlan, n_data: int, *,
                            max_staleness: int, phase: int | None = None,
                            warm: bool = False) -> int:
    """Analytic cross-pod all-reduce *result* bytes of one eventual-sync
    step (the quantity ``benchmarks/bench_dist.py`` reads off the
    compiled HLO): each syncing bucket contributes its 1/``n_data``
    level-2 shard.  ``phase=None`` with ``warm=True`` is the first-step
    full sync (== the monolithic hierarchical total for the same plan).
    """
    idx = eventual_sync_buckets(plan.n_buckets, max_staleness,
                                0 if phase is None else phase, warm=warm)
    return sum(_bucket_shard_elems(plan.buckets[b], n_data)
               * jnp.dtype(plan.buckets[b].dtype).itemsize for b in idx)


def eventual_state_bytes(plan: BucketPlan, n_data: int,
                         n_workers: int) -> dict:
    """Device bytes of the :class:`EventualSync` remote-shard state: one
    1/``n_data`` shard per bucket per worker (``core/memplan`` re-exports
    this for footprint reports; exact vs the real state arrays)."""
    per_worker = sum(_bucket_shard_elems(b, n_data)
                     * jnp.dtype(b.dtype).itemsize for b in plan.buckets)
    return {"per_worker": per_worker, "total": per_worker * n_workers,
            "n_buckets": plan.n_buckets}


class EventualSync:
    """Bounded-staleness cross-pod gradient sync (MXNet §2.3 eventual
    consistency, on-mesh; DESIGN.md §15).

    Holds a :class:`BucketPlan` over the gradient leaves plus *versioned
    per-bucket state*: for every bucket, each worker keeps the stale
    remote-pod level-2 shard it received at that bucket's last scheduled
    exchange, and a host-side version (the step of that exchange).  Per
    step:

    * level-1 always runs — reduce-scatter within the pod's ``data``
      axis, so each worker holds a fresh 1/|data| shard of its *pod's*
      sum (the cheap intra-machine traffic);
    * level-2 runs only for the buckets scheduled at this step's phase
      (``step % (max_staleness + 1)``): those push their shard across
      ``pod``, receive the fresh global shard, and store
      ``global − local`` as the new remote state (the versioned
      push/pull).  Off-schedule buckets *pull* their stale remote shard
      from state instead — zero cross-pod bytes;
    * an all-gather within ``data`` restores the full replica either way.

    Scheduled buckets return the fresh global shard itself (not
    ``local + (global − local)``), so ``max_staleness=0`` — every bucket
    scheduled every step — reproduces ``gradient_sync(mode="bucketed")``
    bit-for-bit.  Observed staleness is ``step − version`` and never
    exceeds ``max_staleness`` (warm first step + round-robin period;
    property-tested in ``tests/test_eventual.py``).

    On a mesh without a multi-way ``pod`` axis there is no cross-pod
    boundary to be stale over: the sync degenerates to the every-step
    flat/hierarchical sum with empty state (``degenerate`` is True).

    Usage (``apply`` is traceable — call it inside an enclosing jit with
    a static ``phase``; ``phase_for``/``record_step`` do the host-side
    bookkeeping)::

        ev = EventualSync(mesh, grads_template, max_staleness=2)
        state = ev.init_state()
        for step in range(n_steps):
            phase, warm = ev.phase_for(step)
            synced, state = jitted[phase, warm](grads, state)
            ev.record_step(step)
    """

    def __init__(self, mesh, template, *, max_staleness: int = 0,
                 bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                 plan: BucketPlan | None = None):
        if max_staleness < 0:
            raise ValueError(f"max_staleness must be >= 0, "
                             f"got {max_staleness}")
        self.mesh = mesh
        self.max_staleness = max_staleness
        self.period = max_staleness + 1
        leaves, self.treedef = jax.tree.flatten(template)
        self.waxes = worker_axes(mesh)
        sizes = dict(mesh.shape)
        self.n_workers = 1
        for a in self.waxes:
            self.n_workers *= sizes[a]
        self.n_data = sizes.get("data", 1) if "data" in mesh.axis_names else 1
        self.n_pod = sizes.get("pod", 1) if "pod" in mesh.axis_names else 1
        # no multi-way pod axis -> no cross-pod boundary -> nothing to be
        # stale over; 1-worker/1-device meshes also have nothing to sync
        self.degenerate = (self.n_pod <= 1 or not self.waxes
                          or self.n_workers == 1 or mesh.size == 1)
        for g in leaves:
            if not self.degenerate and g.shape[0] != self.n_workers:
                raise ValueError(
                    f"gradient leaf has leading dim {g.shape[0]}, expected "
                    f"the worker count {self.n_workers}")
        self.plan = plan or BucketPlan.build(leaves, cap_bytes=bucket_bytes,
                                            lead_dims=1)
        self.n_buckets = self.plan.n_buckets
        # host-side versioning: step of each bucket's last level-2
        # exchange; None until the warm first step runs
        self.versions: list[int | None] = [None] * self.n_buckets
        self.max_observed_staleness = 0
        self._started = False

    # -- schedule ----------------------------------------------------------
    def phase_for(self, step: int) -> tuple[int, bool]:
        """``(phase, warm)`` for a step — both Python ints/bools, meant to
        select a jit-specialized variant (the schedule is static)."""
        return step % self.period, not self._started

    def sync_buckets(self, phase: int, warm: bool = False) -> tuple[int, ...]:
        if self.degenerate:
            return tuple(range(self.n_buckets))
        return eventual_sync_buckets(self.n_buckets, self.max_staleness,
                                     phase, warm=warm)

    def record_step(self, step: int) -> int:
        """Host bookkeeping after running a step: advance per-bucket
        versions, publish per-mode obs counters, and return the maximum
        staleness observed at this step."""
        phase, warm = self.phase_for(step)
        synced = set(self.sync_buckets(phase, warm=warm))
        stale = 0
        for b in range(self.n_buckets):
            if b in synced or self.versions[b] is None:
                self.versions[b] = step
            else:
                stale = max(stale, step - self.versions[b])
        self.max_observed_staleness = max(self.max_observed_staleness, stale)
        self._started = True
        m = obs.get_metrics()
        m.counter("dist.sync.eventual.steps").inc()
        m.counter("dist.sync.eventual.crosspod_bytes").inc(
            self.crosspod_allreduce_bytes(phase, warm=warm))
        m.gauge("dist.sync.eventual.max_staleness_observed").set(
            self.max_observed_staleness)
        return stale

    # -- analytic byte/state models ---------------------------------------
    def crosspod_allreduce_bytes(self, phase: int, warm: bool = False) -> int:
        """Cross-pod all-reduce result bytes this phase's compiled step
        moves (0 on degenerate meshes) — the HLO-cross-validated model."""
        if self.degenerate:
            return 0
        return eventual_crosspod_bytes(self.plan, self.n_data,
                                       max_staleness=self.max_staleness,
                                       phase=phase, warm=warm)

    def state_bytes(self) -> dict:
        if self.degenerate:
            return {"per_worker": 0, "total": 0, "n_buckets": self.n_buckets}
        return eventual_state_bytes(self.plan, self.n_data, self.n_workers)

    # -- state -------------------------------------------------------------
    def init_state(self) -> dict:
        """Zero remote shards, laid out ``(W, shard)`` with the worker dim
        sharded over the worker axes (``make_array_from_callback`` so the
        same code works single- and multi-process)."""
        if self.degenerate:
            return {}
        from jax.sharding import NamedSharding
        sharding = NamedSharding(self.mesh, P(self.waxes))
        out = {}
        for k, bucket in enumerate(self.plan.buckets):
            shape = (self.n_workers, _bucket_shard_elems(bucket, self.n_data))
            dt = jnp.dtype(bucket.dtype)

            def zeros_shard(idx, shape=shape, dt=dt):
                local = tuple(len(range(*s.indices(n)))
                              for s, n in zip(idx, shape))
                return jnp.zeros(local, dt)

            out[f"b{k}"] = jax.make_array_from_callback(shape, sharding,
                                                        zeros_shard)
        return out

    # -- the sync itself ---------------------------------------------------
    def apply(self, grads, state, *, phase: int, warm: bool = False):
        """``(synced_grads, new_state)`` — traceable; ``phase``/``warm``
        are static (each pair lowers to a distinct collective schedule,
        which is what makes the per-phase HLO byte model exact)."""
        if self.degenerate:
            return gradient_sync(self.mesh, grads, mode="bucketed",
                                 plan=self.plan), state
        leaves = jax.tree.flatten(grads)[0]
        buffers = self.plan.pack(leaves, lead_dims=1)
        st = [state[f"b{k}"] for k in range(self.n_buckets)]
        syncing = set(self.sync_buckets(phase, warm=warm))
        n_data, has_data = self.n_data, "data" in self.mesh.axis_names

        def body(bufs, rems):
            out_b, out_r = [], []
            for k, (buf, rem) in enumerate(zip(bufs, rems)):
                tag = "push" if k in syncing else "stale"
                with obs.named_scope(f"ev_sync_b{k}_{tag}"):
                    g = jnp.squeeze(buf, 0)
                    remote = jnp.squeeze(rem, 0)
                    size = g.size
                    pad = (-size) % n_data
                    flat = jnp.pad(g, (0, pad)) if pad else g
                    if has_data and n_data > 1:
                        # level-1: reduce-scatter within the pod (all-to-all
                        # + local sum, as in the hierarchical schedule)
                        chunks = flat.reshape(n_data, -1)
                        received = jax.lax.all_to_all(
                            chunks, "data", split_axis=0, concat_axis=0,
                            tiled=False)
                        shard = received.sum(0)
                    else:
                        shard = flat
                    if k in syncing:
                        # level-2 push/pull: fresh global shard crosses
                        # the pod boundary; remote = global - local is the
                        # versioned pull served on off-schedule steps
                        out_shard = jax.lax.psum(shard, "pod")
                        new_remote = out_shard - shard
                    else:
                        out_shard = shard + remote
                        new_remote = remote
                    if has_data and n_data > 1:
                        gathered = jax.lax.all_gather(out_shard, "data",
                                                      axis=0)
                        full = gathered.reshape(-1)
                    else:
                        full = out_shard
                    if pad:
                        full = full[:size]
                    out_b.append(full)
                    out_r.append(new_remote[None])
            return tuple(out_b), tuple(out_r)

        n = self.n_buckets
        fn = compat.shard_map(
            body, self.mesh,
            in_specs=((P(self.waxes),) * n, (P(self.waxes),) * n),
            out_specs=((P(),) * n, (P(self.waxes),) * n))
        out_bufs, out_rems = fn(tuple(buffers), tuple(st))
        synced_leaves = self.plan.unpack(list(out_bufs), leaves, lead_dims=1)
        synced = self.treedef.unflatten(synced_leaves)
        return synced, {f"b{k}": out_rems[k] for k in range(n)}
