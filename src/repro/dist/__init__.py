"""repro.dist — sharding annotations, partition rules, on-mesh collectives.

The distribution layer of the reproduction (DESIGN.md §5, §7):

* ``annotate`` — per-tensor sharding constraints over a named mesh with a
  graceful no-mesh/1-device fallback (model code is annotation-transparent
  on CPU);
* ``partition`` — PartitionSpec rule tables for params / batches / caches
  covering every config in ``repro/configs``;
* ``collectives`` — ``gradient_sync``: flat vs the paper's §3.3 two-level
  (hierarchical) gradient all-reduce over a ``(pod, data, model)`` mesh,
  plus the bucketed overlap-friendly schedule and ``EventualSync`` — the
  §3.3 eventual-consistency KVStore as bounded-staleness cross-pod sync
  (round-robin bucket schedule, analytic byte/state models, DESIGN.md
  §15);
* ``bucketing`` — ``BucketPlan`` (first-fit byte-capped gradient packing)
  and ``overlap_taps`` (the custom_vjp trick that emits each bucket's
  sync inside the backward computation — the §4 lazy-push analogue);
* ``ring`` — sequence-sharded exact attention as a rotating k/v
  collective-permute schedule with a reverse-ring ``custom_vjp``
  (DESIGN.md §8), plus its analytic permute-byte model;
* ``pipeline`` — the "stage" mesh axis: layer-contiguous super-block
  groups with a 1F1B micro-batch schedule, collective-permute activation
  hand-offs and a reverse-schedule ``custom_vjp`` (DESIGN.md §10), plus
  its analytic bubble/permute-byte models;
* ``compat`` — backfills ``jax.set_mesh`` / ``jax.shard_map`` on older jax
  (imported first, for its side effects).

Worked example — the full surface on a dev box (1 device, so every
annotation is the identity and collectives degrade to local sums)::

    >>> import jax, jax.numpy as jnp
    >>> x = ann(jnp.ones((8, 16)), BATCH, "model")   # no mesh: identity
    >>> x.shape
    (8, 16)
    >>> mesh = jax.make_mesh((1,), ("data",))
    >>> grads = {"w": jnp.ones((4, 6)), "b": jnp.ones((4, 2))}
    >>> out = gradient_sync(mesh, grads, mode="bucketed")
    >>> {k: v.shape for k, v in sorted(out.items())}
    {'b': (2,), 'w': (6,)}
    >>> plan = BucketPlan.build(jax.tree.leaves(grads), cap_bytes=1 << 20,
    ...                         lead_dims=1)
    >>> plan.n_buckets, plan.assignment()
    (1, (0, 0))
"""
from . import compat  # noqa: F401  (installs jax API backfills)
from .annotate import BATCH, DATA_AXES, ann, ann_first_fit, _mesh_axes
from .bucketing import (DEFAULT_BUCKET_BYTES, Bucket, BucketPlan,
                        leaf_nbytes, overlap_taps)
from .collectives import (EventualSync, eventual_crosspod_bytes,
                          eventual_state_bytes, eventual_sync_buckets,
                          gradient_sync, worker_axes)
from .partition import (batch_pspecs, cache_pspecs, make_shardings,
                        param_pspecs)
from .pipeline import (PipelineSpec, pipeline_bubble_fraction,
                       pipeline_permute_bytes, pipeline_stack, stage_pspecs,
                       validate_pipeline)
from .ring import RingSpec, contributing_steps, ring_attention, \
    ring_permute_bytes

__all__ = [
    "BATCH", "DATA_AXES", "ann", "ann_first_fit", "_mesh_axes",
    "gradient_sync", "worker_axes", "EventualSync",
    "eventual_sync_buckets", "eventual_crosspod_bytes",
    "eventual_state_bytes",
    "Bucket", "BucketPlan", "DEFAULT_BUCKET_BYTES", "leaf_nbytes",
    "overlap_taps",
    "param_pspecs", "batch_pspecs", "cache_pspecs", "make_shardings",
    "PipelineSpec", "pipeline_bubble_fraction", "pipeline_permute_bytes",
    "pipeline_stack", "stage_pspecs", "validate_pipeline",
    "RingSpec", "contributing_steps", "ring_attention",
    "ring_permute_bytes",
]
