"""repro.dist — sharding annotations, partition rules, on-mesh collectives.

The distribution layer of the reproduction (DESIGN.md §5):

* ``annotate`` — per-tensor sharding constraints over a named mesh with a
  graceful no-mesh/1-device fallback (model code is annotation-transparent
  on CPU);
* ``partition`` — PartitionSpec rule tables for params / batches / caches
  covering every config in ``repro/configs``;
* ``collectives`` — ``gradient_sync``: flat vs the paper's §3.3 two-level
  (hierarchical) gradient all-reduce over a ``(pod, data, model)`` mesh;
* ``compat`` — backfills ``jax.set_mesh`` / ``jax.shard_map`` on older jax
  (imported first, for its side effects).
"""
from . import compat  # noqa: F401  (installs jax API backfills)
from .annotate import BATCH, DATA_AXES, ann, ann_first_fit, _mesh_axes
from .collectives import gradient_sync, worker_axes
from .partition import (batch_pspecs, cache_pspecs, make_shardings,
                        param_pspecs)

__all__ = [
    "BATCH", "DATA_AXES", "ann", "ann_first_fit", "_mesh_axes",
    "gradient_sync", "worker_axes",
    "param_pspecs", "batch_pspecs", "cache_pspecs", "make_shardings",
]
