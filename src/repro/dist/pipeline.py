"""Pipeline parallelism over the super-block stack (DESIGN.md §10).

The third parallelism axis of the reproduction, after data (PR 1/2) and
sequence (PR 3): a ``"stage"`` mesh axis carries layer-contiguous groups
of super-blocks (the ``lax.scan`` stack is already stage-shaped — shard
its leading scan dim and each stage holds ``n_super / pp`` super-blocks),
and microbatches stream through the stages on a 1F1B fill–drain schedule
spelled as ``collective_permute`` activation hand-offs between adjacent
stages.  This is the on-mesh counterpart of the device-placement layer
split TensorFlow's white paper motivates (Abadi et al., 2016) and of the
paper's own §3/§4 claim that one dependency-engine abstraction covers
heterogeneous topologies.

Schedule (forward): ``T = M + pp - 1`` ticks.  At tick ``t`` stage ``s``
runs microbatch ``m = t - s`` (when ``0 <= m < M``); between ticks the
stage output permutes one hop down the stage ring — ``T - 1`` permutes
of one microbatch activation each.  The idle corner ticks are the bubble:
``pipeline_bubble_fraction = (pp - 1) / (pp - 1 + M)``.

Backward is a ``jax.custom_vjp`` running the schedule in *reverse*:
activation cotangents enter at the last stage and permute backward hop
by hop while each stage recomputes its block group from the saved stage
*inputs* (O(M·b·S·D) residuals per stage — the remat discipline of §3.1
applied at the stage boundary) and accumulates its local parameter
gradients.  Parameter grads reduce over the data axes *inside* the
backward body — never over ``stage``: each stage owns its layer slice
(which is why ``gradient_sync``'s worker axes exclude ``stage`` and the
bucketed overlap taps skip the block stack under pp — DESIGN.md §10).

``pipeline_permute_bytes`` is the analytic per-device collective-permute
byte model mirroring ``ring_permute_bytes``;
``benchmarks/bench_pipeline.py`` cross-validates it against the compiled
HLO exactly and gates pp∈{1,2,4} loss/grad parity.

The stage bodies run under a fully-manual ``shard_map`` (the partial-auto
partitioner is not reliable on the jax this container bakes in), so
sharding annotations inside the stage computation are suppressed
(``annotate.suppressed``) — model-axis tensor parallelism inside a stage
is future work; pp composes with data parallelism today.

Worked example (pure schedule math — runs anywhere)::

    >>> pipeline_bubble_fraction(4, 12)
    0.2
    >>> m = pipeline_permute_bytes(2, 64, 128, n_stages=4, microbatches=8,
    ...                            itemsize=4)
    >>> m["fwd_permutes"], m["fwd_total"] == 10 * 2 * 64 * 128 * 4
    (10, True)
    >>> m["grad_total"] == 2 * m["fwd_total"]
    True
    >>> pipeline_permute_bytes(2, 64, 128, n_stages=1,
    ...                        microbatches=8)["grad_total"]
    0
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro import obs

from . import compat
from .annotate import BATCH, DATA_AXES, _resolve, suppressed


@dataclass(frozen=True)
class PipelineSpec:
    """Static (hashable) configuration of one pipelined stack call."""
    n_stages: int
    microbatches: int
    axis: str = "stage"
    data_axes: tuple[str, ...] = ()
    n_data: int = 1

    @property
    def ticks(self) -> int:
        return self.microbatches + self.n_stages - 1


# ---------------------------------------------------------------------------
# analytic models (cross-validated by benchmarks/bench_pipeline.py)

def pipeline_bubble_fraction(n_stages: int, microbatches: int) -> float:
    """Idle fraction of the stage×tick grid, per direction: ``pp - 1`` of
    the ``M + pp - 1`` ticks on every stage are fill/drain bubble."""
    if n_stages < 1 or microbatches < 1:
        raise ValueError(f"need n_stages >= 1 and microbatches >= 1, got "
                         f"{n_stages}, {microbatches}")
    return (n_stages - 1) / (n_stages - 1 + microbatches)


def pipeline_permute_bytes(b: int, S: int, D: int, *, n_stages: int,
                           microbatches: int, itemsize: int = 2) -> dict:
    """Analytic per-device collective-permute bytes of one pipelined stack.

    ``b`` is the per-device microbatch rows: ``global_batch / microbatches
    / (product of data-axis shards)``.  Forward permutes the ``(b, S, D)``
    activation once per tick except the last — ``M + pp - 2`` hops; the
    reverse schedule permutes the activation cotangent the same number of
    hops.  ``n_stages == 1`` degenerates to zero permutes (the sequential
    fallback).  Cross-validated against compiled HLO exactly by
    ``benchmarks/bench_pipeline.py``.
    """
    payload = b * S * D * itemsize
    hops = 0 if n_stages == 1 else microbatches + n_stages - 2
    fwd = hops * payload
    return {
        "payload_bytes": payload,
        "fwd_permutes": hops,
        "bwd_permutes": hops,
        "fwd_total": fwd,
        "bwd_total": fwd,
        "grad_total": 2 * fwd,
    }


def validate_pipeline(*, n_stages: int, microbatches: int,
                      n_super: int | None = None, batch: int | None = None,
                      n_data: int = 1, seq_shard: bool = False) -> None:
    """Raise ValueError for configurations the schedule cannot run.

    ``n_data``: product of the mesh's data axes.  Unlike the rest of the
    codebase, where a non-dividing axis degrades to replicated safely,
    the pipeline body runs fully-manual: a dropped data axis would make
    every data shard compute the full microbatch while the backward still
    psums block grads over ``data`` — silently ``n_data``-times-too-large
    gradients — so indivisibility is an error here, never a fallback.
    """
    if n_stages < 1:
        raise ValueError(f"pp_stages must be >= 1, got {n_stages}")
    if microbatches < 1:
        raise ValueError(f"microbatches must be >= 1, got {microbatches}")
    if n_super is not None and n_super % n_stages:
        raise ValueError(
            f"n_super={n_super} super-blocks do not split into "
            f"pp_stages={n_stages} layer-contiguous stage groups; pick a "
            f"stage count dividing the stack depth")
    if batch is not None and batch % microbatches:
        raise ValueError(
            f"global batch {batch} not divisible by "
            f"microbatches={microbatches}")
    if batch is not None and (batch // microbatches) % n_data:
        raise ValueError(
            f"per-microbatch batch {batch}//{microbatches}="
            f"{batch // microbatches} not divisible by the data-axis "
            f"product {n_data}; inside the fully-manual stage region a "
            f"dropped data axis would corrupt block gradients "
            f"(DESIGN.md §10), so pick a dividing microbatch count")
    if seq_shard and n_stages > 1:
        raise ValueError(
            "pp_stages > 1 does not compose with PerfFlags.seq_shard: the "
            "stage schedule runs fully-manual over the mesh, which excludes "
            "the ring path's own shard_map (DESIGN.md §10); drop one")


def stage_pspecs(cfg, params, mesh, axis: str = "stage"):
    """Partition rules for pipeline-parallel params: the stacked scan dim
    of ``blocks`` leaves is sharded over the ``stage`` mesh axis (each
    stage owns a layer-contiguous group of super-blocks); everything else
    follows ``param_pspecs`` unchanged."""
    from .partition import param_pspecs
    return param_pspecs(cfg, params, mesh, stage_axis=axis)


# ---------------------------------------------------------------------------
# the schedule (per-device bodies; custom_vjp at the global boundary)

def _fwd_body(spec: PipelineSpec, stage_fn, params_local, xm):
    """Forward 1F1B fill–drain on one device.  ``xm``: (M, b, S, D) local
    microbatches; ``params_local``: this stage's super-block slice.
    Returns (out (M, b, S, D) — the last stage's outputs, replicated over
    ``stage`` via psum; aux scalars summed over stage×data; saved stage
    inputs (1, M, b, S, D) — the backward residuals)."""
    s = jax.lax.axis_index(spec.axis)
    M, n = spec.microbatches, spec.n_stages
    first = s == 0
    last = s == n - 1
    buf = jnp.zeros(xm.shape[1:], xm.dtype)
    outs = jnp.zeros_like(xm)
    saved = jnp.zeros_like(xm)
    aux_tot = None
    perm = [(i, i + 1) for i in range(n - 1)]
    for t in range(spec.ticks):
        # named scope per tick: a device profile shows each fill/steady/
        # drain tick's stage compute + hand-off under one label
        with obs.named_scope(f"pp_fwd_t{t}"):
            m = t - s                               # traced (device-varying)
            active = (m >= 0) & (m < M)
            mc = jnp.clip(m, 0, M - 1)
            inject = xm[t] if t < M else jnp.zeros_like(buf)
            cur = jnp.where(first, inject, buf)
            saved = jnp.where(
                active,
                jax.lax.dynamic_update_index_in_dim(saved, cur, mc, 0),
                saved)
            y, aux = stage_fn(params_local, cur)
            aux = jax.tree.map(lambda a: jnp.where(active, a, 0.0), aux)
            aux_tot = aux if aux_tot is None else jax.tree.map(
                jnp.add, aux_tot, aux)
            outs = jnp.where(
                active & last,
                jax.lax.dynamic_update_index_in_dim(outs, y, mc, 0),
                outs)
            if t < spec.ticks - 1:
                # hand the stage output one hop down the stage ring; the
                # next tick's compute is independent, so the scheduler can
                # overlap
                buf = jax.lax.ppermute(jnp.where(active, y, 0.0), spec.axis,
                                       perm)
    out = jax.lax.psum(outs, spec.axis)             # nonzero on last stage
    aux_tot = jax.tree.map(
        lambda a: jax.lax.psum(a, (spec.axis,) + spec.data_axes), aux_tot)
    return out, aux_tot, saved[None]


def _bwd_body(spec: PipelineSpec, stage_fn, params_local, saved, dy, daux):
    """Reverse schedule on one device: cotangents enter at the last stage
    and permute backward while each stage recomputes its block group from
    the saved inputs (remat) and accumulates local param grads (f32)."""
    s = jax.lax.axis_index(spec.axis)
    M, n = spec.microbatches, spec.n_stages
    first = s == 0
    last = s == n - 1
    saved = saved[0]                                 # (M, b, S, D)
    dbuf = jnp.zeros(dy.shape[1:], dy.dtype)
    dx = jnp.zeros_like(dy)
    dparams = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                           params_local)
    perm = [(i, i - 1) for i in range(1, n)]
    for t in reversed(range(spec.ticks)):
        with obs.named_scope(f"pp_bwd_t{t}"):
            m = t - s
            active = (m >= 0) & (m < M)
            mc = jnp.clip(m, 0, M - 1)
            x_in = jax.lax.dynamic_index_in_dim(saved, mc, 0, keepdims=False)
            d_out = jnp.where(last,
                              jax.lax.dynamic_index_in_dim(dy, mc, 0,
                                                           keepdims=False),
                              dbuf)
            d_out = jnp.where(active, d_out, 0.0)
            daux_m = jax.tree.map(lambda a: jnp.where(active, a, 0.0), daux)
            _, pullback = jax.vjp(stage_fn, params_local, x_in)
            dp, dxi = pullback((d_out, daux_m))
            dparams = jax.tree.map(
                lambda acc, g:
                acc + jnp.where(active, g, 0.0).astype(acc.dtype),
                dparams, dp)
            dx = jnp.where(
                first & active,
                jax.lax.dynamic_update_index_in_dim(dx, dxi.astype(dx.dtype),
                                                    mc, 0),
                dx)
            if t > 0:
                dbuf = jax.lax.ppermute(jnp.where(active, dxi, 0.0),
                                        spec.axis, perm)
    if spec.data_axes:
        # grads reduce over the data axes only — never over stage: each
        # stage owns its layer-contiguous param slice (DESIGN.md §10)
        dparams = jax.tree.map(
            lambda g: jax.lax.psum(g, spec.data_axes), dparams)
    dparams = jax.tree.map(lambda g, p: g.astype(p.dtype), dparams,
                           params_local)
    dx = jax.lax.psum(dx, spec.axis)                 # nonzero on stage 0
    return dparams, dx


def _pipeline_specs(spec: PipelineSpec, stage_params, x_mb, mesh):
    """(param, microbatch, saved) in/out spec pytrees for the shard_map."""
    names, sizes = tuple(mesh.axis_names), dict(mesh.shape)

    def pleaf(leaf):
        ent = (spec.axis,) + (None,) * (len(leaf.shape) - 1)
        return _resolve(ent, leaf.shape, names, sizes)

    p_specs = jax.tree.map(pleaf, stage_params)
    x_ent = (None, BATCH) + (None,) * (x_mb.ndim - 2)
    x_spec = _resolve(x_ent, x_mb.shape, names, sizes)
    save_spec = _resolve((spec.axis,) + x_ent,
                         (spec.n_stages,) + x_mb.shape, names, sizes)
    return p_specs, x_spec, save_spec


def _fwd_call(spec: PipelineSpec, stage_fn, stage_params, x_mb):
    mesh = compat.current_mesh()
    p_specs, x_spec, save_spec = _pipeline_specs(spec, stage_params, x_mb,
                                                 mesh)
    from jax.sharding import PartitionSpec as P
    aux_spec = jax.tree.map(lambda _: P(),
                            jax.eval_shape(stage_fn,
                                           stage_params, x_mb[0])[1])

    def body(p, xm):
        with suppressed():
            return _fwd_body(spec, stage_fn, p, xm)

    f = compat.shard_map(body, mesh, in_specs=(p_specs, x_spec),
                         out_specs=(x_spec, aux_spec, save_spec))
    return f(stage_params, x_mb)


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _pipeline(spec: PipelineSpec, stage_fn, stage_params, x_mb):
    out, aux, _ = _fwd_call(spec, stage_fn, stage_params, x_mb)
    return out, aux


def _pipeline_fwd(spec, stage_fn, stage_params, x_mb):
    out, aux, saved = _fwd_call(spec, stage_fn, stage_params, x_mb)
    return (out, aux), (stage_params, saved)


def _pipeline_bwd(spec, stage_fn, res, cot):
    stage_params, saved = res
    dy, daux = cot
    mesh = compat.current_mesh()
    p_specs, x_spec, save_spec = _pipeline_specs(spec, stage_params, dy,
                                                 mesh)
    from jax.sharding import PartitionSpec as P
    aux_spec = jax.tree.map(lambda _: P(), daux)

    def body(p, sv, d, da):
        with suppressed():
            return _bwd_body(spec, stage_fn, p, sv, d, da)

    f = compat.shard_map(body, mesh,
                         in_specs=(p_specs, save_spec, x_spec, aux_spec),
                         out_specs=(p_specs, x_spec))
    return f(stage_params, saved, dy, daux)


_pipeline.defvjp(_pipeline_fwd, _pipeline_bwd)


# ---------------------------------------------------------------------------
# public entry point

def pipeline_stack(stage_fn, stage_params, x, *, microbatches: int,
                   axis: str = "stage", mesh=None):
    """Run a stacked layer group through the 1F1B stage pipeline.

    ``stage_fn(params_slice, x) -> (y, aux)`` applies one stage's
    super-block slice to a ``(b, S, D)`` activation; ``aux`` is a pytree
    of f32 scalars (MoE losses) that is *summed over stages* and *averaged
    over microbatches and data shards* — matching the unpipelined
    ``run_stack`` semantics for token-mean auxiliaries.  ``stage_params``
    leaves carry the leading scan dim, sharded over ``axis`` so each stage
    holds a layer-contiguous slice.

    Without an ambient mesh (or a 1-sized / absent ``axis``) the schedule
    degenerates to a sequential microbatch loop over the full stack — the
    CPU smoke path, and the oracle the mesh tests compare against.
    Differentiable via the reverse-schedule ``custom_vjp``.
    """
    B = x.shape[0]
    M = microbatches
    validate_pipeline(n_stages=1, microbatches=M, batch=B)
    mesh = mesh or compat.current_mesh()
    n = int(mesh.shape[axis]) if (mesh is not None
                                  and axis in mesh.axis_names) else 1
    lead = {int(leaf.shape[0]) for leaf in jax.tree.leaves(stage_params)}
    if len(lead) != 1:
        raise ValueError(f"stage_params leaves disagree on the scan dim: "
                         f"{sorted(lead)}")
    validate_pipeline(n_stages=n, microbatches=M, n_super=lead.pop(),
                      batch=B)
    x_mb = x.reshape((M, B // M) + x.shape[1:])
    if n == 1:
        outs, aux = [], None
        for m in range(M):
            y, a = stage_fn(stage_params, x_mb[m])
            outs.append(y)
            aux = a if aux is None else jax.tree.map(jnp.add, aux, a)
        y = jnp.concatenate(outs, 0) if M > 1 else outs[0]
        return y, jax.tree.map(lambda t: t / M, aux)
    data_axes = tuple(a for a in DATA_AXES if a in mesh.axis_names)
    n_data = 1
    for a in data_axes:
        n_data *= int(mesh.shape[a])
    # indivisible batches are an error here, not a replication fallback:
    # the backward psums block grads over the data axes
    validate_pipeline(n_stages=n, microbatches=M, batch=B, n_data=n_data)
    spec = PipelineSpec(n_stages=n, microbatches=M, axis=axis,
                        data_axes=data_axes, n_data=n_data)
    y, aux = _pipeline(spec, stage_fn, stage_params, x_mb)
    y = y.reshape((B,) + x.shape[1:])
    # psum over stage+data made aux a raw sum; restore the token-mean scale
    aux = jax.tree.map(lambda t: t / (spec.n_data * M), aux)
    return y, aux
