"""jax version compatibility for the distribution layer.

The repo is written against the modern sharding surface — ``jax.set_mesh``
installing an ambient mesh, ``jax.shard_map`` resolving it implicitly, and
sharding constraints expressed as bare ``PartitionSpec``s.  Older jax
(0.4.x, the toolchain baked into this container) predates those entry
points, so importing this module backfills them:

* ``jax.set_mesh(mesh)`` returns the mesh itself; ``Mesh`` is a context
  manager that installs the legacy resource env, which is exactly the
  ambient-mesh behaviour the callers rely on;
* ``jax.shard_map(f, in_specs=..., out_specs=..., axis_names=...,
  check_vma=...)`` wraps ``jax.experimental.shard_map.shard_map``,
  resolving the ambient mesh at trace time and mapping ``axis_names`` onto
  the legacy ``auto`` set (axes *not* named stay under the partitioner).

``current_mesh()`` is the single place the rest of the package asks "what
mesh am I under?" — it returns the ambient concrete mesh or ``None``, on
every jax version we target.
"""
from __future__ import annotations

import jax


def current_mesh():
    """The ambient concrete mesh (``jax.set_mesh`` / ``with mesh:``), or
    ``None`` when no mesh is installed."""
    try:
        from jax._src import mesh as mesh_lib
    except Exception:  # pragma: no cover - future jax reshuffles internals
        mesh_lib = None
    if mesh_lib is not None:
        get_concrete = getattr(mesh_lib, "get_concrete_mesh", None)
        if get_concrete is not None:
            try:
                m = get_concrete()
                # older jax returns () for "no mesh set"
                if isinstance(m, jax.sharding.Mesh) and not m.empty:
                    return m
            except Exception:
                pass
        tr = getattr(mesh_lib, "thread_resources", None)
        if tr is not None:
            m = tr.env.physical_mesh
            if isinstance(m, jax.sharding.Mesh) and not m.empty:
                return m
    return None


def shard_map(f, mesh, in_specs, out_specs, axis_names=None):
    """Version-portable shard_map over ``mesh``.

    ``axis_names``: the axes the body addresses collectively (manual);
    every other mesh axis is left to the partitioner (legacy ``auto``).
    Replication checking is disabled — the dispatch bodies here mix manual
    batch axes with auto model axes, which the checker cannot track.
    """
    manual = frozenset(axis_names) if axis_names else frozenset(mesh.axis_names)
    auto = frozenset(mesh.axis_names) - manual
    modern = getattr(jax, "shard_map", None)
    if modern is not None and modern is not _shard_map_backfill:
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
        try:
            return modern(f, check_vma=False, **kw)
        except TypeError:  # pre-check_vma spelling
            return modern(f, check_rep=False, **kw)
    from jax.experimental.shard_map import shard_map as legacy
    return legacy(f, mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False, auto=auto)


def _shard_map_backfill(f, mesh=None, in_specs=None, out_specs=None,
                        axis_names=None, check_vma=True, **_kw):
    """Ambient-mesh ``jax.shard_map`` for jax versions without it."""
    def wrapped(*args):
        m = mesh or current_mesh()
        if m is None:
            raise ValueError(
                "jax.shard_map: no mesh passed and no ambient mesh installed "
                "(enter `with jax.set_mesh(mesh):` first)")
        return shard_map(f, m, in_specs, out_specs,
                         axis_names=axis_names)(*args)
    return wrapped


def _install_backfills():
    if not hasattr(jax, "make_mesh"):  # pragma: no cover - jax >= 0.4.35
        def _make_mesh(shape, axis_names):
            from jax.experimental import mesh_utils
            devs = mesh_utils.create_device_mesh(tuple(shape))
            return jax.sharding.Mesh(devs, tuple(axis_names))
        jax.make_mesh = _make_mesh
    if not hasattr(jax, "set_mesh"):
        # Mesh is its own context manager; returning it makes
        # `with jax.set_mesh(mesh):` install the ambient resource env.
        jax.set_mesh = lambda mesh: mesh
    if not hasattr(jax, "shard_map"):
        jax.shard_map = _shard_map_backfill


_install_backfills()
