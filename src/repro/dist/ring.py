"""Ring attention: exact attention over a sequence-sharded batch
(DESIGN.md §8; the §3.3/§4 overlap idea applied to the attention inner
loop).

Every device holds one contiguous sequence chunk of q/k/v (S/P tokens,
P = size of the ring mesh axis).  Attention over the full sequence is P
sequential block-exchanges: each step updates the local queries' online
softmax state (m, l, acc) against the currently-resident k/v chunk, then
collective-permutes k/v one hop around the ring — the permute of step
t+1's chunk is independent of step t's flash compute, so XLA's
latency-hiding scheduler overlaps them (overlap condition: DESIGN.md §8).
The per-device score footprint is one (S/P, S/P) block per head instead
of (S, S): summed over the mesh that is O(S·S/P) versus O(S²·P) —
the only change that makes ``long_500k`` representable at all.

Rotation-index bookkeeping: after t forward hops, device ``i`` holds the
chunk that *originated* on device ``(i - t) mod P``, so its keys live at
global positions ``src·(S/P) + local``.  Causal and sliding-window masks
only consume the *difference* ``qpos - kpos``, whose chunk part is the
static value ``t`` (for ``i ≥ t``) or ``t - P`` (wrapped, i.e. a future
chunk) — which is what lets the Pallas flash kernel, whose mask offsets
are compile-time constants, run unchanged as the per-step inner kernel
(``jax.lax.cond`` selects between the two static variants).

The backward pass is a ``jax.custom_vjp`` running the ring in the
*reverse* direction: (k, v) rotate together with their gradient
accumulators (dk, dv), so after the full P-hop cycle each chunk's
gradient lands back on its home device; dq stays resident.  Saved
residuals are O(S/P) per device: the home q/k/v chunks, the normalized
output and the log-sum-exp — the flash recomputation trick at ring scale.

``ring_permute_bytes`` is the analytic per-device collective-permute
byte model; ``benchmarks/bench_ring.py`` cross-validates it against the
compiled HLO exactly, in the style PR 1–2 established for all-reduce.

Worked example of the mask bookkeeping (pure, no devices)::

    >>> # 4 shards x 32 tokens, window 33: only ring steps 0 and 1 can
    >>> # contribute (step 2 sits >= 33 tokens behind every query)
    >>> contributing_steps(4, 32, causal=True, window=33)
    [0, 1]
    >>> contributing_steps(4, 32, causal=True, window=None)
    [0, 1, 2, 3]
    >>> # backward (reverse ring): the diagonal first, wrapped tail last
    >>> contributing_steps(4, 32, causal=True, window=33, direction="bwd")
    [0, 3]
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels.flash_attention import NEG_INF

from . import compat
from .annotate import BATCH, _resolve


@dataclass(frozen=True)
class RingSpec:
    """Static (hashable) configuration of one ring-attention call."""
    n_shards: int
    axis: str
    causal: bool = True
    window: int | None = None
    softcap: float | None = None
    inner: str = "jnp"          # per-step kernel: "jnp" | "pallas"
    block_q: int = 128
    block_k: int = 128


# ---------------------------------------------------------------------------
# rotation-index bookkeeping

def contributing_steps(n_shards: int, chunk: int, *, causal: bool,
                       window: int | None, direction: str = "fwd"):
    """Ring steps on which at least one device has an unmasked score.

    Forward rotation: at step ``t`` device ``i`` holds chunk
    ``(i - t) % P`` — relative chunk offset ``t`` (past) or ``t - P``
    (future).  Backward rotates in reverse: offsets ``-t`` / ``P - t``.
    A step contributes iff some (qpos - kpos) difference passes both the
    causal (`>= 0`) and window (`<= window - 1`) constraints; the extreme
    differences of step offset ``r`` are ``r·chunk ± (chunk - 1)``.
    """
    def contributes(rel):
        lo = rel * chunk - (chunk - 1)
        hi = rel * chunk + (chunk - 1)
        if causal and hi < 0:
            return False
        if window is not None and lo > window - 1:
            return False
        return True

    steps = []
    for t in range(n_shards):
        rels = ((t,) if t == 0 else
                (t, t - n_shards) if direction == "fwd" else
                (-t, n_shards - t))
        if any(contributes(r) for r in rels):
            steps.append(t)
    return steps


def ring_permute_bytes(B: int, S: int, K: int, hd: int, n_shards: int, *,
                       itemsize: int = 2, causal: bool = True,
                       window: int | None = None) -> dict:
    """Analytic per-device collective-permute bytes of one ring attention.

    Forward rotates (k, v) — ``2·B·(S/P)·K·hd·itemsize`` bytes per step —
    for ``max(contributing_steps)`` hops (a windowed ring stops early: the
    remaining chunks are masked everywhere).  Backward rotates k/v for
    P-1 hops (they are dead after the last compute step) and the f32
    gradient accumulators (dk, dv) for the full P hops — they must
    complete the cycle back to their home shard, regardless of masking.
    Cross-validated against compiled HLO by ``benchmarks/bench_ring.py``.
    """
    if S % n_shards:
        raise ValueError(f"S={S} not divisible by n_shards={n_shards}")
    chunk_elems = B * (S // n_shards) * K * hd
    chunk = chunk_elems * itemsize
    chunk32 = chunk_elems * 4
    if n_shards == 1:
        fwd_rot = bwd_rot = 0
        bwd_kv_rot = 0
    else:
        fwd_rot = max(contributing_steps(n_shards, S // n_shards,
                                         causal=causal, window=window))
        bwd_rot = n_shards
        bwd_kv_rot = n_shards - 1
    fwd_total = fwd_rot * 2 * chunk
    bwd_total = bwd_kv_rot * 2 * chunk + bwd_rot * 2 * chunk32
    return {
        "chunk_bytes": chunk,
        "per_step_fwd": 2 * chunk,
        "per_step_bwd": 2 * (chunk + chunk32),
        "fwd_rotations": fwd_rot,
        "bwd_rotations": bwd_rot,
        "fwd_total": fwd_total,
        "bwd_total": bwd_total,
        "grad_total": fwd_total + bwd_total,
    }


# ---------------------------------------------------------------------------
# per-step block math (jnp inner; f32 accumulation, GQA via head-repeat)

def _mask(Sq, Sk, q_off, kv_off, causal, window):
    qpos = q_off + jnp.arange(Sq)
    kpos = kv_off + jnp.arange(Sk)
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= kpos[None, :] > qpos[:, None] - window
    return m


def _jnp_step(spec: RingSpec, q32, k, v, m, l, acc, q_off, kv_off):
    """One online-softmax block update.  q32: (B, Sq, H, hd) f32;
    k/v: (B, Sk, K, hd); m/l: (B, Sq, H); acc: (B, Sq, H, hd).
    ``q_off``/``kv_off`` may be traced (axis_index-derived)."""
    B, Sq, H, hd = q32.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    kk = jnp.repeat(k, G, axis=2).astype(jnp.float32)
    vv = jnp.repeat(v, G, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bshd->bqhs", q32, kk) * scale
    if spec.softcap is not None:
        s = jnp.tanh(s / spec.softcap) * spec.softcap
    msk = _mask(Sq, Sk, q_off, kv_off, spec.causal, spec.window)
    s = jnp.where(msk[None, :, None, :], s, NEG_INF)
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m, m_cur)
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(msk[None, :, None, :], p, 0.0)    # fully-masked block: 0
    corr = jnp.exp(m - m_new)
    l_new = corr * l + p.sum(-1)
    acc_new = acc * corr[..., None] + jnp.einsum("bqhs,bshd->bqhd", p, vv)
    return m_new, l_new, acc_new


def _pallas_step(spec: RingSpec, t, i, q, k, v, m, l, acc):
    """One ring step through the Pallas flash kernel (carry mode).

    The kernel's mask offsets are static, so the traced chunk offset is
    folded into the *relative* shift ``q_offset = rel·chunk`` with
    ``rel ∈ {t, t - P}`` selected by ``lax.cond(i >= t)``."""
    from repro.kernels.flash_attention import flash_attention
    Sk = k.shape[1]
    carry = (m[..., None], l[..., None], acc)

    def run(rel):
        st = flash_attention(q, k, v, causal=spec.causal, window=spec.window,
                             softcap=spec.softcap, q_offset=rel * Sk,
                             carry=carry, return_carry=True,
                             block_q=spec.block_q, block_k=spec.block_k)
        return st

    if spec.n_shards == 1 or t == 0:
        m4, l4, acc4 = run(0)
    elif spec.causal:
        # wrapped chunks are entirely in the future: carry passes through
        m4, l4, acc4 = jax.lax.cond(i >= t, lambda: run(t), lambda: carry)
    else:
        m4, l4, acc4 = jax.lax.cond(i >= t, lambda: run(t),
                                    lambda: run(t - spec.n_shards))
    return m4[..., 0], l4[..., 0], acc4


# ---------------------------------------------------------------------------
# the ring schedule (per-shard bodies; custom_vjp boundary)

def _axis_index(spec: RingSpec):
    return jax.lax.axis_index(spec.axis) if spec.n_shards > 1 else 0


def _ring_fwd(spec: RingSpec, q, k, v):
    """Forward ring. Returns (out, lse) — out normalized, q.dtype."""
    P_ = spec.n_shards
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    i = _axis_index(spec)
    q_off = i * Sq
    q32 = q.astype(jnp.float32)
    m = jnp.full((B, Sq, H), NEG_INF, jnp.float32)
    l = jnp.zeros((B, Sq, H), jnp.float32)
    acc = jnp.zeros((B, Sq, H, hd), jnp.float32)
    steps = contributing_steps(P_, Sk, causal=spec.causal,
                               window=spec.window)
    n_rot = max(steps)
    perm = [(j, (j + 1) % P_) for j in range(P_)]
    k_cur, v_cur = k, v
    for t in range(n_rot + 1):
        # named scope per ring step: a device profile shows each hop's
        # compute/permute pair under the same label as the host timeline
        with obs.named_scope(f"ring_fwd_t{t}"):
            if t in steps:
                if spec.inner == "pallas":
                    m, l, acc = _pallas_step(spec, t, i, q, k_cur, v_cur,
                                             m, l, acc)
                else:
                    src = jnp.mod(i - t, P_) if P_ > 1 else 0
                    m, l, acc = _jnp_step(spec, q32, k_cur, v_cur, m, l, acc,
                                          q_off, src * Sk)
            if t < n_rot:
                # next chunk's permute is independent of this step's
                # compute: XLA's latency-hiding scheduler overlaps them
                k_cur = jax.lax.ppermute(k_cur, spec.axis, perm)
                v_cur = jax.lax.ppermute(v_cur, spec.axis, perm)
    safe = jnp.where(l == 0.0, 1.0, l)
    out = (acc / safe[..., None]).astype(q.dtype)
    lse = jnp.where(l == 0.0, NEG_INF, m + jnp.log(safe))
    return out, lse


def _bwd_block(spec: RingSpec, q32, do32, k, v, lse, delta, q_off, kv_off):
    """Gradient contributions of one (q-shard, kv-chunk) block.

    Recomputes probs from the saved lse (flash backward), returns
    (dq_partial, dk_chunk, dv_chunk) in f32; dk/dv folded to KV heads."""
    B, Sq, H, hd = q32.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    kk = jnp.repeat(k, G, axis=2).astype(jnp.float32)
    vv = jnp.repeat(v, G, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bshd->bqhs", q32, kk) * scale
    if spec.softcap is not None:
        th = jnp.tanh(s / spec.softcap)
        s_cap = th * spec.softcap
    else:
        th, s_cap = None, s
    msk = _mask(Sq, Sk, q_off, kv_off, spec.causal, spec.window)
    p = jnp.where(msk[None, :, None, :], jnp.exp(s_cap - lse[..., None]), 0.0)
    dv_h = jnp.einsum("bqhs,bqhd->bshd", p, do32)
    dp = jnp.einsum("bqhd,bshd->bqhs", do32, vv)
    ds = p * (dp - delta[..., None])
    if th is not None:                      # d/ds [c·tanh(s/c)] = 1 - tanh²
        ds = ds * (1.0 - th * th)
    dq = jnp.einsum("bqhs,bshd->bqhd", ds, kk) * scale
    dk_h = jnp.einsum("bqhs,bqhd->bshd", ds, q32) * scale
    dk = dk_h.reshape(B, Sk, K, G, hd).sum(3)
    dv = dv_h.reshape(B, Sk, K, G, hd).sum(3)
    return dq, dk, dv


def _ring_bwd_impl(spec: RingSpec, q, k, v, out, lse, do):
    """Reverse-direction ring: (k, v, dk, dv) rotate together for the full
    P hops so each chunk's gradient lands back on its home device."""
    P_ = spec.n_shards
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    i = _axis_index(spec)
    q_off = i * Sq
    q32 = q.astype(jnp.float32)
    do32 = do.astype(jnp.float32)
    delta = jnp.sum(do32 * out.astype(jnp.float32), axis=-1)   # (B, Sq, H)
    dq = jnp.zeros((B, Sq, H, hd), jnp.float32)
    dk = jnp.zeros_like(k, dtype=jnp.float32)
    dv = jnp.zeros_like(v, dtype=jnp.float32)
    steps = contributing_steps(P_, Sk, causal=spec.causal,
                               window=spec.window, direction="bwd")
    perm = [(j, (j - 1) % P_) for j in range(P_)]
    k_cur, v_cur = k, v
    for t in range(P_):
        with obs.named_scope(f"ring_bwd_t{t}"):
            if t in steps:
                src = jnp.mod(i + t, P_) if P_ > 1 else 0
                dq_c, dk_c, dv_c = _bwd_block(spec, q32, do32, k_cur, v_cur,
                                              lse, delta, q_off, src * Sk)
                dq = dq + dq_c
                dk = dk + dk_c
                dv = dv + dv_c
            if P_ > 1:
                if t < P_ - 1:  # k/v are dead after the last compute step
                    k_cur = jax.lax.ppermute(k_cur, spec.axis, perm)
                    v_cur = jax.lax.ppermute(v_cur, spec.axis, perm)
                # dk/dv always complete the full cycle back home
                dk = jax.lax.ppermute(dk, spec.axis, perm)
                dv = jax.lax.ppermute(dv, spec.axis, perm)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _ring_shard(spec: RingSpec, q, k, v):
    out, _ = _ring_fwd(spec, q, k, v)
    return out


def _ring_shard_fwd(spec, q, k, v):
    out, lse = _ring_fwd(spec, q, k, v)
    return out, (q, k, v, out, lse)


def _ring_shard_bwd(spec, res, do):
    q, k, v, out, lse = res
    return _ring_bwd_impl(spec, q, k, v, out, lse, do)


_ring_shard.defvjp(_ring_shard_fwd, _ring_shard_bwd)


# ---------------------------------------------------------------------------
# public entry point

def ring_attention(q, k, v, *, causal=True, window=None, softcap=None,
                   axis="model", inner="jnp", block_q=128, block_k=128,
                   mesh=None):
    """Sequence-sharded exact GQA attention over the ``axis`` ring.

    q: (B, S, H, hd); k/v: (B, S, K, hd) with H % K == 0 — *global*
    shapes; internally the S dim is shard_mapped over ``axis`` and the
    batch dim over the data axes.  Numerically equals the dense/flash
    path (same online softmax, f32 accumulation); differentiable via the
    reverse-ring ``custom_vjp``.

    Without an ambient mesh (or with a 1-sized / absent ``axis``) the
    schedule degenerates to a single local block step — the CPU smoke
    path, and also the backward-math oracle the mesh tests compare
    against.  ``inner="pallas"`` runs the flash kernel per step (TPU).
    """
    B, Sq, H, hd = q.shape
    if k.shape[1] != Sq:
        raise ValueError(
            f"ring attention is self-attention: q and k/v must carry the "
            f"same sequence length, got Sq={Sq}, Sk={k.shape[1]}")
    mesh = mesh or compat.current_mesh()
    n = int(mesh.shape[axis]) if (mesh is not None
                                  and axis in mesh.axis_names) else 1
    if n > 1 and Sq % n != 0:
        raise ValueError(
            f"sequence length {Sq} not divisible by ring axis "
            f"{axis!r}={n}; pad the batch or drop PerfFlags.seq_shard")
    spec = RingSpec(n_shards=n, axis=axis, causal=causal, window=window,
                    softcap=softcap, inner=inner, block_q=block_q,
                    block_k=block_k)
    if n == 1:
        return _ring_shard(spec, q, k, v)
    names, sizes = tuple(mesh.axis_names), dict(mesh.shape)
    qspec = _resolve((BATCH, axis, None, None), q.shape, names, sizes)
    kvspec = _resolve((BATCH, axis, None, None), k.shape, names, sizes)
    f = compat.shard_map(partial(_ring_shard, spec), mesh,
                         in_specs=(qspec, kvspec, kvspec), out_specs=qspec)
    return f(q, k, v)
