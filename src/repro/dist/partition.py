"""PartitionSpec rule tables: params, batches, KV/SSM caches.

One table covers every config in ``repro/configs`` — dense GQA, MoE, SSM,
hybrid, VLM-prefix and enc-dec — because rules are written per *role*
(leaf name in the param pytree) and resolved against the concrete shapes
through the same divisibility-dropping machinery as ``annotate.ann``:
an axis that does not divide a dim is dropped, never an error.

Layout policy (megatron-style tensor parallel + zero-style FSDP):

* attention/MLP weights — contraction-adjacent "wide" dim over ``model``
  (heads for wq/wo, KV heads for wk/wv, d_ff for wg/wu/wd), one other
  large dim over ``data`` (FSDP, gathered on use);
* MoE expert weights — experts over ``model`` (expert parallelism: the
  group→expert reshard is the all-to-all), second dim over ``data``;
* embeddings — vocab over ``model`` (vocab-parallel embedding/logits),
  d_model over ``data``;
* SSM — the fused in/out projections over ``model``, tiny per-head
  params replicated;
* norms / biases / scalars — replicated;
* batches — leading (batch) dim over the data axes;
* caches — batch over data, KV-heads / SSM-heads over ``model``.

Params stacked along a leading ``n_super`` (or encoder-depth) axis get a
``None`` prepended: the scan axis is never sharded.

Worked example — a stacked attention projection on a 1-device dev-box
mesh (no "data" axis, so FSDP entries resolve to ``None``; the scan axis
gets the prepended ``None``)::

    >>> import jax
    >>> mesh = jax.make_mesh((1,), ("model",))
    >>> params = {"blocks": {"wq": jax.ShapeDtypeStruct((4, 8, 2, 16),
    ...                                                 "float32")}}
    >>> specs = param_pspecs(cfg=None, params=params, mesh=mesh)
    >>> specs["blocks"]["wq"] == P(None, None, "model", None)
    True
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, tree_map_with_path

from .annotate import BATCH, _resolve

# role -> spec entries for the UNSTACKED shape (see module docstring)
_PARAM_RULES = {
    "embed": ("model", "data"),            # (V, D)
    "lm_head": ("data", "model"),          # (D, V)
    "wq": ("data", "model", None),         # (D, H, hd)
    "wk": ("data", "model", None),         # (D, K, hd)
    "wv": ("data", "model", None),         # (D, K, hd)
    "wo": ("model", None, "data"),         # (H, hd, D)
    "bq": ("model", None),                 # (H, hd)
    "bk": ("model", None),
    "bv": ("model", None),
    "wg": ("data", "model"),               # (D, F)
    "wu": ("data", "model"),
    "wd": ("model", "data"),               # (F, D)
    "shared_wg": ("data", "model"),
    "shared_wu": ("data", "model"),
    "shared_wd": ("model", "data"),
    "router": ("data", None),              # (D, E) — router stays small
    "in_proj": ("data", "model"),          # (D, 2di+2N+H)
    "out_proj": ("model", "data"),         # (di, D)
    "conv_w": (None, "model"),             # (W, ch)
    "conv_b": ("model",),                  # (ch,)
    "dt_bias": (None,),                    # (H,) — tiny, replicate
    "A_log": (None,),
    "D": (None,),
    "frontend_proj": (None, "model"),      # (frontend_dim, D)
}

# MoE expert tensors share names with the dense MLP but carry a leading
# expert dim: (E, D, F) / (E, F, D) — experts over "model"
_MOE_EXPERT_RULE = ("model", "data", None)


def _generic(ndim):
    """Fallback for unknown roles: first dim FSDP, last dim model."""
    if ndim <= 1:
        return (None,) * ndim
    return ("data",) + (None,) * (ndim - 2) + ("model",)


def _path_keys(path):
    return [k.key for k in path if isinstance(k, DictKey)]


def _rule_spec(keys, shape, names, sizes, stage_axis=None):
    """The role rule table applied to ONE leaf identified by its dict
    key path — shared by ``param_pspecs`` (live pytrees) and
    ``spec_for_path`` (checkpoint-manifest paths)."""
    name = keys[-1] if keys else ""
    in_blocks = any(k == "blocks" for k in keys[:-1])
    stacked = in_blocks or any(k == "encoder" for k in keys[:-1])
    base_ndim = len(shape) - (1 if stacked else 0)
    if name in ("wg", "wu", "wd") and "moe" in keys:
        entries = _MOE_EXPERT_RULE
    else:
        entries = _PARAM_RULES.get(name)
    if entries is None or len(entries) != base_ndim:
        entries = _generic(base_ndim)
    if stacked:
        lead = stage_axis if (stage_axis and in_blocks) else None
        entries = (lead,) + tuple(entries)
    return _resolve(entries, shape, names, sizes)


def spec_for_path(keys, shape, mesh, stage_axis: str | None = None):
    """Single-leaf spec lookup by pytree key path (DESIGN.md §12).

    The same rule table ``param_pspecs`` applies tree-wide, exposed for
    the checkpoint layer's elastic restore, where a leaf arrives as a
    manifest key path plus a global shape rather than a live pytree —
    ``spec_for_path(["params", "blocks", "wq"], (4, 8, 2, 16), mesh)``
    resolves against the *target* mesh, so the same checkpoint restores
    onto any layout.  Works for optimizer-state mirrors too: the role
    name is the last key, wherever the subtree is nested.
    """
    names, sizes = tuple(mesh.axis_names), dict(mesh.shape)
    return _rule_spec(list(keys), tuple(shape), names, sizes, stage_axis)


def param_pspecs(cfg, params, mesh, stage_axis: str | None = None):
    """PartitionSpec pytree matching ``params`` (arrays or
    ShapeDtypeStructs), every sharded dim guaranteed to divide.

    ``stage_axis``: pipeline parallelism (DESIGN.md §10) — the leading
    scan dim of ``blocks`` leaves is sharded over this mesh axis instead
    of staying unsharded, placing layer-contiguous super-block groups on
    each pipeline stage (``dist.pipeline.stage_pspecs`` is the public
    wrapper).  As everywhere, a non-dividing axis is dropped.
    """
    names, sizes = tuple(mesh.axis_names), dict(mesh.shape)

    def rule(path, leaf):
        return _rule_spec(_path_keys(path), tuple(leaf.shape), names,
                          sizes, stage_axis)

    return tree_map_with_path(rule, params)


def batch_pspecs(cfg, batch, mesh, kind: str = "train"):
    """Batch inputs: leading dim over the data axes, rest replicated.

    The same rule serves train/prefill/decode; ``kind="seq"`` is the
    sequence-sharded long-context layout (DESIGN.md §8): dim 1 — the
    sequence — additionally over ``model``, feeding the ring-attention
    path with already-S-sharded tokens so the embedding lookup and the
    residual stream never materialize the full sequence per device.  As
    everywhere, a non-dividing axis is dropped, never an error.
    """
    names, sizes = tuple(mesh.axis_names), dict(mesh.shape)
    seq = kind == "seq"

    def rule(leaf):
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        entries = [BATCH] + [None] * (len(shape) - 1)
        if seq and len(shape) >= 2:
            entries[1] = "model"
        return _resolve(tuple(entries), shape, names, sizes)

    return jax.tree.map(rule, batch)


def cache_pspecs(cfg, cache, mesh):
    """KV/SSM cache pytrees: batch over data, head dims over ``model``.

    Cache leaves carry a leading ``n_super`` scan axis:
    ``k/v (n_super, B, S, K, hd)``, ``conv (n_super, B, W-1, ch)``,
    ``ssm (n_super, B, H, P, N)``; ``pos`` is a replicated scalar.
    """
    names, sizes = tuple(mesh.axis_names), dict(mesh.shape)

    def rule(path, leaf):
        keys = _path_keys(path)
        name = keys[-1] if keys else ""
        shape = tuple(leaf.shape)
        if not shape or name == "pos":
            return P()
        if name == "conv":
            entries = (None, BATCH, None, "model")
        elif name == "ssm":
            entries = (None, BATCH, "model", None, None)
        elif len(shape) == 5:  # k/v and encoder cross-KV tensors
            entries = (None, BATCH, None, "model", None)
        else:
            entries = (None, BATCH) + (None,) * (len(shape) - 2)
        return _resolve(entries, shape, names, sizes)

    return tree_map_with_path(rule, cache)


def make_shardings(mesh, pspecs):
    """PartitionSpec pytree -> NamedSharding pytree on ``mesh``."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda s: isinstance(s, P))
