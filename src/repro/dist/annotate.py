"""Per-tensor sharding annotations over a named mesh.

This is the graph-level placement surface of the dist layer (the analogue
of TensorFlow's device annotations, and of GSPMD sharding constraints in
jax): model code states *where a tensor's dims live* — ``ann(x, BATCH,
"model", None)`` — and the partitioner materialises the collectives.

Three properties make the API usable across every config in
``repro/configs`` and on dev boxes:

* **no-mesh / 1-device fallback** — without an ambient multi-device mesh
  every annotation is the identity, so CPU smoke tests run the exact same
  model code;
* **BATCH sentinel** — "the data-parallel axes of whatever mesh is
  active": ``("pod", "data")`` on the multi-pod production mesh,
  ``("data",)`` on a single pod;
* **divisibility dropping** — an axis that does not divide the annotated
  dim is dropped (largest dividing subset wins), e.g. 8 KV heads on a
  16-way "model" axis degrade to replicated instead of erroring, which is
  what lets one rule table cover dense/MoE/SSM/enc-dec configs.

``ann_first_fit`` tries several full specs in priority order and applies
the first that divides *exactly* (used where two layouts are both natural,
e.g. SSD's heads-sharded vs chunk-sharded score tensors).

Worked example — the spec-resolution core, independent of any devices
(``_resolve`` is pure; ``ann`` wraps it in a sharding constraint)::

    >>> names, sizes = ("pod", "data", "model"), {"pod": 2, "data": 4,
    ...                                           "model": 2}
    >>> spec = _resolve((BATCH, "model", None), (32, 16, 5), names, sizes)
    >>> spec == P(("pod", "data"), "model", None)
    True
    >>> # 6 KV heads on a 4-way axis: 4 does not divide 6 -> dropped
    >>> _resolve(("data",), (6,), names, sizes) == P(None)
    True
    >>> # strict mode refuses instead of dropping (ann_first_fit's probe)
    >>> _resolve(("data",), (6,), names, sizes, strict=True) is None
    True
"""
from __future__ import annotations

import contextlib
import itertools

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from .compat import current_mesh

# trace-time switch: inside a fully-manual shard_map body (e.g. the
# pipeline schedule, dist/pipeline.py) there is no partitioner to honour
# sharding constraints — `suppressed()` turns ann/ann_first_fit into
# identities for everything traced under it
_SUPPRESS = [False]


@contextlib.contextmanager
def suppressed():
    """Trace-time context: annotations become identities (DESIGN.md §10)."""
    _SUPPRESS.append(True)
    try:
        yield
    finally:
        _SUPPRESS.pop()


def annotations_suppressed() -> bool:
    """True while tracing under :func:`suppressed` — code that builds its
    own nested ``shard_map`` (e.g. the MoE grouped dispatch) must fall
    back to its local body inside a fully-manual region, where the batch
    axes are already per-device."""
    return _SUPPRESS[-1]


class _Batch:
    """Sentinel dim entry: shard over all data-parallel mesh axes."""

    def __repr__(self):
        return "BATCH"


BATCH = _Batch()

# mesh axes that carry data parallelism, outermost first (the mesh may
# have any subset of these; "model" is tensor/sequence parallelism)
DATA_AXES = ("pod", "data")


def _mesh_axes():
    """``(axis_names, {axis: size})`` of the ambient mesh; ``((), {})``
    when no mesh is installed (the CPU fallback)."""
    m = current_mesh()
    if m is None:
        return (), {}
    return tuple(m.axis_names), dict(m.shape)


def _product(axes, sizes):
    n = 1
    for a in axes:
        n *= sizes[a]
    return n


def _entry_axes(entry, axis_names):
    """Mesh axes requested by one spec entry (restricted to the mesh)."""
    if entry is None:
        return ()
    if isinstance(entry, _Batch):
        return tuple(a for a in DATA_AXES if a in axis_names)
    if isinstance(entry, str):
        return (entry,) if entry in axis_names else ()
    return tuple(a for a in entry if a in axis_names)


def _best_fit(axes, dim, sizes):
    """Largest-factor subset of ``axes`` whose size product divides ``dim``
    (order preserved); ``()`` when nothing divides."""
    best, best_n = (), 1
    for r in range(1, len(axes) + 1):
        for combo in itertools.combinations(axes, r):
            n = _product(combo, sizes)
            if n > best_n and dim % n == 0:
                best, best_n = combo, n
    return best


def _resolve(spec, shape, axis_names, sizes, strict=False):
    """Turn a spec of ``None | BATCH | axis | (axes...)`` entries into a
    PartitionSpec that divides ``shape``.  Non-dividing axes are dropped
    (best-fit) unless ``strict``, in which case ``None`` is returned."""
    assert len(spec) == len(shape), (spec, shape)
    out = []
    for entry, dim in zip(spec, shape):
        axes = _entry_axes(entry, axis_names)
        if not axes:
            out.append(None)
            continue
        if dim % _product(axes, sizes) == 0:
            out.append(axes[0] if len(axes) == 1 else axes)
            continue
        if strict:
            return None
        fit = _best_fit(axes, dim, sizes)
        out.append(None if not fit else (fit[0] if len(fit) == 1 else fit))
    return P(*out)


def ann(x, *spec):
    """Constrain ``x``'s layout on the ambient mesh; identity without one.

    One entry per dim: ``BATCH`` (data axes), an axis name, a tuple of
    axis names, or ``None`` (replicated / partitioner's choice is pinned
    to replicated — ``ann`` is a *constraint*, so ``None`` entries mean
    "explicitly not sharded here").
    """
    m = current_mesh()
    if m is None or m.size == 1 or _SUPPRESS[-1]:
        return x
    p = _resolve(spec, x.shape, tuple(m.axis_names), dict(m.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(m, p))


def ann_first_fit(x, *specs):
    """Apply the first spec that divides ``x`` exactly; if none does, the
    last spec is applied with best-effort axis dropping."""
    m = current_mesh()
    if m is None or m.size == 1 or _SUPPRESS[-1]:
        return x
    names, sizes = tuple(m.axis_names), dict(m.shape)
    for spec in specs[:-1]:
        p = _resolve(spec, x.shape, names, sizes, strict=True)
        if p is not None:
            return jax.lax.with_sharding_constraint(x, NamedSharding(m, p))
    p = _resolve(specs[-1], x.shape, names, sizes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(m, p))
