"""Generic pattern-based transformer LM covering all assigned families:
dense GQA, MoE, SSM (mamba2), hybrid (jamba), VLM prefix (internvl2) and
enc-dec (whisper).

Layers repeat a *pattern* of LayerSpecs; same-position blocks are stacked
on a leading n_super axis and run under ``lax.scan`` (small HLO at 80L).

Entry points:
  init_params(cfg, key)             real weights (smoke tests)
  loss_fn(cfg)(params, batch)       next-token CE + MoE aux
  prefill_fn(cfg)(params, batch)    forward + KV/SSM cache construction
  decode_fn(cfg)(params, cache, batch, pos)   one-token serve step
  make_cache(cfg, B, cache_len)     zeroed cache pytree
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.annotate import BATCH, ann

from .common import ArchConfig, LayerSpec
from .layers import (attn_block, attn_block_decode, attn_block_decode_paged,
                     attn_project_qkv, apply_rope, cross_attn_block,
                     mlp_block, paged_context_attention,
                     rmsnorm, rope_freqs)
from .moe import moe_block
from .ssm import mamba_block


# ---------------------------------------------------------------------------
# init

def _dense(key, shape, dtype, scale=None):
    scale = scale or (1.0 / np.sqrt(shape[0]))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_attn_params(key, cfg: ArchConfig, cross=False):
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.activation_dtype()
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense(ks[0], (D, H, hd), dt),
        "wk": _dense(ks[1], (D, K, hd), dt),
        "wv": _dense(ks[2], (D, K, hd), dt),
        "wo": _dense(ks[3], (H, hd, D), dt, scale=1.0 / np.sqrt(H * hd)),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H, hd), dt)
        p["bk"] = jnp.zeros((K, hd), dt)
        p["bv"] = jnp.zeros((K, hd), dt)
    return p


def init_mlp_params(key, cfg: ArchConfig, kind: str):
    D, F = cfg.d_model, cfg.d_ff
    dt = cfg.activation_dtype()
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {"wg": _dense(ks[0], (D, F), dt), "wu": _dense(ks[1], (D, F), dt),
                "wd": _dense(ks[2], (F, D), dt)}
    return {"wu": _dense(ks[0], (D, F), dt), "wd": _dense(ks[1], (F, D), dt)}


def init_moe_params(key, cfg: ArchConfig):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = cfg.activation_dtype()
    ks = jax.random.split(key, 7)
    p = {"router": _dense(ks[0], (D, E), jnp.float32),
         "wg": _dense(ks[1], (E, D, F), dt, scale=1.0 / np.sqrt(D)),
         "wu": _dense(ks[2], (E, D, F), dt, scale=1.0 / np.sqrt(D)),
         "wd": _dense(ks[3], (E, F, D), dt, scale=1.0 / np.sqrt(F))}
    if cfg.shared_expert:
        p["shared_wg"] = _dense(ks[4], (D, F), dt)
        p["shared_wu"] = _dense(ks[5], (D, F), dt)
        p["shared_wd"] = _dense(ks[6], (F, D), dt)
    return p


def init_mamba_params(key, cfg: ArchConfig):
    D, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    W = cfg.conv_width
    ch = di + 2 * N
    dt = cfg.activation_dtype()
    ks = jax.random.split(key, 3)
    return {
        "in_proj": _dense(ks[0], (D, 2 * di + 2 * N + H), dt),
        "conv_w": _dense(ks[1], (W, ch), dt, scale=1.0 / np.sqrt(W)),
        "conv_b": jnp.zeros((ch,), dt),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "out_proj": _dense(ks[2], (di, D), dt),
    }


def init_block_params(key, cfg: ArchConfig, spec: LayerSpec):
    dt = cfg.activation_dtype()
    D = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {"ln1": jnp.zeros((D,), dt)}
    if spec.kind == "attn":
        p["attn"] = init_attn_params(ks[0], cfg)
    else:
        p["ssm"] = init_mamba_params(ks[0], cfg)
    if spec.cross_attn:
        p["ln_x"] = jnp.zeros((D,), dt)
        p["xattn"] = init_attn_params(ks[2], cfg, cross=True)
    if spec.mlp != "none":
        p["ln2"] = jnp.zeros((D,), dt)
        p["moe" if spec.mlp == "moe" else "mlp"] = (
            init_moe_params(ks[1], cfg) if spec.mlp == "moe"
            else init_mlp_params(ks[1], cfg, spec.mlp))
    if cfg.sandwich_norm:
        p["ln1_post"] = jnp.zeros((D,), dt)
        if spec.mlp != "none":
            p["ln2_post"] = jnp.zeros((D,), dt)
    return p


def init_params(cfg: ArchConfig, key):
    dt = cfg.activation_dtype()
    keys = jax.random.split(key, 8)
    params = {"embed": _dense(keys[0], (cfg.vocab, cfg.d_model), dt, scale=0.02),
              "final_norm": jnp.zeros((cfg.d_model,), dt)}
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense(keys[1], (cfg.d_model, cfg.vocab), dt)

    blocks = {}
    for i, spec in enumerate(cfg.pattern):
        bkeys = jax.random.split(jax.random.fold_in(keys[2], i), cfg.n_super)
        blocks[f"p{i}"] = jax.vmap(
            lambda k: init_block_params(k, cfg, spec))(bkeys)
    params["blocks"] = blocks

    if cfg.encoder_layers:  # whisper encoder stack (bidir attn + gelu mlp)
        espec = LayerSpec(kind="attn", attn="bidir", mlp="gelu")
        ekeys = jax.random.split(keys[3], cfg.encoder_layers)
        params["encoder"] = jax.vmap(
            lambda k: init_block_params(k, cfg, espec))(ekeys)
        params["enc_final_norm"] = jnp.zeros((cfg.d_model,), dt)
    if cfg.frontend_tokens:  # modality projector stub (VLM / audio)
        params["frontend_proj"] = _dense(keys[4], (cfg.frontend_dim,
                                                   cfg.d_model), dt)
    return params


# ---------------------------------------------------------------------------
# forward blocks

def apply_block(p, x, cfg: ArchConfig, spec: LayerSpec, enc_kv=None,
                positions=None, lengths=None):
    """Full-sequence block (train / prefill). Returns (x, cache, aux).

    ``lengths``: (B,) live lengths of a tail-padded mixed-length prefill —
    causal masking already hides pads from attention, but the SSM scan is
    recurrent: without masking, pad tokens would evolve the cached state.
    """
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if spec.kind == "attn":
        from repro.perf_flags import FLAGS
        if FLAGS.attn_gather_once and not FLAGS.seq_shard:
            # §Perf: one explicit bf16 gather of the sequence-parallel
            # stream before the three qkv einsums (not three, never f32).
            # Under seq_shard the stream must *stay* S-sharded (the ring
            # path never gathers S), so the flag is a no-op there.
            h = ann(h, BATCH, None, None)
        h, kv = attn_block(p["attn"], h, cfg, spec, positions=positions)
        cache = {"k": kv[0], "v": kv[1]}
    else:
        h, (conv_s, ssm_s) = mamba_block(p["ssm"], h, cfg, valid_len=lengths)
        cache = {"conv": conv_s, "ssm": ssm_s}
    if cfg.sandwich_norm:
        h = rmsnorm(h, p["ln1_post"], cfg.norm_eps)
    x = x + h

    if spec.cross_attn:
        h = rmsnorm(x, p["ln_x"], cfg.norm_eps)
        h = cross_attn_block(p["xattn"], h, enc_kv, cfg)
        x = x + h

    aux = {"load_balance": jnp.zeros((), jnp.float32),
           "router_z": jnp.zeros((), jnp.float32)}
    if spec.mlp != "none":
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if spec.mlp == "moe":
            h, aux = moe_block(p["moe"], h, cfg)
            aux = {k: v.astype(jnp.float32) for k, v in aux.items()}
        else:
            h = mlp_block(p["mlp"], h, spec.mlp)
        if cfg.sandwich_norm:
            h = rmsnorm(h, p["ln2_post"], cfg.norm_eps)
        x = x + h
    return x, cache, aux


def apply_block_decode(p, x, cache, pos, cfg: ArchConfig, spec: LayerSpec,
                       enc_kv=None, block_tables=None, active=None):
    """One-token block step.  ``block_tables`` switches attention layers to
    the paged pool (cache["k"]/["v"] are then (NB, bs, K, hd) pools and
    ``pos`` is the (B,) per-sequence position vector).  ``active``: (B,)
    bool — lanes that are NOT decoding this step (empty slots, requests
    still mid-prefill) keep their recurrent SSM states untouched; their
    attention writes already land in the sink block."""
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if spec.kind == "attn" and block_tables is not None:
        h, new_cache = attn_block_decode_paged(p["attn"], h, cache,
                                               block_tables, pos, cfg, spec)
    elif spec.kind == "attn":
        h, ck, cv = attn_block_decode(p["attn"], h, cache["k"], cache["v"],
                                      pos, cfg, spec)
        new_cache = {"k": ck, "v": cv}
    else:
        h, (conv_s, ssm_s) = mamba_block(p["ssm"], h, cfg,
                                         conv_state=cache["conv"],
                                         ssm_state=cache["ssm"], decode=True)
        if active is not None:
            conv_s = jnp.where(active[:, None, None], conv_s, cache["conv"])
            ssm_s = jnp.where(active[:, None, None, None], ssm_s,
                              cache["ssm"])
        new_cache = {"conv": conv_s, "ssm": ssm_s}
    if cfg.sandwich_norm:
        h = rmsnorm(h, p["ln1_post"], cfg.norm_eps)
    x = x + h
    if spec.cross_attn:
        h = rmsnorm(x, p["ln_x"], cfg.norm_eps)
        h = cross_attn_block(p["xattn"], h, enc_kv, cfg)
        x = x + h
    if spec.mlp != "none":
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if spec.mlp == "moe":
            h, _ = moe_block(p["moe"], h, cfg)
        else:
            h = mlp_block(p["mlp"], h, spec.mlp)
        if cfg.sandwich_norm:
            h = rmsnorm(h, p["ln2_post"], cfg.norm_eps)
        x = x + h
    return x, new_cache


# ---------------------------------------------------------------------------
# whisper encoder

def run_encoder(params, frames, cfg: ArchConfig):
    """frames: (B, T_enc, frontend_dim) stub embeddings -> (B, T_enc, D)."""
    x = frames.astype(cfg.activation_dtype()) @ params["frontend_proj"]
    espec = LayerSpec(kind="attn", attn="bidir", mlp="gelu")

    def body(x, p):
        x, _, _ = apply_block(p, x, cfg, espec)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return rmsnorm(x, params["enc_final_norm"], cfg.norm_eps)


def encoder_cross_kv(params, enc_out, cfg):
    """Precompute per-(pattern-position) cross K/V from encoder output."""
    kvs = {}
    for i, spec in enumerate(cfg.pattern):
        if not spec.cross_attn:
            continue
        bp = params["blocks"][f"p{i}"]

        def kv(bp_i):
            k = jnp.einsum("bsd,dhk->bshk", enc_out, bp_i["xattn"]["wk"])
            v = jnp.einsum("bsd,dhk->bshk", enc_out, bp_i["xattn"]["wv"])
            return k, v
        kvs[f"p{i}"] = jax.vmap(kv)(bp)  # stacked over n_super
    return kvs


# ---------------------------------------------------------------------------
# full model

def embed_tokens(params, tokens, cfg):
    x = params["embed"][tokens]
    # residual stream: batch over data axes, SEQUENCE over "model" between
    # blocks (sequence parallelism: the saved/remat activations are 1/|model|
    # the size; attention/MLP gather S and return reduce-scattered partials)
    return ann(x.astype(cfg.activation_dtype()), BATCH, "model", None)


def final_logits(params, x, cfg):
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head).astype(jnp.float32)
    if cfg.final_softcap:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return logits


def run_stack(params, x, cfg: ArchConfig, enc_kvs=None, positions=None,
              collect_cache=False, lengths=None):
    """Scan the super-block stack. Returns (x, caches, aux_totals)."""
    pattern = cfg.pattern

    def body(carry, xs):
        x, lb, rz = carry
        x = ann(x, BATCH, "model", None)   # sequence-parallel between blocks
        bp = xs["params"]
        caches = {}
        for i, spec in enumerate(pattern):
            enc_kv = None
            if spec.cross_attn and enc_kvs is not None:
                enc_kv = xs["enc"][f"p{i}"]
            x, cache, aux = apply_block(bp[f"p{i}"], x, cfg, spec,
                                        enc_kv=enc_kv, positions=positions,
                                        lengths=lengths)
            caches[f"p{i}"] = cache
            lb = lb + aux["load_balance"]
            rz = rz + aux["router_z"]
        out = caches if collect_cache else None
        return (x, lb, rz), out

    if cfg.remat:
        # save only each super-block's input (x, carry); recompute the rest
        # in backward — the remat analogue of §3.1 memory planning
        body = jax.checkpoint(body, prevent_cse=False)

    xs = {"params": params["blocks"]}
    if enc_kvs is not None:
        xs["enc"] = enc_kvs
    if cfg.n_super <= 4:
        # unrolled: exact cost_analysis for the roofline probes (scan bodies
        # are counted once by XLA's analysis)
        carry = (x, 0.0, 0.0)
        ys = []
        for i in range(cfg.n_super):
            carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
            ys.append(y)
        (x, lb, rz) = carry
        caches = (jax.tree.map(lambda *a: jnp.stack(a), *ys)
                  if collect_cache else None)
    else:
        (x, lb, rz), caches = jax.lax.scan(body, (x, 0.0, 0.0), xs)
    return x, caches, {"load_balance": lb, "router_z": rz}


def _pipeline_stage_fn(cfg: ArchConfig):
    """One pipeline stage: apply this stage's super-block slice.

    Returns ``stage_fn(blocks_slice, x) -> (x, aux)`` where ``aux`` holds
    the MoE scalar losses of the slice (summed over its super-blocks).
    The per-super-block body is the train-path subset of ``run_stack``'s
    (no cache collection, no enc-dec cross-attention).
    """
    pattern = cfg.pattern

    def body(carry, bp):
        x, lb, rz = carry
        # sequence-parallel between blocks, like run_stack; identity
        # inside the stage shard_map (annotations suppressed) but live on
        # the pp-requested-without-stage-axis GSPMD fallback
        x = ann(x, BATCH, "model", None)
        for i, spec in enumerate(pattern):
            x, _, aux = apply_block(bp[f"p{i}"], x, cfg, spec)
            lb = lb + aux["load_balance"]
            rz = rz + aux["router_z"]
        return (x, lb, rz), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)

    def stage_fn(blocks_local, x):
        n_local = jax.tree.leaves(blocks_local)[0].shape[0]
        carry = (x, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
        if n_local <= 4:
            for i in range(n_local):
                carry, _ = body(carry,
                                jax.tree.map(lambda a: a[i], blocks_local))
        else:
            carry, _ = jax.lax.scan(body, carry, blocks_local)
        x, lb, rz = carry
        return x, {"load_balance": lb, "router_z": rz}

    return stage_fn


def run_stack_pipelined(params, x, cfg: ArchConfig):
    """The super-block stack as per-stage scans under the 1F1B pipeline
    (DESIGN.md §10): each ``stage`` mesh shard holds a layer-contiguous
    slice of the stacked block params and microbatches stream through
    ``dist.pipeline.pipeline_stack``.  Train path only: caches and
    enc-dec cross-attention are not carried.  Returns (x, aux_totals)."""
    from repro.dist.pipeline import pipeline_stack, validate_pipeline
    from repro.perf_flags import FLAGS
    if cfg.encoder_layers:
        raise ValueError(
            "pipeline parallelism does not support enc-dec archs: the "
            "decoder's cross-attention KV is per-super-block state the "
            "stage hand-off does not carry (DESIGN.md §10)")
    validate_pipeline(n_stages=FLAGS.pp_stages,
                      microbatches=FLAGS.microbatches, n_super=cfg.n_super,
                      batch=x.shape[0], seq_shard=FLAGS.seq_shard)
    stage_fn = _pipeline_stage_fn(cfg)
    x, aux = pipeline_stack(stage_fn, params["blocks"], x,
                            microbatches=FLAGS.microbatches)
    return x, aux


def forward_loss(params, batch, cfg: ArchConfig):
    """Next-token CE loss. batch: tokens (B,S) [+ patches/frames]."""
    from repro.perf_flags import FLAGS
    tokens = batch["tokens"]
    x = embed_tokens(params, tokens, cfg)
    prefix = 0
    enc_kvs = None
    if cfg.encoder_layers:                      # whisper: enc-dec
        enc_out = run_encoder(params, batch["frames"], cfg)
        enc_kvs = encoder_cross_kv(params, enc_out, cfg)
    elif cfg.frontend_tokens:                   # VLM: prefix patch embeds
        pre = batch["patches"].astype(cfg.activation_dtype()) \
            @ params["frontend_proj"]
        x = jnp.concatenate([pre, x], axis=1)
        prefix = pre.shape[1]

    if FLAGS.pp_stages > 1:
        # microbatches alone (pp_stages == 1) are a no-op: without a
        # stage axis the schedule is the plain stack, so keep run_stack's
        # layout annotations and enc-dec support
        x, aux = run_stack_pipelined(params, x, cfg)
    else:
        x, _, aux = run_stack(params, x, cfg, enc_kvs=enc_kvs)
    loss = chunked_ce_loss(params, x[:, prefix:], tokens, cfg)
    total = loss + 0.01 * aux["load_balance"] + 0.001 * aux["router_z"]
    return total, {"ce": loss, **aux}


# number of unrolled head chunks for the CE loss (memory: per-device logits
# never exceed ~tokens/NC × V/model_shards × 4B)
CE_CHUNKS = 16


def chunked_ce_loss(params, x, tokens, cfg: ArchConfig):
    """Next-token CE without materializing the full (B, S, V) logits.

    Chunks run along the SEQUENCE axis (batch stays sharded over the data
    axes; slicing the flattened token dim would break the sharding) in an
    unrolled loop — roofline-exact, and XLA frees each chunk's logits
    before the next.
    """
    from repro.perf_flags import FLAGS
    B, S, D = x.shape
    x = ann(x, BATCH, None, None)        # gather S: chunks slice along S
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    xs = x[:, :-1]                       # (B, S-1, D)
    tg = tokens[:, 1:]
    n_tok = S - 1
    nc = min(FLAGS.ce_chunks, n_tok)
    pad = (-n_tok) % nc
    if pad:
        xs = jnp.pad(xs, [(0, 0), (0, pad), (0, 0)])
        tg = jnp.pad(tg, [(0, 0), (0, pad)])
    wts = None
    if pad:
        wts = jnp.concatenate([jnp.ones((n_tok,), jnp.float32),
                               jnp.zeros((pad,), jnp.float32)])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    csz = xs.shape[1] // nc

    def chunk_nll(xc, tc, wc):
        logits = jnp.einsum("bsd,dv->bsv", xc, head).astype(jnp.float32)
        logits = ann(logits, BATCH, None, "model")
        if cfg.final_softcap:
            logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
        lp = jax.nn.log_softmax(logits, -1)
        nll = -jnp.take_along_axis(lp, tc[..., None], -1)[..., 0]
        if wc is not None:
            nll = nll * wc[None, :]
        return nll.sum()

    if cfg.remat:  # recompute chunk logits in backward: O(B·csz·V) live, once
        chunk_nll = jax.checkpoint(chunk_nll, prevent_cse=False)
    total = 0.0
    for c in range(nc):
        total = total + chunk_nll(
            xs[:, c * csz:(c + 1) * csz], tg[:, c * csz:(c + 1) * csz],
            None if wts is None else wts[c * csz:(c + 1) * csz])
    return total / (B * n_tok)


def _fixup_prefill_cache(caches, cfg: ArchConfig, S: int, pad_to: int | None,
                         lengths=None):
    """Convert full-length prefill KV to decode layout: windowed layers get
    ring-ordered last-``window`` entries; full layers optionally pad the S
    axis to ``pad_to`` for decode headroom.

    ``lengths``: optional (B,) per-sequence live lengths (including any
    VLM prefix) for tail-padded mixed-length batches — windowed rings are
    then aligned per sequence (positions past a sequence's length hold
    pad garbage; decode masks them via its per-sequence cache_len)."""
    out = {}
    for i, spec in enumerate(cfg.pattern):
        c = caches[f"p{i}"]
        if spec.kind != "attn":
            out[f"p{i}"] = c
            continue
        k, v = c["k"], c["v"]          # (n_super, B, S, K, hd)
        if spec.window is not None:
            # buffer = min(window, max(S, pad_to)): ring once past window,
            # padded headroom before that
            target = min(spec.window, max(S, pad_to or S))
            if lengths is not None:
                # ring slot j of a length-L sequence holds position
                # p_j = L-1 - ((L-1-j) mod target)  (the last `target`
                # positions in ring order); out-of-range slots clip to a
                # garbage row that decode's cache_len mask hides
                j = jnp.arange(target)
                last = lengths[:, None] - 1                 # (B, 1)
                src = jnp.clip(last - ((last - j[None]) % target), 0, S - 1)
                idx = src[None, :, :, None, None]           # (1,B,T,1,1)
                k = jnp.take_along_axis(k, idx, axis=2)
                v = jnp.take_along_axis(v, idx, axis=2)
            elif S > target:           # ring of exactly `window`
                s0 = (S - target) % target
                k = jnp.roll(k[:, :, -target:], s0, axis=2)
                v = jnp.roll(v[:, :, -target:], s0, axis=2)
            elif target > S:           # decode headroom below the window
                pad = [(0, 0), (0, 0), (0, target - S), (0, 0), (0, 0)]
                k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        elif pad_to and pad_to > k.shape[2]:
            pad = [(0, 0), (0, 0), (0, pad_to - k.shape[2]), (0, 0), (0, 0)]
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        out[f"p{i}"] = {"k": k, "v": v}
    return out


def prefill(params, batch, cfg: ArchConfig, pad_to: int | None = None):
    """Forward building caches; returns (last_logits, cache_pytree).

    ``batch["lengths"]`` (optional, (B,) int32): per-sequence real prompt
    lengths for tail-padded mixed-length batches.  Last logits are then
    taken at each sequence's own final token (not the pad tail) and the
    cache ``pos`` becomes a per-sequence vector, so decode continues each
    sequence at ITS length — pad rows beyond a sequence's length are
    masked by decode's per-sequence cache_len and progressively
    overwritten by decoded tokens.
    """
    tokens = batch["tokens"]
    lengths = batch.get("lengths")
    x = embed_tokens(params, tokens, cfg)
    enc_kvs = None
    extra = {}
    prefix = 0
    if cfg.encoder_layers:
        enc_out = run_encoder(params, batch["frames"], cfg)
        enc_kvs = encoder_cross_kv(params, enc_out, cfg)
        extra["enc_kvs"] = enc_kvs
    elif cfg.frontend_tokens:
        pre = batch["patches"].astype(cfg.activation_dtype()) \
            @ params["frontend_proj"]
        x = jnp.concatenate([pre, x], axis=1)
        prefix = pre.shape[1]
    eff = (None if lengths is None
           else (prefix + lengths).astype(jnp.int32))   # incl. VLM prefix
    x, caches, _ = run_stack(params, x, cfg, enc_kvs=enc_kvs,
                             collect_cache=True, lengths=eff)
    S = x.shape[1]
    if lengths is None:
        caches = _fixup_prefill_cache(caches, cfg, S, pad_to)
        logits = final_logits(params, x[:, -1:], cfg)
        pos = jnp.asarray(S, jnp.int32)
    else:
        caches = _fixup_prefill_cache(caches, cfg, S, pad_to, lengths=eff)
        x_last = jnp.take_along_axis(x, (eff - 1)[:, None, None], axis=1)
        logits = final_logits(params, x_last, cfg)
        pos = eff
    return logits[:, 0], {"layers": caches, **extra, "pos": pos}


def _stack_step(cfg, body, x, xs):
    """Run ``body`` over the super-block stack (unrolled <=4 for exact
    cost_analysis, ``lax.scan`` else), stacking the per-super-block cache
    outputs — the shared dispatch of every decode/prefill step."""
    if cfg.n_super <= 4:
        ys = []
        for i in range(cfg.n_super):
            x, y = body(x, jax.tree.map(lambda a: a[i], xs))
            ys.append(y)
        return x, jax.tree.map(lambda *a: jnp.stack(a), *ys)
    return jax.lax.scan(body, x, xs)


def decode_step(params, cache, batch, cfg: ArchConfig):
    """One-token serve step. batch: {"tokens": (B, 1)}; cache from
    make_cache/prefill. Returns (logits (B, V), new_cache)."""
    tokens = batch["tokens"]
    x = embed_tokens(params, tokens, cfg)
    pos = cache["pos"]
    pattern = cfg.pattern
    enc_kvs = cache.get("enc_kvs")

    def body(x, xs):
        bp, layer_cache = xs["params"], xs["cache"]
        new_caches = {}
        for i, spec in enumerate(pattern):
            enc_kv = xs["enc"][f"p{i}"] if (spec.cross_attn and
                                            enc_kvs is not None) else None
            x, nc = apply_block_decode(bp[f"p{i}"], x, layer_cache[f"p{i}"],
                                       pos, cfg, spec, enc_kv=enc_kv)
            new_caches[f"p{i}"] = nc
        return x, new_caches

    xs = {"params": params["blocks"], "cache": cache["layers"]}
    if enc_kvs is not None:
        xs["enc"] = enc_kvs
    x, new_layers = _stack_step(cfg, body, x, xs)
    logits = final_logits(params, x[:, -1:], cfg)
    new_cache = {**cache, "layers": new_layers, "pos": pos + 1}
    return logits[:, 0], new_cache


# ---------------------------------------------------------------------------
# cache construction (decode entry without a real prefill — dry-run path)

def cache_len_for(cfg: ArchConfig, spec: LayerSpec, seq_len: int) -> int:
    if spec.window is not None:
        return min(seq_len, spec.window)
    return seq_len


def make_cache(cfg: ArchConfig, batch: int, seq_len: int, enc_len: int = 0):
    """Zeroed cache pytree sized for ``seq_len`` context (ring-buffered to
    ``window`` for windowed layers)."""
    dt = cfg.activation_dtype()
    K, hd = cfg.n_kv_heads, cfg.hd
    layers = {}
    for i, spec in enumerate(cfg.pattern):
        n = cfg.n_super
        if spec.kind == "attn":
            S = cache_len_for(cfg, spec, seq_len)
            layers[f"p{i}"] = {
                "k": jnp.zeros((n, batch, S, K, hd), dt),
                "v": jnp.zeros((n, batch, S, K, hd), dt)}
        else:
            ch = cfg.d_inner + 2 * cfg.ssm_state
            layers[f"p{i}"] = {
                "conv": jnp.zeros((n, batch, cfg.conv_width - 1, ch), dt),
                "ssm": jnp.zeros((n, batch, cfg.ssm_heads, cfg.ssm_p,
                                  cfg.ssm_state), jnp.float32)}
    cache = {"layers": layers, "pos": jnp.asarray(seq_len - 1, jnp.int32)}
    if cfg.encoder_layers:
        enc_len = enc_len or cfg.frontend_tokens
        kvs = {}
        for i, spec in enumerate(cfg.pattern):
            if spec.cross_attn:
                kvs[f"p{i}"] = (jnp.zeros((cfg.n_super, batch, enc_len, K, hd), dt),
                                jnp.zeros((cfg.n_super, batch, enc_len, K, hd), dt))
        cache["enc_kvs"] = kvs
    return cache


# ---------------------------------------------------------------------------
# paged decode path (DESIGN.md §9): block-pool KV cache + per-slot SSM
# states, continuous-batching step functions.  Host-side block bookkeeping
# lives in repro.serve.paging; these are the pure device-side steps.


def make_paged_cache(cfg: ArchConfig, num_blocks: int, block_size: int,
                     max_batch: int, kv_dtype=None):
    """Zeroed paged cache: per attention pattern-position a physical block
    pool (n_super, num_blocks, block_size, K, hd); SSM layers keep per-slot
    recurrent states (their footprint is position-independent — nothing to
    page).  Block 0 is the sink (``serve.paging.SINK_BLOCK``).

    ``kv_dtype``: None/"native" stores KV in the activation dtype;
    "int8"/"fp8_e4m3"/"fp8_e5m2" store quantized rows plus per-(token,
    kv-head) f32 scale pools "k_scale"/"v_scale" (n_super, num_blocks,
    block_size, K) riding alongside (DESIGN.md §13)."""
    if cfg.encoder_layers:
        raise ValueError("paged decode does not support enc-dec archs "
                         "(cross-attention caches are per-request static)")
    from repro.kernels.quant import resolve_kv_dtype
    qdt = resolve_kv_dtype(kv_dtype)
    dt = cfg.activation_dtype()
    K, hd = cfg.n_kv_heads, cfg.hd
    layers = {}
    for i, spec in enumerate(cfg.pattern):
        n = cfg.n_super
        if spec.kind == "attn":
            layers[f"p{i}"] = {
                "k": jnp.zeros((n, num_blocks, block_size, K, hd),
                               qdt or dt),
                "v": jnp.zeros((n, num_blocks, block_size, K, hd),
                               qdt or dt)}
            if qdt is not None:
                layers[f"p{i}"]["k_scale"] = jnp.zeros(
                    (n, num_blocks, block_size, K), jnp.float32)
                layers[f"p{i}"]["v_scale"] = jnp.zeros(
                    (n, num_blocks, block_size, K), jnp.float32)
        else:
            ch = cfg.d_inner + 2 * cfg.ssm_state
            layers[f"p{i}"] = {
                "conv": jnp.zeros((n, max_batch, cfg.conv_width - 1, ch), dt),
                "ssm": jnp.zeros((n, max_batch, cfg.ssm_heads, cfg.ssm_p,
                                  cfg.ssm_state), jnp.float32)}
    return {"layers": layers}


def paged_swap_out(cache, slot: int, block_ids) -> dict:
    """Copy decode lane ``slot``'s live state out of the paged cache to
    host memory (preemption, DESIGN.md §14): for every attention layer
    the lane's physical block rows (codes + quant scales when present),
    for every SSM layer the lane's conv + recurrent state rows.  Returns
    a flat ``{"p<i>.<key>": np.ndarray}`` dict — a bit-exact snapshot
    (same dtypes, no recompute) that ``paged_swap_in`` restores under
    possibly different block ids / a different slot."""
    ids = np.asarray(list(block_ids), np.int32)
    out = {}
    for name, layer in cache["layers"].items():
        if "k" in layer:                       # attn: block-pool rows
            for key in layer:                  # k/v (+ k_scale/v_scale)
                out[f"{name}.{key}"] = np.array(layer[key][:, ids])
        else:                                  # ssm: per-slot state rows
            out[f"{name}.conv"] = np.array(layer["conv"][:, slot])
            out[f"{name}.ssm"] = np.array(layer["ssm"][:, slot])
    return out


def paged_swap_in(cache, slot: int, block_ids, payload: dict):
    """Inverse of ``paged_swap_out``: write the copied rows back into the
    pools at fresh ``block_ids`` and the (possibly different) lane
    ``slot``.  Pure eager updates — the round trip is bit-exact, so a
    preempted-and-restored request emits identical greedy tokens."""
    ids = jnp.asarray(np.asarray(list(block_ids), np.int32))
    new_layers = {}
    for name, layer in cache["layers"].items():
        if "k" in layer:
            new_layers[name] = {
                key: layer[key].at[:, ids].set(
                    jnp.asarray(payload[f"{name}.{key}"], layer[key].dtype))
                for key in layer}
        else:
            new_layers[name] = {
                key: layer[key].at[:, slot].set(
                    jnp.asarray(payload[f"{name}.{key}"], layer[key].dtype))
                for key in ("conv", "ssm")}
    return {**cache, "layers": new_layers}


def decode_step_paged(params, cache, batch, cfg: ArchConfig):
    """One continuous-batching decode step.

    batch: tokens (B, 1); block_tables (B, P) int32 (sink-filled for
    inactive lanes); pos (B,) int32 — the incoming token's absolute
    position per lane (0 for inactive lanes, whose writes land in the
    sink block); active (B,) bool — lanes decoding this step (inactive
    lanes' SSM states are preserved).  Returns (logits (B, V), new_cache).
    """
    tokens, tables, pos = batch["tokens"], batch["block_tables"], batch["pos"]
    active = batch["active"]
    x = embed_tokens(params, tokens, cfg)
    pattern = cfg.pattern

    def body(x, xs):
        bp, layer_cache = xs["params"], xs["cache"]
        new_caches = {}
        for i, spec in enumerate(pattern):
            x, nc = apply_block_decode(bp[f"p{i}"], x, layer_cache[f"p{i}"],
                                       pos, cfg, spec, block_tables=tables,
                                       active=active)
            new_caches[f"p{i}"] = nc
        return x, new_caches

    xs = {"params": params["blocks"], "cache": cache["layers"]}
    x, new_layers = _stack_step(cfg, body, x, xs)
    logits = final_logits(params, x[:, -1:], cfg)
    return logits[:, 0], {**cache, "layers": new_layers}


def _apply_block_prefill_paged(p, x, layer_cache, cfg, spec, *, tables,
                               start, length, slot, positions):
    """One block of a paged prefill chunk.  x: (1, C, D).  Writes the
    chunk's K/V rows through the (1, P) block table (pad rows -> sink),
    attends against the gathered logical context, and threads the slot's
    SSM states.  Returns (x, new_layer_cache)."""
    C = x.shape[1]
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if spec.kind == "attn":
        k_pool, v_pool = layer_cache["k"], layer_cache["v"]
        quantized = "k_scale" in layer_cache
        NB, bs, K, hd = k_pool.shape
        P = tables.shape[1]
        q, k, v = attn_project_qkv(p["attn"], h, cfg)
        cos, sin = rope_freqs(positions, cfg.hd, cfg.rope_theta)
        q = apply_rope(q, cos[None], sin[None])
        k = apply_rope(k, cos[None], sin[None])
        j = jnp.arange(C)
        page = jnp.clip(positions // bs, 0, P - 1)
        idx = jnp.where(j < length,
                        tables[0, page] * bs + positions % bs, 0)
        k_rows, v_rows = k[0], v[0]                       # (C, K, hd)
        scales = {}
        if quantized:
            # quantize on append (DESIGN.md §13): the pool row and its
            # per-(token, kv-head) scale land together; pad rows (idx 0)
            # write garbage into the sink block, masked out by kv_len
            from repro.kernels.quant import kv_dequantize, kv_quantize_rows
            k_rows, ks_rows = kv_quantize_rows(k_rows, k_pool.dtype)
            v_rows, vs_rows = kv_quantize_rows(v_rows, v_pool.dtype)
            scales = {
                "k_scale": layer_cache["k_scale"].reshape(NB * bs, K)
                .at[idx].set(ks_rows).reshape(NB, bs, K),
                "v_scale": layer_cache["v_scale"].reshape(NB * bs, K)
                .at[idx].set(vs_rows).reshape(NB, bs, K)}
        k_pool = k_pool.reshape(NB * bs, K, hd).at[idx].set(
            k_rows.astype(k_pool.dtype)).reshape(NB, bs, K, hd)
        v_pool = v_pool.reshape(NB * bs, K, hd).at[idx].set(
            v_rows.astype(v_pool.dtype)).reshape(NB, bs, K, hd)
        # gather the logical context (chunk rows included) and attend
        ctx_k = k_pool[tables[0]].reshape(1, P * bs, K, hd)
        ctx_v = v_pool[tables[0]].reshape(1, P * bs, K, hd)
        if quantized:
            ctx_k = kv_dequantize(
                ctx_k, scales["k_scale"][tables[0]].reshape(1, P * bs, K))
            ctx_v = kv_dequantize(
                ctx_v, scales["v_scale"][tables[0]].reshape(1, P * bs, K))
        h = paged_context_attention(q, ctx_k, ctx_v, q_offset=start,
                                    kv_len=start + length,
                                    window=spec.window,
                                    softcap=cfg.attn_softcap)
        h = jnp.einsum("bshk,hkd->bsd", h, p["attn"]["wo"])
        new_cache = {"k": k_pool, "v": v_pool, **scales}
    else:
        conv_all, ssm_all = layer_cache["conv"], layer_cache["ssm"]
        conv0 = jax.lax.dynamic_slice_in_dim(conv_all, slot, 1, axis=0)
        ssm0 = jax.lax.dynamic_slice_in_dim(ssm_all, slot, 1, axis=0)
        fresh = start == 0           # first chunk starts from zero state
        conv0 = jnp.where(fresh, jnp.zeros_like(conv0), conv0)
        ssm0 = jnp.where(fresh, jnp.zeros_like(ssm0), ssm0)
        h, (nconv, nssm) = mamba_block(p["ssm"], h, cfg, conv_state=conv0,
                                       ssm_state=ssm0, valid_len=length)
        conv_all = jax.lax.dynamic_update_slice_in_dim(
            conv_all, nconv.astype(conv_all.dtype), slot, axis=0)
        ssm_all = jax.lax.dynamic_update_slice_in_dim(
            ssm_all, nssm.astype(ssm_all.dtype), slot, axis=0)
        new_cache = {"conv": conv_all, "ssm": ssm_all}
    if cfg.sandwich_norm:
        h = rmsnorm(h, p["ln1_post"], cfg.norm_eps)
    x = x + h
    if spec.mlp != "none":
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if spec.mlp == "moe":
            h, _ = moe_block(p["moe"], h, cfg)
        else:
            h = mlp_block(p["mlp"], h, spec.mlp)
        if cfg.sandwich_norm:
            h = rmsnorm(h, p["ln2_post"], cfg.norm_eps)
        x = x + h
    return x, new_cache


def prefill_chunk_paged(params, cache, batch, cfg: ArchConfig):
    """One prompt chunk of a paged prefill (continuous batching admits
    long prompts chunk by chunk so decode lanes never stall behind them).

    batch: tokens (1, C) (tail-padded); block_tables (1, P) int32 for the
    admitted slot; start (scalar) absolute position of tokens[:, 0];
    length (scalar) real tokens in this chunk; slot (scalar) the decode
    lane (SSM state row).  Returns (last_real_token_logits (1, V),
    new_cache).
    """
    tokens, tables = batch["tokens"], batch["block_tables"]
    start, length, slot = batch["start"], batch["length"], batch["slot"]
    C = tokens.shape[1]
    x = embed_tokens(params, tokens, cfg)
    positions = start + jnp.arange(C)
    pattern = cfg.pattern

    def body(x, xs):
        bp, layer_cache = xs["params"], xs["cache"]
        new_caches = {}
        for i, spec in enumerate(pattern):
            x, nc = _apply_block_prefill_paged(
                bp[f"p{i}"], x, layer_cache[f"p{i}"], cfg, spec,
                tables=tables, start=start, length=length, slot=slot,
                positions=positions)
            new_caches[f"p{i}"] = nc
        return x, new_caches

    xs = {"params": params["blocks"], "cache": cache["layers"]}
    x, new_layers = _stack_step(cfg, body, x, xs)
    x_last = jax.lax.dynamic_slice_in_dim(x, length - 1, 1, axis=1)
    logits = final_logits(params, x_last, cfg)
    return logits[:, 0], {**cache, "layers": new_layers}
