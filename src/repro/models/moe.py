"""Mixture-of-Experts layer: token-choice top-k routing with static expert
capacity and GROUPED dispatch (GShard-style).

TPU adaptation: the scatter/gather dispatch runs *locally* under
``shard_map`` over the data axes (each data shard slots its own tokens
into its local (B_loc, E, C, D) buffer — no partitioner involvement, which
otherwise replicates batched scatters), while the expert FFN einsum runs
under GSPMD with experts sharded over the "model" axis — the
group->expert resharding is the all-to-all of expert parallelism.

Capacity is per group (= batch row): C = ceil(S·k/E · capacity_factor);
overflow tokens are dropped (contribute zero), exactly like GShard/Switch.

Aux losses: Switch load-balance + router z-loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist.annotate import BATCH, ann, _mesh_axes


def moe_router(p, x, cfg):
    """x: (B, S, D) -> weights (B,S,k), experts (B,S,k), aux."""
    from repro.perf_flags import FLAGS
    if FLAGS.router_no_f32_copy:
        # §Perf: f32 ACCUMULATION without materializing an f32 copy of x
        # (the copy doubles the reshard bytes of the sequence-parallel x)
        logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype),
                            preferred_element_type=jnp.float32)
    else:
        logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                            p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, -1)
    w, idx = jax.lax.top_k(probs, cfg.top_k)
    w = w / jnp.clip(w.sum(-1, keepdims=True), 1e-9)

    E = cfg.n_experts
    f = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=(0, 1, 2))
    pbar = probs.mean((0, 1))
    lb = E * jnp.sum(f * pbar)
    z = jnp.mean(jax.nn.logsumexp(logits, -1) ** 2)
    return w.astype(x.dtype), idx, {"load_balance": lb, "router_z": z}


# ---------------------------------------------------------------------------
# local (per data-shard) dispatch/combine bodies


def _slots(flat_e, E, C):
    """Position of each (token, choice) within its expert's capacity."""
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)   # (B, S*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - 1
    slot = jnp.take_along_axis(pos_in_e, flat_e[..., None], 2)[..., 0]
    keep = slot < C
    return jnp.where(keep, slot, 0), keep


def _dispatch_local(x, flat_e, flat_t, E, C):
    """x: (B, S, D) local. Returns buf (B, E, C, D), s_idx, keep."""
    B = x.shape[0]
    Sk = flat_e.shape[1]
    s_idx, keep = _slots(flat_e, E, C)
    bidx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, Sk))
    e_idx = jnp.where(keep, flat_e, 0)
    xt = jnp.take_along_axis(x, flat_t[..., None], axis=1)   # (B, S*k, D)
    contrib = jnp.where(keep[..., None], xt, 0)
    buf = jnp.zeros((B, E, C, x.shape[-1]), x.dtype)
    buf = buf.at[bidx, e_idx, s_idx].add(contrib, mode="drop")
    return buf, s_idx, keep


def _combine_local(y, flat_e, flat_t, flat_w, s_idx, keep, S):
    """y: (B, E, C, D) local -> (B, S, D)."""
    B, E, C, D = y.shape
    Sk = flat_e.shape[1]
    bidx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, Sk))
    e_idx = jnp.where(keep, flat_e, 0)
    gathered = y[bidx, e_idx, s_idx]
    gathered = jnp.where(keep[..., None], gathered, 0)
    out = jnp.zeros((B, S, D), y.dtype)
    out = out.at[bidx, flat_t].add(gathered * flat_w[..., None].astype(y.dtype))
    return out


def _data_shard_map(f, n_in, n_out, batch_dim: int = 0, batch_size=None):
    """Run f under shard_map over the data axes (manual) with "model" left
    auto; identity passthrough when no mesh is active (CPU tests), when
    the batch dim does not divide the data axes (e.g. batch-1 long-context
    decode — the local code is then simply global), or inside an enclosing
    fully-manual region (the pipeline stage body, DESIGN.md §10 — the
    batch axes are already per-device there, so f's local body is exactly
    what should run)."""
    from repro.dist.annotate import annotations_suppressed
    if annotations_suppressed():
        return f
    axes, sizes = _mesh_axes()
    dp = tuple(a for a in ("pod", "data") if a in axes)
    if not dp:
        return f
    n = 1
    for a in dp:
        n *= sizes[a]
    if batch_size is not None and batch_size % n != 0:
        return f
    spec_in = tuple(P(dp) for _ in range(n_in))
    spec_out = tuple(P(dp) for _ in range(n_out)) if n_out > 1 else P(dp)
    return jax.shard_map(f, in_specs=spec_in, out_specs=spec_out,
                         axis_names=set(dp), check_vma=False)


def _dispatch_local_kloop(x, idx, k, E, C):
    """k compact scatters: buf from x (B,S,D) without (B,S*k,D).

    idx: (B, S, k). Returns buf (B,E,C,D), s_idx (B,S,k), keep (B,S,k).
    """
    B, S, D = x.shape
    flat_e = idx.reshape(B, S * k)
    s_flat, keep_flat = _slots(flat_e, E, C)
    s_idx = s_flat.reshape(B, S, k)
    keep = keep_flat.reshape(B, S, k)
    bidx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, S))
    buf = jnp.zeros((B, E, C, D), x.dtype)
    for j in range(k):
        e_j = jnp.where(keep[..., j], idx[..., j], 0)
        s_j = jnp.where(keep[..., j], s_idx[..., j], 0)
        contrib = jnp.where(keep[..., j, None], x, 0)
        buf = buf.at[bidx, e_j, s_j].add(contrib, mode="drop")
    return buf, s_idx, keep


def _combine_local_kloop(y, idx, w, s_idx, keep):
    """k compact gathers from y (B,E,C,D) -> (B,S,D)."""
    B, E, C, D = y.shape
    S, k = idx.shape[1], idx.shape[2]
    bidx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, S))
    out = jnp.zeros((B, S, D), y.dtype)
    for j in range(k):
        e_j = jnp.where(keep[..., j], idx[..., j], 0)
        s_j = jnp.where(keep[..., j], s_idx[..., j], 0)
        g = y[bidx, e_j, s_j]                       # (B, S, D)
        g = jnp.where(keep[..., j, None], g, 0)
        out = out + g * w[..., j, None].astype(y.dtype)
    return out


def moe_block(p, x, cfg, mlp_kind="swiglu"):
    """x: (B, S, D) -> (B, S, D), aux. Grouped dispatch: group == batch row."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = max(int(np.ceil(S * k / E * cfg.capacity_factor)), 1)

    from repro.perf_flags import FLAGS
    if FLAGS.moe_gather_once:
        # §Perf: gather the sequence-parallel residual ONCE, compact and
        # bf16, before the S*k-expanded dispatch tensors exist
        x = ann(x, BATCH, None, None)
    w, idx, aux = moe_router(p, x, cfg)            # (B,S,k)

    if FLAGS.moe_k_loop:
        disp = _data_shard_map(
            lambda xx, ii: _dispatch_local_kloop(xx, ii, k, E, C), 2, 3,
            batch_size=B)
        buf, s_idx, keep = disp(x, idx)
    else:
        flat_e = idx.reshape(B, S * k)
        flat_w = w.reshape(B, S * k)
        flat_t = jnp.tile(jnp.repeat(jnp.arange(S), k)[None], (B, 1))
        disp = _data_shard_map(
            lambda xx, fe, ft: _dispatch_local(xx, fe, ft, E, C), 3, 3,
            batch_size=B)
        buf, s_idx, keep = disp(x, flat_e, flat_t)
    # batch over data, experts over model: this reshard is the all-to-all
    buf = ann(buf, BATCH, "model", None, None)

    if mlp_kind in ("swiglu", "geglu"):
        act = jax.nn.silu if mlp_kind == "swiglu" else (
            lambda a: jax.nn.gelu(a, approximate=True))
        h = act(jnp.einsum("becd,edf->becf", buf, p["wg"])) \
            * jnp.einsum("becd,edf->becf", buf, p["wu"])
        y = jnp.einsum("becf,efd->becd", h, p["wd"])
    else:
        h = jax.nn.gelu(jnp.einsum("becd,edf->becf", buf, p["wu"]),
                        approximate=True)
        y = jnp.einsum("becf,efd->becd", h, p["wd"])
    y = ann(y, BATCH, "model", None, None)

    if FLAGS.moe_k_loop:
        comb = _data_shard_map(
            lambda yy, ii, ww, si, kp: _combine_local_kloop(yy, ii, ww, si,
                                                            kp), 5, 1,
            batch_size=B)
        out = comb(y, idx, w, s_idx, keep)
    else:
        comb = _data_shard_map(
            lambda yy, fe, ft, fw, si, kp: _combine_local(yy, fe, ft, fw,
                                                          si, kp, S), 6, 1,
            batch_size=B)
        out = comb(y, flat_e, flat_t, flat_w, s_idx, keep)
    out = ann(out, BATCH, None, None)

    if cfg.shared_expert:
        hs = jax.nn.silu(x @ p["shared_wg"]) * (x @ p["shared_wu"])
        hs = ann(hs, BATCH, None, "model")
        out = out + hs @ p["shared_wd"]
    return out, aux
