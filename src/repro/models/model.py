"""Model facade: functional entry points bound to an ArchConfig."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from .common import ArchConfig
from . import transformer as T


class Model:
    """Thin functional wrapper: all methods are pure and jit-able."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # -- params -----------------------------------------------------------
    def init(self, key):
        return T.init_params(self.cfg, key)

    def param_specs(self):
        """ShapeDtypeStruct pytree — no allocation (dry-run path)."""
        return jax.eval_shape(lambda: T.init_params(self.cfg,
                                                    jax.random.PRNGKey(0)))

    # -- steps --------------------------------------------------------------
    def loss(self, params, batch):
        return T.forward_loss(params, batch, self.cfg)

    def prefill(self, params, batch, pad_to=None):
        return T.prefill(params, batch, self.cfg, pad_to=pad_to)

    def decode(self, params, cache, batch):
        return T.decode_step(params, cache, batch, self.cfg)

    def make_cache(self, batch: int, seq_len: int):
        return T.make_cache(self.cfg, batch, seq_len)

    def cache_specs(self, batch: int, seq_len: int):
        return jax.eval_shape(lambda: T.make_cache(self.cfg, batch, seq_len))

    # -- paged serving (DESIGN.md §9) ---------------------------------------
    def make_paged_cache(self, num_blocks: int, block_size: int,
                         max_batch: int, kv_dtype=None):
        return T.make_paged_cache(self.cfg, num_blocks, block_size,
                                  max_batch, kv_dtype=kv_dtype)

    def paged_cache_specs(self, num_blocks: int, block_size: int,
                          max_batch: int, kv_dtype=None):
        return jax.eval_shape(lambda: T.make_paged_cache(
            self.cfg, num_blocks, block_size, max_batch,
            kv_dtype=kv_dtype))

    def decode_paged(self, params, cache, batch):
        return T.decode_step_paged(params, cache, batch, self.cfg)

    def prefill_chunk_paged(self, params, cache, batch):
        return T.prefill_chunk_paged(params, cache, batch, self.cfg)

    # preemption + swap (DESIGN.md §14): bit-exact host round-trip of one
    # decode lane's KV block rows + SSM slot state
    def paged_swap_out(self, cache, slot: int, block_ids) -> dict:
        return T.paged_swap_out(cache, slot, block_ids)

    def paged_swap_in(self, cache, slot: int, block_ids, payload: dict):
        return T.paged_swap_in(cache, slot, block_ids, payload)

    # -- batch specs ----------------------------------------------------------
    def batch_specs(self, shape_kind: str, global_batch: int, seq_len: int):
        """ShapeDtypeStruct stand-ins for every model input (§input_specs)."""
        cfg = self.cfg
        i32 = jnp.int32
        f32 = jnp.float32
        if shape_kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((global_batch, 1), i32)}
        S = seq_len
        batch = {}
        if cfg.encoder_layers:
            batch["frames"] = jax.ShapeDtypeStruct(
                (global_batch, cfg.frontend_tokens, cfg.frontend_dim), f32)
        elif cfg.frontend_tokens:
            batch["patches"] = jax.ShapeDtypeStruct(
                (global_batch, cfg.frontend_tokens, cfg.frontend_dim), f32)
            S = seq_len - cfg.frontend_tokens  # text + prefix == seq_len
        batch["tokens"] = jax.ShapeDtypeStruct((global_batch, S), i32)
        return batch

    def make_batch(self, key, shape_kind: str, global_batch: int,
                   seq_len: int):
        """Synthetic concrete batch matching batch_specs (smoke tests)."""
        specs = self.batch_specs(shape_kind, global_batch, seq_len)
        out = {}
        for name, s in specs.items():
            key, sub = jax.random.split(key)
            if s.dtype == jnp.int32:
                out[name] = jax.random.randint(sub, s.shape, 0, self.cfg.vocab)
            else:
                out[name] = jax.random.normal(sub, s.shape, s.dtype)
        return out


def get_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
