from .common import ArchConfig, InputShape, INPUT_SHAPES, LayerSpec, reduced
from .model import Model, get_model

__all__ = ["ArchConfig", "InputShape", "INPUT_SHAPES", "LayerSpec",
           "reduced", "Model", "get_model"]
