"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

Training/prefill uses the chunked SSD algorithm: quadratic *within* a chunk
(MXU-friendly einsums over chunk length Q) and a sequential ``lax.scan``
over chunks carrying the (H, P, N) state — the TPU-native mapping of the
paper's "chunk-parallel" GPU kernel.  Decode is the O(1) recurrent update.

Layout: d_inner = expand * d_model, split into H heads of dim P;
B/C are shared across heads (n_groups = 1) with state size N.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.annotate import BATCH, ann, ann_first_fit


def _split_proj(p, x, cfg):
    """in_proj -> (z, xh, Bm, Cm, dt) with conv over (xh|B|C)."""
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = x @ p["in_proj"]                       # (B, T, 2di+2N+H)
    z, xBC, dt = jnp.split(zxbcdt, [di, di + di + 2 * N], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC, weight, bias, prev=None, valid_len=None):
    """Depthwise causal conv, width W.  xBC: (B, T, Ch); weight: (W, Ch).

    ``prev``: (B, W-1, Ch) history for decode; returns (out, new_prev).
    ``valid_len``: only the first ``valid_len`` tokens are real (chunked
    prefill pads the tail) — the carried history then ends at the last
    REAL token, not the padding.
    """
    W = weight.shape[0]
    if prev is None:
        prev = jnp.zeros(xBC.shape[:1] + (W - 1,) + xBC.shape[2:], xBC.dtype)
    xpad = jnp.concatenate([prev, xBC], axis=1)     # (B, T+W-1, Ch)
    out = sum(xpad[:, i:i + xBC.shape[1]] * weight[i] for i in range(W))
    out = jax.nn.silu(out + bias)
    if W <= 1:
        new_prev = prev
    elif valid_len is None:
        new_prev = xpad[:, -(W - 1):]
    else:
        # real tokens occupy xpad[:, W-1 : W-1+valid_len); the last W-1 of
        # them start at index valid_len (scalar, or (B,) for per-sequence
        # tail-padded batches)
        vl = jnp.asarray(valid_len)
        if vl.ndim == 0:
            new_prev = jax.lax.dynamic_slice_in_dim(xpad, vl, W - 1, axis=1)
        else:
            idx = vl[:, None] + jnp.arange(W - 1)[None]       # (B, W-1)
            new_prev = jnp.take_along_axis(xpad, idx[:, :, None], axis=1)
    return out, new_prev


def ssd_chunked(xh, Bm, Cm, dt, A_log, D, chunk, init_state=None):
    """Chunked SSD scan.

    xh: (B, T, H, P); Bm, Cm: (B, T, N); dt: (B, T, H) (post-softplus);
    A_log: (H,); ``init_state``: optional (B, H, P, N) carry from a
    previous chunk call (paged/chunked prefill — resumes mid-sequence).
    Returns y: (B, T, H, P) and final state (B, H, P, N).
    """
    Bsz, T, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, T)
    T0 = T
    if T % Q:  # pad tail with dt=0 tokens: decay 1, zero contribution
        pad = Q - T % Q
        padT = lambda a: jnp.pad(a, [(0, 0), (0, pad)] +
                                 [(0, 0)] * (a.ndim - 2))
        xh, Bm, Cm, dt = padT(xh), padT(Bm), padT(Cm), padT(dt)
        T = T + pad
    nc = T // Q

    f32 = jnp.float32
    A = -jnp.exp(A_log.astype(f32))                 # (H,) negative
    dt = dt.astype(f32)
    dA = dt * A                                     # (B, T, H) log-decay
    # reshape into chunks
    xh_c = xh.reshape(Bsz, nc, Q, H, P).astype(f32)
    B_c = Bm.reshape(Bsz, nc, Q, N).astype(f32)
    C_c = Cm.reshape(Bsz, nc, Q, N).astype(f32)
    dA_c = dA.reshape(Bsz, nc, Q, H)
    dt_c = dt.reshape(Bsz, nc, Q, H)

    cum = jnp.cumsum(dA_c, axis=2)                  # (B, nc, Q, H)
    total = cum[:, :, -1]                           # (B, nc, H)

    # ---- intra-chunk (quadratic in Q, the MXU part)
    # shard the big (B,nc,Q,Q,H) tensors: SSD heads over "model" when they
    # divide, else chunk-parallel over "model" (both are TPU-natural)
    def shard5(t):
        return ann_first_fit(t, (BATCH, None, None, None, "model"),
                             (BATCH, "model", None, None, None),
                             (BATCH, None, None, None, None))

    # L[q, s] = exp(cum_q - cum_s) for s <= q else 0
    diff = shard5(cum[:, :, :, None, :] - cum[:, :, None, :, :])
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    # scores[q,s] = (C_q . B_s) * L[q,s] * dt_s
    cb = jnp.einsum("bcqn,bcsn->bcqs", C_c, B_c)
    scores = shard5(cb[..., None] * L * dt_c[:, :, None, :, :])
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", scores, xh_c)

    # ---- inter-chunk state carry (sequential scan over chunks)
    # state contribution of chunk: sum_s exp(total - cum_s) * dt_s * B_s x_s
    decay_to_end = jnp.exp(total[:, :, None] - cum)         # (B,nc,Q,H)
    dBx = jnp.einsum("bcqh,bcqn,bcqhp->bchpn",
                     decay_to_end * dt_c, B_c, xh_c)        # (B,nc,H,P,N)
    chunk_decay = jnp.exp(total)                            # (B,nc,H)

    def step(state, inp):
        dBx_i, dec_i = inp                                  # (B,H,P,N),(B,H)
        new = state * dec_i[:, :, None, None] + dBx_i
        return new, state                                    # emit PREV state

    init = (jnp.zeros((Bsz, H, P, N), f32) if init_state is None
            else init_state.astype(f32))
    final, prev_states = jax.lax.scan(
        step, init,
        (jnp.moveaxis(dBx, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)           # (B,nc,H,P,N)

    # y_inter[q] = exp(cum_q) * C_q . state_prev
    y_inter = jnp.einsum("bcqh,bcqn,bchpn->bcqhp",
                         jnp.exp(cum), C_c, prev_states)

    y = (y_intra + y_inter).reshape(Bsz, T, H, P)
    y = y + D.astype(f32)[None, None, :, None] * xh.astype(f32)
    return y[:, :T0].astype(xh.dtype), final


def mamba_block(p, x, cfg, conv_state=None, ssm_state=None, decode=False,
                valid_len=None):
    """Full mamba2 block. x: (B, T, D).

    Training/prefill: decode=False, returns (out, (conv_state, ssm_state)).
    Decode: T == 1 with states provided; O(1) update.
    Chunked prefill: decode=False with states = the previous chunk's carry
    and ``valid_len`` = real tokens in this (possibly tail-padded) chunk —
    padded tokens get dt=0 (decay 1, zero contribution) so they cannot
    perturb the carried state, and the conv history ends at the last real
    token.
    """
    Bsz, T, Dm = x.shape
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_p

    z, xBC, dt = _split_proj(p, x, cfg)
    z = ann(z, BATCH, None, "model")
    xBC = ann(xBC, BATCH, None, "model")
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state,
                                 valid_len=valid_len)
    xh, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
    xh = ann(xh.reshape(Bsz, T, H, P), BATCH, None, "model", None)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    if valid_len is not None and not decode:
        vl = jnp.asarray(valid_len).reshape(-1, 1, 1)   # scalar or (B,)
        dt = jnp.where(jnp.arange(T)[None, :, None] < vl, dt, 0.0)

    if not decode:
        y, final = ssd_chunked(xh, Bm, Cm, dt, p["A_log"], p["D"],
                               cfg.ssm_chunk, init_state=ssm_state)
    else:
        # recurrent: state (B, H, P, N)
        f32 = jnp.float32
        A = -jnp.exp(p["A_log"].astype(f32))
        dA = jnp.exp(dt[:, 0] * A)                         # (B, H)
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0],
                         Bm[:, 0].astype(f32), xh[:, 0].astype(f32))
        final = ssm_state * dA[:, :, None, None] + dBx
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(f32), final)
        y = y + p["D"].astype(f32)[None, :, None] * xh[:, 0].astype(f32)
        y = y[:, None].astype(x.dtype)                     # (B, 1, H, P)

    y = y.reshape(Bsz, T, di)
    y = y * jax.nn.silu(z)                                 # gated
    out = y @ p["out_proj"]
    return out, (new_conv, final)


def ssd_reference(xh, Bm, Cm, dt, A_log, D):
    """O(T) sequential oracle for tests: token-by-token recurrence."""
    Bsz, T, H, P = xh.shape
    N = Bm.shape[-1]
    f32 = jnp.float32
    A = -jnp.exp(A_log.astype(f32))
    xh, Bm, Cm, dt = (a.astype(f32) for a in (xh, Bm, Cm, dt))

    def step(state, inp):
        x_t, B_t, C_t, dt_t = inp
        dA = jnp.exp(dt_t * A)                              # (B,H)
        state = state * dA[:, :, None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dt_t, B_t, x_t)
        y = jnp.einsum("bn,bhpn->bhp", C_t, state)
        return state, y

    init = jnp.zeros((Bsz, H, P, N), f32)
    _, ys = jax.lax.scan(step, init,
                         (jnp.moveaxis(xh, 1, 0), jnp.moveaxis(Bm, 1, 0),
                          jnp.moveaxis(Cm, 1, 0), jnp.moveaxis(dt, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1)                              # (B,T,H,P)
    return y + D.astype(f32)[None, None, :, None] * xh
