"""Neural-net layers in pure JAX: RMSNorm, RoPE, GQA attention (train +
decode), gated MLPs.

Decode attention is written so GSPMD can shard the KV-cache *sequence* dim:
scores/softmax/value-combine keep S as a contraction dim, letting XLA lower
the distributed-softmax (flash-decoding) pattern with small collectives.

When ``use_pallas`` is enabled (TPU), attention and RMSNorm route to the
Pallas kernels in ``repro.kernels`` (the paper's "manually implemented
well-optimized big operations").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.annotate import BATCH, ann

# toggled by configs/launchers; False on CPU (Pallas only interprets there)
_USE_PALLAS = False


def set_use_pallas(flag: bool):
    global _USE_PALLAS
    _USE_PALLAS = flag


# ---------------------------------------------------------------------------
# norms

def rmsnorm(x, weight, eps=1e-6):
    if _USE_PALLAS:
        from repro.kernels.ops import rmsnorm as k_rmsnorm
        return k_rmsnorm(x, weight, eps=eps)
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, -1, keepdims=True) + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# RoPE

def rope_freqs(positions, head_dim, theta):
    """positions: int (...,) -> (cos, sin) of shape (..., head_dim//2)."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., S, H, hd); cos/sin: (..., S, hd//2) broadcastable."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]  # add head dim
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)  # rotation in f32, stream stays bf16


# ---------------------------------------------------------------------------
# attention

def _softcap(scores, cap):
    if cap is None:
        return scores
    return jnp.tanh(scores / cap) * cap


# Above this many query rows, attention runs in unrolled query chunks so the
# (Sq, Sk) score matrix never materializes whole (flash-style blocking; the
# unrolled loop also keeps cost_analysis exact — lax.scan bodies are counted
# once by XLA's analysis).
ATTN_Q_CHUNK = 1024


def ring_selected(Sq: int) -> bool:
    """Should this full-sequence attention run on the ring schedule?

    ``PerfFlags.attn_impl``: "ring" forces it (degrades to one local block
    step without a mesh), "dense" forbids it, "auto" rings exactly when
    sequence sharding is on and the ambient mesh's "model" axis divides S
    (DESIGN.md §8).
    """
    from repro.perf_flags import FLAGS
    if FLAGS.attn_impl == "dense":
        return False
    if FLAGS.attn_impl == "ring":
        return True
    if not FLAGS.seq_shard:
        return False
    from repro.dist.compat import current_mesh
    mesh = current_mesh()
    n = dict(mesh.shape).get("model", 1) if mesh is not None else 1
    return n > 1 and Sq % n == 0


def gqa_attention(q, k, v, *, causal=True, window=None, softcap=None,
                  q_offset=0):
    """Grouped-query attention.

    q: (B, Sq, H, hd);  k, v: (B, Sk, K, hd) with H % K == 0.
    ``q_offset``: absolute position of q[0] (decode: cache length).
    ``window``: sliding window in tokens (None = full).
    """
    B, Sq, H, hd = q.shape

    if _USE_PALLAS and Sq > 1:
        from repro.kernels.ops import flash_attention
        return flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, q_offset=q_offset)

    from repro.perf_flags import FLAGS
    qc = FLAGS.attn_q_chunk
    if Sq > qc and FLAGS.attn_chunk_parallel:
        return _attention_chunk_parallel(q, k, v, causal=causal,
                                         window=window, softcap=softcap,
                                         q_offset=q_offset, qc=qc)
    if Sq > qc:
        Sk = k.shape[1]
        nc = (Sq + qc - 1) // qc
        outs = []
        for c in range(nc):
            lo = c * qc
            hi = min(Sq, lo + qc)
            kc, vc, k0 = k, v, 0
            if (FLAGS.window_slice and window is not None and causal
                    and q_offset == 0):
                # §Perf: keys outside [lo-window+1, hi) are masked anyway —
                # slice them out (static bounds): O(S·W) not O(S²)
                k0 = max(0, lo - window + 1)
                kend = min(Sk, hi)
                kc, vc = k[:, k0:kend], v[:, k0:kend]
            outs.append(_attention_dense(
                q[:, lo:hi], kc, vc, causal=causal, window=window,
                softcap=softcap, q_offset=q_offset + lo - k0))
        return jnp.concatenate(outs, axis=1)
    return _attention_dense(q, k, v, causal=causal, window=window,
                            softcap=softcap, q_offset=q_offset)


def _attention_chunk_parallel(q, k, v, *, causal, window, softcap,
                              q_offset, qc):
    """Blockwise attention with the q-chunk dim sharded over "model".

    All chunks compute in parallel across model ranks (k/v replicated);
    the output lands S-block-sharded, composing with the sequence-parallel
    residual stream.  Scores/probs per device are 1/|model| of the full
    (Sq, Sk) matrix.
    """
    from repro.perf_flags import FLAGS
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / np.sqrt(hd)
    pad = (-Sq) % qc
    if pad:
        q = jnp.pad(q, [(0, 0), (0, pad), (0, 0), (0, 0)])
    nc = (Sq + pad) // qc
    qr = q.reshape(B, nc, qc, K, G, hd)
    qr = ann(qr, BATCH, "model", None, None, None, None)

    scores = jnp.einsum("bnqkgh,bskh->bnkgqs", qr.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = _softcap(scores, softcap)
    qpos = (jnp.arange(nc)[:, None] * qc + jnp.arange(qc)[None]) + q_offset
    kpos = jnp.arange(Sk)
    mask = jnp.ones((nc, qc, Sk), bool)
    if causal:
        mask &= kpos[None, None] <= qpos[:, :, None]
    if window is not None:
        mask &= kpos[None, None] > qpos[:, :, None] - window
    scores = jnp.where(mask[None, :, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    if FLAGS.probs_bf16:
        probs = probs.astype(q.dtype)
        out = jnp.einsum("bnkgqs,bskh->bnqkgh", probs, v)
    else:
        out = jnp.einsum("bnkgqs,bskh->bnqkgh", probs,
                         v.astype(jnp.float32))
    out = out.reshape(B, Sq + pad, H, hd)
    if pad:
        out = out[:, :Sq]
    return out.astype(q.dtype)


def _attention_dense(q, k, v, *, causal, window, softcap, q_offset):
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(B, Sq, K, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = _softcap(scores, softcap)

    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    from repro.perf_flags import FLAGS
    if FLAGS.attn_probs_seq_shard:
        scores = ann(scores, BATCH, None, None, None, "model")
    probs = jax.nn.softmax(scores, axis=-1)
    if FLAGS.attn_probs_seq_shard:
        probs = ann(probs, BATCH, None, None, None, "model")
    if FLAGS.probs_bf16:
        # §Perf: f32 softmax, bf16 PV matmul (halves the probs buffers)
        out = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(q.dtype), v)
    else:
        out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v.astype(jnp.float32))
    if FLAGS.attn_probs_seq_shard:
        # pin the per-chunk PV output REPLICATED over model so the S-sharded
        # probs contract locally (partial-sum + small all-reduce) instead of
        # the partitioner replicating the whole probs tensor per chunk
        out = ann(out, BATCH, None, None, None, None)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, softcap=None):
    """One-token attention against a (possibly ring-buffered) KV cache.

    q: (B, 1, H, hd); caches: (B, S, K, hd); cache_len: filled length —
    a scalar (lockstep batch) or a (B,) vector (per-sequence lengths,
    mixed-length serving). Positions >= cache_len are masked out.
    S is a pure contraction dim — shard it and GSPMD emits the
    flash-decoding distributed softmax.
    """
    B, _, H, hd = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(B, K, G, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    scores = _softcap(scores, softcap)
    kpos = jnp.arange(S)
    # (1, S) or (B, S) valid map, broadcast over the (K, G) head dims
    valid = kpos[None, :] < jnp.asarray(cache_len).reshape(-1, 1)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", probs, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def paged_decode_attention(q, k_pages, v_pages, block_tables, lengths, *,
                           k_scale=None, v_scale=None, window=None,
                           softcap=None):
    """One-token attention through the paged pool (DESIGN.md §9).

    q: (B, H, hd); pools: (NB, bs, K, hd); block_tables: (B, P);
    lengths: (B,) live tokens including the current one.  Routes to the
    Pallas paged kernel on TPU; on CPU the gather-based oracle is the
    fast path (interpret-mode Pallas runs the grid in Python).
    ``k_scale``/``v_scale``: (NB, bs, K) f32 per-row scales when the
    pools are quantized (DESIGN.md §13); both paths fuse the dequant into
    attention — no full-precision cache copy.
    """
    if _USE_PALLAS:
        from repro.kernels.ops import paged_attention
        return paged_attention(q, k_pages, v_pages, block_tables, lengths,
                               k_scale=k_scale, v_scale=v_scale,
                               window=window, softcap=softcap)
    from repro.kernels.ref import paged_attention_ref
    return paged_attention_ref(q, k_pages, v_pages, block_tables, lengths,
                               k_scale=k_scale, v_scale=v_scale,
                               window=window, softcap=softcap)


def paged_context_attention(q, k_ctx, v_ctx, *, q_offset, kv_len,
                            window=None, softcap=None):
    """Chunked-prefill attention against gathered paged context.

    q: (B, C, H, hd) — the prompt chunk's queries; k_ctx/v_ctx:
    (B, S_ctx, K, hd) in logical position order (the chunk's own rows
    already written to the pool and gathered back); ``q_offset``:
    absolute position of q[:, 0]; ``kv_len``: live tokens after this
    chunk.  Both scalars or (B,) vectors.  Dense masked attention in
    f32 — prefill is compute-bound, the paged kernel targets decode.
    """
    B, C, H, hd = q.shape
    Sk, K = k_ctx.shape[1], k_ctx.shape[2]
    G = H // K
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(B, C, K, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                        k_ctx.astype(jnp.float32)) * scale
    scores = _softcap(scores, softcap)
    qpos = (jnp.asarray(q_offset).reshape(-1, 1)
            + jnp.arange(C)[None])                     # (B or 1, C)
    kpos = jnp.arange(Sk)
    mask = kpos[None, None] <= qpos[..., None]         # causal
    mask &= kpos[None, None] < jnp.asarray(kv_len).reshape(-1, 1, 1)
    if window is not None:
        mask &= kpos[None, None] > qpos[..., None] - window
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v_ctx.astype(jnp.float32))
    return out.reshape(B, C, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block (projections + rope + attn + out-proj)

def attn_project_qkv(p, x, cfg):
    B, S, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    from repro.perf_flags import FLAGS
    if FLAGS.seq_shard:
        # sequence sharding (DESIGN.md §8): q/k/v stay S-sharded over
        # "model" — GQA's small K never has to divide the model axis, and
        # the ring schedule consumes exactly this layout
        q = ann(q, BATCH, "model", None, None)
        k = ann(k, BATCH, "model", None, None)
        v = ann(v, BATCH, "model", None, None)
    else:
        # megatron: batch over data axes, heads over model (ann drops an
        # axis when the dim is not divisible, e.g. kv=8 heads on a 16-way
        # model axis)
        q = ann(q, BATCH, None, "model", None)
        k = ann(k, BATCH, None, "model", None)
        v = ann(v, BATCH, None, "model", None)
    return q, k, v


def attn_block(p, x, cfg, spec, positions=None, rope=True):
    """Full-sequence attention block (training / prefill).

    Returns (out, (k, v)) — the kv tensors become the prefill cache.
    """
    B, S, D = x.shape
    q, k, v = attn_project_qkv(p, x, cfg)
    if rope:
        pos = positions if positions is not None else jnp.arange(S)
        cos, sin = rope_freqs(pos, cfg.hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    causal = spec.attn != "bidir"
    if causal and S > 1 and ring_selected(S):
        # sequence-sharded ring schedule (DESIGN.md §8): S stays sharded
        # over "model" end to end; per-device attention state is O(S·S/P)
        from repro.dist.ring import ring_attention
        out = ring_attention(q, k, v, causal=True, window=spec.window,
                             softcap=cfg.attn_softcap,
                             inner="pallas" if _USE_PALLAS else "jnp")
        out = ann(out, BATCH, "model", None, None)
    else:
        out = gqa_attention(q, k, v, causal=causal,
                            window=spec.window, softcap=cfg.attn_softcap)
        out = ann(out, BATCH, None, "model", None)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    # sequence-parallel output: the heads-contraction all-reduce becomes a
    # reduce-scatter over S (a no-op re-pin on the ring path, which is
    # already S-sharded)
    return ann(out, BATCH, "model", None), (k, v)


def attn_block_decode(p, x, cache_k, cache_v, pos, cfg, spec):
    """Single-token decode step. x: (B, 1, D); caches: (B, S, K, hd);
    pos: absolute position — scalar (lockstep batch) or (B,) vector
    (per-sequence lengths). Returns (out, new_k_cache, new_v_cache).
    For windowed layers the cache is a ring buffer of size ``window``."""
    q, k, v = attn_project_qkv(p, x, cfg)
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        cos, sin = rope_freqs(pos[None], cfg.hd, cfg.rope_theta)
        cos, sin = cos[None], sin[None]          # (1, 1, hd//2), broadcast B
    else:
        cos, sin = rope_freqs(pos[:, None], cfg.hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    B, S, K, hd = cache_k.shape
    slot = pos % S  # ring for windowed caches; identity else
    if pos.ndim == 0:
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot,
                                                      axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot,
                                                      axis=1)
    else:
        # per-sequence write slots: one scatter over the flattened (B, S)
        idx = jnp.arange(B) * S + slot
        cache_k = cache_k.reshape(B * S, K, hd).at[idx].set(
            k[:, 0]).reshape(B, S, K, hd)
        cache_v = cache_v.reshape(B * S, K, hd).at[idx].set(
            v[:, 0]).reshape(B, S, K, hd)
    cache_len = jnp.minimum(pos + 1, S)
    # NOTE: windowing is enforced by ring-buffer SIZING (cache ring == window
    # for windowed layers), not by a position mask — ring slots are not in
    # position order.
    out = decode_attention(q, cache_k, cache_v, cache_len,
                           softcap=cfg.attn_softcap)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, cache_k, cache_v


def attn_block_decode_paged(p, x, cache, block_tables, pos, cfg, spec):
    """Single-token decode through the paged pool. x: (B, 1, D); cache:
    layer dict with "k"/"v" (NB, bs, K, hd) pools (plus "k_scale"/
    "v_scale" (NB, bs, K) f32 when the pools are quantized, DESIGN.md
    §13); block_tables: (B, P); pos: (B,) absolute position of the
    incoming token.  Writes the token's k/v into its block-table slot
    (quantizing on append), then attends through the table.  Returns
    (out, new_cache).  Inactive lanes must carry sink tables (pos 0,
    table 0) so their writes land in the sink block."""
    k_pages, v_pages = cache["k"], cache["v"]
    quantized = "k_scale" in cache
    q, k, v = attn_project_qkv(p, x, cfg)
    cos, sin = rope_freqs(pos[:, None], cfg.hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    NB, bs, K, hd = k_pages.shape
    B = q.shape[0]
    page = block_tables[jnp.arange(B), pos // bs]        # physical block
    idx = page * bs + pos % bs
    k_row, v_row = k[:, 0], v[:, 0]                      # (B, K, hd)
    scales = {}
    if quantized:
        from repro.kernels.quant import kv_quantize_rows
        k_row, ks_row = kv_quantize_rows(k_row, k_pages.dtype)
        v_row, vs_row = kv_quantize_rows(v_row, v_pages.dtype)
        scales = {
            "k_scale": cache["k_scale"].reshape(NB * bs, K).at[idx].set(
                ks_row).reshape(NB, bs, K),
            "v_scale": cache["v_scale"].reshape(NB * bs, K).at[idx].set(
                vs_row).reshape(NB, bs, K)}
    k_pages = k_pages.reshape(NB * bs, K, hd).at[idx].set(
        k_row.astype(k_pages.dtype)).reshape(NB, bs, K, hd)
    v_pages = v_pages.reshape(NB * bs, K, hd).at[idx].set(
        v_row.astype(v_pages.dtype)).reshape(NB, bs, K, hd)
    out = paged_decode_attention(q[:, 0], k_pages, v_pages, block_tables,
                                 pos + 1, window=spec.window,
                                 softcap=cfg.attn_softcap, **scales)
    out = jnp.einsum("bshk,hkd->bsd", out[:, None], p["wo"])
    return out, {"k": k_pages, "v": v_pages, **scales}


def cross_attn_block(p, x, enc_kv, cfg):
    """Decoder cross-attention to encoder output (whisper)."""
    B, S, D = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k, v = enc_kv
    out = gqa_attention(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ---------------------------------------------------------------------------
# MLPs

def mlp_block(p, x, kind):
    hid = lambda h: ann(h, BATCH, None, "model")   # F over model
    if kind == "swiglu":
        h = hid(jax.nn.silu(x @ p["wg"]) * (x @ p["wu"]))
    elif kind == "geglu":
        h = hid(jax.nn.gelu(x @ p["wg"], approximate=True) * (x @ p["wu"]))
    elif kind == "gelu":
        h = hid(jax.nn.gelu(x @ p["wu"], approximate=True))
    else:
        raise ValueError(kind)
    return ann(h @ p["wd"], BATCH, "model", None)  # sequence-parallel out
