"""Architecture configuration shared by the model zoo.

A model is a repeating *pattern* of layer blocks (the smallest repeating
unit): dense archs have a 1-element pattern, gemma2 a [local, global] pair,
jamba an 8-element mamba/attention block, etc.  Blocks at the same pattern
position are stacked along a leading axis and executed with ``lax.scan`` so
the lowered HLO stays small at 80+ layers.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Literal

import jax.numpy as jnp

LayerKind = Literal["attn", "mamba"]
MlpKind = Literal["swiglu", "geglu", "gelu", "moe", "none"]
AttnKind = Literal["causal", "window", "bidir"]


@dataclass(frozen=True)
class LayerSpec:
    kind: LayerKind = "attn"
    attn: AttnKind = "causal"
    window: int | None = None          # sliding-window size (tokens)
    mlp: MlpKind = "swiglu"
    cross_attn: bool = False           # decoder layers attending to encoder


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    pattern: tuple[LayerSpec, ...]

    head_dim: int | None = None        # default d_model // n_heads
    # --- MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    shared_expert: bool = False        # llama4-style always-on expert
    # --- attention details
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    attn_softcap: float | None = None  # gemma2 logit soft-capping
    final_softcap: float | None = None
    # --- SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_heads: int = 0                 # P = d_head for SSD; heads = d_inner/P
    ssm_expand: int = 2
    ssm_chunk: int = 128
    conv_width: int = 4
    # --- enc-dec / multimodal stubs
    encoder_layers: int = 0            # whisper encoder depth
    frontend_tokens: int = 0           # stub patch/frame embeddings length
    frontend_dim: int = 0              # stub embedding dim (before projector)
    # --- numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    sandwich_norm: bool = False        # gemma2 pre+post block norms
    # activation rematerialization at super-block granularity (the pjit-path
    # analogue of the paper's memory planning — DESIGN.md §2)
    remat: bool = True

    def __post_init__(self):
        assert self.n_layers % len(self.pattern) == 0, \
            (self.name, self.n_layers, len(self.pattern))

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_super(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_p(self) -> int:
        """SSD head dim P."""
        return self.d_inner // self.ssm_heads if self.ssm_heads else 0

    def activation_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    # ---- parameter count (for 6·N·D model-FLOPs bookkeeping) -------------
    def param_count(self, active_only: bool = False) -> int:
        D, F, V = self.d_model, self.d_ff, self.vocab
        H, K, hd = self.n_heads, self.n_kv_heads, self.hd
        total = V * D  # embed
        if not self.tie_embeddings:
            total += D * V
        for spec in self.pattern:
            n = self.n_super
            if spec.kind == "attn":
                attn = D * H * hd + 2 * D * K * hd + H * hd * D
                total += n * (attn + 2 * D)  # + norms
                if spec.cross_attn:
                    total += n * (attn + D)
            else:  # mamba2 (B/C shared across heads: n_groups=1)
                di, N, Hs = self.d_inner, self.ssm_state, self.ssm_heads
                ssm = (D * (2 * di + 2 * N + Hs)   # in_proj (z,x,B,C,dt)
                       + self.conv_width * (di + 2 * N)
                       + di * D + 3 * Hs)
                total += n * (ssm + D)
            if spec.mlp == "moe":
                e_all = self.n_experts
                e_act = self.top_k + (1 if self.shared_expert else 0)
                per_expert = 3 * D * F
                total += n * (D * e_all + 2 * D)
                total += n * per_expert * (e_act if active_only else e_all)
                if self.shared_expert:
                    total += 0 if active_only else 0  # counted in e_all? no:
            elif spec.mlp in ("swiglu", "geglu"):
                total += n * (3 * D * F + 2 * D)
            elif spec.mlp == "gelu":
                total += n * (2 * D * F + 2 * D)
        if self.encoder_layers:
            attn = D * H * hd + 2 * D * K * hd + H * hd * D
            mlp = 2 * D * F
            total += self.encoder_layers * (attn + mlp + 2 * D)
        if self.frontend_tokens:
            total += self.frontend_dim * D  # projector stub
        return int(total)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
    # the full-sequence long-context shape (DESIGN.md §8): quadratic
    # attention cannot fit it per device — it exists for the
    # sequence-sharded ring path (PerfFlags.seq_shard, dist/ring.py)
    "long_500k_prefill": InputShape("long_500k_prefill", 524_288, 1,
                                    "prefill"),
}


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A tiny same-family variant for CPU smoke tests (<=2 super-blocks,
    d_model<=512, <=4 experts)."""
    pat = cfg.pattern
    small = dict(
        n_layers=len(pat) * min(2, cfg.n_super),
        d_model=256,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) or 2,
        d_ff=512,
        vocab=512,
        head_dim=64,
        n_experts=4 if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        # drop-free capacity so prefill/decode routing agree exactly in tests
        capacity_factor=8.0,
        ssm_state=32 if cfg.ssm_state else 0,
        ssm_heads=8 if cfg.ssm_heads else 0,
        ssm_chunk=16,
        encoder_layers=2 if cfg.encoder_layers else 0,
        frontend_tokens=16 if cfg.frontend_tokens else 0,
        frontend_dim=64 if cfg.frontend_dim else 0,
        dtype="float32",
    )
    small.update(overrides)
    return replace(cfg, **small)
