"""Fault injection for the serve path (the serving sibling of the
checkpoint layer's ``FailingFS``, DESIGN.md §12/§14).

``ChaosHooks`` is an injectable seam threaded through the block
allocator and ``PagedServeEngine``: every hook is a host-side call at a
well-defined point in the step loop, so an injected fault models a real
failure mode without patching engine internals:

* ``fail_alloc_after``  — the allocator raises ``ChaosError`` on every
  ``alloc()`` after N successful calls (a device pool that goes bad
  mid-run; the engine must fail the *growing request*, not the process).
* ``fail_decode_at_step`` — one transient device fault immediately
  before the Nth batched decode dispatch (fires once; the engine retries
  the identical step — no cache mutation has happened yet).
* ``poison_rid`` — every device-path touch (prefill chunk, decode lane
  assembly) of request ``rid`` faults: the poisoned request must end in
  a terminal ``ERROR`` with its blocks/slot/SSM state reclaimed while
  every other lane's tokens are unaffected.
* ``corrupt_swap_rid`` — flips one byte of the request's swap payload on
  swap-out.  The engine checksums payloads at swap-out and verifies on
  restore, so the corruption is *detected* and the request fails typed
  instead of silently decoding from garbage KV.
* ``admission_delay_s`` — sleeps before each admission pass (a slow
  frontend; exercises queue-wait accounting and deadline expiry).

All hooks are no-ops at their defaults, and the engine disables the seam
during warmup — the throwaway compile request is not traffic.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field


class ChaosError(RuntimeError):
    """An injected fault (never raised by real engine logic)."""


@dataclass
class ChaosHooks:
    fail_alloc_after: int | None = None
    fail_decode_at_step: int | None = None
    poison_rid: int | None = None
    corrupt_swap_rid: int | None = None
    admission_delay_s: float = 0.0
    # observability: how often each seam was crossed / fired
    allocs: int = 0
    decode_steps: int = 0
    faults_fired: int = 0
    corrupted: list[int] = field(default_factory=list)

    def on_alloc(self, n: int) -> None:
        if self.fail_alloc_after is not None \
                and self.allocs >= self.fail_alloc_after:
            self.faults_fired += 1
            raise ChaosError(
                f"chaos: block alloc failed (after {self.allocs} allocs)")
        self.allocs += 1

    def on_decode_step(self) -> None:
        self.decode_steps += 1
        if self.fail_decode_at_step == self.decode_steps:
            self.faults_fired += 1
            raise ChaosError(
                f"chaos: decode step {self.decode_steps} faulted")

    def check_request(self, rid: int) -> None:
        if self.poison_rid == rid:
            self.faults_fired += 1
            raise ChaosError(f"chaos: poisoned request {rid}")

    def on_swap_out(self, rid: int, arrays: dict) -> None:
        """Corrupt one byte of ``rid``'s payload in place (post-checksum,
        so the engine's restore-time verification must catch it)."""
        if self.corrupt_swap_rid != rid or not arrays:
            return
        name = sorted(arrays)[0]
        buf = arrays[name].view("uint8").reshape(-1)
        buf[0] ^= 0xFF
        self.faults_fired += 1
        self.corrupted.append(rid)

    def on_admission(self) -> None:
        if self.admission_delay_s > 0:
            time.sleep(self.admission_delay_s)
