"""Serving engines: static batched decode and paged continuous batching.

``ServeEngine`` is the static path: one batch, prompts tail-padded to a
common length, a dense ``(B, max_len)`` KV cache, lockstep decode until
the batch's token budget is exhausted.  Mixed-length prompts are handled
honestly (per-sequence ``lengths`` thread through prefill; decode masks
each sequence's own live cache length) but the *memory* is still padded
capacity and the *schedule* still runs the whole batch until the slowest
request finishes.

``PagedServeEngine`` is the continuous-batching path (DESIGN.md §9):
KV storage is a pool of fixed-size blocks (``serve/paging.py``), decode
lanes are slots that requests flow through — admission fills free slots
each step, long prompts prefill chunk-by-chunk so they never stall the
decode batch, finished sequences release their blocks immediately.
Decode attention gathers K/V through per-sequence block tables (the
Pallas ``kernels/paged_attention.py`` kernel on TPU).

Both engines report jit compile time separately (``compile_s``) so
``tok_per_s`` measures steady-state decode, not compilation.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import ArchConfig, get_model

from .paging import BlockAllocator, BlockTables, PagingError


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    compile_s: float = 0.0     # jit compile + first-call warmup, reported
    tokens_out: int = 0        # tokens produced by TIMED decode steps (each
    steps: int = 0             # request's first token comes from prefill
    peak_cache_blocks: int = 0   # logits and is counted by neither engine)
    peak_cache_bytes: int = 0    # paged engine only
    # per-request latency accounting (paged engine; DESIGN.md §11):
    # TTFT = enqueue -> first token, TPOT = mean inter-token time after
    # the first, queue_wait = enqueue -> admission.  Seconds.
    ttft_p50: float = 0.0
    ttft_p99: float = 0.0
    tpot_p50: float = 0.0
    tpot_p99: float = 0.0
    queue_wait_p50: float = 0.0
    queue_wait_p99: float = 0.0

    @property
    def tok_per_s(self):
        return self.tokens_out / self.decode_s if self.decode_s else 0.0


class ServeEngine:
    """Static batch engine: dense padded cache, lockstep decode."""

    def __init__(self, cfg: ArchConfig, params, max_len: int = 512):
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, pad_to=max_len))
        self._decode = jax.jit(self.model.decode)

    def pad_batch(self, prompts: list[list[int]], pad_to: int | None = None):
        """Tail-pad prompts to a common length.  Returns (tokens (B, L),
        lengths (B,)) — the lengths ride along so prefill takes each
        sequence's logits at its OWN last token and decode masks the pad
        tail (pad id 0 is a real vocab id; masking, not the pad value,
        is what keeps it out of attention).  ``pad_to`` fixes L across
        batches so multi-batch serving compiles prefill once."""
        L = max(max(len(p) for p in prompts), pad_to or 0)
        toks = np.zeros((len(prompts), L), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
        lengths = np.asarray([len(p) for p in prompts], np.int32)
        return jnp.asarray(toks), jnp.asarray(lengths)

    def generate(self, prompts: list[list[int]], max_new_tokens: int = 32,
                 temperature: float = 0.0, seed: int = 0,
                 top_k: int | None = None, top_p: float | None = None,
                 extra_inputs: dict | None = None, warmup: bool = True,
                 pad_prompts_to: int | None = None):
        """Returns (tokens (B, max_new_tokens), ServeStats)."""
        toks, lengths = self.pad_batch(prompts, pad_to=pad_prompts_to)
        batch = {"tokens": toks, "lengths": lengths, **(extra_inputs or {})}
        stats = ServeStats()
        if warmup:
            # compile both steps on the real shapes; one throwaway
            # execution each (compile dominates) keeps tok_per_s honest
            t0 = time.time()
            logits, cache = self._prefill(self.params, batch)
            wtok = jnp.zeros((len(prompts), 1), jnp.int32)
            wl, _ = self._decode(self.params, cache, {"tokens": wtok})
            jax.block_until_ready(wl)
            stats.compile_s = time.time() - t0

        t0 = time.time()
        logits, cache = self._prefill(self.params, batch)
        logits.block_until_ready()
        stats.prefill_s = time.time() - t0

        key = jax.random.PRNGKey(seed)
        out = []
        t0 = time.time()
        for i in range(max_new_tokens):
            if temperature > 0 and (top_k is not None or top_p is not None):
                from repro.kernels.ops import sample_tokens
                key, sub = jax.random.split(key)
                u = jax.random.uniform(sub, (logits.shape[0],))
                nxt = sample_tokens(logits, u, temperature=temperature,
                                    top_k=top_k, top_p=top_p)
            elif temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits / temperature, -1)
            else:
                nxt = jnp.argmax(logits, -1)
            out.append(nxt)
            logits, cache = self._decode(self.params, cache,
                                         {"tokens": nxt[:, None].astype(jnp.int32)})
        jax.block_until_ready(logits)
        stats.decode_s = time.time() - t0
        stats.steps = max_new_tokens
        # first tokens are prefill-derived — same accounting as the paged
        # engine so --paged / static tok_per_s compare apples to apples
        stats.tokens_out = len(prompts) * max(0, max_new_tokens - 1)
        return np.stack([np.asarray(t) for t in out], axis=1), stats


# ---------------------------------------------------------------------------
# continuous batching


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    out: list[int] = field(default_factory=list)
    prefilled: int = 0          # prompt tokens already in the cache
    # lifecycle stamps (time.perf_counter(); obs layer, DESIGN.md §11)
    t_enq: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0        # first token sampled (prefill logits)
    t_done: float = 0.0

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new_tokens


class PagedServeEngine:
    """Paged KV-cache + continuous-batching decode (DESIGN.md §9).

    ``max_batch`` decode lanes over a block pool of ``num_blocks`` blocks
    of ``block_size`` tokens (block 0 is the sink).  Admission is
    reservation-checked: a request is admitted only when its worst-case
    block need (prompt + generation budget) fits alongside every other
    admitted request's, so the engine can never deadlock on the free
    list.  Long prompts prefill at most ``prefill_chunks_per_step``
    chunks of ``prefill_chunk`` tokens per engine step, interleaved with
    decode steps for the already-running lanes.
    """

    def __init__(self, cfg: ArchConfig, params, *, block_size: int = 16,
                 max_batch: int = 8, max_len: int = 512,
                 prefill_chunk: int = 64, num_blocks: int | None = None,
                 prefill_chunks_per_step: int = 1, kv_dtype=None,
                 top_k: int | None = None, top_p: float | None = None):
        if cfg.encoder_layers or cfg.frontend_tokens:
            raise ValueError("paged serving supports decoder-only text "
                             "archs (no enc-dec / multimodal prefixes)")
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.block_size = block_size
        self.max_batch = max_batch
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        self.prefill_chunks_per_step = prefill_chunks_per_step
        # "int8"/"fp8_e4m3"/"fp8_e5m2" quantize the KV pools with per-row
        # scale tensors riding alongside (DESIGN.md §13); None = native
        self.kv_dtype = None if kv_dtype == "native" else kv_dtype
        self.top_k = top_k
        self.top_p = top_p
        self.max_pages = -(-max_len // block_size)
        if num_blocks is None:
            num_blocks = max_batch * self.max_pages + 1   # +1: sink
        self.alloc = BlockAllocator(num_blocks, block_size)
        self.tables = BlockTables(self.alloc, max_batch, self.max_pages)
        self.cache = self.model.make_paged_cache(num_blocks, block_size,
                                                 max_batch,
                                                 kv_dtype=self.kv_dtype)
        self._decode = jax.jit(self.model.decode_paged, donate_argnums=(1,))
        self._chunk = jax.jit(self.model.prefill_chunk_paged,
                              donate_argnums=(1,))
        self.pos = np.zeros(max_batch, np.int64)   # tokens in cache per lane
        self.slots: list[Request | None] = [None] * max_batch
        self.pending: deque[Request] = deque()
        self.completed: dict[int, list[int]] = {}  # rid -> emitted tokens
        self._last_logits: dict[int, jax.Array] = {}   # slot -> (V,) logits
        self._reserved_blocks = 0
        self._next_rid = 0
        self._key = jax.random.PRNGKey(0)
        self.temperature = 0.0
        # obs (DESIGN.md §11): lifecycle spans land on per-request tracks
        # ("req<rid>"), engine steps on "serve"; TTFT/TPOT/queue-wait
        # histograms live in the process metrics registry.  _observe is
        # dropped during warmup so the throwaway request pollutes nothing.
        self._observe = True

    # -- obs helpers --------------------------------------------------------
    @staticmethod
    def _hist(name: str):
        return obs.get_metrics().histogram(name)

    def _req_track(self, req: Request) -> str:
        return f"req{req.rid}"

    # -- request lifecycle --------------------------------------------------
    def add_request(self, prompt: list[int], max_new_tokens: int) -> int:
        if len(prompt) + max_new_tokens > self.max_len:
            raise PagingError(
                f"prompt({len(prompt)}) + new({max_new_tokens}) exceeds "
                f"max_len={self.max_len}")
        need = self.tables.pages_for(len(prompt) + max_new_tokens)
        if need > self.alloc.num_blocks - 1:
            raise PagingError(
                f"request needs {need} blocks but the pool only has "
                f"{self.alloc.num_blocks - 1} — it could never be admitted")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, list(prompt), max_new_tokens,
                      t_enq=time.perf_counter())
        self.pending.append(req)
        if self._observe:
            obs.get_recorder().instant(
                "enqueued", cat="serve", track=self._req_track(req),
                prompt_len=len(prompt), budget=max_new_tokens)
        return rid

    def _worst_case_pages(self, req: Request) -> int:
        return self.tables.pages_for(len(req.prompt) + req.max_new_tokens)

    def _admit(self):
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.pending:
                continue
            need = self._worst_case_pages(self.pending[0])
            if self._reserved_blocks + need > self.alloc.num_blocks - 1:
                break                       # head-of-line: keep FIFO order
            req = self.pending.popleft()
            self._reserved_blocks += need
            self.slots[slot] = req
            self.pos[slot] = 0
            req.prefilled = 0
            req.t_admit = time.perf_counter()
            if self._observe:
                rec = obs.get_recorder()
                rec.complete("queued", rec.to_us(req.t_enq),
                             rec.to_us(req.t_admit), cat="serve",
                             track=self._req_track(req), slot=slot)
                self._hist("serve.queue_wait_s").observe(
                    req.t_admit - req.t_enq)

    def _first_token(self, req: Request):
        """Stamp + record the first-token milestone (TTFT)."""
        req.t_first = time.perf_counter()
        if self._observe:
            obs.get_recorder().instant("first_token", cat="serve",
                                       track=self._req_track(req))
            self._hist("serve.ttft_s").observe(req.t_first - req.t_enq)

    def _finish(self, slot: int):
        req = self.slots[slot]
        req.t_done = time.perf_counter()
        if self._observe:
            rec = obs.get_recorder()
            t0 = req.t_first or req.t_admit or req.t_enq
            rec.complete("decode", rec.to_us(t0), rec.to_us(req.t_done),
                         cat="serve", track=self._req_track(req),
                         tokens=len(req.out))
            rec.instant("evicted", cat="serve", track=self._req_track(req))
            if req.t_first and len(req.out) > 1:
                self._hist("serve.tpot_s").observe(
                    (req.t_done - req.t_first) / (len(req.out) - 1))
        self.completed[req.rid] = list(req.out)
        self._reserved_blocks -= self._worst_case_pages(req)
        self.tables.release(slot)
        self.slots[slot] = None
        self.pos[slot] = 0
        self._last_logits.pop(slot, None)

    # -- device steps -------------------------------------------------------
    def _prefill_one_chunk(self, slot: int, stats: ServeStats):
        req = self.slots[slot]
        C = self.prefill_chunk
        start = req.prefilled
        chunk = req.prompt[start:start + C]
        n = len(chunk)
        toks = np.zeros((1, C), np.int32)
        toks[0, :n] = chunk
        self.tables.ensure(slot, start + n)
        batch = {"tokens": jnp.asarray(toks),
                 "block_tables": jnp.asarray(self.tables.row(slot)[None]),
                 "start": jnp.asarray(start, jnp.int32),
                 "length": jnp.asarray(n, jnp.int32),
                 "slot": jnp.asarray(slot, jnp.int32)}
        rec = obs.get_recorder()
        t0 = time.time()
        with rec.span("prefill_chunk", cat="serve",
                      track=self._req_track(req) if self._observe else "serve",
                      slot=slot, start=start, tokens=n):
            logits, self.cache = self._chunk(self.params, self.cache, batch)
            logits.block_until_ready()
        stats.prefill_s += time.time() - t0
        req.prefilled += n
        self.pos[slot] = req.prefilled
        if req.prefilled >= len(req.prompt):
            self._last_logits[slot] = logits[0]   # sample at next decode

    def _sample(self, logits):
        """logits: (V,) or (B, V) -> sampled token id(s), same leading
        shape.  With ``top_k``/``top_p`` set the fused Pallas sampling
        kernel filters + draws in one pass (DESIGN.md §13); otherwise the
        plain categorical / argmax path."""
        if self.temperature > 0 and (self.top_k is not None
                                     or self.top_p is not None):
            from repro.kernels.ops import sample_tokens
            rows = jnp.atleast_2d(logits)
            self._key, sub = jax.random.split(self._key)
            u = jax.random.uniform(sub, (rows.shape[0],))
            toks = sample_tokens(rows, u, temperature=self.temperature,
                                 top_k=self.top_k, top_p=self.top_p)
            return toks if logits.ndim > 1 else toks[0]
        if self.temperature > 0:
            self._key, sub = jax.random.split(self._key)
            return jax.random.categorical(sub, logits / self.temperature, -1)
        return jnp.argmax(logits, -1)

    def step(self, stats: ServeStats | None = None) -> int:
        """One engine step: admit, advance prefills, decode every running
        lane, retire finished requests.  Returns tokens emitted."""
        stats = stats if stats is not None else ServeStats()
        self._admit()

        budget = self.prefill_chunks_per_step
        for slot, req in enumerate(self.slots):
            if budget <= 0:
                break
            if req is not None and req.prefilled < len(req.prompt):
                self._prefill_one_chunk(slot, stats)
                budget -= 1

        # sample the first token for lanes whose prefill just completed
        for slot, logits in list(self._last_logits.items()):
            req = self.slots[slot]
            req.out.append(int(np.asarray(self._sample(logits))))
            self._first_token(req)
            del self._last_logits[slot]
            if req.done:                      # degenerate 1-token budget
                self._finish(slot)

        lanes = [b for b, r in enumerate(self.slots)
                 if r is not None and r.prefilled >= len(r.prompt)
                 and not r.done]
        if not lanes:
            return 0

        toks = np.zeros((self.max_batch, 1), np.int32)
        tables = np.zeros_like(self.tables.tables)
        pos = np.zeros(self.max_batch, np.int32)
        active = np.zeros(self.max_batch, bool)
        for b in lanes:
            req = self.slots[b]
            toks[b, 0] = req.out[-1]
            # the incoming token is written at position pos[b]
            self.tables.ensure(b, int(self.pos[b]) + 1)
            tables[b] = self.tables.row(b)
            pos[b] = self.pos[b]
            active[b] = True
        batch = {"tokens": jnp.asarray(toks),
                 "block_tables": jnp.asarray(tables),
                 "pos": jnp.asarray(pos),
                 "active": jnp.asarray(active)}
        rec = obs.get_recorder()
        if self._observe:
            rec.counter("blocks_in_use", self.alloc.in_use, track="serve",
                        cat="serve")
            obs.get_metrics().gauge("serve.blocks_in_use").set(
                self.alloc.in_use)
        t0 = time.time()
        with rec.span("decode_step", cat="serve", track="serve",
                      lanes=len(lanes)):
            logits, self.cache = self._decode(self.params, self.cache, batch)
            nxt = np.asarray(self._sample(logits))
        stats.decode_s += time.time() - t0
        stats.steps += 1

        for b in lanes:
            req = self.slots[b]
            req.out.append(int(nxt[b]))
            self.pos[b] += 1
            stats.tokens_out += 1
            if req.done:
                self._finish(b)
        return len(lanes)

    @property
    def busy(self) -> bool:
        return bool(self.pending) or any(r is not None for r in self.slots)

    def run(self, stats: ServeStats | None = None,
            max_steps: int = 1_000_000) -> ServeStats:
        stats = stats if stats is not None else ServeStats()
        # report THIS run's high-water mark (in-flight blocks still count)
        self.alloc.peak_in_use = self.alloc.in_use
        # latency percentiles are computed over THIS run's observations
        # (the registry histograms accumulate across runs)
        h_ttft = self._hist("serve.ttft_s")
        h_tpot = self._hist("serve.tpot_s")
        h_wait = self._hist("serve.queue_wait_s")
        marks = {id(h): len(h.values) for h in (h_ttft, h_tpot, h_wait)}
        steps = 0
        while self.busy:
            self.step(stats)
            steps += 1
            if steps > max_steps:
                raise RuntimeError("engine did not drain the request queue")
        stats.peak_cache_blocks = self.alloc.peak_in_use
        from repro.core.memplan import kv_cache_bytes_paged
        stats.peak_cache_bytes = (self.alloc.peak_in_use
                                  * kv_cache_bytes_paged(
                                      self.cfg, [], self.block_size,
                                      kv_dtype=self.kv_dtype)
                                  ["block_bytes"])

        def pcts(h):
            vs = h.values[marks[id(h)]:]
            return h.quantile(0.50, vs), h.quantile(0.99, vs)

        stats.ttft_p50, stats.ttft_p99 = pcts(h_ttft)
        stats.tpot_p50, stats.tpot_p99 = pcts(h_tpot)
        stats.queue_wait_p50, stats.queue_wait_p99 = pcts(h_wait)
        return stats

    def reset(self):
        """Drop all requests and recycle every block (cache contents stay
        — they are garbage by definition once unreferenced)."""
        for slot, r in enumerate(self.slots):
            if r is not None:
                self._finish(slot)
        self.pending.clear()
        self.alloc = BlockAllocator(self.alloc.num_blocks, self.block_size)
        self.tables = BlockTables(self.alloc, self.max_batch, self.max_pages)
        self.pos[:] = 0
        self._reserved_blocks = 0

    def warmup(self) -> float:
        """Compile the chunk-prefill and decode steps (one throwaway
        request); returns the wall time (reported as ``compile_s``)."""
        t0 = time.time()
        saved_pending = self.pending
        self.pending = deque()
        self._observe = False       # the throwaway request is not traffic
        try:
            self.add_request([1] * min(self.prefill_chunk + 1,
                                       self.max_len - 2), 2)
            self.run()
            self.reset()
        finally:
            self._observe = True
            self.pending = saved_pending
        return time.time() - t0

    def generate(self, prompts: list[list[int]],
                 max_new_tokens: int | list[int] = 32,
                 temperature: float = 0.0, seed: int = 0,
                 top_k: int | None = None, top_p: float | None = None,
                 warmup: bool = True):
        """Batch convenience API: enqueue everything, run to drain.

        Returns (list of per-request token lists, ServeStats) — requests
        may have different ``max_new_tokens`` (continuous batching's whole
        point), so the output is ragged.
        """
        stats = ServeStats()
        if warmup:
            self.temperature = 0.0      # throwaway request decodes greedily
            stats.compile_s = self.warmup()
        # seed AFTER warmup so sampled streams are reproducible across
        # warmup settings
        self.temperature = temperature
        if top_k is not None:
            self.top_k = top_k
        if top_p is not None:
            self.top_p = top_p
        self._key = jax.random.PRNGKey(seed)
        budgets = (max_new_tokens if isinstance(max_new_tokens, (list, tuple))
                   else [max_new_tokens] * len(prompts))
        rids = [self.add_request(p, n) for p, n in zip(prompts, budgets)]
        self.run(stats)
        return [self.completed[r] for r in rids], stats
