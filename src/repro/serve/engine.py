"""Serving engines: static batched decode and paged continuous batching.

``ServeEngine`` is the static path: one batch, prompts tail-padded to a
common length, a dense ``(B, max_len)`` KV cache, lockstep decode until
the batch's token budget is exhausted.  Mixed-length prompts are handled
honestly (per-sequence ``lengths`` thread through prefill; decode masks
each sequence's own live cache length) but the *memory* is still padded
capacity and the *schedule* still runs the whole batch until the slowest
request finishes.

``PagedServeEngine`` is the continuous-batching path (DESIGN.md §9):
KV storage is a pool of fixed-size blocks (``serve/paging.py``), decode
lanes are slots that requests flow through — admission fills free slots
each step, long prompts prefill chunk-by-chunk so they never stall the
decode batch, finished sequences release their blocks immediately.
Decode attention gathers K/V through per-sequence block tables (the
Pallas ``kernels/paged_attention.py`` kernel on TPU).

Overload robustness (DESIGN.md §14): the paged engine degrades instead
of crashing.  Every request ends in a typed terminal status
(``OK | SHED | TIMEOUT | CANCELLED | ERROR``); admission is bounded and
shedding, deadlines and ``cancel(rid)`` free resources deterministically,
and when the block pool runs dry a victim policy preempts a lane —
swapping its live KV blocks + SSM slot state to a host-side ``SwapPool``
(bit-exact restore) or falling back to recompute-preemption when the
swap pool is full.  A ``ChaosHooks`` seam (``serve/chaos.py``) injects
faults at each of these points for the fault-isolation tests.

Both engines report jit compile time separately (``compile_s``) so
``tok_per_s`` measures steady-state decode, not compilation.
"""
from __future__ import annotations

import enum
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import ArchConfig, get_model

from .chaos import ChaosError
from .paging import (BlockAllocator, BlockTables, PagingError, SwapEntry,
                     SwapPool, checksum_arrays)


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    compile_s: float = 0.0     # jit compile + first-call warmup, reported
    tokens_out: int = 0        # tokens produced by TIMED decode steps (each
    steps: int = 0             # request's first token comes from prefill
    peak_cache_blocks: int = 0   # logits and is counted by neither engine)
    peak_cache_bytes: int = 0    # paged engine only
    # per-request latency accounting (paged engine; DESIGN.md §11):
    # TTFT = enqueue -> first token, TPOT = mean inter-token time after
    # the first, queue_wait = enqueue -> admission.  Seconds.
    ttft_p50: float = 0.0
    ttft_p99: float = 0.0
    tpot_p50: float = 0.0
    tpot_p99: float = 0.0
    queue_wait_p50: float = 0.0
    queue_wait_p99: float = 0.0
    # lifecycle accounting for THIS run (DESIGN.md §14)
    preempted: int = 0         # lane evictions (swap or recompute)
    restored: int = 0          # preempted requests resumed
    shed: int = 0              # admission rejections (typed, never raised)
    timeouts: int = 0          # deadline expiries
    cancelled: int = 0
    errors: int = 0            # faulted requests isolated to terminal ERROR
    swap_peak_blocks: int = 0  # host swap pool high-water mark
    goodput_tokens: int = 0    # decode tokens of requests that ended OK

    @property
    def tok_per_s(self):
        return self.tokens_out / self.decode_s if self.decode_s else 0.0

    @property
    def goodput_tok_per_s(self):
        return self.goodput_tokens / self.decode_s if self.decode_s else 0.0


class ServeEngine:
    """Static batch engine: dense padded cache, lockstep decode."""

    def __init__(self, cfg: ArchConfig, params, max_len: int = 512):
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, pad_to=max_len))
        self._decode = jax.jit(self.model.decode)

    def pad_batch(self, prompts: list[list[int]], pad_to: int | None = None):
        """Tail-pad prompts to a common length.  Returns (tokens (B, L),
        lengths (B,)) — the lengths ride along so prefill takes each
        sequence's logits at its OWN last token and decode masks the pad
        tail (pad id 0 is a real vocab id; masking, not the pad value,
        is what keeps it out of attention).  ``pad_to`` fixes L across
        batches so multi-batch serving compiles prefill once."""
        L = max(max(len(p) for p in prompts), pad_to or 0)
        toks = np.zeros((len(prompts), L), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
        lengths = np.asarray([len(p) for p in prompts], np.int32)
        return jnp.asarray(toks), jnp.asarray(lengths)

    def generate(self, prompts: list[list[int]], max_new_tokens: int = 32,
                 temperature: float = 0.0, seed: int = 0,
                 top_k: int | None = None, top_p: float | None = None,
                 extra_inputs: dict | None = None, warmup: bool = True,
                 pad_prompts_to: int | None = None):
        """Returns (tokens (B, max_new_tokens), ServeStats)."""
        toks, lengths = self.pad_batch(prompts, pad_to=pad_prompts_to)
        batch = {"tokens": toks, "lengths": lengths, **(extra_inputs or {})}
        stats = ServeStats()
        if warmup:
            # compile both steps on the real shapes; one throwaway
            # execution each (compile dominates) keeps tok_per_s honest
            t0 = time.time()
            logits, cache = self._prefill(self.params, batch)
            wtok = jnp.zeros((len(prompts), 1), jnp.int32)
            wl, _ = self._decode(self.params, cache, {"tokens": wtok})
            jax.block_until_ready(wl)
            stats.compile_s = time.time() - t0

        t0 = time.time()
        logits, cache = self._prefill(self.params, batch)
        logits.block_until_ready()
        stats.prefill_s = time.time() - t0

        key = jax.random.PRNGKey(seed)
        out = []
        t0 = time.time()
        for i in range(max_new_tokens):
            if temperature > 0 and (top_k is not None or top_p is not None):
                from repro.kernels.ops import sample_tokens
                key, sub = jax.random.split(key)
                u = jax.random.uniform(sub, (logits.shape[0],))
                nxt = sample_tokens(logits, u, temperature=temperature,
                                    top_k=top_k, top_p=top_p)
            elif temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits / temperature, -1)
            else:
                nxt = jnp.argmax(logits, -1)
            out.append(nxt)
            logits, cache = self._decode(self.params, cache,
                                         {"tokens": nxt[:, None].astype(jnp.int32)})
        jax.block_until_ready(logits)
        stats.decode_s = time.time() - t0
        stats.steps = max_new_tokens
        # first tokens are prefill-derived — same accounting as the paged
        # engine so --paged / static tok_per_s compare apples to apples
        stats.tokens_out = len(prompts) * max(0, max_new_tokens - 1)
        return np.stack([np.asarray(t) for t in out], axis=1), stats


# ---------------------------------------------------------------------------
# continuous batching


class Status(enum.Enum):
    """Typed terminal status — every request ends in exactly one of
    these (DESIGN.md §14 state machine); exceptions are reserved for
    engine invariant violations, never for overload."""
    OK = "OK"
    SHED = "SHED"
    TIMEOUT = "TIMEOUT"
    CANCELLED = "CANCELLED"
    ERROR = "ERROR"


# typed rejection reason codes carried by Ticket / RequestResult.reason
REJECT_QUEUE_FULL = "QUEUE_FULL"
REJECT_PROMPT_TOO_LONG = "PROMPT_TOO_LONG"
REJECT_EVICTED = "EVICTED"      # shed from the queue by a higher priority


class ServeError(RuntimeError):
    """The engine could not drain its queue (stuck scheduler).  Carries
    the stuck request ids and the allocator occupancy so the failure is
    actionable instead of a bare RuntimeError."""

    def __init__(self, msg: str, stuck_rids=(), blocks_in_use: int = 0,
                 num_free: int = 0):
        self.stuck_rids = list(stuck_rids)
        self.blocks_in_use = blocks_in_use
        self.num_free = num_free
        super().__init__(
            f"{msg}: stuck rids {self.stuck_rids}, "
            f"{blocks_in_use} blocks in use, {num_free} free")


@dataclass
class Ticket:
    """Admission result — ``add_request`` never raises on overload.
    ``accepted=False`` carries a typed ``reason`` code (QUEUE_FULL /
    PROMPT_TOO_LONG), a human ``detail``, and for queue rejections a
    ``retry_after_s`` backoff hint."""
    rid: int
    accepted: bool
    reason: str = ""
    detail: str = ""
    retry_after_s: float | None = None


@dataclass
class RequestResult:
    """Terminal record for one request (``engine.results[rid]``)."""
    rid: int
    status: Status
    tokens: list[int]
    reason: str = ""
    preemptions: int = 0
    deadline_miss_s: float | None = None


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    out: list[int] = field(default_factory=list)
    prefilled: int = 0          # seq tokens already in the cache
    priority: int = 0           # higher = more important (preempts lower)
    deadline: float | None = None   # absolute perf_counter() deadline
    # ``seq`` is what prefill rebuilds: the prompt, or after a
    # recompute-preemption the prompt + already-emitted tokens (minus the
    # last, which re-enters as the next decode input)
    seq: list[int] = field(default_factory=list)
    emit_first: bool = True     # sample a first token when prefill ends
    n_preempted: int = 0
    reserved_pages: int = 0     # worst-case reservation (reserve mode)
    admit_seq: int = -1         # admission order (LIFO victim policy)
    # lifecycle stamps (time.perf_counter(); obs layer, DESIGN.md §11)
    t_enq: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0        # first token sampled (prefill logits)
    t_done: float = 0.0

    def __post_init__(self):
        if not self.seq:
            self.seq = list(self.prompt)

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new_tokens


class PagedServeEngine:
    """Paged KV-cache + continuous-batching decode (DESIGN.md §9, §14).

    ``max_batch`` decode lanes over a block pool of ``num_blocks`` blocks
    of ``block_size`` tokens (block 0 is the sink).  Two admission modes:

    * ``admission="reserve"`` (default): a request is admitted only when
      its worst-case block need (prompt + generation budget) fits
      alongside every other admitted request's — deadlock-free by
      construction, but conservative: short actual generations strand
      reserved blocks.
    * ``admission="optimistic"``: only the *prompt* has to fit at
      admission; decode-time growth is backstopped by preemption — when
      the pool runs dry a victim policy (``lowest_priority`` /
      ``most_blocks`` / ``lifo``) evicts a strictly-lower-precedence
      lane, swapping its KV blocks + SSM state to the host ``SwapPool``
      (``swap_blocks`` capacity; bit-exact restore) or dropping them for
      recompute when the pool is full.  The highest-precedence live
      request is never a victim, which is the progress guarantee: it can
      always grow (evicting everyone else if needed), so it finishes,
      frees its blocks, and precedence passes on — no deadlock.

    Long prompts prefill at most ``prefill_chunks_per_step`` chunks of
    ``prefill_chunk`` tokens per engine step, interleaved with decode
    steps for the already-running lanes.
    """

    def __init__(self, cfg: ArchConfig, params, *, block_size: int = 16,
                 max_batch: int = 8, max_len: int = 512,
                 prefill_chunk: int = 64, num_blocks: int | None = None,
                 prefill_chunks_per_step: int = 1, kv_dtype=None,
                 top_k: int | None = None, top_p: float | None = None,
                 admission: str = "reserve", swap_blocks: int = 0,
                 victim_policy: str = "lowest_priority",
                 max_queue: int | None = None,
                 shed_policy: str = "reject_newest", chaos=None):
        if cfg.encoder_layers or cfg.frontend_tokens:
            raise ValueError("paged serving supports decoder-only text "
                             "archs (no enc-dec / multimodal prefixes)")
        if admission not in ("reserve", "optimistic"):
            raise ValueError(f"unknown admission mode {admission!r}")
        if victim_policy not in ("lowest_priority", "most_blocks", "lifo"):
            raise ValueError(f"unknown victim policy {victim_policy!r}")
        if shed_policy not in ("reject_newest", "evict_lowest"):
            raise ValueError(f"unknown shed policy {shed_policy!r}")
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.block_size = block_size
        self.max_batch = max_batch
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        self.prefill_chunks_per_step = prefill_chunks_per_step
        # "int8"/"fp8_e4m3"/"fp8_e5m2" quantize the KV pools with per-row
        # scale tensors riding alongside (DESIGN.md §13); None = native
        self.kv_dtype = None if kv_dtype == "native" else kv_dtype
        self.top_k = top_k
        self.top_p = top_p
        self.admission = admission
        self.victim_policy = victim_policy
        self.max_queue = max_queue
        self.shed_policy = shed_policy
        self.chaos = chaos
        self.max_pages = -(-max_len // block_size)
        if num_blocks is None:
            num_blocks = max_batch * self.max_pages + 1   # +1: sink
        self.alloc = BlockAllocator(num_blocks, block_size, chaos=chaos)
        self.tables = BlockTables(self.alloc, max_batch, self.max_pages)
        self.swap = SwapPool(swap_blocks)
        self.cache = self.model.make_paged_cache(num_blocks, block_size,
                                                 max_batch,
                                                 kv_dtype=self.kv_dtype)
        self._decode = jax.jit(self.model.decode_paged, donate_argnums=(1,))
        self._chunk = jax.jit(self.model.prefill_chunk_paged,
                              donate_argnums=(1,))
        self.pos = np.zeros(max_batch, np.int64)   # tokens in cache per lane
        self.slots: list[Request | None] = [None] * max_batch
        self.pending: list[Request] = []
        self.preempted: list[Request] = []         # waiting to restore
        self.completed: dict[int, list[int]] = {}  # rid -> emitted tokens
        self.results: dict[int, RequestResult] = {}  # rid -> terminal record
        self._last_logits: dict[int, jax.Array] = {}   # slot -> (V,) logits
        self._reserved_blocks = 0
        self._next_rid = 0
        self._admit_counter = 0
        self._avg_service_s = 0.0      # EMA of admit->done (retry hints)
        self._counts = {"preempted": 0, "restored": 0, "shed": 0,
                        "timeout": 0, "cancelled": 0, "error": 0,
                        "decode_faults": 0}
        # run() reports counts/goodput since the PREVIOUS run's end, so
        # lifecycle events between runs (add_request sheds, cancels)
        # attribute to the next run's ServeStats
        self._counts_mark = dict(self._counts)
        self._results_mark: set[int] = set()
        self._key = jax.random.PRNGKey(0)
        self.temperature = 0.0
        # obs (DESIGN.md §11): lifecycle spans land on per-request tracks
        # ("req<rid>"), engine steps on "serve"; TTFT/TPOT/queue-wait
        # histograms live in the process metrics registry.  _observe is
        # dropped during warmup so the throwaway request pollutes nothing.
        self._observe = True

    # -- obs helpers --------------------------------------------------------
    @staticmethod
    def _hist(name: str):
        return obs.get_metrics().histogram(name)

    def _count(self, key: str):
        self._counts[key] += 1
        if self._observe:
            obs.get_metrics().counter(f"serve.{key}").inc()

    def _req_track(self, req: Request) -> str:
        return f"req{req.rid}"

    # -- request lifecycle --------------------------------------------------
    @staticmethod
    def _precedence(req: Request):
        """Scheduling order: higher priority first, then FIFO.  Strict
        total order — the basis of the no-deadlock argument (a lane may
        only preempt strictly-lower-precedence lanes)."""
        return (-req.priority, req.rid)

    def add_request(self, prompt: list[int], max_new_tokens: int, *,
                    priority: int = 0,
                    deadline_ms: float | None = None) -> Ticket:
        """Enqueue a request.  NEVER raises on overload or an unservable
        request — the returned ``Ticket`` carries a typed rejection
        (``QUEUE_FULL`` with a retry-after hint, ``PROMPT_TOO_LONG``)
        and the request is recorded as terminal ``SHED``.  ``PagingError``
        stays reserved for true allocator invariant violations."""
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, list(prompt), max_new_tokens, priority=priority,
                      t_enq=time.perf_counter())
        if deadline_ms is not None:
            req.deadline = req.t_enq + deadline_ms / 1e3
        need = self.tables.pages_for(len(prompt) + max_new_tokens)
        if (len(prompt) + max_new_tokens > self.max_len
                or need > self.max_pages
                or need > self.alloc.num_blocks - 1):
            return self._reject(
                req, REJECT_PROMPT_TOO_LONG,
                f"prompt({len(prompt)}) + new({max_new_tokens}) needs "
                f"{need} blocks; limits: max_len={self.max_len}, "
                f"pool={self.alloc.num_blocks - 1} blocks of "
                f"{self.block_size}")
        if self.max_queue is not None and len(self.pending) >= self.max_queue:
            if self.shed_policy == "evict_lowest":
                victim = max(self.pending, key=self._precedence)
                if self._precedence(victim) > self._precedence(req):
                    self.pending.remove(victim)
                    self._record_terminal(victim, Status.SHED,
                                          REJECT_EVICTED)
                    self._count("shed")
                else:
                    return self._reject(req, REJECT_QUEUE_FULL,
                                        f"queue at max_queue="
                                        f"{self.max_queue} and no lower-"
                                        f"priority request to evict")
            else:
                return self._reject(req, REJECT_QUEUE_FULL,
                                    f"queue at max_queue={self.max_queue}")
        self.pending.append(req)
        if self._observe:
            obs.get_recorder().instant(
                "enqueued", cat="serve", track=self._req_track(req),
                prompt_len=len(prompt), budget=max_new_tokens,
                priority=priority)
        return Ticket(rid, True)

    def _reject(self, req: Request, code: str, detail: str) -> Ticket:
        self._record_terminal(req, Status.SHED, code)
        self._count("shed")
        hint = self._retry_after_hint() if code == REJECT_QUEUE_FULL else None
        return Ticket(req.rid, False, reason=code, detail=detail,
                      retry_after_s=hint)

    def _retry_after_hint(self) -> float:
        """Rough queue-drain estimate: recent per-request service time x
        queue depth / lanes — a backoff hint, not a promise."""
        per = self._avg_service_s or 0.05
        return max(0.01, per * (len(self.pending) + 1) / self.max_batch)

    def cancel(self, rid: int) -> bool:
        """Cancel a request wherever it is (queued, running, preempted);
        blocks / slot / SSM state / swap entry are freed immediately.
        Returns False if the rid is unknown or already terminal."""
        for req in self.pending:
            if req.rid == rid:
                self.pending.remove(req)
                self._record_terminal(req, Status.CANCELLED, "in queue")
                self._count("cancelled")
                return True
        for slot, r in enumerate(self.slots):
            if r is not None and r.rid == rid:
                self._finish_slot(slot, Status.CANCELLED, "while running")
                return True
        for req in self.preempted:
            if req.rid == rid:
                self.preempted.remove(req)
                if rid in self.swap:
                    self.swap.pop(rid)
                self._record_terminal(req, Status.CANCELLED,
                                      "while preempted")
                self._count("cancelled")
                return True
        return False

    def _record_terminal(self, req: Request, status: Status, reason: str):
        """Every request's endpoint: one typed RequestResult, exactly
        once.  Resource release is the caller's job (it differs by where
        the request was: slot, queue, or swap pool)."""
        if req.t_done == 0.0:
            req.t_done = time.perf_counter()
        miss = None
        if req.deadline is not None and req.t_done > req.deadline:
            miss = req.t_done - req.deadline
            if self._observe:
                self._hist("serve.deadline_miss_s").observe(miss)
        self.results[req.rid] = RequestResult(
            req.rid, status, list(req.out), reason, req.n_preempted, miss)
        self.completed[req.rid] = list(req.out)
        if self._observe and status is not Status.OK:
            obs.get_recorder().instant(status.value.lower(), cat="serve",
                                       track=self._req_track(req),
                                       reason=reason)

    def _worst_case_pages(self, req: Request) -> int:
        return self.tables.pages_for(len(req.prompt) + req.max_new_tokens)

    def _expire(self):
        """Deadline sweep over every live home a request can be in."""
        now = time.perf_counter()
        for req in [r for r in self.pending
                    if r.deadline is not None and now > r.deadline]:
            self.pending.remove(req)
            req.t_done = now
            self._record_terminal(req, Status.TIMEOUT, "in queue")
            self._count("timeout")
        for slot, r in enumerate(self.slots):
            if r is not None and r.deadline is not None and now > r.deadline:
                self._finish_slot(slot, Status.TIMEOUT, "while running")
        for req in [r for r in self.preempted
                    if r.deadline is not None and now > r.deadline]:
            self.preempted.remove(req)
            if req.rid in self.swap:
                self.swap.pop(req.rid)
            req.t_done = now
            self._record_terminal(req, Status.TIMEOUT, "while preempted")
            self._count("timeout")

    def _admit(self):
        self.pending.sort(key=self._precedence)
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.pending:
                continue
            req = self.pending[0]
            if self.admission == "reserve":
                need = self._worst_case_pages(req)
                if self._reserved_blocks + need > self.alloc.num_blocks - 1:
                    break               # head-of-line: keep precedence order
                req.reserved_pages = need
                self._reserved_blocks += need
            else:
                # optimistic: the PROMPT has to fit now; the generation
                # budget rides the preemption backstop (DESIGN.md §14)
                if self.tables.pages_for(len(req.seq)) > self.alloc.num_free:
                    break
            self.pending.pop(0)
            self._place(req, slot)

    def _place(self, req: Request, slot: int):
        self.slots[slot] = req
        self.pos[slot] = 0
        req.prefilled = 0
        req.t_admit = time.perf_counter()
        if req.admit_seq < 0:
            req.admit_seq = self._admit_counter
            self._admit_counter += 1
        if self._observe:
            rec = obs.get_recorder()
            rec.complete("queued", rec.to_us(req.t_enq),
                         rec.to_us(req.t_admit), cat="serve",
                         track=self._req_track(req), slot=slot)
            self._hist("serve.queue_wait_s").observe(
                req.t_admit - req.t_enq)

    # -- preemption + swap (DESIGN.md §14) ----------------------------------
    def _pick_victim(self, cands: list[int]) -> int:
        if self.victim_policy == "most_blocks":
            key = lambda s: (-self.tables.n_pages(s),        # noqa: E731
                             -self.slots[s].admit_seq)
        elif self.victim_policy == "lifo":
            key = lambda s: -self.slots[s].admit_seq         # noqa: E731
        else:  # lowest_priority (FIFO-late tie break)
            key = lambda s: (self.slots[s].priority,         # noqa: E731
                             -self.slots[s].admit_seq)
        return min(cands, key=key)

    def preempt(self, rid: int) -> bool:
        """Evict a *running* request's lane (public primitive — the
        disaggregated-fleet router migrates lanes with this).  The
        request stays live: it re-enters via the preempted queue."""
        for slot, r in enumerate(self.slots):
            if r is not None and r.rid == rid:
                self._preempt_slot(slot)
                return True
        return False

    def _preempt_slot(self, slot: int):
        req = self.slots[slot]
        n = self.tables.n_pages(slot)
        use_swap = n > 0 and self.swap.can_hold(n)
        if use_swap:
            block_ids = [int(b) for b in self.tables.row(slot)[:n]]
            payload = self.model.paged_swap_out(self.cache, slot, block_ids)
            crcs = checksum_arrays(payload)     # pre-corruption truth
            if self.chaos is not None:
                self.chaos.on_swap_out(req.rid, payload)
            ll = self._last_logits.pop(slot, None)
            self.swap.put(SwapEntry(
                req.rid, n, payload, crcs, int(self.pos[slot]),
                req.prefilled,
                None if ll is None else np.asarray(ll)))
        else:
            # recompute-preemption: drop the blocks; restore re-prefills
            # prompt + emitted tokens (the last one re-enters as the next
            # decode input, so no first-token re-sample)
            self._last_logits.pop(slot, None)
            if req.out:
                req.seq = list(req.prompt) + req.out[:-1]
                req.emit_first = False
            req.prefilled = 0
        if req.reserved_pages:
            self._reserved_blocks -= req.reserved_pages
            req.reserved_pages = 0
        self.tables.release(slot)
        self.slots[slot] = None
        self.pos[slot] = 0
        req.n_preempted += 1
        self.preempted.append(req)
        self._count("preempted")
        if self._observe:
            obs.get_recorder().instant(
                "preempted", cat="serve", track=self._req_track(req),
                mode="swap" if use_swap else "recompute", blocks=n)
            obs.get_metrics().gauge("serve.swap_blocks_in_use").set(
                self.swap.in_use)

    def _free_by_preemption(self, requester_slot: int,
                            need_blocks: int) -> bool:
        """Preempt strictly-lower-precedence lanes (victim policy order)
        until ``need_blocks`` are free.  The precedence order is total,
        so the highest-precedence live request always finds victims or
        already owns the pool — the no-deadlock invariant."""
        req = self.slots[requester_slot]
        while self.alloc.num_free < need_blocks:
            cands = [s for s, r in enumerate(self.slots)
                     if r is not None and s != requester_slot
                     and self._precedence(r) > self._precedence(req)]
            if not cands:
                return False
            self._preempt_slot(self._pick_victim(cands))
        return True

    def _ensure_blocks(self, slot: int, length: int) -> bool:
        """Grow ``slot``'s table to cover ``length`` tokens; on a dry
        pool, preempt victims (optimistic mode's backstop).  False means
        the lane cannot run this step — it was preempted (waiting) or
        failed typed (chaos alloc fault -> terminal ERROR)."""
        want = self.tables.pages_for(length)
        need = want - self.tables.n_pages(slot)
        if need > 0 and self.alloc.num_free < need \
                and not self._free_by_preemption(slot, need):
            # no lower-precedence victim: the lane itself yields (its
            # progress is preserved by swap/recompute) and waits for
            # blocks to free up
            self._preempt_slot(slot)
            return False
        try:
            self.tables.ensure(slot, length)
            return True
        except ChaosError as e:         # injected device fault: isolate
            self._finish_slot(slot, Status.ERROR, f"alloc fault: {e}")
            return False
        except PagingError as e:        # invariant, not overload
            self._finish_slot(slot, Status.ERROR, f"alloc failed: {e}")
            return False

    def _restore_preempted(self):
        """Resume preempted requests (precedence order) into free slots.
        Swap restores need their block count + 1 free (the headroom
        keeps a restored lane from instantly re-preempting); recompute
        restores need their rebuilt prompt to fit, like admission."""
        if not self.preempted:
            return
        self.preempted.sort(key=self._precedence)
        for req in list(self.preempted):
            slot = next((s for s in range(self.max_batch)
                         if self.slots[s] is None), None)
            if slot is None:
                break
            if req.rid in self.swap:
                n = self.swap.blocks_of(req.rid)
                if self.alloc.num_free < n + 1:
                    continue
                entry = self.swap.pop(req.rid)
                self.preempted.remove(req)
                if not entry.verify():
                    self._record_terminal(
                        req, Status.ERROR,
                        "swap payload corrupt (crc mismatch)")
                    self._count("error")
                    continue
                try:
                    blocks = self.alloc.alloc(n)
                except ChaosError as e:
                    self._record_terminal(req, Status.ERROR,
                                          f"restore alloc fault: {e}")
                    self._count("error")
                    continue
                self.tables.adopt(slot, blocks)
                self.cache = self.model.paged_swap_in(self.cache, slot,
                                                      blocks, entry.arrays)
                self.slots[slot] = req
                self.pos[slot] = entry.pos
                req.prefilled = entry.prefilled
                if entry.last_logits is not None:
                    self._last_logits[slot] = jnp.asarray(entry.last_logits)
                mode = "swap"
            else:
                need = self.tables.pages_for(len(req.seq))
                if self.alloc.num_free < need + 1:
                    continue
                self.preempted.remove(req)
                self.slots[slot] = req
                self.pos[slot] = 0
                req.prefilled = 0
                mode = "recompute"
            if self.admission == "reserve":
                req.reserved_pages = self._worst_case_pages(req)
                self._reserved_blocks += req.reserved_pages
            self._count("restored")
            if self._observe:
                obs.get_recorder().instant(
                    "restored", cat="serve", track=self._req_track(req),
                    mode=mode, slot=slot)
                obs.get_metrics().gauge("serve.swap_blocks_in_use").set(
                    self.swap.in_use)

    def _first_token(self, req: Request):
        """Stamp + record the first-token milestone (TTFT)."""
        req.t_first = time.perf_counter()
        if self._observe:
            obs.get_recorder().instant("first_token", cat="serve",
                                       track=self._req_track(req))
            self._hist("serve.ttft_s").observe(req.t_first - req.t_enq)

    def _finish_slot(self, slot: int, status: Status = Status.OK,
                     reason: str = ""):
        req = self.slots[slot]
        req.t_done = time.perf_counter()
        if self._observe:
            rec = obs.get_recorder()
            t0 = req.t_first or req.t_admit or req.t_enq
            rec.complete("decode", rec.to_us(t0), rec.to_us(req.t_done),
                         cat="serve", track=self._req_track(req),
                         tokens=len(req.out))
            rec.instant("evicted", cat="serve", track=self._req_track(req))
            if status is Status.OK and req.t_first and len(req.out) > 1:
                self._hist("serve.tpot_s").observe(
                    (req.t_done - req.t_first) / (len(req.out) - 1))
        if status is Status.OK and req.t_admit:
            dt = req.t_done - req.t_admit
            self._avg_service_s = (dt if not self._avg_service_s
                                   else 0.8 * self._avg_service_s + 0.2 * dt)
        if req.reserved_pages:
            self._reserved_blocks -= req.reserved_pages
            req.reserved_pages = 0
        self.tables.release(slot)
        self.slots[slot] = None
        self.pos[slot] = 0
        self._last_logits.pop(slot, None)
        self._record_terminal(req, status, reason)
        if status is not Status.OK:
            self._count(status.value.lower())

    # -- device steps -------------------------------------------------------
    def _prefill_one_chunk(self, slot: int, stats: ServeStats):
        req = self.slots[slot]
        C = self.prefill_chunk
        start = req.prefilled
        chunk = req.seq[start:start + C]
        n = len(chunk)
        toks = np.zeros((1, C), np.int32)
        toks[0, :n] = chunk
        batch = {"tokens": jnp.asarray(toks),
                 "block_tables": jnp.asarray(self.tables.row(slot)[None]),
                 "start": jnp.asarray(start, jnp.int32),
                 "length": jnp.asarray(n, jnp.int32),
                 "slot": jnp.asarray(slot, jnp.int32)}
        rec = obs.get_recorder()
        t0 = time.time()
        with rec.span("prefill_chunk", cat="serve",
                      track=self._req_track(req) if self._observe else "serve",
                      slot=slot, start=start, tokens=n):
            logits, self.cache = self._chunk(self.params, self.cache, batch)
            logits.block_until_ready()
        stats.prefill_s += time.time() - t0
        req.prefilled += n
        self.pos[slot] = req.prefilled
        if req.prefilled >= len(req.seq):
            self._last_logits[slot] = logits[0]   # sample at next decode

    def _sample(self, logits):
        """logits: (V,) or (B, V) -> sampled token id(s), same leading
        shape.  With ``top_k``/``top_p`` set the fused Pallas sampling
        kernel filters + draws in one pass (DESIGN.md §13); otherwise the
        plain categorical / argmax path."""
        if self.temperature > 0 and (self.top_k is not None
                                     or self.top_p is not None):
            from repro.kernels.ops import sample_tokens
            rows = jnp.atleast_2d(logits)
            self._key, sub = jax.random.split(self._key)
            u = jax.random.uniform(sub, (rows.shape[0],))
            toks = sample_tokens(rows, u, temperature=self.temperature,
                                 top_k=self.top_k, top_p=self.top_p)
            return toks if logits.ndim > 1 else toks[0]
        if self.temperature > 0:
            self._key, sub = jax.random.split(self._key)
            return jax.random.categorical(sub, logits / self.temperature, -1)
        return jnp.argmax(logits, -1)

    def _check_poison(self, slot: int) -> bool:
        """True if the lane survived the chaos poison check; a poisoned
        request is isolated to a terminal ERROR with resources
        reclaimed — other lanes never see the fault."""
        if self.chaos is None:
            return True
        try:
            self.chaos.check_request(self.slots[slot].rid)
            return True
        except ChaosError as e:
            self._finish_slot(slot, Status.ERROR, str(e))
            return False

    def step(self, stats: ServeStats | None = None) -> int:
        """One engine step: expire deadlines, restore preempted lanes,
        admit, advance prefills, decode every running lane, retire
        finished requests.  Returns tokens emitted."""
        stats = stats if stats is not None else ServeStats()
        if self.chaos is not None:
            self.chaos.on_admission()
        self._expire()
        self._restore_preempted()
        self._admit()

        budget = self.prefill_chunks_per_step
        for slot in range(self.max_batch):
            if budget <= 0:
                break
            req = self.slots[slot]
            if req is None or req.prefilled >= len(req.seq):
                continue
            if not self._check_poison(slot):
                continue
            target = min(req.prefilled + self.prefill_chunk, len(req.seq))
            if not self._ensure_blocks(slot, target):
                continue
            self._prefill_one_chunk(slot, stats)
            budget -= 1

        # sample the first token for lanes whose prefill just completed
        # (restored recompute lanes skip it — their next token is already
        # in req.out, re-entering as the decode input below)
        for slot, logits in list(self._last_logits.items()):
            req = self.slots[slot]
            if req.emit_first:
                req.out.append(int(np.asarray(self._sample(logits))))
                self._first_token(req)
            else:
                req.emit_first = True      # one skip per recompute restore
            del self._last_logits[slot]
            if req.done:                      # degenerate 1-token budget
                self._finish_slot(slot)

        lanes = []
        for b, r in enumerate(self.slots):
            if r is None or r.prefilled < len(r.seq) or r.done:
                continue
            if not self._check_poison(b):
                continue
            # the incoming token is written at position pos[b]
            if not self._ensure_blocks(b, int(self.pos[b]) + 1):
                continue
            lanes.append(b)
        # a later lane's _ensure_blocks may have preempted an earlier
        # collected lane — drop lanes whose slot was emptied
        lanes = [b for b in lanes if self.slots[b] is not None]
        if not lanes:
            return 0

        toks = np.zeros((self.max_batch, 1), np.int32)
        tables = np.zeros_like(self.tables.tables)
        pos = np.zeros(self.max_batch, np.int32)
        active = np.zeros(self.max_batch, bool)
        for b in lanes:
            req = self.slots[b]
            toks[b, 0] = req.out[-1]
            tables[b] = self.tables.row(b)
            pos[b] = self.pos[b]
            active[b] = True
        batch = {"tokens": jnp.asarray(toks),
                 "block_tables": jnp.asarray(tables),
                 "pos": jnp.asarray(pos),
                 "active": jnp.asarray(active)}
        if self.chaos is not None:
            try:
                self.chaos.on_decode_step()
            except ChaosError:
                # transient device fault BEFORE dispatch: nothing was
                # mutated, so the identical step re-runs next iteration
                self._count("decode_faults")
                return 0
        rec = obs.get_recorder()
        if self._observe:
            rec.counter("blocks_in_use", self.alloc.in_use, track="serve",
                        cat="serve")
            obs.get_metrics().gauge("serve.blocks_in_use").set(
                self.alloc.in_use)
        t0 = time.time()
        with rec.span("decode_step", cat="serve", track="serve",
                      lanes=len(lanes)):
            logits, self.cache = self._decode(self.params, self.cache, batch)
            nxt = np.asarray(self._sample(logits))
        stats.decode_s += time.time() - t0
        stats.steps += 1

        for b in lanes:
            req = self.slots[b]
            req.out.append(int(nxt[b]))
            self.pos[b] += 1
            stats.tokens_out += 1
            if req.done:
                self._finish_slot(b)
        return len(lanes)

    @property
    def busy(self) -> bool:
        return (bool(self.pending) or bool(self.preempted)
                or any(r is not None for r in self.slots))

    def run(self, stats: ServeStats | None = None,
            max_steps: int = 1_000_000) -> ServeStats:
        stats = stats if stats is not None else ServeStats()
        # report THIS run's high-water mark (in-flight blocks still count)
        self.alloc.peak_in_use = self.alloc.in_use
        # latency percentiles + lifecycle counts are computed over THIS
        # run's observations (registry/engine accumulate across runs)
        h_ttft = self._hist("serve.ttft_s")
        h_tpot = self._hist("serve.tpot_s")
        h_wait = self._hist("serve.queue_wait_s")
        marks = {id(h): len(h.values) for h in (h_ttft, h_tpot, h_wait)}
        counts0 = self._counts_mark
        done0 = self._results_mark
        steps = 0
        while self.busy:
            self.step(stats)
            steps += 1
            if steps > max_steps:
                stuck = ([r.rid for r in self.pending]
                         + [r.rid for r in self.slots if r is not None]
                         + [r.rid for r in self.preempted])
                raise ServeError(
                    f"engine did not drain the request queue in "
                    f"{max_steps} steps", stuck_rids=stuck,
                    blocks_in_use=self.alloc.in_use,
                    num_free=self.alloc.num_free)
        stats.peak_cache_blocks = self.alloc.peak_in_use
        from repro.core.memplan import kv_cache_bytes_paged
        stats.peak_cache_bytes = (self.alloc.peak_in_use
                                  * kv_cache_bytes_paged(
                                      self.cfg, [], self.block_size,
                                      kv_dtype=self.kv_dtype)
                                  ["block_bytes"])
        for name in ("preempted", "restored", "shed", "cancelled"):
            setattr(stats, name, self._counts[name] - counts0[name])
        stats.timeouts = self._counts["timeout"] - counts0["timeout"]
        stats.errors = self._counts["error"] - counts0["error"]
        stats.swap_peak_blocks = self.swap.peak_in_use
        stats.goodput_tokens = sum(
            max(0, len(res.tokens) - 1) for rid, res in self.results.items()
            if rid not in done0 and res.status is Status.OK)
        self._counts_mark = dict(self._counts)
        self._results_mark = set(self.results)

        def pcts(h):
            vs = h.values[marks[id(h)]:]
            return h.quantile(0.50, vs), h.quantile(0.99, vs)

        stats.ttft_p50, stats.ttft_p99 = pcts(h_ttft)
        stats.tpot_p50, stats.tpot_p99 = pcts(h_tpot)
        stats.queue_wait_p50, stats.queue_wait_p99 = pcts(h_wait)
        return stats

    def reset(self):
        """Drop all requests (unfinished ones are recorded CANCELLED) and
        recycle every block (cache contents stay — they are garbage by
        definition once unreferenced)."""
        for slot, r in enumerate(self.slots):
            if r is not None:
                self._finish_slot(slot)
        for req in self.pending:
            self._record_terminal(req, Status.CANCELLED, "engine reset")
        self.pending.clear()
        for req in self.preempted:
            if req.rid in self.swap:
                self.swap.pop(req.rid)
            self._record_terminal(req, Status.CANCELLED, "engine reset")
        self.preempted.clear()
        self.alloc = BlockAllocator(self.alloc.num_blocks, self.block_size,
                                    chaos=self.chaos)
        self.tables = BlockTables(self.alloc, self.max_batch, self.max_pages)
        self.swap = SwapPool(self.swap.capacity_blocks)
        self.pos[:] = 0
        self._reserved_blocks = 0

    def warmup(self) -> float:
        """Compile the chunk-prefill and decode steps (one throwaway
        request); returns the wall time (reported as ``compile_s``)."""
        t0 = time.time()
        saved_pending = self.pending
        self.pending = []
        saved_queue, self.max_queue = self.max_queue, None
        saved_chaos, self.chaos, self.alloc.chaos = self.chaos, None, None
        self._observe = False       # the throwaway request is not traffic
        try:
            # sized to fit even a tiny pool (one block of headroom)
            cap = (self.alloc.num_blocks - 2) * self.block_size
            n = max(1, min(self.prefill_chunk + 1, self.max_len - 2, cap))
            t = self.add_request([1] * n, 2)
            self.run()
            self.reset()
            # the throwaway is not traffic: scrub its terminal record so
            # callers tallying ``results`` only ever see real requests
            self.results.pop(t.rid, None)
            self._results_mark.discard(t.rid)
        finally:
            self._observe = True
            self.pending = saved_pending
            self.max_queue = saved_queue
            self.chaos = saved_chaos
            self.alloc.chaos = saved_chaos
        return time.time() - t0

    def generate(self, prompts: list[list[int]],
                 max_new_tokens: int | list[int] = 32,
                 temperature: float = 0.0, seed: int = 0,
                 top_k: int | None = None, top_p: float | None = None,
                 warmup: bool = True, priorities: list[int] | None = None,
                 deadlines_ms: list[float | None] | None = None):
        """Batch convenience API: enqueue everything, run to drain.

        Returns (list of per-request token lists, ServeStats) — requests
        may have different ``max_new_tokens`` (continuous batching's whole
        point), so the output is ragged.  A request that did not end
        ``OK`` (shed, timed out, errored) contributes the tokens it got
        to; consult ``engine.results[rid]`` for its typed status.
        """
        stats = ServeStats()
        if warmup:
            self.temperature = 0.0      # throwaway request decodes greedily
            stats.compile_s = self.warmup()
        # seed AFTER warmup so sampled streams are reproducible across
        # warmup settings
        self.temperature = temperature
        if top_k is not None:
            self.top_k = top_k
        if top_p is not None:
            self.top_p = top_p
        self._key = jax.random.PRNGKey(seed)
        budgets = (max_new_tokens if isinstance(max_new_tokens, (list, tuple))
                   else [max_new_tokens] * len(prompts))
        priorities = priorities or [0] * len(prompts)
        deadlines_ms = deadlines_ms or [None] * len(prompts)
        tickets = [self.add_request(p, n, priority=pr, deadline_ms=dl)
                   for p, n, pr, dl in zip(prompts, budgets, priorities,
                                           deadlines_ms)]
        self.run(stats)
        return [self.results[t.rid].tokens for t in tickets], stats
