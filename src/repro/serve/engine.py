"""Batched serving engine: prefill + decode with KV/SSM caches.

Requests are batched; prefill builds the cache (padded to max_len for
decode headroom), then greedy/temperature decode steps run jointly for
the whole batch.  Both phases are single jitted calls (lowered with the
same shardings as the dry-run's prefill/serve steps).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ArchConfig, get_model


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_out: int = 0

    @property
    def tok_per_s(self):
        return self.tokens_out / self.decode_s if self.decode_s else 0.0


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, max_len: int = 512):
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, pad_to=max_len))
        self._decode = jax.jit(self.model.decode)

    def pad_batch(self, prompts: list[list[int]]):
        """Left-align prompts to a common length (pad with 0)."""
        L = max(len(p) for p in prompts)
        toks = np.zeros((len(prompts), L), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
        return jnp.asarray(toks)

    def generate(self, prompts: list[list[int]], max_new_tokens: int = 32,
                 temperature: float = 0.0, seed: int = 0,
                 extra_inputs: dict | None = None):
        """Returns (tokens (B, max_new_tokens), ServeStats)."""
        toks = self.pad_batch(prompts)
        batch = {"tokens": toks, **(extra_inputs or {})}
        t0 = time.time()
        logits, cache = self._prefill(self.params, batch)
        logits.block_until_ready()
        stats = ServeStats(prefill_s=time.time() - t0)

        key = jax.random.PRNGKey(seed)
        out = []
        t0 = time.time()
        for i in range(max_new_tokens):
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits / temperature, -1)
            else:
                nxt = jnp.argmax(logits, -1)
            out.append(nxt)
            logits, cache = self._decode(self.params, cache,
                                         {"tokens": nxt[:, None].astype(jnp.int32)})
        jax.block_until_ready(logits)
        stats.decode_s = time.time() - t0
        stats.tokens_out = len(prompts) * max_new_tokens
        return np.stack([np.asarray(t) for t in out], axis=1), stats
