from .chaos import ChaosError, ChaosHooks
from .engine import (PagedServeEngine, Request, RequestResult, ServeEngine,
                     ServeError, ServeStats, Status, Ticket)
from .paging import (BlockAllocator, BlockTables, PagingError, SINK_BLOCK,
                     SwapEntry, SwapPool)

__all__ = ["ServeEngine", "PagedServeEngine", "Request", "ServeStats",
           "Status", "Ticket", "RequestResult", "ServeError",
           "BlockAllocator", "BlockTables", "PagingError", "SINK_BLOCK",
           "SwapEntry", "SwapPool", "ChaosHooks", "ChaosError"]
