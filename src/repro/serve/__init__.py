from .engine import PagedServeEngine, Request, ServeEngine, ServeStats
from .paging import BlockAllocator, BlockTables, PagingError, SINK_BLOCK

__all__ = ["ServeEngine", "PagedServeEngine", "Request", "ServeStats",
           "BlockAllocator", "BlockTables", "PagingError", "SINK_BLOCK"]
