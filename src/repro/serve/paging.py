"""Paged KV-cache bookkeeping: block allocator + per-sequence block tables.

The device-side cache is a pool of fixed-size blocks per attention layer
(``make_paged_cache`` in ``models/transformer.py``); this module owns the
*host-side* metadata — which physical block backs which logical page of
which sequence — exactly the split the MXNet §3.1 memory planner makes
between the static byte plan and the runtime buffers.

Conventions:

* physical block 0 is the **sink**: it backs every table entry that maps
  no real page (empty slots, pages past a sequence's length) so device
  writes from inactive decode lanes land somewhere harmless.  Block 0 is
  never handed out by the allocator and its contents are garbage by
  design (always masked out of attention by the per-sequence length).
* block tables are dense ``(max_batch, max_pages)`` int32 arrays, sink-
  filled; logical page ``p`` of slot ``b`` covers absolute positions
  ``[p*block_size, (p+1)*block_size)``.
* the allocator tracks ``peak_in_use`` so benchmarks can report the true
  high-water cache footprint against the dense ``B x max_len`` padding.
* ``SwapPool`` is the host-side block reservoir preemption swaps into
  (DESIGN.md §14): a bounded capacity of block-equivalents, per-request
  entries carrying the copied KV rows + SSM slot state + a crc32 per
  array so a corrupted round-trip is *detected* at restore, never
  silently decoded from.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field

import numpy as np

SINK_BLOCK = 0


class PagingError(RuntimeError):
    """A paging *invariant* violation (double-free, sink free, impossible
    request).  Overload conditions are NOT this — the engine reports
    those as typed rejection/terminal results (DESIGN.md §14)."""


@dataclass
class BlockAllocator:
    """Free-list allocator over ``num_blocks`` fixed-size cache blocks.

    Block ids are ints in ``[1, num_blocks)``; id 0 is the reserved sink
    and is never allocated.  ``free`` of a block not currently in use
    (double-free, sink, out of range) raises ``PagingError`` — the
    allocator is the ground truth the engine's slot recycling is audited
    against (``tests/test_serve.py``).
    """

    num_blocks: int
    block_size: int
    _free: list[int] = field(default_factory=list)
    _in_use: set[int] = field(default_factory=set)
    peak_in_use: int = 0
    # fault-injection seam (serve/chaos.py): ``on_alloc`` may raise
    # ChaosError — a *device* fault, distinct from PagingError shortage
    chaos: object = None

    def __post_init__(self):
        if self.num_blocks < 2:
            raise PagingError("need >= 2 blocks (block 0 is the sink)")
        # LIFO free list: recently-freed blocks are re-used first (warm)
        self._free = list(range(self.num_blocks - 1, 0, -1))

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._in_use)

    def alloc(self, n: int = 1) -> list[int]:
        if self.chaos is not None:
            self.chaos.on_alloc(n)
        if n > len(self._free):
            raise PagingError(
                f"out of cache blocks: want {n}, have {len(self._free)} "
                f"free of {self.num_blocks - 1}")
        out = [self._free.pop() for _ in range(n)]
        self._in_use.update(out)
        self.peak_in_use = max(self.peak_in_use, len(self._in_use))
        return out

    def free(self, blocks: list[int]) -> None:
        for b in blocks:
            if b not in self._in_use:
                raise PagingError(
                    f"free of block {b} that is not in use "
                    f"(double-free or sink)")
            self._in_use.remove(b)
            self._free.append(b)


class BlockTables:
    """Per-slot logical-page -> physical-block maps over one allocator.

    ``ensure(slot, length)`` grows slot ``slot``'s table to cover
    ``length`` tokens (allocating blocks as needed); ``release(slot)``
    returns every block to the free list and sink-fills the row.  The
    ``tables`` array is passed to the device step functions as-is.
    """

    def __init__(self, alloc: BlockAllocator, max_batch: int,
                 max_pages: int):
        self.alloc = alloc
        self.max_pages = max_pages
        self.tables = np.full((max_batch, max_pages), SINK_BLOCK, np.int32)
        self._n_pages = np.zeros(max_batch, np.int32)

    def pages_for(self, length: int) -> int:
        return -(-int(length) // self.alloc.block_size)

    def ensure(self, slot: int, length: int) -> None:
        """Back positions ``[0, length)`` of ``slot`` with real blocks."""
        want = self.pages_for(length)
        if want > self.max_pages:
            raise PagingError(
                f"sequence needs {want} pages > max_pages={self.max_pages}")
        have = int(self._n_pages[slot])
        if want > have:
            for p, blk in zip(range(have, want), self.alloc.alloc(want - have)):
                self.tables[slot, p] = blk
            self._n_pages[slot] = want

    def release(self, slot: int) -> None:
        n = int(self._n_pages[slot])
        if n:
            self.alloc.free([int(b) for b in self.tables[slot, :n]])
        self.tables[slot, :] = SINK_BLOCK
        self._n_pages[slot] = 0

    def adopt(self, slot: int, blocks: list[int]) -> None:
        """Install already-allocated ``blocks`` as ``slot``'s table (the
        swap-restore path: the lane's pages come back under fresh
        physical ids).  The slot must be empty."""
        if int(self._n_pages[slot]):
            raise PagingError(f"adopt into non-empty slot {slot}")
        if len(blocks) > self.max_pages:
            raise PagingError(
                f"adopt of {len(blocks)} blocks > max_pages={self.max_pages}")
        for p, blk in enumerate(blocks):
            self.tables[slot, p] = blk
        self._n_pages[slot] = len(blocks)

    def row(self, slot: int) -> np.ndarray:
        return self.tables[slot]

    def n_pages(self, slot: int) -> int:
        return int(self._n_pages[slot])


# ---------------------------------------------------------------------------
# host-side swap pool (preemption target; DESIGN.md §14)


def checksum_arrays(arrays: dict) -> dict:
    """crc32 per payload array — computed at swap-out, verified at
    restore, so a corrupted host round-trip fails *typed* (terminal
    ``ERROR``) instead of silently resuming from garbage KV."""
    return {name: zlib.crc32(np.ascontiguousarray(a).view("uint8").tobytes())
            for name, a in arrays.items()}


@dataclass
class SwapEntry:
    """One preempted request's resumable state: the copied KV block rows
    (+ quant scales) and SSM slot state keyed by layer, the lane's decode
    position / prefill progress, and the pending first-token logits if
    prefill had finished but the token was not yet sampled."""
    rid: int
    n_blocks: int
    arrays: dict[str, np.ndarray]
    crcs: dict[str, int]
    pos: int
    prefilled: int
    last_logits: np.ndarray | None = None

    def verify(self) -> bool:
        return checksum_arrays(self.arrays) == self.crcs


class SwapPool:
    """Bounded host-side reservoir of swapped-out request state.

    Capacity is counted in *block-equivalents* (same unit as the device
    allocator), so ``core.memplan.swap_pool_bytes`` prices it with the
    identical per-block byte model.  ``put`` of an entry that does not
    fit raises ``PagingError`` — callers must check ``can_hold`` first
    (the engine falls back to recompute-preemption when the pool is
    full, so overload degrades instead of erroring).
    """

    def __init__(self, capacity_blocks: int):
        self.capacity_blocks = int(capacity_blocks)
        self._entries: dict[int, SwapEntry] = {}
        self.in_use = 0
        self.peak_in_use = 0
        self.total_swapped = 0          # lifetime swap-out count

    def can_hold(self, n_blocks: int) -> bool:
        return self.in_use + n_blocks <= self.capacity_blocks

    def blocks_of(self, rid: int) -> int:
        """Block count of ``rid``'s entry (restore feasibility check)."""
        return self._entries[rid].n_blocks

    def __contains__(self, rid: int) -> bool:
        return rid in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def put(self, entry: SwapEntry) -> None:
        if entry.rid in self._entries:
            raise PagingError(f"rid {entry.rid} already swapped out")
        if not self.can_hold(entry.n_blocks):
            raise PagingError(
                f"swap pool full: want {entry.n_blocks} blocks, "
                f"{self.capacity_blocks - self.in_use} free "
                f"of {self.capacity_blocks}")
        self._entries[entry.rid] = entry
        self.in_use += entry.n_blocks
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        self.total_swapped += 1

    def pop(self, rid: int) -> SwapEntry:
        if rid not in self._entries:
            raise PagingError(f"rid {rid} is not swapped out")
        entry = self._entries.pop(rid)
        self.in_use -= entry.n_blocks
        return entry
