"""Data iterators with multi-threaded prefetch (MXNet §2.4: "data
pre-fetching and pre-processing are multi-threaded").

``PrefetchIterator`` wraps any iterator with a bounded background queue so
decode/transform overlaps training compute — the CPU-thread analogue of
the engine's compute/IO overlap.  Worker threads shut down when the
consumer abandons the iterator early, and reader exceptions surface at
the consumer's ``next()`` instead of hanging the queue.

Multi-host sharding (DESIGN.md §15): every iterator here can run in
*per-host shard* mode — pass ``process_index``/``process_count`` and each
host derives the SAME global shuffled order from the shared seed, then
reads only its contiguous row-slice of every global batch
(:func:`global_batch_slice`).  Shards are disjoint, cover the epoch, and
concatenating the per-host batches in process order reproduces the
single-host stream exactly — which is what lets
``jax.make_array_from_process_local_data`` assemble the global batch on a
process-major ``(pod, data)`` mesh with no cross-host shuffle.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable

import numpy as np


def global_batch_slice(batch: int, process_index: int,
                       process_count: int) -> tuple[int, int]:
    """Row range ``[start, stop)`` of the global batch owned by one host.

    Contiguous per-host slices line up with process-major device order on
    a ``(pod, data)`` mesh, so local arrays drop into the global batch
    with zero resharding.

    >>> [global_batch_slice(8, p, 4) for p in range(4)]
    [(0, 2), (2, 4), (4, 6), (6, 8)]
    """
    if not 0 <= process_index < process_count:
        raise ValueError(f"process_index {process_index} out of range "
                         f"[0, {process_count})")
    if batch % process_count:
        raise ValueError(f"global batch {batch} not divisible by "
                         f"process_count {process_count}")
    local = batch // process_count
    return process_index * local, (process_index + 1) * local


class SyntheticLM:
    """Deterministic synthetic token stream (for examples / smoke runs).

    With ``process_count > 1`` every host generates the identical global
    batch from the shared seed and yields only its own row slice — the
    per-host shards concatenate back to the single-host stream bit-exact.
    """

    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0,
                 n_batches: int = 1 << 30, fixed_pattern: bool = False,
                 process_index: int = 0, process_count: int = 1):
        self.vocab, self.seq_len, self.batch = vocab, seq_len, batch
        self.seed = seed
        self.n_batches = n_batches
        # fixed_pattern: one GLOBAL stride shared by every sequence — a
        # bigram rule (t+1 = t + stride mod V) learnable within few steps,
        # for short demo runs where per-row random strides are data-starved
        self.fixed_pattern = fixed_pattern
        self._lo, self._hi = global_batch_slice(batch, process_index,
                                                process_count)

    def __iter__(self):
        rng = np.random.RandomState(self.seed)
        global_step = rng.randint(1, 4) if self.fixed_pattern else None
        for _ in range(self.n_batches):
            # learnable synthetic structure: tokens follow a noisy
            # mod-vocab autoregression so loss can actually decrease
            base = rng.randint(0, self.vocab, (self.batch, 1))
            steps = (global_step if self.fixed_pattern
                     else rng.randint(1, 4, (self.batch, 1)))
            pos = np.arange(self.seq_len)[None, :]
            toks = (base + steps * pos) % self.vocab
            noise = rng.rand(self.batch, self.seq_len) < 0.05
            toks = np.where(noise, rng.randint(0, self.vocab, toks.shape),
                            toks)
            yield {"tokens": toks[self._lo:self._hi].astype(np.int32)}


class DataIterator:
    """Batches decoded records from a RecordReader, with shuffling
    (random seek makes shuffling cheap) and a decode_fn per record.

    Multi-host: every host shuffles the full epoch with the shared seed
    (so the global order is common knowledge), then decodes only its
    :func:`global_batch_slice` rows of each global batch — host-local
    RecordIO reads, disjoint across hosts, covering the epoch.
    ``record_indices()`` exposes the assignment for auditing.
    """

    def __init__(self, reader, batch: int, decode_fn: Callable[[bytes], np.ndarray],
                 shuffle: bool = True, seed: int = 0, drop_last: bool = True,
                 process_index: int = 0, process_count: int = 1):
        self.reader, self.batch, self.decode_fn = reader, batch, decode_fn
        self.shuffle, self.seed, self.drop_last = shuffle, seed, drop_last
        self.process_index, self.process_count = process_index, process_count
        self._lo, self._hi = global_batch_slice(batch, process_index,
                                                process_count)
        if not drop_last and process_count > 1:
            raise ValueError("multi-host sharding requires drop_last=True "
                             "(a ragged tail cannot split evenly)")

    def _epoch_order(self) -> np.ndarray:
        order = np.arange(len(self.reader))
        if self.shuffle:
            np.random.RandomState(self.seed).shuffle(order)
        return order

    def record_indices(self) -> np.ndarray:
        """Record indices THIS host reads, in read order — per global
        batch, rows ``[lo, hi)`` of the shared shuffled order."""
        order = self._epoch_order()
        n_full = len(order) // self.batch
        picks = []
        for t in range(n_full):
            row = order[t * self.batch:(t + 1) * self.batch]
            picks.append(row[self._lo:self._hi])
        if picks:
            return np.concatenate(picks)
        return np.empty((0,), dtype=order.dtype)

    def __iter__(self):
        order = self._epoch_order()
        if self.process_count > 1:
            n_full = len(order) // self.batch
            for t in range(n_full):
                row = order[t * self.batch:(t + 1) * self.batch]
                buf = [self.decode_fn(self.reader.read(int(i)))
                       for i in row[self._lo:self._hi]]
                yield np.stack(buf)
            return
        buf = []
        for i in order:
            buf.append(self.decode_fn(self.reader.read(int(i))))
            if len(buf) == self.batch:
                yield np.stack(buf)
                buf = []
        if buf and not self.drop_last:
            yield np.stack(buf)


class _ReaderError:
    """Queue envelope for an exception raised inside a worker thread."""

    def __init__(self, exc: BaseException):
        self.exc = exc


class PrefetchIterator:
    """Background-thread prefetch with a bounded queue.

    Lifecycle guarantees (the §2.4 prefetcher grown up):

    * abandoning the consumer early (``break``, ``close()``, GC of the
      generator) stops the workers — ``put`` never blocks forever because
      every enqueue re-checks a stop flag on a timeout loop, and the
      ``finally`` block drains the queue and joins the threads;
    * an exception in the wrapped iterator propagates to the consumer's
      ``next()`` (re-raised from a ``_ReaderError`` envelope) instead of
      silently ending — or worse, hanging — the stream.
    """

    _SENTINEL = object()

    def __init__(self, it, depth: int = 4, num_threads: int = 1):
        self._it = it
        self.depth = depth
        self.num_threads = num_threads

    def __iter__(self):
        q: queue.Queue = queue.Queue(maxsize=self.depth)
        src = iter(self._it)
        lock = threading.Lock()
        stop = threading.Event()
        n_done = [0]

        def put(item) -> bool:
            # bounded put that gives up when the consumer is gone
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def worker():
            while not stop.is_set():
                try:
                    with lock:
                        item = next(src)
                except StopIteration:
                    break
                except BaseException as exc:  # propagate, don't hang
                    put(_ReaderError(exc))
                    break
                if not put(item):
                    return
            with lock:
                n_done[0] += 1
                if n_done[0] == self.num_threads:
                    put(self._SENTINEL)

        threads = [threading.Thread(target=worker, daemon=True)
                   for _ in range(self.num_threads)]
        for t in threads:
            t.start()
        try:
            while True:
                item = q.get()
                if item is self._SENTINEL:
                    break
                if isinstance(item, _ReaderError):
                    raise item.exc
                yield item
        finally:
            stop.set()
            # unblock any worker stuck on a full queue, then join
            while True:
                try:
                    q.get_nowait()
                except queue.Empty:
                    break
            for t in threads:
                t.join(timeout=2.0)
