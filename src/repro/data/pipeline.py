"""Data iterators with multi-threaded prefetch (MXNet §2.4: "data
pre-fetching and pre-processing are multi-threaded").

``PrefetchIterator`` wraps any iterator with a bounded background queue so
decode/transform overlaps training compute — the CPU-thread analogue of
the engine's compute/IO overlap.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable

import numpy as np


class SyntheticLM:
    """Deterministic synthetic token stream (for examples / smoke runs)."""

    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0,
                 n_batches: int = 1 << 30, fixed_pattern: bool = False):
        self.vocab, self.seq_len, self.batch = vocab, seq_len, batch
        self.seed = seed
        self.n_batches = n_batches
        # fixed_pattern: one GLOBAL stride shared by every sequence — a
        # bigram rule (t+1 = t + stride mod V) learnable within few steps,
        # for short demo runs where per-row random strides are data-starved
        self.fixed_pattern = fixed_pattern

    def __iter__(self):
        rng = np.random.RandomState(self.seed)
        global_step = rng.randint(1, 4) if self.fixed_pattern else None
        for _ in range(self.n_batches):
            # learnable synthetic structure: tokens follow a noisy
            # mod-vocab autoregression so loss can actually decrease
            base = rng.randint(0, self.vocab, (self.batch, 1))
            steps = (global_step if self.fixed_pattern
                     else rng.randint(1, 4, (self.batch, 1)))
            pos = np.arange(self.seq_len)[None, :]
            toks = (base + steps * pos) % self.vocab
            noise = rng.rand(self.batch, self.seq_len) < 0.05
            toks = np.where(noise, rng.randint(0, self.vocab, toks.shape),
                            toks)
            yield {"tokens": toks.astype(np.int32)}


class DataIterator:
    """Batches decoded records from a RecordReader, with shuffling
    (random seek makes shuffling cheap) and a decode_fn per record."""

    def __init__(self, reader, batch: int, decode_fn: Callable[[bytes], np.ndarray],
                 shuffle: bool = True, seed: int = 0, drop_last: bool = True):
        self.reader, self.batch, self.decode_fn = reader, batch, decode_fn
        self.shuffle, self.seed, self.drop_last = shuffle, seed, drop_last

    def __iter__(self):
        order = np.arange(len(self.reader))
        if self.shuffle:
            np.random.RandomState(self.seed).shuffle(order)
        buf = []
        for i in order:
            buf.append(self.decode_fn(self.reader.read(int(i))))
            if len(buf) == self.batch:
                yield np.stack(buf)
                buf = []
        if buf and not self.drop_last:
            yield np.stack(buf)


class PrefetchIterator:
    """Background-thread prefetch with a bounded queue."""

    _SENTINEL = object()

    def __init__(self, it, depth: int = 4, num_threads: int = 1):
        self._it = it
        self.depth = depth
        self.num_threads = num_threads

    def __iter__(self):
        q: queue.Queue = queue.Queue(maxsize=self.depth)
        src = iter(self._it)
        lock = threading.Lock()
        n_done = [0]

        def worker():
            while True:
                with lock:
                    try:
                        item = next(src)
                    except StopIteration:
                        break
                q.put(item)
            with lock:
                n_done[0] += 1
                if n_done[0] == self.num_threads:
                    q.put(self._SENTINEL)

        threads = [threading.Thread(target=worker, daemon=True)
                   for _ in range(self.num_threads)]
        for t in threads:
            t.start()
        while True:
            item = q.get()
            if item is self._SENTINEL:
                break
            yield item
