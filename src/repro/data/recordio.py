"""Packed record file with an index for sequential AND random seek
(MXNet §2.4: "tools to pack arbitrary sized examples into a single compact
file to facilitate both sequential and random seek").

Format: each record is [magic u32][length u32][crc32 u32][payload bytes],
with a sidecar ``.idx`` file of u64 offsets so ``read(i)`` is one seek.
"""
from __future__ import annotations

import struct
import zlib
from pathlib import Path

MAGIC = 0x4D584E54  # "MXNT"
_HDR = struct.Struct("<III")


class RecordWriter:
    def __init__(self, path: str):
        self.path = Path(path)
        self._f = open(path, "wb")
        self._offsets: list[int] = []

    def write(self, payload: bytes):
        self._offsets.append(self._f.tell())
        self._f.write(_HDR.pack(MAGIC, len(payload),
                                zlib.crc32(payload) & 0xFFFFFFFF))
        self._f.write(payload)

    def close(self):
        self._f.close()
        with open(str(self.path) + ".idx", "wb") as f:
            f.write(struct.pack("<Q", len(self._offsets)))
            for off in self._offsets:
                f.write(struct.pack("<Q", off))

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()


class RecordReader:
    """Random-seek reader over a packed record file."""

    def __init__(self, path: str):
        self.path = Path(path)
        self._f = open(path, "rb")
        with open(str(path) + ".idx", "rb") as f:
            (n,) = struct.unpack("<Q", f.read(8))
            self._offsets = [struct.unpack("<Q", f.read(8))[0]
                             for _ in range(n)]

    def __len__(self):
        return len(self._offsets)

    def read(self, i: int) -> bytes:
        self._f.seek(self._offsets[i])
        magic, length, crc = _HDR.unpack(self._f.read(_HDR.size))
        if magic != MAGIC:
            raise IOError(f"bad magic at record {i}")
        payload = self._f.read(length)
        if (zlib.crc32(payload) & 0xFFFFFFFF) != crc:
            raise IOError(f"crc mismatch at record {i}")
        return payload

    def __iter__(self):
        for i in range(len(self)):
            yield self.read(i)

    def close(self):
        self._f.close()


def pack_records(path: str, payloads) -> int:
    with RecordWriter(path) as w:
        n = 0
        for p in payloads:
            w.write(p)
            n += 1
    return n
