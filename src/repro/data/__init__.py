from .recordio import RecordWriter, RecordReader, pack_records
from .pipeline import DataIterator, PrefetchIterator, SyntheticLM

__all__ = ["RecordWriter", "RecordReader", "pack_records", "DataIterator",
           "PrefetchIterator", "SyntheticLM"]
