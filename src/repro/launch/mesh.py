"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state.  Single pod: 256 chips (16 data × 16 model).
Multi-pod: 2 pods × 256 = 512 chips with a leading "pod" axis.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke runs of the pjit code path."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants (roofline targets; DESIGN.md §6)
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
CHIPS_PER_POD = 256
