"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state.  Single pod: 256 chips (16 data × 16 model).
Multi-pod: 2 pods × 256 = 512 chips with a leading "pod" axis.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, pp_stages: int = 1):
    """``pp_stages > 1`` carves a leading ``stage`` axis out of the data
    axis (DESIGN.md §10): chips-per-pod stays 256, the gradient-worker
    count shrinks to ``16 // pp_stages`` — the stage axis carries layer
    groups, not replicas."""
    if pp_stages < 1 or 16 % pp_stages:
        raise ValueError(f"pp_stages must divide the 16-way data axis, "
                         f"got {pp_stages}")
    shape = (16 // pp_stages, 16)
    axes = ("data", "model")
    if pp_stages > 1:
        shape = (pp_stages,) + shape
        axes = ("stage",) + axes
    if multi_pod:
        shape = (2,) + shape
        axes = ("pod",) + axes
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke runs of the pjit code path."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants (roofline targets; DESIGN.md §6)
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
CHIPS_PER_POD = 256
