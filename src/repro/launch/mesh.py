"""Production mesh construction + the multi-host entry point.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state.  Single pod: 256 chips (16 data × 16 model).
Multi-pod: 2 pods × 256 = 512 chips with a leading "pod" axis.

Multi-host (DESIGN.md §15): :func:`initialize_distributed` joins this
process to a ``jax.distributed`` group — addressing comes from explicit
arguments, or the ``REPRO_COORDINATOR`` / ``REPRO_NUM_PROCS`` /
``REPRO_PROC_ID`` environment (what ``repro.launch.multihost`` exports to
its workers).  :func:`make_distributed_mesh` then builds a process-major
``(pod, data, model)`` mesh over the job's global devices, so the same
dryrun meshes run on real pods and on N local CPU processes.
"""
from __future__ import annotations

import os

import jax
import numpy as np


def initialize_distributed(coordinator_address: str | None = None,
                           num_processes: int | None = None,
                           process_id: int | None = None) -> bool:
    """Join (or no-op re-join) a ``jax.distributed`` process group.

    Arguments fall back to the ``REPRO_COORDINATOR`` /
    ``REPRO_NUM_PROCS`` / ``REPRO_PROC_ID`` environment; with neither,
    the call is the single-process identity (returns False).  Safe to
    call twice — an already-initialized group is left untouched.  On the
    CPU backend the gloo collectives implementation is selected so
    cross-process psums actually work (the per-process device count is an
    *environment* matter: set ``XLA_FLAGS=--xla_force_host_platform_-
    device_count=L`` before the first jax use, as the multihost launcher
    does for its workers).
    """
    coordinator_address = (coordinator_address
                           or os.environ.get("REPRO_COORDINATOR"))
    if num_processes is None and "REPRO_NUM_PROCS" in os.environ:
        num_processes = int(os.environ["REPRO_NUM_PROCS"])
    if process_id is None and "REPRO_PROC_ID" in os.environ:
        process_id = int(os.environ["REPRO_PROC_ID"])
    if coordinator_address is None:
        return False
    if num_processes is None or process_id is None:
        raise ValueError("distributed init needs num_processes and "
                         "process_id alongside the coordinator address")
    from jax._src import distributed as _dist
    if getattr(_dist.global_state, "client", None) is not None:
        return True     # already in a group
    try:   # CPU collectives backend: only gloo supports cross-process
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:  # pragma: no cover - config renamed on newer jax
        pass
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=num_processes,
                               process_id=process_id)
    return True


def make_distributed_mesh(*, model_axis: int = 1):
    """Process-major ``(pod, data, model)`` mesh over every device of the
    current ``jax.distributed`` job: the pod axis IS the process index
    (each host's local devices form its data×model block), so per-host
    batch slices drop into the global batch with no resharding and the
    eventual-consistency pod boundary coincides with the host boundary.
    """
    procs = jax.process_count()
    devs = jax.devices()
    local = len(devs) // procs
    if local * procs != len(devs):
        raise ValueError(f"{len(devs)} devices do not split over "
                         f"{procs} processes")
    if model_axis < 1 or local % model_axis:
        raise ValueError(f"model_axis {model_axis} must divide the "
                         f"per-process device count {local}")
    shape = (procs, local // model_axis, model_axis)
    # plain reshape, NOT mesh_utils.create_device_mesh: jax.devices() is
    # process-major, and keeping that order is the whole point
    return jax.sharding.Mesh(np.array(devs).reshape(shape),
                             ("pod", "data", "model"))


def make_production_mesh(*, multi_pod: bool = False, pp_stages: int = 1,
                         distributed: bool = False):
    """``pp_stages > 1`` carves a leading ``stage`` axis out of the data
    axis (DESIGN.md §10): chips-per-pod stays 256, the gradient-worker
    count shrinks to ``16 // pp_stages`` — the stage axis carries layer
    groups, not replicas.

    ``distributed=True`` runs :func:`initialize_distributed` (env
    addressing) first, so the same 256/512-chip shapes assemble from a
    real multi-host job's global devices; the device count must still
    match the production topology — for arbitrary process×device
    geometries (CI's N-process CPU runs) use :func:`make_distributed_mesh`.
    """
    if distributed:
        initialize_distributed()
    if pp_stages < 1 or 16 % pp_stages:
        raise ValueError(f"pp_stages must divide the 16-way data axis, "
                         f"got {pp_stages}")
    shape = (16 // pp_stages, 16)
    axes = ("data", "model")
    if pp_stages > 1:
        shape = (pp_stages,) + shape
        axes = ("stage",) + axes
    if multi_pod:
        shape = (2,) + shape
        axes = ("pod",) + axes
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke runs of the pjit code path."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants (roofline targets; DESIGN.md §6)
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
CHIPS_PER_POD = 256
