"""Multi-host bootstrap: coordinator/worker launch for jax.distributed.

The real-cluster shape (DESIGN.md §15): one process per host joins the
group via :func:`repro.launch.mesh.initialize_distributed` (env-var or
CLI addressing), builds the process-major ``(pod, data, model)`` mesh,
and trains with per-host sharded data.  The SAME entry point is the CI
harness — ``--local-procs N`` forks N workers on this machine, each a
separate jax process with its own ``XLA_FLAGS``-forced device count, so
a laptop or a CI runner exercises genuine cross-process collectives
(gloo) without a pod.

Driver (spawns workers, validates their reports)::

    PYTHONPATH=src python -m repro.launch.multihost \\
        --local-procs 4 --task smoke --metrics-dir /tmp/mh

Worker (what the driver execs; on a real cluster, run one per host with
REPRO_COORDINATOR/REPRO_NUM_PROCS/REPRO_PROC_ID exported, or pass
``--coordinator host:port --num-procs N --proc-id I``)::

    PYTHONPATH=src python -m repro.launch.multihost --worker --task smoke

Tasks:

* ``smoke``    — short real training run (reduced arch, Trainer.fit) with
  ``--sync-mode``; per-process metrics land in ``proc<i>.jsonl``.
* ``parity``   — the eventual-vs-sequential gate: both modes trained on
  identical data; final params must be bit-identical at staleness 0, and
  every process must report the same losses.
* ``elastic``  — checkpoint under one process count, restore + continue
  under another (the driver runs the two groups back to back).
* ``shard_check`` — every process reports its RecordIO shard assignment
  and stream checksums; the driver proves shards are disjoint, cover the
  epoch, and concatenate to the single-host stream.
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import subprocess
import sys
import time
import zlib
from pathlib import Path

TASKS = ("smoke", "parity", "elastic", "shard_check")


# ---------------------------------------------------------------------------
# worker side

def _result_path(metrics_dir: str) -> Path:
    import jax
    return Path(metrics_dir) / f"proc{jax.process_index()}.jsonl"


def _report(metrics_dir: str, record: dict):
    p = _result_path(metrics_dir)
    p.parent.mkdir(parents=True, exist_ok=True)
    with open(p, "a") as f:
        f.write(json.dumps(record) + "\n")


def _tree_crc(tree) -> int:
    """Order-stable crc32 over every leaf's bytes (replicated trees give
    the same value on every process — the cross-host parity probe)."""
    import jax
    import numpy as np
    crc = 0
    for leaf in jax.tree.leaves(tree):
        crc = zlib.crc32(np.ascontiguousarray(np.asarray(leaf)).tobytes(),
                         crc)
    return crc


def _smoke_cfg(vocab: int = 32):
    from repro.configs import get_config
    from repro.models import reduced
    return reduced(get_config("qwen1.5-0.5b"), vocab=vocab, n_layers=2,
                   d_model=64, d_ff=128)


def _train(mesh, *, sync_mode: str, max_staleness: int, steps: int,
           batch: int, seed: int = 0, state=None, start_step: int = 0):
    """One short Trainer.fit over the per-host shard of the synthetic
    stream; returns (trainer, params, history)."""
    import jax
    from repro.data import PrefetchIterator, SyntheticLM
    from repro.train import TrainConfig, Trainer
    cfg = _smoke_cfg()
    tcfg = TrainConfig(lr=1e-2, total_steps=steps, log_every=max(steps, 1),
                       warmup_steps=1, sync_mode=sync_mode,
                       max_staleness=max_staleness, bucket_mb=0.001)
    data = SyntheticLM(cfg.vocab, 16, batch, seed=7, n_batches=steps,
                       process_index=jax.process_index(),
                       process_count=jax.process_count())
    it = iter(PrefetchIterator(data, depth=2))
    for _ in range(start_step):
        next(it, None)
    with jax.set_mesh(mesh):
        tr = Trainer(cfg, tcfg)
        params, opt = tr.fit(it, seed=seed, state=state,
                             start_step=start_step)
    return tr, params, tr.history


def _task_smoke(args, mesh):
    import jax
    tr, params, hist = _train(mesh, sync_mode=args.sync_mode,
                              max_staleness=args.max_staleness,
                              steps=args.steps, batch=args.batch)
    stale = (tr._ev.max_observed_staleness if tr._ev is not None else 0)
    _report(args.metrics_dir, {
        "task": "smoke", "proc": jax.process_index(),
        "sync_mode": args.sync_mode, "max_staleness": args.max_staleness,
        "observed_staleness": stale,
        "losses": [h["loss"] for h in hist],
        "params_crc": _tree_crc(params)})
    assert stale <= args.max_staleness, (stale, args.max_staleness)


def _task_parity(args, mesh):
    """Eventual at staleness 0 vs sequential: bit-identical params."""
    import jax
    import numpy as np
    _, p_seq, h_seq = _train(mesh, sync_mode="sequential", max_staleness=0,
                             steps=args.steps, batch=args.batch)
    _, p_ev, h_ev = _train(mesh, sync_mode="eventual", max_staleness=0,
                           steps=args.steps, batch=args.batch)
    for a, b in zip(jax.tree.leaves(p_seq), jax.tree.leaves(p_ev)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert [h["loss"] for h in h_seq] == [h["loss"] for h in h_ev]
    _report(args.metrics_dir, {
        "task": "parity", "proc": jax.process_index(),
        "losses": [h["loss"] for h in h_seq],
        "params_crc": _tree_crc(p_seq), "bit_exact": True})


def _task_elastic(args, mesh):
    """Phase is selected by --elastic-phase: 'save' trains then commits a
    checkpoint (process 0 writes; params are replicated); 'restore' —
    typically under a DIFFERENT process count — loads it, proves cross-
    process parity, and continues training."""
    import jax
    from repro.train.checkpoint import load_checkpoint, save_checkpoint
    ckpt = str(Path(args.metrics_dir) / "elastic_ckpt")
    if args.elastic_phase == "save":
        _, params, hist = _train(mesh, sync_mode=args.sync_mode,
                                 max_staleness=args.max_staleness,
                                 steps=args.steps, batch=args.batch)
        if jax.process_index() == 0:
            save_checkpoint(ckpt, {"params": params}, step=args.steps - 1)
        _report(args.metrics_dir, {
            "task": "elastic_save", "proc": jax.process_index(),
            "procs": jax.process_count(), "params_crc": _tree_crc(params),
            "losses": [h["loss"] for h in hist]})
        return
    # restore under this (different) process count
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P
    restored, step = load_checkpoint(ckpt)
    rep = NamedSharding(mesh, P())
    params = jax.tree.map(lambda x: jax.device_put(x, rep),
                          restored["params"])
    crc = _tree_crc(params)
    with jax.set_mesh(mesh):
        from repro.train import TrainConfig, Trainer
        tr = Trainer(_smoke_cfg(), TrainConfig(
            lr=1e-2, total_steps=step + 1 + args.steps,
            log_every=1, warmup_steps=1, sync_mode=args.sync_mode,
            max_staleness=args.max_staleness, bucket_mb=0.001))
        opt = tr.optimizer.init(params)
        from repro.data import SyntheticLM
        data = SyntheticLM(_smoke_cfg().vocab, 16, args.batch, seed=7,
                           n_batches=step + 1 + args.steps,
                           process_index=jax.process_index(),
                           process_count=jax.process_count())
        it = iter(data)
        for _ in range(step + 1):
            next(it, None)
        params2, _ = tr.fit(it, state=(params, opt), start_step=step + 1)
    _report(args.metrics_dir, {
        "task": "elastic_restore", "proc": jax.process_index(),
        "procs": jax.process_count(), "restored_step": step,
        "restored_crc": crc, "continued_crc": _tree_crc(params2),
        "losses": [h["loss"] for h in tr.history]})


def _task_shard_check(args, mesh):
    """Per-host RecordIO shard assignment: report this host's record
    indices and stream checksum; assert the local stream equals the
    matching row-slice of a single-host iterator."""
    import jax
    import numpy as np
    from repro.data import DataIterator, RecordReader
    from repro.data.pipeline import global_batch_slice
    path = str(Path(args.metrics_dir) / "shards.rec")  # driver pre-writes
    decode = lambda b: np.frombuffer(b, np.int32)
    pi, pc = jax.process_index(), jax.process_count()
    it = DataIterator(RecordReader(path), batch=args.batch,
                      decode_fn=decode, seed=3, process_index=pi,
                      process_count=pc)
    ref = DataIterator(RecordReader(path), batch=args.batch,
                       decode_fn=decode, seed=3)
    lo, hi = global_batch_slice(args.batch, pi, pc)
    crc = 0
    n_local = 0
    for mine, full in zip(it, ref):
        np.testing.assert_array_equal(mine, full[lo:hi])
        crc = zlib.crc32(np.ascontiguousarray(mine).tobytes(), crc)
        n_local += mine.shape[0]
    _report(args.metrics_dir, {
        "task": "shard_check", "proc": pi, "procs": pc,
        "record_indices": [int(i) for i in it.record_indices()],
        "n_local": n_local, "stream_crc": crc})


def run_worker(args) -> int:
    # join the group BEFORE any other jax device use; addressing via CLI
    # flags if given, else the REPRO_* env the driver exported
    from repro.launch.mesh import (initialize_distributed,
                                   make_distributed_mesh)
    initialize_distributed(args.coordinator, args.num_procs, args.proc_id)
    import jax
    mesh = make_distributed_mesh()
    task_fn = {"smoke": _task_smoke, "parity": _task_parity,
               "elastic": _task_elastic,
               "shard_check": _task_shard_check}[args.task]
    task_fn(args, mesh)
    # per-process metrics registry -> the proc JSONL (the CI artifact)
    from repro import obs
    obs.get_metrics().dump_jsonl(str(_result_path(args.metrics_dir)))
    print(f"[proc {jax.process_index()}] task {args.task} OK", flush=True)
    return 0


# ---------------------------------------------------------------------------
# driver side

def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _spawn_group(args, n_procs: int, extra: list[str]) -> None:
    """Fork n_procs workers (one jax process each), stream their output,
    fail loudly on any nonzero exit or on timeout."""
    port = _free_port()
    procs = []
    for i in range(n_procs):
        env = dict(os.environ)
        env.update(
            REPRO_COORDINATOR=f"127.0.0.1:{port}",
            REPRO_NUM_PROCS=str(n_procs), REPRO_PROC_ID=str(i),
            XLA_FLAGS="--xla_force_host_platform_device_count="
                      f"{args.local_devices}")
        cmd = [sys.executable, "-m", "repro.launch.multihost", "--worker",
               "--task", args.task, "--metrics-dir", args.metrics_dir,
               "--steps", str(args.steps), "--batch", str(args.batch),
               "--sync-mode", args.sync_mode,
               "--max-staleness", str(args.max_staleness), *extra]
        procs.append(subprocess.Popen(cmd, env=env))
    deadline = time.time() + args.timeout
    failed = []
    for i, p in enumerate(procs):
        try:
            rc = p.wait(timeout=max(deadline - time.time(), 1))
        except subprocess.TimeoutExpired:
            for q in procs:
                q.kill()
            raise SystemExit(f"worker {i} timed out after {args.timeout}s")
        if rc != 0:
            failed.append((i, rc))
    if failed:
        raise SystemExit(f"workers failed: {failed}")


def _load_reports(metrics_dir: str, task: str) -> list[dict]:
    out = []
    for p in sorted(Path(metrics_dir).glob("proc*.jsonl")):
        for line in p.read_text().splitlines():
            rec = json.loads(line)
            if rec.get("task", "").startswith(task):
                out.append(rec)
    return out


def _check_parity(reports: list[dict]):
    crcs = {r["params_crc"] for r in reports}
    losses = {tuple(r["losses"]) for r in reports}
    if len(crcs) != 1 or len(losses) != 1:
        raise SystemExit(f"cross-process divergence: crcs={crcs} "
                         f"losses={losses}")


def _check_shards(reports: list[dict], n_records: int, batch: int):
    all_idx: list[int] = []
    for r in reports:
        all_idx.extend(r["record_indices"])
    if len(all_idx) != len(set(all_idx)):
        raise SystemExit("per-host shards overlap")
    n_full = (n_records // batch) * batch
    if len(set(all_idx)) != n_full:
        raise SystemExit(f"shards cover {len(set(all_idx))} records, "
                         f"expected the full epoch {n_full}")


def run_driver(args) -> int:
    Path(args.metrics_dir).mkdir(parents=True, exist_ok=True)
    for old in Path(args.metrics_dir).glob("proc*.jsonl"):
        old.unlink()
    if args.task == "shard_check":
        import numpy as np
        from repro.data import pack_records
        rng = np.random.default_rng(0)
        payloads = [rng.integers(0, 1000, 8, dtype=np.int32).tobytes()
                    for _ in range(args.n_records)]
        pack_records(str(Path(args.metrics_dir) / "shards.rec"), payloads)
    if args.task == "elastic":
        # checkpoint under N procs, restore + continue under M != N
        _spawn_group(args, args.local_procs, ["--elastic-phase", "save"])
        restore = args.restore_procs or (4 if args.local_procs == 2
                                         else max(args.local_procs // 2, 1))
        _spawn_group(args, restore, ["--elastic-phase", "restore"])
        saves = _load_reports(args.metrics_dir, "elastic_save")
        rests = _load_reports(args.metrics_dir, "elastic_restore")
        _check_parity([{**r, "losses": []} for r in saves])
        save_crc = saves[0]["params_crc"]
        for r in rests:
            if r["restored_crc"] != save_crc:
                raise SystemExit(
                    f"elastic restore diverged: saved crc {save_crc}, "
                    f"proc {r['proc']} restored {r['restored_crc']}")
        _check_parity([{"params_crc": r["continued_crc"],
                        "losses": r["losses"]} for r in rests])
        print(f"elastic OK: saved@{args.local_procs} procs, "
              f"restored+continued@{restore} procs, crc {save_crc}")
        return 0
    _spawn_group(args, args.local_procs, [])
    reports = _load_reports(args.metrics_dir, args.task)
    if len(reports) != args.local_procs:
        raise SystemExit(f"expected {args.local_procs} reports, "
                         f"got {len(reports)}")
    if args.task == "smoke" and args.max_staleness > 0:
        # bounded-staleness smoke: per-pod params legitimately diverge
        # (each pod integrates its own local+stored-remote gradient view
        # while a bucket is stale), so the gate is the staleness bound +
        # finite losses, not cross-process crc equality
        import math
        for r in reports:
            if r["observed_staleness"] > args.max_staleness:
                raise SystemExit(f"proc {r['proc']} staleness "
                                 f"{r['observed_staleness']} > bound "
                                 f"{args.max_staleness}")
            if not all(math.isfinite(x) for x in r["losses"]):
                raise SystemExit(f"proc {r['proc']} non-finite losses: "
                                 f"{r['losses']}")
        print(f"smoke OK across {args.local_procs} procs: staleness "
              f"<= {args.max_staleness}, crcs "
              f"{sorted({r['params_crc'] for r in reports})}")
    elif args.task in ("smoke", "parity"):
        _check_parity(reports)
        print(f"{args.task} OK across {args.local_procs} procs: "
              f"losses {reports[0]['losses']}")
    else:  # shard_check
        _check_shards(reports, args.n_records, args.batch)
        print(f"shard_check OK: {args.local_procs} disjoint shards cover "
              f"the epoch")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--worker", action="store_true",
                    help="run as ONE process of the group (driver-internal "
                         "on CI; on a real cluster, one per host)")
    ap.add_argument("--task", choices=TASKS, default="smoke")
    ap.add_argument("--local-procs", type=int, default=2,
                    help="driver: number of worker processes to fork")
    ap.add_argument("--local-devices", type=int, default=2,
                    help="devices per worker process (XLA forced host "
                         "platform count)")
    ap.add_argument("--restore-procs", type=int, default=0,
                    help="elastic: process count for the restore phase "
                         "(default: 4 when saving at 2, else N/2)")
    ap.add_argument("--metrics-dir", default="multihost-report",
                    help="per-process JSONL reports + artifacts")
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--batch", type=int, default=8,
                    help="GLOBAL batch (split over processes)")
    ap.add_argument("--n-records", type=int, default=64,
                    help="shard_check: RecordIO file size")
    ap.add_argument("--sync-mode",
                    choices=["auto", "sequential", "eventual"],
                    default="sequential")
    ap.add_argument("--max-staleness", type=int, default=0)
    ap.add_argument("--timeout", type=float, default=600.0,
                    help="driver: per-group wall-clock budget (s)")
    ap.add_argument("--elastic-phase", choices=["save", "restore"],
                    default="save")
    # worker-side CLI addressing (overrides the REPRO_* env)
    ap.add_argument("--coordinator", default=None, metavar="HOST:PORT")
    ap.add_argument("--num-procs", type=int, default=None)
    ap.add_argument("--proc-id", type=int, default=None)
    args = ap.parse_args(argv)
    if args.worker:
        return run_worker(args)
    return run_driver(args)


if __name__ == "__main__":
    raise SystemExit(main())
