"""Step functions (pure, jit-able) shared by the dry-run, the trainer and
the serving engine: train_step (loss+grad+SGD-momentum), prefill_step,
decode_step — plus their abstract input specs for lowering.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro import obs
from repro.models import INPUT_SHAPES, ArchConfig, get_model


# ---------------------------------------------------------------------------
# optimizer (SGD + momentum; fp32 master momentum, same sharding as params)

def init_opt_state(params):
    return {"mom": jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32)}


def sgd_momentum_update(params, grads, opt_state, lr=1e-3, mu=0.9,
                        weight_decay=1e-4):
    def upd(p, g, m):
        g32 = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
        m = mu * m + g32
        return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

    flat = jax.tree.map(upd, params, grads, opt_state["mom"])
    new_p = jax.tree.map(lambda t: t[0], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"mom": new_m, "step": opt_state["step"] + 1}


# ---------------------------------------------------------------------------
# steps

def make_train_step(cfg: ArchConfig, lr=1e-3):
    model = get_model(cfg)
    # remat happens at super-block granularity inside the model (cfg.remat)

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(params, opt_state, batch):
        # named scopes land in the HLO op metadata, so a device profile
        # (jax.profiler.trace) shows fwd/bwd/update as labelled regions
        # that line up with the trainer's host-side "step" span
        with obs.named_scope("fwd_bwd"):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        with obs.named_scope("optimizer_update"):
            params, opt_state = sgd_momentum_update(params, grads, opt_state,
                                                    lr=lr)
        return params, opt_state, {"loss": loss, **metrics}

    return train_step


def make_prefill_step(cfg: ArchConfig):
    model = get_model(cfg)

    def prefill_step(params, batch):
        with obs.named_scope("prefill"):
            return model.prefill(params, batch)

    return prefill_step


def make_decode_step(cfg: ArchConfig):
    model = get_model(cfg)

    def decode_step(params, cache, batch):
        with obs.named_scope("decode"):
            return model.decode(params, cache, batch)

    return decode_step


# ---------------------------------------------------------------------------
# abstract inputs (§MULTI-POD DRY-RUN item 2: ShapeDtypeStruct stand-ins)

def input_specs(cfg: ArchConfig, shape_name: str):
    """All step inputs as ShapeDtypeStructs (weak-type-correct, shardable,
    no device allocation)."""
    shp = INPUT_SHAPES[shape_name]
    model = get_model(cfg)
    params = model.param_specs()
    if shp.kind == "train":
        batch = model.batch_specs("train", shp.global_batch, shp.seq_len)
        opt = jax.eval_shape(init_opt_state, params)
        return {"params": params, "opt_state": opt, "batch": batch}
    if shp.kind == "prefill":
        batch = model.batch_specs("prefill", shp.global_batch, shp.seq_len)
        return {"params": params, "batch": batch}
    # decode: one new token against a seq_len cache
    batch = model.batch_specs("decode", shp.global_batch, shp.seq_len)
    cache = model.cache_specs(shp.global_batch, shp.seq_len)
    return {"params": params, "cache": cache, "batch": batch}
