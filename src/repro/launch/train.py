"""Training launcher.

CPU smoke (reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --reduced --steps 50 --batch 8 --seq 64

Production (TPU pod; mesh built from the assignment's production shapes):
  python -m repro.launch.train --arch dbrx-132b --shape train_4k --mesh single
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ARCH_IDS, get_config
from repro.data import PrefetchIterator, SyntheticLM
from repro.models import reduced
from repro.train import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family variant (CPU smoke)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--vocab", type=int, default=0,
                    help="override vocab (reduced runs)")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default="checkpoints",
                    help="run directory for sharded step_<n> checkpoints "
                         "(DESIGN.md §12)")
    ap.add_argument("--sync-checkpoint", action="store_true",
                    help="serialize checkpoints on the step critical path "
                         "instead of the async background writer")
    ap.add_argument("--resume", action="store_true",
                    help="restore params+opt from the latest committed "
                         "checkpoint in --checkpoint-dir (elastic: the "
                         "target mesh may differ from the saved one) and "
                         "continue from the next step")
    ap.add_argument("--init-from", metavar="CKPT", default=None,
                    help="warm-start params from a checkpoint directory "
                         "(optimizer state fresh, step 0)")
    ap.add_argument("--mesh", choices=["host", "single", "multi", "dist"],
                    default="host",
                    help="'dist' builds a (pod, data, model) mesh over all "
                         "processes of a jax.distributed job (DESIGN.md §15; "
                         "launch via repro.launch.multihost or set the "
                         "REPRO_COORDINATOR/... env addressing)")
    ap.add_argument("--sync-mode", choices=["auto", "sequential", "eventual"],
                    default="auto",
                    help="cross-worker gradient sync: GSPMD-implicit, "
                         "explicit two-level every step, or bounded-staleness "
                         "eventual consistency (DESIGN.md §15)")
    ap.add_argument("--max-staleness", type=int, default=0,
                    help="staleness bound (steps) for --sync-mode eventual")
    ap.add_argument("--overlap", action="store_true",
                    help="bucketed gradient sync emitted inside backward "
                         "(DESIGN.md §7); numerically identical")
    ap.add_argument("--bucket-mb", type=float, default=4.0,
                    help="bucket byte cap in MiB for --overlap")
    ap.add_argument("--seq-shard", action="store_true",
                    help="sequence-sharded batches: S stays sharded over "
                         "'model' and attention runs the ring schedule "
                         "(DESIGN.md §8); numerically identical")
    ap.add_argument("--attn-impl", choices=["auto", "dense", "ring"],
                    default="auto",
                    help="attention implementation selection "
                         "(PerfFlags.attn_impl)")
    ap.add_argument("--pp-stages", type=int, default=1,
                    help="pipeline stages over the super-block stack "
                         "(the 'stage' mesh axis; DESIGN.md §10)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="micro-batches streamed through the 1F1B "
                         "pipeline schedule (--pp-stages)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="record engine/trainer spans and write a "
                         "Perfetto / chrome://tracing JSON (DESIGN.md §11)")
    ap.add_argument("--metrics", metavar="PATH", default=None,
                    help="write per-step records and the final metrics "
                         "snapshot as JSONL")
    args = ap.parse_args()

    from repro import obs
    if args.trace:
        obs.enable()

    if args.seq_shard or args.attn_impl != "auto":
        from repro.perf_flags import set_flags
        set_flags(seq_shard=args.seq_shard, attn_impl=args.attn_impl)

    cfg = get_config(args.arch)
    if args.reduced:
        over = {"vocab": args.vocab} if args.vocab else {}
        cfg = reduced(cfg, **over)

    if args.mesh == "dist":
        from repro.launch.mesh import (initialize_distributed,
                                       make_distributed_mesh)
        initialize_distributed()
        mesh = make_distributed_mesh()
        ctx = jax.set_mesh(mesh)
    elif args.mesh != "host":
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.mesh == "multi",
                                    pp_stages=args.pp_stages)
        ctx = jax.set_mesh(mesh)
    else:
        import contextlib
        ctx = contextlib.nullcontext()

    tcfg = TrainConfig(lr=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 10, 1),
                       checkpoint_every=args.checkpoint_every,
                       checkpoint_dir=args.checkpoint_dir,
                       checkpoint_async=not args.sync_checkpoint,
                       grad_clip=5.0, overlap=args.overlap,
                       bucket_mb=args.bucket_mb,
                       pp_stages=args.pp_stages,
                       microbatches=args.microbatches,
                       sync_mode=args.sync_mode,
                       max_staleness=args.max_staleness)
    # per-host shard of the synthetic stream (identity single-process):
    # every host derives the same global batches and keeps its own rows
    data = PrefetchIterator(
        SyntheticLM(cfg.vocab, args.seq, args.batch, n_batches=args.steps,
                    process_index=jax.process_index(),
                    process_count=jax.process_count()),
        depth=4)
    logger = None
    if args.metrics:
        from repro.obs import JsonlSink, MetricsLogger, StdoutSink
        logger = MetricsLogger([StdoutSink(), JsonlSink(args.metrics)])
    with ctx:
        state, start_step = None, 0
        if args.resume:
            from repro.train import latest_checkpoint, load_checkpoint
            ck = latest_checkpoint(args.checkpoint_dir)
            if ck is None:
                raise SystemExit(f"--resume: no committed checkpoint under "
                                 f"{args.checkpoint_dir}")
            # elastic: restored onto the AMBIENT mesh's rule table, which
            # may differ from the mesh the checkpoint was saved under
            restored, step = load_checkpoint(ck)
            state, start_step = ((restored["params"], restored["opt"]),
                                 step + 1)
            print(f"resumed from {ck} (step {step})")
        elif args.init_from:
            from repro.train import load_checkpoint
            restored, _ = load_checkpoint(args.init_from)
            params = restored.get("params", restored)
            print(f"warm-start params from {args.init_from}")
        tr = Trainer(cfg, tcfg, logger=logger)
        if args.init_from and not args.resume:
            state = (params, tr.optimizer.init(params))
        it = iter(data)
        for _ in range(start_step):     # fast-forward the token stream
            next(it, None)
        tr.fit(it, state=state, start_step=start_step)
    print("final:", tr.history[-1])
    if logger is not None:
        logger.close()
    if args.metrics:
        obs.get_metrics().dump_jsonl(args.metrics)
        print(f"metrics: {args.metrics}")
    if args.trace:
        obs.export(args.trace)
        print(f"trace: {args.trace} (open in ui.perfetto.dev or "
              f"chrome://tracing)")


if __name__ == "__main__":
    main()
