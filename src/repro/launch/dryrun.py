import os
# default to the 512-chip dry-run topology, preserving any other XLA flags
# the caller set — but never clobber an explicit device count (tests and
# benches import this module for its parsers after setting up smaller meshes)
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape) pair, lower + compile the step
function on the production meshes (16×16 single-pod, 2×16×16 multi-pod),
print/record memory_analysis (proves it fits) and cost_analysis
(FLOPs/bytes for §Roofline), and parse collective bytes out of the
compiled HLO.

Roofline probes: cost_analysis counts a lax.scan body once, so per-layer
costs come from compiling 1- and 2-superblock UNROLLED variants with
identical shardings; total = probe1 + (n_super-1) * (probe2 - probe1).

Usage:
  python -m repro.launch.dryrun --arch dbrx-132b --shape train_4k
  python -m repro.launch.dryrun --all            # every pair, both meshes
  python -m repro.launch.dryrun --all --mesh single --no-probes
"""
import argparse
import json
import re
import time
from dataclasses import replace
from pathlib import Path

import jax

from repro.configs import ARCH_IDS, LONG_CONTEXT_ARCHS, get_config
from repro.dist import (batch_pspecs, cache_pspecs, make_shardings,
                        param_pspecs)
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (input_specs, make_decode_step,
                                make_prefill_step, make_train_step)
from repro.models import INPUT_SHAPES
from jax.sharding import PartitionSpec as P, NamedSharding

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s+(?P<ty>\(?[a-z0-9\[\],{}\s]+\)?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\((?P<rest>[^\n]*)")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _group_size(rest: str) -> int:
    """Shard-group size of one collective (iota or explicit list form)."""
    m = _GROUP_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUP_LIST_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_bytes(hlo_text: str) -> dict:
    """Per-device link-bytes estimate for every collective in an HLO dump.

    From each instruction's RESULT bytes S and replica-group size g
    (ring-algorithm accounting):
      all-gather        S·(g-1)/g        (result = gathered)
      all-reduce        2·S·(g-1)/g
      reduce-scatter    S·(g-1)          (result = scattered shard)
      all-to-all        S·(g-1)/g
      collective-permute S
    ``raw`` keeps the plain result-bytes sums for reference.
    """
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    raw = dict.fromkeys(out, 0)
    counts = dict.fromkeys(out, 0)
    for m in _COLL_RE.finditer(hlo_text):
        op = m.group("op")
        g = _group_size(m.group("rest"))
        total = 0
        for dt, dims in _SHAPE_RE.findall(m.group("ty")):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        raw[op] += total
        counts[op] += 1
        if op == "all-gather":
            moved = total * (g - 1) / max(g, 1)
        elif op == "all-reduce":
            moved = 2 * total * (g - 1) / max(g, 1)
        elif op == "reduce-scatter":
            moved = total * (g - 1)
        elif op == "all-to-all":
            moved = total * (g - 1) / max(g, 1)
        else:
            moved = total
        out[op] += moved
    out["total"] = sum(out.values())
    out["raw"] = raw
    out["raw_total"] = sum(raw.values())
    out["counts"] = counts
    return out


def _step_and_specs(cfg, shape_name, mesh):
    """Build (step_fn, kwargs specs, in_shardings, donate) for a shape."""
    from repro.perf_flags import FLAGS
    shp = INPUT_SHAPES[shape_name]
    specs = input_specs(cfg, shape_name)
    # sequence sharding: full-sequence batches enter S-sharded over "model"
    # (DESIGN.md §8) so the ring path never gathers the sequence
    bkind = ("seq" if FLAGS.seq_shard and shp.kind in ("train", "prefill")
             else shp.kind)
    p_sh = make_shardings(mesh, param_pspecs(cfg, specs["params"], mesh))
    b_sh = make_shardings(mesh, batch_pspecs(cfg, specs["batch"], mesh,
                                             bkind))
    repl = NamedSharding(mesh, P())
    if shp.kind == "train":
        step = make_train_step(cfg)
        o_sh = {"mom": jax.tree.map(lambda s: s, p_sh), "step": repl}
        in_sh = (p_sh, o_sh, b_sh)
        out_sh = (p_sh, o_sh, jax.tree.map(lambda _: repl,
                                           jax.eval_shape(
                                               step, specs["params"],
                                               specs["opt_state"],
                                               specs["batch"])[2]))
        args = (specs["params"], specs["opt_state"], specs["batch"])
        donate = (0, 1)
    elif shp.kind == "prefill":
        step = make_prefill_step(cfg)
        out_shapes = jax.eval_shape(step, specs["params"], specs["batch"])
        logits_sh = repl
        c_sh = make_shardings(mesh, cache_pspecs(cfg, out_shapes[1], mesh))
        in_sh = (p_sh, b_sh)
        out_sh = (logits_sh, c_sh)
        args = (specs["params"], specs["batch"])
        donate = ()
    else:  # decode
        step = make_decode_step(cfg)
        c_sh = make_shardings(mesh, cache_pspecs(cfg, specs["cache"], mesh))
        in_sh = (p_sh, c_sh, b_sh)
        out_sh = (NamedSharding(mesh, P()), c_sh)
        args = (specs["params"], specs["cache"], specs["batch"])
        donate = (1,)
    return step, args, in_sh, out_sh, donate


class _CompiledCompat:
    """Delegating wrapper normalizing ``cost_analysis()`` to the modern
    dict form (older jax returns a one-dict-per-program list)."""

    def __init__(self, compiled):
        self._compiled = compiled

    def __getattr__(self, name):
        return getattr(self._compiled, name)

    def cost_analysis(self):
        ca = self._compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        return ca or {}


def lower_and_compile(cfg, shape_name, mesh):
    step, args, in_sh, out_sh, donate = _step_and_specs(cfg, shape_name, mesh)
    t0 = time.time()
    with jax.set_mesh(mesh):
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = _CompiledCompat(lowered.compile())
        t_compile = time.time() - t0
    return lowered, compiled, t_lower, t_compile


def analyze(compiled):
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    return {
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device": (mem.argument_size_in_bytes
                                + mem.output_size_in_bytes
                                + mem.temp_size_in_bytes
                                - mem.alias_size_in_bytes),
        },
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
        "collectives": coll,
    }


def probe_cfg(cfg, n_super):
    return replace(cfg, n_layers=len(cfg.pattern) * n_super)


def run_pair(arch: str, shape_name: str, multi_pod: bool,
             probes: bool = True, verbose: bool = True,
             seq_shard: bool = False, pp_stages: int = 1,
             microbatches: int = 1) -> dict:
    long_ctx = shape_name.startswith("long_500k")
    if long_ctx and arch not in LONG_CONTEXT_ARCHS and not seq_shard:
        return {"arch": arch, "shape": shape_name, "status": "SKIP",
                "reason": "pure full-attention arch; long_500k requires "
                          "sub-quadratic attention (DESIGN.md §5) or the "
                          "sequence-sharded ring path (--seq-shard, §8)"}
    cfg = get_config(arch, long_context=long_ctx, seq_shard=seq_shard)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "n_layers": cfg.n_layers, "n_super": cfg.n_super,
           "params": cfg.param_count(),
           "params_active": cfg.param_count(active_only=True),
           "seq_shard": seq_shard,
           "status": "OK"}
    shp = INPUT_SHAPES[shape_name]
    if shp.kind == "decode":
        # true vs padded serving-cache footprint (DESIGN.md §9): the dense
        # engine pays B x max_len rectangles; a paged cache pays only live
        # blocks — reported at full occupancy and at the S/2 mean of a
        # steady-state mixed-traffic batch
        from repro.core.memplan import (kv_cache_bytes_dense,
                                        kv_cache_bytes_paged)
        bs = 16
        B, S = shp.global_batch, shp.seq_len
        dense = kv_cache_bytes_dense(cfg, B, S)
        full = kv_cache_bytes_paged(cfg, [S] * B, bs)
        half = kv_cache_bytes_paged(cfg, [S // 2] * B, bs)
        # quantized pools (int8 codes + per-row f32 scales, DESIGN.md §13)
        full_q = kv_cache_bytes_paged(cfg, [S] * B, bs, kv_dtype="int8")
        rec["cache_footprint"] = {
            "block_size": bs,
            "dense_bytes": dense,
            "paged_bytes_full": full["bytes"],
            "paged_bytes_mixed_mean": half["bytes"],
            "padded_over_true_mixed": round(dense / max(half["bytes"], 1), 2),
            "paged_bytes_full_int8": full_q["bytes"],
            "fp_over_int8": round(full["bytes"] / max(full_q["bytes"], 1), 2),
        }
    if pp_stages > 1 and shp.kind == "train":
        # per-stage param/activation memplan of the 1F1B pipeline
        # (DESIGN.md §10): what each "stage" shard holds, the saved
        # microbatch residuals, and the activation hand-off bytes
        from repro.core.memplan import pipeline_stage_bytes
        n_data = (16 // pp_stages) * (2 if multi_pod else 1)
        rec["pipeline"] = pipeline_stage_bytes(
            cfg, n_stages=pp_stages, microbatches=microbatches,
            global_batch=shp.global_batch, seq_len=shp.seq_len,
            n_data=n_data)
        if verbose:
            p = rec["pipeline"]
            print(f"  [pipeline pp={pp_stages} M={microbatches}] "
                  f"stage params {p['stage_param_bytes']/2**30:.2f} GiB "
                  f"saved acts {p['stage_activation_bytes']/2**30:.2f} GiB "
                  f"bubble {p['bubble_fraction']:.3f}")
    from repro.perf_flags import FLAGS, set_flags
    prev_flags = (FLAGS.seq_shard, FLAGS.attn_impl)
    if seq_shard:
        set_flags(seq_shard=True, attn_impl="auto")
    try:
        lowered, compiled, t_l, t_c = lower_and_compile(cfg, shape_name, mesh)
        rec["full"] = analyze(compiled)
        rec["t_lower_s"] = round(t_l, 2)
        rec["t_compile_s"] = round(t_c, 2)
        if verbose:
            m = rec["full"]["memory"]
            print(f"  [{mesh_name}] lower {t_l:.1f}s compile {t_c:.1f}s "
                  f"peak/device {m['peak_per_device']/2**30:.2f} GiB "
                  f"coll {rec['full']['collectives']['total']/2**20:.1f} MiB")
        if probes:
            # 2- and 4-superblock UNROLLED probes (1-layer graphs trigger
            # partitioner edge cases; differences over {2,4} are stable)
            for n in (2, 4):
                if cfg.n_super < n:
                    continue
                _, c2, _, _ = lower_and_compile(probe_cfg(cfg, n),
                                                shape_name, mesh)
                rec[f"probe{n}"] = analyze(c2)
    except Exception as e:  # noqa: BLE001 — record failures, they are bugs
        rec["status"] = "FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
        if verbose:
            print(f"  [{mesh_name}] FAILED: {rec['error'][:200]}")
    finally:
        # restore only what we set — callers may hold other tuned flags
        set_flags(seq_shard=prev_flags[0], attn_impl=prev_flags[1])
    return rec


def save(rec: dict):
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    ring = "__ring" if rec.get("seq_shard") else ""
    name = f"{rec['arch']}__{rec['shape']}__{rec.get('mesh', 'skip')}{ring}.json"
    (OUT_DIR / name).write_text(json.dumps(rec, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--seq-shard", action="store_true",
                    help="sequence-sharded batches + ring attention "
                         "(PerfFlags.seq_shard; unlocks long_500k for "
                         "full-attention archs — DESIGN.md §8)")
    ap.add_argument("--pp-stages", type=int, default=1,
                    help="report the per-stage pipeline memplan (param/"
                         "activation bytes per 'stage' shard; DESIGN.md "
                         "§10) for train shapes")
    ap.add_argument("--microbatches", type=int, default=8,
                    help="micro-batch count for the --pp-stages memplan")
    ap.add_argument("--force", action="store_true",
                    help="recompute even if a result JSON exists")
    args = ap.parse_args()

    pairs = ([(a, s) for a in ARCH_IDS for s in INPUT_SHAPES]
             if args.all else [(args.arch, args.shape)])
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    ring = "__ring" if args.seq_shard else ""
    for arch, shape in pairs:
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            out = OUT_DIR / f"{arch}__{shape}__{mesh_name}{ring}.json"
            skip_name = OUT_DIR / f"{arch}__{shape}__skip.json"
            # a stale default-run skip (full attention × long_500k) must
            # not block the --seq-shard run that exists to unlock the pair
            skipped = skip_name.exists() and not args.seq_shard
            if not args.force and (out.exists() or skipped):
                continue
            print(f"== {arch} × {shape} × {mesh_name}"
                  + (" (seq-shard/ring)" if args.seq_shard else ""))
            # probes only needed on the single-pod mesh (roofline table)
            rec = run_pair(arch, shape, mp,
                           probes=(not args.no_probes) and not mp,
                           seq_shard=args.seq_shard,
                           pp_stages=args.pp_stages,
                           microbatches=args.microbatches)
            save(rec)
            failures += rec["status"] == "FAIL"
            if rec["status"] == "SKIP":
                break  # skip applies to both meshes
    print(f"done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
